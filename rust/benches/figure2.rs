//! Regenerates the paper's Figure 2: training-time comparison on the
//! eight p >> n data-set profiles (glmnet / Shotgun / L1_LS / SVEN CPU
//! vs SVEN XLA). Scale with SVEN_BENCH_SCALE=quick|mid|full.
//! Run: `cargo bench --bench figure2`
fn main() {
    let rows = sven::bench::figures::figure2(0);
    sven::bench::figures::write_csv("target/figure2.csv", &rows);
}
