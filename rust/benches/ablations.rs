//! Ablation studies (DESIGN.md §5): primal/dual crossover, warm starts,
//! gram caching, bucket-padding overhead.
//! Run: `cargo bench --bench ablations`
fn main() {
    sven::bench::figures::ablations(0);
}
