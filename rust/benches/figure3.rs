//! Regenerates the paper's Figure 3: training-time comparison on the
//! four n >> p data-set profiles, where SVEN's cost is dominated by the
//! one-off kernel (gram) computation.
//! Run: `cargo bench --bench figure3`
fn main() {
    let rows = sven::bench::figures::figure3(0);
    sven::bench::figures::write_csv("target/figure3.csv", &rows);
}
