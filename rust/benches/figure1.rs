//! Regenerates the paper's Figure 1: regularization paths of glmnet and
//! SVEN on the prostate-like data set match exactly.
//! Run: `cargo bench --bench figure1`
fn main() {
    let dev = sven::bench::figures::figure1(0);
    assert!(dev < 1e-3, "paths diverged: max dev {dev}");
    println!("\nFigure 1 reproduced: paths match (max dev {dev:.2e} < 1e-3)");
}
