//! Microbenchmarks of the substrate hot paths (gemm, gram, spmv, CD
//! epoch, Newton step) — the profile targets of EXPERIMENTS.md §Perf.
//!
//! Run: `cargo bench --bench micro` for the full shapes (including the
//! blocked-kernel acceptance shapes: gemm 1024³, the gram of an n=4096,
//! p=1024 design, and the sparse shapes at n=8192, p=4096, density 0.01),
//! or `cargo bench --bench micro -- --test` for the CI smoke mode (tiny
//! shapes, compile-and-run-once) that gates kernel regressions without
//! paying figure-scale runtimes.
use sven::bench::harness::measure;
use sven::data::{synth_regression, SynthSpec};
use sven::linalg::{Design, Mat};
use sven::rng::Rng;
use sven::solvers::glmnet::{self, GlmnetConfig};
use sven::solvers::svm::samples::reduction_labels;
use sven::solvers::svm::{primal_newton, PrimalOptions, ReducedSamples, SampleSet};

fn main() {
    let smoke = std::env::args().any(|a| a == "--test");
    let mut rng = Rng::seed_from(7);

    // Blocked-kernel micro-bench: naive seed kernel vs packed blocked,
    // serial and threaded (the tentpole's measured speedup).
    let (sp_gemm, sp_gram) = sven::bench::figures::linalg_micro(!smoke);
    if !smoke {
        println!(
            "blocked-vs-naive speedup: gemm {sp_gemm:.1}x, gram {sp_gram:.1}x \
             (acceptance: >= 2x with >= 4 threads)"
        );
    }

    // Microkernel dispatch bench: per-kernel in-L1 tile rooflines plus
    // the forced-scalar vs dispatched-SIMD gram comparison (asserts
    // cross-kernel numeric agreement even in smoke mode).
    let (sp_simd, frac) = sven::bench::figures::kernel_micro(!smoke);
    if !smoke {
        println!(
            "kernel dispatch: simd-over-scalar gram {sp_simd:.2}x at {:.0}% of its tile \
             roofline (acceptance: dispatched SIMD beats the autovectorized scalar \
             blocked kernel on gram builds)",
            frac * 100.0
        );
    }

    // Sparse-kernel micro-bench: serial vs threaded CSR matvec/matvec_t/
    // gram plus sparse-vs-dense CD at the paper's ~1e-2 density regime.
    let (sp_spmv, sp_sgram) = sven::bench::figures::sparse_micro(!smoke);
    if !smoke {
        println!(
            "sparse serial-vs-threaded speedup: spmv {sp_spmv:.1}x, gram {sp_sgram:.1}x \
             (acceptance: spmv >= 2x with >= 4 threads at n=8192, p=4096, d=0.01)"
        );
    }

    // Coordinator service micro-bench: point-job vs path-job throughput
    // through the worker pool, plus the shared prep cache's hit rate
    // (asserts the single-build invariant even in smoke mode).
    let (pt_rate, path_rate) = sven::bench::figures::service_micro(!smoke);
    if !smoke {
        println!(
            "service throughput: {pt_rate:.1} point jobs/s vs {path_rate:.1} \
             path points/s (path amortizes dispatch + warm starts)"
        );
    }

    // Path-engine micro-bench: fused multi-RHS panels vs repeated GEMVs,
    // gathered (shrinking) vs masked primal Newton, and segmented vs
    // single-worker path sweeps (asserts segment bit-identity even in
    // smoke mode).
    let (sp_panel, sp_newton, sp_seg) = sven::bench::figures::path_micro(!smoke);
    if !smoke {
        println!(
            "path engine speedups: multi-RHS panel {sp_panel:.1}x, gathered newton \
             {sp_newton:.1}x, segmented sweep {sp_seg:.1}x (acceptance: segmented > 1x \
             on >= 4 threads; gathered > 1x at sv-frac < 0.5)"
        );
    }

    // Batched-solve / CV micro-bench: width-1 CG vs blocked CG panels,
    // and k standalone fold path jobs vs one CvPath job (asserts
    // per-column and per-fold bit-identity even in smoke mode).
    let (sp_bcg, sp_cv) = sven::bench::figures::cv_micro(!smoke);
    if !smoke {
        println!(
            "batched-solve speedups: blocked CG @width 4 {sp_bcg:.1}x, CvPath vs \
             k-standalone {sp_cv:.2}x (acceptance: blocked CG > 1x at width >= 4 on \
             the bench shapes)"
        );
    }

    // Mixed-precision micro-bench: f64 vs f32 GEMV panel streaming plus
    // an F64-vs-MixedF32 solve (asserts refined-β agreement and that
    // refinement passes actually ran, even in smoke mode).
    let (sp_prec, prec_dev) = sven::bench::figures::precision_micro(!smoke);
    if !smoke {
        println!(
            "mixed precision: f32 panel streaming {sp_prec:.2}x over f64, refined beta \
             within {prec_dev:.1e} of f64 (acceptance: >= 1.5x on the bandwidth-bound \
             gemv pair; agreement asserted at every scale)"
        );
    }

    // Whole-screen micro-bench: R standalone Path jobs vs one
    // MultiResponse job (asserts per-response bit-identity, the
    // single-prep-build invariant, and fused width > 1 even in smoke
    // mode; the full run writes BENCH_PR8.json).
    let (sp_screen, screen_width) = sven::bench::figures::screen_micro(!smoke);
    if !smoke {
        println!(
            "whole-screen serving: MultiResponse vs R-standalone {sp_screen:.2}x \
             responses/s at max fused width {screen_width:.0} (acceptance: > 1x at \
             R = 64 with fused width > 1)"
        );
    }

    // Robustness micro-bench: shed latency, deadline-control overhead on
    // a path sweep, p50/p99 point-job latency under an injected fault
    // schedule, and checkpoint economics (per-point publish cost plus
    // resumed-vs-scratch retry latency). Asserts shed-builds-nothing and
    // deadline / fault-recovery / checkpoint-resume bit-identity even in
    // smoke mode; the full run writes BENCH_PR9.json and BENCH_PR10.json.
    let (sp_ctl, sp_fault) = sven::bench::figures::robustness_micro(!smoke);
    if !smoke {
        println!(
            "robustness: deadline-armed sweep {sp_ctl:.3}x the clean sweep, faulted p50 \
             {sp_fault:.2}x the clean p50 (acceptance: deadline overhead < 1.2x; every \
             faulted job recovers bit-identically)"
        );
    }

    let (warm, reps) = if smoke { (1, 2) } else { (2, 10) };

    // gemm through the Mat facade (includes dispatch + allocation)
    let e = if smoke { 128 } else { 256 };
    let a = Mat::from_fn(e, e, |_, _| rng.normal());
    let b = Mat::from_fn(e, e, |_, _| rng.normal());
    let m = measure(warm, reps, || a.matmul(&b));
    let flops = 2.0 * (e as f64).powi(3);
    println!(
        "gemm {e}^3 (Mat): median {:.3}ms  ({:.2} GFLOP/s)",
        m.summary.median() * 1e3,
        flops / m.summary.median() / 1e9
    );

    // gram through the Mat facade
    let (gr, gc) = if smoke { (192, 96) } else { (512, 256) };
    let g = Mat::from_fn(gr, gc, |_, _| rng.normal());
    let m = measure(warm, reps, || g.gram());
    println!(
        "gram {gr}x{gc} (AAᵀ): median {:.3}ms  ({:.2} GFLOP/s)",
        m.summary.median() * 1e3,
        (gr * gr * gc) as f64 / m.summary.median() / 1e9
    );

    // CD epoch
    let (cd_n, cd_p) = if smoke { (60, 300) } else { (200, 2000) };
    let d = synth_regression(&SynthSpec {
        n: cd_n,
        p: cd_p,
        support: 20.min(cd_p / 4),
        seed: 1,
        ..Default::default()
    });
    let lambda = glmnet::cd::lambda_max(&d.x, &d.y, 0.5) * 0.2;
    let m = measure(1, if smoke { 1 } else { 5 }, || {
        glmnet::solve_penalized(&d.x, &d.y, lambda, &GlmnetConfig::default(), None)
    });
    println!("glmnet solve {cd_n}x{cd_p}: median {:.3}ms", m.summary.median() * 1e3);

    // primal Newton on the reduction (implicit operator)
    let design: Design = d.x.clone().into();
    let samples = ReducedSamples::new(&design, &d.y, 1.0);
    let labels = reduction_labels(d.x.cols());
    let mm = measure(1, if smoke { 1 } else { 5 }, || {
        primal_newton(&samples, &labels, 10.0, &PrimalOptions::default(), None)
    });
    println!(
        "primal newton (m={}, d={}): median {:.3}ms",
        samples.m(),
        samples.d(),
        mm.summary.median() * 1e3
    );

    // XLA single solve latency (bucket-padded), if artifacts exist
    if let Ok(backend) = sven::runtime::XlaBackend::from_default_dir() {
        use sven::solvers::sven::Sven;
        let d2 = synth_regression(&SynthSpec {
            n: 100,
            p: 400,
            support: 10,
            seed: 2,
            ..Default::default()
        });
        let grid = {
            use sven::coordinator::{PathRunner, PathRunnerConfig};
            PathRunner::new(PathRunnerConfig { grid: 3, ..Default::default() }).derive_grid(&d2)
        };
        if let Some(pt) = grid.last() {
            let sven_xla = Sven::new(backend);
            let prob = sven::solvers::elastic_net::EnProblem::new(
                d2.x.clone(),
                d2.y.clone(),
                pt.t,
                pt.lambda2.max(1e-6),
            );
            let prep = sven_xla.prepare(&d2.x, &d2.y).unwrap();
            let mut scratch = sven::solvers::sven::SvmScratch::new();
            let m = measure(2, 10, || {
                sven_xla.solve_prepared(prep.as_ref(), &mut scratch, &prob, None, None).unwrap()
            });
            println!(
                "sven_xla solve 100x400 (prepared): median {:.3}ms",
                m.summary.median() * 1e3
            );
        }
    }
}
