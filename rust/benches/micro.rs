//! Microbenchmarks of the substrate hot paths (gemm, gram, CD epoch,
//! Newton step) — the profile targets of EXPERIMENTS.md §Perf.
//! Run: `cargo bench --bench micro`
use sven::bench::harness::measure;
use sven::data::{synth_regression, SynthSpec};
use sven::linalg::Mat;
use sven::rng::Rng;
use sven::solvers::glmnet::{self, GlmnetConfig};
use sven::solvers::svm::{primal_newton, PrimalOptions, ReducedSamples, SampleSet};
use sven::solvers::svm::samples::reduction_labels;

fn main() {
    let mut rng = Rng::seed_from(7);

    // gemm 256x256x256
    let a = Mat::from_fn(256, 256, |_, _| rng.normal());
    let b = Mat::from_fn(256, 256, |_, _| rng.normal());
    let m = measure(2, 10, || a.matmul(&b));
    let flops = 2.0 * 256f64.powi(3);
    println!(
        "gemm 256^3: median {:.3}ms  ({:.2} GFLOP/s)",
        m.summary.median() * 1e3,
        flops / m.summary.median() / 1e9
    );

    // gram 512x256
    let g = Mat::from_fn(512, 256, |_, _| rng.normal());
    let m = measure(2, 10, || g.gram());
    println!(
        "gram 512x256 (AAᵀ): median {:.3}ms  ({:.2} GFLOP/s)",
        m.summary.median() * 1e3,
        512.0 * 512.0 * 256.0 / m.summary.median() / 1e9
    );

    // CD epoch on 200x2000
    let d = synth_regression(&SynthSpec { n: 200, p: 2000, support: 20, seed: 1, ..Default::default() });
    let lambda = glmnet::cd::lambda_max(&d.x, &d.y, 0.5) * 0.2;
    let m = measure(1, 5, || {
        glmnet::solve_penalized(&d.x, &d.y, lambda, &GlmnetConfig::default(), None)
    });
    println!("glmnet solve 200x2000: median {:.3}ms", m.summary.median() * 1e3);

    // primal Newton on the reduction (implicit operator)
    let samples = ReducedSamples { x: &d.x, y: &d.y, t: 1.0 };
    let labels = reduction_labels(d.x.cols());
    let mm = measure(1, 5, || {
        primal_newton(&samples, &labels, 10.0, &PrimalOptions::default(), None)
    });
    println!(
        "primal newton (m={}, d={}): median {:.3}ms",
        samples.m(),
        samples.d(),
        mm.summary.median() * 1e3
    );

    // XLA single solve latency (bucket-padded), if artifacts exist
    if let Ok(backend) = sven::runtime::XlaBackend::from_default_dir() {
        use sven::solvers::sven::Sven;
        let d2 = synth_regression(&SynthSpec { n: 100, p: 400, support: 10, seed: 2, ..Default::default() });
        let grid = {
            use sven::coordinator::{PathRunner, PathRunnerConfig};
            PathRunner::new(PathRunnerConfig { grid: 3, ..Default::default() }).derive_grid(&d2)
        };
        if let Some(pt) = grid.last() {
            let sven_xla = Sven::new(backend);
            let prob = sven::solvers::elastic_net::EnProblem::new(
                d2.x.clone(), d2.y.clone(), pt.t, pt.lambda2.max(1e-6));
            let mut prep = sven_xla.prepare(&d2.x, &d2.y).unwrap();
            let m = measure(2, 10, || {
                sven_xla.solve_prepared(prep.as_mut(), &prob, None).unwrap()
            });
            println!("sven_xla solve 100x400 (prepared): median {:.3}ms", m.summary.median() * 1e3);
        }
    }
}
