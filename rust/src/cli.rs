//! Hand-rolled CLI (no clap offline). Subcommands:
//!
//! ```text
//! sven datasets                         list the 12 dataset profiles
//! sven artifacts                        artifact registry status
//! sven solve   --dataset GLI-85 [--t X --lambda2 Y] [--backend xla|rust]
//! sven path    --dataset GLI-85 [--grid 40] [--backend xla|rust]
//! sven serve   --requests 64 [--workers N] [--deadline-ms N] [--max-queue-depth N]   demo service run
//! sven screen  --responses 8 [--grid 16] [--workers N]   whole-screen multi-response job
//! ```

use crate::coordinator::{
    BackendChoice, JobError, JobKind, JobResult, PathRunner, PathRunnerConfig, Service,
    ServiceConfig, SubmitOptions,
};
use crate::data::{profile_by_name, ALL_PROFILES};
use crate::solvers::elastic_net::EnProblem;
use crate::solvers::glmnet::PathSettings;
use crate::solvers::sven::{RustBackend, Sven};
use crate::linalg::{set_global_kernel, set_global_precision, KernelChoice, KernelCtx, Precision};
use crate::util::fmt_duration;
use crate::util::parallel::{set_global_parallelism, Parallelism};
use anyhow::{anyhow, bail, Result};
use std::collections::HashMap;
use std::sync::Arc;
use std::time::Duration;

/// Parsed flags: `--key value` pairs plus positionals.
pub struct Args {
    pub positional: Vec<String>,
    pub flags: HashMap<String, String>,
}

/// Parse a raw arg list (everything after the subcommand).
pub fn parse_args(raw: &[String]) -> Result<Args> {
    let mut positional = Vec::new();
    let mut flags = HashMap::new();
    let mut it = raw.iter().peekable();
    while let Some(a) = it.next() {
        if let Some(key) = a.strip_prefix("--") {
            let val = match it.peek() {
                Some(v) if !v.starts_with("--") => it.next().unwrap().clone(),
                _ => "true".to_string(),
            };
            flags.insert(key.to_string(), val);
        } else {
            positional.push(a.clone());
        }
    }
    Ok(Args { positional, flags })
}

impl Args {
    pub fn get(&self, key: &str) -> Option<&str> {
        self.flags.get(key).map(|s| s.as_str())
    }

    pub fn get_f64(&self, key: &str) -> Result<Option<f64>> {
        match self.get(key) {
            None => Ok(None),
            Some(v) => Ok(Some(
                v.parse().map_err(|_| anyhow!("--{key} expects a number, got '{v}'"))?,
            )),
        }
    }

    pub fn get_usize(&self, key: &str) -> Result<Option<usize>> {
        match self.get(key) {
            None => Ok(None),
            Some(v) => Ok(Some(
                v.parse().map_err(|_| anyhow!("--{key} expects an integer, got '{v}'"))?,
            )),
        }
    }
}

const USAGE: &str = "\
SVEN — Support Vector Elastic Net (AAAI 2015 reproduction)

USAGE:
  sven <COMMAND> [FLAGS]

COMMANDS:
  datasets                 list the twelve dataset profiles
  artifacts                show artifact registry / compile status
  solve                    solve one Elastic Net problem
      --dataset NAME       profile name (see `sven datasets`)
      --seed N             generation seed            [default 0]
      --t X                L1 budget (default: from a path point)
      --lambda2 Y          L2 coefficient             [default 1.0]
      --backend xla|rust   SVM backend                [default rust]
      --threads N          linalg worker threads (0 = auto, 1 = serial)
      --kernel K           compute kernel: scalar|avx2|fma|auto [default auto]
      --precision P        compute precision: f64|mixed-f32|auto [default auto]
  path                     sweep a regularization path (paper protocol)
      --dataset NAME       profile name
      --seed N             generation seed            [default 0]
      --grid K             number of settings         [default 40]
      --backend xla|rust   SVM backend                [default rust]
      --threads N          linalg worker threads (0 = auto, 1 = serial)
      --kernel K           compute kernel: scalar|avx2|fma|auto [default auto]
      --precision P        compute precision: f64|mixed-f32|auto [default auto]
  serve                    demo coordinator run
      --requests N         number of jobs             [default 32]
      --workers N          pool size                  [default cpus]
      --deadline-ms N      per-job wall-clock budget; a deadline that
                           lands mid-sweep returns the solved prefix as
                           a truncated result (off by default)
      --max-queue-depth N  admission budget in grid-point solve units;
                           over-budget submissions are shed with an
                           overloaded error (off by default)
      --backend xla|rust   SVM backend                [default rust]
      --threads N          linalg worker threads (0 = auto, 1 = serial)
      --kernel K           compute kernel: scalar|avx2|fma|auto [default auto]
      --precision P        compute precision: f64|mixed-f32|auto [default auto]
  screen                   whole-screen serving: R responses, one design,
                           one shared preparation, fused batched sweeps
      --dataset NAME       profile name
      --seed N             generation seed            [default 0]
      --responses R        number of response vectors [default 8]
      --grid K             number of grid points      [default 16]
      --workers N          pool size                  [default cpus]
      --early-stop T       deviance-plateau threshold (off by default)
      --threads N          linalg worker threads (0 = auto, 1 = serial)
      --kernel K           compute kernel: scalar|avx2|fma|auto [default auto]
      --precision P        compute precision: f64|mixed-f32|auto [default auto]
  help                     show this message

Thread resolution when --threads is absent: PALLAS_NUM_THREADS (fallback
SVEN_THREADS), else the machine's available parallelism. For a fixed
kernel choice, all blocked kernels produce bit-identical results at any
thread count. Kernel resolution when --kernel is absent: PALLAS_KERNEL
(scalar|avx2|fma|auto), else the best SIMD tier the CPU supports.
Precision resolution when --precision is absent: PALLAS_PRECISION
(f64|mixed-f32|auto), else f64. mixed-f32 streams the primal Newton's
panel products in f32 and restores the f64 CG tolerance with iterative
refinement; results agree with f64 to solver tolerance (not bit-for-bit).
";

/// CLI entrypoint (used by `rust/src/main.rs`).
pub fn run() -> Result<()> {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let Some(cmd) = argv.first() else {
        print!("{USAGE}");
        return Ok(());
    };
    let args = parse_args(&argv[1..])?;
    match cmd.as_str() {
        "datasets" => cmd_datasets(),
        "artifacts" => cmd_artifacts(),
        "solve" => cmd_solve(&args),
        "path" => cmd_path(&args),
        "serve" => cmd_serve(&args),
        "screen" => cmd_screen(&args),
        "help" | "--help" | "-h" => {
            print!("{USAGE}");
            Ok(())
        }
        other => bail!("unknown command '{other}' (try `sven help`)"),
    }
}

fn cmd_datasets() -> Result<()> {
    println!(
        "{:<18} {:>9} {:>9} {:>8} {:>9} {:>7}  {}",
        "name", "paper n", "paper p", "ours n", "ours p", "regime", "about"
    );
    for p in &ALL_PROFILES {
        println!(
            "{:<18} {:>9} {:>9} {:>8} {:>9} {:>7}  {}",
            p.name,
            p.paper_n,
            p.paper_p,
            p.n,
            p.p,
            match p.regime {
                crate::data::Regime::PGreaterN => "p>>n",
                crate::data::Regime::NGreaterP => "n>>p",
            },
            p.about
        );
    }
    Ok(())
}

fn cmd_artifacts() -> Result<()> {
    let dir = crate::runtime::default_artifact_dir();
    let reg = crate::runtime::Registry::load(&dir)?;
    println!("artifact dir: {} ({} artifacts)", dir.display(), reg.artifacts.len());
    for a in &reg.artifacts {
        println!("  {:<24} kind={:?} n={} p={}", a.name, a.kind, a.n, a.p);
    }
    Ok(())
}

fn load_dataset(args: &Args) -> Result<crate::data::Dataset> {
    let name = args.get("dataset").unwrap_or("GLI-85");
    let seed = args.get_usize("seed")?.unwrap_or(0) as u64;
    let profile = profile_by_name(name)
        .ok_or_else(|| anyhow!("unknown dataset '{name}' (see `sven datasets`)"))?;
    crate::info!("generating {name} (n={}, p={})", profile.n, profile.p);
    Ok(profile.generate(seed))
}

/// Apply `--threads` to the process-wide parallelism setting.
fn apply_threads(args: &Args) -> Result<()> {
    if let Some(n) = args.get_usize("threads")? {
        let p = match n {
            0 => Parallelism::Auto,
            1 => Parallelism::None,
            k => Parallelism::Fixed(k),
        };
        set_global_parallelism(p);
        crate::info!("linalg parallelism: {} worker thread(s)", p.threads());
    }
    Ok(())
}

/// Apply `--kernel` to the process-wide compute-kernel dispatch
/// (`auto` clears any force back to `PALLAS_KERNEL`/CPU detection).
/// An unsupported force fails here with the dispatch error instead of
/// panicking on the first matrix product.
fn apply_kernel(args: &Args) -> Result<()> {
    if let Some(v) = args.get("kernel") {
        let choice = KernelChoice::parse(v)?;
        set_global_kernel(choice)?;
        crate::info!("compute {}", KernelCtx::current().describe());
    }
    Ok(())
}

/// Apply `--precision` to the process-wide compute-precision setting
/// (`auto` clears any force back to `PALLAS_PRECISION`/f64). A bad value
/// fails here with the parse error instead of panicking at the first
/// preparation.
fn apply_precision(args: &Args) -> Result<()> {
    if let Some(v) = args.get("precision") {
        let p = Precision::parse(v)?;
        set_global_precision(p);
        crate::info!("compute precision: {p}");
    }
    Ok(())
}

fn backend_choice(args: &Args) -> Result<BackendChoice> {
    match args.get("backend").unwrap_or("rust") {
        "rust" | "cpu" => Ok(BackendChoice::Rust),
        "xla" | "gpu" => Ok(BackendChoice::Xla),
        other => bail!("--backend must be 'rust' or 'xla', got '{other}'"),
    }
}

fn cmd_solve(args: &Args) -> Result<()> {
    apply_threads(args)?;
    apply_kernel(args)?;
    apply_precision(args)?;
    let data = load_dataset(args)?;
    let lambda2 = args.get_f64("lambda2")?.unwrap_or(1.0);
    // Default budget: the largest-support point of a short derived path.
    let t = match args.get_f64("t")? {
        Some(t) => t,
        None => {
            let runner = PathRunner::new(PathRunnerConfig {
                grid: 5,
                path: PathSettings { num_lambda: 30, ..Default::default() },
                ..Default::default()
            });
            let grid = runner.derive_grid(&data);
            grid.last()
                .map(|pt| pt.t)
                .ok_or_else(|| anyhow!("could not derive a default budget"))?
        }
    };
    let prob = EnProblem::new(data.x.clone(), data.y.clone(), t, lambda2);
    let sol = match backend_choice(args)? {
        BackendChoice::Rust => Sven::new(RustBackend::default()).solve(&prob)?,
        BackendChoice::Xla => {
            let backend = crate::runtime::XlaBackend::from_default_dir()?;
            Sven::new(backend).solve(&prob)?
        }
    };
    println!(
        "solver={} t={t:.4} lambda2={lambda2:.4} nnz={} objective={:.6} time={}",
        sol.solver.name(),
        sol.nnz(),
        sol.objective,
        fmt_duration(sol.seconds)
    );
    if let Some(d) = sol.degenerate {
        println!("degenerate: {d:?}");
    }
    Ok(())
}

fn cmd_path(args: &Args) -> Result<()> {
    apply_threads(args)?;
    apply_kernel(args)?;
    apply_precision(args)?;
    let data = load_dataset(args)?;
    let grid = args.get_usize("grid")?.unwrap_or(40);
    let runner = PathRunner::new(PathRunnerConfig { grid, ..Default::default() });
    let points = runner.derive_grid(&data);
    crate::info!("derived {} grid points", points.len());
    let results = match backend_choice(args)? {
        BackendChoice::Rust => {
            runner.run(&data, &Sven::new(RustBackend::default()), &points)?
        }
        BackendChoice::Xla => {
            let backend = crate::runtime::XlaBackend::from_default_dir()?;
            runner.run(&data, &Sven::new(backend), &points)?
        }
    };
    println!(
        "{:>10} {:>10} {:>6} {:>10} {:>12}",
        "t", "lambda2", "nnz", "time", "max|Δβ|"
    );
    for r in &results {
        println!(
            "{:>10.4} {:>10.4} {:>6} {:>10} {:>12.2e}",
            r.t,
            r.lambda2,
            r.nnz,
            fmt_duration(r.seconds),
            r.max_dev
        );
    }
    let dev = crate::coordinator::path::max_deviation(&results);
    println!("max deviation vs glmnet reference across path: {dev:.2e}");
    Ok(())
}

fn cmd_serve(args: &Args) -> Result<()> {
    apply_threads(args)?;
    apply_kernel(args)?;
    apply_precision(args)?;
    let requests = args.get_usize("requests")?.unwrap_or(32);
    let backend = backend_choice(args)?;
    let mut config = ServiceConfig::default();
    if let Some(w) = args.get_usize("workers")? {
        config.pool.workers = w;
    }
    if let Some(depth) = args.get_usize("max-queue-depth")? {
        config.max_queue_depth = Some(depth);
    }
    let options = SubmitOptions {
        deadline: args.get_usize("deadline-ms")?.map(|ms| Duration::from_millis(ms as u64)),
        ..Default::default()
    };
    let data = load_dataset(args)?;
    let runner = PathRunner::new(PathRunnerConfig {
        grid: requests.min(40),
        ..Default::default()
    });
    let grid = runner.derive_grid(&data);
    if grid.is_empty() {
        bail!("no active path points for this dataset");
    }
    let service = Service::start(config);
    let x = Arc::new(crate::linalg::Design::from(data.x.clone()));
    let y = Arc::new(data.y.clone());
    let timer = crate::util::Timer::start();
    let mut rxs = Vec::with_capacity(requests);
    let mut shed = 0usize;
    for i in 0..requests {
        let pt = &grid[i % grid.len()];
        let kind = JobKind::Point { t: pt.t, lambda2: pt.lambda2.max(1e-6) };
        match service.submit_with(1, x.clone(), y.clone(), kind, backend, options) {
            Ok(rx) => rxs.push(rx),
            Err(JobError::Overloaded { .. }) => shed += 1,
            Err(e) => return Err(e.into()),
        }
    }
    let mut ok = 0usize;
    for rx in rxs {
        if rx.recv()?.result.is_ok() {
            ok += 1;
        }
    }
    let wall = timer.elapsed();
    // Then the whole grid as one warm-start chained path job (the
    // paper's sweep as a single service workload), timed separately so
    // the point-job throughput above stays comparable across runs.
    let path_timer = crate::util::Timer::start();
    let path_points = match service.submit_path_with(
        1,
        x.clone(),
        y.clone(),
        runner.grid_points(&grid),
        backend,
        options,
    ) {
        Ok(path_rx) => match path_rx.recv()?.result {
            Ok(JobResult::Truncated { completed, total, .. }) => {
                println!("path job truncated by the deadline: {completed}/{total} points");
                completed
            }
            Ok(r) => r.expect_path().len(),
            Err(e) => {
                eprintln!("path job failed: {e}");
                0
            }
        },
        Err(e) => {
            eprintln!("path job rejected: {e}");
            0
        }
    };
    let path_wall = path_timer.elapsed();
    println!("{}", service.metrics().report());
    println!(
        "requests={requests} ok={ok} shed={shed} wall={} throughput={:.1} req/s",
        fmt_duration(wall),
        requests as f64 / wall
    );
    println!(
        "path job: {path_points} points in {} ({:.1} points/s)",
        fmt_duration(path_wall),
        path_points as f64 / path_wall.max(1e-9)
    );
    service.shutdown();
    Ok(())
}

/// The whole-screen workload: R response vectors against one design,
/// submitted as a single `JobKind::MultiResponse` job — one preparation
/// build, λ_max screening in one fused pass, response chunks batched
/// through the shared-panel Newton.
fn cmd_screen(args: &Args) -> Result<()> {
    apply_threads(args)?;
    apply_kernel(args)?;
    apply_precision(args)?;
    let nresp = args.get_usize("responses")?.unwrap_or(8);
    let backend = backend_choice(args)?;
    let mut config = ServiceConfig::default();
    if let Some(w) = args.get_usize("workers")? {
        config.pool.workers = w;
    }
    if let Some(t) = args.get_f64("early-stop")? {
        config.multi_response_early_stop = Some(t);
    }
    let data = load_dataset(args)?;
    let runner = PathRunner::new(PathRunnerConfig {
        grid: args.get_usize("grid")?.unwrap_or(16),
        ..Default::default()
    });
    let derived = runner.derive_grid(&data);
    if derived.is_empty() {
        bail!("no active path points for this dataset");
    }
    let grid = runner.grid_points(&derived);
    // Demo responses: scaled copies of the profile's response (a real
    // screen would carry R measured phenotypes over the same design).
    let responses: Vec<Arc<Vec<f64>>> = (0..nresp)
        .map(|r| {
            let f = 1.0 + 0.5 * r as f64 / nresp.max(1) as f64;
            Arc::new(data.y.iter().map(|v| v * f).collect::<Vec<f64>>())
        })
        .collect();
    let service = Service::start(config);
    let x = Arc::new(crate::linalg::Design::from(data.x.clone()));
    let timer = crate::util::Timer::start();
    let rx = service.submit_multi_response(1, x, responses, grid, backend)?;
    let res = match rx.recv()?.result {
        Ok(r) => r.expect_multi_response(),
        Err(e) => bail!("screen job failed: {e}"),
    };
    let wall = timer.elapsed();
    println!(
        "{:>4} {:>12} {:>9} {:>7} {:>6}",
        "resp", "lambda_max", "screened", "points", "nnz"
    );
    for r in 0..res.paths.len() {
        println!(
            "{:>4} {:>12.4e} {:>9} {:>7} {:>6}",
            r,
            res.lambda_max[r],
            res.screened[r],
            res.paths[r].len(),
            res.paths[r].last().map_or(0, |s| s.nnz())
        );
    }
    println!("{}", service.metrics().report());
    println!(
        "responses={nresp} wall={} throughput={:.1} responses/s",
        fmt_duration(wall),
        nresp as f64 / wall.max(1e-9)
    );
    service.shutdown();
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn raw(args: &[&str]) -> Vec<String> {
        args.iter().map(|s| s.to_string()).collect()
    }

    #[test]
    fn parses_flags_and_positionals() {
        let a = parse_args(&raw(&["--dataset", "Arcene", "pos1", "--grid", "10", "--verbose"]))
            .unwrap();
        assert_eq!(a.get("dataset"), Some("Arcene"));
        assert_eq!(a.get_usize("grid").unwrap(), Some(10));
        assert_eq!(a.get("verbose"), Some("true"));
        assert_eq!(a.positional, vec!["pos1"]);
    }

    #[test]
    fn numeric_flag_errors_are_friendly() {
        let a = parse_args(&raw(&["--t", "abc"])).unwrap();
        assert!(a.get_f64("t").is_err());
    }

    #[test]
    fn threads_flag_parses_and_noop_without_flag() {
        let a = parse_args(&raw(&["--threads", "4"])).unwrap();
        assert_eq!(a.get_usize("threads").unwrap(), Some(4));
        // Without the flag, apply_threads must not touch the global
        // setting (other tests in this process rely on Auto).
        let none = parse_args(&raw(&[])).unwrap();
        apply_threads(&none).unwrap();
        let bad = parse_args(&raw(&["--threads", "x"])).unwrap();
        assert!(apply_threads(&bad).is_err());
    }

    #[test]
    fn kernel_flag_parses_and_noop_without_flag() {
        // Without the flag, apply_kernel must not touch the global
        // dispatch (other tests in this process rely on Auto).
        let none = parse_args(&raw(&[])).unwrap();
        apply_kernel(&none).unwrap();
        // `auto` is always accepted and stores the do-nothing default,
        // so this is safe to run concurrently with kernel-pinning tests.
        let auto = parse_args(&raw(&["--kernel", "auto"])).unwrap();
        apply_kernel(&auto).unwrap();
        // A nonsense kernel is a friendly error, not a panic later.
        let bad = parse_args(&raw(&["--kernel", "sse9"])).unwrap();
        let err = apply_kernel(&bad).unwrap_err().to_string();
        assert!(err.contains("sse9"), "got: {err}");
    }

    #[test]
    fn precision_flag_parses_and_noop_without_flag() {
        // Without the flag, apply_precision must not touch the global
        // setting (other tests in this process rely on Auto).
        let none = parse_args(&raw(&[])).unwrap();
        apply_precision(&none).unwrap();
        // `auto` stores the do-nothing default — safe to run concurrently
        // with precision-scoping tests.
        let auto = parse_args(&raw(&["--precision", "auto"])).unwrap();
        apply_precision(&auto).unwrap();
        // A nonsense precision is a friendly error, not a panic later.
        let bad = parse_args(&raw(&["--precision", "f16"])).unwrap();
        let err = apply_precision(&bad).unwrap_err().to_string();
        assert!(err.contains("f16"), "got: {err}");
    }

    #[test]
    fn backend_parse() {
        let a = parse_args(&raw(&["--backend", "xla"])).unwrap();
        assert_eq!(backend_choice(&a).unwrap(), BackendChoice::Xla);
        let b = parse_args(&raw(&["--backend", "nope"])).unwrap();
        assert!(backend_choice(&b).is_err());
    }
}
