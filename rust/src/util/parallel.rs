//! Scoped thread pool and the crate-wide parallelism knob.
//!
//! Every blocked kernel in [`crate::linalg`] parallelizes by splitting
//! its *output* into disjoint tiles and fanning those tiles out over
//! scoped threads. This module owns the two pieces that makes uniform:
//!
//! - [`Parallelism`]: the user-facing knob (serial / auto / fixed),
//!   resolvable per-call, per-solve (via `SvenConfig`), per-process
//!   (via [`set_global_parallelism`] / the CLI `--threads` flag), or
//!   from the `PALLAS_NUM_THREADS` environment variable.
//! - [`parallel_items`]: the scoped fan-out primitive. Work items are
//!   moved to workers (so `&mut` output tiles ride along safely), and
//!   the *decomposition into items never depends on the thread count* —
//!   which is what makes every kernel built on it bit-stable across
//!   `Parallelism` settings (see `rust/tests/proptests.rs`).
//!
//! No rayon offline; workers are `std::thread::scope` spawns, so borrowed
//! tiles need no `'static` bound and panics propagate to the caller.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::OnceLock;

/// Degree of parallelism for the blocked linalg kernels.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum Parallelism {
    /// Strictly serial (equivalent to one worker thread).
    None,
    /// Resolve from `PALLAS_NUM_THREADS` (fallback `SVEN_THREADS`), else
    /// the machine's available parallelism.
    #[default]
    Auto,
    /// Exactly this many worker threads (clamped to ≥ 1).
    Fixed(usize),
}

impl Parallelism {
    /// Resolve to a concrete worker count.
    pub fn threads(self) -> usize {
        match self {
            Parallelism::None => 1,
            Parallelism::Fixed(n) => n.max(1),
            Parallelism::Auto => env_threads(),
        }
    }
}

/// Parse a thread-count setting (`PALLAS_NUM_THREADS` / `SVEN_THREADS`):
/// a positive integer. Split out of the env reader so the rejection
/// cases are unit-testable without mutating process environment.
pub fn parse_threads(s: &str) -> Result<usize, String> {
    match s.trim().parse::<usize>() {
        Ok(n) if n > 0 => Ok(n),
        Ok(_) => Err(format!("thread count must be >= 1, got {s:?}")),
        Err(_) => Err(format!("thread count must be a positive integer, got {s:?}")),
    }
}

/// `PALLAS_NUM_THREADS` / `SVEN_THREADS` / available parallelism, cached.
///
/// An unparseable value is a **hard error** on first resolution — the
/// same contract as `PALLAS_KERNEL` and `PALLAS_PRECISION`. (It used to
/// fall back silently to auto detection, which made a typo like
/// `PALLAS_NUM_THREADS=fout` run a benchmark on every core.)
fn env_threads() -> usize {
    static N: OnceLock<usize> = OnceLock::new();
    *N.get_or_init(|| {
        let from_env = |key: &str| {
            std::env::var(key).ok().map(|s| {
                parse_threads(&s)
                    .unwrap_or_else(|e| panic!("{key}: {e} (unset it or pick a positive integer)"))
            })
        };
        from_env("PALLAS_NUM_THREADS")
            .or_else(|| from_env("SVEN_THREADS"))
            .unwrap_or_else(|| {
                std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4)
            })
    })
}

/// Process-wide setting: 0 = Auto, k ≥ 1 = exactly k threads.
static GLOBAL: AtomicUsize = AtomicUsize::new(0);

thread_local! {
    /// Per-thread override installed by [`with_parallelism`]; takes
    /// precedence over the global setting on the installing thread.
    static OVERRIDE: std::cell::Cell<usize> = const { std::cell::Cell::new(0) };
}

/// Set the process-wide default (the CLI `--threads` flag lands here).
pub fn set_global_parallelism(p: Parallelism) {
    let enc = match p {
        Parallelism::Auto => 0,
        other => other.threads(),
    };
    GLOBAL.store(enc, Ordering::Relaxed);
}

/// Run `f` with `p` as the effective parallelism on this thread.
///
/// The kernels spawn their workers from the calling thread, so a
/// thread-local override is enough to scope the whole computation —
/// `Sven::solve` wraps each solve in this. `Auto` installs nothing and
/// inherits whatever scope is already in effect, so an outer
/// `with_parallelism(Parallelism::None, ..)` around a default-config
/// `Sven::solve` still forces the solve serial.
pub fn with_parallelism<T>(p: Parallelism, f: impl FnOnce() -> T) -> T {
    if matches!(p, Parallelism::Auto) {
        return f();
    }
    struct Restore(usize);
    impl Drop for Restore {
        fn drop(&mut self) {
            OVERRIDE.with(|c| c.set(self.0));
        }
    }
    let prev = OVERRIDE.with(|c| {
        let prev = c.get();
        c.set(p.threads());
        prev
    });
    let _restore = Restore(prev);
    f()
}

/// Worker count the kernels should use right now: thread-local override,
/// else the global setting, else `Parallelism::Auto`.
pub fn effective_threads() -> usize {
    let tls = OVERRIDE.with(|c| c.get());
    if tls > 0 {
        return tls;
    }
    match GLOBAL.load(Ordering::Relaxed) {
        0 => env_threads(),
        n => n,
    }
}

/// Fan `items` out over at most `nt` scoped worker threads.
///
/// `f(i, item)` receives the item's index in the original order plus the
/// item by value — pass `&mut` slices (e.g. from `chunks_mut`) as items
/// to write disjoint output tiles in parallel. Items are distributed
/// round-robin; with `nt <= 1` (or a single item) everything runs inline
/// on the caller. The item decomposition is the caller's, so results do
/// not depend on `nt` as long as each `f(i, item)` is deterministic.
pub fn parallel_items<T, F>(nt: usize, items: Vec<T>, f: F)
where
    T: Send,
    F: Fn(usize, T) + Sync,
{
    let nt = nt.clamp(1, items.len().max(1));
    if nt <= 1 || items.len() <= 1 {
        for (i, item) in items.into_iter().enumerate() {
            f(i, item);
        }
        return;
    }
    let mut buckets: Vec<Vec<(usize, T)>> = (0..nt).map(|_| Vec::new()).collect();
    for (i, item) in items.into_iter().enumerate() {
        buckets[i % nt].push((i, item));
    }
    let f = &f;
    std::thread::scope(|s| {
        for bucket in buckets {
            s.spawn(move || {
                for (i, item) in bucket {
                    f(i, item);
                }
            });
        }
    });
}

/// Dynamic-scheduling variant for jobs that only need an index (shared
/// read-only inputs, interior outputs): workers pull job indices from an
/// atomic counter, which load-balances ragged job costs.
pub fn parallel_for<F>(nt: usize, njobs: usize, f: F)
where
    F: Fn(usize) + Sync,
{
    let nt = nt.clamp(1, njobs.max(1));
    if nt <= 1 || njobs <= 1 {
        for j in 0..njobs {
            f(j);
        }
        return;
    }
    let next = AtomicUsize::new(0);
    let (f, next) = (&f, &next);
    std::thread::scope(|s| {
        for _ in 0..nt {
            s.spawn(move || loop {
                let j = next.fetch_add(1, Ordering::Relaxed);
                if j >= njobs {
                    break;
                }
                f(j);
            });
        }
    });
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parallelism_resolution() {
        assert_eq!(Parallelism::None.threads(), 1);
        assert_eq!(Parallelism::Fixed(6).threads(), 6);
        assert_eq!(Parallelism::Fixed(0).threads(), 1);
        assert!(Parallelism::Auto.threads() >= 1);
    }

    #[test]
    fn thread_count_parsing_is_strict() {
        // The env reader hard-errors through this parser: every rejection
        // here is a value that previously fell back to auto silently.
        assert_eq!(parse_threads("4"), Ok(4));
        assert_eq!(parse_threads(" 8 "), Ok(8));
        assert!(parse_threads("0").is_err());
        assert!(parse_threads("-2").is_err());
        assert!(parse_threads("four").is_err());
        assert!(parse_threads("4.0").is_err());
        assert!(parse_threads("").is_err());
        let e = parse_threads("fout").unwrap_err();
        assert!(e.contains("fout"), "error must echo the bad value: {e}");
    }

    #[test]
    fn with_parallelism_scopes_and_restores() {
        let before = effective_threads();
        let inside = with_parallelism(Parallelism::Fixed(3), effective_threads);
        assert_eq!(inside, 3);
        assert_eq!(effective_threads(), before);
        let serial = with_parallelism(Parallelism::None, effective_threads);
        assert_eq!(serial, 1);
        // Auto inherits the enclosing scope instead of clobbering it.
        let nested = with_parallelism(Parallelism::None, || {
            with_parallelism(Parallelism::Auto, effective_threads)
        });
        assert_eq!(nested, 1);
    }

    #[test]
    fn parallel_items_writes_disjoint_chunks() {
        let mut data = vec![0usize; 40];
        let chunks: Vec<&mut [usize]> = data.chunks_mut(7).collect();
        parallel_items(4, chunks, |i, chunk| {
            for v in chunk.iter_mut() {
                *v = i + 1;
            }
        });
        for (pos, v) in data.iter().enumerate() {
            assert_eq!(*v, pos / 7 + 1, "pos {pos}");
        }
    }

    #[test]
    fn parallel_items_serial_matches_parallel() {
        let run = |nt: usize| {
            let mut out = vec![0.0f64; 16];
            let chunks: Vec<&mut [f64]> = out.chunks_mut(4).collect();
            parallel_items(nt, chunks, |i, chunk| {
                for (j, v) in chunk.iter_mut().enumerate() {
                    *v = (i * 4 + j) as f64 * 0.5;
                }
            });
            out
        };
        assert_eq!(run(1), run(4));
    }

    #[test]
    fn parallel_for_covers_all_jobs() {
        let hits: Vec<AtomicUsize> = (0..23).map(|_| AtomicUsize::new(0)).collect();
        parallel_for(5, 23, |j| {
            hits[j].fetch_add(1, Ordering::Relaxed);
        });
        for (j, h) in hits.iter().enumerate() {
            assert_eq!(h.load(Ordering::Relaxed), 1, "job {j}");
        }
    }

    #[test]
    fn empty_items_is_noop() {
        parallel_items(4, Vec::<usize>::new(), |_, _| panic!("no items"));
        parallel_for(4, 0, |_| panic!("no jobs"));
    }
}
