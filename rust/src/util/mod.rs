//! Small utilities: the scoped thread pool / parallelism knob, timing,
//! summary statistics, logging.

pub mod parallel;
pub mod stats;
pub mod timer;

pub use parallel::{
    effective_threads, parallel_for, parallel_items, set_global_parallelism, with_parallelism,
    Parallelism,
};
pub use stats::Summary;
pub use timer::Timer;

use std::sync::atomic::{AtomicU8, Ordering};

static LOG_LEVEL: AtomicU8 = AtomicU8::new(1); // 0=quiet 1=info 2=debug

/// Set global verbosity (0 quiet, 1 info, 2 debug).
pub fn set_log_level(level: u8) {
    LOG_LEVEL.store(level, Ordering::Relaxed);
}

pub fn log_level() -> u8 {
    LOG_LEVEL.load(Ordering::Relaxed)
}

/// Info-level log line to stderr.
#[macro_export]
macro_rules! info {
    ($($arg:tt)*) => {
        if $crate::util::log_level() >= 1 {
            eprintln!("[sven] {}", format!($($arg)*));
        }
    };
}

/// Debug-level log line to stderr.
#[macro_export]
macro_rules! debug {
    ($($arg:tt)*) => {
        if $crate::util::log_level() >= 2 {
            eprintln!("[sven:debug] {}", format!($($arg)*));
        }
    };
}

/// Format a duration in adaptive human units.
pub fn fmt_duration(secs: f64) -> String {
    if secs < 1e-6 {
        format!("{:.1}ns", secs * 1e9)
    } else if secs < 1e-3 {
        format!("{:.1}µs", secs * 1e6)
    } else if secs < 1.0 {
        format!("{:.2}ms", secs * 1e3)
    } else {
        format!("{:.3}s", secs)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn duration_formatting() {
        assert_eq!(fmt_duration(2.5e-9), "2.5ns");
        assert_eq!(fmt_duration(3.5e-5), "35.0µs");
        assert_eq!(fmt_duration(0.0123), "12.30ms");
        assert_eq!(fmt_duration(1.5), "1.500s");
    }
}
