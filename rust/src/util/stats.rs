//! Summary statistics for benchmark reporting.

/// Order statistics + moments over a sample of measurements.
#[derive(Clone, Debug)]
pub struct Summary {
    sorted: Vec<f64>,
    mean: f64,
    std: f64,
}

impl Summary {
    pub fn from(mut xs: Vec<f64>) -> Self {
        assert!(!xs.is_empty(), "empty sample");
        xs.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let n = xs.len() as f64;
        let mean = xs.iter().sum::<f64>() / n;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n;
        Summary { sorted: xs, mean, std: var.sqrt() }
    }

    pub fn mean(&self) -> f64 {
        self.mean
    }

    pub fn std(&self) -> f64 {
        self.std
    }

    pub fn min(&self) -> f64 {
        self.sorted[0]
    }

    pub fn max(&self) -> f64 {
        *self.sorted.last().unwrap()
    }

    /// Percentile by linear interpolation, q in [0, 1].
    pub fn quantile(&self, q: f64) -> f64 {
        let q = q.clamp(0.0, 1.0);
        let pos = q * (self.sorted.len() - 1) as f64;
        let lo = pos.floor() as usize;
        let hi = pos.ceil() as usize;
        if lo == hi {
            self.sorted[lo]
        } else {
            let w = pos - lo as f64;
            self.sorted[lo] * (1.0 - w) + self.sorted[hi] * w
        }
    }

    pub fn median(&self) -> f64 {
        self.quantile(0.5)
    }

    pub fn p95(&self) -> f64 {
        self.quantile(0.95)
    }

    pub fn n(&self) -> usize {
        self.sorted.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_basics() {
        let s = Summary::from(vec![3.0, 1.0, 2.0, 4.0, 5.0]);
        assert_eq!(s.min(), 1.0);
        assert_eq!(s.max(), 5.0);
        assert_eq!(s.median(), 3.0);
        assert!((s.mean() - 3.0).abs() < 1e-12);
        assert_eq!(s.n(), 5);
    }

    #[test]
    fn quantile_interpolates() {
        let s = Summary::from(vec![0.0, 10.0]);
        assert!((s.quantile(0.25) - 2.5).abs() < 1e-12);
        assert_eq!(s.quantile(0.0), 0.0);
        assert_eq!(s.quantile(1.0), 10.0);
    }

    #[test]
    #[should_panic]
    fn empty_sample_panics() {
        Summary::from(vec![]);
    }
}
