//! Wall-clock timing helper.

use std::time::Instant;

/// Simple stopwatch.
#[derive(Clone, Debug)]
pub struct Timer {
    start: Instant,
}

impl Timer {
    pub fn start() -> Self {
        Timer { start: Instant::now() }
    }

    /// Seconds elapsed since start.
    pub fn elapsed(&self) -> f64 {
        self.start.elapsed().as_secs_f64()
    }

    /// Restart and return the lap time in seconds.
    pub fn lap(&mut self) -> f64 {
        let e = self.elapsed();
        self.start = Instant::now();
        e
    }
}

/// Time a closure, returning (result, seconds).
pub fn timed<T>(f: impl FnOnce() -> T) -> (T, f64) {
    let t = Timer::start();
    let out = f();
    (out, t.elapsed())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn timer_is_monotone() {
        let mut t = Timer::start();
        std::thread::sleep(std::time::Duration::from_millis(2));
        let lap = t.lap();
        assert!(lap >= 0.002);
        assert!(t.elapsed() < lap); // restarted
    }

    #[test]
    fn timed_returns_result() {
        let (v, secs) = timed(|| 41 + 1);
        assert_eq!(v, 42);
        assert!(secs >= 0.0);
    }
}
