//! # SVEN — Support Vector Elastic Net
//!
//! A reproduction of *"A Reduction of the Elastic Net to Support Vector
//! Machines with an Application to GPU Computing"* (Zhou et al., AAAI 2015)
//! as a three-layer rust + JAX + Pallas system.
//!
//! The paper's result: the Elastic Net
//!
//! ```text
//! min_β ‖Xβ − y‖² + λ₂‖β‖²   s.t. |β|₁ ≤ t
//! ```
//!
//! reduces *exactly* to a squared-hinge-loss SVM without bias on a
//! constructed data set of `2p` samples in `n` dimensions, with
//! `C = 1/(2λ₂)` and back-map `β = t·(α⁺ − α⁻)/|α|₁`. Since squared-hinge
//! SVMs are solved almost entirely with dense matrix operations (Newton +
//! conjugate gradients), the Elastic Net inherits parallel hardware for
//! free. Here the "GPU" backend of the paper is an AOT-compiled XLA
//! program executed through PJRT from rust (see [`runtime`]), while
//! [`solvers::svm`] is the pure-rust CPU backend.
//!
//! Layer map:
//! - **L3** (this crate): [`coordinator`] — regularization-path scheduler,
//!   worker pool, solver service; [`cli`]; [`bench`].
//! - **L2/L1** (`python/compile`): JAX Newton-CG solver graphs calling
//!   Pallas kernels, AOT-lowered to `artifacts/*.hlo.txt`.
//! - **runtime**: [`runtime`] loads the artifacts via the `xla` crate
//!   (PJRT CPU) and exposes them as [`solvers::sven::SvmBackend`]s.
//!
//! See `DESIGN.md` for the full system inventory and experiment index.

// Index-heavy numeric kernels read better with explicit `for i in 0..n`
// loops than with iterator chains; silence the two style lints that
// would otherwise rewrite half the hot paths.
#![allow(clippy::needless_range_loop)]
#![allow(clippy::too_many_arguments)]

pub mod bench;
pub mod cli;
pub mod coordinator;
pub mod data;
pub mod linalg;
pub mod rng;
pub mod runtime;
pub mod solvers;
pub mod testing;
pub mod util;

pub use linalg::{Csc, Csr, Design, KernelChoice, KernelCtx, MultiVec};
pub use solvers::elastic_net::{EnProblem, EnSolution, EnSolverKind};
pub use solvers::sven::{Sven, SvenConfig};
