//! Microkernels: the register-tile inner loops of the blocked GEMM/Gram
//! core, selected once at startup by runtime CPU-feature detection.
//!
//! A [`MicroKernel`] computes one `mr × nr` register tile over *packed*
//! operands (see the contract on the trait). Three implementations
//! ship:
//!
//! - [`ScalarKernel`] — the crate's original fixed 4×8 tile, plain
//!   mul/add, written so LLVM autovectorizes it. Always available; the
//!   reference every other kernel is tested against.
//! - [`Avx2Kernel`] — explicit AVX2 intrinsics, 4×8 tile held in eight
//!   256-bit accumulators, separate multiply and add. Because each
//!   output element still sees exactly one rounding per multiply and
//!   one per add, in the same k-ascending order, its results are
//!   **bit-identical** to [`ScalarKernel`].
//! - [`FmaKernel`] — FMA intrinsics, 6×8 tile in twelve 256-bit
//!   accumulators, one fused multiply-add (single rounding) per step.
//!   Its scalar model is the same loop with [`f64::mul_add`]; results
//!   are bit-identical to that model but *not* to the mul/add kernels —
//!   which is why forcing a kernel is first-class (see
//!   [`KernelChoice`] and `PALLAS_KERNEL`).
//!
//! Per-kernel determinism: for a fixed kernel the accumulation order is
//! fixed, so every result is bit-identical at any thread count.
//! Cross-kernel identity is explicitly *not* promised.

use std::fmt;

/// Largest `mr·nr` any shipped kernel uses; the block driver keeps its
/// accumulator tile on the stack at this size.
pub(crate) const MAX_TILE: usize = 64;

/// One register-tile inner loop of the blocked GEMM/Gram core.
///
/// # Contract
///
/// `tile(ap, bp, kc, acc)` must compute, for `0 ≤ i < mr`, `0 ≤ j < nr`:
///
/// ```text
/// acc[i·nr + j] += Σ_{kk=0..kc} ap[kk·mr + i] · bp[kk·nr + j]
/// ```
///
/// with `kk` ascending and each step applied to the running element
/// accumulator in order (one rounding per multiply and one per add —
/// or one fused rounding for an FMA kernel, in which case
/// [`MicroKernel::tile_model`] must be overridden to match).
///
/// - **Packing**: `ap` is a k-major packed A tile (`ap[kk·mr + i]`,
///   length `≥ kc·mr`) and `bp` a k-major packed B panel
///   (`bp[kk·nr + j]`, length `≥ kc·nr`), both produced by the packing
///   stage in `gemm.rs`, which zero-pads row/column tails to the full
///   `mr`/`nr` — a kernel always runs the full tile and the driver
///   masks the write-back, so implementations never see fringes.
/// - **Aliasing**: `acc` (length `≥ mr·nr`, row-major) must not alias
///   either packed panel; the driver owns it exclusively.
/// - **Determinism**: two calls with the same inputs must produce the
///   same bits, on every thread (no internal reordering, no FTZ/DAZ
///   mode changes).
pub trait MicroKernel: Send + Sync {
    /// Kernel name for logs/metrics (`"scalar"`, `"avx2"`, `"fma"`).
    fn name(&self) -> &'static str;
    /// Register-tile rows.
    fn mr(&self) -> usize;
    /// Register-tile columns.
    fn nr(&self) -> usize;
    /// Accumulate one `mr×nr` tile over `kc` packed steps (see the
    /// trait-level contract).
    fn tile(&self, ap: &[f64], bp: &[f64], kc: usize, acc: &mut [f64]);
    /// The kernel's *scalar model*: a plain-Rust loop with the exact
    /// rounding semantics `tile` promises. Proptests pin
    /// `tile == tile_model` bit-for-bit on every enabled kernel. The
    /// default model is the one-rounding-per-mul-and-add loop; FMA
    /// kernels override it with the fused ([`f64::mul_add`]) loop.
    fn tile_model(&self, ap: &[f64], bp: &[f64], kc: usize, acc: &mut [f64]) {
        scalar_tile(self.mr(), self.nr(), false, ap, bp, kc, acc);
    }
}

/// Generic scalar tile loop: the rounding model shared by every kernel.
/// `fused` selects one fused rounding per step ([`f64::mul_add`])
/// instead of separate multiply and add.
pub(crate) fn scalar_tile(
    mr: usize,
    nr: usize,
    fused: bool,
    ap: &[f64],
    bp: &[f64],
    kc: usize,
    acc: &mut [f64],
) {
    for kk in 0..kc {
        let a = &ap[kk * mr..(kk + 1) * mr];
        let b = &bp[kk * nr..(kk + 1) * nr];
        for i in 0..mr {
            let aik = a[i];
            let row = &mut acc[i * nr..(i + 1) * nr];
            if fused {
                for j in 0..nr {
                    row[j] = aik.mul_add(b[j], row[j]);
                }
            } else {
                for j in 0..nr {
                    row[j] += aik * b[j];
                }
            }
        }
    }
}

// ---------------------------------------------------------------------------
// Scalar kernel (always available)
// ---------------------------------------------------------------------------

/// The original autovectorized 4×8 tile: fixed-size array views let
/// LLVM drop bounds checks and unroll the fan-out; plain mul/add.
pub struct ScalarKernel;

const S_MR: usize = 4;
const S_NR: usize = 8;

impl MicroKernel for ScalarKernel {
    fn name(&self) -> &'static str {
        "scalar"
    }

    fn mr(&self) -> usize {
        S_MR
    }

    fn nr(&self) -> usize {
        S_NR
    }

    fn tile(&self, ap: &[f64], bp: &[f64], kc: usize, acc: &mut [f64]) {
        // Load acc into the register tile first so every element's chain
        // is `acc₀ + t₁ + t₂ + …` — the model's in-place order exactly
        // (summing into a zeroed tile and adding at the end would
        // re-associate the chain).
        let mut c = [[0.0f64; S_NR]; S_MR];
        for (i, ci) in c.iter_mut().enumerate() {
            ci.copy_from_slice(&acc[i * S_NR..(i + 1) * S_NR]);
        }
        for (ak, bk) in
            ap[..kc * S_MR].chunks_exact(S_MR).zip(bp[..kc * S_NR].chunks_exact(S_NR))
        {
            let ak: &[f64; S_MR] = ak.try_into().expect("tile width");
            let bk: &[f64; S_NR] = bk.try_into().expect("panel width");
            for i in 0..S_MR {
                let aik = ak[i];
                for j in 0..S_NR {
                    c[i][j] += aik * bk[j];
                }
            }
        }
        for (i, ci) in c.iter().enumerate() {
            acc[i * S_NR..(i + 1) * S_NR].copy_from_slice(ci);
        }
    }
}

// ---------------------------------------------------------------------------
// x86-64 SIMD kernels
// ---------------------------------------------------------------------------

/// Explicit AVX2 4×8 tile (separate mul + add; bit-identical to
/// [`ScalarKernel`]). Constructible only when `avx2` is detected.
#[cfg(target_arch = "x86_64")]
pub struct Avx2Kernel;

#[cfg(target_arch = "x86_64")]
impl MicroKernel for Avx2Kernel {
    fn name(&self) -> &'static str {
        "avx2"
    }

    fn mr(&self) -> usize {
        4
    }

    fn nr(&self) -> usize {
        8
    }

    fn tile(&self, ap: &[f64], bp: &[f64], kc: usize, acc: &mut [f64]) {
        assert!(ap.len() >= kc * 4 && bp.len() >= kc * 8 && acc.len() >= 32);
        // SAFETY: `kernel_for` only hands out this kernel when the
        // `avx2` feature was detected at runtime, and the slice bounds
        // were just checked.
        unsafe { avx2_tile_4x8(ap.as_ptr(), bp.as_ptr(), kc, acc.as_mut_ptr()) }
    }
}

/// 4×8 AVX2 tile: accumulators are loaded from `acc`, so the per-element
/// chain is exactly `acc[e] + t₁ + t₂ + …` — the scalar model's order.
#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2")]
unsafe fn avx2_tile_4x8(ap: *const f64, bp: *const f64, kc: usize, acc: *mut f64) {
    use std::arch::x86_64::*;
    let mut c00 = _mm256_loadu_pd(acc);
    let mut c01 = _mm256_loadu_pd(acc.add(4));
    let mut c10 = _mm256_loadu_pd(acc.add(8));
    let mut c11 = _mm256_loadu_pd(acc.add(12));
    let mut c20 = _mm256_loadu_pd(acc.add(16));
    let mut c21 = _mm256_loadu_pd(acc.add(20));
    let mut c30 = _mm256_loadu_pd(acc.add(24));
    let mut c31 = _mm256_loadu_pd(acc.add(28));
    for kk in 0..kc {
        let b0 = _mm256_loadu_pd(bp.add(kk * 8));
        let b1 = _mm256_loadu_pd(bp.add(kk * 8 + 4));
        let a0 = _mm256_set1_pd(*ap.add(kk * 4));
        c00 = _mm256_add_pd(c00, _mm256_mul_pd(a0, b0));
        c01 = _mm256_add_pd(c01, _mm256_mul_pd(a0, b1));
        let a1 = _mm256_set1_pd(*ap.add(kk * 4 + 1));
        c10 = _mm256_add_pd(c10, _mm256_mul_pd(a1, b0));
        c11 = _mm256_add_pd(c11, _mm256_mul_pd(a1, b1));
        let a2 = _mm256_set1_pd(*ap.add(kk * 4 + 2));
        c20 = _mm256_add_pd(c20, _mm256_mul_pd(a2, b0));
        c21 = _mm256_add_pd(c21, _mm256_mul_pd(a2, b1));
        let a3 = _mm256_set1_pd(*ap.add(kk * 4 + 3));
        c30 = _mm256_add_pd(c30, _mm256_mul_pd(a3, b0));
        c31 = _mm256_add_pd(c31, _mm256_mul_pd(a3, b1));
    }
    _mm256_storeu_pd(acc, c00);
    _mm256_storeu_pd(acc.add(4), c01);
    _mm256_storeu_pd(acc.add(8), c10);
    _mm256_storeu_pd(acc.add(12), c11);
    _mm256_storeu_pd(acc.add(16), c20);
    _mm256_storeu_pd(acc.add(20), c21);
    _mm256_storeu_pd(acc.add(24), c30);
    _mm256_storeu_pd(acc.add(28), c31);
}

/// FMA 6×8 tile (one fused rounding per step; bit-identical to its
/// `mul_add` scalar model, *not* to the mul/add kernels). Constructible
/// only when `avx2` **and** `fma` are detected.
#[cfg(target_arch = "x86_64")]
pub struct FmaKernel;

#[cfg(target_arch = "x86_64")]
impl MicroKernel for FmaKernel {
    fn name(&self) -> &'static str {
        "fma"
    }

    fn mr(&self) -> usize {
        6
    }

    fn nr(&self) -> usize {
        8
    }

    fn tile(&self, ap: &[f64], bp: &[f64], kc: usize, acc: &mut [f64]) {
        assert!(ap.len() >= kc * 6 && bp.len() >= kc * 8 && acc.len() >= 48);
        // SAFETY: handed out only when `avx2` and `fma` were detected;
        // bounds just checked.
        unsafe { fma_tile_6x8(ap.as_ptr(), bp.as_ptr(), kc, acc.as_mut_ptr()) }
    }

    fn tile_model(&self, ap: &[f64], bp: &[f64], kc: usize, acc: &mut [f64]) {
        scalar_tile(6, 8, true, ap, bp, kc, acc);
    }
}

/// 6×8 FMA tile: twelve accumulators + two B vectors + one broadcast
/// fill 15 of the 16 ymm registers.
#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2,fma")]
unsafe fn fma_tile_6x8(ap: *const f64, bp: *const f64, kc: usize, acc: *mut f64) {
    use std::arch::x86_64::*;
    let mut c: [[__m256d; 2]; 6] = [[_mm256_setzero_pd(); 2]; 6];
    for (i, ci) in c.iter_mut().enumerate() {
        ci[0] = _mm256_loadu_pd(acc.add(i * 8));
        ci[1] = _mm256_loadu_pd(acc.add(i * 8 + 4));
    }
    for kk in 0..kc {
        let b0 = _mm256_loadu_pd(bp.add(kk * 8));
        let b1 = _mm256_loadu_pd(bp.add(kk * 8 + 4));
        for (i, ci) in c.iter_mut().enumerate() {
            let a = _mm256_set1_pd(*ap.add(kk * 6 + i));
            ci[0] = _mm256_fmadd_pd(a, b0, ci[0]);
            ci[1] = _mm256_fmadd_pd(a, b1, ci[1]);
        }
    }
    for (i, ci) in c.iter().enumerate() {
        _mm256_storeu_pd(acc.add(i * 8), ci[0]);
        _mm256_storeu_pd(acc.add(i * 8 + 4), ci[1]);
    }
}

// ---------------------------------------------------------------------------
// f32 microkernels (the mixed-precision compute tier)
// ---------------------------------------------------------------------------

/// One register-tile inner loop of the blocked GEMM/Gram core in
/// **f32** — the same packed-operand contract as [`MicroKernel`]
/// (k-major `ap[kk·mr + i]` / `bp[kk·nr + j]`, zero-padded fringes,
/// non-aliasing `acc`, deterministic accumulation order) at half the
/// element width. SIMD tiles double their rows (8×8 where the f64
/// kernels run 4×8/6×8) because one 256-bit lane now holds eight
/// lanes. Per-kernel determinism carries over unchanged; cross-kernel
/// bit-identity is *not* promised (tile shapes differ, so even the
/// mul/add kernels see different `kc` blockings).
pub trait MicroKernelF32: Send + Sync {
    /// Kernel name for logs/metrics (`"scalar-f32"`, …).
    fn name(&self) -> &'static str;
    /// Register-tile rows.
    fn mr(&self) -> usize;
    /// Register-tile columns.
    fn nr(&self) -> usize;
    /// Accumulate one `mr×nr` tile over `kc` packed steps.
    fn tile(&self, ap: &[f32], bp: &[f32], kc: usize, acc: &mut [f32]);
    /// The kernel's scalar model (see [`MicroKernel::tile_model`]);
    /// FMA kernels override with the fused [`f32::mul_add`] loop.
    fn tile_model(&self, ap: &[f32], bp: &[f32], kc: usize, acc: &mut [f32]) {
        scalar_tile_f32(self.mr(), self.nr(), false, ap, bp, kc, acc);
    }
}

/// Generic f32 scalar tile loop — the rounding model shared by every
/// f32 kernel (`fused` selects [`f32::mul_add`] per step).
pub(crate) fn scalar_tile_f32(
    mr: usize,
    nr: usize,
    fused: bool,
    ap: &[f32],
    bp: &[f32],
    kc: usize,
    acc: &mut [f32],
) {
    for kk in 0..kc {
        let a = &ap[kk * mr..(kk + 1) * mr];
        let b = &bp[kk * nr..(kk + 1) * nr];
        for i in 0..mr {
            let aik = a[i];
            let row = &mut acc[i * nr..(i + 1) * nr];
            if fused {
                for j in 0..nr {
                    row[j] = aik.mul_add(b[j], row[j]);
                }
            } else {
                for j in 0..nr {
                    row[j] += aik * b[j];
                }
            }
        }
    }
}

/// Autovectorized 4×8 f32 reference tile (always available).
pub struct ScalarKernelF32;

impl MicroKernelF32 for ScalarKernelF32 {
    fn name(&self) -> &'static str {
        "scalar-f32"
    }

    fn mr(&self) -> usize {
        S_MR
    }

    fn nr(&self) -> usize {
        S_NR
    }

    fn tile(&self, ap: &[f32], bp: &[f32], kc: usize, acc: &mut [f32]) {
        // Same load-acc-first chain as the f64 scalar kernel so the
        // in-place model order is preserved exactly.
        let mut c = [[0.0f32; S_NR]; S_MR];
        for (i, ci) in c.iter_mut().enumerate() {
            ci.copy_from_slice(&acc[i * S_NR..(i + 1) * S_NR]);
        }
        for (ak, bk) in
            ap[..kc * S_MR].chunks_exact(S_MR).zip(bp[..kc * S_NR].chunks_exact(S_NR))
        {
            let ak: &[f32; S_MR] = ak.try_into().expect("tile width");
            let bk: &[f32; S_NR] = bk.try_into().expect("panel width");
            for i in 0..S_MR {
                let aik = ak[i];
                for j in 0..S_NR {
                    c[i][j] += aik * bk[j];
                }
            }
        }
        for (i, ci) in c.iter().enumerate() {
            acc[i * S_NR..(i + 1) * S_NR].copy_from_slice(ci);
        }
    }
}

/// Explicit AVX2 8×8 f32 tile (separate mul + add; bit-identical to
/// [`ScalarKernelF32`]'s model at the same shape). Constructible only
/// when `avx2` is detected.
#[cfg(target_arch = "x86_64")]
pub struct Avx2KernelF32;

#[cfg(target_arch = "x86_64")]
impl MicroKernelF32 for Avx2KernelF32 {
    fn name(&self) -> &'static str {
        "avx2-f32"
    }

    fn mr(&self) -> usize {
        8
    }

    fn nr(&self) -> usize {
        8
    }

    fn tile(&self, ap: &[f32], bp: &[f32], kc: usize, acc: &mut [f32]) {
        assert!(ap.len() >= kc * 8 && bp.len() >= kc * 8 && acc.len() >= 64);
        // SAFETY: handed out only when `avx2` was detected; bounds just
        // checked.
        unsafe { avx2_tile_8x8_f32(ap.as_ptr(), bp.as_ptr(), kc, acc.as_mut_ptr()) }
    }
}

/// 8×8 AVX2 f32 tile: eight single-ymm accumulator rows loaded from
/// `acc` (the scalar model's in-place chain), one broadcast per row.
#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2")]
unsafe fn avx2_tile_8x8_f32(ap: *const f32, bp: *const f32, kc: usize, acc: *mut f32) {
    use std::arch::x86_64::*;
    let mut c: [__m256; 8] = [_mm256_setzero_ps(); 8];
    for (i, ci) in c.iter_mut().enumerate() {
        *ci = _mm256_loadu_ps(acc.add(i * 8));
    }
    for kk in 0..kc {
        let b = _mm256_loadu_ps(bp.add(kk * 8));
        for (i, ci) in c.iter_mut().enumerate() {
            let a = _mm256_set1_ps(*ap.add(kk * 8 + i));
            *ci = _mm256_add_ps(*ci, _mm256_mul_ps(a, b));
        }
    }
    for (i, ci) in c.iter().enumerate() {
        _mm256_storeu_ps(acc.add(i * 8), *ci);
    }
}

/// FMA 8×8 f32 tile (one fused rounding per step; bit-identical to its
/// [`f32::mul_add`] model, not to the mul/add kernels). Constructible
/// only when `avx2` **and** `fma` are detected.
#[cfg(target_arch = "x86_64")]
pub struct FmaKernelF32;

#[cfg(target_arch = "x86_64")]
impl MicroKernelF32 for FmaKernelF32 {
    fn name(&self) -> &'static str {
        "fma-f32"
    }

    fn mr(&self) -> usize {
        8
    }

    fn nr(&self) -> usize {
        8
    }

    fn tile(&self, ap: &[f32], bp: &[f32], kc: usize, acc: &mut [f32]) {
        assert!(ap.len() >= kc * 8 && bp.len() >= kc * 8 && acc.len() >= 64);
        // SAFETY: handed out only when `avx2` and `fma` were detected;
        // bounds just checked.
        unsafe { fma_tile_8x8_f32(ap.as_ptr(), bp.as_ptr(), kc, acc.as_mut_ptr()) }
    }

    fn tile_model(&self, ap: &[f32], bp: &[f32], kc: usize, acc: &mut [f32]) {
        scalar_tile_f32(8, 8, true, ap, bp, kc, acc);
    }
}

/// 8×8 FMA f32 tile: eight accumulators + one B vector + one broadcast.
#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2,fma")]
unsafe fn fma_tile_8x8_f32(ap: *const f32, bp: *const f32, kc: usize, acc: *mut f32) {
    use std::arch::x86_64::*;
    let mut c: [__m256; 8] = [_mm256_setzero_ps(); 8];
    for (i, ci) in c.iter_mut().enumerate() {
        *ci = _mm256_loadu_ps(acc.add(i * 8));
    }
    for kk in 0..kc {
        let b = _mm256_loadu_ps(bp.add(kk * 8));
        for (i, ci) in c.iter_mut().enumerate() {
            let a = _mm256_set1_ps(*ap.add(kk * 8 + i));
            *ci = _mm256_fmadd_ps(a, b, *ci);
        }
    }
    for (i, ci) in c.iter().enumerate() {
        _mm256_storeu_ps(acc.add(i * 8), *ci);
    }
}

static SCALAR_F32: ScalarKernelF32 = ScalarKernelF32;
#[cfg(target_arch = "x86_64")]
static AVX2_F32: Avx2KernelF32 = Avx2KernelF32;
#[cfg(target_arch = "x86_64")]
static FMA_F32: FmaKernelF32 = FmaKernelF32;

/// Resolve a non-`Auto` choice to its f32 kernel. Availability mirrors
/// the f64 tier exactly (same CPU-feature requirements), so a choice
/// [`kernel_for`] accepts always has an f32 twin.
pub(crate) fn kernel_f32_for(
    choice: KernelChoice,
) -> Result<&'static dyn MicroKernelF32, KernelError> {
    // Reuse the f64 resolver for detection/error messages, then map to
    // the same tier's f32 kernel.
    kernel_for(choice)?;
    match choice {
        KernelChoice::Auto => {
            unreachable!("Auto must be resolved by the caller (KernelCtx::for_choice)")
        }
        KernelChoice::Scalar => Ok(&SCALAR_F32),
        #[cfg(target_arch = "x86_64")]
        KernelChoice::Avx2 => Ok(&AVX2_F32),
        #[cfg(target_arch = "x86_64")]
        KernelChoice::Fma => Ok(&FMA_F32),
        #[cfg(not(target_arch = "x86_64"))]
        _ => unreachable!("kernel_for rejects SIMD tiers off x86_64"),
    }
}

// ---------------------------------------------------------------------------
// Choice, detection, errors
// ---------------------------------------------------------------------------

/// Which microkernel the blocked core should use.
///
/// `Auto` resolves from the `PALLAS_KERNEL` environment variable when
/// set (`scalar | avx2 | fma | auto`), else to the best kernel the CPU
/// supports. Forcing an unsupported kernel is a hard error, surfaced by
/// [`crate::linalg::KernelCtx::for_choice`] (and by `SvenConfig` /
/// `ServiceConfig` validation before any solve runs).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum KernelChoice {
    /// `PALLAS_KERNEL` if set, else the best detected kernel.
    #[default]
    Auto,
    /// The autovectorized reference tile.
    Scalar,
    /// Explicit AVX2 (bit-identical to `Scalar`).
    Avx2,
    /// FMA (fused roundings; differs from the mul/add kernels).
    Fma,
}

impl KernelChoice {
    /// Parse a `PALLAS_KERNEL` / CLI value.
    pub fn parse(s: &str) -> Result<Self, KernelError> {
        match s.trim().to_ascii_lowercase().as_str() {
            "auto" => Ok(KernelChoice::Auto),
            "scalar" => Ok(KernelChoice::Scalar),
            "avx2" => Ok(KernelChoice::Avx2),
            "fma" => Ok(KernelChoice::Fma),
            other => Err(KernelError(format!(
                "unknown kernel {other:?} (expected scalar | avx2 | fma | auto)"
            ))),
        }
    }
}

impl fmt::Display for KernelChoice {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let s = match self {
            KernelChoice::Auto => "auto",
            KernelChoice::Scalar => "scalar",
            KernelChoice::Avx2 => "avx2",
            KernelChoice::Fma => "fma",
        };
        f.write_str(s)
    }
}

/// A kernel was forced (`PALLAS_KERNEL`, `SvenConfig::kernel`, CLI
/// `--kernel`) that this build or this CPU cannot run.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct KernelError(pub(crate) String);

impl fmt::Display for KernelError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "kernel dispatch: {}", self.0)
    }
}

impl std::error::Error for KernelError {}

static SCALAR: ScalarKernel = ScalarKernel;
#[cfg(target_arch = "x86_64")]
static AVX2: Avx2Kernel = Avx2Kernel;
#[cfg(target_arch = "x86_64")]
static FMA: FmaKernel = FmaKernel;

#[cfg(target_arch = "x86_64")]
fn avx2_detected() -> bool {
    std::is_x86_feature_detected!("avx2")
}

#[cfg(target_arch = "x86_64")]
fn fma_detected() -> bool {
    std::is_x86_feature_detected!("avx2") && std::is_x86_feature_detected!("fma")
}

/// The best kernel this CPU supports (what `Auto` resolves to when
/// `PALLAS_KERNEL` is unset).
pub fn best_available() -> KernelChoice {
    #[cfg(target_arch = "x86_64")]
    {
        if fma_detected() {
            return KernelChoice::Fma;
        }
        if avx2_detected() {
            return KernelChoice::Avx2;
        }
    }
    KernelChoice::Scalar
}

/// Every kernel choice this machine can actually run, scalar first.
pub fn enabled_choices() -> Vec<KernelChoice> {
    let mut v = vec![KernelChoice::Scalar];
    #[cfg(target_arch = "x86_64")]
    {
        if avx2_detected() {
            v.push(KernelChoice::Avx2);
        }
        if fma_detected() {
            v.push(KernelChoice::Fma);
        }
    }
    v
}

/// Resolve a non-`Auto` choice to its kernel, or a clear error when the
/// CPU/build cannot run it.
pub(crate) fn kernel_for(
    choice: KernelChoice,
) -> Result<&'static dyn MicroKernel, KernelError> {
    match choice {
        KernelChoice::Auto => {
            unreachable!("Auto must be resolved by the caller (KernelCtx::for_choice)")
        }
        KernelChoice::Scalar => Ok(&SCALAR),
        KernelChoice::Avx2 => avx2_kernel(),
        KernelChoice::Fma => fma_kernel(),
    }
}

#[cfg(target_arch = "x86_64")]
fn avx2_kernel() -> Result<&'static dyn MicroKernel, KernelError> {
    if avx2_detected() {
        Ok(&AVX2)
    } else {
        Err(KernelError("avx2 kernel forced but the CPU does not report AVX2".into()))
    }
}

#[cfg(not(target_arch = "x86_64"))]
fn avx2_kernel() -> Result<&'static dyn MicroKernel, KernelError> {
    Err(KernelError("avx2 kernel forced but this build targets a non-x86_64 arch".into()))
}

#[cfg(target_arch = "x86_64")]
fn fma_kernel() -> Result<&'static dyn MicroKernel, KernelError> {
    if fma_detected() {
        Ok(&FMA)
    } else {
        Err(KernelError("fma kernel forced but the CPU does not report AVX2+FMA".into()))
    }
}

#[cfg(not(target_arch = "x86_64"))]
fn fma_kernel() -> Result<&'static dyn MicroKernel, KernelError> {
    Err(KernelError("fma kernel forced but this build targets a non-x86_64 arch".into()))
}

/// A kernel's scalar model wearing the kernel's shape: `tile` runs the
/// wrapped kernel's [`MicroKernel::tile_model`]. The proptests drive the
/// whole blocked core with this to pin blocked products bit-identical to
/// plain-Rust arithmetic per kernel.
pub(crate) struct ModelKernel(&'static dyn MicroKernel);

impl MicroKernel for ModelKernel {
    fn name(&self) -> &'static str {
        "model"
    }

    fn mr(&self) -> usize {
        self.0.mr()
    }

    fn nr(&self) -> usize {
        self.0.nr()
    }

    fn tile(&self, ap: &[f64], bp: &[f64], kc: usize, acc: &mut [f64]) {
        self.0.tile_model(ap, bp, kc, acc);
    }
}

static SCALAR_MODEL: ModelKernel = ModelKernel(&SCALAR);
#[cfg(target_arch = "x86_64")]
static AVX2_MODEL: ModelKernel = ModelKernel(&AVX2);
#[cfg(target_arch = "x86_64")]
static FMA_MODEL: ModelKernel = ModelKernel(&FMA);

/// The model twin of `kernel_for(choice)` (same support requirements,
/// same error on unsupported forces).
pub(crate) fn model_kernel_for(
    choice: KernelChoice,
) -> Result<&'static dyn MicroKernel, KernelError> {
    kernel_for(choice)?;
    match choice {
        KernelChoice::Scalar => Ok(&SCALAR_MODEL),
        #[cfg(target_arch = "x86_64")]
        KernelChoice::Avx2 => Ok(&AVX2_MODEL),
        #[cfg(target_arch = "x86_64")]
        KernelChoice::Fma => Ok(&FMA_MODEL),
        _ => unreachable!("kernel_for accepted the choice"),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;

    fn packed(rng: &mut Rng, len: usize) -> Vec<f64> {
        (0..len).map(|_| rng.normal()).collect()
    }

    #[test]
    fn choice_parse_roundtrip() {
        for c in [
            KernelChoice::Auto,
            KernelChoice::Scalar,
            KernelChoice::Avx2,
            KernelChoice::Fma,
        ] {
            assert_eq!(KernelChoice::parse(&c.to_string()).unwrap(), c);
        }
        assert_eq!(KernelChoice::parse(" FMA "), Ok(KernelChoice::Fma));
        assert!(KernelChoice::parse("avx512").is_err());
        assert!(KernelChoice::parse("").is_err());
    }

    #[test]
    fn enabled_always_includes_scalar_and_best() {
        let enabled = enabled_choices();
        assert_eq!(enabled[0], KernelChoice::Scalar);
        assert!(enabled.contains(&best_available()));
        for &c in &enabled {
            let k = kernel_for(c).expect("enabled kernel must resolve");
            assert!(k.mr() * k.nr() <= MAX_TILE, "{} tile too large", k.name());
        }
    }

    #[test]
    fn scalar_tile_matches_its_model_bitwise() {
        let mut rng = Rng::seed_from(91);
        for kc in [1usize, 2, 7, 33] {
            let ap = packed(&mut rng, kc * S_MR);
            let bp = packed(&mut rng, kc * S_NR);
            let mut a1 = vec![0.0; S_MR * S_NR];
            let mut a2 = vec![0.0; S_MR * S_NR];
            SCALAR.tile(&ap, &bp, kc, &mut a1);
            SCALAR.tile_model(&ap, &bp, kc, &mut a2);
            for (x, y) in a1.iter().zip(&a2) {
                assert_eq!(x.to_bits(), y.to_bits(), "kc={kc}");
            }
        }
    }

    #[test]
    fn every_enabled_kernel_matches_its_model_bitwise() {
        let mut rng = Rng::seed_from(92);
        for &choice in &enabled_choices() {
            let k = kernel_for(choice).unwrap();
            let (mr, nr) = (k.mr(), k.nr());
            for kc in [1usize, 5, 64] {
                let ap = packed(&mut rng, kc * mr);
                let bp = packed(&mut rng, kc * nr);
                // Non-zero starting acc exercises the += contract.
                let start = packed(&mut rng, mr * nr);
                let mut a1 = start.clone();
                let mut a2 = start.clone();
                k.tile(&ap, &bp, kc, &mut a1);
                k.tile_model(&ap, &bp, kc, &mut a2);
                for (e, (x, y)) in a1.iter().zip(&a2).enumerate() {
                    assert_eq!(
                        x.to_bits(),
                        y.to_bits(),
                        "{} kc={kc} elem={e}: {x} vs {y}",
                        k.name()
                    );
                }
            }
        }
    }

    #[test]
    fn every_enabled_f32_kernel_matches_its_model_bitwise() {
        let mut rng = Rng::seed_from(93);
        for &choice in &enabled_choices() {
            let k = kernel_f32_for(choice).unwrap();
            let (mr, nr) = (k.mr(), k.nr());
            assert!(mr * nr <= MAX_TILE, "{} tile too large", k.name());
            for kc in [1usize, 5, 64] {
                let ap: Vec<f32> = (0..kc * mr).map(|_| rng.normal() as f32).collect();
                let bp: Vec<f32> = (0..kc * nr).map(|_| rng.normal() as f32).collect();
                let start: Vec<f32> = (0..mr * nr).map(|_| rng.normal() as f32).collect();
                let mut a1 = start.clone();
                let mut a2 = start.clone();
                k.tile(&ap, &bp, kc, &mut a1);
                k.tile_model(&ap, &bp, kc, &mut a2);
                for (e, (x, y)) in a1.iter().zip(&a2).enumerate() {
                    assert_eq!(
                        x.to_bits(),
                        y.to_bits(),
                        "{} kc={kc} elem={e}: {x} vs {y}",
                        k.name()
                    );
                }
            }
        }
    }

    #[test]
    fn f32_kernel_availability_mirrors_f64() {
        for c in [KernelChoice::Scalar, KernelChoice::Avx2, KernelChoice::Fma] {
            assert_eq!(kernel_for(c).is_ok(), kernel_f32_for(c).is_ok(), "{c}");
        }
    }

    #[test]
    fn unsupported_force_is_a_clear_error() {
        // Whatever this machine supports, the error path must render a
        // human-readable message; exercise it via a fabricated
        // non-x86_64 style check when possible.
        #[cfg(target_arch = "x86_64")]
        {
            if !fma_detected() {
                let e = kernel_for(KernelChoice::Fma).unwrap_err();
                assert!(e.to_string().contains("fma"));
            }
        }
        let e = KernelChoice::parse("neon").unwrap_err();
        assert!(e.to_string().contains("neon"));
    }
}
