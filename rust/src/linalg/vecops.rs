//! Vector primitives used in every solver inner loop.
//!
//! Loops are written over fixed-width chunks so LLVM reliably
//! auto-vectorizes them (4×f64 = one AVX2 lane). These routines are the
//! bottom of the profile for the coordinate-descent baselines, so they are
//! kept allocation-free and branch-light.

/// Dot product `xᵀy`.
#[inline]
pub fn dot(x: &[f64], y: &[f64]) -> f64 {
    debug_assert_eq!(x.len(), y.len());
    let mut acc = [0.0f64; 4];
    let chunks = x.len() / 4;
    for i in 0..chunks {
        let b = i * 4;
        // Four independent accumulators break the dependency chain.
        acc[0] += x[b] * y[b];
        acc[1] += x[b + 1] * y[b + 1];
        acc[2] += x[b + 2] * y[b + 2];
        acc[3] += x[b + 3] * y[b + 3];
    }
    let mut s = acc[0] + acc[1] + acc[2] + acc[3];
    for i in chunks * 4..x.len() {
        s += x[i] * y[i];
    }
    s
}

/// `y ← y + a·x` (BLAS axpy).
#[inline]
pub fn axpy(a: f64, x: &[f64], y: &mut [f64]) {
    debug_assert_eq!(x.len(), y.len());
    for (yi, xi) in y.iter_mut().zip(x.iter()) {
        *yi += a * xi;
    }
}

/// `y ← a·x + b·y`.
#[inline]
pub fn axpby(a: f64, x: &[f64], b: f64, y: &mut [f64]) {
    debug_assert_eq!(x.len(), y.len());
    for (yi, xi) in y.iter_mut().zip(x.iter()) {
        *yi = a * xi + b * *yi;
    }
}

/// `x ← a·x`.
#[inline]
pub fn scale(a: f64, x: &mut [f64]) {
    for xi in x.iter_mut() {
        *xi *= a;
    }
}

/// Squared Euclidean norm `‖x‖²`.
#[inline]
pub fn norm2_sq(x: &[f64]) -> f64 {
    dot(x, x)
}

/// Euclidean norm `‖x‖`.
#[inline]
pub fn norm2(x: &[f64]) -> f64 {
    norm2_sq(x).sqrt()
}

/// L1 norm `|x|₁`.
#[inline]
pub fn norm1(x: &[f64]) -> f64 {
    x.iter().map(|v| v.abs()).sum()
}

/// Max-abs (L∞) norm.
#[inline]
pub fn norm_inf(x: &[f64]) -> f64 {
    x.iter().fold(0.0, |m, v| m.max(v.abs()))
}

/// Elementwise `z ← x − y`.
#[inline]
pub fn sub(x: &[f64], y: &[f64], z: &mut [f64]) {
    debug_assert!(x.len() == y.len() && y.len() == z.len());
    for i in 0..z.len() {
        z[i] = x[i] - y[i];
    }
}

/// Elementwise `z ← x + y`.
#[inline]
pub fn add(x: &[f64], y: &[f64], z: &mut [f64]) {
    debug_assert!(x.len() == y.len() && y.len() == z.len());
    for i in 0..z.len() {
        z[i] = x[i] + y[i];
    }
}

/// Mean of a slice (0 for empty input).
#[inline]
pub fn mean(x: &[f64]) -> f64 {
    if x.is_empty() {
        0.0
    } else {
        x.iter().sum::<f64>() / x.len() as f64
    }
}

/// Soft-thresholding operator `S(v, γ) = sign(v)·max(|v|−γ, 0)` — the
/// elementary step of every coordinate-descent Lasso/Elastic-Net update.
#[inline]
pub fn soft_threshold(v: f64, gamma: f64) -> f64 {
    if v > gamma {
        v - gamma
    } else if v < -gamma {
        v + gamma
    } else {
        0.0
    }
}

/// Number of entries with `|x_i| > tol`.
#[inline]
pub fn nnz(x: &[f64], tol: f64) -> usize {
    x.iter().filter(|v| v.abs() > tol).count()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dot_matches_naive() {
        let x: Vec<f64> = (0..37).map(|i| i as f64 * 0.5 - 3.0).collect();
        let y: Vec<f64> = (0..37).map(|i| (i as f64).sin()).collect();
        let naive: f64 = x.iter().zip(&y).map(|(a, b)| a * b).sum();
        assert!((dot(&x, &y) - naive).abs() < 1e-10);
    }

    #[test]
    fn dot_empty_and_short() {
        assert_eq!(dot(&[], &[]), 0.0);
        assert_eq!(dot(&[2.0], &[3.0]), 6.0);
        assert_eq!(dot(&[1.0, 2.0, 3.0], &[4.0, 5.0, 6.0]), 32.0);
    }

    #[test]
    fn axpy_updates_in_place() {
        let x = [1.0, 2.0, 3.0];
        let mut y = [10.0, 20.0, 30.0];
        axpy(2.0, &x, &mut y);
        assert_eq!(y, [12.0, 24.0, 36.0]);
    }

    #[test]
    fn axpby_combines() {
        let x = [1.0, 1.0];
        let mut y = [2.0, 4.0];
        axpby(3.0, &x, 0.5, &mut y);
        assert_eq!(y, [4.0, 5.0]);
    }

    #[test]
    fn norms() {
        let x = [3.0, -4.0];
        assert!((norm2(&x) - 5.0).abs() < 1e-15);
        assert!((norm1(&x) - 7.0).abs() < 1e-15);
        assert!((norm_inf(&x) - 4.0).abs() < 1e-15);
    }

    #[test]
    fn soft_threshold_cases() {
        assert_eq!(soft_threshold(3.0, 1.0), 2.0);
        assert_eq!(soft_threshold(-3.0, 1.0), -2.0);
        assert_eq!(soft_threshold(0.5, 1.0), 0.0);
        assert_eq!(soft_threshold(-0.5, 1.0), 0.0);
        assert_eq!(soft_threshold(1.0, 1.0), 0.0);
    }

    #[test]
    fn nnz_counts() {
        assert_eq!(nnz(&[0.0, 1e-12, 0.5, -2.0], 1e-9), 2);
    }

    #[test]
    fn mean_handles_empty() {
        assert_eq!(mean(&[]), 0.0);
        assert!((mean(&[1.0, 2.0, 3.0]) - 2.0).abs() < 1e-15);
    }
}
