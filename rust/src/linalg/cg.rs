//! Conjugate gradients over abstract linear operators.
//!
//! The SVM primal Newton step solves `(λI + 2C·X̂ᵀ diag(sv) X̂)·δ = −g`
//! without ever forming the Hessian — only Hessian-vector products — which
//! is exactly the structure Chapelle (2007) exploits and what the paper's
//! GPU backend parallelizes. The same routine (with a Jacobi/diagonal
//! preconditioner) backs the L1_LS interior-point solver (Kim et al. 2007).

use super::multivec::MultiVec;
use super::vecops;

/// Abstract symmetric positive (semi)definite operator `v ↦ A·v`.
pub trait LinOp {
    fn dim(&self) -> usize;
    /// `out ← A·v` (out is pre-sized, may be overwritten).
    fn apply(&self, v: &[f64], out: &mut [f64]);
    /// Optional diagonal preconditioner `M⁻¹ ≈ diag(A)⁻¹`; `None` = identity.
    fn precond(&self, _r: &[f64], _out: &mut [f64]) -> bool {
        false
    }
}

/// A family of symmetric positive (semi)definite operators sharing one
/// data stream — the blocked-CG substrate. Problem `j` of the family is
/// an operator `A_j` (typically the same matrix with per-problem scalar
/// shifts: neighboring path points' Newton Hessians, per-λ interior-point
/// systems); `apply_multi` computes the whole panel of products in one
/// fused pass over the shared data.
///
/// **Contract:** slot `s` of `out` must be **bit-identical** to what a
/// solo [`LinOp::apply`] of operator `A_{cols[s]}` would produce on
/// `vs.col(s)`, at any thread count and any panel width / slot order.
/// The fused multi-RHS kernels in [`crate::linalg`] satisfy this (they
/// keep the exact single-RHS per-element reduction order), so operators
/// built on them inherit it — which is what lets
/// [`cg_solve_multi_with`] promise per-column bit-identity to solo CG.
pub trait MultiLinOp {
    /// Shared system dimension.
    fn dim(&self) -> usize;
    /// Number of problems in the family.
    fn nprobs(&self) -> usize;
    /// Fused panel product: `out.col(s) ← A_{cols[s]} · vs.col(s)` for
    /// every slot `s`. `cols` maps panel slots to problem indices (the
    /// panel shrinks under compaction, so slots are not problem ids).
    fn apply_multi(&self, cols: &[usize], vs: &MultiVec, out: &mut MultiVec);
    /// Optional per-problem diagonal preconditioner for problem `j`;
    /// must match the solo operator's [`LinOp::precond`] bit-for-bit.
    fn precond(&self, _j: usize, _r: &[f64], _out: &mut [f64]) -> bool {
        false
    }
}

/// Adapter viewing one problem of a [`MultiLinOp`] family as a solo
/// [`LinOp`] — the reference the blocked solver's bit-identity contract
/// (and its tests) compare against.
pub struct MultiCol<'a, A: MultiLinOp> {
    pub op: &'a A,
    pub col: usize,
}

impl<A: MultiLinOp> LinOp for MultiCol<'_, A> {
    fn dim(&self) -> usize {
        self.op.dim()
    }

    fn apply(&self, v: &[f64], out: &mut [f64]) {
        let vs = MultiVec::from_cols(&[v]);
        let mut os = MultiVec::zeros(out.len(), 1);
        self.op.apply_multi(&[self.col], &vs, &mut os);
        out.copy_from_slice(os.col(0));
    }

    fn precond(&self, r: &[f64], out: &mut [f64]) -> bool {
        self.op.precond(self.col, r, out)
    }
}

/// Options for [`cg_solve`].
#[derive(Clone, Debug)]
pub struct CgOptions {
    /// Relative residual tolerance ‖r‖/‖b‖.
    pub tol: f64,
    /// Iteration cap (0 ⇒ 2·dim; finite-precision CG routinely needs more
    /// than the textbook n iterations on ill-conditioned systems).
    pub max_iter: usize,
}

impl Default for CgOptions {
    fn default() -> Self {
        CgOptions { tol: 1e-10, max_iter: 0 }
    }
}

/// Result of a CG solve.
#[derive(Clone, Debug)]
pub struct CgOutcome {
    pub iters: usize,
    pub rel_residual: f64,
    pub converged: bool,
    /// A NaN/±∞ was observed in the right-hand side, the curvature
    /// `pᵀAp`, or the residual — the numerical-health signal the
    /// degradation ladder keys off (a plain curvature breakdown on a
    /// PSD-only operator stays `false`).
    pub non_finite: bool,
}

/// Reusable CG workspace: the five work vectors (`r`, `ax`, `z`, `p`,
/// `ap`) that [`cg_solve`] would otherwise allocate on every call, plus
/// their panel-shaped twins for the blocked solver
/// ([`cg_solve_multi_with`]). Hot callers (the primal Newton's
/// per-iteration CG, the L1_LS interior-point loop) hold one scratch for
/// the whole outer loop, so the inner solves allocate nothing.
#[derive(Clone, Debug, Default)]
pub struct CgScratch {
    r: Vec<f64>,
    ax: Vec<f64>,
    z: Vec<f64>,
    p: Vec<f64>,
    ap: Vec<f64>,
    /// Panel-shaped r/p/ap (+ z) buffers of the blocked solver.
    rm: MultiVec,
    zm: MultiVec,
    pm: MultiVec,
    apm: MultiVec,
    /// Outer-loop residual / correction of [`cg_solve_refined`]. Kept
    /// out of [`CgScratch::resize`] — the inner solves resize the five
    /// solo buffers while these two must survive across them.
    rr: Vec<f64>,
    cx: Vec<f64>,
}

impl CgScratch {
    pub fn new() -> Self {
        Self::default()
    }

    /// Size every buffer to `n` and zero it, so a reused scratch starts
    /// every solve from exactly the state a fresh allocation would —
    /// reuse can never change result bits.
    fn resize(&mut self, n: usize) {
        for buf in [&mut self.r, &mut self.ax, &mut self.z, &mut self.p, &mut self.ap] {
            buf.clear();
            buf.resize(n, 0.0);
        }
    }

    /// Size the panel buffers to `n × w` and zero them (same reuse-is-
    /// bit-identical guarantee as [`CgScratch::resize`];
    /// [`MultiVec::resize`] zero-fills by contract).
    fn resize_multi(&mut self, n: usize, w: usize) {
        for buf in [&mut self.rm, &mut self.zm, &mut self.pm, &mut self.apm] {
            buf.resize(n, w);
        }
    }
}

/// Preconditioned conjugate gradients: solves `A·x = b`, starting from the
/// provided `x` (warm start). Returns iteration stats. Allocates its
/// workspace; loops should use [`cg_solve_with`] with a reused
/// [`CgScratch`].
pub fn cg_solve<A: LinOp>(a: &A, b: &[f64], x: &mut [f64], opts: &CgOptions) -> CgOutcome {
    cg_solve_with(a, b, x, opts, &mut CgScratch::new())
}

/// [`cg_solve`] over a caller-provided workspace — allocation-free when
/// the scratch is already sized.
pub fn cg_solve_with<A: LinOp>(
    a: &A,
    b: &[f64],
    x: &mut [f64],
    opts: &CgOptions,
    scratch: &mut CgScratch,
) -> CgOutcome {
    let n = a.dim();
    assert_eq!(b.len(), n);
    assert_eq!(x.len(), n);
    let max_iter = if opts.max_iter == 0 { (2 * n).max(16) } else { opts.max_iter };

    let bnorm = vecops::norm2(b);
    if bnorm == 0.0 {
        x.fill(0.0);
        return CgOutcome { iters: 0, rel_residual: 0.0, converged: true, non_finite: false };
    }
    let mut non_finite = !bnorm.is_finite();

    scratch.resize(n);
    let CgScratch { r, ax, z, p, ap, .. } = scratch;
    a.apply(x, ax);
    for i in 0..n {
        r[i] = b[i] - ax[i];
    }

    let have_pre = a.precond(r, z);
    if !have_pre {
        z.copy_from_slice(r);
    }
    p.copy_from_slice(z);
    let mut rz = vecops::dot(r, z);

    let mut iters = 0;
    let mut rel = vecops::norm2(r) / bnorm;
    while rel > opts.tol && iters < max_iter {
        a.apply(p, ap);
        let pap = vecops::dot(p, ap);
        if pap <= 0.0 || !pap.is_finite() {
            // Curvature breakdown: operator only PSD along p; stop with
            // the current (best-so-far) iterate.
            non_finite = non_finite || !pap.is_finite();
            break;
        }
        let alpha = rz / pap;
        vecops::axpy(alpha, p, x);
        vecops::axpy(-alpha, ap, r);
        rel = vecops::norm2(r) / bnorm;
        iters += 1;
        if rel <= opts.tol {
            break;
        }
        if a.precond(r, z) {
            // preconditioned direction update
        } else {
            z.copy_from_slice(r);
        }
        let rz_new = vecops::dot(r, z);
        let beta = rz_new / rz;
        rz = rz_new;
        for i in 0..n {
            p[i] = z[i] + beta * p[i];
        }
    }
    CgOutcome {
        iters,
        rel_residual: rel,
        converged: rel <= opts.tol,
        non_finite: non_finite || !rel.is_finite(),
    }
}

/// Result of a mixed-precision refined solve ([`cg_solve_refined`]).
#[derive(Clone, Debug)]
pub struct RefineOutcome {
    /// Total inner CG iterations (fast-operator solves plus any f64
    /// fallback solve).
    pub cg_iters: usize,
    /// Outer refinement passes (correction solves after the initial one).
    pub refine_passes: usize,
    /// Whether the **f64** residual met `opts.tol · ‖b‖`.
    pub converged: bool,
    /// Whether refinement stalled and the solve fell back to plain f64
    /// CG from the current iterate.
    pub fell_back: bool,
    /// A NaN/±∞ survived the full ladder (f32 inner loops → f64
    /// fallback, with a non-finite iterate reset to zero first): the
    /// system itself is poisoned, not just the f32 approximation.
    pub non_finite: bool,
}

/// Refinement passes are capped here; a solve that has not converged by
/// then is not gaining a digit per pass and goes to the f64 fallback.
const MAX_REFINE_PASSES: usize = 8;

/// Mixed-precision iterative refinement: solve `A·x = b` to the **f64**
/// tolerance in `opts` while running the bandwidth-bound CG inner loops
/// on a cheaper `fast` operator (in practice: the same Hessian with its
/// panel products demoted to `f32`).
///
/// Each pass computes the true residual `r = b − exact·x` in f64, checks
/// it against `opts.tol·‖b‖`, and if needed solves the correction system
/// `fast·c ≈ r` (inner tolerance `max(opts.tol, 1e-6)` — f32 products
/// cannot resolve residuals much below single precision) and updates
/// `x += c`. When a pass fails to halve the residual — the f32
/// approximation has run out of digits — or the pass cap is reached, the
/// solve falls back to plain f64 [`cg_solve_with`] on `exact` from the
/// current iterate, so the returned direction always meets the same
/// contract as a pure-f64 solve.
///
/// Every step is fixed-order f64 arithmetic around the inner solves, so
/// for a fixed kernel choice the result is bit-stable across thread
/// counts whenever the two operators are (the crate's operators all
/// are).
pub fn cg_solve_refined<E: LinOp, F: LinOp>(
    exact: &E,
    fast: &F,
    b: &[f64],
    x: &mut [f64],
    opts: &CgOptions,
    scratch: &mut CgScratch,
) -> RefineOutcome {
    let n = exact.dim();
    assert_eq!(fast.dim(), n, "fast/exact dimension mismatch");
    assert_eq!(b.len(), n);
    assert_eq!(x.len(), n);

    let bnorm = vecops::norm2(b);
    if bnorm == 0.0 {
        x.fill(0.0);
        return RefineOutcome {
            cg_iters: 0,
            refine_passes: 0,
            converged: true,
            fell_back: false,
            non_finite: false,
        };
    }
    if !bnorm.is_finite() {
        // An ∞ rhs would make `rn ≤ tol·bnorm` compare ∞ ≤ ∞ and declare
        // spurious convergence; flag the poisoned system immediately.
        return RefineOutcome {
            cg_iters: 0,
            refine_passes: 0,
            converged: false,
            fell_back: false,
            non_finite: true,
        };
    }

    let inner = CgOptions { tol: opts.tol.max(1e-6), max_iter: opts.max_iter };
    let mut rr = std::mem::take(&mut scratch.rr);
    let mut cx = std::mem::take(&mut scratch.cx);
    rr.clear();
    rr.resize(n, 0.0);
    cx.clear();
    cx.resize(n, 0.0);

    let mut cg_iters = cg_solve_with(fast, b, x, &inner, scratch).iters;
    let mut refine_passes = 0usize;
    let mut prev_rn = f64::INFINITY;
    let (converged, fell_back, non_finite) = loop {
        exact.apply(x, &mut rr);
        for i in 0..n {
            rr[i] = b[i] - rr[i];
        }
        let rn = vecops::norm2(&rr);
        if rn <= opts.tol * bnorm {
            break (true, false, false);
        }
        if rn >= 0.5 * prev_rn || refine_passes >= MAX_REFINE_PASSES || !rn.is_finite() {
            // Stalled (or out of passes): the f32 operator has run out of
            // digits. Finish in f64 from the current iterate — unless the
            // iterate itself went non-finite (an f32 overflow can), in
            // which case restart the f64 solve from zero so a transient
            // f32 blow-up never poisons the f64 rung of the ladder.
            if x.iter().any(|v| !v.is_finite()) {
                x.fill(0.0);
            }
            let out = cg_solve_with(exact, b, x, opts, scratch);
            cg_iters += out.iters;
            break (out.converged, true, out.non_finite);
        }
        prev_rn = rn;
        cx.fill(0.0);
        let out = cg_solve_with(fast, &rr, &mut cx, &inner, scratch);
        cg_iters += out.iters;
        refine_passes += 1;
        vecops::axpy(1.0, &cx, x);
    };
    scratch.rr = rr;
    scratch.cx = cx;
    RefineOutcome { cg_iters, refine_passes, converged, fell_back, non_finite }
}

/// Result of a blocked multi-RHS CG solve.
#[derive(Clone, Debug)]
pub struct CgMultiOutcome {
    /// Per-problem outcome, identical to what solo [`cg_solve_with`]
    /// would report for that problem.
    pub outcomes: Vec<CgOutcome>,
    /// How many times the panel was compacted (converged columns
    /// evicted so later Hessian products run on a narrower panel).
    pub compactions: usize,
}

/// Blocked preconditioned CG: drives every problem of a [`MultiLinOp`]
/// family through **one shared panel product per iteration**
/// (`apply_multi`), which is where the panel width pays — the shared
/// data (the gathered SV panel, the design matrix) is streamed once per
/// iteration for all right-hand sides instead of once per problem.
///
/// Column `j` is solved from the warm start `x.col(j)`; its iterate
/// sequence is **bit-identical** to a solo [`cg_solve_with`] run of the
/// corresponding [`MultiCol`] operator at any thread count: every
/// per-column scalar/vector operation replicates the solo loop's order
/// exactly, and the panel product's per-column bit-identity contract
/// does the rest. Converged (or broken-down) columns stop updating but
/// stay in the panel until fewer than half the slots are live, at which
/// point the panel is compacted (counted in
/// [`CgMultiOutcome::compactions`]); eviction cannot move bits because
/// no column's arithmetic ever reads another column.
///
/// `opts` is per-problem (`opts.len() == a.nprobs()`), so callers like
/// the L1_LS interior point can tighten each system's tolerance
/// independently.
pub fn cg_solve_multi_with<A: MultiLinOp>(
    a: &A,
    b: &MultiVec,
    x: &mut MultiVec,
    opts: &[CgOptions],
    scratch: &mut CgScratch,
) -> CgMultiOutcome {
    let n = a.dim();
    let nprobs = a.nprobs();
    assert_eq!((b.rows(), b.ncols()), (n, nprobs), "B shape mismatch");
    assert_eq!((x.rows(), x.ncols()), (n, nprobs), "X shape mismatch");
    assert_eq!(opts.len(), nprobs, "one CgOptions per problem");

    let mut outcomes =
        vec![
            CgOutcome { iters: 0, rel_residual: 0.0, converged: false, non_finite: false };
            nprobs
        ];
    let mut done = vec![false; nprobs];
    let mut rz = vec![0.0; nprobs];
    let mut bnorm = vec![0.0; nprobs];
    let max_iter: Vec<usize> = opts
        .iter()
        .map(|o| if o.max_iter == 0 { (2 * n).max(16) } else { o.max_iter })
        .collect();

    // Zero right-hand sides resolve immediately (exactly as solo CG does)
    // and never enter the panel.
    let mut slots: Vec<usize> = Vec::with_capacity(nprobs);
    for j in 0..nprobs {
        bnorm[j] = vecops::norm2(b.col(j));
        if bnorm[j] == 0.0 {
            x.col_mut(j).fill(0.0);
            outcomes[j].converged = true;
            done[j] = true;
        } else {
            slots.push(j);
        }
    }
    if slots.is_empty() {
        return CgMultiOutcome { outcomes, compactions: 0 };
    }

    scratch.resize_multi(n, slots.len());
    let CgScratch { rm, zm, pm, apm, .. } = scratch;

    // Initial residual r = b − A·x: one fused panel product over the
    // warm starts.
    for (s, &j) in slots.iter().enumerate() {
        pm.col_mut(s).copy_from_slice(x.col(j));
    }
    a.apply_multi(&slots, pm, apm);
    for (s, &j) in slots.iter().enumerate() {
        let bcol = b.col(j);
        let ax = apm.col(s);
        let r = rm.col_mut(s);
        for i in 0..n {
            r[i] = bcol[i] - ax[i];
        }
    }
    // z / p / ρ per column, and the initial convergence check.
    let mut live = 0usize;
    for (s, &j) in slots.iter().enumerate() {
        if !a.precond(j, rm.col(s), zm.col_mut(s)) {
            zm.col_mut(s).copy_from_slice(rm.col(s));
        }
        pm.col_mut(s).copy_from_slice(zm.col(s));
        rz[j] = vecops::dot(rm.col(s), zm.col(s));
        let rel = vecops::norm2(rm.col(s)) / bnorm[j];
        outcomes[j].rel_residual = rel;
        if rel <= opts[j].tol {
            outcomes[j].converged = true;
            done[j] = true;
        } else if !rel.is_finite() {
            // Poisoned column: solo CG's `while rel > tol` never enters
            // on a NaN residual, so freezing here keeps bit-parity while
            // flagging the breakdown.
            outcomes[j].non_finite = true;
            done[j] = true;
        } else {
            live += 1;
        }
    }

    let mut compactions = 0usize;
    while live > 0 {
        // Converged columns ride along (their slots are skipped but still
        // multiplied) until fewer than half the slots are live, then the
        // panel compacts: p/r columns slide down, dead slots drop off.
        if live * 2 <= slots.len() && live < slots.len() {
            let rows = n;
            let mut dst = 0usize;
            let mut kept: Vec<usize> = Vec::with_capacity(live);
            for (s, &j) in slots.iter().enumerate() {
                if done[j] {
                    continue;
                }
                if dst != s {
                    pm.data_mut().copy_within(s * rows..(s + 1) * rows, dst * rows);
                    rm.data_mut().copy_within(s * rows..(s + 1) * rows, dst * rows);
                }
                kept.push(j);
                dst += 1;
            }
            slots = kept;
            pm.truncate_cols(dst);
            rm.truncate_cols(dst);
            zm.truncate_cols(dst);
            apm.truncate_cols(dst);
            compactions += 1;
        }

        // The blocked step: one fused product feeds every live column.
        a.apply_multi(&slots, pm, apm);
        for (s, &j) in slots.iter().enumerate() {
            if done[j] {
                continue;
            }
            let pap = vecops::dot(pm.col(s), apm.col(s));
            if pap <= 0.0 || !pap.is_finite() {
                // Curvature breakdown: stop with the best-so-far iterate,
                // exactly as the solo loop does.
                outcomes[j].non_finite = outcomes[j].non_finite || !pap.is_finite();
                done[j] = true;
                live -= 1;
                continue;
            }
            let alpha = rz[j] / pap;
            vecops::axpy(alpha, pm.col(s), x.col_mut(j));
            vecops::axpy(-alpha, apm.col(s), rm.col_mut(s));
            let rel = vecops::norm2(rm.col(s)) / bnorm[j];
            outcomes[j].iters += 1;
            outcomes[j].rel_residual = rel;
            if rel <= opts[j].tol {
                outcomes[j].converged = true;
                done[j] = true;
                live -= 1;
                continue;
            }
            if !rel.is_finite() {
                // Solo CG exits at the loop head when rel goes NaN (the
                // comparison is false); freeze the column the same way.
                outcomes[j].non_finite = true;
                done[j] = true;
                live -= 1;
                continue;
            }
            if outcomes[j].iters >= max_iter[j] {
                // Solo CG would still update z/p before noticing the cap
                // at the loop head; those updates are unobservable, so
                // the column can freeze here without moving bits.
                done[j] = true;
                live -= 1;
                continue;
            }
            if !a.precond(j, rm.col(s), zm.col_mut(s)) {
                zm.col_mut(s).copy_from_slice(rm.col(s));
            }
            let rz_new = vecops::dot(rm.col(s), zm.col(s));
            let beta = rz_new / rz[j];
            rz[j] = rz_new;
            let zc = zm.col(s);
            let pc = pm.col_mut(s);
            for i in 0..n {
                pc[i] = zc[i] + beta * pc[i];
            }
        }
    }
    CgMultiOutcome { outcomes, compactions }
}

/// [`cg_solve_multi_with`] over a fresh workspace (tests / one-shot
/// callers).
pub fn cg_solve_multi<A: MultiLinOp>(
    a: &A,
    b: &MultiVec,
    x: &mut MultiVec,
    opts: &[CgOptions],
) -> CgMultiOutcome {
    cg_solve_multi_with(a, b, x, opts, &mut CgScratch::new())
}

/// A dense matrix as a LinOp (testing / small systems).
pub struct DenseOp<'a>(pub &'a super::dense::Mat);

impl LinOp for DenseOp<'_> {
    fn dim(&self) -> usize {
        self.0.rows()
    }
    fn apply(&self, v: &[f64], out: &mut [f64]) {
        self.0.matvec_into(v, out);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::dense::Mat;
    use crate::rng::Rng;

    fn random_spd(rng: &mut Rng, n: usize) -> Mat {
        let a = Mat::from_fn(n, n, |_, _| rng.normal());
        let mut g = a.gram();
        for i in 0..n {
            let v = g.get(i, i) + 1.0;
            g.set(i, i, v);
        }
        g
    }

    #[test]
    fn solves_spd_system() {
        let mut rng = Rng::seed_from(31);
        for n in [1usize, 3, 10, 50] {
            let a = random_spd(&mut rng, n);
            let x_true: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
            let b = a.matvec(&x_true);
            let mut x = vec![0.0; n];
            let out = cg_solve(&DenseOp(&a), &b, &mut x, &CgOptions::default());
            assert!(out.converged, "n={n} rel={}", out.rel_residual);
            for i in 0..n {
                assert!((x[i] - x_true[i]).abs() < 1e-6, "n={n}");
            }
        }
    }

    #[test]
    fn warm_start_reduces_iterations() {
        let mut rng = Rng::seed_from(32);
        let n = 60;
        let a = random_spd(&mut rng, n);
        let x_true: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
        let b = a.matvec(&x_true);
        let mut cold = vec![0.0; n];
        let it_cold = cg_solve(&DenseOp(&a), &b, &mut cold, &CgOptions::default()).iters;
        // warm start near the solution
        let mut warm: Vec<f64> = x_true.iter().map(|v| v + 1e-6).collect();
        let it_warm = cg_solve(&DenseOp(&a), &b, &mut warm, &CgOptions::default()).iters;
        assert!(it_warm < it_cold, "warm {it_warm} vs cold {it_cold}");
    }

    #[test]
    fn zero_rhs_gives_zero() {
        let a = Mat::eye(4);
        let mut x = vec![1.0; 4];
        let out = cg_solve(&DenseOp(&a), &[0.0; 4], &mut x, &CgOptions::default());
        assert!(out.converged);
        assert_eq!(x, vec![0.0; 4]);
    }

    #[test]
    fn scratch_reuse_is_bit_identical_to_fresh() {
        // One CgScratch across differently-sized solves must give exactly
        // the allocating path's results (the scratch resize fully
        // re-initializes every buffer).
        let mut rng = Rng::seed_from(34);
        let mut scratch = CgScratch::new();
        for n in [40usize, 12, 25] {
            let a = random_spd(&mut rng, n);
            let b: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
            let mut x1 = vec![0.0; n];
            let out1 = cg_solve(&DenseOp(&a), &b, &mut x1, &CgOptions::default());
            let mut x2 = vec![0.0; n];
            let out2 =
                cg_solve_with(&DenseOp(&a), &b, &mut x2, &CgOptions::default(), &mut scratch);
            assert_eq!(out1.iters, out2.iters, "n={n}");
            for i in 0..n {
                assert_eq!(x1[i].to_bits(), x2[i].to_bits(), "n={n} i={i}");
            }
        }
    }

    #[test]
    fn non_finite_rhs_is_flagged() {
        let a = Mat::eye(4);
        let mut b = vec![1.0; 4];
        b[2] = f64::NAN;
        let mut x = vec![0.0; 4];
        let out = cg_solve(&DenseOp(&a), &b, &mut x, &CgOptions::default());
        assert!(out.non_finite, "NaN rhs must trip the guard");
        assert!(!out.converged);
        // clean solves never flag
        let mut xc = vec![0.0; 4];
        let clean = cg_solve(&DenseOp(&a), &[1.0; 4], &mut xc, &CgOptions::default());
        assert!(clean.converged && !clean.non_finite);
    }

    #[test]
    fn blocked_flags_poisoned_column_and_siblings_stay_bit_clean() {
        let mut rng = Rng::seed_from(44);
        let n = 30;
        let g = random_spd(&mut rng, n);
        let fam = ShiftedFamily { g: &g, shifts: vec![1.0, 2.0, 0.5] };
        let mut b = MultiVec::from_fn(n, 3, |_, _| rng.normal());
        let clean_b1 = b.col(1).to_vec();
        b.col_mut(1)[0] = f64::NAN;
        let mut x = MultiVec::zeros(n, 3);
        let opts = vec![CgOptions::default(); 3];
        let multi = cg_solve_multi(&fam, &b, &mut x, &opts);
        assert!(multi.outcomes[1].non_finite, "poisoned column must be flagged");
        assert!(!multi.outcomes[1].converged);
        for j in [0usize, 2] {
            assert!(!multi.outcomes[j].non_finite, "j={j}");
            let solo_op = ShiftedOp { g: &g, d: fam.shifts[j] };
            let mut xs = vec![0.0; n];
            let solo = cg_solve(&solo_op, b.col(j), &mut xs, &CgOptions::default());
            assert_eq!(solo.iters, multi.outcomes[j].iters, "j={j}");
            for i in 0..n {
                assert_eq!(xs[i].to_bits(), x.col(j)[i].to_bits(), "j={j} i={i}");
            }
        }
        let _ = clean_b1;
    }

    #[test]
    fn refined_flags_non_finite_system_but_recovers_from_f32_blowup() {
        let mut rng = Rng::seed_from(45);
        let n = 12;
        let a = random_spd(&mut rng, n);
        let mut b: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
        b[0] = f64::INFINITY;
        let mut x = vec![0.0; n];
        let out = cg_solve_refined(
            &DenseOp(&a),
            &RoundedOp(&a),
            &b,
            &mut x,
            &CgOptions::default(),
            &mut CgScratch::new(),
        );
        assert!(out.non_finite, "a poisoned system must be flagged after the full ladder");
        assert!(!out.converged);
    }

    #[test]
    fn respects_max_iter() {
        let mut rng = Rng::seed_from(33);
        let a = random_spd(&mut rng, 40);
        let b: Vec<f64> = (0..40).map(|_| rng.normal()).collect();
        let mut x = vec![0.0; 40];
        let out = cg_solve(&DenseOp(&a), &b, &mut x, &CgOptions { tol: 1e-16, max_iter: 3 });
        assert!(out.iters <= 3);
    }

    /// An f32-degraded view of a dense SPD operator: entries rounded to
    /// `f32`, products accumulated in `f32` — the refinement loop's
    /// stand-in for the real demoted panel products.
    struct RoundedOp<'a>(&'a Mat);

    impl LinOp for RoundedOp<'_> {
        fn dim(&self) -> usize {
            self.0.rows()
        }

        fn apply(&self, v: &[f64], out: &mut [f64]) {
            let n = self.0.rows();
            for i in 0..n {
                let mut acc = 0.0f32;
                for j in 0..n {
                    acc += (self.0.get(i, j) as f32) * (v[j] as f32);
                }
                out[i] = acc as f64;
            }
        }
    }

    #[test]
    fn refined_solve_reaches_f64_tolerance_through_f32_inner_loops() {
        let mut rng = Rng::seed_from(40);
        for n in [5usize, 20, 60] {
            let a = random_spd(&mut rng, n);
            let x_true: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
            let b = a.matvec(&x_true);
            let opts = CgOptions::default();
            let mut x = vec![0.0; n];
            let out = cg_solve_refined(
                &DenseOp(&a),
                &RoundedOp(&a),
                &b,
                &mut x,
                &opts,
                &mut CgScratch::new(),
            );
            assert!(out.converged, "n={n} passes={}", out.refine_passes);
            // The f64 residual really is at the f64 tolerance, regardless
            // of how it got there.
            let mut ax = vec![0.0; n];
            DenseOp(&a).apply(&x, &mut ax);
            let rn: f64 = (0..n).map(|i| (b[i] - ax[i]).powi(2)).sum::<f64>().sqrt();
            assert!(rn <= opts.tol * vecops::norm2(&b) * (1.0 + 1e-12), "n={n} rn={rn}");
        }
    }

    #[test]
    fn refined_solve_falls_back_when_fast_operator_is_useless() {
        let mut rng = Rng::seed_from(41);
        let n = 30;
        let a = random_spd(&mut rng, n);
        let x_true: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
        let b = a.matvec(&x_true);
        // "Fast" operator with no relation to the exact one: refinement
        // cannot gain digits and must finish in f64.
        let eye = Mat::eye(n);
        let mut x = vec![0.0; n];
        let out = cg_solve_refined(
            &DenseOp(&a),
            &DenseOp(&eye),
            &b,
            &mut x,
            &CgOptions::default(),
            &mut CgScratch::new(),
        );
        assert!(out.fell_back, "identity fast operator must trigger the f64 fallback");
        assert!(out.converged);
        for i in 0..n {
            assert!((x[i] - x_true[i]).abs() < 1e-6, "i={i}");
        }
    }

    #[test]
    fn refined_solve_matches_plain_cg_contract_on_zero_rhs() {
        let a = Mat::eye(4);
        let mut x = vec![1.0; 4];
        let out = cg_solve_refined(
            &DenseOp(&a),
            &RoundedOp(&a),
            &[0.0; 4],
            &mut x,
            &CgOptions::default(),
            &mut CgScratch::new(),
        );
        assert!(out.converged && !out.fell_back);
        assert_eq!(x, vec![0.0; 4]);
    }

    #[test]
    fn refined_scratch_reuse_is_bit_identical_to_fresh() {
        let mut rng = Rng::seed_from(42);
        let mut scratch = CgScratch::new();
        for n in [33usize, 11] {
            let a = random_spd(&mut rng, n);
            let b: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
            let opts = CgOptions::default();
            let mut x1 = vec![0.0; n];
            let o1 = cg_solve_refined(
                &DenseOp(&a),
                &RoundedOp(&a),
                &b,
                &mut x1,
                &opts,
                &mut CgScratch::new(),
            );
            let mut x2 = vec![0.0; n];
            let o2 = cg_solve_refined(&DenseOp(&a), &RoundedOp(&a), &b, &mut x2, &opts, &mut scratch);
            assert_eq!(o1.cg_iters, o2.cg_iters, "n={n}");
            assert_eq!(o1.refine_passes, o2.refine_passes, "n={n}");
            for i in 0..n {
                assert_eq!(x1[i].to_bits(), x2[i].to_bits(), "n={n} i={i}");
            }
        }
    }

    /// A family sharing one gram matrix with per-problem diagonal shifts
    /// `A_j = G + d_j·I` — the blocked-CG test double (the shifts give
    /// every column its own spectrum, hence its own iteration count).
    struct ShiftedFamily<'a> {
        g: &'a Mat,
        shifts: Vec<f64>,
    }

    impl MultiLinOp for ShiftedFamily<'_> {
        fn dim(&self) -> usize {
            self.g.rows()
        }

        fn nprobs(&self) -> usize {
            self.shifts.len()
        }

        fn apply_multi(&self, cols: &[usize], vs: &MultiVec, out: &mut MultiVec) {
            self.g.matvec_multi_into(vs, out);
            for (s, &j) in cols.iter().enumerate() {
                let d = self.shifts[j];
                let v = vs.col(s);
                let o = out.col_mut(s);
                for i in 0..o.len() {
                    o[i] += d * v[i];
                }
            }
        }
    }

    /// Solo reference for one member of [`ShiftedFamily`], built on the
    /// *single-RHS* kernel — so the bit-equality below proves the
    /// blocked solver matches a genuinely independent solo run, not just
    /// a width-1 panel of itself.
    struct ShiftedOp<'a> {
        g: &'a Mat,
        d: f64,
    }

    impl LinOp for ShiftedOp<'_> {
        fn dim(&self) -> usize {
            self.g.rows()
        }

        fn apply(&self, v: &[f64], out: &mut [f64]) {
            self.g.matvec_into(v, out);
            for i in 0..out.len() {
                out[i] += self.d * v[i];
            }
        }
    }

    #[test]
    fn blocked_columns_bit_match_solo_runs() {
        let mut rng = Rng::seed_from(35);
        let n = 48;
        let g = random_spd(&mut rng, n);
        for width in [1usize, 2, 4, 8] {
            // Spread the shifts over orders of magnitude so columns
            // converge at very different iteration counts (exercising the
            // freeze-then-compact path).
            let shifts: Vec<f64> = (0..width).map(|j| 10.0f64.powi(j as i32 % 4)).collect();
            let fam = ShiftedFamily { g: &g, shifts: shifts.clone() };
            let b = MultiVec::from_fn(n, width, |_, _| rng.normal());
            let mut x = MultiVec::zeros(n, width);
            let opts = vec![CgOptions::default(); width];
            let multi = cg_solve_multi(&fam, &b, &mut x, &opts);
            for j in 0..width {
                let solo_op = ShiftedOp { g: &g, d: shifts[j] };
                let mut xs = vec![0.0; n];
                let solo = cg_solve(&solo_op, b.col(j), &mut xs, &CgOptions::default());
                assert_eq!(solo.iters, multi.outcomes[j].iters, "w={width} j={j}");
                assert_eq!(
                    solo.converged, multi.outcomes[j].converged,
                    "w={width} j={j}"
                );
                for i in 0..n {
                    assert_eq!(
                        xs[i].to_bits(),
                        x.col(j)[i].to_bits(),
                        "w={width} j={j} i={i}"
                    );
                }
            }
        }
    }

    #[test]
    fn blocked_handles_zero_columns_and_warm_starts() {
        let mut rng = Rng::seed_from(36);
        let n = 30;
        let g = random_spd(&mut rng, n);
        let fam = ShiftedFamily { g: &g, shifts: vec![1.0, 2.0, 0.5] };
        let mut b = MultiVec::from_fn(n, 3, |_, _| rng.normal());
        b.col_mut(1).fill(0.0); // zero RHS in the middle of the panel
        let mut x = MultiVec::from_fn(n, 3, |_, _| rng.normal()); // warm
        let x0 = x.clone();
        let opts = vec![CgOptions::default(); 3];
        let multi = cg_solve_multi(&fam, &b, &mut x, &opts);
        assert!(multi.outcomes[1].converged);
        assert_eq!(multi.outcomes[1].iters, 0);
        assert!(x.col(1).iter().all(|&v| v == 0.0));
        for j in [0usize, 2] {
            let solo_op = ShiftedOp { g: &g, d: fam.shifts[j] };
            let mut xs = x0.col(j).to_vec();
            let solo = cg_solve(&solo_op, b.col(j), &mut xs, &CgOptions::default());
            assert_eq!(solo.iters, multi.outcomes[j].iters, "j={j}");
            for i in 0..n {
                assert_eq!(xs[i].to_bits(), x.col(j)[i].to_bits(), "j={j} i={i}");
            }
        }
    }

    #[test]
    fn blocked_compacts_after_early_convergence() {
        let mut rng = Rng::seed_from(37);
        let n = 40;
        let g = random_spd(&mut rng, n);
        // One nearly-diagonal (huge shift ⇒ converges in a few iters)
        // column against three slow ones: the fast column must be evicted
        // once ≤ half the panel is live.
        let fam = ShiftedFamily { g: &g, shifts: vec![1e6, 0.1, 0.2, 1e6] };
        let b = MultiVec::from_fn(n, 4, |_, _| rng.normal());
        let mut x = MultiVec::zeros(n, 4);
        let opts = vec![CgOptions::default(); 4];
        let multi = cg_solve_multi(&fam, &b, &mut x, &opts);
        assert!(multi.compactions >= 1, "expected a panel compaction");
        for j in 0..4 {
            let solo_op = ShiftedOp { g: &g, d: fam.shifts[j] };
            let mut xs = vec![0.0; n];
            let solo = cg_solve(&solo_op, b.col(j), &mut xs, &CgOptions::default());
            assert_eq!(solo.iters, multi.outcomes[j].iters, "j={j}");
            for i in 0..n {
                assert_eq!(xs[i].to_bits(), x.col(j)[i].to_bits(), "j={j} i={i}");
            }
        }
    }

    #[test]
    fn blocked_scratch_reuse_is_bit_identical() {
        let mut rng = Rng::seed_from(38);
        let n = 25;
        let g = random_spd(&mut rng, n);
        let fam = ShiftedFamily { g: &g, shifts: vec![0.5, 3.0] };
        let b = MultiVec::from_fn(n, 2, |_, _| rng.normal());
        let opts = vec![CgOptions::default(); 2];
        let mut scratch = CgScratch::new();
        // Dirty the scratch with a differently-shaped solve first.
        let fam_big = ShiftedFamily { g: &g, shifts: vec![1.0; 5] };
        let b_big = MultiVec::from_fn(n, 5, |_, _| rng.normal());
        let mut x_big = MultiVec::zeros(n, 5);
        let opts_big = vec![CgOptions::default(); 5];
        cg_solve_multi_with(&fam_big, &b_big, &mut x_big, &opts_big, &mut scratch);
        let mut x1 = MultiVec::zeros(n, 2);
        let fresh = cg_solve_multi(&fam, &b, &mut x1, &opts);
        let mut x2 = MultiVec::zeros(n, 2);
        let reused = cg_solve_multi_with(&fam, &b, &mut x2, &opts, &mut scratch);
        for j in 0..2 {
            assert_eq!(fresh.outcomes[j].iters, reused.outcomes[j].iters);
            for i in 0..n {
                assert_eq!(x1.col(j)[i].to_bits(), x2.col(j)[i].to_bits());
            }
        }
    }

    #[test]
    fn blocked_respects_per_problem_options() {
        let mut rng = Rng::seed_from(39);
        let n = 35;
        let g = random_spd(&mut rng, n);
        let fam = ShiftedFamily { g: &g, shifts: vec![0.3, 0.3] };
        let b = MultiVec::from_fn(n, 2, |_, _| rng.normal());
        let mut x = MultiVec::zeros(n, 2);
        let opts = vec![
            CgOptions { tol: 1e-16, max_iter: 3 },
            CgOptions { tol: 1e-10, max_iter: 0 },
        ];
        let multi = cg_solve_multi(&fam, &b, &mut x, &opts);
        assert!(multi.outcomes[0].iters <= 3);
        assert!(multi.outcomes[1].converged);
    }
}
