//! Conjugate gradients over abstract linear operators.
//!
//! The SVM primal Newton step solves `(λI + 2C·X̂ᵀ diag(sv) X̂)·δ = −g`
//! without ever forming the Hessian — only Hessian-vector products — which
//! is exactly the structure Chapelle (2007) exploits and what the paper's
//! GPU backend parallelizes. The same routine (with a Jacobi/diagonal
//! preconditioner) backs the L1_LS interior-point solver (Kim et al. 2007).

use super::vecops;

/// Abstract symmetric positive (semi)definite operator `v ↦ A·v`.
pub trait LinOp {
    fn dim(&self) -> usize;
    /// `out ← A·v` (out is pre-sized, may be overwritten).
    fn apply(&self, v: &[f64], out: &mut [f64]);
    /// Optional diagonal preconditioner `M⁻¹ ≈ diag(A)⁻¹`; `None` = identity.
    fn precond(&self, _r: &[f64], _out: &mut [f64]) -> bool {
        false
    }
}

/// Options for [`cg_solve`].
#[derive(Clone, Debug)]
pub struct CgOptions {
    /// Relative residual tolerance ‖r‖/‖b‖.
    pub tol: f64,
    /// Iteration cap (0 ⇒ 2·dim; finite-precision CG routinely needs more
    /// than the textbook n iterations on ill-conditioned systems).
    pub max_iter: usize,
}

impl Default for CgOptions {
    fn default() -> Self {
        CgOptions { tol: 1e-10, max_iter: 0 }
    }
}

/// Result of a CG solve.
#[derive(Clone, Debug)]
pub struct CgOutcome {
    pub iters: usize,
    pub rel_residual: f64,
    pub converged: bool,
}

/// Reusable CG workspace: the five work vectors (`r`, `ax`, `z`, `p`,
/// `ap`) that [`cg_solve`] would otherwise allocate on every call. Hot
/// callers (the primal Newton's per-iteration CG, the L1_LS
/// interior-point loop) hold one scratch for the whole outer loop, so the
/// inner solves allocate nothing.
#[derive(Clone, Debug, Default)]
pub struct CgScratch {
    r: Vec<f64>,
    ax: Vec<f64>,
    z: Vec<f64>,
    p: Vec<f64>,
    ap: Vec<f64>,
}

impl CgScratch {
    pub fn new() -> Self {
        Self::default()
    }

    /// Size every buffer to `n` and zero it, so a reused scratch starts
    /// every solve from exactly the state a fresh allocation would —
    /// reuse can never change result bits.
    fn resize(&mut self, n: usize) {
        for buf in [&mut self.r, &mut self.ax, &mut self.z, &mut self.p, &mut self.ap] {
            buf.clear();
            buf.resize(n, 0.0);
        }
    }
}

/// Preconditioned conjugate gradients: solves `A·x = b`, starting from the
/// provided `x` (warm start). Returns iteration stats. Allocates its
/// workspace; loops should use [`cg_solve_with`] with a reused
/// [`CgScratch`].
pub fn cg_solve<A: LinOp>(a: &A, b: &[f64], x: &mut [f64], opts: &CgOptions) -> CgOutcome {
    cg_solve_with(a, b, x, opts, &mut CgScratch::new())
}

/// [`cg_solve`] over a caller-provided workspace — allocation-free when
/// the scratch is already sized.
pub fn cg_solve_with<A: LinOp>(
    a: &A,
    b: &[f64],
    x: &mut [f64],
    opts: &CgOptions,
    scratch: &mut CgScratch,
) -> CgOutcome {
    let n = a.dim();
    assert_eq!(b.len(), n);
    assert_eq!(x.len(), n);
    let max_iter = if opts.max_iter == 0 { (2 * n).max(16) } else { opts.max_iter };

    let bnorm = vecops::norm2(b);
    if bnorm == 0.0 {
        x.fill(0.0);
        return CgOutcome { iters: 0, rel_residual: 0.0, converged: true };
    }

    scratch.resize(n);
    let CgScratch { r, ax, z, p, ap } = scratch;
    a.apply(x, ax);
    for i in 0..n {
        r[i] = b[i] - ax[i];
    }

    let have_pre = a.precond(r, z);
    if !have_pre {
        z.copy_from_slice(r);
    }
    p.copy_from_slice(z);
    let mut rz = vecops::dot(r, z);

    let mut iters = 0;
    let mut rel = vecops::norm2(r) / bnorm;
    while rel > opts.tol && iters < max_iter {
        a.apply(p, ap);
        let pap = vecops::dot(p, ap);
        if pap <= 0.0 || !pap.is_finite() {
            // Curvature breakdown: operator only PSD along p; stop with
            // the current (best-so-far) iterate.
            break;
        }
        let alpha = rz / pap;
        vecops::axpy(alpha, p, x);
        vecops::axpy(-alpha, ap, r);
        rel = vecops::norm2(r) / bnorm;
        iters += 1;
        if rel <= opts.tol {
            break;
        }
        if a.precond(r, z) {
            // preconditioned direction update
        } else {
            z.copy_from_slice(r);
        }
        let rz_new = vecops::dot(r, z);
        let beta = rz_new / rz;
        rz = rz_new;
        for i in 0..n {
            p[i] = z[i] + beta * p[i];
        }
    }
    CgOutcome { iters, rel_residual: rel, converged: rel <= opts.tol }
}

/// A dense matrix as a LinOp (testing / small systems).
pub struct DenseOp<'a>(pub &'a super::dense::Mat);

impl LinOp for DenseOp<'_> {
    fn dim(&self) -> usize {
        self.0.rows()
    }
    fn apply(&self, v: &[f64], out: &mut [f64]) {
        self.0.matvec_into(v, out);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::dense::Mat;
    use crate::rng::Rng;

    fn random_spd(rng: &mut Rng, n: usize) -> Mat {
        let a = Mat::from_fn(n, n, |_, _| rng.normal());
        let mut g = a.gram();
        for i in 0..n {
            let v = g.get(i, i) + 1.0;
            g.set(i, i, v);
        }
        g
    }

    #[test]
    fn solves_spd_system() {
        let mut rng = Rng::seed_from(31);
        for n in [1usize, 3, 10, 50] {
            let a = random_spd(&mut rng, n);
            let x_true: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
            let b = a.matvec(&x_true);
            let mut x = vec![0.0; n];
            let out = cg_solve(&DenseOp(&a), &b, &mut x, &CgOptions::default());
            assert!(out.converged, "n={n} rel={}", out.rel_residual);
            for i in 0..n {
                assert!((x[i] - x_true[i]).abs() < 1e-6, "n={n}");
            }
        }
    }

    #[test]
    fn warm_start_reduces_iterations() {
        let mut rng = Rng::seed_from(32);
        let n = 60;
        let a = random_spd(&mut rng, n);
        let x_true: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
        let b = a.matvec(&x_true);
        let mut cold = vec![0.0; n];
        let it_cold = cg_solve(&DenseOp(&a), &b, &mut cold, &CgOptions::default()).iters;
        // warm start near the solution
        let mut warm: Vec<f64> = x_true.iter().map(|v| v + 1e-6).collect();
        let it_warm = cg_solve(&DenseOp(&a), &b, &mut warm, &CgOptions::default()).iters;
        assert!(it_warm < it_cold, "warm {it_warm} vs cold {it_cold}");
    }

    #[test]
    fn zero_rhs_gives_zero() {
        let a = Mat::eye(4);
        let mut x = vec![1.0; 4];
        let out = cg_solve(&DenseOp(&a), &[0.0; 4], &mut x, &CgOptions::default());
        assert!(out.converged);
        assert_eq!(x, vec![0.0; 4]);
    }

    #[test]
    fn scratch_reuse_is_bit_identical_to_fresh() {
        // One CgScratch across differently-sized solves must give exactly
        // the allocating path's results (the scratch resize fully
        // re-initializes every buffer).
        let mut rng = Rng::seed_from(34);
        let mut scratch = CgScratch::new();
        for n in [40usize, 12, 25] {
            let a = random_spd(&mut rng, n);
            let b: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
            let mut x1 = vec![0.0; n];
            let out1 = cg_solve(&DenseOp(&a), &b, &mut x1, &CgOptions::default());
            let mut x2 = vec![0.0; n];
            let out2 =
                cg_solve_with(&DenseOp(&a), &b, &mut x2, &CgOptions::default(), &mut scratch);
            assert_eq!(out1.iters, out2.iters, "n={n}");
            for i in 0..n {
                assert_eq!(x1[i].to_bits(), x2[i].to_bits(), "n={n} i={i}");
            }
        }
    }

    #[test]
    fn respects_max_iter() {
        let mut rng = Rng::seed_from(33);
        let a = random_spd(&mut rng, 40);
        let b: Vec<f64> = (0..40).map(|_| rng.normal()).collect();
        let mut x = vec![0.0; 40];
        let out = cg_solve(&DenseOp(&a), &b, &mut x, &CgOptions { tol: 1e-16, max_iter: 3 });
        assert!(out.iters <= 3);
    }
}
