//! The f32 compute tier: packed single-precision storage and the
//! fixed-order products the mixed-precision solver path rides.
//!
//! `MixedF32` (see [`crate::linalg::Precision`]) computes the
//! bandwidth-bound panel products in `f32` — half the memory traffic,
//! double the SIMD width — while residuals, recurrences, and
//! convergence tests stay `f64` and an outer refinement loop restores
//! the full `f64` tolerance. This module owns the storage side:
//!
//! - [`MatF32`] / [`MultiVecF32`] — f32 twins of `Mat`/`MultiVec`,
//!   with GEMV products that *accumulate in f32 and widen to f64 at
//!   the output boundary*, mirroring the f64 kernels' banding / fixed
//!   chunk grids exactly so they inherit the crate's
//!   bit-stable-across-threads contract.
//! - [`DesignShadowF32`] — a one-time f32 shadow of a `Design`
//!   (demoted dense matrix, or demoted values riding the parent CSR's
//!   structure), built at prep time and cached on the prepared
//!   problem.
//!
//! Like the f64 CG product path, the solver-facing products here are
//! plain fixed-order loops, **not** microkernel calls — so the mixed
//! path stays bit-stable across kernel choices as well as thread
//! counts. The f32 *microkernels* (`MicroKernelF32` in `kernel.rs`)
//! serve the blocked GEMM/Gram tier and the benches.

use super::multivec::MultiVec;
use super::{gemm, Design};
use crate::util::parallel;

/// Fixed row-chunk length for transpose-product reductions — the same
/// constant the f64 kernels use, so chunk grids (and result bits) never
/// depend on the worker count.
const TCHUNK: usize = 512;

/// f32 vector primitives mirroring `vecops` (same 4-lane accumulator
/// split, so LLVM vectorizes them identically).
pub mod vecops_f32 {
    /// Dot product `xᵀy` in f32.
    #[inline]
    pub fn dot(x: &[f32], y: &[f32]) -> f32 {
        debug_assert_eq!(x.len(), y.len());
        let mut acc = [0.0f32; 4];
        let chunks = x.len() / 4;
        for i in 0..chunks {
            let b = i * 4;
            acc[0] += x[b] * y[b];
            acc[1] += x[b + 1] * y[b + 1];
            acc[2] += x[b + 2] * y[b + 2];
            acc[3] += x[b + 3] * y[b + 3];
        }
        let mut s = acc[0] + acc[1] + acc[2] + acc[3];
        for i in chunks * 4..x.len() {
            s += x[i] * y[i];
        }
        s
    }

    /// `y ← y + a·x` in f32.
    #[inline]
    pub fn axpy(a: f32, x: &[f32], y: &mut [f32]) {
        debug_assert_eq!(x.len(), y.len());
        for (yi, xi) in y.iter_mut().zip(x.iter()) {
            *yi += a * xi;
        }
    }

    /// Demote an f64 slice into a reusable f32 buffer.
    #[inline]
    pub fn demote(src: &[f64], dst: &mut Vec<f32>) {
        dst.clear();
        dst.extend(src.iter().map(|&v| v as f32));
    }
}

/// Column-major f32 panel — the single-precision twin of
/// [`MultiVec`].
#[derive(Clone, Debug, Default)]
pub struct MultiVecF32 {
    rows: usize,
    ncols: usize,
    data: Vec<f32>,
}

impl MultiVecF32 {
    /// Zero panel of shape `rows × ncols`.
    pub fn zeros(rows: usize, ncols: usize) -> Self {
        MultiVecF32 { rows, ncols, data: vec![0.0; rows * ncols] }
    }

    /// Demote an f64 panel.
    pub fn from_multivec(m: &MultiVec) -> Self {
        MultiVecF32 {
            rows: m.rows(),
            ncols: m.ncols(),
            data: m.data().iter().map(|&v| v as f32).collect(),
        }
    }

    #[inline]
    pub fn rows(&self) -> usize {
        self.rows
    }

    #[inline]
    pub fn ncols(&self) -> usize {
        self.ncols
    }

    /// Column `j` as a contiguous slice.
    #[inline]
    pub fn col(&self, j: usize) -> &[f32] {
        &self.data[j * self.rows..(j + 1) * self.rows]
    }

    /// Mutable column `j`.
    #[inline]
    pub fn col_mut(&mut self, j: usize) -> &mut [f32] {
        &mut self.data[j * self.rows..(j + 1) * self.rows]
    }

    #[inline]
    pub fn data(&self) -> &[f32] {
        &self.data
    }

    #[inline]
    pub fn data_mut(&mut self) -> &mut [f32] {
        &mut self.data
    }
}

/// Dense row-major f32 matrix — the packed-storage twin of `Mat`,
/// used as a one-time demoted shadow of solver operands.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct MatF32 {
    rows: usize,
    cols: usize,
    data: Vec<f32>,
}

impl MatF32 {
    /// Zero matrix of shape `rows × cols`.
    pub fn zeros(rows: usize, cols: usize) -> Self {
        MatF32 { rows, cols, data: vec![0.0; rows * cols] }
    }

    /// One-time demotion of an f64 matrix (round-to-nearest per entry).
    pub fn from_mat(m: &super::Mat) -> Self {
        MatF32 {
            rows: m.rows(),
            cols: m.cols(),
            data: m.data().iter().map(|&v| v as f32).collect(),
        }
    }

    /// Wrap an existing row-major buffer.
    pub fn from_vec(rows: usize, cols: usize, data: Vec<f32>) -> Self {
        assert_eq!(data.len(), rows * cols, "buffer/shape mismatch");
        MatF32 { rows, cols, data }
    }

    #[inline]
    pub fn rows(&self) -> usize {
        self.rows
    }

    #[inline]
    pub fn cols(&self) -> usize {
        self.cols
    }

    #[inline]
    pub fn data(&self) -> &[f32] {
        &self.data
    }

    #[inline]
    pub fn data_mut(&mut self) -> &mut [f32] {
        &mut self.data
    }

    /// Storage footprint in bytes (the shadow-cache accounting unit).
    #[inline]
    pub fn bytes(&self) -> usize {
        self.data.len() * std::mem::size_of::<f32>()
    }

    /// Immutable view of row `r`.
    #[inline]
    pub fn row(&self, r: usize) -> &[f32] {
        &self.data[r * self.cols..(r + 1) * self.cols]
    }

    /// `y ← A·x` with f32 row dots, widened to f64 at the write. Bands
    /// the output rows exactly like `Mat::matvec_into` (each `y[r]` is
    /// one fixed-order row dot, so the result never depends on the
    /// banding or the kernel choice).
    pub fn matvec_into(&self, x: &[f32], y: &mut [f64]) {
        assert_eq!(x.len(), self.cols);
        assert_eq!(y.len(), self.rows);
        let nt = parallel::effective_threads();
        if self.rows * self.cols < gemm::KernelCtx::current().blocking_f32().gemv_threshold
            || nt == 1
        {
            for (r, yr) in y.iter_mut().enumerate() {
                *yr = vecops_f32::dot(self.row(r), x) as f64;
            }
            return;
        }
        let band = self.rows.div_ceil(nt);
        let chunks: Vec<&mut [f64]> = y.chunks_mut(band).collect();
        parallel::parallel_items(nt, chunks, |tid, ych| {
            let lo = tid * band;
            for (i, yr) in ych.iter_mut().enumerate() {
                *yr = vecops_f32::dot(self.row(lo + i), x) as f64;
            }
        });
    }

    /// `y ← Aᵀ·x` with f32 chunk partials, widened to f64 at the
    /// chunk-order merge. Uses the same fixed [`TCHUNK`] grid as
    /// `Mat::matvec_t_into`; the serial path runs the identical
    /// one-chunk reduction, so bits match at any thread count.
    pub fn matvec_t_into(&self, x: &[f32], y: &mut [f64]) {
        assert_eq!(x.len(), self.rows);
        assert_eq!(y.len(), self.cols);
        y.fill(0.0);
        if self.rows == 0 || self.cols == 0 {
            return;
        }
        let nchunks = self.rows.div_ceil(TCHUNK);
        let nt = parallel::effective_threads();
        let mut partials = vec![0.0f32; nchunks * self.cols];
        {
            let chunks: Vec<&mut [f32]> = partials.chunks_mut(self.cols).collect();
            parallel::parallel_items(nt, chunks, |ci, acc| {
                let lo = ci * TCHUNK;
                let hi = (lo + TCHUNK).min(self.rows);
                for r in lo..hi {
                    vecops_f32::axpy(x[r], self.row(r), acc);
                }
            });
        }
        for p in partials.chunks(self.cols) {
            for (yc, &pc) in y.iter_mut().zip(p.iter()) {
                *yc += pc as f64;
            }
        }
    }

    /// `Y ← A·X` for an f32 panel (all-f32 compute and output — the
    /// bench-facing bandwidth shape). Column `j` is bit-identical to an
    /// f32 row-dot pass at any thread count, mirroring
    /// `Mat::matvec_multi_into`.
    pub fn matvec_multi_into(&self, xs: &MultiVecF32, ys: &mut MultiVecF32) {
        assert_eq!(xs.rows(), self.cols, "panel rows must match A cols");
        assert_eq!(ys.rows(), self.rows, "output rows must match A rows");
        assert_eq!(xs.ncols(), ys.ncols(), "panel widths must match");
        let r = xs.ncols();
        if r == 0 || self.rows == 0 {
            return;
        }
        let nt = parallel::effective_threads();
        if self.rows * self.cols < gemm::KernelCtx::current().blocking_f32().gemv_threshold
            || nt == 1
        {
            for row in 0..self.rows {
                let a = self.row(row);
                for j in 0..r {
                    ys.col_mut(j)[row] = vecops_f32::dot(a, xs.col(j));
                }
            }
            return;
        }
        let band = self.rows.div_ceil(nt);
        let nbands = self.rows.div_ceil(band);
        let mut items: Vec<Vec<&mut [f32]>> =
            (0..nbands).map(|_| Vec::with_capacity(r)).collect();
        let rows = self.rows;
        for col in ys.data_mut().chunks_mut(rows) {
            for (b, piece) in col.chunks_mut(band).enumerate() {
                items[b].push(piece);
            }
        }
        parallel::parallel_items(nt, items, |b, mut cols| {
            let lo = b * band;
            let len = cols[0].len();
            for i in 0..len {
                let a = self.row(lo + i);
                for (j, piece) in cols.iter_mut().enumerate() {
                    piece[i] = vecops_f32::dot(a, xs.col(j));
                }
            }
        });
    }
}

/// One-time f32 shadow of a [`Design`]: a demoted dense matrix, or
/// demoted CSR values riding the *parent's* index structure (no
/// structural copy — the sparse products take both the shadow and the
/// parent design, so the shadow never densifies or self-references).
#[derive(Clone, Debug)]
pub enum DesignShadowF32 {
    /// Demoted dense design.
    Dense(MatF32),
    /// Demoted CSR values, positionally aligned with the parent
    /// `Design::Sparse` CSR value array.
    Sparse {
        /// `vals[k] = parent.csr.values[k] as f32`.
        vals: Vec<f32>,
    },
}

impl DesignShadowF32 {
    /// Demote a design once (the prep-time shadow build).
    pub fn of(design: &Design) -> Self {
        match design {
            Design::Dense(m) => DesignShadowF32::Dense(MatF32::from_mat(m)),
            Design::Sparse { csr, .. } => {
                DesignShadowF32::Sparse { vals: csr.values_f32() }
            }
        }
    }

    /// Shadow storage footprint in bytes (metrics accounting).
    pub fn bytes(&self) -> usize {
        match self {
            DesignShadowF32::Dense(m) => m.bytes(),
            DesignShadowF32::Sparse { vals } => vals.len() * std::mem::size_of::<f32>(),
        }
    }

    /// `y ← X·x` through the f32 shadow (`design` must be the parent
    /// the shadow was built from — it carries the sparse structure).
    pub fn matvec_into(&self, design: &Design, x: &[f32], y: &mut [f64]) {
        match (self, design) {
            (DesignShadowF32::Dense(m), _) => m.matvec_into(x, y),
            (DesignShadowF32::Sparse { vals }, Design::Sparse { csr, .. }) => {
                csr.matvec_f32_into(vals, x, y)
            }
            (DesignShadowF32::Sparse { .. }, Design::Dense(_)) => {
                panic!("sparse shadow applied to a dense design")
            }
        }
    }

    /// `y ← Xᵀ·x` through the f32 shadow.
    pub fn matvec_t_into(&self, design: &Design, x: &[f32], y: &mut [f64]) {
        match (self, design) {
            (DesignShadowF32::Dense(m), _) => m.matvec_t_into(x, y),
            (DesignShadowF32::Sparse { vals }, Design::Sparse { csr, .. }) => {
                csr.matvec_t_f32_into(vals, x, y)
            }
            (DesignShadowF32::Sparse { .. }, Design::Dense(_)) => {
                panic!("sparse shadow applied to a dense design")
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::Mat;
    use crate::rng::Rng;
    use crate::util::parallel::{with_parallelism, Parallelism};

    fn randmat(rng: &mut Rng, r: usize, c: usize) -> Mat {
        Mat::from_fn(r, c, |_, _| rng.normal())
    }

    #[test]
    fn f32_matvec_close_to_f64() {
        let mut rng = Rng::seed_from(11);
        let a = randmat(&mut rng, 57, 33);
        let a32 = MatF32::from_mat(&a);
        let x: Vec<f64> = (0..33).map(|_| rng.normal()).collect();
        let x32: Vec<f32> = x.iter().map(|&v| v as f32).collect();
        let y64 = a.matvec(&x);
        let mut y = vec![0.0; 57];
        a32.matvec_into(&x32, &mut y);
        for (a, b) in y.iter().zip(&y64) {
            assert!((a - b).abs() <= 1e-4 * (1.0 + b.abs()), "{a} vs {b}");
        }
    }

    #[test]
    fn f32_matvec_t_bit_stable_across_threads() {
        let mut rng = Rng::seed_from(12);
        // Tall enough for several TCHUNK chunks.
        let a = randmat(&mut rng, 1100, 19);
        let a32 = MatF32::from_mat(&a);
        let x32: Vec<f32> = (0..1100).map(|_| rng.normal() as f32).collect();
        let run = |par: Parallelism| {
            with_parallelism(par, || {
                let mut y = vec![0.0; 19];
                a32.matvec_t_into(&x32, &mut y);
                y
            })
        };
        let serial = run(Parallelism::None);
        for nt in [2usize, 5, 8] {
            let par = run(Parallelism::Fixed(nt));
            for (s, p) in serial.iter().zip(&par) {
                assert_eq!(s.to_bits(), p.to_bits(), "nt={nt}");
            }
        }
    }

    #[test]
    fn f32_multi_matches_single_rhs_bits() {
        let mut rng = Rng::seed_from(13);
        let a = randmat(&mut rng, 64, 40);
        let a32 = MatF32::from_mat(&a);
        let mut xs = MultiVecF32::zeros(40, 3);
        for j in 0..3 {
            for v in xs.col_mut(j) {
                *v = rng.normal() as f32;
            }
        }
        let mut ys = MultiVecF32::zeros(64, 3);
        a32.matvec_multi_into(&xs, &mut ys);
        for j in 0..3 {
            let mut solo = vec![0.0f64; 64];
            a32.matvec_into(xs.col(j), &mut solo);
            for (m, s) in ys.col(j).iter().zip(&solo) {
                assert_eq!((*m as f64).to_bits(), s.to_bits(), "col {j}");
            }
        }
    }

    #[test]
    fn shadow_roundtrip_dense_and_sparse() {
        let mut rng = Rng::seed_from(14);
        let m = Mat::from_fn(30, 12, |r, c| {
            if (r + c) % 5 == 0 {
                rng.normal()
            } else {
                0.0
            }
        });
        let dense: Design = m.clone().into();
        let sparse: Design = crate::linalg::Csr::from_dense(&m, 0.0).into();
        let v: Vec<f64> = (0..12).map(|_| rng.normal()).collect();
        let mut v32 = Vec::new();
        vecops_f32::demote(&v, &mut v32);
        let u: Vec<f64> = (0..30).map(|_| rng.normal()).collect();
        let mut u32 = Vec::new();
        vecops_f32::demote(&u, &mut u32);

        for d in [&dense, &sparse] {
            let sh = DesignShadowF32::of(d);
            assert!(sh.bytes() > 0);
            let mut y = vec![0.0; 30];
            sh.matvec_into(d, &v32, &mut y);
            let y64 = d.matvec(&v);
            for (a, b) in y.iter().zip(&y64) {
                assert!((a - b).abs() <= 1e-4 * (1.0 + b.abs()));
            }
            let mut z = vec![0.0; 12];
            sh.matvec_t_into(d, &u32, &mut z);
            let z64 = d.matvec_t(&u);
            for (a, b) in z.iter().zip(&z64) {
                assert!((a - b).abs() <= 1e-4 * (1.0 + b.abs()));
            }
        }
    }

    #[test]
    fn dense_and_sparse_shadows_agree() {
        // The same underlying data through both storage kinds: results
        // won't be bit-identical (different reduction orders) but must
        // agree to f32 accuracy.
        let mut rng = Rng::seed_from(15);
        let m = Mat::from_fn(25, 10, |_, _| rng.normal());
        let dense: Design = m.clone().into();
        let sparse: Design = crate::linalg::Csr::from_dense(&m, 0.0).into();
        let v32: Vec<f32> = (0..10).map(|_| rng.normal() as f32).collect();
        let (shd, shs) = (DesignShadowF32::of(&dense), DesignShadowF32::of(&sparse));
        let mut yd = vec![0.0; 25];
        let mut ys = vec![0.0; 25];
        shd.matvec_into(&dense, &v32, &mut yd);
        shs.matvec_into(&sparse, &v32, &mut ys);
        for (a, b) in yd.iter().zip(&ys) {
            assert!((a - b).abs() <= 1e-4 * (1.0 + b.abs()));
        }
    }
}
