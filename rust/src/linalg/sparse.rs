//! CSR/CSC sparse matrices with threaded kernels.
//!
//! Two of the paper's data sets (Dorothea, E2006-tfidf) are extremely
//! sparse; the synthetic profiles mirror that, and the coordinate-descent
//! baselines exploit sparsity through per-column access. CSR supports the
//! row-major products; column access goes through a CSC mirror (see
//! [`super::design::Design`], which carries both).
//!
//! Every kernel here parallelizes over the scoped pool in
//! [`crate::util::parallel`] under the same determinism contract as the
//! dense layer: the decomposition into work items is derived from the
//! matrix shape, never from the worker count, and reductions run over
//! fixed-size row chunks merged in chunk order — so results are
//! bit-identical across `Parallelism` settings (pinned by the proptests
//! in `rust/src/testing/prop.rs`).

use super::dense::Mat;
use super::multivec::MultiVec;
use crate::util::parallel;

/// Below this stored-entry count the kernels stay inline on the caller:
/// the work is too small to amortize a scoped fan-out. Compared against
/// `nnz`, never against the thread count, so the serial/threaded split is
/// itself deterministic.
const PAR_NNZ: usize = 1 << 14;

/// Minimum row-chunk length for the `Aᵀx` / column-norm partial-sum
/// reductions (same scheme as the dense `Mat::matvec_t_into`).
const TCHUNK: usize = 512;

/// Cap on the number of reduction chunks: each chunk owns a dense
/// length-`cols` partial, so an uncapped `rows / TCHUNK` grid would make
/// the partial buffers (and the chunk-order merge) scale with the dense
/// shape instead of nnz on very tall, very sparse inputs.
const MAX_TCHUNKS: usize = 64;

/// Chunk count for an (rows × cols, nnz) reduction, bounded three ways —
/// all derived from the matrix, never from the thread count, so the
/// reduction tree (and therefore the result bits) is identical in serial
/// and parallel runs:
///
/// - ≤ `rows / TCHUNK`: each chunk covers at least [`TCHUNK`] rows;
/// - ≤ [`MAX_TCHUNKS`];
/// - ≤ `nnz / (4·cols)`: the dense partials (`nchunks·cols` f64) and
///   their chunk-order merge stay a fraction of the O(nnz) scatter, so
///   wide ultra-sparse inputs (the E2006-tfidf regime) never pay memory
///   or merge work proportional to the dense shape. When this bound
///   forces one chunk the caller's serial path runs instead.
#[inline]
fn reduction_chunks(rows: usize, cols: usize, nnz: usize) -> usize {
    let by_rows = rows.div_ceil(TCHUNK);
    let by_fill = nnz / (4 * cols.max(1));
    by_rows.min(MAX_TCHUNKS).min(by_fill).max(1)
}

/// Compressed sparse row matrix.
#[derive(Clone, Debug, PartialEq)]
pub struct Csr {
    rows: usize,
    cols: usize,
    indptr: Vec<usize>,
    indices: Vec<usize>,
    values: Vec<f64>,
}

impl Csr {
    /// Build from (row, col, value) triplets; duplicates are summed.
    ///
    /// Entries are sorted by (row, col), then each run of equal
    /// coordinates is merged into one stored value (a straight grouped
    /// merge; explicit zeros — including duplicate runs summing to zero —
    /// are kept).
    pub fn from_triplets(rows: usize, cols: usize, mut trip: Vec<(usize, usize, f64)>) -> Self {
        trip.sort_unstable_by_key(|&(r, c, _)| (r, c));
        let mut indptr = vec![0usize; rows + 1];
        let mut indices = Vec::with_capacity(trip.len());
        let mut values: Vec<f64> = Vec::with_capacity(trip.len());
        let mut i = 0;
        while i < trip.len() {
            let (r, c, _) = trip[i];
            assert!(r < rows && c < cols, "triplet ({r}, {c}) out of bounds");
            let mut v = 0.0;
            while i < trip.len() && trip[i].0 == r && trip[i].1 == c {
                v += trip[i].2;
                i += 1;
            }
            indices.push(c);
            values.push(v);
            indptr[r + 1] = indices.len();
        }
        // prefix-fill: rows with no entries inherit the previous offset
        for r in 1..=rows {
            indptr[r] = indptr[r].max(indptr[r - 1]);
        }
        Csr { rows, cols, indptr, indices, values }
    }

    /// Densify (small matrices / tests).
    pub fn to_dense(&self) -> Mat {
        let mut m = Mat::zeros(self.rows, self.cols);
        for r in 0..self.rows {
            for k in self.indptr[r]..self.indptr[r + 1] {
                m.set(r, self.indices[k], self.values[k]);
            }
        }
        m
    }

    /// Build from a dense matrix, dropping entries with |v| <= tol.
    pub fn from_dense(m: &Mat, tol: f64) -> Self {
        let mut trip = Vec::new();
        for r in 0..m.rows() {
            for c in 0..m.cols() {
                let v = m.get(r, c);
                if v.abs() > tol {
                    trip.push((r, c, v));
                }
            }
        }
        Self::from_triplets(m.rows(), m.cols(), trip)
    }

    #[inline]
    pub fn rows(&self) -> usize {
        self.rows
    }

    #[inline]
    pub fn cols(&self) -> usize {
        self.cols
    }

    /// Number of stored entries.
    #[inline]
    pub fn nnz(&self) -> usize {
        self.values.len()
    }

    /// Fill fraction.
    pub fn density(&self) -> f64 {
        self.nnz() as f64 / (self.rows * self.cols).max(1) as f64
    }

    /// Row iterator: (col, value) pairs of row r.
    #[inline]
    pub fn row_iter(&self, r: usize) -> impl Iterator<Item = (usize, f64)> + '_ {
        let lo = self.indptr[r];
        let hi = self.indptr[r + 1];
        self.indices[lo..hi].iter().copied().zip(self.values[lo..hi].iter().copied())
    }

    /// `y ← A·x` (allocates the output).
    pub fn matvec(&self, x: &[f64]) -> Vec<f64> {
        let mut y = vec![0.0; self.rows];
        self.matvec_into(x, &mut y);
        y
    }

    /// `y ← A·x` into a caller-provided buffer. Output rows are banded
    /// over the scoped pool; each `y[r]` is one sparse row dot, so the
    /// result does not depend on the banding.
    pub fn matvec_into(&self, x: &[f64], y: &mut [f64]) {
        assert_eq!(x.len(), self.cols);
        assert_eq!(y.len(), self.rows);
        let nt = parallel::effective_threads();
        if self.nnz() < PAR_NNZ || nt <= 1 || self.rows <= 1 {
            for (r, yr) in y.iter_mut().enumerate() {
                let mut s = 0.0;
                for (c, v) in self.row_iter(r) {
                    s += v * x[c];
                }
                *yr = s;
            }
            return;
        }
        let band = self.rows.div_ceil(nt);
        let chunks: Vec<&mut [f64]> = y.chunks_mut(band).collect();
        parallel::parallel_items(nt, chunks, |tid, ych| {
            let lo = tid * band;
            for (i, yr) in ych.iter_mut().enumerate() {
                let mut s = 0.0;
                for (c, v) in self.row_iter(lo + i) {
                    s += v * x[c];
                }
                *yr = s;
            }
        });
    }

    /// `y ← Aᵀ·x` (allocates the output).
    pub fn matvec_t(&self, x: &[f64]) -> Vec<f64> {
        let mut y = vec![0.0; self.cols];
        self.matvec_t_into(x, &mut y);
        y
    }

    /// `y ← Aᵀ·x` into a caller-provided buffer.
    ///
    /// Rows are reduced in shape-derived chunks (see
    /// [`reduction_chunks`]): each chunk scatters into a private
    /// length-`cols` partial (parallel across chunks), then the partials
    /// are summed in chunk order — identical bits at any worker count,
    /// with partial memory and merge work bounded by a fraction of nnz.
    pub fn matvec_t_into(&self, x: &[f64], y: &mut [f64]) {
        assert_eq!(x.len(), self.rows);
        assert_eq!(y.len(), self.cols);
        y.fill(0.0);
        if self.rows == 0 || self.cols == 0 {
            return;
        }
        let tchunk = self.rows.div_ceil(reduction_chunks(self.rows, self.cols, self.nnz()));
        let nchunks = self.rows.div_ceil(tchunk);
        if nchunks == 1 || self.nnz() < PAR_NNZ {
            for r in 0..self.rows {
                let xr = x[r];
                if xr == 0.0 {
                    continue;
                }
                for (c, v) in self.row_iter(r) {
                    y[c] += v * xr;
                }
            }
            return;
        }
        let nt = parallel::effective_threads();
        let mut partials = vec![0.0; nchunks * self.cols];
        {
            let chunks: Vec<&mut [f64]> = partials.chunks_mut(self.cols).collect();
            parallel::parallel_items(nt, chunks, |ci, acc| {
                let lo = ci * tchunk;
                let hi = (lo + tchunk).min(self.rows);
                for r in lo..hi {
                    let xr = x[r];
                    if xr == 0.0 {
                        continue;
                    }
                    for (c, v) in self.row_iter(r) {
                        acc[c] += v * xr;
                    }
                }
            });
        }
        for p in partials.chunks(self.cols) {
            super::vecops::axpy(1.0, p, y);
        }
    }

    /// Demoted copy of the value array, positionally aligned with the
    /// CSR structure — the sparse half of a
    /// [`DesignShadowF32`](super::lowp::DesignShadowF32) (the indices
    /// are shared with the parent, so the shadow costs nnz·4 bytes).
    pub fn values_f32(&self) -> Vec<f32> {
        self.values.iter().map(|&v| v as f32).collect()
    }

    /// Scale every stored value by a per-column factor:
    /// `A[:, j] *= factor[j]`.
    ///
    /// The structure (`indptr`/`indices`) is untouched — a zero factor
    /// leaves the entry stored with value `0.0` rather than dropping it,
    /// so `nnz` is invariant. This is the fill-in-free half of sparse
    /// standardization (`crate::data::standardize::standardize_design`):
    /// centering is *tracked* by the caller, scaling is applied here.
    pub fn scale_cols(&mut self, factor: &[f64]) {
        assert_eq!(factor.len(), self.cols, "one factor per column");
        for (c, v) in self.indices.iter().zip(self.values.iter_mut()) {
            *v *= factor[*c];
        }
    }

    /// `y ← A·x` with f32 arithmetic over a demoted value array
    /// (`vals32 = self.values_f32()`), widened to f64 at the write.
    /// Same banding and gates as [`Csr::matvec_into`]; each output is
    /// one fixed-order sparse row dot, so results are bit-stable across
    /// thread counts.
    pub fn matvec_f32_into(&self, vals32: &[f32], x: &[f32], y: &mut [f64]) {
        assert_eq!(vals32.len(), self.nnz(), "shadow/value length mismatch");
        assert_eq!(x.len(), self.cols);
        assert_eq!(y.len(), self.rows);
        let row_dot = |r: usize| -> f32 {
            let lo = self.indptr[r];
            let hi = self.indptr[r + 1];
            let mut s = 0.0f32;
            for (c, v) in self.indices[lo..hi].iter().zip(&vals32[lo..hi]) {
                s += v * x[*c];
            }
            s
        };
        let nt = parallel::effective_threads();
        if self.nnz() < PAR_NNZ || nt <= 1 || self.rows <= 1 {
            for (r, yr) in y.iter_mut().enumerate() {
                *yr = row_dot(r) as f64;
            }
            return;
        }
        let band = self.rows.div_ceil(nt);
        let chunks: Vec<&mut [f64]> = y.chunks_mut(band).collect();
        parallel::parallel_items(nt, chunks, |tid, ych| {
            let lo = tid * band;
            for (i, yr) in ych.iter_mut().enumerate() {
                *yr = row_dot(lo + i) as f64;
            }
        });
    }

    /// `y ← Aᵀ·x` with f32 scatter arithmetic over a demoted value
    /// array, widened to f64 at the chunk-order merge. Same
    /// shape-derived chunk grid as [`Csr::matvec_t_into`] (the serial
    /// gate runs the identical one-chunk f32 reduction), so bits never
    /// depend on the worker count.
    pub fn matvec_t_f32_into(&self, vals32: &[f32], x: &[f32], y: &mut [f64]) {
        assert_eq!(vals32.len(), self.nnz(), "shadow/value length mismatch");
        assert_eq!(x.len(), self.rows);
        assert_eq!(y.len(), self.cols);
        y.fill(0.0);
        if self.rows == 0 || self.cols == 0 {
            return;
        }
        let scatter = |lo: usize, hi: usize, acc: &mut [f32]| {
            for r in lo..hi {
                let xr = x[r];
                if xr == 0.0 {
                    continue;
                }
                let (plo, phi) = (self.indptr[r], self.indptr[r + 1]);
                for (c, v) in self.indices[plo..phi].iter().zip(&vals32[plo..phi]) {
                    acc[*c] += v * xr;
                }
            }
        };
        let tchunk = self.rows.div_ceil(reduction_chunks(self.rows, self.cols, self.nnz()));
        let nchunks = self.rows.div_ceil(tchunk);
        if nchunks == 1 || self.nnz() < PAR_NNZ {
            let mut acc = vec![0.0f32; self.cols];
            scatter(0, self.rows, &mut acc);
            for (yc, &pc) in y.iter_mut().zip(&acc) {
                *yc = pc as f64;
            }
            return;
        }
        let nt = parallel::effective_threads();
        let mut partials = vec![0.0f32; nchunks * self.cols];
        {
            let chunks: Vec<&mut [f32]> = partials.chunks_mut(self.cols).collect();
            parallel::parallel_items(nt, chunks, |ci, acc| {
                let lo = ci * tchunk;
                let hi = (lo + tchunk).min(self.rows);
                scatter(lo, hi, acc);
            });
        }
        for p in partials.chunks(self.cols) {
            for (yc, &pc) in y.iter_mut().zip(p.iter()) {
                *yc += pc as f64;
            }
        }
    }

    /// Squared L2 norm of each column (CD Lipschitz constants), reduced
    /// over the same shape-derived chunk scheme as [`Csr::matvec_t_into`].
    pub fn col_norms_sq(&self) -> Vec<f64> {
        let mut n = vec![0.0; self.cols];
        if self.rows == 0 || self.cols == 0 {
            return n;
        }
        let tchunk = self.rows.div_ceil(reduction_chunks(self.rows, self.cols, self.nnz()));
        let nchunks = self.rows.div_ceil(tchunk);
        if nchunks == 1 || self.nnz() < PAR_NNZ {
            for r in 0..self.rows {
                for (c, v) in self.row_iter(r) {
                    n[c] += v * v;
                }
            }
            return n;
        }
        let nt = parallel::effective_threads();
        let mut partials = vec![0.0; nchunks * self.cols];
        {
            let chunks: Vec<&mut [f64]> = partials.chunks_mut(self.cols).collect();
            parallel::parallel_items(nt, chunks, |ci, acc| {
                let lo = ci * tchunk;
                let hi = (lo + tchunk).min(self.rows);
                for r in lo..hi {
                    for (c, v) in self.row_iter(r) {
                        acc[c] += v * v;
                    }
                }
            });
        }
        for p in partials.chunks(self.cols) {
            super::vecops::axpy(1.0, p, &mut n);
        }
        n
    }

    /// `Y ← A·X` for a panel of right-hand sides (`X` is `cols × r`,
    /// `Y` is `rows × r`): the sparse twin of
    /// [`Mat::matvec_multi_into`](super::Mat::matvec_multi_into). Column
    /// `j` of `Y` is bit-identical to `matvec_into(X.col(j), ..)` — each
    /// output element is the same sequential sparse row dot — and the
    /// fused pass touches each row's nnz once per panel while the index
    /// array stays hot across columns.
    pub fn matvec_multi_into(&self, xs: &MultiVec, ys: &mut MultiVec) {
        assert_eq!(xs.rows(), self.cols, "panel rows must match A cols");
        assert_eq!(ys.rows(), self.rows, "output rows must match A rows");
        assert_eq!(xs.ncols(), ys.ncols(), "panel widths must match");
        let r = xs.ncols();
        if r == 0 || self.rows == 0 {
            return;
        }
        let nt = parallel::effective_threads();
        if self.nnz() < PAR_NNZ || nt <= 1 || self.rows <= 1 {
            for row in 0..self.rows {
                for j in 0..r {
                    let x = xs.col(j);
                    let mut s = 0.0;
                    for (c, v) in self.row_iter(row) {
                        s += v * x[c];
                    }
                    ys.col_mut(j)[row] = s;
                }
            }
            return;
        }
        let band = self.rows.div_ceil(nt);
        let nbands = self.rows.div_ceil(band);
        let mut items: Vec<Vec<&mut [f64]>> =
            (0..nbands).map(|_| Vec::with_capacity(r)).collect();
        let rows = self.rows;
        for col in ys.data_mut().chunks_mut(rows) {
            for (b, piece) in col.chunks_mut(band).enumerate() {
                items[b].push(piece);
            }
        }
        parallel::parallel_items(nt, items, |b, mut cols| {
            let lo = b * band;
            let len = cols[0].len();
            for i in 0..len {
                for (j, piece) in cols.iter_mut().enumerate() {
                    let x = xs.col(j);
                    let mut s = 0.0;
                    for (c, v) in self.row_iter(lo + i) {
                        s += v * x[c];
                    }
                    piece[i] = s;
                }
            }
        });
    }

    /// `Y ← Aᵀ·U` for a panel (`U` is `rows × r`, `Y` is `cols × r`),
    /// over the same shape-derived chunk grid as [`Csr::matvec_t_into`]
    /// and with the same per-column zero-skip, so column `j` of `Y` is
    /// bit-identical to `matvec_t_into(U.col(j), ..)` at any thread
    /// count.
    pub fn matvec_t_multi_into(&self, us: &MultiVec, ys: &mut MultiVec) {
        assert_eq!(us.rows(), self.rows, "panel rows must match A rows");
        assert_eq!(ys.rows(), self.cols, "output rows must match A cols");
        assert_eq!(us.ncols(), ys.ncols(), "panel widths must match");
        let r = us.ncols();
        ys.data_mut().fill(0.0);
        if self.rows == 0 || self.cols == 0 || r == 0 {
            return;
        }
        let tchunk = self.rows.div_ceil(reduction_chunks(self.rows, self.cols, self.nnz()));
        let nchunks = self.rows.div_ceil(tchunk);
        if nchunks == 1 || self.nnz() < PAR_NNZ {
            for row in 0..self.rows {
                for j in 0..r {
                    let xr = us.col(j)[row];
                    if xr == 0.0 {
                        continue;
                    }
                    let y = ys.col_mut(j);
                    for (c, v) in self.row_iter(row) {
                        y[c] += v * xr;
                    }
                }
            }
            return;
        }
        let nt = parallel::effective_threads();
        let width = self.cols * r;
        let mut partials = vec![0.0; nchunks * width];
        {
            let chunks: Vec<&mut [f64]> = partials.chunks_mut(width).collect();
            parallel::parallel_items(nt, chunks, |ci, acc| {
                let lo = ci * tchunk;
                let hi = (lo + tchunk).min(self.rows);
                for row in lo..hi {
                    for j in 0..r {
                        let xr = us.col(j)[row];
                        if xr == 0.0 {
                            continue;
                        }
                        let acc_j = &mut acc[j * self.cols..(j + 1) * self.cols];
                        for (c, v) in self.row_iter(row) {
                            acc_j[c] += v * xr;
                        }
                    }
                }
            });
        }
        for p in partials.chunks(width) {
            for j in 0..r {
                super::vecops::axpy(1.0, &p[j * self.cols..(j + 1) * self.cols], ys.col_mut(j));
            }
        }
    }

    /// An empty 0 × 0 matrix — the initial value for reusable gather
    /// targets.
    pub fn empty() -> Csr {
        Csr { rows: 0, cols: 0, indptr: vec![0], indices: Vec::new(), values: Vec::new() }
    }

    /// Gather the rows `idx` into `out`, reusing its buffers —
    /// `out.row(s) = self.row(idx[s])` (O(Σ nnz(row)) per rebuild). The
    /// compact-panel primitive of the active-set primal Newton on sparse
    /// designs.
    pub fn gather_rows_into(&self, idx: &[usize], out: &mut Csr) {
        out.rows = idx.len();
        out.cols = self.cols;
        out.indptr.clear();
        out.indptr.push(0);
        out.indices.clear();
        out.values.clear();
        for &r in idx {
            let lo = self.indptr[r];
            let hi = self.indptr[r + 1];
            out.indices.extend_from_slice(&self.indices[lo..hi]);
            out.values.extend_from_slice(&self.values[lo..hi]);
            out.indptr.push(out.indices.len());
        }
    }

    /// `G ← AᵀA` (cols × cols, dense) — the t-independent block of the
    /// SVEN dual gram `K(t)`. Output row `j` joins column `j`'s CSC
    /// entries with the CSR rows they touch, so the cost is
    /// `Σ_r nnz(row r)²` instead of the dense `O(n·p²)`. Each output row
    /// is owned by exactly one worker and accumulated in a fixed
    /// (row-ascending, then column-ascending) order — bit-identical
    /// across thread counts.
    pub fn gram_into(&self, csc: &Csc, out: &mut Mat) {
        assert_eq!(csc.rows(), self.rows, "CSC mirror shape mismatch");
        assert_eq!(csc.cols(), self.cols, "CSC mirror shape mismatch");
        assert_eq!((out.rows(), out.cols()), (self.cols, self.cols), "gram output shape");
        let p = self.cols;
        out.data_mut().fill(0.0);
        if p == 0 || self.nnz() == 0 {
            return;
        }
        let nt = if self.nnz() < PAR_NNZ { 1 } else { parallel::effective_threads() };
        let rows: Vec<&mut [f64]> = out.data_mut().chunks_mut(p).collect();
        parallel::parallel_items(nt, rows, |j, row| {
            for (r, vjr) in csc.col_iter(j) {
                for (c, vrc) in self.row_iter(r) {
                    row[c] += vjr * vrc;
                }
            }
        });
    }

    /// `G ← AAᵀ` (rows × rows, dense): the mirror of [`Csr::gram_into`]
    /// with rows and columns swapped (used by the ridge pre-check on the
    /// n < p side). Same ownership/determinism contract.
    pub fn gram_rows_into(&self, csc: &Csc, out: &mut Mat) {
        assert_eq!(csc.rows(), self.rows, "CSC mirror shape mismatch");
        assert_eq!(csc.cols(), self.cols, "CSC mirror shape mismatch");
        assert_eq!((out.rows(), out.cols()), (self.rows, self.rows), "gram output shape");
        let n = self.rows;
        out.data_mut().fill(0.0);
        if n == 0 || self.nnz() == 0 {
            return;
        }
        let nt = if self.nnz() < PAR_NNZ { 1 } else { parallel::effective_threads() };
        let rows: Vec<&mut [f64]> = out.data_mut().chunks_mut(n).collect();
        parallel::parallel_items(nt, rows, |i, row| {
            for (c, vic) in self.row_iter(i) {
                for (r2, vr2c) in csc.col_iter(c) {
                    row[r2] += vic * vr2c;
                }
            }
        });
    }
}

/// Compressed sparse column mirror — gives coordinate descent O(nnz(col))
/// access to single columns.
#[derive(Clone, Debug, PartialEq)]
pub struct Csc {
    rows: usize,
    cols: usize,
    colptr: Vec<usize>,
    indices: Vec<usize>,
    values: Vec<f64>,
}

impl Csc {
    /// Transpose-scatter a CSR matrix into column-major storage.
    ///
    /// The column layout (entries sorted by row within each column) is an
    /// exact integer placement, so the result is identical however the
    /// scatter is decomposed. Large inputs split the output into
    /// contiguous column bands balanced by entry count; each worker scans
    /// the CSR once and keeps only its band.
    pub fn from_csr(a: &Csr) -> Self {
        let nnz = a.nnz();
        let mut colptr = vec![0usize; a.cols + 1];
        for &c in &a.indices {
            colptr[c + 1] += 1;
        }
        for c in 0..a.cols {
            colptr[c + 1] += colptr[c];
        }
        let mut indices = vec![0usize; nnz];
        let mut values = vec![0.0f64; nnz];
        let nt = if nnz < PAR_NNZ { 1 } else { parallel::effective_threads() };
        let nbands = nt.min(a.cols.max(1));
        if nbands <= 1 {
            let mut cursor = colptr.clone();
            for r in 0..a.rows {
                for (c, v) in a.row_iter(r) {
                    let k = cursor[c];
                    indices[k] = r;
                    values[k] = v;
                    cursor[c] += 1;
                }
            }
            return Csc { rows: a.rows, cols: a.cols, colptr, indices, values };
        }
        // Column-band boundaries at ~nnz/nbands entries per band.
        let target = nnz.div_ceil(nbands);
        let mut bounds = vec![0usize];
        let mut next_goal = target;
        for c in 1..a.cols {
            if colptr[c] >= next_goal && bounds.len() < nbands {
                bounds.push(c);
                next_goal = colptr[c] + target;
            }
        }
        bounds.push(a.cols);
        // Split the output storage at the band boundaries so each worker
        // owns a disjoint contiguous range.
        let mut items = Vec::with_capacity(bounds.len() - 1);
        let mut idx_rest: &mut [usize] = &mut indices;
        let mut val_rest: &mut [f64] = &mut values;
        for w in bounds.windows(2) {
            let (c0, c1) = (w[0], w[1]);
            let len = colptr[c1] - colptr[c0];
            let (ih, it) = idx_rest.split_at_mut(len);
            let (vh, vt) = val_rest.split_at_mut(len);
            idx_rest = it;
            val_rest = vt;
            items.push((c0, c1, ih, vh));
        }
        let colptr_ref = &colptr;
        let nitems = items.len();
        parallel::parallel_items(nitems, items, |_, (c0, c1, idx, val)| {
            // Each worker streams the (cache-friendly) column-index array
            // once and touches values only for entries in its band, so the
            // extra traversal cost of band ownership is one sequential
            // 8-byte read per entry per band — the price of staying free
            // of shared mutable scatter targets.
            let base = colptr_ref[c0];
            let mut cursor: Vec<usize> =
                colptr_ref[c0..c1].iter().map(|&v| v - base).collect();
            for r in 0..a.rows {
                for k in a.indptr[r]..a.indptr[r + 1] {
                    let c = a.indices[k];
                    if c >= c0 && c < c1 {
                        let kk = cursor[c - c0];
                        idx[kk] = r;
                        val[kk] = a.values[k];
                        cursor[c - c0] += 1;
                    }
                }
            }
        });
        Csc { rows: a.rows, cols: a.cols, colptr, indices, values }
    }

    #[inline]
    pub fn rows(&self) -> usize {
        self.rows
    }

    #[inline]
    pub fn cols(&self) -> usize {
        self.cols
    }

    /// Number of stored entries.
    #[inline]
    pub fn nnz(&self) -> usize {
        self.values.len()
    }

    /// Column iterator: (row, value) pairs of column c.
    #[inline]
    pub fn col_iter(&self, c: usize) -> impl Iterator<Item = (usize, f64)> + '_ {
        let lo = self.colptr[c];
        let hi = self.colptr[c + 1];
        self.indices[lo..hi].iter().copied().zip(self.values[lo..hi].iter().copied())
    }

    /// Gather the columns `idx` into the *rows* of a CSR matrix, reusing
    /// its buffers: `out.row(s) = self[:, idx[s]]ᵀ` (so `out` is
    /// `idx.len() × self.rows`). Column entries are stored row-ascending,
    /// which is exactly CSR's sorted-row invariant — the gather is a pure
    /// O(Σ nnz(col)) copy. Used by the SVEN reduction's active-set
    /// gather, whose implicit sample rows are design columns.
    pub fn gather_cols_into(&self, idx: &[usize], out: &mut Csr) {
        out.rows = idx.len();
        out.cols = self.rows;
        out.indptr.clear();
        out.indptr.push(0);
        out.indices.clear();
        out.values.clear();
        for &c in idx {
            let lo = self.colptr[c];
            let hi = self.colptr[c + 1];
            out.indices.extend_from_slice(&self.indices[lo..hi]);
            out.values.extend_from_slice(&self.values[lo..hi]);
            out.indptr.push(out.indices.len());
        }
    }

    /// `⟨A[:,c], x⟩`.
    #[inline]
    pub fn col_dot(&self, c: usize, x: &[f64]) -> f64 {
        self.col_iter(c).map(|(r, v)| v * x[r]).sum()
    }

    /// `x ← x + a·A[:,c]`.
    #[inline]
    pub fn col_axpy(&self, c: usize, a: f64, x: &mut [f64]) {
        for (r, v) in self.col_iter(c) {
            x[r] += a * v;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;
    use crate::util::parallel::{with_parallelism, Parallelism};

    fn random_sparse(rng: &mut Rng, rows: usize, cols: usize, density: f64) -> Csr {
        let mut trip = Vec::new();
        for r in 0..rows {
            for c in 0..cols {
                if rng.bernoulli(density) {
                    trip.push((r, c, rng.normal()));
                }
            }
        }
        Csr::from_triplets(rows, cols, trip)
    }

    #[test]
    fn matvec_matches_dense() {
        let mut rng = Rng::seed_from(41);
        let a = random_sparse(&mut rng, 20, 15, 0.3);
        let d = a.to_dense();
        let x: Vec<f64> = (0..15).map(|_| rng.normal()).collect();
        let ys = a.matvec(&x);
        let yd = d.matvec(&x);
        for i in 0..20 {
            assert!((ys[i] - yd[i]).abs() < 1e-12);
        }
    }

    #[test]
    fn matvec_t_matches_dense() {
        let mut rng = Rng::seed_from(42);
        let a = random_sparse(&mut rng, 18, 25, 0.2);
        let d = a.to_dense();
        let x: Vec<f64> = (0..18).map(|_| rng.normal()).collect();
        let ys = a.matvec_t(&x);
        let yd = d.matvec_t(&x);
        for i in 0..25 {
            assert!((ys[i] - yd[i]).abs() < 1e-12);
        }
    }

    #[test]
    fn triplet_duplicates_sum() {
        let a = Csr::from_triplets(2, 2, vec![(0, 0, 1.0), (0, 0, 2.0), (1, 1, 5.0)]);
        let d = a.to_dense();
        assert_eq!(d.get(0, 0), 3.0);
        assert_eq!(d.get(1, 1), 5.0);
        assert_eq!(d.get(0, 1), 0.0);
        assert_eq!(a.nnz(), 2);
    }

    #[test]
    fn unsorted_duplicate_triplets_merge() {
        // Duplicates split across the input order (and out of row order)
        // must still merge into one entry per coordinate.
        let a = Csr::from_triplets(
            3,
            3,
            vec![(2, 1, 4.0), (0, 2, 1.0), (2, 1, -1.0), (0, 2, 0.5), (2, 1, 2.0)],
        );
        assert_eq!(a.nnz(), 2);
        let d = a.to_dense();
        assert_eq!(d.get(2, 1), 5.0);
        assert_eq!(d.get(0, 2), 1.5);
    }

    #[test]
    fn empty_rows_handled() {
        let a = Csr::from_triplets(4, 3, vec![(0, 1, 2.0), (3, 2, -1.0)]);
        let y = a.matvec(&[1.0, 1.0, 1.0]);
        assert_eq!(y, vec![2.0, 0.0, 0.0, -1.0]);
    }

    #[test]
    fn csc_roundtrip_and_col_ops() {
        let mut rng = Rng::seed_from(43);
        let a = random_sparse(&mut rng, 12, 9, 0.4);
        let d = a.to_dense();
        let csc = Csc::from_csr(&a);
        let x: Vec<f64> = (0..12).map(|_| rng.normal()).collect();
        for c in 0..9 {
            let expect: f64 = (0..12).map(|r| d.get(r, c) * x[r]).sum();
            assert!((csc.col_dot(c, &x) - expect).abs() < 1e-12);
        }
        let mut acc = vec![0.0; 12];
        csc.col_axpy(3, 2.0, &mut acc);
        for r in 0..12 {
            assert!((acc[r] - 2.0 * d.get(r, 3)).abs() < 1e-12);
        }
    }

    #[test]
    fn col_norms_match_dense() {
        let mut rng = Rng::seed_from(44);
        let a = random_sparse(&mut rng, 10, 7, 0.5);
        let d = a.to_dense();
        let n = a.col_norms_sq();
        for c in 0..7 {
            let expect: f64 = (0..10).map(|r| d.get(r, c) * d.get(r, c)).sum();
            assert!((n[c] - expect).abs() < 1e-12);
        }
    }

    #[test]
    fn density_and_nnz() {
        let a = Csr::from_triplets(2, 2, vec![(0, 0, 1.0)]);
        assert_eq!(a.nnz(), 1);
        assert!((a.density() - 0.25).abs() < 1e-12);
    }

    #[test]
    fn gram_into_matches_dense() {
        let mut rng = Rng::seed_from(45);
        let a = random_sparse(&mut rng, 30, 12, 0.3);
        let csc = Csc::from_csr(&a);
        let mut g = Mat::zeros(12, 12);
        a.gram_into(&csc, &mut g);
        let gd = a.to_dense().gram_t();
        for i in 0..12 {
            for j in 0..12 {
                assert!((g.get(i, j) - gd.get(i, j)).abs() < 1e-10, "({i},{j})");
            }
        }
    }

    #[test]
    fn gram_rows_into_matches_dense() {
        let mut rng = Rng::seed_from(46);
        let a = random_sparse(&mut rng, 11, 25, 0.3);
        let csc = Csc::from_csr(&a);
        let mut g = Mat::zeros(11, 11);
        a.gram_rows_into(&csc, &mut g);
        let gd = a.to_dense().gram();
        for i in 0..11 {
            for j in 0..11 {
                assert!((g.get(i, j) - gd.get(i, j)).abs() < 1e-10, "({i},{j})");
            }
        }
    }

    /// All sparse kernels must be bit-identical serial vs threaded on a
    /// shape that crosses the [`PAR_NNZ`] fan-out threshold.
    #[test]
    fn kernels_bit_stable_across_parallelism() {
        let mut rng = Rng::seed_from(47);
        // ~60k nnz > PAR_NNZ; > TCHUNK rows so the reduction chunks split.
        let a = random_sparse(&mut rng, 1200, 180, 0.28);
        assert!(a.nnz() >= PAR_NNZ, "test shape must cross the threshold");
        let x: Vec<f64> = (0..180).map(|_| rng.normal()).collect();
        let xt: Vec<f64> = (0..1200).map(|_| rng.normal()).collect();
        let serial = with_parallelism(Parallelism::None, || {
            let mut g = Mat::zeros(180, 180);
            let csc = Csc::from_csr(&a);
            a.gram_into(&csc, &mut g);
            (a.matvec(&x), a.matvec_t(&xt), a.col_norms_sq(), csc, g)
        });
        for nt in [2usize, 4] {
            let threaded = with_parallelism(Parallelism::Fixed(nt), || {
                let mut g = Mat::zeros(180, 180);
                let csc = Csc::from_csr(&a);
                a.gram_into(&csc, &mut g);
                (a.matvec(&x), a.matvec_t(&xt), a.col_norms_sq(), csc, g)
            });
            for (s, t) in serial.0.iter().zip(&threaded.0) {
                assert_eq!(s.to_bits(), t.to_bits(), "matvec nt={nt}");
            }
            for (s, t) in serial.1.iter().zip(&threaded.1) {
                assert_eq!(s.to_bits(), t.to_bits(), "matvec_t nt={nt}");
            }
            for (s, t) in serial.2.iter().zip(&threaded.2) {
                assert_eq!(s.to_bits(), t.to_bits(), "col_norms_sq nt={nt}");
            }
            assert_eq!(serial.3, threaded.3, "csc construction nt={nt}");
            for (s, t) in serial.4.data().iter().zip(threaded.4.data()) {
                assert_eq!(s.to_bits(), t.to_bits(), "gram nt={nt}");
            }
        }
    }

    /// Multi-RHS columns must be bit-identical to single-RHS calls on a
    /// shape crossing the fan-out threshold, at several thread counts.
    #[test]
    fn sparse_multi_rhs_columns_bit_match_single_rhs() {
        let mut rng = Rng::seed_from(49);
        let a = random_sparse(&mut rng, 1100, 160, 0.25);
        assert!(a.nnz() >= PAR_NNZ);
        let xs = MultiVec::from_fn(160, 3, |_, _| rng.normal());
        // include exact zeros so the per-column zero-skip is exercised
        let us = MultiVec::from_fn(1100, 3, |i, _| {
            if i % 7 == 0 {
                0.0
            } else {
                rng.normal()
            }
        });
        for par in [Parallelism::None, Parallelism::Fixed(2), Parallelism::Fixed(4)] {
            let (ys, yts) = with_parallelism(par, || {
                let mut ys = MultiVec::zeros(1100, 3);
                a.matvec_multi_into(&xs, &mut ys);
                let mut yts = MultiVec::zeros(160, 3);
                a.matvec_t_multi_into(&us, &mut yts);
                (ys, yts)
            });
            for j in 0..3 {
                let (y1, yt1) = with_parallelism(par, || {
                    (a.matvec(xs.col(j)), a.matvec_t(us.col(j)))
                });
                for (s, t) in y1.iter().zip(ys.col(j)) {
                    assert_eq!(s.to_bits(), t.to_bits(), "matvec col {j} ({par:?})");
                }
                for (s, t) in yt1.iter().zip(yts.col(j)) {
                    assert_eq!(s.to_bits(), t.to_bits(), "matvec_t col {j} ({par:?})");
                }
            }
        }
    }

    #[test]
    fn gather_rows_matches_dense_gather() {
        let mut rng = Rng::seed_from(50);
        let a = random_sparse(&mut rng, 15, 9, 0.4);
        let d = a.to_dense();
        let idx = [14usize, 2, 2, 7, 0];
        let mut out = Csr::empty();
        a.gather_rows_into(&idx, &mut out);
        assert_eq!((out.rows(), out.cols()), (5, 9));
        let od = out.to_dense();
        for (s, &r) in idx.iter().enumerate() {
            for c in 0..9 {
                assert_eq!(od.get(s, c), d.get(r, c), "({s},{c})");
            }
        }
        // reuse with a different selection
        a.gather_rows_into(&[1], &mut out);
        assert_eq!(out.rows(), 1);
        assert_eq!(out.to_dense().row(0), d.row(1));
    }

    #[test]
    fn csc_gather_cols_is_transposed_selection() {
        let mut rng = Rng::seed_from(51);
        let a = random_sparse(&mut rng, 12, 10, 0.35);
        let d = a.to_dense();
        let csc = Csc::from_csr(&a);
        let idx = [9usize, 0, 4];
        let mut out = Csr::empty();
        csc.gather_cols_into(&idx, &mut out);
        assert_eq!((out.rows(), out.cols()), (3, 12));
        let od = out.to_dense();
        for (s, &c) in idx.iter().enumerate() {
            for r in 0..12 {
                assert_eq!(od.get(s, r), d.get(r, c), "({s},{r})");
            }
        }
    }

    #[test]
    fn parallel_csc_matches_serial_on_ragged_columns() {
        // Heavily skewed column occupancy exercises the nnz-balanced
        // band split (some bands hold one hot column, some hold many).
        let mut rng = Rng::seed_from(48);
        let mut trip = Vec::new();
        for r in 0..900 {
            // hot columns 0..3 plus a sparse tail
            for c in 0..3 {
                trip.push((r, c, rng.normal()));
            }
            for _ in 0..20 {
                trip.push((r, 3 + rng.below(97), rng.normal()));
            }
        }
        let a = Csr::from_triplets(900, 100, trip);
        assert!(a.nnz() >= PAR_NNZ);
        let serial = with_parallelism(Parallelism::None, || Csc::from_csr(&a));
        let threaded = with_parallelism(Parallelism::Fixed(4), || Csc::from_csr(&a));
        assert_eq!(serial, threaded);
        // and the mirror is correct against the dense transpose
        let d = a.to_dense();
        let x: Vec<f64> = (0..900).map(|_| rng.normal()).collect();
        for c in [0usize, 1, 2, 50, 99] {
            let expect: f64 = (0..900).map(|r| d.get(r, c) * x[r]).sum();
            let got = serial.col_dot(c, &x);
            assert!((got - expect).abs() < 1e-9 * (1.0 + expect.abs()), "col {c}");
        }
    }
}
