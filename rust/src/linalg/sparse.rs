//! CSR sparse matrices.
//!
//! Two of the paper's data sets (Dorothea, E2006-tfidf) are extremely
//! sparse; the synthetic profiles mirror that, and the coordinate-descent
//! baselines exploit sparsity through per-column access. CSR supports the
//! row-major products; column access goes through an optional CSC mirror.

use super::dense::Mat;

/// Compressed sparse row matrix.
#[derive(Clone, Debug)]
pub struct Csr {
    rows: usize,
    cols: usize,
    indptr: Vec<usize>,
    indices: Vec<usize>,
    values: Vec<f64>,
}

impl Csr {
    /// Build from (row, col, value) triplets; duplicates are summed.
    pub fn from_triplets(rows: usize, cols: usize, mut trip: Vec<(usize, usize, f64)>) -> Self {
        trip.sort_unstable_by_key(|&(r, c, _)| (r, c));
        let mut indptr = vec![0usize; rows + 1];
        let mut indices = Vec::with_capacity(trip.len());
        let mut values: Vec<f64> = Vec::with_capacity(trip.len());
        for &(r, c, v) in &trip {
            assert!(r < rows && c < cols, "triplet out of bounds");
            if let (Some(&last_c), true) = (indices.last(), indptr[r + 1] > 0) {
                // merge duplicate within the same current row
                if last_c == c && indices.len() > indptr[r] && indptr[r + 1] == indices.len() {
                    // last entry belongs to row r with same col: accumulate
                    let lv = values.last_mut().unwrap();
                    *lv += v;
                    continue;
                }
            }
            // close out rows between
            indices.push(c);
            values.push(v);
            indptr[r + 1] = indices.len();
        }
        // prefix-fill: rows with no entries inherit previous offset
        for r in 1..=rows {
            if indptr[r] < indptr[r - 1] {
                indptr[r] = indptr[r - 1];
            }
        }
        Csr { rows, cols, indptr, indices, values }
    }

    /// Densify (small matrices / tests).
    pub fn to_dense(&self) -> Mat {
        let mut m = Mat::zeros(self.rows, self.cols);
        for r in 0..self.rows {
            for k in self.indptr[r]..self.indptr[r + 1] {
                m.set(r, self.indices[k], self.values[k]);
            }
        }
        m
    }

    /// Build from a dense matrix, dropping entries with |v| <= tol.
    pub fn from_dense(m: &Mat, tol: f64) -> Self {
        let mut trip = Vec::new();
        for r in 0..m.rows() {
            for c in 0..m.cols() {
                let v = m.get(r, c);
                if v.abs() > tol {
                    trip.push((r, c, v));
                }
            }
        }
        Self::from_triplets(m.rows(), m.cols(), trip)
    }

    #[inline]
    pub fn rows(&self) -> usize {
        self.rows
    }

    #[inline]
    pub fn cols(&self) -> usize {
        self.cols
    }

    /// Number of stored entries.
    #[inline]
    pub fn nnz(&self) -> usize {
        self.values.len()
    }

    /// Fill fraction.
    pub fn density(&self) -> f64 {
        self.nnz() as f64 / (self.rows * self.cols).max(1) as f64
    }

    /// Row iterator: (col, value) pairs of row r.
    #[inline]
    pub fn row_iter(&self, r: usize) -> impl Iterator<Item = (usize, f64)> + '_ {
        let lo = self.indptr[r];
        let hi = self.indptr[r + 1];
        self.indices[lo..hi].iter().copied().zip(self.values[lo..hi].iter().copied())
    }

    /// `y ← A·x`.
    pub fn matvec(&self, x: &[f64]) -> Vec<f64> {
        assert_eq!(x.len(), self.cols);
        let mut y = vec![0.0; self.rows];
        for r in 0..self.rows {
            let mut s = 0.0;
            for (c, v) in self.row_iter(r) {
                s += v * x[c];
            }
            y[r] = s;
        }
        y
    }

    /// `y ← Aᵀ·x`.
    pub fn matvec_t(&self, x: &[f64]) -> Vec<f64> {
        assert_eq!(x.len(), self.rows);
        let mut y = vec![0.0; self.cols];
        for r in 0..self.rows {
            let xr = x[r];
            if xr == 0.0 {
                continue;
            }
            for (c, v) in self.row_iter(r) {
                y[c] += v * xr;
            }
        }
        y
    }

    /// Squared L2 norm of each column (CD Lipschitz constants).
    pub fn col_norms_sq(&self) -> Vec<f64> {
        let mut n = vec![0.0; self.cols];
        for r in 0..self.rows {
            for (c, v) in self.row_iter(r) {
                n[c] += v * v;
            }
        }
        n
    }
}

/// Compressed sparse column mirror — gives coordinate descent O(nnz(col))
/// access to single columns.
#[derive(Clone, Debug)]
pub struct Csc {
    rows: usize,
    cols: usize,
    colptr: Vec<usize>,
    indices: Vec<usize>,
    values: Vec<f64>,
}

impl Csc {
    pub fn from_csr(a: &Csr) -> Self {
        let mut counts = vec![0usize; a.cols + 1];
        for &c in &a.indices {
            counts[c + 1] += 1;
        }
        for c in 0..a.cols {
            counts[c + 1] += counts[c];
        }
        let colptr = counts.clone();
        let mut cursor = counts;
        let mut indices = vec![0usize; a.nnz()];
        let mut values = vec![0.0; a.nnz()];
        for r in 0..a.rows {
            for (c, v) in a.row_iter(r) {
                let k = cursor[c];
                indices[k] = r;
                values[k] = v;
                cursor[c] += 1;
            }
        }
        Csc { rows: a.rows, cols: a.cols, colptr, indices, values }
    }

    #[inline]
    pub fn rows(&self) -> usize {
        self.rows
    }

    #[inline]
    pub fn cols(&self) -> usize {
        self.cols
    }

    /// Column iterator: (row, value) pairs of column c.
    #[inline]
    pub fn col_iter(&self, c: usize) -> impl Iterator<Item = (usize, f64)> + '_ {
        let lo = self.colptr[c];
        let hi = self.colptr[c + 1];
        self.indices[lo..hi].iter().copied().zip(self.values[lo..hi].iter().copied())
    }

    /// `⟨A[:,c], x⟩`.
    #[inline]
    pub fn col_dot(&self, c: usize, x: &[f64]) -> f64 {
        self.col_iter(c).map(|(r, v)| v * x[r]).sum()
    }

    /// `x ← x + a·A[:,c]`.
    #[inline]
    pub fn col_axpy(&self, c: usize, a: f64, x: &mut [f64]) {
        for (r, v) in self.col_iter(c) {
            x[r] += a * v;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;

    fn random_sparse(rng: &mut Rng, rows: usize, cols: usize, density: f64) -> Csr {
        let mut trip = Vec::new();
        for r in 0..rows {
            for c in 0..cols {
                if rng.bernoulli(density) {
                    trip.push((r, c, rng.normal()));
                }
            }
        }
        Csr::from_triplets(rows, cols, trip)
    }

    #[test]
    fn matvec_matches_dense() {
        let mut rng = Rng::seed_from(41);
        let a = random_sparse(&mut rng, 20, 15, 0.3);
        let d = a.to_dense();
        let x: Vec<f64> = (0..15).map(|_| rng.normal()).collect();
        let ys = a.matvec(&x);
        let yd = d.matvec(&x);
        for i in 0..20 {
            assert!((ys[i] - yd[i]).abs() < 1e-12);
        }
    }

    #[test]
    fn matvec_t_matches_dense() {
        let mut rng = Rng::seed_from(42);
        let a = random_sparse(&mut rng, 18, 25, 0.2);
        let d = a.to_dense();
        let x: Vec<f64> = (0..18).map(|_| rng.normal()).collect();
        let ys = a.matvec_t(&x);
        let yd = d.matvec_t(&x);
        for i in 0..25 {
            assert!((ys[i] - yd[i]).abs() < 1e-12);
        }
    }

    #[test]
    fn triplet_duplicates_sum() {
        let a = Csr::from_triplets(2, 2, vec![(0, 0, 1.0), (0, 0, 2.0), (1, 1, 5.0)]);
        let d = a.to_dense();
        assert_eq!(d.get(0, 0), 3.0);
        assert_eq!(d.get(1, 1), 5.0);
        assert_eq!(d.get(0, 1), 0.0);
    }

    #[test]
    fn empty_rows_handled() {
        let a = Csr::from_triplets(4, 3, vec![(0, 1, 2.0), (3, 2, -1.0)]);
        let y = a.matvec(&[1.0, 1.0, 1.0]);
        assert_eq!(y, vec![2.0, 0.0, 0.0, -1.0]);
    }

    #[test]
    fn csc_roundtrip_and_col_ops() {
        let mut rng = Rng::seed_from(43);
        let a = random_sparse(&mut rng, 12, 9, 0.4);
        let d = a.to_dense();
        let csc = Csc::from_csr(&a);
        let x: Vec<f64> = (0..12).map(|_| rng.normal()).collect();
        for c in 0..9 {
            let expect: f64 = (0..12).map(|r| d.get(r, c) * x[r]).sum();
            assert!((csc.col_dot(c, &x) - expect).abs() < 1e-12);
        }
        let mut acc = vec![0.0; 12];
        csc.col_axpy(3, 2.0, &mut acc);
        for r in 0..12 {
            assert!((acc[r] - 2.0 * d.get(r, 3)).abs() < 1e-12);
        }
    }

    #[test]
    fn col_norms_match_dense() {
        let mut rng = Rng::seed_from(44);
        let a = random_sparse(&mut rng, 10, 7, 0.5);
        let d = a.to_dense();
        let n = a.col_norms_sq();
        for c in 0..7 {
            let expect: f64 = (0..10).map(|r| d.get(r, c) * d.get(r, c)).sum();
            assert!((n[c] - expect).abs() < 1e-12);
        }
    }

    #[test]
    fn density_and_nnz() {
        let a = Csr::from_triplets(2, 2, vec![(0, 0, 1.0)]);
        assert_eq!(a.nnz(), 1);
        assert!((a.density() - 0.25).abs() < 1e-12);
    }
}
