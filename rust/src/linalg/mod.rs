//! Dense & sparse linear algebra substrate.
//!
//! The offline crate set has no BLAS/ndarray, so everything the solvers
//! need is implemented here. The dense hot path is organized as a
//! microkernel stack behind one seam, [`KernelCtx`]:
//!
//! - [`MicroKernel`] — an mr×nr register tile over packed operands,
//!   with scalar, AVX2, and FMA implementations selected once at
//!   startup by runtime CPU-feature detection (force one with
//!   [`KernelChoice`] / `PALLAS_KERNEL` / [`with_kernel_choice`]),
//! - [`CacheGeometry`] — probed L1/L2/L3 sizes, from which a
//!   [`Blocking`] (`kc`/`mc`/`nc`, gram block edge, serial-vs-threaded
//!   and naive-vs-blocked thresholds) is derived per kernel shape,
//! - [`KernelCtx`] — kernel choice + geometry + blocking; every matrix
//!   product ([`Mat::matmul`](dense::Mat::matmul),
//!   [`Mat::gram`](dense::Mat::gram), the multi-RHS panel kernels, the
//!   blocked-CG panel products, dual `K(t)` assembly) routes through a
//!   resolved ctx.
//!
//! Around that core: contiguous row-major matrices ([`dense`]),
//! Cholesky factorization, conjugate gradients over abstract linear
//! operators, threaded CSR/CSC sparse kernels ([`sparse`]), and the
//! [`Design`] abstraction that lets every solver consume dense or
//! sparse data through one interface without densifying. Worker counts
//! come from [`crate::util::parallel`] (`PALLAS_NUM_THREADS`), and for
//! a fixed kernel choice every parallel product is bit-stable across
//! thread counts (different kernels may round differently — FMA fuses —
//! which is why forcing one is first-class).
//!
//! Reference numerics are `f64`. An optional mixed-precision tier
//! ([`lowp`], selected via [`Precision`] / `PALLAS_PRECISION` /
//! [`with_precision`]) runs the bandwidth-bound panel products in `f32`
//! — packed [`MatF32`] storage, f32 microkernels behind the same
//! [`KernelCtx`] dispatch — while residuals, recurrences, and
//! convergence tests stay in `f64`, and an outer iterative-refinement
//! loop ([`cg::cg_solve_refined`]) restores full-precision solutions.
//! The XLA exchange path converts to `f32` at the runtime boundary
//! (matching the paper's single-precision GPU arithmetic).

mod cache;
pub mod cg;
pub mod cholesky;
pub mod dense;
pub mod design;
pub(crate) mod gemm;
mod kernel;
pub mod lowp;
pub mod multivec;
mod precision;
pub mod sparse;
pub mod vecops;

pub use cache::{Blocking, CacheGeometry};
pub use cg::{
    cg_solve, cg_solve_multi, cg_solve_multi_with, cg_solve_refined, cg_solve_with,
    CgMultiOutcome, CgOptions, CgOutcome, CgScratch, LinOp, MultiCol, MultiLinOp, RefineOutcome,
};
pub use cholesky::Cholesky;
pub use dense::Mat;
pub use design::{AsDesign, Design, DesignCols};
pub use gemm::{set_global_kernel, with_kernel_choice, KernelCtx};
pub use kernel::{best_available, enabled_choices, KernelChoice, KernelError, MicroKernel};
pub use lowp::{DesignShadowF32, MatF32, MultiVecF32};
pub use multivec::MultiVec;
pub use precision::{
    resolve_precision, resolved_precision, set_global_precision, try_resolve_precision,
    with_precision, Precision, PrecisionError,
};
pub use sparse::{Csc, Csr};
