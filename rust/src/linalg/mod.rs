//! Dense & sparse linear algebra substrate.
//!
//! The offline crate set has no BLAS/ndarray, so everything the solvers
//! need is implemented here: a packed, register/L2-tiled, multi-threaded
//! GEMM/Gram core ([`gemm`]), contiguous row-major matrices routed
//! through it ([`dense`]), Cholesky factorization, conjugate gradients
//! over abstract linear operators, threaded CSR/CSC sparse kernels
//! ([`sparse`]), and the [`Design`] abstraction that lets every solver
//! consume dense or sparse data through one interface without
//! densifying. Worker counts come from [`crate::util::parallel`]
//! (`PALLAS_NUM_THREADS`), and every parallel product is bit-stable
//! across thread counts.
//!
//! All solver numerics are `f64`; the XLA exchange path converts to `f32`
//! at the runtime boundary (matching the paper's single-precision GPU
//! arithmetic).

pub mod cg;
pub mod cholesky;
pub mod dense;
pub mod design;
pub mod gemm;
pub mod multivec;
pub mod sparse;
pub mod vecops;

pub use cg::{
    cg_solve, cg_solve_multi, cg_solve_multi_with, cg_solve_with, CgMultiOutcome, CgOptions,
    CgOutcome, CgScratch, LinOp, MultiCol, MultiLinOp,
};
pub use cholesky::Cholesky;
pub use dense::Mat;
pub use design::{AsDesign, Design, DesignCols};
pub use multivec::MultiVec;
pub use sparse::{Csc, Csr};
