//! Dense row-major `f64` matrix with blocked, thread-parallel products.
//!
//! `Mat` is the workhorse of every solver in this crate. The GEMM/GRAM
//! kernels use cache-blocked loops and `std::thread::scope` for row-band
//! parallelism — no external BLAS is available offline, and this keeps the
//! rust CPU backend an honest "optimized CPU baseline" for the paper's
//! comparisons.

use super::vecops;

/// Dense row-major matrix.
#[derive(Clone, Debug, PartialEq)]
pub struct Mat {
    rows: usize,
    cols: usize,
    data: Vec<f64>,
}

/// Number of worker threads for blocked products. Cached once.
pub fn num_threads() -> usize {
    use std::sync::OnceLock;
    static N: OnceLock<usize> = OnceLock::new();
    *N.get_or_init(|| {
        std::env::var("SVEN_THREADS")
            .ok()
            .and_then(|s| s.parse().ok())
            .unwrap_or_else(|| {
                std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4)
            })
    })
}

impl Mat {
    /// Zero matrix of shape `rows × cols`.
    pub fn zeros(rows: usize, cols: usize) -> Self {
        Mat { rows, cols, data: vec![0.0; rows * cols] }
    }

    /// Build from a closure `f(r, c)`.
    pub fn from_fn(rows: usize, cols: usize, mut f: impl FnMut(usize, usize) -> f64) -> Self {
        let mut data = Vec::with_capacity(rows * cols);
        for r in 0..rows {
            for c in 0..cols {
                data.push(f(r, c));
            }
        }
        Mat { rows, cols, data }
    }

    /// Wrap an existing row-major buffer.
    pub fn from_vec(rows: usize, cols: usize, data: Vec<f64>) -> Self {
        assert_eq!(data.len(), rows * cols, "buffer/shape mismatch");
        Mat { rows, cols, data }
    }

    /// Identity matrix.
    pub fn eye(n: usize) -> Self {
        Self::from_fn(n, n, |r, c| if r == c { 1.0 } else { 0.0 })
    }

    #[inline]
    pub fn rows(&self) -> usize {
        self.rows
    }

    #[inline]
    pub fn cols(&self) -> usize {
        self.cols
    }

    #[inline]
    pub fn data(&self) -> &[f64] {
        &self.data
    }

    #[inline]
    pub fn data_mut(&mut self) -> &mut [f64] {
        &mut self.data
    }

    #[inline]
    pub fn get(&self, r: usize, c: usize) -> f64 {
        debug_assert!(r < self.rows && c < self.cols);
        self.data[r * self.cols + c]
    }

    #[inline]
    pub fn set(&mut self, r: usize, c: usize, v: f64) {
        debug_assert!(r < self.rows && c < self.cols);
        self.data[r * self.cols + c] = v;
    }

    /// Immutable view of row `r`.
    #[inline]
    pub fn row(&self, r: usize) -> &[f64] {
        &self.data[r * self.cols..(r + 1) * self.cols]
    }

    /// Mutable view of row `r`.
    #[inline]
    pub fn row_mut(&mut self, r: usize) -> &mut [f64] {
        &mut self.data[r * self.cols..(r + 1) * self.cols]
    }

    /// Copy of column `c` (rows are contiguous, columns are strided).
    pub fn col(&self, c: usize) -> Vec<f64> {
        (0..self.rows).map(|r| self.get(r, c)).collect()
    }

    /// Explicit transpose.
    pub fn transpose(&self) -> Mat {
        let mut t = Mat::zeros(self.cols, self.rows);
        // Blocked to keep both source rows and destination rows in cache.
        const B: usize = 64;
        for rb in (0..self.rows).step_by(B) {
            for cb in (0..self.cols).step_by(B) {
                for r in rb..(rb + B).min(self.rows) {
                    for c in cb..(cb + B).min(self.cols) {
                        t.data[c * self.rows + r] = self.data[r * self.cols + c];
                    }
                }
            }
        }
        t
    }

    /// `y ← A·x` (allocates the output).
    pub fn matvec(&self, x: &[f64]) -> Vec<f64> {
        let mut y = vec![0.0; self.rows];
        self.matvec_into(x, &mut y);
        y
    }

    /// `y ← A·x` into a caller-provided buffer (hot-path form).
    pub fn matvec_into(&self, x: &[f64], y: &mut [f64]) {
        assert_eq!(x.len(), self.cols);
        assert_eq!(y.len(), self.rows);
        let nt = num_threads();
        if self.rows * self.cols < 1 << 16 || nt == 1 {
            for r in 0..self.rows {
                y[r] = vecops::dot(self.row(r), x);
            }
            return;
        }
        let band = self.rows.div_ceil(nt);
        std::thread::scope(|s| {
            for (tid, ych) in y.chunks_mut(band).enumerate() {
                let lo = tid * band;
                s.spawn(move || {
                    for (i, yr) in ych.iter_mut().enumerate() {
                        *yr = vecops::dot(self.row(lo + i), x);
                    }
                });
            }
        });
    }

    /// `y ← Aᵀ·x` (allocates the output).
    pub fn matvec_t(&self, x: &[f64]) -> Vec<f64> {
        let mut y = vec![0.0; self.cols];
        self.matvec_t_into(x, &mut y);
        y
    }

    /// `y ← Aᵀ·x` into a caller-provided buffer. Accumulates row-wise so
    /// memory access stays sequential over `self.data`.
    pub fn matvec_t_into(&self, x: &[f64], y: &mut [f64]) {
        assert_eq!(x.len(), self.rows);
        assert_eq!(y.len(), self.cols);
        y.fill(0.0);
        let nt = num_threads();
        if self.rows * self.cols < 1 << 16 || nt == 1 {
            for r in 0..self.rows {
                vecops::axpy(x[r], self.row(r), y);
            }
            return;
        }
        // Each thread accumulates a private output, then we reduce.
        let band = self.rows.div_ceil(nt);
        let partials: Vec<Vec<f64>> = std::thread::scope(|s| {
            let handles: Vec<_> = (0..nt)
                .map(|tid| {
                    s.spawn(move || {
                        let mut acc = vec![0.0; self.cols];
                        let lo = tid * band;
                        let hi = ((tid + 1) * band).min(self.rows);
                        for r in lo..hi {
                            vecops::axpy(x[r], self.row(r), &mut acc);
                        }
                        acc
                    })
                })
                .collect();
            handles.into_iter().map(|h| h.join().unwrap()).collect()
        });
        for p in &partials {
            vecops::axpy(1.0, p, y);
        }
    }

    /// `C ← A·B` — blocked, thread-parallel over row bands of A.
    pub fn matmul(&self, b: &Mat) -> Mat {
        assert_eq!(self.cols, b.rows, "gemm shape mismatch");
        let mut c = Mat::zeros(self.rows, b.cols);
        let nt = num_threads();
        let (m, k, n) = (self.rows, self.cols, b.cols);
        let work = m * k * n;
        if work < 1 << 18 || nt == 1 {
            gemm_band(&self.data, &b.data, &mut c.data, 0, m, k, n);
            return c;
        }
        let band = m.div_ceil(nt);
        std::thread::scope(|s| {
            for (tid, cch) in c.data.chunks_mut(band * n).enumerate() {
                let lo = tid * band;
                let rows_here = cch.len() / n;
                let a = &self.data;
                let bd = &b.data;
                s.spawn(move || {
                    gemm_band_into(&a[lo * k..(lo + rows_here) * k], bd, cch, rows_here, k, n);
                });
            }
        });
        c
    }

    /// Gram matrix `AᵀA` (`cols × cols`), exploiting symmetry.
    pub fn gram_t(&self) -> Mat {
        let at = self.transpose();
        at.gram()
    }

    /// Gram matrix `AAᵀ` (`rows × rows`), exploiting symmetry: only the
    /// upper triangle is computed, then mirrored.
    pub fn gram(&self) -> Mat {
        let m = self.rows;
        let mut g = Mat::zeros(m, m);
        let nt = num_threads();
        if m * m * self.cols < 1 << 18 || nt == 1 {
            for i in 0..m {
                for j in i..m {
                    let v = vecops::dot(self.row(i), self.row(j));
                    g.data[i * m + j] = v;
                    g.data[j * m + i] = v;
                }
            }
            return g;
        }
        // Parallel over i with interleaved assignment so triangle work
        // (row i costs m−i dots) balances across threads.
        let rows_done: Vec<Vec<(usize, Vec<f64>)>> = std::thread::scope(|s| {
            let handles: Vec<_> = (0..nt)
                .map(|tid| {
                    s.spawn(move || {
                        let mut out = Vec::new();
                        let mut i = tid;
                        while i < m {
                            let mut row = vec![0.0; m - i];
                            for j in i..m {
                                row[j - i] = vecops::dot(self.row(i), self.row(j));
                            }
                            out.push((i, row));
                            i += nt;
                        }
                        out
                    })
                })
                .collect();
            handles.into_iter().map(|h| h.join().unwrap()).collect()
        });
        for chunk in rows_done {
            for (i, row) in chunk {
                for (off, v) in row.into_iter().enumerate() {
                    let j = i + off;
                    g.data[i * m + j] = v;
                    g.data[j * m + i] = v;
                }
            }
        }
        g
    }

    /// Frobenius norm.
    pub fn fro_norm(&self) -> f64 {
        vecops::norm2(&self.data)
    }

    /// Horizontal concatenation `[A, B]`.
    pub fn hcat(&self, b: &Mat) -> Mat {
        assert_eq!(self.rows, b.rows);
        let mut out = Mat::zeros(self.rows, self.cols + b.cols);
        for r in 0..self.rows {
            out.row_mut(r)[..self.cols].copy_from_slice(self.row(r));
            out.row_mut(r)[self.cols..].copy_from_slice(b.row(r));
        }
        out
    }

    /// Vertical concatenation `[A; B]`.
    pub fn vcat(&self, b: &Mat) -> Mat {
        assert_eq!(self.cols, b.cols);
        let mut data = self.data.clone();
        data.extend_from_slice(&b.data);
        Mat { rows: self.rows + b.rows, cols: self.cols, data }
    }

    /// Convert to `f32` row-major buffer (XLA exchange boundary).
    pub fn to_f32(&self) -> Vec<f32> {
        self.data.iter().map(|&v| v as f32).collect()
    }
}

/// Sequential blocked GEMM over a row band: `C[0..m_band] += A_band · B`.
fn gemm_band(a: &[f64], b: &[f64], c: &mut [f64], row_lo: usize, row_hi: usize, k: usize, n: usize) {
    let rows = row_hi - row_lo;
    gemm_band_into(&a[row_lo * k..row_hi * k], b, &mut c[row_lo * n..row_hi * n], rows, k, n);
}

/// Kernel: `C (m×n) += A (m×k) · B (k×n)`, ikj loop order with k-blocking
/// so B rows stream through cache while C rows stay hot.
fn gemm_band_into(a: &[f64], b: &[f64], c: &mut [f64], m: usize, k: usize, n: usize) {
    const KB: usize = 256;
    for kb in (0..k).step_by(KB) {
        let kend = (kb + KB).min(k);
        for i in 0..m {
            let crow = &mut c[i * n..(i + 1) * n];
            for kk in kb..kend {
                let aik = a[i * k + kk];
                if aik == 0.0 {
                    continue;
                }
                let brow = &b[kk * n..(kk + 1) * n];
                vecops::axpy(aik, brow, crow);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;

    fn rand_mat(rng: &mut Rng, r: usize, c: usize) -> Mat {
        Mat::from_fn(r, c, |_, _| rng.normal())
    }

    #[test]
    fn matvec_matches_naive() {
        let mut rng = Rng::seed_from(7);
        let a = rand_mat(&mut rng, 13, 29);
        let x: Vec<f64> = (0..29).map(|_| rng.normal()).collect();
        let y = a.matvec(&x);
        for r in 0..13 {
            let naive: f64 = (0..29).map(|c| a.get(r, c) * x[c]).sum();
            assert!((y[r] - naive).abs() < 1e-10);
        }
    }

    #[test]
    fn matvec_t_matches_transpose_matvec() {
        let mut rng = Rng::seed_from(8);
        let a = rand_mat(&mut rng, 17, 11);
        let x: Vec<f64> = (0..17).map(|_| rng.normal()).collect();
        let y1 = a.matvec_t(&x);
        let y2 = a.transpose().matvec(&x);
        for (v1, v2) in y1.iter().zip(&y2) {
            assert!((v1 - v2).abs() < 1e-10);
        }
    }

    #[test]
    fn matmul_matches_naive() {
        let mut rng = Rng::seed_from(9);
        let a = rand_mat(&mut rng, 7, 5);
        let b = rand_mat(&mut rng, 5, 9);
        let c = a.matmul(&b);
        for i in 0..7 {
            for j in 0..9 {
                let naive: f64 = (0..5).map(|k| a.get(i, k) * b.get(k, j)).sum();
                assert!((c.get(i, j) - naive).abs() < 1e-10);
            }
        }
    }

    #[test]
    fn matmul_large_parallel_path() {
        let mut rng = Rng::seed_from(10);
        let a = rand_mat(&mut rng, 130, 70);
        let b = rand_mat(&mut rng, 70, 90);
        let c = a.matmul(&b);
        // Spot-check against naive on a few entries.
        for &(i, j) in &[(0, 0), (129, 89), (64, 45), (12, 3)] {
            let naive: f64 = (0..70).map(|k| a.get(i, k) * b.get(k, j)).sum();
            assert!((c.get(i, j) - naive).abs() < 1e-9);
        }
    }

    #[test]
    fn gram_is_aat() {
        let mut rng = Rng::seed_from(11);
        let a = rand_mat(&mut rng, 12, 6);
        let g = a.gram();
        let g2 = a.matmul(&a.transpose());
        for i in 0..12 {
            for j in 0..12 {
                assert!((g.get(i, j) - g2.get(i, j)).abs() < 1e-10);
            }
        }
    }

    #[test]
    fn gram_large_parallel_matches() {
        let mut rng = Rng::seed_from(12);
        let a = rand_mat(&mut rng, 90, 40);
        let g = a.gram();
        let g2 = a.matmul(&a.transpose());
        let mut max = 0.0f64;
        for i in 0..90 {
            for j in 0..90 {
                max = max.max((g.get(i, j) - g2.get(i, j)).abs());
            }
        }
        assert!(max < 1e-9, "max dev {max}");
    }

    #[test]
    fn transpose_roundtrip() {
        let mut rng = Rng::seed_from(13);
        let a = rand_mat(&mut rng, 33, 21);
        assert_eq!(a.transpose().transpose(), a);
    }

    #[test]
    fn hcat_vcat_shapes() {
        let a = Mat::eye(2);
        let b = Mat::zeros(2, 3);
        let h = a.hcat(&b);
        assert_eq!((h.rows(), h.cols()), (2, 5));
        assert_eq!(h.get(1, 1), 1.0);
        assert_eq!(h.get(1, 4), 0.0);
        let c = Mat::zeros(4, 2);
        let v = a.vcat(&c);
        assert_eq!((v.rows(), v.cols()), (6, 2));
    }

    #[test]
    fn eye_matvec_is_identity() {
        let x = vec![1.0, -2.0, 3.5];
        assert_eq!(Mat::eye(3).matvec(&x), x);
    }
}
