//! Dense row-major `f64` matrix over the blocked kernel layer.
//!
//! `Mat` is the workhorse of every solver in this crate. All O(n³)
//! products (GEMM, Gram) route through the ambient
//! [`KernelCtx`](crate::linalg::KernelCtx) — packed, register-tiled by
//! the dispatched microkernel, cache-blocked by the probed geometry,
//! fanned out over the scoped pool in [`crate::util::parallel`] — and
//! the O(n²) GEMV paths band their output rows over the same pool,
//! going parallel only past the ctx's cache-derived `gemv_threshold`.
//! No external BLAS is available offline; this layer keeps the rust
//! CPU backend an honest "optimized CPU baseline" for the paper's
//! comparisons.
//!
//! Determinism contract: for a fixed kernel choice, every product's
//! result is bit-identical under any `Parallelism` setting (the
//! decomposition never depends on the worker count — see the notes in
//! `gemm.rs` and the fixed-chunk reduction in [`Mat::matvec_t_into`]).

use super::multivec::MultiVec;
use super::{gemm, vecops};
use crate::util::parallel;

/// Dense row-major matrix.
#[derive(Clone, Debug, PartialEq)]
pub struct Mat {
    rows: usize,
    cols: usize,
    data: Vec<f64>,
}

/// Fixed row-chunk length for the `Aᵀx` partial-sum reduction. Constant
/// (never thread-count-derived) so the reduction tree — and therefore
/// the result bits — are identical in serial and parallel runs.
const TCHUNK: usize = 512;

impl Mat {
    /// Zero matrix of shape `rows × cols`.
    pub fn zeros(rows: usize, cols: usize) -> Self {
        Mat { rows, cols, data: vec![0.0; rows * cols] }
    }

    /// Build from a closure `f(r, c)`.
    pub fn from_fn(rows: usize, cols: usize, mut f: impl FnMut(usize, usize) -> f64) -> Self {
        let mut data = Vec::with_capacity(rows * cols);
        for r in 0..rows {
            for c in 0..cols {
                data.push(f(r, c));
            }
        }
        Mat { rows, cols, data }
    }

    /// Wrap an existing row-major buffer.
    pub fn from_vec(rows: usize, cols: usize, data: Vec<f64>) -> Self {
        assert_eq!(data.len(), rows * cols, "buffer/shape mismatch");
        Mat { rows, cols, data }
    }

    /// Identity matrix.
    pub fn eye(n: usize) -> Self {
        Self::from_fn(n, n, |r, c| if r == c { 1.0 } else { 0.0 })
    }

    #[inline]
    pub fn rows(&self) -> usize {
        self.rows
    }

    #[inline]
    pub fn cols(&self) -> usize {
        self.cols
    }

    #[inline]
    pub fn data(&self) -> &[f64] {
        &self.data
    }

    #[inline]
    pub fn data_mut(&mut self) -> &mut [f64] {
        &mut self.data
    }

    #[inline]
    pub fn get(&self, r: usize, c: usize) -> f64 {
        debug_assert!(r < self.rows && c < self.cols);
        self.data[r * self.cols + c]
    }

    #[inline]
    pub fn set(&mut self, r: usize, c: usize, v: f64) {
        debug_assert!(r < self.rows && c < self.cols);
        self.data[r * self.cols + c] = v;
    }

    /// Immutable view of row `r`.
    #[inline]
    pub fn row(&self, r: usize) -> &[f64] {
        &self.data[r * self.cols..(r + 1) * self.cols]
    }

    /// Mutable view of row `r`.
    #[inline]
    pub fn row_mut(&mut self, r: usize) -> &mut [f64] {
        &mut self.data[r * self.cols..(r + 1) * self.cols]
    }

    /// Copy of column `c` (rows are contiguous, columns are strided).
    pub fn col(&self, c: usize) -> Vec<f64> {
        (0..self.rows).map(|r| self.get(r, c)).collect()
    }

    /// Explicit transpose.
    pub fn transpose(&self) -> Mat {
        let mut t = Mat::zeros(self.cols, self.rows);
        // Blocked to keep both source rows and destination rows in cache.
        const B: usize = 64;
        for rb in (0..self.rows).step_by(B) {
            for cb in (0..self.cols).step_by(B) {
                for r in rb..(rb + B).min(self.rows) {
                    for c in cb..(cb + B).min(self.cols) {
                        t.data[c * self.rows + r] = self.data[r * self.cols + c];
                    }
                }
            }
        }
        t
    }

    /// `y ← A·x` (allocates the output).
    pub fn matvec(&self, x: &[f64]) -> Vec<f64> {
        let mut y = vec![0.0; self.rows];
        self.matvec_into(x, &mut y);
        y
    }

    /// `y ← A·x` into a caller-provided buffer (hot-path form). Output
    /// rows are banded over the pool once the matrix clears the ambient
    /// ctx's cache-derived `gemv_threshold`; each `y[r]` is one row dot,
    /// so the result does not depend on the banding.
    pub fn matvec_into(&self, x: &[f64], y: &mut [f64]) {
        assert_eq!(x.len(), self.cols);
        assert_eq!(y.len(), self.rows);
        let nt = parallel::effective_threads();
        if self.rows * self.cols < gemm::KernelCtx::current().blocking().gemv_threshold
            || nt == 1
        {
            for (r, yr) in y.iter_mut().enumerate() {
                *yr = vecops::dot(self.row(r), x);
            }
            return;
        }
        let band = self.rows.div_ceil(nt);
        let chunks: Vec<&mut [f64]> = y.chunks_mut(band).collect();
        parallel::parallel_items(nt, chunks, |tid, ych| {
            let lo = tid * band;
            for (i, yr) in ych.iter_mut().enumerate() {
                *yr = vecops::dot(self.row(lo + i), x);
            }
        });
    }

    /// `y ← Aᵀ·x` (allocates the output).
    pub fn matvec_t(&self, x: &[f64]) -> Vec<f64> {
        let mut y = vec![0.0; self.cols];
        self.matvec_t_into(x, &mut y);
        y
    }

    /// `y ← Aᵀ·x` into a caller-provided buffer.
    ///
    /// Rows are reduced in fixed [`TCHUNK`]-row chunks: each chunk
    /// accumulates a private partial (parallel across chunks), then the
    /// partials are summed in chunk order. The chunk grid is
    /// size-derived, never thread-derived, so serial and parallel runs
    /// produce identical bits.
    pub fn matvec_t_into(&self, x: &[f64], y: &mut [f64]) {
        assert_eq!(x.len(), self.rows);
        assert_eq!(y.len(), self.cols);
        y.fill(0.0);
        if self.rows == 0 || self.cols == 0 {
            return;
        }
        let nchunks = self.rows.div_ceil(TCHUNK);
        if nchunks == 1 {
            for r in 0..self.rows {
                vecops::axpy(x[r], self.row(r), y);
            }
            return;
        }
        let nt = parallel::effective_threads();
        let mut partials = vec![0.0; nchunks * self.cols];
        {
            let chunks: Vec<&mut [f64]> = partials.chunks_mut(self.cols).collect();
            parallel::parallel_items(nt, chunks, |ci, acc| {
                let lo = ci * TCHUNK;
                let hi = (lo + TCHUNK).min(self.rows);
                for r in lo..hi {
                    vecops::axpy(x[r], self.row(r), acc);
                }
            });
        }
        for p in partials.chunks(self.cols) {
            vecops::axpy(1.0, p, y);
        }
    }

    /// `Y ← A·X` for a panel of right-hand sides (the fused multi-RHS
    /// GEMV): `X` is `cols × r`, `Y` is `rows × r`.
    ///
    /// Contract (pinned by proptests): column `j` of `Y` is
    /// **bit-identical** to `matvec_into(X.col(j), ..)`, and the result
    /// is bit-stable across thread counts. Each output element is the
    /// same `vecops::dot` the single-RHS path computes; the fusion win is
    /// purely in memory traffic — `A` is streamed once per *panel* (each
    /// row stays hot in L1 across the `r` columns, the panel stays
    /// L2-resident across rows) instead of once per right-hand side,
    /// which is what turns the bandwidth-bound banded GEMV into
    /// GEMM-shaped work. A KC-blocked accumulation through the packed
    /// microkernel would be faster still for very large `r`, but it
    /// would re-associate the per-element sums and break the
    /// column-bit-identity contract, so the panel kernel deliberately
    /// keeps the single-RHS reduction order.
    pub fn matvec_multi_into(&self, xs: &MultiVec, ys: &mut MultiVec) {
        assert_eq!(xs.rows(), self.cols, "panel rows must match A cols");
        assert_eq!(ys.rows(), self.rows, "output rows must match A rows");
        assert_eq!(xs.ncols(), ys.ncols(), "panel widths must match");
        let r = xs.ncols();
        if r == 0 || self.rows == 0 {
            return;
        }
        let nt = parallel::effective_threads();
        if self.rows * self.cols < gemm::KernelCtx::current().blocking().gemv_threshold
            || nt == 1
        {
            for row in 0..self.rows {
                let a = self.row(row);
                for j in 0..r {
                    ys.col_mut(j)[row] = vecops::dot(a, xs.col(j));
                }
            }
            return;
        }
        // Band the output rows over the pool; each band owns the same
        // row-range slice of every output column.
        let band = self.rows.div_ceil(nt);
        let nbands = self.rows.div_ceil(band);
        let mut items: Vec<Vec<&mut [f64]>> =
            (0..nbands).map(|_| Vec::with_capacity(r)).collect();
        let rows = self.rows;
        for col in ys.data_mut().chunks_mut(rows) {
            for (b, piece) in col.chunks_mut(band).enumerate() {
                items[b].push(piece);
            }
        }
        parallel::parallel_items(nt, items, |b, mut cols| {
            let lo = b * band;
            let len = cols[0].len();
            for i in 0..len {
                let a = self.row(lo + i);
                for (j, piece) in cols.iter_mut().enumerate() {
                    piece[i] = vecops::dot(a, xs.col(j));
                }
            }
        });
    }

    /// `Y ← Aᵀ·U` for a panel of right-hand sides: `U` is `rows × r`,
    /// `Y` is `cols × r`. Same fixed [`TCHUNK`] reduction grid as
    /// [`Mat::matvec_t_into`], applied per column in the single-RHS
    /// order, so column `j` of `Y` is bit-identical to
    /// `matvec_t_into(U.col(j), ..)` at any thread count.
    pub fn matvec_t_multi_into(&self, us: &MultiVec, ys: &mut MultiVec) {
        assert_eq!(us.rows(), self.rows, "panel rows must match A rows");
        assert_eq!(ys.rows(), self.cols, "output rows must match A cols");
        assert_eq!(us.ncols(), ys.ncols(), "panel widths must match");
        let r = us.ncols();
        ys.data_mut().fill(0.0);
        if self.rows == 0 || self.cols == 0 || r == 0 {
            return;
        }
        let nchunks = self.rows.div_ceil(TCHUNK);
        if nchunks == 1 {
            for row in 0..self.rows {
                let a = self.row(row);
                for j in 0..r {
                    vecops::axpy(us.col(j)[row], a, ys.col_mut(j));
                }
            }
            return;
        }
        let nt = parallel::effective_threads();
        let width = self.cols * r;
        let mut partials = vec![0.0; nchunks * width];
        {
            let chunks: Vec<&mut [f64]> = partials.chunks_mut(width).collect();
            parallel::parallel_items(nt, chunks, |ci, acc| {
                let lo = ci * TCHUNK;
                let hi = (lo + TCHUNK).min(self.rows);
                for row in lo..hi {
                    let a = self.row(row);
                    for j in 0..r {
                        let acc_j = &mut acc[j * self.cols..(j + 1) * self.cols];
                        vecops::axpy(us.col(j)[row], a, acc_j);
                    }
                }
            });
        }
        for p in partials.chunks(width) {
            for j in 0..r {
                vecops::axpy(1.0, &p[j * self.cols..(j + 1) * self.cols], ys.col_mut(j));
            }
        }
    }

    /// Gather the rows `idx` into `out` (reusing its allocation) —
    /// `out.row(s) = self.row(idx[s])`. The compact-panel primitive of
    /// the active-set (shrinking) primal Newton.
    pub fn gather_rows_into(&self, idx: &[usize], out: &mut Mat) {
        out.rows = idx.len();
        out.cols = self.cols;
        out.data.clear();
        out.data.reserve(idx.len() * self.cols);
        for &r in idx {
            out.data.extend_from_slice(self.row(r));
        }
    }

    /// Gather the *columns* `idx` into the *rows* of `out` (reusing its
    /// allocation) — `out.row(s) = self.col(idx[s])`. Blocked over source
    /// rows so the strided column reads stay cache-friendly; used by the
    /// SVEN reduction, whose implicit sample rows are design columns.
    pub fn gather_cols_as_rows_into(&self, idx: &[usize], out: &mut Mat) {
        out.rows = idx.len();
        out.cols = self.rows;
        out.data.clear();
        out.data.resize(idx.len() * self.rows, 0.0);
        const B: usize = 64;
        for rb in (0..self.rows).step_by(B) {
            let hi = (rb + B).min(self.rows);
            for (s, &c) in idx.iter().enumerate() {
                for r in rb..hi {
                    out.data[s * self.rows + r] = self.data[r * self.cols + c];
                }
            }
        }
    }

    /// `C ← A·B` through the ambient
    /// [`KernelCtx`](crate::linalg::KernelCtx) (reuse-poor small
    /// products fall back to the naive loop inside the ctx's size gate).
    pub fn matmul(&self, b: &Mat) -> Mat {
        assert_eq!(self.cols, b.rows, "gemm shape mismatch");
        let mut c = Mat::zeros(self.rows, b.cols);
        gemm::KernelCtx::current().matmul_into(
            &self.data,
            &b.data,
            &mut c.data,
            self.rows,
            self.cols,
            b.cols,
        );
        c
    }

    /// Gram matrix `AᵀA` (`cols × cols`), exploiting symmetry.
    pub fn gram_t(&self) -> Mat {
        let at = self.transpose();
        at.gram()
    }

    /// Gram matrix `AAᵀ` (`rows × rows`) through the symmetric blocked
    /// kernel: only upper-triangle block pairs are computed, then
    /// mirrored.
    pub fn gram(&self) -> Mat {
        let mut g = Mat::zeros(self.rows, self.rows);
        gemm::KernelCtx::current().gram_into(&self.data, &mut g.data, self.rows, self.cols);
        g
    }

    /// Frobenius norm.
    pub fn fro_norm(&self) -> f64 {
        vecops::norm2(&self.data)
    }

    /// Horizontal concatenation `[A, B]`.
    pub fn hcat(&self, b: &Mat) -> Mat {
        assert_eq!(self.rows, b.rows);
        let mut out = Mat::zeros(self.rows, self.cols + b.cols);
        for r in 0..self.rows {
            out.row_mut(r)[..self.cols].copy_from_slice(self.row(r));
            out.row_mut(r)[self.cols..].copy_from_slice(b.row(r));
        }
        out
    }

    /// Vertical concatenation `[A; B]`.
    pub fn vcat(&self, b: &Mat) -> Mat {
        assert_eq!(self.cols, b.cols);
        let mut data = self.data.clone();
        data.extend_from_slice(&b.data);
        Mat { rows: self.rows + b.rows, cols: self.cols, data }
    }

    /// Convert to `f32` row-major buffer (XLA exchange boundary).
    pub fn to_f32(&self) -> Vec<f32> {
        self.data.iter().map(|&v| v as f32).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;
    use crate::util::parallel::{with_parallelism, Parallelism};

    fn rand_mat(rng: &mut Rng, r: usize, c: usize) -> Mat {
        Mat::from_fn(r, c, |_, _| rng.normal())
    }

    #[test]
    fn matvec_matches_naive() {
        let mut rng = Rng::seed_from(7);
        let a = rand_mat(&mut rng, 13, 29);
        let x: Vec<f64> = (0..29).map(|_| rng.normal()).collect();
        let y = a.matvec(&x);
        for r in 0..13 {
            let naive: f64 = (0..29).map(|c| a.get(r, c) * x[c]).sum();
            assert!((y[r] - naive).abs() < 1e-10);
        }
    }

    #[test]
    fn matvec_t_matches_transpose_matvec() {
        let mut rng = Rng::seed_from(8);
        let a = rand_mat(&mut rng, 17, 11);
        let x: Vec<f64> = (0..17).map(|_| rng.normal()).collect();
        let y1 = a.matvec_t(&x);
        let y2 = a.transpose().matvec(&x);
        for (v1, v2) in y1.iter().zip(&y2) {
            assert!((v1 - v2).abs() < 1e-10);
        }
    }

    #[test]
    fn matvec_t_bit_stable_across_parallelism() {
        let mut rng = Rng::seed_from(18);
        // > TCHUNK rows so the chunked reduction actually splits.
        let a = rand_mat(&mut rng, 1100, 37);
        let x: Vec<f64> = (0..1100).map(|_| rng.normal()).collect();
        let serial = with_parallelism(Parallelism::None, || a.matvec_t(&x));
        let threaded = with_parallelism(Parallelism::Fixed(4), || a.matvec_t(&x));
        for (s, t) in serial.iter().zip(&threaded) {
            assert_eq!(s.to_bits(), t.to_bits());
        }
    }

    #[test]
    fn matmul_matches_naive() {
        let mut rng = Rng::seed_from(9);
        let a = rand_mat(&mut rng, 7, 5);
        let b = rand_mat(&mut rng, 5, 9);
        let c = a.matmul(&b);
        for i in 0..7 {
            for j in 0..9 {
                let naive: f64 = (0..5).map(|k| a.get(i, k) * b.get(k, j)).sum();
                assert!((c.get(i, j) - naive).abs() < 1e-10);
            }
        }
    }

    #[test]
    fn matmul_large_parallel_path() {
        let mut rng = Rng::seed_from(10);
        let a = rand_mat(&mut rng, 130, 70);
        let b = rand_mat(&mut rng, 70, 90);
        let c = a.matmul(&b);
        // Spot-check against naive on a few entries.
        for &(i, j) in &[(0, 0), (129, 89), (64, 45), (12, 3)] {
            let naive: f64 = (0..70).map(|k| a.get(i, k) * b.get(k, j)).sum();
            assert!((c.get(i, j) - naive).abs() < 1e-9);
        }
    }

    #[test]
    fn gram_is_aat() {
        let mut rng = Rng::seed_from(11);
        let a = rand_mat(&mut rng, 12, 6);
        let g = a.gram();
        let g2 = a.matmul(&a.transpose());
        for i in 0..12 {
            for j in 0..12 {
                assert!((g.get(i, j) - g2.get(i, j)).abs() < 1e-10);
            }
        }
    }

    #[test]
    fn gram_large_parallel_matches() {
        let mut rng = Rng::seed_from(12);
        let a = rand_mat(&mut rng, 90, 40);
        let g = a.gram();
        let g2 = a.matmul(&a.transpose());
        let mut max = 0.0f64;
        for i in 0..90 {
            for j in 0..90 {
                max = max.max((g.get(i, j) - g2.get(i, j)).abs());
            }
        }
        assert!(max < 1e-9, "max dev {max}");
    }

    #[test]
    fn transpose_roundtrip() {
        let mut rng = Rng::seed_from(13);
        let a = rand_mat(&mut rng, 33, 21);
        assert_eq!(a.transpose().transpose(), a);
    }

    #[test]
    fn hcat_vcat_shapes() {
        let a = Mat::eye(2);
        let b = Mat::zeros(2, 3);
        let h = a.hcat(&b);
        assert_eq!((h.rows(), h.cols()), (2, 5));
        assert_eq!(h.get(1, 1), 1.0);
        assert_eq!(h.get(1, 4), 0.0);
        let c = Mat::zeros(4, 2);
        let v = a.vcat(&c);
        assert_eq!((v.rows(), v.cols()), (6, 2));
    }

    #[test]
    fn eye_matvec_is_identity() {
        let x = vec![1.0, -2.0, 3.5];
        assert_eq!(Mat::eye(3).matvec(&x), x);
    }

    /// Multi-RHS columns must be bit-identical to single-RHS calls, on
    /// shapes that cross both the GEMV banding and TCHUNK thresholds.
    #[test]
    fn multi_rhs_columns_bit_match_single_rhs() {
        use crate::linalg::MultiVec;
        let mut rng = Rng::seed_from(19);
        // 1100 × 80 = 88k elements > 2^16, rows > TCHUNK.
        let a = rand_mat(&mut rng, 1100, 80);
        let xs = MultiVec::from_fn(80, 3, |_, _| rng.normal());
        let us = MultiVec::from_fn(1100, 3, |_, _| rng.normal());
        for par in [Parallelism::None, Parallelism::Fixed(4)] {
            let (ys, yts) = with_parallelism(par, || {
                let mut ys = MultiVec::zeros(1100, 3);
                a.matvec_multi_into(&xs, &mut ys);
                let mut yts = MultiVec::zeros(80, 3);
                a.matvec_t_multi_into(&us, &mut yts);
                (ys, yts)
            });
            for j in 0..3 {
                let (y1, yt1) = with_parallelism(par, || {
                    (a.matvec(xs.col(j)), a.matvec_t(us.col(j)))
                });
                for (s, t) in y1.iter().zip(ys.col(j)) {
                    assert_eq!(s.to_bits(), t.to_bits(), "matvec col {j}");
                }
                for (s, t) in yt1.iter().zip(yts.col(j)) {
                    assert_eq!(s.to_bits(), t.to_bits(), "matvec_t col {j}");
                }
            }
        }
    }

    #[test]
    fn multi_rhs_bit_stable_across_parallelism() {
        use crate::linalg::MultiVec;
        let mut rng = Rng::seed_from(20);
        let a = rand_mat(&mut rng, 1200, 61);
        let xs = MultiVec::from_fn(61, 2, |_, _| rng.normal());
        let us = MultiVec::from_fn(1200, 2, |_, _| rng.normal());
        let run = |par: Parallelism| {
            with_parallelism(par, || {
                let mut ys = MultiVec::zeros(1200, 2);
                a.matvec_multi_into(&xs, &mut ys);
                let mut yts = MultiVec::zeros(61, 2);
                a.matvec_t_multi_into(&us, &mut yts);
                (ys, yts)
            })
        };
        let serial = run(Parallelism::None);
        for nt in [2usize, 4, 8] {
            let threaded = run(Parallelism::Fixed(nt));
            for (s, t) in serial.0.data().iter().zip(threaded.0.data()) {
                assert_eq!(s.to_bits(), t.to_bits(), "matvec_multi nt={nt}");
            }
            for (s, t) in serial.1.data().iter().zip(threaded.1.data()) {
                assert_eq!(s.to_bits(), t.to_bits(), "matvec_t_multi nt={nt}");
            }
        }
    }

    #[test]
    fn gather_rows_and_cols() {
        let mut rng = Rng::seed_from(21);
        let a = rand_mat(&mut rng, 9, 5);
        let mut out = Mat::zeros(0, 0);
        a.gather_rows_into(&[7, 0, 3], &mut out);
        assert_eq!((out.rows(), out.cols()), (3, 5));
        assert_eq!(out.row(0), a.row(7));
        assert_eq!(out.row(1), a.row(0));
        assert_eq!(out.row(2), a.row(3));
        // gather is reusable: a second gather overwrites the panel
        a.gather_cols_as_rows_into(&[4, 1], &mut out);
        assert_eq!((out.rows(), out.cols()), (2, 9));
        for r in 0..9 {
            assert_eq!(out.get(0, r), a.get(r, 4));
            assert_eq!(out.get(1, r), a.get(r, 1));
        }
    }
}
