//! Cholesky factorization for SPD systems.
//!
//! Used by the SVM Newton solvers (small free-set systems), the L1_LS
//! interior-point preconditioner, and as the exact fallback when CG is
//! not worth the iteration overhead.

use super::dense::Mat;
use std::fmt;

#[derive(Debug)]
pub enum CholeskyError {
    NotPd(usize, f64),
    NotSquare(usize, usize),
}

impl fmt::Display for CholeskyError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            CholeskyError::NotPd(pivot, value) => {
                write!(f, "matrix not positive definite at pivot {pivot} (value {value})")
            }
            CholeskyError::NotSquare(r, c) => write!(f, "matrix not square: {r}x{c}"),
        }
    }
}

impl std::error::Error for CholeskyError {}

/// Lower-triangular Cholesky factor `A = L·Lᵀ`.
#[derive(Clone, Debug)]
pub struct Cholesky {
    l: Mat,
}

impl Cholesky {
    /// Factor an SPD matrix. Returns an error on non-PD input (used by
    /// callers to detect loss of curvature and add ridge).
    pub fn factor(a: &Mat) -> Result<Self, CholeskyError> {
        if a.rows() != a.cols() {
            return Err(CholeskyError::NotSquare(a.rows(), a.cols()));
        }
        let n = a.rows();
        let mut l = Mat::zeros(n, n);
        for j in 0..n {
            // Diagonal.
            let mut d = a.get(j, j);
            for k in 0..j {
                let v = l.get(j, k);
                d -= v * v;
            }
            if d <= 0.0 || !d.is_finite() {
                return Err(CholeskyError::NotPd(j, d));
            }
            let dj = d.sqrt();
            l.set(j, j, dj);
            // Column below the diagonal.
            for i in j + 1..n {
                let mut s = a.get(i, j);
                // dot over the already-computed prefix rows
                let (ri, rj) = (i * n, j * n);
                let ld = l.data();
                let mut acc = 0.0;
                for k in 0..j {
                    acc += ld[ri + k] * ld[rj + k];
                }
                s -= acc;
                l.set(i, j, s / dj);
            }
        }
        Ok(Cholesky { l })
    }

    /// Factor with a ridge retry: adds `ridge` to the diagonal, multiplying
    /// by 10 on failure, up to `max_tries`.
    pub fn factor_ridged(a: &Mat, mut ridge: f64, max_tries: usize) -> Result<Self, CholeskyError> {
        match Self::factor(a) {
            Ok(c) => return Ok(c),
            Err(_) => {}
        }
        for _ in 0..max_tries {
            let mut ar = a.clone();
            for i in 0..a.rows() {
                let v = ar.get(i, i) + ridge;
                ar.set(i, i, v);
            }
            if let Ok(c) = Self::factor(&ar) {
                return Ok(c);
            }
            ridge *= 10.0;
        }
        Err(CholeskyError::NotPd(0, ridge))
    }

    /// Solve `A·x = b` via forward/back substitution.
    pub fn solve(&self, b: &[f64]) -> Vec<f64> {
        let n = self.l.rows();
        assert_eq!(b.len(), n);
        let ld = self.l.data();
        // Forward: L·z = b
        let mut z = vec![0.0; n];
        for i in 0..n {
            let mut s = b[i];
            let row = &ld[i * n..i * n + i];
            for (k, lik) in row.iter().enumerate() {
                s -= lik * z[k];
            }
            z[i] = s / ld[i * n + i];
        }
        // Backward: Lᵀ·x = z
        let mut x = vec![0.0; n];
        for i in (0..n).rev() {
            let mut s = z[i];
            for k in i + 1..n {
                s -= ld[k * n + i] * x[k];
            }
            x[i] = s / ld[i * n + i];
        }
        x
    }

    /// The lower factor.
    pub fn l(&self) -> &Mat {
        &self.l
    }

    /// log(det A) = 2·Σ log L_ii — used by the IPM line search diagnostics.
    pub fn log_det(&self) -> f64 {
        let n = self.l.rows();
        (0..n).map(|i| self.l.get(i, i).ln()).sum::<f64>() * 2.0
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;

    fn random_spd(rng: &mut Rng, n: usize) -> Mat {
        let a = Mat::from_fn(n, n, |_, _| rng.normal());
        let mut g = a.gram(); // AAᵀ ⪰ 0
        for i in 0..n {
            let v = g.get(i, i) + 0.5;
            g.set(i, i, v);
        }
        g
    }

    #[test]
    fn factor_and_solve_roundtrip() {
        let mut rng = Rng::seed_from(21);
        for n in [1usize, 2, 5, 17, 40] {
            let a = random_spd(&mut rng, n);
            let chol = Cholesky::factor(&a).unwrap();
            let x_true: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
            let b = a.matvec(&x_true);
            let x = chol.solve(&b);
            for i in 0..n {
                assert!((x[i] - x_true[i]).abs() < 1e-7, "n={n} i={i}");
            }
        }
    }

    #[test]
    fn reconstructs_matrix() {
        let mut rng = Rng::seed_from(22);
        let a = random_spd(&mut rng, 8);
        let chol = Cholesky::factor(&a).unwrap();
        let l = chol.l();
        let rec = l.matmul(&l.transpose());
        for i in 0..8 {
            for j in 0..8 {
                assert!((rec.get(i, j) - a.get(i, j)).abs() < 1e-9);
            }
        }
    }

    #[test]
    fn rejects_indefinite() {
        let a = Mat::from_vec(2, 2, vec![1.0, 2.0, 2.0, 1.0]); // eigenvalues 3, -1
        assert!(Cholesky::factor(&a).is_err());
    }

    #[test]
    fn ridged_recovers_semidefinite() {
        let a = Mat::from_vec(2, 2, vec![1.0, 1.0, 1.0, 1.0]); // rank 1 PSD
        let c = Cholesky::factor_ridged(&a, 1e-8, 12).unwrap();
        let x = c.solve(&[2.0, 2.0]);
        // ridged solve of a consistent system stays near a solution
        let r0 = x[0] + x[1];
        assert!((r0 - 2.0).abs() < 1e-3);
    }

    #[test]
    fn log_det_identity_is_zero() {
        let c = Cholesky::factor(&Mat::eye(5)).unwrap();
        assert!(c.log_det().abs() < 1e-12);
    }
}
