//! Cache-geometry probe and the blocking parameters derived from it.
//!
//! The blocked GEMM/Gram core used to hard-code its tile constants
//! (`KC = 256`, `MC = 64`, `NC = 512`, gram `BS = 128`, a magic
//! `NAIVE_CUTOFF` flop gate). Those numbers encode one specific cache
//! hierarchy; on a machine with a 48 KB L1d and a 2 MB L2 they leave
//! half the cache idle, and on a smaller one they thrash. This module
//! replaces them with a [`CacheGeometry`] probed once at startup
//! (Linux sysfs, with documented fallbacks) and a [`Blocking`] derived
//! from it per microkernel shape:
//!
//! - `kc` — k-depth such that one packed `kc×nr` B panel occupies about
//!   half of L1d (the panel is streamed `rows/mr` times per block, so it
//!   must stay L1-resident),
//! - `mc` — A-band height such that the packed `mc×kc` A block occupies
//!   about half of L2 (each worker's slab),
//! - `nc` — B-block width such that the packed `kc×nc` block stays
//!   within a modest L3 share,
//! - `bs` — gram block edge such that one packed A tile plus one packed
//!   Aᵀ panel (`2·bs·kc` doubles) stay L2-resident per worker,
//! - `threading_threshold` — multiply-add count below which the scoped
//!   fan-out costs more than it buys (spawn overhead amortizes over
//!   roughly one `mc×kc` band applied to a few panels),
//! - `gemv_threshold` — matrix element count below which the banded
//!   GEMV paths stay serial (banding pays once the matrix spills L2).
//!
//! Everything here is **size-derived, never thread-count-derived**, so
//! the kernels built on these parameters keep the crate-wide contract:
//! for a fixed kernel choice, results are bit-identical at any
//! `Parallelism` setting. Different machines may derive different
//! blockings — that moves *which* decomposition runs, which is exactly
//! why the per-kernel accumulation order (not the blocking) carries the
//! bit-stability contract; see `gemm.rs`.

/// Detected (or fallback) cache sizes in bytes.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct CacheGeometry {
    /// L1 data cache size per core.
    pub l1d_bytes: usize,
    /// L2 cache size (per core or core cluster).
    pub l2_bytes: usize,
    /// Last-level cache size (0 when the machine reports none; the
    /// derivations then fall back to a multiple of L2).
    pub l3_bytes: usize,
    /// Where the numbers came from: `"sysfs"` or `"fallback"`.
    pub source: &'static str,
}

/// Conservative defaults when no probe source is available: a small
/// contemporary x86 core (32 KB L1d, 512 KB L2, 8 MB shared L3). These
/// reproduce the crate's historical constants (`KC = 256`, gram panels
/// ≈ 64 rows) so un-probeable machines behave like the old hard-coded
/// kernel rather than like an arbitrary new one.
const FALLBACK: CacheGeometry = CacheGeometry {
    l1d_bytes: 32 * 1024,
    l2_bytes: 512 * 1024,
    l3_bytes: 8 * 1024 * 1024,
    source: "fallback",
};

impl CacheGeometry {
    /// Probe the machine once. Linux exposes per-cpu cache descriptors
    /// under `/sys/devices/system/cpu/cpu0/cache/index*`; any parse
    /// failure (non-Linux, sandboxed sysfs, exotic topology) degrades to
    /// [`CacheGeometry::fallback`] rather than erroring — geometry only
    /// steers performance, never correctness.
    pub fn detect() -> Self {
        Self::from_sysfs().unwrap_or(FALLBACK)
    }

    /// The documented defaults used when probing fails.
    pub fn fallback() -> Self {
        FALLBACK
    }

    fn from_sysfs() -> Option<Self> {
        let base = "/sys/devices/system/cpu/cpu0/cache";
        let mut l1d = None;
        let mut l2 = None;
        let mut l3 = None;
        for idx in 0..8 {
            let dir = format!("{base}/index{idx}");
            let read = |f: &str| std::fs::read_to_string(format!("{dir}/{f}")).ok();
            let Some(level) = read("level") else { continue };
            let Some(ctype) = read("type") else { continue };
            let Some(size) = read("size").and_then(|s| parse_size(s.trim())) else {
                continue;
            };
            let ctype = ctype.trim();
            match (level.trim(), ctype) {
                ("1", "Data") | ("1", "Unified") => l1d = Some(size),
                ("2", "Data") | ("2", "Unified") => l2 = Some(size),
                ("3", "Data") | ("3", "Unified") => l3 = Some(size),
                _ => {}
            }
        }
        let l1d = l1d?;
        // An L2 is assumed on anything this crate targets; L3 may be
        // genuinely absent (some embedded/VM topologies).
        let l2 = l2?;
        Some(CacheGeometry {
            l1d_bytes: l1d,
            l2_bytes: l2,
            l3_bytes: l3.unwrap_or(0),
            source: "sysfs",
        })
    }

    /// Effective last-level budget: L3 when present, else treat four
    /// L2s' worth as the streaming budget.
    fn llc_bytes(&self) -> usize {
        if self.l3_bytes > 0 {
            self.l3_bytes
        } else {
            self.l2_bytes * 4
        }
    }

    /// Derive the blocking parameters for a microkernel with register
    /// tile `mr × nr`. All clamps keep the parameters inside the range
    /// the packing/driver code is efficient for, whatever the probe
    /// reports.
    pub fn blocking(&self, mr: usize, nr: usize) -> Blocking {
        assert!(mr >= 1 && nr >= 1, "degenerate microkernel tile");
        const F64: usize = std::mem::size_of::<f64>();
        // kc: one kc×nr B panel in about half of L1d.
        let kc = round_down((self.l1d_bytes / 2) / (F64 * nr), 8).clamp(64, 512);
        // mc: packed mc×kc A block in about half of L2 (clamped first so
        // the bound itself rounds to a multiple of mr).
        let mc = round_down(((self.l2_bytes / 2) / (F64 * kc)).clamp(2 * mr, 512), mr);
        // nc: packed kc×nc B block within an eighth of the LLC.
        let nc = round_down(((self.llc_bytes() / 8) / (F64 * kc)).clamp(4 * nr, 4096), nr);
        // bs: apack + bpack (2·bs·kc doubles) within half of L2.
        let bs = round_down(self.l2_bytes / (4 * F64 * kc), 8).clamp(32, 256);
        Blocking {
            mr,
            nr,
            kc,
            mc,
            nc,
            bs,
            threading_threshold: mc * kc * nr,
            gemv_threshold: self.l2_bytes / F64,
            l1d_elems: self.l1d_bytes / F64,
        }
    }

    /// Derive blocking parameters for an **f32** microkernel of tile
    /// `mr × nr`. Same cache-budget formulas as
    /// [`CacheGeometry::blocking`] at half the element size, with every
    /// element-count clamp doubled — so each cache block holds twice
    /// the *elements* at the same *byte* footprint (the whole point of
    /// the mixed-precision tier: double the data per line of memory
    /// traffic). Fields stay in elements, as everywhere else.
    pub fn blocking_f32(&self, mr: usize, nr: usize) -> Blocking {
        assert!(mr >= 1 && nr >= 1, "degenerate microkernel tile");
        const F32: usize = std::mem::size_of::<f32>();
        // kc: one kc×nr B panel in about half of L1d (twice the f64 depth).
        let kc = round_down((self.l1d_bytes / 2) / (F32 * nr), 8).clamp(128, 1024);
        // mc: packed mc×kc A block in about half of L2.
        let mc = round_down(((self.l2_bytes / 2) / (F32 * kc)).clamp(2 * mr, 1024), mr);
        // nc: packed kc×nc B block within an eighth of the LLC.
        let nc = round_down(((self.llc_bytes() / 8) / (F32 * kc)).clamp(4 * nr, 8192), nr);
        // bs: apack + bpack (2·bs·kc floats) within half of L2.
        let bs = round_down(self.l2_bytes / (4 * F32 * kc), 8).clamp(64, 512);
        Blocking {
            mr,
            nr,
            kc,
            mc,
            nc,
            bs,
            threading_threshold: mc * kc * nr,
            gemv_threshold: self.l2_bytes / F32,
            l1d_elems: self.l1d_bytes / F32,
        }
    }
}

/// Parse sysfs cache sizes of the form `48K`, `2048K`, `1M`, `32M`.
fn parse_size(s: &str) -> Option<usize> {
    let (digits, mult) = match s.as_bytes().last()? {
        b'K' | b'k' => (&s[..s.len() - 1], 1024),
        b'M' | b'm' => (&s[..s.len() - 1], 1024 * 1024),
        _ => (s, 1),
    };
    digits.parse::<usize>().ok().map(|n| n * mult).filter(|&n| n > 0)
}

/// Round `v` down to a positive multiple of `m`.
fn round_down(v: usize, m: usize) -> usize {
    ((v / m).max(1)) * m
}

/// Blocking parameters derived from a [`CacheGeometry`] for one
/// microkernel shape. See the module docs for each parameter's
/// derivation; all fields are in *elements* (f64) or multiply-adds,
/// never bytes.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Blocking {
    /// Microkernel register-tile rows.
    pub mr: usize,
    /// Microkernel register-tile columns.
    pub nr: usize,
    /// k-dimension cache block (B panel `kc×nr` ≈ half L1d).
    pub kc: usize,
    /// Rows of A packed per band job (`mc×kc` ≈ half L2).
    pub mc: usize,
    /// Columns of B packed per block (`kc×nc` within an LLC share).
    pub nc: usize,
    /// Gram block edge (`2·bs·kc` packed doubles ≈ half L2 per worker).
    pub bs: usize,
    /// Multiply-add count below which blocked kernels stay serial.
    pub threading_threshold: usize,
    /// Matrix element count below which banded GEMV paths stay serial.
    pub gemv_threshold: usize,
    /// L1d capacity in f64 elements (for the naive-vs-blocked gate).
    pub l1d_elems: usize,
}

impl Blocking {
    /// Should `C = A·B` (`m×k·k×n`) take the packed blocked path?
    ///
    /// This replaces the old fixed `NAIVE_CUTOFF = 1<<15` flop gate with
    /// a shape- and cache-aware one: packing moves `m·k + k·n` elements
    /// to buy `m·k·n` multiply-adds of contiguous streaming, so blocked
    /// wins once each packed element is reused enough times to hide the
    /// copy — fewer when B (`k×n`) has already spilled L1d and the naive
    /// kernel would re-stream it from L2/memory for every output row.
    /// Small-but-wide shapes whose B panel is cache-hot stay naive
    /// (packing can never amortize at `m ≲ mr`); the same shapes on a
    /// B-spilling machine go blocked instead of falling off the fast
    /// path. Size-derived only — identical under every `Parallelism`.
    pub fn prefer_blocked_gemm(&self, m: usize, k: usize, n: usize) -> bool {
        let madds = m.saturating_mul(k).saturating_mul(n);
        let packed = m.saturating_mul(k).saturating_add(k.saturating_mul(n));
        if madds == 0 || packed == 0 {
            return false;
        }
        let amortize = if k.saturating_mul(n) <= self.l1d_elems { 16 } else { 8 };
        madds >= packed.saturating_mul(amortize)
    }

    /// Should `G = A·Aᵀ` (`m×k`) take the blocked symmetric path? Same
    /// gate as GEMM viewed as `m×k·k×m` (packing `2·m·k`, computing
    /// `m²·k` — blocked once `m` clears the reuse bar).
    pub fn prefer_blocked_gram(&self, m: usize, k: usize) -> bool {
        self.prefer_blocked_gemm(m, k, m)
    }

    /// One-line rendering for startup logs / `Service` metrics.
    pub fn describe(&self) -> String {
        format!(
            "mr={} nr={} kc={} mc={} nc={} bs={}",
            self.mr, self.nr, self.kc, self.mc, self.nc, self.bs
        )
    }
}

impl std::fmt::Display for CacheGeometry {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "l1d={}K l2={}K l3={}K ({})",
            self.l1d_bytes / 1024,
            self.l2_bytes / 1024,
            self.l3_bytes / 1024,
            self.source
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_size_forms() {
        assert_eq!(parse_size("48K"), Some(48 * 1024));
        assert_eq!(parse_size("2048K"), Some(2048 * 1024));
        assert_eq!(parse_size("1M"), Some(1024 * 1024));
        assert_eq!(parse_size("512"), Some(512));
        assert_eq!(parse_size(""), None);
        assert_eq!(parse_size("xK"), None);
        assert_eq!(parse_size("0K"), None);
    }

    #[test]
    fn fallback_reproduces_historical_constants() {
        // The old hard-coded kernel assumed a 32K/256K-ish hierarchy;
        // the fallback derivation must land on the same KC the crate
        // shipped with so un-probeable machines keep their behavior.
        let b = CacheGeometry::fallback().blocking(4, 8);
        assert_eq!(b.kc, 256);
        assert!(b.mc >= 2 * 4 && b.mc <= 512);
        assert!(b.nc >= 32 && b.nc <= 4096);
        assert!(b.bs >= 32 && b.bs <= 256);
    }

    #[test]
    fn detect_never_panics_and_is_sane() {
        let g = CacheGeometry::detect();
        assert!(g.l1d_bytes >= 4 * 1024, "implausible L1d: {g}");
        assert!(g.l2_bytes >= g.l1d_bytes, "L2 smaller than L1: {g}");
        for &(mr, nr) in &[(4usize, 8usize), (6, 8), (8, 4)] {
            let b = g.blocking(mr, nr);
            assert!(b.kc >= 64 && b.kc <= 512);
            assert_eq!(b.kc % 8, 0);
            assert!(b.mc % mr == 0 && b.mc >= 2 * mr);
            assert!(b.nc % nr == 0 && b.nc >= 4 * nr);
            assert!(b.threading_threshold > 0);
            assert!(b.gemv_threshold > 0);
        }
    }

    #[test]
    fn f32_blocking_doubles_elements_at_same_byte_footprint() {
        // The pinned f32/f64 relationship: at the same (mr, nr) every
        // byte-budgeted element count doubles — same cache bytes, twice
        // the elements per block.
        for geom in [CacheGeometry::fallback(), CacheGeometry::detect()] {
            for &(mr, nr) in &[(4usize, 8usize), (8, 8)] {
                let b64 = geom.blocking(mr, nr);
                let b32 = geom.blocking_f32(mr, nr);
                // kc doubles unless a clamp intervened; the byte
                // footprint of the B panel (kc·nr·elem_size) never grows.
                assert!(b32.kc * 4 <= b64.kc * 8, "f32 kc panel outgrew the f64 one");
                assert!(b32.kc >= b64.kc, "f32 kc must not shrink in elements");
                // Unclamped thresholds double exactly.
                assert_eq!(b32.gemv_threshold, 2 * b64.gemv_threshold);
                assert_eq!(b32.l1d_elems, 2 * b64.l1d_elems);
                // Derived blocks stay within the driver-friendly ranges.
                assert!(b32.kc >= 128 && b32.kc <= 1024 && b32.kc % 8 == 0);
                assert!(b32.mc % mr == 0 && b32.mc >= 2 * mr);
                assert!(b32.nc % nr == 0 && b32.nc >= 4 * nr);
                assert!(b32.bs >= 64 && b32.bs <= 512);
                assert!(b32.threading_threshold > 0);
            }
            // On the fallback geometry nothing clamps: kc doubles exactly.
            let (b64, b32) =
                (CacheGeometry::fallback().blocking(4, 8), CacheGeometry::fallback().blocking_f32(4, 8));
            assert_eq!(b32.kc, 2 * b64.kc);
        }
    }

    #[test]
    fn blocked_gate_is_shape_aware() {
        let b = CacheGeometry::fallback().blocking(4, 8);
        // Tiny cubes: naive (the old flop gate agreed).
        assert!(!b.prefer_blocked_gemm(8, 8, 8));
        // Big cubes: blocked.
        assert!(b.prefer_blocked_gemm(256, 256, 256));
        // GEMV-shaped (m = 1): packing can never amortize.
        assert!(!b.prefer_blocked_gemm(1, 512, 512));
        // Reuse-poor wide shape: the old gate (1M madds > 2^15) forced
        // it blocked, but packing B (k·n elements) can never amortize
        // over 4 output rows — the derived gate keeps it naive.
        assert!(!b.prefer_blocked_gemm(4, 512, 512));
        // Small-but-wide with B spilling L1d goes blocked at a lower
        // reuse bar than the cache-hot equivalent: at m=12 the spilled
        // variant is blocked while a cache-resident B of the same flop
        // count is not.
        assert!(b.prefer_blocked_gemm(12, 80, 128)); // k·n spills 32K L1d
        assert!(!b.prefer_blocked_gemm(12, 40, 100)); // k·n L1-resident
        // Degenerate dims never go blocked.
        assert!(!b.prefer_blocked_gemm(0, 16, 16));
        assert!(!b.prefer_blocked_gemm(16, 0, 16));
        // Gram gate follows the same reuse logic.
        assert!(b.prefer_blocked_gram(128, 64));
        assert!(!b.prefer_blocked_gram(4, 64));
    }
}
