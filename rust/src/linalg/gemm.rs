//! Cache-blocked, multi-threaded GEMM/Gram kernels — the hot path under
//! every SVEN matrix product.
//!
//! Structure (BLIS-style, sized for L1/L2 without runtime probing):
//!
//! - a 4×8 register-tiled microkernel (`MR`×`NR`) over packed panels,
//! - a packing stage that copies A into MR-row tiles and B into NR-column
//!   panels so the microkernel streams contiguous memory,
//! - `KC`/`MC`/`NC` cache blocking around it,
//! - row-band / block-pair fan-out over the scoped pool in
//!   [`crate::util::parallel`].
//!
//! Determinism: the block decomposition and the per-element accumulation
//! order (k ascending within each `KC` block, blocks ascending) never
//! depend on the worker count, so results are **bit-identical** across
//! `Parallelism` settings — the property `rust/tests/proptests.rs` pins.
//!
//! The naive kernels the seed shipped are kept as `naive_*` references
//! for the equivalence tests and the micro-bench baselines.

use super::vecops;
use crate::util::parallel;

/// Microkernel rows (register tile height).
pub const MR: usize = 4;
/// Microkernel columns (register tile width; 8 f64 = two AVX2 lanes).
pub const NR: usize = 8;
/// k-dimension cache block (A tile `MR·KC` ≈ 8 KB, B panel `KC·NR` ≈ 16 KB).
const KC: usize = 256;
/// Rows of A packed per band job (`MC·KC` ≈ 128 KB, L2-resident).
const MC: usize = 64;
/// Columns of B packed per block (`KC·NC` ≈ 1 MB).
const NC: usize = 512;
/// Gram block edge for the symmetric block-pair decomposition.
const BS: usize = 128;
/// Below this many multiply-adds the naive kernels win (no packing
/// overhead). Size-based only — never thread-count-based — so the
/// kernel choice is identical under every `Parallelism` setting.
const NAIVE_CUTOFF: usize = 1 << 15;

// ---------------------------------------------------------------------------
// Public entry points
// ---------------------------------------------------------------------------

/// `C = A·B` with A `m×k`, B `k×n`, all row-major. Allocates C.
pub fn matmul(a: &[f64], b: &[f64], m: usize, k: usize, n: usize) -> Vec<f64> {
    let mut c = vec![0.0; m * n];
    matmul_into(a, b, &mut c, m, k, n);
    c
}

/// `C ← A·B` into a caller-provided buffer (overwrites C).
pub fn matmul_into(a: &[f64], b: &[f64], c: &mut [f64], m: usize, k: usize, n: usize) {
    assert_eq!(a.len(), m * k, "A shape mismatch");
    assert_eq!(b.len(), k * n, "B shape mismatch");
    assert_eq!(c.len(), m * n, "C shape mismatch");
    if m * k * n <= NAIVE_CUTOFF {
        naive_matmul_into(a, b, c, m, k, n);
        return;
    }
    blocked_matmul_into(a, b, c, m, k, n, parallel::effective_threads());
}

/// `G = A·Aᵀ` (`m×m`) with A `m×k` row-major. Allocates G.
pub fn gram(a: &[f64], m: usize, k: usize) -> Vec<f64> {
    let mut g = vec![0.0; m * m];
    gram_into(a, &mut g, m, k);
    g
}

/// `G ← A·Aᵀ` into a caller-provided buffer (overwrites G).
pub fn gram_into(a: &[f64], g: &mut [f64], m: usize, k: usize) {
    assert_eq!(a.len(), m * k, "A shape mismatch");
    assert_eq!(g.len(), m * m, "G shape mismatch");
    if m * m * k <= NAIVE_CUTOFF {
        naive_gram_into(a, g, m, k);
        return;
    }
    blocked_gram_into(a, g, m, k, parallel::effective_threads());
}

// ---------------------------------------------------------------------------
// Naive reference kernels (the seed's loops; serial)
// ---------------------------------------------------------------------------

/// The seed's ikj/axpy GEMM, kept as the correctness reference and the
/// micro-bench baseline. Serial; overwrites C.
pub fn naive_matmul_into(a: &[f64], b: &[f64], c: &mut [f64], m: usize, k: usize, n: usize) {
    c.fill(0.0);
    for i in 0..m {
        let crow = &mut c[i * n..(i + 1) * n];
        for kk in 0..k {
            let aik = a[i * k + kk];
            if aik == 0.0 {
                continue;
            }
            vecops::axpy(aik, &b[kk * n..(kk + 1) * n], crow);
        }
    }
}

/// The seed's dot-product symmetric Gram, kept as reference/baseline.
/// Serial; overwrites G.
pub fn naive_gram_into(a: &[f64], g: &mut [f64], m: usize, k: usize) {
    for i in 0..m {
        for j in i..m {
            let v = vecops::dot(&a[i * k..(i + 1) * k], &a[j * k..(j + 1) * k]);
            g[i * m + j] = v;
            g[j * m + i] = v;
        }
    }
}

// ---------------------------------------------------------------------------
// Packing
// ---------------------------------------------------------------------------

/// Pack `rows` rows of A (starting at `row0`, k-slice `[k0, k0+kc)`) into
/// MR-row tiles: `out[t·kc·MR + kk·MR + i] = A[row0+t·MR+i, k0+kk]`,
/// zero-padded when the last tile is short of MR rows.
fn pack_a(a: &[f64], lda: usize, row0: usize, rows: usize, k0: usize, kc: usize, out: &mut [f64]) {
    let tiles = rows.div_ceil(MR);
    for t in 0..tiles {
        let tile = &mut out[t * kc * MR..(t + 1) * kc * MR];
        for i in 0..MR {
            let r = t * MR + i;
            if r < rows {
                let base = (row0 + r) * lda + k0;
                let src = &a[base..base + kc];
                for (kk, &v) in src.iter().enumerate() {
                    tile[kk * MR + i] = v;
                }
            } else {
                for kk in 0..kc {
                    tile[kk * MR + i] = 0.0;
                }
            }
        }
    }
}

/// Pack one NR-column panel of B (k-slice `[k0, k0+kc)`, columns
/// `[col0, col0+w)`, `w ≤ NR`): `panel[kk·NR + j] = B[k0+kk, col0+j]`,
/// zero-padded beyond `w`.
fn pack_b_panel(
    b: &[f64],
    ldb: usize,
    k0: usize,
    kc: usize,
    col0: usize,
    w: usize,
    panel: &mut [f64],
) {
    for kk in 0..kc {
        let base = (k0 + kk) * ldb + col0;
        let dst = &mut panel[kk * NR..(kk + 1) * NR];
        dst[..w].copy_from_slice(&b[base..base + w]);
        for v in dst[w..].iter_mut() {
            *v = 0.0;
        }
    }
}

/// Pack one NR-column panel of Aᵀ for the Gram kernel: the panel's
/// columns are A's *rows* `[row0, row0+w)`, so the read is contiguous
/// per row: `panel[kk·NR + j] = A[row0+j, k0+kk]`.
fn pack_bt_panel(
    a: &[f64],
    lda: usize,
    k0: usize,
    kc: usize,
    row0: usize,
    w: usize,
    panel: &mut [f64],
) {
    for j in 0..NR {
        if j < w {
            let base = (row0 + j) * lda + k0;
            let src = &a[base..base + kc];
            for (kk, &v) in src.iter().enumerate() {
                panel[kk * NR + j] = v;
            }
        } else {
            for kk in 0..kc {
                panel[kk * NR + j] = 0.0;
            }
        }
    }
}

// ---------------------------------------------------------------------------
// Microkernel and block driver
// ---------------------------------------------------------------------------

/// `acc += Ap·Bp` over one packed tile/panel pair; `acc` stays in
/// registers (MR×NR accumulators, k innermost with contiguous loads).
#[inline(always)]
fn microkernel(apanel: &[f64], bpanel: &[f64], acc: &mut [[f64; NR]; MR]) {
    for (ak, bk) in apanel.chunks_exact(MR).zip(bpanel.chunks_exact(NR)) {
        // Fixed-size views let LLVM drop the bounds checks and keep the
        // MR×NR accumulator fan-out fully unrolled.
        let ak: &[f64; MR] = ak.try_into().expect("tile width");
        let bk: &[f64; NR] = bk.try_into().expect("panel width");
        for i in 0..MR {
            let aik = ak[i];
            for j in 0..NR {
                acc[i][j] += aik * bk[j];
            }
        }
    }
}

/// `C[c_row0.., c_col0..] += Apack·Bpack` for one packed (rows × cols)
/// block; edge tiles are computed full-width and written back masked.
fn block_kernel(
    apack: &[f64],
    bpack: &[f64],
    kc: usize,
    rows: usize,
    cols: usize,
    c: &mut [f64],
    ldc: usize,
    c_row0: usize,
    c_col0: usize,
) {
    let tiles = rows.div_ceil(MR);
    let panels = cols.div_ceil(NR);
    for t in 0..tiles {
        let ap = &apack[t * kc * MR..(t + 1) * kc * MR];
        let mrows = MR.min(rows - t * MR);
        for p in 0..panels {
            let bp = &bpack[p * kc * NR..(p + 1) * kc * NR];
            let ncols = NR.min(cols - p * NR);
            let mut acc = [[0.0f64; NR]; MR];
            microkernel(ap, bp, &mut acc);
            for i in 0..mrows {
                let base = (c_row0 + t * MR + i) * ldc + c_col0 + p * NR;
                let crow = &mut c[base..base + ncols];
                for (j, cv) in crow.iter_mut().enumerate() {
                    *cv += acc[i][j];
                }
            }
        }
    }
}

/// Blocked parallel GEMM (exposed for tests/benches that want to bypass
/// the small-size cutoff). Overwrites C.
pub fn blocked_matmul_into(
    a: &[f64],
    b: &[f64],
    c: &mut [f64],
    m: usize,
    k: usize,
    n: usize,
    nt: usize,
) {
    c.fill(0.0);
    let mut bpack = vec![0.0; NC.div_ceil(NR) * NR * KC];
    for jc in (0..n).step_by(NC) {
        let jn = NC.min(n - jc);
        let jpanels = jn.div_ceil(NR);
        for kb in (0..k).step_by(KC) {
            let kc = KC.min(k - kb);
            // Pack this (kc × jn) block of B on the calling thread: it is
            // a ≤ 1 MB memory-bound copy, cheaper than a spawn round.
            let packed_len = jpanels * kc * NR;
            for (p, panel) in bpack[..packed_len].chunks_mut(kc * NR).enumerate() {
                let c0 = p * NR;
                pack_b_panel(b, n, kb, kc, jc + c0, NR.min(jn - c0), panel);
            }
            // MC-row bands of C in parallel; each band packs its own A.
            let bp = &bpack[..packed_len];
            let bands: Vec<&mut [f64]> = c.chunks_mut(MC * n).collect();
            parallel::parallel_items(nt, bands, |bi, cband| {
                let row0 = bi * MC;
                let rows = cband.len() / n;
                let mut apack = vec![0.0; rows.div_ceil(MR) * MR * kc];
                pack_a(a, k, row0, rows, kb, kc, &mut apack);
                block_kernel(&apack, bp, kc, rows, jn, cband, n, 0, jc);
            });
        }
    }
}

/// One upper-triangle block `A[i0..i0+ri]·A[j0..j0+rj]ᵀ` of the Gram
/// matrix, fully packed and k-blocked, written **straight into** the
/// destination `c` (leading dimension `ldc`, rows relative to `c`'s
/// first row, columns at offset `c_col0`) — no transient block buffer.
fn gram_block(
    a: &[f64],
    k: usize,
    i0: usize,
    ri: usize,
    j0: usize,
    rj: usize,
    c: &mut [f64],
    ldc: usize,
    c_col0: usize,
) {
    for r in 0..ri {
        let base = r * ldc + c_col0;
        c[base..base + rj].fill(0.0);
    }
    let mut apack = vec![0.0; ri.div_ceil(MR) * MR * KC];
    let mut bpack = vec![0.0; rj.div_ceil(NR) * NR * KC];
    let panels = rj.div_ceil(NR);
    for kb in (0..k).step_by(KC) {
        let kc = KC.min(k - kb);
        pack_a(a, k, i0, ri, kb, kc, &mut apack[..ri.div_ceil(MR) * MR * kc]);
        for p in 0..panels {
            let c0 = p * NR;
            pack_bt_panel(
                a,
                k,
                kb,
                kc,
                j0 + c0,
                NR.min(rj - c0),
                &mut bpack[p * kc * NR..(p + 1) * kc * NR],
            );
        }
        block_kernel(
            &apack[..ri.div_ceil(MR) * MR * kc],
            &bpack[..panels * kc * NR],
            kc,
            ri,
            rj,
            c,
            ldc,
            0,
            c_col0,
        );
    }
}

/// Blocked parallel symmetric Gram (exposed for tests/benches). Computes
/// only upper-triangle blocks, written **in place** into their BS-row
/// destination bands (each band owns its blocks `(bi, bj ≥ bi)`, so the
/// parallel writes are disjoint), then mirrors the strict upper triangle
/// into the lower one in band-sequential waves: bands are finalized
/// top-down, each new band reading the already-final bands above it
/// through a shrinking `split_at_mut` frontier while its own rows fan
/// out over the pool. Peak transient memory is one packed A tile + one
/// packed Aᵀ panel per worker (≈ `BS·KC` doubles each) instead of the
/// ~m²/2 staged block buffers of the old scatter/mirror scheme — the
/// difference is pinned by `rust/tests/gram_peak_alloc.rs`. Overwrites G
/// with bits identical to the staged scheme (same per-block accumulation
/// order, same mirrored copies), at any thread count.
pub fn blocked_gram_into(a: &[f64], g: &mut [f64], m: usize, k: usize, nt: usize) {
    let nb = m.div_ceil(BS);
    let edge = |b: usize| BS.min(m - b * BS);
    // Phase 1: upper-triangle blocks, straight into their row bands.
    let bands: Vec<&mut [f64]> = g.chunks_mut(BS * m).collect();
    parallel::parallel_items(nt, bands, |bi, gband| {
        let ri = edge(bi);
        for bj in bi..nb {
            gram_block(a, k, bi * BS, ri, bj * BS, edge(bj), gband, m, bj * BS);
        }
    });
    // Phase 2: mirror waves. Band bi's lower-triangle columns are the
    // transposes of blocks living in bands < bi, all final by the time
    // the frontier reaches bi.
    let mut done: Vec<&[f64]> = Vec::with_capacity(nb);
    let mut tail: &mut [f64] = g;
    for bi in 0..nb {
        let band_len = edge(bi) * m;
        let (band, rest) = {
            let t = std::mem::take(&mut tail);
            t.split_at_mut(band_len)
        };
        if bi > 0 {
            let done_ref: &[&[f64]] = &done;
            let rows: Vec<&mut [f64]> = band.chunks_mut(m).collect();
            parallel::parallel_items(nt, rows, |r, grow| {
                let gi = bi * BS + r;
                for (bj, src_band) in done_ref.iter().enumerate() {
                    let rj = edge(bj);
                    for c in 0..rj {
                        grow[bj * BS + c] = src_band[c * m + gi];
                    }
                }
            });
        }
        done.push(band);
        tail = rest;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;

    fn rand_vec(rng: &mut Rng, len: usize) -> Vec<f64> {
        (0..len).map(|_| rng.normal()).collect()
    }

    fn max_abs_diff(a: &[f64], b: &[f64]) -> f64 {
        a.iter().zip(b).map(|(x, y)| (x - y).abs()).fold(0.0, f64::max)
    }

    #[test]
    fn blocked_matches_naive_ragged_shapes() {
        let mut rng = Rng::seed_from(21);
        // Deliberately not multiples of MR/NR/KC/MC/NC.
        for &(m, k, n) in &[(1, 1, 1), (5, 3, 9), (33, 17, 41), (70, 130, 51), (64, 256, 64)] {
            let a = rand_vec(&mut rng, m * k);
            let b = rand_vec(&mut rng, k * n);
            let mut naive = vec![0.0; m * n];
            naive_matmul_into(&a, &b, &mut naive, m, k, n);
            for nt in [1, 3, 8] {
                let mut blocked = vec![0.0; m * n];
                blocked_matmul_into(&a, &b, &mut blocked, m, k, n, nt);
                let dev = max_abs_diff(&naive, &blocked);
                assert!(dev < 1e-10, "({m},{k},{n}) nt={nt}: dev {dev}");
            }
        }
    }

    #[test]
    fn blocked_gram_matches_naive_ragged_shapes() {
        let mut rng = Rng::seed_from(22);
        for &(m, k) in &[(1, 4), (7, 5), (40, 33), (130, 70), (129, 257)] {
            let a = rand_vec(&mut rng, m * k);
            let mut naive = vec![0.0; m * m];
            naive_gram_into(&a, &mut naive, m, k);
            for nt in [1, 4] {
                let mut blocked = vec![0.0; m * m];
                blocked_gram_into(&a, &mut blocked, m, k, nt);
                let dev = max_abs_diff(&naive, &blocked);
                assert!(dev < 1e-10, "({m},{k}) nt={nt}: dev {dev}");
            }
        }
    }

    #[test]
    fn blocked_is_bit_stable_across_thread_counts() {
        let mut rng = Rng::seed_from(23);
        let (m, k, n) = (67, 310, 45);
        let a = rand_vec(&mut rng, m * k);
        let b = rand_vec(&mut rng, k * n);
        let mut c1 = vec![0.0; m * n];
        blocked_matmul_into(&a, &b, &mut c1, m, k, n, 1);
        for nt in [2, 5, 16] {
            let mut cn = vec![0.0; m * n];
            blocked_matmul_into(&a, &b, &mut cn, m, k, n, nt);
            assert!(
                c1.iter().zip(&cn).all(|(x, y)| x.to_bits() == y.to_bits()),
                "gemm not bit-stable at nt={nt}"
            );
        }
        let mut g1 = vec![0.0; m * m];
        blocked_gram_into(&a, &mut g1, m, k, 1);
        for nt in [2, 7] {
            let mut gn = vec![0.0; m * m];
            blocked_gram_into(&a, &mut gn, m, k, nt);
            assert!(
                g1.iter().zip(&gn).all(|(x, y)| x.to_bits() == y.to_bits()),
                "gram not bit-stable at nt={nt}"
            );
        }
    }

    #[test]
    fn gram_is_symmetric() {
        let mut rng = Rng::seed_from(24);
        let (m, k) = (90, 40);
        let a = rand_vec(&mut rng, m * k);
        let mut g = vec![0.0; m * m];
        blocked_gram_into(&a, &mut g, m, k, 4);
        for i in 0..m {
            for j in 0..m {
                assert_eq!(g[i * m + j].to_bits(), g[j * m + i].to_bits(), "({i},{j})");
            }
        }
    }

    #[test]
    fn public_entry_points_route_both_paths() {
        let mut rng = Rng::seed_from(25);
        // Small: naive path. Large: blocked path. Both must agree with
        // an explicit naive run.
        for &(m, k, n) in &[(6, 4, 5), (48, 64, 48)] {
            let a = rand_vec(&mut rng, m * k);
            let b = rand_vec(&mut rng, k * n);
            let c = matmul(&a, &b, m, k, n);
            let mut reference = vec![0.0; m * n];
            naive_matmul_into(&a, &b, &mut reference, m, k, n);
            assert!(max_abs_diff(&c, &reference) < 1e-10, "({m},{k},{n})");
        }
        for &(m, k) in &[(6, 4), (72, 40)] {
            let a = rand_vec(&mut rng, m * k);
            let g = gram(&a, m, k);
            let mut reference = vec![0.0; m * m];
            naive_gram_into(&a, &mut reference, m, k);
            assert!(max_abs_diff(&g, &reference) < 1e-10, "({m},{k})");
        }
    }
}
