//! The blocked GEMM/Gram core behind [`KernelCtx`] — the hot path under
//! every SVEN matrix product.
//!
//! Structure (BLIS-style):
//!
//! - a [`MicroKernel`] register tile (scalar / AVX2 / FMA, dispatched
//!   once at startup — see [`crate::linalg::kernel`]) over packed panels,
//! - a packing stage that copies A into mr-row tiles and B into
//!   nr-column panels so the microkernel streams contiguous memory,
//! - `kc`/`mc`/`nc` cache blocking derived from the probed
//!   [`CacheGeometry`] instead of hard-coded constants,
//! - row-band / block-pair fan-out over the scoped pool in
//!   [`crate::util::parallel`].
//!
//! All of it hangs off a [`KernelCtx`]: kernel choice + cache geometry +
//! derived [`Blocking`]. Callers never pick tile sizes or thread counts
//! per call — they resolve a ctx ([`KernelCtx::current`] for the
//! ambient one, [`KernelCtx::for_choice`] to force a kernel) and call
//! its methods; `Mat::matmul`/`Mat::gram`, the multi-RHS panel kernels,
//! blocked-CG panel products, and dual `K(t)` assembly all route
//! through here.
//!
//! Determinism: for a **fixed kernel choice**, the block decomposition
//! and the per-element accumulation order (k ascending within each `kc`
//! block, blocks ascending) never depend on the worker count, so
//! results are bit-identical across `Parallelism` settings — the
//! property `rust/tests/proptests.rs` pins per kernel. Different
//! kernels round differently (FMA fuses) and may differ from each
//! other, which is exactly why forcing one is first-class:
//! [`with_kernel_choice`] scopes a choice, [`set_global_kernel`] /
//! `PALLAS_KERNEL` set the process default.
//!
//! The naive kernels the seed shipped are kept as `pub(crate)`
//! references for the equivalence tests and micro-bench baselines.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::OnceLock;

use super::cache::{Blocking, CacheGeometry};
use super::kernel::{self, KernelChoice, KernelError, MicroKernel, MicroKernelF32};
use super::lowp::vecops_f32;
use super::vecops;
use crate::util::parallel;

// ---------------------------------------------------------------------------
// KernelCtx: dispatch + geometry handle
// ---------------------------------------------------------------------------

/// The compute context every blocked product routes through: one
/// dispatched [`MicroKernel`] plus the [`Blocking`] derived for its tile
/// shape from the probed [`CacheGeometry`].
///
/// Resolve one with [`KernelCtx::current`] (ambient choice: scoped
/// override → process global → `PALLAS_KERNEL` → best detected) or
/// [`KernelCtx::for_choice`] (explicit, fallible). Contexts are cached
/// `'static` singletons per kernel choice — copying the handle is free
/// and two resolutions of the same choice see identical geometry.
#[derive(Clone, Copy)]
pub struct KernelCtx {
    kernel: &'static dyn MicroKernel,
    /// The same tier's f32 twin (availability mirrors the f64 kernel
    /// exactly), with its own cache blocking — twice the elements per
    /// block at the same byte footprint.
    kernel_f32: &'static dyn MicroKernelF32,
    choice: KernelChoice,
    geom: CacheGeometry,
    blk: Blocking,
    blk_f32: Blocking,
}

static SCALAR_CTX: OnceLock<KernelCtx> = OnceLock::new();
static AVX2_CTX: OnceLock<KernelCtx> = OnceLock::new();
static FMA_CTX: OnceLock<KernelCtx> = OnceLock::new();

/// Process-wide forced choice: 0 = none (env/auto), else encoded.
static GLOBAL_KERNEL: AtomicUsize = AtomicUsize::new(0);

thread_local! {
    /// Per-thread override installed by [`with_kernel_choice`]; takes
    /// precedence over the global setting on the installing thread.
    static KERNEL_OVERRIDE: std::cell::Cell<usize> = const { std::cell::Cell::new(0) };
}

fn encode_choice(c: KernelChoice) -> usize {
    match c {
        KernelChoice::Auto => 0,
        KernelChoice::Scalar => 1,
        KernelChoice::Avx2 => 2,
        KernelChoice::Fma => 3,
    }
}

fn decode_choice(e: usize) -> KernelChoice {
    match e {
        1 => KernelChoice::Scalar,
        2 => KernelChoice::Avx2,
        3 => KernelChoice::Fma,
        _ => KernelChoice::Auto,
    }
}

/// What `Auto` means for this process: `PALLAS_KERNEL` when set (an
/// unsupported or unparsable value is a hard error, not a silent
/// fallback), else the best detected kernel. Cached after first look.
fn env_kernel_choice() -> Result<KernelChoice, KernelError> {
    static CHOICE: OnceLock<Result<KernelChoice, KernelError>> = OnceLock::new();
    CHOICE
        .get_or_init(|| match std::env::var("PALLAS_KERNEL") {
            Ok(s) if !s.trim().is_empty() => match KernelChoice::parse(&s)? {
                KernelChoice::Auto => Ok(kernel::best_available()),
                forced => {
                    kernel::kernel_for(forced)?;
                    Ok(forced)
                }
            },
            _ => Ok(kernel::best_available()),
        })
        .clone()
}

impl KernelCtx {
    /// The context for an explicit kernel choice. `Auto` resolves via
    /// `PALLAS_KERNEL` / CPU detection; a forced kernel this CPU or
    /// build cannot run is a clear [`KernelError`] — `SvenConfig` and
    /// `ServiceConfig` validation surface it before any solve runs.
    pub fn for_choice(choice: KernelChoice) -> Result<&'static KernelCtx, KernelError> {
        let resolved = match choice {
            KernelChoice::Auto => env_kernel_choice()?,
            c => c,
        };
        let kernel = kernel::kernel_for(resolved)?;
        let slot = match resolved {
            KernelChoice::Scalar => &SCALAR_CTX,
            KernelChoice::Avx2 => &AVX2_CTX,
            KernelChoice::Fma => &FMA_CTX,
            KernelChoice::Auto => unreachable!("Auto resolved above"),
        };
        let kernel_f32 =
            kernel::kernel_f32_for(resolved).expect("f32 tier mirrors f64 availability");
        Ok(slot.get_or_init(|| {
            let geom = CacheGeometry::detect();
            let blk = geom.blocking(kernel.mr(), kernel.nr());
            let blk_f32 = geom.blocking_f32(kernel_f32.mr(), kernel_f32.nr());
            KernelCtx { kernel, kernel_f32, choice: resolved, geom, blk, blk_f32 }
        }))
    }

    /// The ambient context: the [`with_kernel_choice`] override on this
    /// thread, else the [`set_global_kernel`] process setting, else
    /// `Auto` (`PALLAS_KERNEL` / best detected).
    ///
    /// # Panics
    ///
    /// If `PALLAS_KERNEL` names an unknown or unsupported kernel (the
    /// scoped/global setters validate before installing, so only the
    /// env path can reach the panic). Long-running services validate
    /// eagerly via [`KernelCtx::for_choice`] at config time instead.
    pub fn current() -> &'static KernelCtx {
        let enc = KERNEL_OVERRIDE.with(|c| c.get());
        let enc = if enc != 0 { enc } else { GLOBAL_KERNEL.load(Ordering::Relaxed) };
        match Self::for_choice(decode_choice(enc)) {
            Ok(ctx) => ctx,
            Err(e) => panic!("{e} (fix PALLAS_KERNEL: scalar | avx2 | fma | auto)"),
        }
    }

    /// The choice this context resolved to (never `Auto`).
    pub fn choice(&self) -> KernelChoice {
        self.choice
    }

    /// Dispatched kernel name (`"scalar"`, `"avx2"`, `"fma"`).
    pub fn kernel_name(&self) -> &'static str {
        self.kernel.name()
    }

    /// The probed (or fallback) cache sizes behind this context.
    pub fn geometry(&self) -> &CacheGeometry {
        &self.geom
    }

    /// The blocking parameters derived for this kernel's tile shape.
    pub fn blocking(&self) -> &Blocking {
        &self.blk
    }

    /// The blocking parameters derived for the f32 twin's tile shape
    /// (element-count budgets doubled at the same cache-byte footprint).
    pub fn blocking_f32(&self) -> &Blocking {
        &self.blk_f32
    }

    /// The dispatched microkernel itself — tile-level access for the
    /// bit-identity proptests and the `kernel_micro` roofline bench.
    pub(crate) fn micro(&self) -> &'static dyn MicroKernel {
        self.kernel
    }

    /// The dispatched f32 microkernel (the mixed-precision compute
    /// tier's tile), for the proptests and `precision_micro` bench.
    pub(crate) fn micro_f32(&self) -> &'static dyn MicroKernelF32 {
        self.kernel_f32
    }

    /// One-line summary for startup logs / `Service` metrics.
    pub fn describe(&self) -> String {
        format!(
            "kernel={}({}x{}) cache[{}] {} f32[{}({}x{}) kc={}]",
            self.kernel.name(),
            self.blk.mr,
            self.blk.nr,
            self.geom,
            self.blk.describe(),
            self.kernel_f32.name(),
            self.blk_f32.mr,
            self.blk_f32.nr,
            self.blk_f32.kc,
        )
    }

    // -- products ----------------------------------------------------------

    /// `C = A·B` with A `m×k`, B `k×n`, all row-major. Allocates C.
    pub fn matmul(&self, a: &[f64], b: &[f64], m: usize, k: usize, n: usize) -> Vec<f64> {
        let mut c = vec![0.0; m * n];
        self.matmul_into(a, b, &mut c, m, k, n);
        c
    }

    /// `C ← A·B` into a caller-provided buffer (overwrites C). Picks
    /// naive vs blocked by the cache-aware reuse gate and serial vs
    /// threaded by the derived threading threshold — both size-based
    /// only, so the path taken (and hence the bits produced) is
    /// identical under every `Parallelism` setting.
    pub fn matmul_into(
        &self,
        a: &[f64],
        b: &[f64],
        c: &mut [f64],
        m: usize,
        k: usize,
        n: usize,
    ) {
        assert_eq!(a.len(), m * k, "A shape mismatch");
        assert_eq!(b.len(), k * n, "B shape mismatch");
        assert_eq!(c.len(), m * n, "C shape mismatch");
        if !self.blk.prefer_blocked_gemm(m, k, n) {
            naive_matmul_into(a, b, c, m, k, n);
            return;
        }
        let madds = m.saturating_mul(k).saturating_mul(n);
        let nt = if madds < self.blk.threading_threshold {
            1
        } else {
            parallel::effective_threads()
        };
        self.blocked_matmul_into(a, b, c, m, k, n, nt);
    }

    /// `G = A·Aᵀ` (`m×m`) with A `m×k` row-major. Allocates G.
    pub fn gram(&self, a: &[f64], m: usize, k: usize) -> Vec<f64> {
        let mut g = vec![0.0; m * m];
        self.gram_into(a, &mut g, m, k);
        g
    }

    /// `G ← A·Aᵀ` into a caller-provided buffer (overwrites G). Same
    /// size-based path selection as [`KernelCtx::matmul_into`].
    pub fn gram_into(&self, a: &[f64], g: &mut [f64], m: usize, k: usize) {
        assert_eq!(a.len(), m * k, "A shape mismatch");
        assert_eq!(g.len(), m * m, "G shape mismatch");
        if !self.blk.prefer_blocked_gram(m, k) {
            naive_gram_into(a, g, m, k);
            return;
        }
        let madds = m.saturating_mul(m).saturating_mul(k);
        let nt = if madds < self.blk.threading_threshold {
            1
        } else {
            parallel::effective_threads()
        };
        self.blocked_gram_into(a, g, m, k, nt);
    }

    /// Blocked parallel GEMM with an explicit worker count (exposed for
    /// tests/benches that want to bypass the size gates). Overwrites C.
    pub fn blocked_matmul_into(
        &self,
        a: &[f64],
        b: &[f64],
        c: &mut [f64],
        m: usize,
        k: usize,
        n: usize,
        nt: usize,
    ) {
        assert_eq!(a.len(), m * k, "A shape mismatch");
        assert_eq!(b.len(), k * n, "B shape mismatch");
        assert_eq!(c.len(), m * n, "C shape mismatch");
        c.fill(0.0);
        let Blocking { mr, nr, kc: kcb, mc, nc, .. } = self.blk;
        let kern = self.kernel;
        let mut bpack = vec![0.0; nc * kcb];
        for jc in (0..n).step_by(nc) {
            let jn = nc.min(n - jc);
            let jpanels = jn.div_ceil(nr);
            for kb in (0..k).step_by(kcb) {
                let kc = kcb.min(k - kb);
                // Pack this (kc × jn) block of B on the calling thread:
                // it is a memory-bound copy sized to an LLC share,
                // cheaper than a spawn round.
                let packed_len = jpanels * kc * nr;
                for (p, panel) in bpack[..packed_len].chunks_mut(kc * nr).enumerate() {
                    let c0 = p * nr;
                    pack_b_panel(b, n, kb, kc, jc + c0, nr.min(jn - c0), nr, panel);
                }
                // mc-row bands of C in parallel; each band packs its own A.
                let bp = &bpack[..packed_len];
                let bands: Vec<&mut [f64]> = c.chunks_mut(mc * n).collect();
                parallel::parallel_items(nt, bands, |bi, cband| {
                    let row0 = bi * mc;
                    let rows = cband.len() / n;
                    let mut apack = vec![0.0; rows.div_ceil(mr) * mr * kc];
                    pack_a(a, k, row0, rows, kb, kc, mr, &mut apack);
                    block_kernel(kern, &apack, bp, kc, rows, jn, cband, n, 0, jc);
                });
            }
        }
    }

    /// Blocked parallel symmetric Gram with an explicit worker count
    /// (exposed for tests/benches). Computes only upper-triangle blocks,
    /// written **in place** into their `bs`-row destination bands (each
    /// band owns its blocks `(bi, bj ≥ bi)`, so the parallel writes are
    /// disjoint), then mirrors the strict upper triangle into the lower
    /// one in band-sequential waves: bands are finalized top-down, each
    /// new band reading the already-final bands above it through a
    /// shrinking `split_at_mut` frontier while its own rows fan out over
    /// the pool. Peak transient memory is one packed A tile + one packed
    /// Aᵀ panel per worker (≈ `bs·kc` doubles each) instead of ~m²/2
    /// staged block buffers — pinned by `rust/tests/gram_peak_alloc.rs`.
    /// Overwrites G with the same bits at any thread count.
    pub fn blocked_gram_into(&self, a: &[f64], g: &mut [f64], m: usize, k: usize, nt: usize) {
        assert_eq!(a.len(), m * k, "A shape mismatch");
        assert_eq!(g.len(), m * m, "G shape mismatch");
        let bs = self.blk.bs;
        let nb = m.div_ceil(bs);
        let edge = |b: usize| bs.min(m - b * bs);
        // Phase 1: upper-triangle blocks, straight into their row bands.
        let bands: Vec<&mut [f64]> = g.chunks_mut(bs * m).collect();
        parallel::parallel_items(nt, bands, |bi, gband| {
            let ri = edge(bi);
            for bj in bi..nb {
                gram_block(
                    self.kernel,
                    &self.blk,
                    a,
                    k,
                    bi * bs,
                    ri,
                    bj * bs,
                    edge(bj),
                    gband,
                    m,
                    bj * bs,
                );
            }
        });
        // Phase 2: mirror waves. Band bi's lower-triangle columns are
        // the transposes of blocks living in bands < bi, all final by
        // the time the frontier reaches bi.
        let mut done: Vec<&[f64]> = Vec::with_capacity(nb);
        let mut tail: &mut [f64] = g;
        for bi in 0..nb {
            let band_len = edge(bi) * m;
            let (band, rest) = {
                let t = std::mem::take(&mut tail);
                t.split_at_mut(band_len)
            };
            if bi > 0 {
                let done_ref: &[&[f64]] = &done;
                let rows: Vec<&mut [f64]> = band.chunks_mut(m).collect();
                parallel::parallel_items(nt, rows, |r, grow| {
                    let gi = bi * bs + r;
                    for (bj, src_band) in done_ref.iter().enumerate() {
                        let rj = edge(bj);
                        for c in 0..rj {
                            grow[bj * bs + c] = src_band[c * m + gi];
                        }
                    }
                });
            }
            done.push(band);
            tail = rest;
        }
    }

    // -- f32 products (the mixed-precision compute tier) -------------------

    /// `C ← A·B` in **f32** (overwrites C). Same size-based naive/blocked
    /// and serial/threaded selection as [`KernelCtx::matmul_into`], using
    /// the f32 blocking's thresholds.
    pub fn matmul_f32_into(
        &self,
        a: &[f32],
        b: &[f32],
        c: &mut [f32],
        m: usize,
        k: usize,
        n: usize,
    ) {
        assert_eq!(a.len(), m * k, "A shape mismatch");
        assert_eq!(b.len(), k * n, "B shape mismatch");
        assert_eq!(c.len(), m * n, "C shape mismatch");
        if !self.blk_f32.prefer_blocked_gemm(m, k, n) {
            naive_matmul_f32_into(a, b, c, m, k, n);
            return;
        }
        let madds = m.saturating_mul(k).saturating_mul(n);
        let nt = if madds < self.blk_f32.threading_threshold {
            1
        } else {
            parallel::effective_threads()
        };
        self.blocked_matmul_f32_into(a, b, c, m, k, n, nt);
    }

    /// `G ← A·Aᵀ` in **f32** (overwrites G). Same size-based selection
    /// as [`KernelCtx::gram_into`] over the f32 blocking.
    pub fn gram_f32_into(&self, a: &[f32], g: &mut [f32], m: usize, k: usize) {
        assert_eq!(a.len(), m * k, "A shape mismatch");
        assert_eq!(g.len(), m * m, "G shape mismatch");
        if !self.blk_f32.prefer_blocked_gram(m, k) {
            naive_gram_f32_into(a, g, m, k);
            return;
        }
        let madds = m.saturating_mul(m).saturating_mul(k);
        let nt = if madds < self.blk_f32.threading_threshold {
            1
        } else {
            parallel::effective_threads()
        };
        self.blocked_gram_f32_into(a, g, m, k, nt);
    }

    /// Blocked parallel f32 GEMM with an explicit worker count — the
    /// single-precision twin of [`KernelCtx::blocked_matmul_into`]:
    /// same packing stage (generic over the element), same block walk,
    /// driven by the f32 microkernel over the f32 blocking. Bit-stable
    /// across thread counts for the same reason the f64 core is (the
    /// decomposition is size-derived only). Overwrites C.
    pub fn blocked_matmul_f32_into(
        &self,
        a: &[f32],
        b: &[f32],
        c: &mut [f32],
        m: usize,
        k: usize,
        n: usize,
        nt: usize,
    ) {
        assert_eq!(a.len(), m * k, "A shape mismatch");
        assert_eq!(b.len(), k * n, "B shape mismatch");
        assert_eq!(c.len(), m * n, "C shape mismatch");
        c.fill(0.0);
        let Blocking { mr, nr, kc: kcb, mc, nc, .. } = self.blk_f32;
        let kern = self.kernel_f32;
        let mut bpack = vec![0.0f32; nc * kcb];
        for jc in (0..n).step_by(nc) {
            let jn = nc.min(n - jc);
            let jpanels = jn.div_ceil(nr);
            for kb in (0..k).step_by(kcb) {
                let kc = kcb.min(k - kb);
                let packed_len = jpanels * kc * nr;
                for (p, panel) in bpack[..packed_len].chunks_mut(kc * nr).enumerate() {
                    let c0 = p * nr;
                    pack_b_panel(b, n, kb, kc, jc + c0, nr.min(jn - c0), nr, panel);
                }
                let bp = &bpack[..packed_len];
                let bands: Vec<&mut [f32]> = c.chunks_mut(mc * n).collect();
                parallel::parallel_items(nt, bands, |bi, cband| {
                    let row0 = bi * mc;
                    let rows = cband.len() / n;
                    let mut apack = vec![0.0f32; rows.div_ceil(mr) * mr * kc];
                    pack_a(a, k, row0, rows, kb, kc, mr, &mut apack);
                    block_kernel_f32(kern, &apack, bp, kc, rows, jn, cband, n, 0, jc);
                });
            }
        }
    }

    /// Blocked parallel f32 symmetric Gram with an explicit worker
    /// count — the single-precision twin of
    /// [`KernelCtx::blocked_gram_into`] (upper-triangle bands in place,
    /// then band-sequential mirror waves). Overwrites G with the same
    /// bits at any thread count.
    pub fn blocked_gram_f32_into(
        &self,
        a: &[f32],
        g: &mut [f32],
        m: usize,
        k: usize,
        nt: usize,
    ) {
        assert_eq!(a.len(), m * k, "A shape mismatch");
        assert_eq!(g.len(), m * m, "G shape mismatch");
        let bs = self.blk_f32.bs;
        let nb = m.div_ceil(bs);
        let edge = |b: usize| bs.min(m - b * bs);
        let bands: Vec<&mut [f32]> = g.chunks_mut(bs * m).collect();
        parallel::parallel_items(nt, bands, |bi, gband| {
            let ri = edge(bi);
            for bj in bi..nb {
                gram_block_f32(
                    self.kernel_f32,
                    &self.blk_f32,
                    a,
                    k,
                    bi * bs,
                    ri,
                    bj * bs,
                    edge(bj),
                    gband,
                    m,
                    bj * bs,
                );
            }
        });
        let mut done: Vec<&[f32]> = Vec::with_capacity(nb);
        let mut tail: &mut [f32] = g;
        for bi in 0..nb {
            let band_len = edge(bi) * m;
            let (band, rest) = {
                let t = std::mem::take(&mut tail);
                t.split_at_mut(band_len)
            };
            if bi > 0 {
                let done_ref: &[&[f32]] = &done;
                let rows: Vec<&mut [f32]> = band.chunks_mut(m).collect();
                parallel::parallel_items(nt, rows, |r, grow| {
                    let gi = bi * bs + r;
                    for (bj, src_band) in done_ref.iter().enumerate() {
                        let rj = edge(bj);
                        for c in 0..rj {
                            grow[bj * bs + c] = src_band[c * m + gi];
                        }
                    }
                });
            }
            done.push(band);
            tail = rest;
        }
    }
}

/// A context running `choice`'s *scalar model* as its kernel (same tile
/// shape, same derived blocking, plain-Rust arithmetic). The proptests
/// drive the full blocked core with this to pin real-kernel products
/// bit-identical to the model.
pub(crate) fn model_ctx(choice: KernelChoice) -> Result<KernelCtx, KernelError> {
    let kernel = kernel::model_kernel_for(choice)?;
    let kernel_f32 = kernel::kernel_f32_for(choice)?;
    let geom = CacheGeometry::detect();
    let blk = geom.blocking(kernel.mr(), kernel.nr());
    let blk_f32 = geom.blocking_f32(kernel_f32.mr(), kernel_f32.nr());
    Ok(KernelCtx { kernel, kernel_f32, choice, geom, blk, blk_f32 })
}

// ---------------------------------------------------------------------------
// Scoping / process-wide kernel forcing
// ---------------------------------------------------------------------------

/// Run `f` with `choice` as the ambient kernel on this thread, restoring
/// the previous setting afterwards. `Auto` installs nothing and inherits
/// the enclosing scope (mirroring
/// [`with_parallelism`](crate::util::parallel::with_parallelism)), so a
/// default-config solve inside a forced scope stays forced. Errors out
/// — before running `f` — when the forced kernel is unsupported.
pub fn with_kernel_choice<T>(
    choice: KernelChoice,
    f: impl FnOnce() -> T,
) -> Result<T, KernelError> {
    if matches!(choice, KernelChoice::Auto) {
        return Ok(f());
    }
    KernelCtx::for_choice(choice)?;
    struct Restore(usize);
    impl Drop for Restore {
        fn drop(&mut self) {
            KERNEL_OVERRIDE.with(|c| c.set(self.0));
        }
    }
    let prev = KERNEL_OVERRIDE.with(|c| {
        let prev = c.get();
        c.set(encode_choice(choice));
        prev
    });
    let _restore = Restore(prev);
    Ok(f())
}

/// Set the process-wide default kernel (the CLI `--kernel` flag lands
/// here). `Auto` clears the force back to `PALLAS_KERNEL`/detection.
/// Errors out without changing anything when the kernel is unsupported.
pub fn set_global_kernel(choice: KernelChoice) -> Result<(), KernelError> {
    if !matches!(choice, KernelChoice::Auto) {
        KernelCtx::for_choice(choice)?;
    }
    GLOBAL_KERNEL.store(encode_choice(choice), Ordering::Relaxed);
    Ok(())
}

// ---------------------------------------------------------------------------
// Naive reference kernels (the seed's loops; serial)
// ---------------------------------------------------------------------------

/// The seed's ikj/axpy GEMM, kept as the correctness reference and the
/// micro-bench baseline. Serial; overwrites C.
pub(crate) fn naive_matmul_into(
    a: &[f64],
    b: &[f64],
    c: &mut [f64],
    m: usize,
    k: usize,
    n: usize,
) {
    c.fill(0.0);
    for i in 0..m {
        let crow = &mut c[i * n..(i + 1) * n];
        for kk in 0..k {
            let aik = a[i * k + kk];
            if aik == 0.0 {
                continue;
            }
            vecops::axpy(aik, &b[kk * n..(kk + 1) * n], crow);
        }
    }
}

/// The seed's dot-product symmetric Gram, kept as reference/baseline.
/// Serial; overwrites G.
pub(crate) fn naive_gram_into(a: &[f64], g: &mut [f64], m: usize, k: usize) {
    for i in 0..m {
        for j in i..m {
            let v = vecops::dot(&a[i * k..(i + 1) * k], &a[j * k..(j + 1) * k]);
            g[i * m + j] = v;
            g[j * m + i] = v;
        }
    }
}

/// f32 ikj/axpy GEMM reference — the small-shape path of
/// [`KernelCtx::matmul_f32_into`] and the f32 correctness baseline.
/// Serial; overwrites C.
pub(crate) fn naive_matmul_f32_into(
    a: &[f32],
    b: &[f32],
    c: &mut [f32],
    m: usize,
    k: usize,
    n: usize,
) {
    c.fill(0.0);
    for i in 0..m {
        let crow = &mut c[i * n..(i + 1) * n];
        for kk in 0..k {
            let aik = a[i * k + kk];
            if aik == 0.0 {
                continue;
            }
            vecops_f32::axpy(aik, &b[kk * n..(kk + 1) * n], crow);
        }
    }
}

/// f32 dot-product symmetric Gram reference. Serial; overwrites G.
pub(crate) fn naive_gram_f32_into(a: &[f32], g: &mut [f32], m: usize, k: usize) {
    for i in 0..m {
        for j in i..m {
            let v = vecops_f32::dot(&a[i * k..(i + 1) * k], &a[j * k..(j + 1) * k]);
            g[i * m + j] = v;
            g[j * m + i] = v;
        }
    }
}

// ---------------------------------------------------------------------------
// Packing
// ---------------------------------------------------------------------------

/// Pack `rows` rows of A (starting at `row0`, k-slice `[k0, k0+kc)`) into
/// mr-row tiles: `out[t·kc·mr + kk·mr + i] = A[row0+t·mr+i, k0+kk]`,
/// zero-padded when the last tile is short of mr rows. Generic over the
/// element type so the f32 tier shares the packing stage.
fn pack_a<T: Copy + Default>(
    a: &[T],
    lda: usize,
    row0: usize,
    rows: usize,
    k0: usize,
    kc: usize,
    mr: usize,
    out: &mut [T],
) {
    let tiles = rows.div_ceil(mr);
    for t in 0..tiles {
        let tile = &mut out[t * kc * mr..(t + 1) * kc * mr];
        for i in 0..mr {
            let r = t * mr + i;
            if r < rows {
                let base = (row0 + r) * lda + k0;
                let src = &a[base..base + kc];
                for (kk, &v) in src.iter().enumerate() {
                    tile[kk * mr + i] = v;
                }
            } else {
                for kk in 0..kc {
                    tile[kk * mr + i] = T::default();
                }
            }
        }
    }
}

/// Pack one nr-column panel of B (k-slice `[k0, k0+kc)`, columns
/// `[col0, col0+w)`, `w ≤ nr`): `panel[kk·nr + j] = B[k0+kk, col0+j]`,
/// zero-padded beyond `w`.
fn pack_b_panel<T: Copy + Default>(
    b: &[T],
    ldb: usize,
    k0: usize,
    kc: usize,
    col0: usize,
    w: usize,
    nr: usize,
    panel: &mut [T],
) {
    for kk in 0..kc {
        let base = (k0 + kk) * ldb + col0;
        let dst = &mut panel[kk * nr..(kk + 1) * nr];
        dst[..w].copy_from_slice(&b[base..base + w]);
        for v in dst[w..].iter_mut() {
            *v = T::default();
        }
    }
}

/// Pack one nr-column panel of Aᵀ for the Gram kernel: the panel's
/// columns are A's *rows* `[row0, row0+w)`, so the read is contiguous
/// per row: `panel[kk·nr + j] = A[row0+j, k0+kk]`.
fn pack_bt_panel<T: Copy + Default>(
    a: &[T],
    lda: usize,
    k0: usize,
    kc: usize,
    row0: usize,
    w: usize,
    nr: usize,
    panel: &mut [T],
) {
    for j in 0..nr {
        if j < w {
            let base = (row0 + j) * lda + k0;
            let src = &a[base..base + kc];
            for (kk, &v) in src.iter().enumerate() {
                panel[kk * nr + j] = v;
            }
        } else {
            for kk in 0..kc {
                panel[kk * nr + j] = T::default();
            }
        }
    }
}

// ---------------------------------------------------------------------------
// Block driver
// ---------------------------------------------------------------------------

/// `C[c_row0.., c_col0..] += Apack·Bpack` for one packed (rows × cols)
/// block; edge tiles are computed full-width (packing zero-padded them)
/// and written back masked, so the microkernel never sees fringes.
fn block_kernel(
    kern: &dyn MicroKernel,
    apack: &[f64],
    bpack: &[f64],
    kc: usize,
    rows: usize,
    cols: usize,
    c: &mut [f64],
    ldc: usize,
    c_row0: usize,
    c_col0: usize,
) {
    let (mr, nr) = (kern.mr(), kern.nr());
    debug_assert!(mr * nr <= kernel::MAX_TILE, "register tile exceeds driver scratch");
    let mut acc = [0.0f64; kernel::MAX_TILE];
    let tiles = rows.div_ceil(mr);
    let panels = cols.div_ceil(nr);
    for t in 0..tiles {
        let ap = &apack[t * kc * mr..(t + 1) * kc * mr];
        let mrows = mr.min(rows - t * mr);
        for p in 0..panels {
            let bp = &bpack[p * kc * nr..(p + 1) * kc * nr];
            let ncols = nr.min(cols - p * nr);
            let tile = &mut acc[..mr * nr];
            tile.fill(0.0);
            kern.tile(ap, bp, kc, tile);
            for i in 0..mrows {
                let base = (c_row0 + t * mr + i) * ldc + c_col0 + p * nr;
                let crow = &mut c[base..base + ncols];
                for (j, cv) in crow.iter_mut().enumerate() {
                    *cv += tile[i * nr + j];
                }
            }
        }
    }
}

/// f32 twin of [`block_kernel`]: one packed (rows × cols) block through
/// the f32 microkernel, stack accumulator at the same `MAX_TILE` bound.
fn block_kernel_f32(
    kern: &dyn MicroKernelF32,
    apack: &[f32],
    bpack: &[f32],
    kc: usize,
    rows: usize,
    cols: usize,
    c: &mut [f32],
    ldc: usize,
    c_row0: usize,
    c_col0: usize,
) {
    let (mr, nr) = (kern.mr(), kern.nr());
    debug_assert!(mr * nr <= kernel::MAX_TILE, "register tile exceeds driver scratch");
    let mut acc = [0.0f32; kernel::MAX_TILE];
    let tiles = rows.div_ceil(mr);
    let panels = cols.div_ceil(nr);
    for t in 0..tiles {
        let ap = &apack[t * kc * mr..(t + 1) * kc * mr];
        let mrows = mr.min(rows - t * mr);
        for p in 0..panels {
            let bp = &bpack[p * kc * nr..(p + 1) * kc * nr];
            let ncols = nr.min(cols - p * nr);
            let tile = &mut acc[..mr * nr];
            tile.fill(0.0);
            kern.tile(ap, bp, kc, tile);
            for i in 0..mrows {
                let base = (c_row0 + t * mr + i) * ldc + c_col0 + p * nr;
                let crow = &mut c[base..base + ncols];
                for (j, cv) in crow.iter_mut().enumerate() {
                    *cv += tile[i * nr + j];
                }
            }
        }
    }
}

/// One upper-triangle block `A[i0..i0+ri]·A[j0..j0+rj]ᵀ` of the Gram
/// matrix, fully packed and k-blocked, written **straight into** the
/// destination `c` (leading dimension `ldc`, rows relative to `c`'s
/// first row, columns at offset `c_col0`) — no transient block buffer.
fn gram_block(
    kern: &dyn MicroKernel,
    blk: &Blocking,
    a: &[f64],
    k: usize,
    i0: usize,
    ri: usize,
    j0: usize,
    rj: usize,
    c: &mut [f64],
    ldc: usize,
    c_col0: usize,
) {
    let Blocking { mr, nr, kc: kcb, .. } = *blk;
    for r in 0..ri {
        let base = r * ldc + c_col0;
        c[base..base + rj].fill(0.0);
    }
    let mut apack = vec![0.0; ri.div_ceil(mr) * mr * kcb];
    let mut bpack = vec![0.0; rj.div_ceil(nr) * nr * kcb];
    let panels = rj.div_ceil(nr);
    for kb in (0..k).step_by(kcb) {
        let kc = kcb.min(k - kb);
        pack_a(a, k, i0, ri, kb, kc, mr, &mut apack[..ri.div_ceil(mr) * mr * kc]);
        for p in 0..panels {
            let c0 = p * nr;
            pack_bt_panel(
                a,
                k,
                kb,
                kc,
                j0 + c0,
                nr.min(rj - c0),
                nr,
                &mut bpack[p * kc * nr..(p + 1) * kc * nr],
            );
        }
        block_kernel(
            kern,
            &apack[..ri.div_ceil(mr) * mr * kc],
            &bpack[..panels * kc * nr],
            kc,
            ri,
            rj,
            c,
            ldc,
            0,
            c_col0,
        );
    }
}

/// f32 twin of [`gram_block`].
fn gram_block_f32(
    kern: &dyn MicroKernelF32,
    blk: &Blocking,
    a: &[f32],
    k: usize,
    i0: usize,
    ri: usize,
    j0: usize,
    rj: usize,
    c: &mut [f32],
    ldc: usize,
    c_col0: usize,
) {
    let Blocking { mr, nr, kc: kcb, .. } = *blk;
    for r in 0..ri {
        let base = r * ldc + c_col0;
        c[base..base + rj].fill(0.0);
    }
    let mut apack = vec![0.0f32; ri.div_ceil(mr) * mr * kcb];
    let mut bpack = vec![0.0f32; rj.div_ceil(nr) * nr * kcb];
    let panels = rj.div_ceil(nr);
    for kb in (0..k).step_by(kcb) {
        let kc = kcb.min(k - kb);
        pack_a(a, k, i0, ri, kb, kc, mr, &mut apack[..ri.div_ceil(mr) * mr * kc]);
        for p in 0..panels {
            let c0 = p * nr;
            pack_bt_panel(
                a,
                k,
                kb,
                kc,
                j0 + c0,
                nr.min(rj - c0),
                nr,
                &mut bpack[p * kc * nr..(p + 1) * kc * nr],
            );
        }
        block_kernel_f32(
            kern,
            &apack[..ri.div_ceil(mr) * mr * kc],
            &bpack[..panels * kc * nr],
            kc,
            ri,
            rj,
            c,
            ldc,
            0,
            c_col0,
        );
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;

    fn rand_vec(rng: &mut Rng, len: usize) -> Vec<f64> {
        (0..len).map(|_| rng.normal()).collect()
    }

    fn max_abs_diff(a: &[f64], b: &[f64]) -> f64 {
        a.iter().zip(b).map(|(x, y)| (x - y).abs()).fold(0.0, f64::max)
    }

    fn enabled_ctxs() -> Vec<&'static KernelCtx> {
        kernel::enabled_choices()
            .into_iter()
            .map(|c| KernelCtx::for_choice(c).expect("enabled choice resolves"))
            .collect()
    }

    #[test]
    fn blocked_matches_naive_ragged_shapes() {
        let mut rng = Rng::seed_from(21);
        // Deliberately not multiples of any mr/nr/kc/mc/nc.
        for &(m, k, n) in &[(1, 1, 1), (5, 3, 9), (33, 17, 41), (70, 130, 51), (64, 256, 64)] {
            let a = rand_vec(&mut rng, m * k);
            let b = rand_vec(&mut rng, k * n);
            let mut naive = vec![0.0; m * n];
            naive_matmul_into(&a, &b, &mut naive, m, k, n);
            for ctx in enabled_ctxs() {
                for nt in [1, 3, 8] {
                    let mut blocked = vec![0.0; m * n];
                    ctx.blocked_matmul_into(&a, &b, &mut blocked, m, k, n, nt);
                    let dev = max_abs_diff(&naive, &blocked);
                    assert!(
                        dev < 1e-10,
                        "{} ({m},{k},{n}) nt={nt}: dev {dev}",
                        ctx.kernel_name()
                    );
                }
            }
        }
    }

    #[test]
    fn blocked_gram_matches_naive_ragged_shapes() {
        let mut rng = Rng::seed_from(22);
        for &(m, k) in &[(1, 4), (7, 5), (40, 33), (130, 70), (129, 257)] {
            let a = rand_vec(&mut rng, m * k);
            let mut naive = vec![0.0; m * m];
            naive_gram_into(&a, &mut naive, m, k);
            for ctx in enabled_ctxs() {
                for nt in [1, 4] {
                    let mut blocked = vec![0.0; m * m];
                    ctx.blocked_gram_into(&a, &mut blocked, m, k, nt);
                    let dev = max_abs_diff(&naive, &blocked);
                    assert!(dev < 1e-10, "{} ({m},{k}) nt={nt}: dev {dev}", ctx.kernel_name());
                }
            }
        }
    }

    #[test]
    fn every_kernel_is_bit_stable_across_thread_counts() {
        let mut rng = Rng::seed_from(23);
        let (m, k, n) = (67, 310, 45);
        let a = rand_vec(&mut rng, m * k);
        let b = rand_vec(&mut rng, k * n);
        for ctx in enabled_ctxs() {
            let mut c1 = vec![0.0; m * n];
            ctx.blocked_matmul_into(&a, &b, &mut c1, m, k, n, 1);
            for nt in [2, 5, 16] {
                let mut cn = vec![0.0; m * n];
                ctx.blocked_matmul_into(&a, &b, &mut cn, m, k, n, nt);
                assert!(
                    c1.iter().zip(&cn).all(|(x, y)| x.to_bits() == y.to_bits()),
                    "{} gemm not bit-stable at nt={nt}",
                    ctx.kernel_name()
                );
            }
            let mut g1 = vec![0.0; m * m];
            ctx.blocked_gram_into(&a, &mut g1, m, k, 1);
            for nt in [2, 7] {
                let mut gn = vec![0.0; m * m];
                ctx.blocked_gram_into(&a, &mut gn, m, k, nt);
                assert!(
                    g1.iter().zip(&gn).all(|(x, y)| x.to_bits() == y.to_bits()),
                    "{} gram not bit-stable at nt={nt}",
                    ctx.kernel_name()
                );
            }
        }
    }

    #[test]
    fn every_kernel_matches_its_model_through_the_blocked_core() {
        let mut rng = Rng::seed_from(26);
        let (m, k, n) = (53, 91, 38);
        let a = rand_vec(&mut rng, m * k);
        let b = rand_vec(&mut rng, k * n);
        for ctx in enabled_ctxs() {
            let model = model_ctx(ctx.choice()).expect("model for enabled kernel");
            let mut real = vec![0.0; m * n];
            let mut modeled = vec![0.0; m * n];
            ctx.blocked_matmul_into(&a, &b, &mut real, m, k, n, 2);
            model.blocked_matmul_into(&a, &b, &mut modeled, m, k, n, 2);
            assert!(
                real.iter().zip(&modeled).all(|(x, y)| x.to_bits() == y.to_bits()),
                "{} blocked gemm deviates from its scalar model",
                ctx.kernel_name()
            );
            let mut greal = vec![0.0; m * m];
            let mut gmodel = vec![0.0; m * m];
            ctx.blocked_gram_into(&a, &mut greal, m, k, 2);
            model.blocked_gram_into(&a, &mut gmodel, m, k, 2);
            assert!(
                greal.iter().zip(&gmodel).all(|(x, y)| x.to_bits() == y.to_bits()),
                "{} blocked gram deviates from its scalar model",
                ctx.kernel_name()
            );
        }
    }

    #[test]
    fn gram_is_symmetric() {
        let mut rng = Rng::seed_from(24);
        let (m, k) = (90, 40);
        let a = rand_vec(&mut rng, m * k);
        for ctx in enabled_ctxs() {
            let mut g = vec![0.0; m * m];
            ctx.blocked_gram_into(&a, &mut g, m, k, 4);
            for i in 0..m {
                for j in 0..m {
                    assert_eq!(
                        g[i * m + j].to_bits(),
                        g[j * m + i].to_bits(),
                        "{} ({i},{j})",
                        ctx.kernel_name()
                    );
                }
            }
        }
    }

    #[test]
    fn public_entry_points_route_both_paths() {
        let mut rng = Rng::seed_from(25);
        let ctx = KernelCtx::current();
        // Small: naive path. Large: blocked path. Both must agree with
        // an explicit naive run.
        for &(m, k, n) in &[(6, 4, 5), (48, 64, 48)] {
            let a = rand_vec(&mut rng, m * k);
            let b = rand_vec(&mut rng, k * n);
            let c = ctx.matmul(&a, &b, m, k, n);
            let mut reference = vec![0.0; m * n];
            naive_matmul_into(&a, &b, &mut reference, m, k, n);
            assert!(max_abs_diff(&c, &reference) < 1e-10, "({m},{k},{n})");
        }
        for &(m, k) in &[(6, 4), (72, 40)] {
            let a = rand_vec(&mut rng, m * k);
            let g = ctx.gram(&a, m, k);
            let mut reference = vec![0.0; m * m];
            naive_gram_into(&a, &mut reference, m, k);
            assert!(max_abs_diff(&g, &reference) < 1e-10, "({m},{k})");
        }
    }

    #[test]
    fn with_kernel_choice_scopes_and_restores() {
        let ambient = KernelCtx::current().choice();
        let inside = with_kernel_choice(KernelChoice::Scalar, || KernelCtx::current().choice())
            .expect("scalar always supported");
        assert_eq!(inside, KernelChoice::Scalar);
        assert_eq!(KernelCtx::current().choice(), ambient);
        // Auto inherits the enclosing scope instead of clobbering it.
        let nested = with_kernel_choice(KernelChoice::Scalar, || {
            with_kernel_choice(KernelChoice::Auto, || KernelCtx::current().choice())
        })
        .expect("outer")
        .expect("inner");
        assert_eq!(nested, KernelChoice::Scalar);
    }

    #[test]
    fn ctx_describe_names_kernel_and_geometry() {
        let ctx = KernelCtx::for_choice(KernelChoice::Scalar).unwrap();
        let d = ctx.describe();
        assert!(d.contains("kernel=scalar"), "{d}");
        assert!(d.contains("kc="), "{d}");
        assert!(d.contains("l1d="), "{d}");
        assert_eq!(ctx.choice(), KernelChoice::Scalar);
        assert_eq!(ctx.blocking().mr, 4);
        assert_eq!(ctx.blocking().nr, 8);
        assert!(d.contains("f32["), "{d}");
    }

    fn rand_vec_f32(rng: &mut Rng, len: usize) -> Vec<f32> {
        (0..len).map(|_| rng.normal() as f32).collect()
    }

    fn max_abs_diff_f32(a: &[f32], b: &[f32]) -> f32 {
        a.iter().zip(b).map(|(x, y)| (x - y).abs()).fold(0.0, f32::max)
    }

    #[test]
    fn f32_blocked_matches_naive_ragged_shapes() {
        let mut rng = Rng::seed_from(27);
        for &(m, k, n) in &[(1, 1, 1), (5, 3, 9), (33, 17, 41), (70, 130, 51), (64, 256, 64)] {
            let a = rand_vec_f32(&mut rng, m * k);
            let b = rand_vec_f32(&mut rng, k * n);
            let mut naive = vec![0.0f32; m * n];
            naive_matmul_f32_into(&a, &b, &mut naive, m, k, n);
            for ctx in enabled_ctxs() {
                for nt in [1, 3, 8] {
                    let mut blocked = vec![0.0f32; m * n];
                    ctx.blocked_matmul_f32_into(&a, &b, &mut blocked, m, k, n, nt);
                    let dev = max_abs_diff_f32(&naive, &blocked);
                    assert!(
                        dev < 1e-3,
                        "{} ({m},{k},{n}) nt={nt}: dev {dev}",
                        ctx.kernel_name()
                    );
                }
            }
        }
    }

    #[test]
    fn f32_blocked_gram_matches_naive_and_is_symmetric() {
        let mut rng = Rng::seed_from(28);
        for &(m, k) in &[(1, 4), (7, 5), (40, 33), (129, 70)] {
            let a = rand_vec_f32(&mut rng, m * k);
            let mut naive = vec![0.0f32; m * m];
            naive_gram_f32_into(&a, &mut naive, m, k);
            for ctx in enabled_ctxs() {
                for nt in [1, 4] {
                    let mut blocked = vec![0.0f32; m * m];
                    ctx.blocked_gram_f32_into(&a, &mut blocked, m, k, nt);
                    let dev = max_abs_diff_f32(&naive, &blocked);
                    assert!(dev < 1e-3, "{} ({m},{k}) nt={nt}: dev {dev}", ctx.kernel_name());
                    for i in 0..m {
                        for j in 0..m {
                            assert_eq!(
                                blocked[i * m + j].to_bits(),
                                blocked[j * m + i].to_bits(),
                                "{} ({i},{j})",
                                ctx.kernel_name()
                            );
                        }
                    }
                }
            }
        }
    }

    #[test]
    fn every_f32_kernel_is_bit_stable_across_thread_counts() {
        let mut rng = Rng::seed_from(29);
        let (m, k, n) = (67, 310, 45);
        let a = rand_vec_f32(&mut rng, m * k);
        let b = rand_vec_f32(&mut rng, k * n);
        for ctx in enabled_ctxs() {
            let mut c1 = vec![0.0f32; m * n];
            ctx.blocked_matmul_f32_into(&a, &b, &mut c1, m, k, n, 1);
            for nt in [2, 5, 16] {
                let mut cn = vec![0.0f32; m * n];
                ctx.blocked_matmul_f32_into(&a, &b, &mut cn, m, k, n, nt);
                assert!(
                    c1.iter().zip(&cn).all(|(x, y)| x.to_bits() == y.to_bits()),
                    "{} f32 gemm not bit-stable at nt={nt}",
                    ctx.kernel_name()
                );
            }
            let mut g1 = vec![0.0f32; m * m];
            ctx.blocked_gram_f32_into(&a, &mut g1, m, k, 1);
            for nt in [2, 7] {
                let mut gn = vec![0.0f32; m * m];
                ctx.blocked_gram_f32_into(&a, &mut gn, m, k, nt);
                assert!(
                    g1.iter().zip(&gn).all(|(x, y)| x.to_bits() == y.to_bits()),
                    "{} f32 gram not bit-stable at nt={nt}",
                    ctx.kernel_name()
                );
            }
        }
    }

    #[test]
    fn f32_public_entry_points_route_both_paths() {
        let mut rng = Rng::seed_from(30);
        let ctx = KernelCtx::current();
        // Small: naive path. Large: blocked path. Both must agree with
        // an explicit naive run.
        for &(m, k, n) in &[(6, 4, 5), (48, 64, 48)] {
            let a = rand_vec_f32(&mut rng, m * k);
            let b = rand_vec_f32(&mut rng, k * n);
            let mut c = vec![0.0f32; m * n];
            ctx.matmul_f32_into(&a, &b, &mut c, m, k, n);
            let mut reference = vec![0.0f32; m * n];
            naive_matmul_f32_into(&a, &b, &mut reference, m, k, n);
            assert!(max_abs_diff_f32(&c, &reference) < 1e-3, "({m},{k},{n})");
        }
        for &(m, k) in &[(6, 4), (72, 40)] {
            let a = rand_vec_f32(&mut rng, m * k);
            let mut g = vec![0.0f32; m * m];
            ctx.gram_f32_into(&a, &mut g, m, k);
            let mut reference = vec![0.0f32; m * m];
            naive_gram_f32_into(&a, &mut reference, m, k);
            assert!(max_abs_diff_f32(&g, &reference) < 1e-3, "({m},{k})");
        }
    }
}
