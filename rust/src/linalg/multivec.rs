//! `MultiVec` — a column panel of right-hand sides for the fused
//! multi-RHS kernels.
//!
//! The hot GEMV paths (`matvec` / `matvec_t` on [`super::Mat`],
//! [`super::Csr`] and [`super::Design`]) are bandwidth-bound: each call
//! streams the whole matrix to produce one vector. When a caller needs
//! the product against several vectors at once (the primal Newton's
//! batched margin refresh, blocked-CG workloads, CV folds), fusing the
//! right-hand sides into one panel amortizes the matrix traffic r-fold —
//! the matrix is streamed once per *panel* instead of once per *vector*,
//! which is the whole BLAS-2 → BLAS-3 lever the paper's GPU backend
//! pulls.
//!
//! Storage is column-major so that column `j` is one contiguous slice:
//! the multi-RHS kernels are specified (and property-tested) to make
//! column `j` of their output **bit-identical** to the corresponding
//! single-RHS call on column `j`, and the simplest way to honor that
//! contract is to hand the kernels exactly the slices the single-RHS
//! paths would see.

/// A dense `rows × ncols` panel of column vectors, column-major.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct MultiVec {
    rows: usize,
    ncols: usize,
    data: Vec<f64>,
}

impl MultiVec {
    /// Zero panel of shape `rows × ncols`.
    pub fn zeros(rows: usize, ncols: usize) -> Self {
        MultiVec { rows, ncols, data: vec![0.0; rows * ncols] }
    }

    /// Build from a closure `f(row, col)`.
    pub fn from_fn(rows: usize, ncols: usize, mut f: impl FnMut(usize, usize) -> f64) -> Self {
        let mut data = Vec::with_capacity(rows * ncols);
        for j in 0..ncols {
            for i in 0..rows {
                data.push(f(i, j));
            }
        }
        MultiVec { rows, ncols, data }
    }

    /// Build a panel whose columns are the given vectors (all must share
    /// one length).
    pub fn from_cols(cols: &[&[f64]]) -> Self {
        let rows = cols.first().map_or(0, |c| c.len());
        let mut mv = MultiVec::zeros(rows, cols.len());
        for (j, c) in cols.iter().enumerate() {
            mv.col_mut(j).copy_from_slice(c);
        }
        mv
    }

    #[inline]
    pub fn rows(&self) -> usize {
        self.rows
    }

    #[inline]
    pub fn ncols(&self) -> usize {
        self.ncols
    }

    /// Column `j` as a contiguous slice.
    #[inline]
    pub fn col(&self, j: usize) -> &[f64] {
        &self.data[j * self.rows..(j + 1) * self.rows]
    }

    /// Mutable column `j`.
    #[inline]
    pub fn col_mut(&mut self, j: usize) -> &mut [f64] {
        &mut self.data[j * self.rows..(j + 1) * self.rows]
    }

    #[inline]
    pub fn get(&self, i: usize, j: usize) -> f64 {
        debug_assert!(i < self.rows && j < self.ncols);
        self.data[j * self.rows + i]
    }

    #[inline]
    pub fn set(&mut self, i: usize, j: usize, v: f64) {
        debug_assert!(i < self.rows && j < self.ncols);
        self.data[j * self.rows + i] = v;
    }

    /// Whole backing buffer (column-major).
    #[inline]
    pub fn data(&self) -> &[f64] {
        &self.data
    }

    /// Mutable backing buffer (column-major).
    #[inline]
    pub fn data_mut(&mut self) -> &mut [f64] {
        &mut self.data
    }

    /// Reshape in place, reusing the allocation. The resized panel is
    /// **zero-filled** — callers that rely on a clean panel (the CG
    /// scratch buffers) get one without a second memset; callers that
    /// overwrite every entry pay one clear either way.
    pub fn resize(&mut self, rows: usize, ncols: usize) {
        self.rows = rows;
        self.ncols = ncols;
        self.data.clear();
        self.data.resize(rows * ncols, 0.0);
    }

    /// Drop trailing columns, keeping the leading `ncols` columns intact
    /// (unlike [`MultiVec::resize`], which zeroes everything) — the
    /// blocked-CG panel compaction step.
    pub fn truncate_cols(&mut self, ncols: usize) {
        assert!(ncols <= self.ncols, "cannot truncate to a wider panel");
        self.ncols = ncols;
        self.data.truncate(self.rows * ncols);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn columns_are_contiguous() {
        let mv = MultiVec::from_fn(3, 2, |i, j| (10 * j + i) as f64);
        assert_eq!(mv.col(0), &[0.0, 1.0, 2.0]);
        assert_eq!(mv.col(1), &[10.0, 11.0, 12.0]);
        assert_eq!(mv.get(2, 1), 12.0);
    }

    #[test]
    fn from_cols_roundtrip() {
        let a = [1.0, 2.0];
        let b = [3.0, 4.0];
        let mv = MultiVec::from_cols(&[&a, &b]);
        assert_eq!((mv.rows(), mv.ncols()), (2, 2));
        assert_eq!(mv.col(0), &a);
        assert_eq!(mv.col(1), &b);
    }

    #[test]
    fn resize_reuses_buffer() {
        let mut mv = MultiVec::zeros(4, 3);
        mv.set(0, 0, 5.0);
        mv.resize(2, 2);
        assert_eq!((mv.rows(), mv.ncols()), (2, 2));
        assert_eq!(mv.data().len(), 4);
        assert_eq!(mv.col(1), &[0.0, 0.0]);
    }
}
