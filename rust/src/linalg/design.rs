//! The [`Design`] abstraction: one type for the regression design matrix
//! that every solver consumes, dense or sparse.
//!
//! The paper's sparse data sets (Dorothea, E2006-tfidf) arrive through
//! `read_svmlight` as CSR; before this type existed they were densified
//! before any flops happened. `Design` keeps sparse data sparse from
//! loader to solution: it exposes exactly the products and column
//! operations the solvers need (`matvec`, `matvec_t`, gram blocks,
//! per-column dot/axpy), dispatching to the blocked dense kernels or the
//! threaded CSR/CSC kernels — both bit-stable across thread counts.
//!
//! A sparse design carries the CSR *and* a CSC mirror: row access feeds
//! the matvec-shaped products, the mirror gives coordinate descent
//! O(nnz(col)) column access. The mirror is built once, at construction
//! (`Design::from(csr)`), by the parallel transpose-scatter in
//! [`Csc::from_csr`].

use super::dense::Mat;
use super::multivec::MultiVec;
use super::sparse::{Csc, Csr};
use std::borrow::Cow;

/// A regression design matrix (n samples × p features), dense or sparse.
#[derive(Clone, Debug)]
pub enum Design {
    /// Dense row-major storage over the blocked GEMM/GEMV layer.
    Dense(Mat),
    /// CSR storage plus its CSC mirror (built at construction).
    Sparse { csr: Csr, csc: Csc },
}

impl From<Mat> for Design {
    fn from(m: Mat) -> Self {
        Design::Dense(m)
    }
}

impl From<Csr> for Design {
    /// Wrap a CSR matrix, building the CSC mirror for column access.
    fn from(csr: Csr) -> Self {
        let csc = Csc::from_csr(&csr);
        Design::Sparse { csr, csc }
    }
}

impl Design {
    #[inline]
    pub fn rows(&self) -> usize {
        match self {
            Design::Dense(m) => m.rows(),
            Design::Sparse { csr, .. } => csr.rows(),
        }
    }

    #[inline]
    pub fn cols(&self) -> usize {
        match self {
            Design::Dense(m) => m.cols(),
            Design::Sparse { csr, .. } => csr.cols(),
        }
    }

    /// Stored entries: `rows·cols` for dense, `nnz` for sparse.
    pub fn nnz(&self) -> usize {
        match self {
            Design::Dense(m) => m.rows() * m.cols(),
            Design::Sparse { csr, .. } => csr.nnz(),
        }
    }

    #[inline]
    pub fn is_sparse(&self) -> bool {
        matches!(self, Design::Sparse { .. })
    }

    /// Borrow the dense storage, if this design is dense.
    pub fn as_dense(&self) -> Option<&Mat> {
        match self {
            Design::Dense(m) => Some(m),
            Design::Sparse { .. } => None,
        }
    }

    /// Materialize to dense (device-exchange boundaries, tests). This is
    /// the *only* densifying operation on a sparse design; the solver
    /// paths never call it.
    pub fn to_dense(&self) -> Mat {
        match self {
            Design::Dense(m) => m.clone(),
            Design::Sparse { csr, .. } => csr.to_dense(),
        }
    }

    /// `y ← X·x` (allocates the output).
    pub fn matvec(&self, x: &[f64]) -> Vec<f64> {
        match self {
            Design::Dense(m) => m.matvec(x),
            Design::Sparse { csr, .. } => csr.matvec(x),
        }
    }

    /// `y ← X·x` into a caller-provided buffer.
    pub fn matvec_into(&self, x: &[f64], y: &mut [f64]) {
        match self {
            Design::Dense(m) => m.matvec_into(x, y),
            Design::Sparse { csr, .. } => csr.matvec_into(x, y),
        }
    }

    /// `y ← Xᵀ·x` (allocates the output).
    pub fn matvec_t(&self, x: &[f64]) -> Vec<f64> {
        match self {
            Design::Dense(m) => m.matvec_t(x),
            Design::Sparse { csr, .. } => csr.matvec_t(x),
        }
    }

    /// `y ← Xᵀ·x` into a caller-provided buffer.
    pub fn matvec_t_into(&self, x: &[f64], y: &mut [f64]) {
        match self {
            Design::Dense(m) => m.matvec_t_into(x, y),
            Design::Sparse { csr, .. } => csr.matvec_t_into(x, y),
        }
    }

    /// `Y ← X·P` for a panel of right-hand sides — the fused multi-RHS
    /// GEMV. Column `j` of `Y` is bit-identical to
    /// `matvec_into(P.col(j), ..)` (the contract both underlying kernels
    /// pin), and bit-stable across thread counts.
    pub fn matvec_multi_into(&self, xs: &MultiVec, ys: &mut MultiVec) {
        match self {
            Design::Dense(m) => m.matvec_multi_into(xs, ys),
            Design::Sparse { csr, .. } => csr.matvec_multi_into(xs, ys),
        }
    }

    /// `Y ← Xᵀ·P` for a panel of right-hand sides; same per-column
    /// bit-identity contract as [`Design::matvec_multi_into`].
    pub fn matvec_t_multi_into(&self, us: &MultiVec, ys: &mut MultiVec) {
        match self {
            Design::Dense(m) => m.matvec_t_multi_into(us, ys),
            Design::Sparse { csr, .. } => csr.matvec_t_multi_into(us, ys),
        }
    }

    /// Squared L2 norm of each column.
    pub fn col_norms_sq(&self) -> Vec<f64> {
        match self {
            Design::Dense(m) => {
                let mut n = vec![0.0; m.cols()];
                for r in 0..m.rows() {
                    for (c, &v) in m.row(r).iter().enumerate() {
                        n[c] += v * v;
                    }
                }
                n
            }
            Design::Sparse { csr, .. } => csr.col_norms_sq(),
        }
    }

    /// Gram matrix `XᵀX` (p × p, dense output) — the t-independent block
    /// of the SVEN dual `K(t)`. Dense designs use the packed blocked
    /// kernel; sparse designs use the O(Σ nnz(row)²) CSR/CSC join.
    pub fn gram_t(&self) -> Mat {
        match self {
            Design::Dense(m) => m.gram_t(),
            Design::Sparse { csr, csc } => {
                let mut g = Mat::zeros(csr.cols(), csr.cols());
                csr.gram_into(csc, &mut g);
                g
            }
        }
    }

    /// Gram matrix `XXᵀ` (n × n, dense output).
    pub fn gram(&self) -> Mat {
        match self {
            Design::Dense(m) => m.gram(),
            Design::Sparse { csr, csc } => {
                let mut g = Mat::zeros(csr.rows(), csr.rows());
                csr.gram_rows_into(csc, &mut g);
                g
            }
        }
    }

    /// Gather the selected rows into a new design of the same storage
    /// kind — the CV-fold sub-problem constructor. Dense designs gather
    /// through [`Mat::gather_rows_into`]; sparse designs through the
    /// O(Σ nnz(row)) [`Csr::gather_rows_into`] (the CSC mirror of the
    /// result is rebuilt, as any fresh sparse design's is). The gathered
    /// rows are bit-identical copies, so solves on the result are
    /// bit-for-bit solves on "that data as its own data set".
    pub fn gather_rows(&self, rows: &[usize]) -> Design {
        match self {
            Design::Dense(m) => {
                let mut out = Mat::zeros(0, 0);
                m.gather_rows_into(rows, &mut out);
                Design::Dense(out)
            }
            Design::Sparse { csr, .. } => {
                let mut out = Csr::empty();
                csr.gather_rows_into(rows, &mut out);
                Design::from(out)
            }
        }
    }

    /// `⟨X[r, :], v⟩` — one prediction, used by the CV scorer on
    /// held-out rows (O(p) dense, O(nnz(row)) sparse).
    pub fn row_dot(&self, r: usize, v: &[f64]) -> f64 {
        match self {
            Design::Dense(m) => super::vecops::dot(m.row(r), v),
            Design::Sparse { csr, .. } => {
                csr.row_iter(r).map(|(c, x)| x * v[c]).sum()
            }
        }
    }

    /// Column-access view for coordinate descent: a dense design yields a
    /// one-time transposed copy (contiguous columns, exactly what the
    /// dense CD inner loop always used); a sparse design borrows the CSC
    /// mirror for O(nnz(col)) access.
    pub fn cols_view(&self) -> DesignCols<'_> {
        match self {
            Design::Dense(m) => DesignCols::Dense(m.transpose()),
            Design::Sparse { csc, .. } => DesignCols::Sparse(csc),
        }
    }
}

/// Column-access layer behind [`Design::cols_view`]; the inner-loop
/// currency of the CD solvers (glmnet, Shotgun).
pub enum DesignCols<'a> {
    /// Transposed dense copy: row `j` is column `j` of X, contiguous.
    Dense(Mat),
    /// Borrowed CSC mirror of a sparse design.
    Sparse(&'a Csc),
}

impl DesignCols<'_> {
    /// `⟨X[:,j], x⟩`.
    #[inline]
    pub fn col_dot(&self, j: usize, x: &[f64]) -> f64 {
        match self {
            DesignCols::Dense(xt) => super::vecops::dot(xt.row(j), x),
            DesignCols::Sparse(csc) => csc.col_dot(j, x),
        }
    }

    /// `x ← x + a·X[:,j]`.
    #[inline]
    pub fn col_axpy(&self, j: usize, a: f64, x: &mut [f64]) {
        match self {
            DesignCols::Dense(xt) => super::vecops::axpy(a, xt.row(j), x),
            DesignCols::Sparse(csc) => csc.col_axpy(j, a, x),
        }
    }

    /// Visit the nonzero entries of column `j` as (row, value) pairs
    /// (dense entries that happen to be exactly 0.0 are skipped, matching
    /// the Shotgun inner loop's historical behavior).
    #[inline]
    pub fn for_each_nz(&self, j: usize, mut f: impl FnMut(usize, f64)) {
        match self {
            DesignCols::Dense(xt) => {
                for (i, &v) in xt.row(j).iter().enumerate() {
                    if v != 0.0 {
                        f(i, v);
                    }
                }
            }
            DesignCols::Sparse(csc) => {
                for (i, v) in csc.col_iter(j) {
                    f(i, v);
                }
            }
        }
    }
}

/// Borrowed-or-converted access to a [`Design`], so APIs like
/// `Sven::prepare` accept a bare `Mat`, a `Csr`, or an existing `Design`
/// without forcing callers to wrap by hand.
///
/// The `Mat`/`Csr` impls clone into an owned `Design` (one transient
/// O(np) / O(nnz) copy); callers on a hot path that prepare the same
/// data repeatedly should build a `Design` once and pass that instead.
pub trait AsDesign {
    fn as_design(&self) -> Cow<'_, Design>;
}

impl AsDesign for Design {
    fn as_design(&self) -> Cow<'_, Design> {
        Cow::Borrowed(self)
    }
}

impl AsDesign for Mat {
    fn as_design(&self) -> Cow<'_, Design> {
        Cow::Owned(Design::Dense(self.clone()))
    }
}

impl AsDesign for Csr {
    fn as_design(&self) -> Cow<'_, Design> {
        Cow::Owned(Design::from(self.clone()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;

    fn sparse_design(rng: &mut Rng, n: usize, p: usize, density: f64) -> (Design, Mat) {
        let dense = Mat::from_fn(n, p, |_, _| {
            if rng.bernoulli(density) {
                rng.normal()
            } else {
                0.0
            }
        });
        let csr = Csr::from_dense(&dense, 0.0);
        (Design::from(csr), dense)
    }

    #[test]
    fn sparse_products_match_dense() {
        let mut rng = Rng::seed_from(61);
        let (d, m) = sparse_design(&mut rng, 23, 17, 0.3);
        assert!(d.is_sparse());
        assert_eq!((d.rows(), d.cols()), (23, 17));
        let x: Vec<f64> = (0..17).map(|_| rng.normal()).collect();
        let u: Vec<f64> = (0..23).map(|_| rng.normal()).collect();
        let y_s = d.matvec(&x);
        let y_d = m.matvec(&x);
        for i in 0..23 {
            assert!((y_s[i] - y_d[i]).abs() < 1e-12, "matvec {i}");
        }
        let t_s = d.matvec_t(&u);
        let t_d = m.matvec_t(&u);
        for j in 0..17 {
            assert!((t_s[j] - t_d[j]).abs() < 1e-12, "matvec_t {j}");
        }
        let g_s = d.gram_t();
        let g_d = m.gram_t();
        for i in 0..17 {
            for j in 0..17 {
                assert!((g_s.get(i, j) - g_d.get(i, j)).abs() < 1e-10, "gram_t ({i},{j})");
            }
        }
        let gg_s = d.gram();
        let gg_d = m.gram();
        for i in 0..23 {
            for j in 0..23 {
                assert!((gg_s.get(i, j) - gg_d.get(i, j)).abs() < 1e-10, "gram ({i},{j})");
            }
        }
    }

    #[test]
    fn cols_view_agrees_across_variants() {
        let mut rng = Rng::seed_from(62);
        let (d_sparse, m) = sparse_design(&mut rng, 14, 9, 0.4);
        let d_dense = Design::from(m.clone());
        let sv = d_sparse.cols_view();
        let dv = d_dense.cols_view();
        let x: Vec<f64> = (0..14).map(|_| rng.normal()).collect();
        for j in 0..9 {
            assert!((sv.col_dot(j, &x) - dv.col_dot(j, &x)).abs() < 1e-12, "dot {j}");
            let mut a = vec![0.0; 14];
            let mut b = vec![0.0; 14];
            sv.col_axpy(j, 1.5, &mut a);
            dv.col_axpy(j, 1.5, &mut b);
            for i in 0..14 {
                assert!((a[i] - b[i]).abs() < 1e-12, "axpy {j}/{i}");
            }
            let mut seen_s = Vec::new();
            let mut seen_d = Vec::new();
            sv.for_each_nz(j, |i, v| seen_s.push((i, v)));
            dv.for_each_nz(j, |i, v| seen_d.push((i, v)));
            assert_eq!(seen_s, seen_d, "nz iteration {j}");
        }
    }

    #[test]
    fn col_norms_and_nnz() {
        let mut rng = Rng::seed_from(63);
        let (d, m) = sparse_design(&mut rng, 12, 6, 0.5);
        let ns = d.col_norms_sq();
        let nd = Design::from(m).col_norms_sq();
        for j in 0..6 {
            assert!((ns[j] - nd[j]).abs() < 1e-12, "col {j}");
        }
        assert!(d.nnz() <= 12 * 6);
    }

    #[test]
    fn gather_rows_and_row_dot_agree_across_variants() {
        let mut rng = Rng::seed_from(64);
        let (d_sparse, m) = sparse_design(&mut rng, 10, 5, 0.4);
        let d_dense = Design::from(m.clone());
        let rows = [7usize, 0, 3];
        let v: Vec<f64> = (0..5).map(|_| rng.normal()).collect();
        for d in [&d_dense, &d_sparse] {
            let g = d.gather_rows(&rows);
            assert_eq!((g.rows(), g.cols()), (3, 5));
            assert_eq!(g.is_sparse(), d.is_sparse());
            for (s, &r) in rows.iter().enumerate() {
                let expect = crate::linalg::vecops::dot(m.row(r), &v);
                assert!((d.row_dot(r, &v) - expect).abs() < 1e-12, "row {r}");
                assert!((g.row_dot(s, &v) - expect).abs() < 1e-12, "gathered {s}");
            }
        }
    }

    #[test]
    fn as_design_conversions() {
        let m = Mat::eye(3);
        let via_mat = m.as_design();
        assert!(!via_mat.is_sparse());
        let csr = Csr::from_dense(&m, 0.0);
        let via_csr = csr.as_design();
        assert!(via_csr.is_sparse());
        assert_eq!(via_csr.nnz(), 3);
        let d: Design = m.clone().into();
        let borrowed = d.as_design();
        assert_eq!(borrowed.rows(), 3);
        assert_eq!(d.to_dense().data(), m.data());
        assert!(d.as_dense().is_some());
        assert!(via_csr.as_dense().is_none());
    }
}
