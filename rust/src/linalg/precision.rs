//! The compute-precision knob and its forcing chain.
//!
//! Mirrors the kernel-forcing machinery in `gemm.rs`: a [`Precision`]
//! can be forced per-call ([`with_precision`]), per-process
//! ([`set_global_precision`], where the CLI `--precision` flag lands),
//! or from the `PALLAS_PRECISION` environment variable
//! (`f64 | mixed-f32 | auto`). An invalid env value is a **hard error**
//! surfaced on first resolution — never a silent fallback — exactly
//! like `PALLAS_KERNEL`.
//!
//! `F64` is the classic all-double path. `MixedF32` computes the
//! bandwidth-bound panel products (the primal Newton-CG Hessian
//! applies) in `f32` from one-time shadow copies of the design, and
//! recovers the full `f64` CG tolerance with iterative refinement on
//! the Newton direction (see [`crate::linalg::cg::cg_solve_refined`]).
//! The refined solution meets the same acceptance bars as `F64`; the
//! per-precision results are *not* bit-identical to each other, but
//! each precision keeps the crate's bit-stable-across-threads contract.

use std::fmt;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::OnceLock;

/// Which arithmetic tier the solver hot loops should use.
///
/// `Auto` resolves from the `PALLAS_PRECISION` environment variable when
/// set, else to [`Precision::F64`]. Resolution happens at prep time
/// (`RustBackend::prepare`), so a prepared problem is pinned to one
/// tier and the service prep cache keys on it.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq, Hash)]
pub enum Precision {
    /// `PALLAS_PRECISION` if set, else `F64`.
    #[default]
    Auto,
    /// All-f64 arithmetic (the historical path).
    F64,
    /// f32 panel products + f64 iterative refinement on the Newton
    /// direction. Applies to the primal regime; the dual active-set
    /// Newton (direct Cholesky) stays f64 under this setting.
    MixedF32,
}

impl Precision {
    /// Parse a `PALLAS_PRECISION` / CLI value.
    pub fn parse(s: &str) -> Result<Self, PrecisionError> {
        match s.trim().to_ascii_lowercase().as_str() {
            "auto" => Ok(Precision::Auto),
            "f64" | "double" => Ok(Precision::F64),
            "mixed-f32" | "mixed_f32" | "mixedf32" | "f32" => Ok(Precision::MixedF32),
            other => Err(PrecisionError(format!(
                "unknown precision {other:?} (expected f64 | mixed-f32 | auto)"
            ))),
        }
    }
}

impl fmt::Display for Precision {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let s = match self {
            Precision::Auto => "auto",
            Precision::F64 => "f64",
            Precision::MixedF32 => "mixed-f32",
        };
        f.write_str(s)
    }
}

/// A precision was forced (`PALLAS_PRECISION`, `SvenConfig::precision`,
/// CLI `--precision`) that does not name a supported tier.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct PrecisionError(pub(crate) String);

impl fmt::Display for PrecisionError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "precision dispatch: {}", self.0)
    }
}

impl std::error::Error for PrecisionError {}

/// Process-wide setting: 0 = Auto (fall through to env), else encoded.
static GLOBAL_PRECISION: AtomicUsize = AtomicUsize::new(0);

thread_local! {
    /// Per-thread override installed by [`with_precision`]; takes
    /// precedence over the global setting on the installing thread.
    static PRECISION_OVERRIDE: std::cell::Cell<usize> = const { std::cell::Cell::new(0) };
}

fn encode(p: Precision) -> usize {
    match p {
        Precision::Auto => 0,
        Precision::F64 => 1,
        Precision::MixedF32 => 2,
    }
}

fn decode(enc: usize) -> Option<Precision> {
    match enc {
        1 => Some(Precision::F64),
        2 => Some(Precision::MixedF32),
        _ => None,
    }
}

/// `PALLAS_PRECISION`, parsed once. An invalid value is a hard error
/// (surfaced by [`resolved_precision`] / config validation), mirroring
/// `PALLAS_KERNEL`.
fn env_precision() -> Result<Option<Precision>, PrecisionError> {
    static ENV: OnceLock<Result<Option<Precision>, PrecisionError>> = OnceLock::new();
    ENV.get_or_init(|| match std::env::var("PALLAS_PRECISION") {
        Ok(s) => Precision::parse(&s).map(|p| match p {
            Precision::Auto => None,
            forced => Some(forced),
        }),
        Err(_) => Ok(None),
    })
    .clone()
}

/// Set the process-wide default (the CLI `--precision` flag lands here).
/// `Auto` clears the forcing.
pub fn set_global_precision(p: Precision) {
    GLOBAL_PRECISION.store(encode(p), Ordering::Relaxed);
}

/// Run `f` with `p` as the effective precision on this thread. `Auto`
/// installs nothing and inherits the enclosing scope, exactly like
/// [`crate::util::parallel::with_parallelism`].
pub fn with_precision<T>(p: Precision, f: impl FnOnce() -> T) -> T {
    if matches!(p, Precision::Auto) {
        return f();
    }
    struct Restore(usize);
    impl Drop for Restore {
        fn drop(&mut self) {
            PRECISION_OVERRIDE.with(|c| c.set(self.0));
        }
    }
    let prev = PRECISION_OVERRIDE.with(|c| {
        let prev = c.get();
        c.set(encode(p));
        prev
    });
    let _restore = Restore(prev);
    f()
}

/// Resolve `Auto` through the forcing chain: thread-local override →
/// global setting → `PALLAS_PRECISION` → `F64`. A non-`Auto` input is
/// returned unchanged (explicit config wins over every ambient source).
///
/// # Panics
///
/// Panics when `PALLAS_PRECISION` holds an unparseable value and the
/// chain reaches it — same hard-error contract as `PALLAS_KERNEL`
/// (config validation paths can pre-check with [`try_resolve_precision`]).
pub fn resolve_precision(p: Precision) -> Precision {
    try_resolve_precision(p)
        .unwrap_or_else(|e| panic!("{e} (fix PALLAS_PRECISION: f64 | mixed-f32 | auto)"))
}

/// Non-panicking twin of [`resolve_precision`] for config validation.
pub fn try_resolve_precision(p: Precision) -> Result<Precision, PrecisionError> {
    if !matches!(p, Precision::Auto) {
        return Ok(p);
    }
    let tls = PRECISION_OVERRIDE.with(|c| c.get());
    if let Some(p) = decode(tls) {
        return Ok(p);
    }
    if let Some(p) = decode(GLOBAL_PRECISION.load(Ordering::Relaxed)) {
        return Ok(p);
    }
    Ok(env_precision()?.unwrap_or(Precision::F64))
}

/// The effective ambient precision right now (`Auto` fully resolved).
pub fn resolved_precision() -> Precision {
    resolve_precision(Precision::Auto)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_roundtrip_and_aliases() {
        for p in [Precision::Auto, Precision::F64, Precision::MixedF32] {
            assert_eq!(Precision::parse(&p.to_string()).unwrap(), p);
        }
        assert_eq!(Precision::parse(" MIXED-F32 "), Ok(Precision::MixedF32));
        assert_eq!(Precision::parse("mixed_f32"), Ok(Precision::MixedF32));
        assert_eq!(Precision::parse("double"), Ok(Precision::F64));
        let e = Precision::parse("f16").unwrap_err();
        assert!(e.to_string().contains("f16"));
        assert!(Precision::parse("").is_err());
    }

    #[test]
    fn with_precision_scopes_and_restores() {
        // Note: the ambient default depends on PALLAS_PRECISION in the
        // test environment (the CI mixed-f32 leg sets it), so only the
        // scoped values are asserted exactly.
        let before = resolved_precision();
        let inside = with_precision(Precision::MixedF32, resolved_precision);
        assert_eq!(inside, Precision::MixedF32);
        assert_eq!(resolved_precision(), before);
        let forced = with_precision(Precision::F64, resolved_precision);
        assert_eq!(forced, Precision::F64);
        // Auto inherits the enclosing scope instead of clobbering it.
        let nested = with_precision(Precision::F64, || {
            with_precision(Precision::Auto, resolved_precision)
        });
        assert_eq!(nested, Precision::F64);
    }

    #[test]
    fn explicit_choice_wins_over_ambient() {
        let inside = with_precision(Precision::MixedF32, || {
            resolve_precision(Precision::F64)
        });
        assert_eq!(inside, Precision::F64);
    }
}
