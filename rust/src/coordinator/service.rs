//! The solver service: a leader that accepts Elastic Net solve jobs and
//! dispatches them across the worker pool, with a shared per-dataset
//! preparation cache, warm metrics and graceful drain — the "deployable"
//! face of the SVEN system (exercised end-to-end by
//! `examples/end_to_end.rs`).
//!
//! Zero-copy by construction: a [`SolveJob`] carries `Arc<Design>` /
//! `Arc<Vec<f64>>`, problems are [`EnProblem::shared`] views, and
//! preparations are immutable `Arc<dyn SvmPrep>`s shared by every worker
//! through the single-flight [`PrepCache`] — K jobs on one data set do
//! zero design/response deep copies and exactly one preparation build,
//! regardless of worker count.
//!
//! Fault isolation: every job attempt runs under `catch_unwind`, so a
//! panicking solve fails *that job* with [`JobError::WorkerPanic`]
//! instead of killing the worker (the pool's supervised loop is the
//! backstop for panics that escape anyway). Submissions carry
//! [`SubmitOptions`] — a wall-clock deadline observed at grid-point
//! boundaries (a mid-sweep deadline returns the bit-identical solved
//! prefix as [`JobResult::Truncated`]) and a capped-backoff
//! [`RetryPolicy`](super::admission::RetryPolicy) for transient
//! failures. [`ServiceConfig::max_queue_depth`] adds cost-based
//! admission control: over-budget submissions shed synchronously with
//! [`JobError::Overloaded`] before any worker is touched.

use super::admission::{Admission, CostTicket, JobError, RetryPolicy, SubmitOptions};
use super::cv::{self, CvPathResult};
use super::faults::{FaultPlan, FaultState};
use super::metrics::Metrics;
use super::path::{
    sweep_multi_prepared, sweep_prepared, CheckpointSlot, GridPoint, SweepCtl,
};
use super::pool::{Pool, PoolConfig};
use super::prep_cache::PrepCache;
use super::queue::Queue;
use super::sync::{lock, wait_timeout_while};
use crate::linalg::{try_resolve_precision, Design, MultiVec, Precision};
use crate::solvers::elastic_net::{EnProblem, EnSolution, EnSolverKind};
use crate::solvers::svm::SolveCtl;
use crate::solvers::sven::{
    RustBackend, Sven, SvenConfig, SvmMode, SvmPrep, SvmScratch, SvmWarm,
};
use crate::util::Timer;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Condvar, Mutex, OnceLock};
use std::time::Duration;

/// Which solver a job should use.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum BackendChoice {
    /// In-process Newton ("SVEN (CPU)").
    Rust,
    /// AOT artifacts over PJRT ("SVEN (XLA)").
    Xla,
}

/// What a job asks for: one (t, λ₂) point, or a whole warm-start chained
/// path sweep — the paper's Figure-1/2 access pattern as a servable
/// request.
#[derive(Clone, Debug)]
pub enum JobKind {
    /// One constrained-form solve.
    Point { t: f64, lambda2: f64 },
    /// A warm-start chained sweep over the grid against the shared
    /// preparation. Short grids run in order on one worker; long grids
    /// are split into chained segments across the pool
    /// (`ServiceConfig::path_segment_min`) with speculative warm starts
    /// handed across segment boundaries. Either way the result matches
    /// an offline [`PathRunner::run`](super::path::PathRunner::run)
    /// bit-for-bit when the runner keeps its default `warm_start: true`
    /// (path jobs always chain warm starts — that's the amortization
    /// they exist for; a cold-start sweep is just a sequence of `Point`
    /// jobs).
    Path { grid: Vec<GridPoint> },
    /// k-fold cross-validation of the grid: build k fold sub-problems
    /// (contiguous validation slices, training rows gathered once per
    /// fold and shared), sweep each fold's grid through the same
    /// machinery as `Path` — fold×segment work items across the pool,
    /// fold preparations deduplicated by the prep cache — and assemble
    /// the per-λ CV-error curve plus the winning grid point refit on the
    /// full data. Each fold's path is bit-for-bit identical to a
    /// standalone `Path` job on that fold's training data.
    CvPath { folds: usize, grid: Vec<GridPoint> },
    /// A whole screen: sweep the same grid for R response vectors that
    /// share one design — the genomics/multi-target serving pattern. The
    /// job builds **one** preparation (the reduced sample set is
    /// y-independent up to the ±y/t column shifts, which the per-column
    /// shift kernels apply per response), fans out response-chunk work
    /// items across the pool, batches each chunk's (response × grid)
    /// solves through the fused multi-response Newton so R responses
    /// share gathered SV panels and blocked-CG panel products, and
    /// screens responses by λ_max in one fused `XᵀY` panel product
    /// before any solve. Each response's path is bit-for-bit identical
    /// to a standalone `Path` job on (X, yᵣ). Rust backend only.
    MultiResponse { responses: Vec<Arc<Vec<f64>>>, grid: Vec<GridPoint> },
}

/// A solve job. Data sets (dense or sparse [`Design`]s) are shared via
/// `Arc` and identified by `dataset_id` so the service can cache
/// preparations across jobs and workers. The id is a contract: one id ↔
/// one data set. Workers reject a reused id whose design shape differs
/// from the cached preparation; a same-shape different-data reuse is
/// undetectable and yields answers for the originally-prepared data.
pub struct SolveJob {
    pub id: u64,
    pub dataset_id: u64,
    pub x: Arc<Design>,
    pub y: Arc<Vec<f64>>,
    pub kind: JobKind,
    pub backend: BackendChoice,
    /// Where to send the outcome.
    pub reply: Sender<SolveOutcome>,
    /// Submission timestamp (set by `Service::submit`).
    pub submitted: Timer,
    /// Per-submission deadline + retry policy.
    pub options: SubmitOptions,
    /// Admission-budget charge, released when the job drops.
    ticket: Option<CostTicket>,
}

/// Successful payload of a job, mirroring [`JobKind`].
#[derive(Clone, Debug)]
pub enum JobResult {
    Point(EnSolution),
    /// Per-point solutions, in grid order.
    Path(Vec<EnSolution>),
    /// Fold paths, CV-error curve, and the winning refit.
    CvPath(CvPathResult),
    /// Per-response paths plus the screening verdicts.
    MultiResponse(MultiResponseResult),
    /// Graceful degradation under a [`SubmitOptions`] deadline: the job
    /// ran out of wall clock after `completed` of `total` grid points.
    /// `partial` holds the solved prefix — bit-for-bit identical to the
    /// first `completed` points of an undeadlined run (Path and CvPath
    /// carry prefix paths; CvPath's CV curve, winner and refit are
    /// computed over the common fold prefix; MultiResponse paths are
    /// truncated to the shortest chunk's progress). A deadline that
    /// lands before *any* point is solved fails with
    /// [`JobError::DeadlineExceeded`] instead, so `completed >= 1`.
    Truncated { completed: usize, total: usize, partial: Box<JobResult> },
}

/// Result of a `JobKind::MultiResponse` job.
#[derive(Clone, Debug)]
pub struct MultiResponseResult {
    /// Per-response solved paths, in response order. An early-stopped
    /// response carries the solved prefix of the grid (still bit-for-bit
    /// the standalone path's prefix); everyone else carries the full
    /// grid. Screened responses carry all-zero solutions.
    pub paths: Vec<Vec<EnSolution>>,
    /// Per-response λ_max = ‖Xᵀyᵣ‖∞ / n, from the fused screening pass.
    pub lambda_max: Vec<f64>,
    /// Responses the screen retired without any SVM solve (primal mode,
    /// exactly-zero response ⇒ β = 0 at every grid point, provably
    /// bit-identical to solving).
    pub screened: Vec<bool>,
    /// Grid index at which each response's deviance plateaued (its path
    /// still includes that point); `None` ⇒ the full grid was solved.
    pub early_stopped_at: Vec<Option<usize>>,
    /// Per-response numerical-breakdown eviction: `Some(detail)` means
    /// the response tripped the guardrail ladder mid-sweep and was
    /// retired (the member failed, not the batch) — its path holds the
    /// clean prefix solved before the breakdown, and every sibling's
    /// path is bit-identical to a sweep without the sick member.
    pub broken: Vec<Option<String>>,
}

impl JobResult {
    /// Unwrap a point result (panics otherwise — caller bug).
    pub fn expect_point(self) -> EnSolution {
        match self {
            JobResult::Point(sol) => sol,
            _ => panic!("expected a point result"),
        }
    }

    /// Unwrap a path result (panics otherwise — caller bug).
    pub fn expect_path(self) -> Vec<EnSolution> {
        match self {
            JobResult::Path(sols) => sols,
            _ => panic!("expected a path result"),
        }
    }

    /// Unwrap a CV-path result (panics otherwise — caller bug).
    pub fn expect_cv_path(self) -> CvPathResult {
        match self {
            JobResult::CvPath(res) => res,
            _ => panic!("expected a cv-path result"),
        }
    }

    /// Unwrap a multi-response result (panics otherwise — caller bug).
    pub fn expect_multi_response(self) -> MultiResponseResult {
        match self {
            JobResult::MultiResponse(res) => res,
            _ => panic!("expected a multi-response result"),
        }
    }

    /// Unwrap a truncated result into `(completed, total, partial)`
    /// (panics otherwise — caller bug).
    pub fn expect_truncated(self) -> (usize, usize, JobResult) {
        match self {
            JobResult::Truncated { completed, total, partial } => {
                (completed, total, *partial)
            }
            _ => panic!("expected a truncated result"),
        }
    }
}

/// The outcome of a job.
pub struct SolveOutcome {
    pub id: u64,
    pub result: Result<JobResult, JobError>,
    /// Seconds from submit to completion.
    pub total_seconds: f64,
    /// Seconds the job waited in the queue before a worker picked it up.
    pub queue_wait_seconds: f64,
}

/// Service configuration.
#[derive(Clone, Debug)]
pub struct ServiceConfig {
    pub pool: PoolConfig,
    pub sven: SvenConfig,
    /// Artifact directory for XLA workers (None ⇒ default dir).
    pub artifact_dir: Option<std::path::PathBuf>,
    /// Max ready preparations in the shared cache (LRU beyond this).
    pub prep_cache_capacity: usize,
    /// Minimum grid points per segment when splitting one long
    /// `JobKind::Path` grid across pool workers (the segmented path
    /// engine). A grid splits into `min(workers, len / min)` segments,
    /// so grids shorter than `2·min` — and every grid on a one-worker
    /// pool — run unsegmented. `usize::MAX` disables segmentation.
    pub path_segment_min: usize,
    /// Opt-in per-response early stopping for `JobKind::MultiResponse`:
    /// a response retires after grid point k when its training deviance
    /// plateaus (`prev − dev ≤ thresh·prev`). `None` (the default)
    /// solves every grid point, keeping each response's path bit-for-bit
    /// a standalone `Path` job; `Some(thresh)` trades the tail of the
    /// path for throughput while the solved prefix stays bit-identical.
    pub multi_response_early_stop: Option<f64>,
    /// Admission-control budget in *grid-point solves* (`Some(d)` ⇒ a
    /// submission whose cost — grid length × responses × folds — would
    /// push the in-flight total past `d` is shed synchronously with
    /// [`JobError::Overloaded`], before validation and before any worker
    /// is touched). `None` (the default) admits everything.
    pub max_queue_depth: Option<usize>,
    /// Deterministic fault injection for tests and benches (see
    /// [`FaultPlan`]). Production configs leave this `None`, which
    /// compiles every hook down to a skipped `Option` check.
    pub fault_plan: Option<FaultPlan>,
}

impl Default for ServiceConfig {
    fn default() -> Self {
        ServiceConfig {
            pool: PoolConfig::default(),
            sven: SvenConfig::default(),
            artifact_dir: None,
            prep_cache_capacity: 16,
            path_segment_min: 8,
            multi_response_early_stop: None,
            max_queue_depth: None,
            fault_plan: None,
        }
    }
}

/// Invalid [`ServiceConfig`] — returned by [`ServiceConfig::validate`] /
/// [`Service::try_start`] at construction, instead of letting
/// zero-valued knobs reach division or eviction edge cases deep inside
/// the running service.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct ServiceConfigError(String);

impl std::fmt::Display for ServiceConfigError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "invalid service config: {}", self.0)
    }
}

impl std::error::Error for ServiceConfigError {}

impl ServiceConfig {
    /// Check every knob the service would otherwise trip over at
    /// runtime: a zero `path_segment_min` divides by zero when
    /// segmenting (`usize::MAX` is the documented way to disable
    /// segmentation), a zero `prep_cache_capacity` evicts preparations
    /// while they are being shared, a zero-worker or zero-capacity
    /// pool can never make progress, and a compute kernel this CPU (or
    /// a bad `PALLAS_KERNEL`) cannot deliver would panic on the first
    /// product deep inside a worker.
    pub fn validate(&self) -> Result<(), ServiceConfigError> {
        // Resolving the kernel here (including `Auto` through the
        // `PALLAS_KERNEL` env var) turns an unsupported force into a
        // construction-time error instead of a worker-thread panic.
        if let Err(e) = crate::linalg::KernelCtx::for_choice(self.sven.kernel) {
            return Err(ServiceConfigError(e.to_string()));
        }
        // Same treatment for the precision chain: an unparseable
        // `PALLAS_PRECISION` becomes a construction-time error here
        // instead of a panic at the first prep inside a worker.
        if let Err(e) = try_resolve_precision(self.sven.precision) {
            return Err(ServiceConfigError(e.to_string()));
        }
        if self.pool.workers == 0 {
            return Err(ServiceConfigError("pool.workers must be >= 1".into()));
        }
        if self.pool.queue_capacity == 0 {
            return Err(ServiceConfigError(
                "pool.queue_capacity must be >= 1 (a zero-capacity queue accepts nothing)"
                    .into(),
            ));
        }
        if self.prep_cache_capacity == 0 {
            return Err(ServiceConfigError(
                "prep_cache_capacity must be >= 1 (a zero-capacity cache would evict \
                 preparations while workers share them)"
                    .into(),
            ));
        }
        if self.path_segment_min == 0 {
            return Err(ServiceConfigError(
                "path_segment_min must be >= 1 (0 would divide by zero when segmenting; \
                 use usize::MAX to disable segmentation)"
                    .into(),
            ));
        }
        if let Some(thresh) = self.multi_response_early_stop {
            if !thresh.is_finite() || thresh <= 0.0 {
                return Err(ServiceConfigError(format!(
                    "multi_response_early_stop must be a positive finite threshold \
                     (got {thresh}); use None to solve every grid point"
                )));
            }
        }
        if self.max_queue_depth == Some(0) {
            return Err(ServiceConfigError(
                "max_queue_depth must be >= 1 (a zero budget sheds every job); \
                 use None to disable admission control"
                    .into(),
            ));
        }
        Ok(())
    }
}

/// Cache key: one preparation per (data set, backend, precision). The
/// resolved precision is part of the key because a preparation is pinned
/// at build time to its tier (f32 shadows or not) — flipping the process
/// precision must never hand back a prep built under the old tier.
type PrepKey = (u64, BackendChoice, Precision);

/// Parameter validation shared by the workers and the segmenting submit
/// path: bad jobs must become failed outcomes — never a worker panic,
/// and never a late segment failure after earlier segments burned whole
/// sweeps. `points` is every (t, λ₂) that will be solved.
fn validate_job(x: &Design, y: &[f64], points: &[GridPoint]) -> Result<(), String> {
    if x.rows() != y.len() {
        return Err(format!(
            "invalid job: X has {} rows but y has {} entries",
            x.rows(),
            y.len()
        ));
    }
    for gp in points {
        if gp.t.is_nan() || gp.t <= 0.0 {
            return Err(format!("invalid job: t must be positive, got {}", gp.t));
        }
        if gp.lambda2.is_nan() || gp.lambda2 < 0.0 {
            return Err(format!(
                "invalid job: lambda2 must be non-negative, got {}",
                gp.lambda2
            ));
        }
    }
    Ok(())
}

/// True once `deadline` (measured from `submitted`) has passed.
fn deadline_expired(submitted: &Timer, deadline: Option<Duration>) -> bool {
    deadline.is_some_and(|d| submitted.elapsed() >= d.as_secs_f64())
}

/// Contiguous segment sizes for a grid of `len` points over `nseg`
/// segments — the one split formula shared by submission (building the
/// segments) and assembly (detecting deadline-truncated parts).
fn segment_sizes(len: usize, nseg: usize) -> Vec<usize> {
    let base = len / nseg;
    let extra = len % nseg;
    (0..nseg).map(|i| base + usize::from(i < extra)).collect()
}

/// Human-readable payload of a caught panic.
fn panic_message(payload: Box<dyn std::any::Any + Send>) -> String {
    if let Some(s) = payload.downcast_ref::<&str>() {
        (*s).to_string()
    } else if let Some(s) = payload.downcast_ref::<String>() {
        s.clone()
    } else {
        "unknown panic payload".to_string()
    }
}

/// Meter a finished job: truncated successes complete *and* count as
/// truncated; everything else keeps the existing complete/fail split.
fn meter_outcome(
    metrics: &Metrics,
    result: &Result<JobResult, JobError>,
    total: f64,
    queue_wait: f64,
) {
    match result {
        Ok(JobResult::Truncated { .. }) => {
            metrics.on_complete(total, queue_wait);
            metrics.on_truncated();
        }
        Ok(_) => metrics.on_complete(total, queue_wait),
        Err(_) => metrics.on_fail(queue_wait),
    }
}

/// What actually travels through the worker pool: a whole job, one
/// segment of a split `Path` grid, one fold×segment of a `CvPath`, or
/// one response chunk of a `MultiResponse` screen.
enum WorkItem {
    Job(SolveJob),
    Segment(PathSegment),
    CvSegment(CvSegment),
    MultiSegment(MultiSegment),
}

/// How long a segment worker parks on its predecessor's hand-off
/// condvar before falling back to the speculative endpoint re-solve.
/// Both routes are bit-identical, so the wait only trades this job's
/// latency against the duplicated endpoint solve's CPU — worth paying
/// only when the pool has other queued work that CPU could serve.
const HANDOFF_WAIT: Duration = Duration::from_millis(5);

/// A segment-boundary warm-start hand-off slot. The mutexed slot is the
/// PR-7 serialize-else-speculate protocol; the condvar lets an eager
/// successor *wait briefly* for an in-flight predecessor instead of
/// speculating the moment it finds the slot empty.
struct Handoff {
    state: Mutex<HandoffState>,
    cv: Condvar,
}

#[derive(Default)]
struct HandoffState {
    /// The predecessor's endpoint warm start: `None` until published,
    /// and `None` forever when the predecessor was truncated or failed
    /// (its last point is not the endpoint the successor's chain
    /// expects).
    warm: Option<SvmWarm>,
    /// True once the predecessor finished its slice — with or without a
    /// warm start to hand over — so waiters stop waiting either way.
    done: bool,
}

impl Handoff {
    fn new() -> Self {
        Handoff { state: Mutex::new(HandoffState::default()), cv: Condvar::new() }
    }

    /// Record the predecessor's outcome and wake every waiter.
    fn publish(&self, warm: Option<SvmWarm>) {
        let mut st = lock(&self.state);
        st.warm = warm;
        st.done = true;
        self.cv.notify_all();
    }

    /// Take the handed-off warm start. `wait: Some(d)` parks up to `d`
    /// for an unfinished predecessor (a predecessor that never
    /// publishes — lost to a panic — costs exactly the timeout, never a
    /// hang). Returns the warm start plus whether this call parked.
    fn take(&self, wait: Option<Duration>) -> (Option<SvmWarm>, bool) {
        let st = lock(&self.state);
        match wait {
            Some(d) if !st.done => {
                let mut st = wait_timeout_while(&self.cv, st, d, |s| !s.done);
                (st.warm.take(), true)
            }
            _ => {
                let mut st = st;
                (st.warm.take(), false)
            }
        }
    }
}

/// One segment of a segmented path job: the half-open grid range
/// `[start, end)` plus a handle on the job-wide shared state.
struct PathSegment {
    shared: Arc<SegmentedPath>,
    index: usize,
    start: usize,
    end: usize,
}

/// Shared state of a `Path` job split into chained segments.
///
/// Every segment solves its slice of the grid independently; a segment
/// with `start > 0` first re-solves the previous segment's endpoint
/// (`grid[start-1]`) cold and hands its β to its own first point as the
/// warm start — the *speculative warm start*. Speculation is the
/// fallback, not the default: a finishing segment serializes its final
/// solution's warm start into its successor's `handoffs` slot, so a
/// segment that starts after its predecessor finished (always, on a
/// one-worker pool; whenever the queue ran deep otherwise) skips the
/// duplicated endpoint solve entirely. The handed-off warm start is
/// bit-identical to the speculative one — the cold endpoint β equals
/// the chained endpoint β (the invariant below) and `beta_to_warm` is a
/// pure function of it — so taking either route cannot move bits, only
/// wall-clock. The result is bit-for-bit
/// the sequential chain's because the SVM solves are warm-start-
/// invariant in their final iterate: the primal ignores dual warm starts
/// entirely, and the dual active-set Newton's last iterate is the exact
/// Cholesky solve on the final free set, which the warm start can reach
/// faster but (non-degeneracy aside) cannot change. The duplicated
/// endpoint solve is the price of cutting the chain: one extra point per
/// segment, against a ~`segments`-fold wall-clock win on the sweep. The
/// `tests/service.rs` bit-for-bit gate pins the equivalence at 1/2/8
/// workers in both SVM regimes.
struct SegmentedPath {
    id: u64,
    dataset_id: u64,
    x: Arc<Design>,
    y: Arc<Vec<f64>>,
    backend: BackendChoice,
    grid: Vec<GridPoint>,
    /// Reply channel (mutex-wrapped: only the assembling segment sends,
    /// but `Sender` offers no `Sync` guarantee we can rely on here).
    reply: Mutex<Sender<SolveOutcome>>,
    submitted: Timer,
    options: SubmitOptions,
    /// Admission-budget charge, released when the job's shared state
    /// drops (after the last segment finished — panics included).
    #[allow(dead_code)]
    ticket: Option<CostTicket>,
    /// Per-segment results, in segment order. A deadline-truncated
    /// segment records the (possibly empty) solved prefix of its slice.
    parts: Mutex<Vec<Option<Result<Vec<EnSolution>, JobError>>>>,
    /// Segments still outstanding; the worker that drops this to zero
    /// assembles and replies.
    remaining: AtomicUsize,
    /// Earliest submit→pickup wait across segments (the job's effective
    /// queue wait).
    first_pickup: Mutex<Option<f64>>,
    /// Per-segment warm-start hand-off slots: slot k holds segment k−1's
    /// final warm start once that segment lands (slot 0 stays empty —
    /// the first segment starts cold). A segment picking up checks its
    /// slot — parking briefly on the condvar when the pool has other
    /// queued work — before falling back to the speculative endpoint
    /// re-solve.
    handoffs: Vec<Handoff>,
    /// Per-segment sweep checkpoints: retry attempts (worker panics,
    /// stall recovery, deadline sheds) resume the slice from the last
    /// completed grid point instead of re-solving the prefix, and a
    /// deadline shed between attempts still serves the checkpointed
    /// prefix through assembly's truncation path.
    checkpoints: Vec<CheckpointSlot>,
}

impl SegmentedPath {
    /// Record a segment result; the last segment to land assembles the
    /// grid-ordered solution vector and sends the outcome. A segment
    /// shorter than its slice marks a deadline cut: assembly keeps the
    /// contiguous prefix up to the cut (later segments' solutions are
    /// discarded — they are correct but not contiguous) and reports
    /// `Truncated`, or `DeadlineExceeded` when nothing was solved.
    fn finish_segment(
        &self,
        index: usize,
        result: Result<Vec<EnSolution>, JobError>,
        metrics: &Metrics,
    ) {
        {
            let mut parts = lock(&self.parts);
            parts[index] = Some(result);
        }
        if self.remaining.fetch_sub(1, Ordering::AcqRel) != 1 {
            return;
        }
        let total = self.submitted.elapsed();
        let queue_wait = lock(&self.first_pickup).unwrap_or(0.0);
        let parts = lock(&self.parts);
        let sizes = segment_sizes(self.grid.len(), parts.len());
        let mut all = Vec::with_capacity(self.grid.len());
        let mut err: Option<JobError> = None;
        let mut cut = false;
        for (s, part) in parts.iter().enumerate() {
            match part {
                Some(Ok(sols)) => {
                    if cut {
                        continue;
                    }
                    all.extend(sols.iter().cloned());
                    if sols.len() < sizes[s] {
                        cut = true;
                    }
                }
                Some(Err(e)) => {
                    err = Some(e.clone());
                    break;
                }
                None => {
                    err = Some(JobError::Internal(
                        "internal: path segment lost".to_string(),
                    ));
                    break;
                }
            }
        }
        let result = match err {
            Some(e) => Err(e),
            None if cut && all.is_empty() => Err(JobError::DeadlineExceeded),
            None if cut => Ok(JobResult::Truncated {
                completed: all.len(),
                total: self.grid.len(),
                partial: Box::new(JobResult::Path(all)),
            }),
            None => Ok(JobResult::Path(all)),
        };
        meter_outcome(metrics, &result, total, queue_wait);
        let _ = lock(&self.reply).send(SolveOutcome {
            id: self.id,
            result,
            total_seconds: total,
            queue_wait_seconds: queue_wait,
        });
    }
}

/// One fold×segment work item of a `CvPath` job: the half-open grid
/// range `[start, end)` of fold `fold`, plus a handle on the job-wide
/// shared state.
struct CvSegment {
    shared: Arc<SharedCvPath>,
    fold: usize,
    index: usize,
    start: usize,
    end: usize,
}

/// Shared state of a `CvPath` job fanned out as fold×segment work items.
///
/// Fold sub-problems are built **once** — the first worker to touch a
/// fold gathers its training rows (`cv::fold_problem`) under the fold's
/// mutex and every later segment clones the `Arc`s. Fold preparations
/// are deduplicated by the service prep cache under derived dataset ids
/// (`cv::fold_dataset_id`), so k folds × s segments × w workers still
/// build exactly one preparation per fold. Each fold's segments run the
/// same speculative-warm-start chain as a split `Path` job, so fold
/// paths are bit-for-bit standalone path jobs on the fold data.
struct SharedCvPath {
    id: u64,
    dataset_id: u64,
    x: Arc<Design>,
    y: Arc<Vec<f64>>,
    backend: BackendChoice,
    folds: usize,
    grid: Vec<GridPoint>,
    /// Per-fold training sub-problem, built once on first touch.
    fold_data: Vec<Mutex<Option<(Arc<Design>, Arc<Vec<f64>>)>>>,
    reply: Mutex<Sender<SolveOutcome>>,
    submitted: Timer,
    options: SubmitOptions,
    /// Admission-budget charge, released when the job's shared state
    /// drops.
    #[allow(dead_code)]
    ticket: Option<CostTicket>,
    /// Fold-major parts: `parts[fold · nseg + segment]`. A deadline-
    /// truncated part records the solved prefix of its slice.
    parts: Mutex<Vec<Option<Result<Vec<EnSolution>, JobError>>>>,
    /// Parts still outstanding; whoever drops this to zero assembles.
    remaining: AtomicUsize,
    first_pickup: Mutex<Option<f64>>,
    /// Segments per fold (the same split a standalone `Path` job of this
    /// grid would get).
    nseg: usize,
    /// Fold-major warm-start hand-off slots (`fold · nseg + segment`),
    /// the same wait-else-speculate protocol as [`SegmentedPath`]
    /// applied within each fold's chain.
    handoffs: Vec<Handoff>,
    /// Fold-major sweep checkpoints (`fold · nseg + segment`), as in
    /// [`SegmentedPath::checkpoints`].
    checkpoints: Vec<CheckpointSlot>,
}

impl SharedCvPath {
    /// Record one part; returns true when this call was the last one.
    fn record(&self, slot: usize, result: Result<Vec<EnSolution>, JobError>) -> bool {
        {
            let mut parts = lock(&self.parts);
            parts[slot] = Some(result);
        }
        self.remaining.fetch_sub(1, Ordering::AcqRel) == 1
    }

    /// Drain the recorded parts into fold-major paths (first error, in
    /// fold-major order, wins) plus the *common* solved prefix length
    /// across folds — `grid.len()` unless a deadline cut some fold, in
    /// which case each fold keeps its contiguous prefix up to its first
    /// short part and the minimum over folds is what CV can score.
    fn take_fold_paths(&self) -> Result<(Vec<Vec<EnSolution>>, usize), JobError> {
        let mut parts = std::mem::take(&mut *lock(&self.parts));
        let sizes = segment_sizes(self.grid.len(), self.nseg);
        let mut fold_paths = Vec::with_capacity(self.folds);
        let mut completed = self.grid.len();
        for f in 0..self.folds {
            let mut path = Vec::with_capacity(self.grid.len());
            let mut cut = false;
            for s in 0..self.nseg {
                match parts[f * self.nseg + s].take() {
                    Some(Ok(sols)) => {
                        if cut {
                            continue;
                        }
                        if sols.len() < sizes[s] {
                            cut = true;
                        }
                        path.extend(sols);
                    }
                    Some(Err(e)) => return Err(e),
                    None => {
                        return Err(JobError::Internal(
                            "internal: cv segment lost".to_string(),
                        ))
                    }
                }
            }
            completed = completed.min(path.len());
            fold_paths.push(path);
        }
        Ok((fold_paths, completed))
    }

    /// Send the assembled outcome (and meter it).
    fn send_outcome(&self, result: Result<JobResult, JobError>, metrics: &Metrics) {
        let total = self.submitted.elapsed();
        let queue_wait = lock(&self.first_pickup).unwrap_or(0.0);
        meter_outcome(metrics, &result, total, queue_wait);
        let _ = lock(&self.reply).send(SolveOutcome {
            id: self.id,
            result,
            total_seconds: total,
            queue_wait_seconds: queue_wait,
        });
    }
}

/// One response chunk of a `MultiResponse` job: the half-open response
/// range `[start, end)` plus a handle on the job-wide shared state.
struct MultiSegment {
    shared: Arc<SharedMultiResponse>,
    index: usize,
    start: usize,
    end: usize,
}

/// Per-response results of one chunk: solved paths, where (if anywhere)
/// each response's deviance plateaued, which responses the guardrail
/// ladder evicted (with the breakdown detail), and how many grid points
/// the chunk finished before a deadline cut it (`grid.len()` when it
/// ran to completion).
type MultiPart = (Vec<Vec<EnSolution>>, Vec<Option<usize>>, Vec<Option<String>>, usize);

/// The shared screening verdicts of a `MultiResponse` job, computed
/// once by the first chunk to reach a preparation: per-response λ_max
/// from one fused `XᵀY` panel product, and which responses the screen
/// retires outright.
struct ScreenInfo {
    lambda_max: Vec<f64>,
    screened: Vec<bool>,
}

/// Shared state of a `MultiResponse` job fanned out as response-chunk
/// work items.
///
/// All chunks solve against **one** preparation (the reduced sample set
/// is response-independent up to the ±y/t shifts, so the prep built on
/// `responses[0]` serves every response — the prep cache's single-flight
/// build makes that exactly one build per job at any worker count). The
/// first chunk to hold the preparation also computes [`ScreenInfo`] for
/// the whole job under the `screen` mutex; later chunks reuse it.
struct SharedMultiResponse {
    id: u64,
    dataset_id: u64,
    x: Arc<Design>,
    responses: Vec<Arc<Vec<f64>>>,
    backend: BackendChoice,
    grid: Vec<GridPoint>,
    /// Job-wide screening verdicts, lazily built by the first chunk.
    screen: Mutex<Option<Arc<ScreenInfo>>>,
    reply: Mutex<Sender<SolveOutcome>>,
    submitted: Timer,
    options: SubmitOptions,
    /// Admission-budget charge, released when the job's shared state
    /// drops.
    #[allow(dead_code)]
    ticket: Option<CostTicket>,
    /// Per-chunk results, in chunk (= response) order.
    parts: Mutex<Vec<Option<Result<MultiPart, JobError>>>>,
    /// Chunks still outstanding; the worker that drops this to zero
    /// assembles and replies.
    remaining: AtomicUsize,
    first_pickup: Mutex<Option<f64>>,
    /// Per-chunk sweep checkpoints, as in [`SegmentedPath::checkpoints`]
    /// — the multi-response checkpoint additionally carries every
    /// member's warm chain, early-stop and eviction state so a resumed
    /// chunk continues the point-major sweep bit-identically.
    checkpoints: Vec<CheckpointSlot>,
}

impl SharedMultiResponse {
    /// Record a chunk result; the last chunk to land assembles the
    /// response-ordered result and sends the outcome. When a deadline
    /// cut some chunk, every response path is trimmed to the *common*
    /// solved prefix (minimum `points_done` over chunks) so the partial
    /// result stays rectangular, and the job returns `Truncated`.
    fn finish_segment(
        &self,
        index: usize,
        result: Result<MultiPart, JobError>,
        metrics: &Metrics,
    ) {
        {
            let mut parts = lock(&self.parts);
            parts[index] = Some(result);
        }
        if self.remaining.fetch_sub(1, Ordering::AcqRel) != 1 {
            return;
        }
        let total = self.submitted.elapsed();
        let queue_wait = lock(&self.first_pickup).unwrap_or(0.0);
        let mut parts = std::mem::take(&mut *lock(&self.parts));
        let mut paths = Vec::with_capacity(self.responses.len());
        let mut stops = Vec::with_capacity(self.responses.len());
        let mut broken = Vec::with_capacity(self.responses.len());
        let mut completed = self.grid.len();
        let mut err: Option<JobError> = None;
        for part in parts.iter_mut() {
            match part.take() {
                Some(Ok((chunk_paths, chunk_stops, chunk_broken, points_done))) => {
                    completed = completed.min(points_done);
                    paths.extend(chunk_paths);
                    stops.extend(chunk_stops);
                    broken.extend(chunk_broken);
                }
                Some(Err(e)) => {
                    err = Some(e);
                    break;
                }
                None => {
                    err = Some(JobError::Internal(
                        "internal: response chunk lost".to_string(),
                    ));
                    break;
                }
            }
        }
        let result = match err {
            Some(e) => Err(e),
            None if completed == 0 => Err(JobError::DeadlineExceeded),
            None => match lock(&self.screen).clone() {
                Some(screen) => {
                    if completed < self.grid.len() {
                        // Trim every response to the common prefix; an
                        // early-stop index past the cut is no longer an
                        // observed plateau of the partial path. Evicted
                        // members' paths are already shorter than any
                        // completed prefix (their breakdown ended them),
                        // so the trim never touches them.
                        for path in &mut paths {
                            path.truncate(completed);
                        }
                        for stop in &mut stops {
                            if stop.is_some_and(|k| k >= completed) {
                                *stop = None;
                            }
                        }
                    }
                    let inner = JobResult::MultiResponse(MultiResponseResult {
                        paths,
                        lambda_max: screen.lambda_max.clone(),
                        screened: screen.screened.clone(),
                        early_stopped_at: stops,
                        broken,
                    });
                    if completed < self.grid.len() {
                        Ok(JobResult::Truncated {
                            completed,
                            total: self.grid.len(),
                            partial: Box::new(inner),
                        })
                    } else {
                        Ok(inner)
                    }
                }
                // Unreachable in practice: any chunk that returned Ok
                // computed (or reused) the screen first.
                None => Err(JobError::Internal(
                    "internal: screening info missing".to_string(),
                )),
            },
        };
        meter_outcome(metrics, &result, total, queue_wait);
        let _ = lock(&self.reply).send(SolveOutcome {
            id: self.id,
            result,
            total_seconds: total,
            queue_wait_seconds: queue_wait,
        });
    }
}

/// Per-worker solver context: one rust backend, one lazy XLA backend, a
/// per-thread scratch, and a handle on the service-wide shared
/// preparation cache.
struct WorkerCtx {
    rust: Sven<RustBackend>,
    xla: Option<Sven<crate::runtime::XlaBackend>>,
    xla_error: Option<String>,
    preps: Arc<PrepCache<PrepKey>>,
    scratch: SvmScratch,
    config: ServiceConfig,
    metrics: Arc<Metrics>,
    /// Deterministic fault-injection schedule (test/bench only); `None`
    /// in production.
    faults: Option<Arc<FaultState>>,
    /// Live view of the pool queue (set once, right after the pool
    /// spawns): the hand-off wait gate parks for a predecessor only
    /// when other queued work could use the CPU a speculative endpoint
    /// re-solve would burn.
    backlog: Arc<OnceLock<Arc<Queue<WorkItem>>>>,
}

impl WorkerCtx {
    fn new(
        config: ServiceConfig,
        preps: Arc<PrepCache<PrepKey>>,
        metrics: Arc<Metrics>,
        faults: Option<Arc<FaultState>>,
        backlog: Arc<OnceLock<Arc<Queue<WorkItem>>>>,
    ) -> Self {
        WorkerCtx {
            rust: Sven::with_config(RustBackend::default(), config.sven.clone()),
            xla: None,
            xla_error: None,
            preps,
            scratch: SvmScratch::new(),
            config,
            metrics,
            faults,
            backlog,
        }
    }

    /// Work items currently waiting in the pool queue.
    fn queued_work(&self) -> usize {
        self.backlog.get().map_or(0, |q| q.len())
    }

    /// Classify a sweep/solve error string, metering guardrail
    /// breakdowns as they surface.
    fn sweep_error(&self, e: anyhow::Error) -> JobError {
        let err = JobError::from_solver(e.to_string());
        if matches!(err, JobError::NumericalBreakdown { .. }) {
            self.metrics.on_numerical_breakdown();
        }
        err
    }

    /// Fire the per-pickup fault hook (panics on an injected ordinal).
    fn fault_pickup(&self) {
        if let Some(f) = &self.faults {
            f.on_pickup();
        }
    }

    /// Run `f` under per-attempt panic isolation and the job's retry
    /// policy. A panic anywhere inside the attempt — an injected fault,
    /// a kernel assert, a poisoned invariant — is caught here, converted
    /// to [`JobError::WorkerPanic`], and the per-thread scratch is
    /// rebuilt (the unwind may have left it mid-update). Transient
    /// failures (panics, failed preparation builds) retry with capped
    /// exponential backoff as long as the deadline has not passed;
    /// deterministic errors (validation, solver refusals) fail fast.
    fn run_attempts<T>(
        &mut self,
        retry: RetryPolicy,
        expired: impl Fn() -> bool,
        f: impl Fn(&mut Self) -> Result<T, JobError>,
    ) -> Result<T, JobError> {
        let mut attempt = 0u32;
        loop {
            attempt += 1;
            let result = match catch_unwind(AssertUnwindSafe(|| f(self))) {
                Ok(r) => r,
                Err(payload) => {
                    // The unwind may have interrupted a solve mid-flight;
                    // scratch buffers are sized-on-demand caches, so a
                    // fresh one is always safe (and cheap) to swap in.
                    self.scratch = SvmScratch::new();
                    self.metrics.on_worker_panic();
                    Err(JobError::WorkerPanic(panic_message(payload)))
                }
            };
            match result {
                Err(e) if e.is_transient() && attempt < retry.max_attempts && !expired() => {
                    self.metrics.on_job_retried();
                    std::thread::sleep(retry.backoff_for(attempt));
                    // The backoff sleep counts against the job's wall
                    // clock: when it burned the rest of the budget,
                    // shed here instead of launching an attempt that is
                    // already doomed (and could repeat to max_attempts
                    // against an expired deadline). Callers holding a
                    // sweep checkpoint turn this shed into the
                    // checkpointed prefix.
                    if expired() {
                        self.metrics.on_deadline_abort();
                        return Err(JobError::DeadlineExceeded);
                    }
                }
                other => return other,
            }
        }
    }

    fn ensure_xla(&mut self) -> Result<(), String> {
        if self.xla.is_some() {
            return Ok(());
        }
        if let Some(err) = &self.xla_error {
            return Err(err.clone());
        }
        let dir = self
            .config
            .artifact_dir
            .clone()
            .unwrap_or_else(crate::runtime::default_artifact_dir);
        match crate::runtime::XlaEngine::load(&dir) {
            Ok(engine) => {
                let backend = crate::runtime::XlaBackend::new(Arc::new(engine));
                self.xla = Some(Sven::with_config(backend, self.config.sven.clone()));
                Ok(())
            }
            Err(e) => {
                let msg = format!("xla backend unavailable: {e}");
                self.xla_error = Some(msg.clone());
                Err(msg)
            }
        }
    }

    fn handle(&mut self, job: SolveJob) {
        // Real queue wait: submit → worker pickup (the backpressure
        // signal behind `Metrics::queue_wait_summary`).
        let queue_wait = job.submitted.elapsed();
        // Per-job sweep checkpoint: it outlives every retry attempt, so
        // a resumed attempt continues from the last completed grid
        // point instead of re-solving the prefix. Publishing is gated
        // on retries being possible — with one attempt there is nothing
        // to resume, and the default path stays clone-free.
        let checkpoint = CheckpointSlot::default();
        let use_checkpoint =
            job.options.retry.max_attempts > 1 && matches!(job.kind, JobKind::Path { .. });
        let outcome = if deadline_expired(&job.submitted, job.options.deadline) {
            // The whole budget burned in the queue; don't touch a solver.
            self.metrics.on_deadline_abort();
            Err(JobError::DeadlineExceeded)
        } else {
            let deadline = job.options.deadline;
            let submitted = job.submitted.clone();
            let slot = use_checkpoint.then_some(&checkpoint);
            self.run_attempts(
                job.options.retry,
                move || deadline_expired(&submitted, deadline),
                |ctx| {
                    ctx.fault_pickup();
                    ctx.solve(&job, slot)
                },
            )
        };
        // A deadline shed between attempts still owes the caller every
        // checkpointed point: serve the prefix as `Truncated`, exactly
        // as an in-sweep deadline would have.
        let outcome = match outcome {
            Err(JobError::DeadlineExceeded) => {
                let prefix =
                    lock(&checkpoint).take().map(|cp| cp.completed).unwrap_or_default();
                match (&job.kind, prefix.is_empty()) {
                    (JobKind::Path { grid }, false) => Ok(JobResult::Truncated {
                        completed: prefix.len(),
                        total: grid.len(),
                        partial: Box::new(JobResult::Path(prefix)),
                    }),
                    _ => Err(JobError::DeadlineExceeded),
                }
            }
            other => other,
        };
        let total = job.submitted.elapsed();
        meter_outcome(&self.metrics, &outcome, total, queue_wait);
        let _ = job.reply.send(SolveOutcome {
            id: job.id,
            result: outcome,
            total_seconds: total,
            queue_wait_seconds: queue_wait,
        });
    }

    /// Fetch (or single-flight build) the shared preparation for a
    /// (data set, backend) pair.
    fn prep_for(
        &mut self,
        dataset_id: u64,
        backend: BackendChoice,
        x: &Arc<Design>,
        y: &Arc<Vec<f64>>,
    ) -> Result<Arc<dyn SvmPrep>, JobError> {
        if backend == BackendChoice::Xla {
            self.ensure_xla().map_err(JobError::Solver)?;
        }
        // Resolve the precision the prepare below will see (explicit
        // config beats the ambient chain), so the cache key matches what
        // the build pins. Config validation already vetted the env value;
        // re-surface it as a job error rather than unwrap, in case a
        // worker ever runs under an unvalidated config.
        let precision = try_resolve_precision(self.config.sven.precision)
            .map_err(|e| JobError::Solver(e.to_string()))?;
        let key = (dataset_id, backend, precision);
        let rust = &self.rust;
        let xla = &self.xla;
        let metrics = &self.metrics;
        let faults = &self.faults;
        self.preps
            .get_or_build(key, || {
                if let Some(f) = faults {
                    f.on_prep_build()?;
                }
                let prep = match backend {
                    BackendChoice::Rust => {
                        rust.prepare_shared(x, y).map_err(|e| e.to_string())?
                    }
                    BackendChoice::Xla => match xla.as_ref() {
                        Some(xla) => xla.prepare_shared(x, y).map_err(|e| e.to_string())?,
                        None => {
                            return Err("internal: xla backend missing after ensure".into())
                        }
                    },
                };
                metrics.on_f32_panel_bytes(prep.f32_shadow_bytes());
                Ok(prep)
            })
            // A failed or panicked single-flight build is transient: the
            // cache evicted the entry, so a retry rebuilds from scratch.
            .map_err(JobError::PrepFailed)
    }

    /// Shared validation + prep fetch: bad parameters become a failed
    /// outcome, not a worker-thread panic inside `EnProblem`'s (or the
    /// linalg kernels') asserts. `points` is every (t, λ₂) the caller
    /// will solve against the preparation.
    fn checked_prep(
        &mut self,
        dataset_id: u64,
        backend: BackendChoice,
        x: &Arc<Design>,
        y: &Arc<Vec<f64>>,
        points: &[GridPoint],
    ) -> Result<Arc<dyn SvmPrep>, JobError> {
        validate_job(x, y, points).map_err(JobError::Invalid)?;
        let prep = self.prep_for(dataset_id, backend, x, y)?;
        // `dataset_id` is the caller's promise that the data is the same;
        // a reused id with a differently-shaped design would otherwise
        // drive the cached preparation into kernel index asserts (or,
        // worse, silently solve against the wrong matrix). Catch the
        // detectable half of that misuse here.
        let dims = prep.dims();
        if dims != (x.rows(), x.cols()) {
            return Err(JobError::Invalid(format!(
                "invalid job: dataset_id {} was prepared as {}×{} but this job's \
                 design is {}×{} — dataset ids must identify one data set",
                dataset_id,
                dims.0,
                dims.1,
                x.rows(),
                x.cols()
            )));
        }
        Ok(prep)
    }

    fn solve(
        &mut self,
        job: &SolveJob,
        checkpoint: Option<&CheckpointSlot>,
    ) -> Result<JobResult, JobError> {
        let prep = match &job.kind {
            JobKind::Point { t, lambda2 } => self.checked_prep(
                job.dataset_id,
                job.backend,
                &job.x,
                &job.y,
                &[GridPoint { t: *t, lambda2: *lambda2 }],
            ),
            JobKind::Path { grid } => {
                self.checked_prep(job.dataset_id, job.backend, &job.x, &job.y, grid)
            }
            JobKind::CvPath { .. } => {
                return Err(JobError::Internal(
                    "internal: CvPath jobs are dispatched as fold segments".into(),
                ))
            }
            JobKind::MultiResponse { .. } => {
                return Err(JobError::Internal(
                    "internal: MultiResponse jobs are dispatched as response chunks".into(),
                ))
            }
        }?;
        match &job.kind {
            JobKind::Point { t, lambda2 } => {
                // Exactly one fault draw per solve ordinal: `on_solve`
                // fires the delay/panic hooks and reports whether this
                // ordinal's inputs are NaN-poisoned. The poison enters
                // the solver's own arithmetic through `t`, so the
                // numerical guardrails — not the injection site — must
                // stop it from reaching a served β.
                let poisoned = self.faults.as_ref().is_some_and(|f| f.on_solve());
                let t = if poisoned { f64::NAN } else { *t };
                let deadline = job.options.deadline;
                let submitted = job.submitted.clone();
                let expired = move || deadline_expired(&submitted, deadline);
                let sctl =
                    if deadline.is_some() { Some(SolveCtl::new(&expired)) } else { None };
                let prob = EnProblem::shared(job.x.clone(), job.y.clone(), t, *lambda2);
                let sol = match job.backend {
                    BackendChoice::Rust => self.rust.solve_prepared(
                        prep.as_ref(),
                        &mut self.scratch,
                        &prob,
                        None,
                        sctl.as_ref(),
                    ),
                    BackendChoice::Xla => match self.xla.as_ref() {
                        Some(xla) => xla.solve_prepared(
                            prep.as_ref(),
                            &mut self.scratch,
                            &prob,
                            None,
                            sctl.as_ref(),
                        ),
                        None => {
                            return Err(JobError::Internal(
                                "internal: xla backend missing after ensure".into(),
                            ))
                        }
                    },
                }
                .map_err(|e| self.sweep_error(e))?;
                if let Some(detail) = &sol.broken {
                    self.metrics.on_numerical_breakdown();
                    return Err(JobError::NumericalBreakdown {
                        stage: "point".to_string(),
                        detail: detail.clone(),
                    });
                }
                if sol.aborted {
                    // The deadline fired inside the Newton loop; the
                    // half-converged iterate is never served.
                    self.metrics.on_intra_solve_abort();
                    self.metrics.on_deadline_abort();
                    return Err(JobError::DeadlineExceeded);
                }
                self.metrics.on_solve_stats(sol.cg_iters, sol.gather_rebuilds, sol.refine_passes);
                Ok(JobResult::Point(sol))
            }
            JobKind::Path { grid } => {
                let deadline = job.options.deadline;
                let submitted = job.submitted.clone();
                let faults = self.faults.clone();
                let metrics = self.metrics.clone();
                let use_ctl = deadline.is_some() || faults.is_some();
                let expired = move || deadline_expired(&submitted, deadline);
                // Fault hooks draw exactly once per solve ordinal,
                // through the poison closure (`on_solve` fires the
                // delay/panic hooks and returns the NaN verdict);
                // `before_solve` stays a no-op so the ordinal cannot
                // advance twice for one solve.
                let noop = || {};
                let poison = move || faults.as_ref().is_some_and(|f| f.on_solve());
                let on_intra_abort = move || metrics.on_intra_solve_abort();
                let ctl = SweepCtl {
                    expired: &expired,
                    before_solve: &noop,
                    poison: &poison,
                    on_intra_abort: &on_intra_abort,
                };
                let ctl_opt = use_ctl.then_some(&ctl);
                let resumed = checkpoint
                    .map_or(0, |s| lock(s).as_ref().map_or(0, |cp| cp.completed.len()));
                if resumed > 0 {
                    self.metrics.on_resumed_from_checkpoint();
                }
                let (sols, batch) = match job.backend {
                    BackendChoice::Rust => sweep_prepared(
                        &self.rust,
                        prep.as_ref(),
                        &mut self.scratch,
                        &job.x,
                        &job.y,
                        grid,
                        None,
                        true,
                        ctl_opt,
                        checkpoint,
                    ),
                    BackendChoice::Xla => match self.xla.as_ref() {
                        Some(xla) => sweep_prepared(
                            xla,
                            prep.as_ref(),
                            &mut self.scratch,
                            &job.x,
                            &job.y,
                            grid,
                            None,
                            true,
                            ctl_opt,
                            checkpoint,
                        ),
                        None => {
                            return Err(JobError::Internal(
                                "internal: xla backend missing after ensure".into(),
                            ))
                        }
                    },
                }
                .map_err(|e| self.sweep_error(e))?;
                if checkpoint.is_some() {
                    self.metrics
                        .on_checkpoints_published(sols.len().saturating_sub(resumed));
                }
                self.metrics.on_batch_stats(batch.batched_rhs, batch.panel_builds);
                for sol in &sols {
                    self.metrics.on_solve_stats(
                        sol.cg_iters,
                        sol.gather_rebuilds,
                        sol.refine_passes,
                    );
                }
                if sols.len() < grid.len() {
                    // Deadline fired mid-sweep; the solved prefix is
                    // bit-identical to an uncontrolled sweep's.
                    self.metrics.on_deadline_abort();
                    if sols.is_empty() {
                        return Err(JobError::DeadlineExceeded);
                    }
                    return Ok(JobResult::Truncated {
                        completed: sols.len(),
                        total: grid.len(),
                        partial: Box::new(JobResult::Path(sols)),
                    });
                }
                Ok(JobResult::Path(sols))
            }
            JobKind::CvPath { .. } | JobKind::MultiResponse { .. } => {
                unreachable!("handled above")
            }
        }
    }

    /// Run one segment of a split path job: speculative warm start from
    /// the previous segment's endpoint, then the usual chained sweep over
    /// this segment's slice.
    fn handle_segment(&mut self, seg: PathSegment) {
        let sp = seg.shared.clone();
        {
            let wait = sp.submitted.elapsed();
            let mut fp = lock(&sp.first_pickup);
            *fp = Some(fp.map_or(wait, |v| v.min(wait)));
        }
        self.metrics.on_path_segment();
        let result = if deadline_expired(&sp.submitted, sp.options.deadline) {
            // Budget gone before this slice started: record an empty
            // prefix so assembly truncates the path here.
            self.metrics.on_deadline_abort();
            Ok(vec![])
        } else {
            let deadline = sp.options.deadline;
            let submitted = sp.submitted.clone();
            self.run_attempts(
                sp.options.retry,
                move || deadline_expired(&submitted, deadline),
                |ctx| {
                    ctx.fault_pickup();
                    ctx.solve_segment(&seg)
                },
            )
        };
        // A deadline shed between retry attempts still owes assembly
        // the checkpointed slice prefix — the truncation path treats it
        // exactly like an in-sweep deadline cut.
        let result = match result {
            Err(JobError::DeadlineExceeded) => Ok(lock(&sp.checkpoints[seg.index])
                .take()
                .map_or_else(Vec::new, |cp| cp.completed)),
            other => other,
        };
        // Wake any successor parked on our hand-off: a failed or short
        // segment has nothing to hand over.
        if seg.index + 1 < sp.handoffs.len()
            && !matches!(&result, Ok(sols) if sols.len() == seg.end - seg.start)
        {
            sp.handoffs[seg.index + 1].publish(None);
        }
        sp.finish_segment(seg.index, result, &self.metrics);
    }

    fn solve_segment(&mut self, seg: &PathSegment) -> Result<Vec<EnSolution>, JobError> {
        let sp = seg.shared.as_ref();
        // Validate this segment's slice *plus* the speculative endpoint.
        let lo = seg.start.saturating_sub(1);
        let prep = self.checked_prep(
            sp.dataset_id,
            sp.backend,
            &sp.x,
            &sp.y,
            &sp.grid[lo..seg.end],
        )?;
        // Resume state: a retry attempt adopts the checkpointed slice
        // prefix (published by the dead attempt) and skips the warm-up
        // entirely — the checkpoint's warm chain supersedes both the
        // hand-off and the speculative endpoint re-solve.
        let slot = (sp.options.retry.max_attempts > 1).then(|| &sp.checkpoints[seg.index]);
        let resumed =
            slot.map_or(0, |s| lock(s).as_ref().map_or(0, |cp| cp.completed.len()));
        if resumed > 0 {
            self.metrics.on_resumed_from_checkpoint();
        }
        // Warm start for the first point: take the predecessor's
        // handed-off warm start if it already landed — parking briefly
        // on its condvar when the pool has other queued work that the
        // speculative re-solve's CPU could serve instead — and fall
        // back to the speculative endpoint re-solve otherwise. The two
        // warm starts are bit-identical — the cold endpoint β equals
        // the chained β (the `SegmentedPath` invariant) and
        // `beta_to_warm` is a pure function of it — so the route taken
        // is purely a wall-clock decision.
        let mut warm0: Option<SvmWarm> = None;
        if seg.start > 0 && resumed == 0 {
            let wait = (self.queued_work() > 0).then_some(HANDOFF_WAIT);
            let (w, waited) = sp.handoffs[seg.index].take(wait);
            if waited {
                self.metrics.on_segment_handoff_wait();
            }
            if let Some(w) = w {
                self.metrics.on_segment_handoff();
                warm0 = Some(w);
            }
        }
        if seg.start > 0 && warm0.is_none() && resumed == 0 {
            let gp = sp.grid[seg.start - 1];
            let prob = EnProblem::shared(sp.x.clone(), sp.y.clone(), gp.t, gp.lambda2);
            let sol = match sp.backend {
                BackendChoice::Rust => self.rust.solve_prepared(
                    prep.as_ref(),
                    &mut self.scratch,
                    &prob,
                    None,
                    None,
                ),
                BackendChoice::Xla => match self.xla.as_ref() {
                    Some(xla) => xla.solve_prepared(
                        prep.as_ref(),
                        &mut self.scratch,
                        &prob,
                        None,
                        None,
                    ),
                    None => {
                        return Err(JobError::Internal(
                            "internal: xla backend missing after ensure".into(),
                        ))
                    }
                },
            }
            .map_err(|e| self.sweep_error(e))?;
            self.metrics.on_solve_stats(sol.cg_iters, sol.gather_rebuilds, sol.refine_passes);
            warm0 = Some(SvmWarm { w: None, alpha: Some(sol.beta_to_warm(gp.t)) });
        }
        let slice = &sp.grid[seg.start..seg.end];
        let deadline = sp.options.deadline;
        let submitted = sp.submitted.clone();
        let faults = self.faults.clone();
        let metrics = self.metrics.clone();
        let use_ctl = deadline.is_some() || faults.is_some();
        let expired = move || deadline_expired(&submitted, deadline);
        let noop = || {};
        let poison = move || faults.as_ref().is_some_and(|f| f.on_solve());
        let on_intra_abort = move || metrics.on_intra_solve_abort();
        let ctl = SweepCtl {
            expired: &expired,
            before_solve: &noop,
            poison: &poison,
            on_intra_abort: &on_intra_abort,
        };
        let ctl_opt = use_ctl.then_some(&ctl);
        let (sols, batch) = match sp.backend {
            BackendChoice::Rust => sweep_prepared(
                &self.rust,
                prep.as_ref(),
                &mut self.scratch,
                &sp.x,
                &sp.y,
                slice,
                warm0,
                true,
                ctl_opt,
                slot,
            ),
            BackendChoice::Xla => match self.xla.as_ref() {
                Some(xla) => sweep_prepared(
                    xla,
                    prep.as_ref(),
                    &mut self.scratch,
                    &sp.x,
                    &sp.y,
                    slice,
                    warm0,
                    true,
                    ctl_opt,
                    slot,
                ),
                None => {
                    return Err(JobError::Internal(
                        "internal: xla backend missing after ensure".into(),
                    ))
                }
            },
        }
        .map_err(|e| self.sweep_error(e))?;
        if slot.is_some() {
            self.metrics.on_checkpoints_published(sols.len().saturating_sub(resumed));
        }
        if sols.len() == slice.len() {
            // Hand our endpoint warm start to the successor before
            // metering — the earlier it lands, the likelier the successor
            // skips its speculative re-solve. A truncated sweep hands
            // off `None`: its last point is not the slice endpoint the
            // successor's chain expects, but the publish still wakes any
            // waiter.
            if seg.index + 1 < sp.handoffs.len() {
                if let Some(sol) = sols.last() {
                    let gp = sp.grid[seg.end - 1];
                    sp.handoffs[seg.index + 1]
                        .publish(Some(SvmWarm { w: None, alpha: Some(sol.beta_to_warm(gp.t)) }));
                }
            }
        } else {
            self.metrics.on_deadline_abort();
            if seg.index + 1 < sp.handoffs.len() {
                sp.handoffs[seg.index + 1].publish(None);
            }
        }
        self.metrics.on_batch_stats(batch.batched_rhs, batch.panel_builds);
        for sol in &sols {
            self.metrics.on_solve_stats(sol.cg_iters, sol.gather_rebuilds, sol.refine_passes);
        }
        Ok(sols)
    }

    /// Run one fold×segment of a `CvPath` job; the last part to land
    /// assembles the CV curve and refits the winner.
    fn handle_cv_segment(&mut self, seg: CvSegment) {
        let sp = seg.shared.clone();
        {
            let wait = sp.submitted.elapsed();
            let mut fp = lock(&sp.first_pickup);
            *fp = Some(fp.map_or(wait, |v| v.min(wait)));
        }
        let result = if deadline_expired(&sp.submitted, sp.options.deadline) {
            self.metrics.on_deadline_abort();
            Ok(vec![])
        } else {
            let deadline = sp.options.deadline;
            let submitted = sp.submitted.clone();
            self.run_attempts(
                sp.options.retry,
                move || deadline_expired(&submitted, deadline),
                |ctx| {
                    ctx.fault_pickup();
                    ctx.solve_cv_segment(&seg)
                },
            )
        };
        let slot = seg.fold * sp.nseg + seg.index;
        // Deadline shed between attempts → the checkpointed slice
        // prefix, as in `handle_segment`.
        let result = match result {
            Err(JobError::DeadlineExceeded) => Ok(lock(&sp.checkpoints[slot])
                .take()
                .map_or_else(Vec::new, |cp| cp.completed)),
            other => other,
        };
        if seg.index + 1 < sp.nseg
            && !matches!(&result, Ok(sols) if sols.len() == seg.end - seg.start)
        {
            sp.handoffs[slot + 1].publish(None);
        }
        if sp.record(slot, result) {
            // Last part in: assemble under panic isolation too — a panic
            // in the refit must fail this job, not the worker. No retry:
            // assembly drains the recorded parts, so a second attempt
            // would have nothing to assemble.
            let once = RetryPolicy { max_attempts: 1, ..sp.options.retry };
            let outcome = self.run_attempts(once, || false, |ctx| ctx.assemble_cv(&sp));
            sp.send_outcome(outcome, &self.metrics);
        }
    }

    /// The fold-segment solve: fetch (or build, once) the fold's
    /// training sub-problem, then run exactly the split-`Path` segment
    /// logic against it — speculative warm start from the previous grid
    /// point, chained sweep over the slice.
    fn solve_cv_segment(&mut self, seg: &CvSegment) -> Result<Vec<EnSolution>, JobError> {
        let sp = seg.shared.as_ref();
        let (fx, fy) = {
            let mut guard = lock(&sp.fold_data[seg.fold]);
            match &*guard {
                Some(pair) => pair.clone(),
                None => {
                    let pair = cv::fold_problem(&sp.x, &sp.y, sp.folds, seg.fold);
                    self.metrics.on_cv_fold();
                    *guard = Some(pair.clone());
                    pair
                }
            }
        };
        let fold_ds = cv::fold_dataset_id(sp.dataset_id, seg.fold as u64);
        let lo = seg.start.saturating_sub(1);
        let prep = self.checked_prep(fold_ds, sp.backend, &fx, &fy, &sp.grid[lo..seg.end])?;
        // Wait-else-speculate, exactly as in `solve_segment`, but
        // within this fold's chain of hand-off and checkpoint slots.
        let slot0 = seg.fold * sp.nseg;
        let cslot =
            (sp.options.retry.max_attempts > 1).then(|| &sp.checkpoints[slot0 + seg.index]);
        let resumed =
            cslot.map_or(0, |s| lock(s).as_ref().map_or(0, |cp| cp.completed.len()));
        if resumed > 0 {
            self.metrics.on_resumed_from_checkpoint();
        }
        let mut warm0: Option<SvmWarm> = None;
        if seg.start > 0 && resumed == 0 {
            let wait = (self.queued_work() > 0).then_some(HANDOFF_WAIT);
            let (w, waited) = sp.handoffs[slot0 + seg.index].take(wait);
            if waited {
                self.metrics.on_segment_handoff_wait();
            }
            if let Some(w) = w {
                self.metrics.on_segment_handoff();
                warm0 = Some(w);
            }
        }
        if seg.start > 0 && warm0.is_none() && resumed == 0 {
            let gp = sp.grid[seg.start - 1];
            let prob = EnProblem::shared(fx.clone(), fy.clone(), gp.t, gp.lambda2);
            let sol = match sp.backend {
                BackendChoice::Rust => self.rust.solve_prepared(
                    prep.as_ref(),
                    &mut self.scratch,
                    &prob,
                    None,
                    None,
                ),
                BackendChoice::Xla => match self.xla.as_ref() {
                    Some(xla) => xla.solve_prepared(
                        prep.as_ref(),
                        &mut self.scratch,
                        &prob,
                        None,
                        None,
                    ),
                    None => {
                        return Err(JobError::Internal(
                            "internal: xla backend missing after ensure".into(),
                        ))
                    }
                },
            }
            .map_err(|e| self.sweep_error(e))?;
            self.metrics.on_solve_stats(sol.cg_iters, sol.gather_rebuilds, sol.refine_passes);
            warm0 = Some(SvmWarm { w: None, alpha: Some(sol.beta_to_warm(gp.t)) });
        }
        let slice = &sp.grid[seg.start..seg.end];
        let deadline = sp.options.deadline;
        let submitted = sp.submitted.clone();
        let faults = self.faults.clone();
        let metrics = self.metrics.clone();
        let use_ctl = deadline.is_some() || faults.is_some();
        let expired = move || deadline_expired(&submitted, deadline);
        let noop = || {};
        let poison = move || faults.as_ref().is_some_and(|f| f.on_solve());
        let on_intra_abort = move || metrics.on_intra_solve_abort();
        let ctl = SweepCtl {
            expired: &expired,
            before_solve: &noop,
            poison: &poison,
            on_intra_abort: &on_intra_abort,
        };
        let ctl_opt = use_ctl.then_some(&ctl);
        let (sols, batch) = match sp.backend {
            BackendChoice::Rust => sweep_prepared(
                &self.rust,
                prep.as_ref(),
                &mut self.scratch,
                &fx,
                &fy,
                slice,
                warm0,
                true,
                ctl_opt,
                cslot,
            ),
            BackendChoice::Xla => match self.xla.as_ref() {
                Some(xla) => sweep_prepared(
                    xla,
                    prep.as_ref(),
                    &mut self.scratch,
                    &fx,
                    &fy,
                    slice,
                    warm0,
                    true,
                    ctl_opt,
                    cslot,
                ),
                None => {
                    return Err(JobError::Internal(
                        "internal: xla backend missing after ensure".into(),
                    ))
                }
            },
        }
        .map_err(|e| self.sweep_error(e))?;
        if cslot.is_some() {
            self.metrics.on_checkpoints_published(sols.len().saturating_sub(resumed));
        }
        if sols.len() == slice.len() {
            if seg.index + 1 < sp.nseg {
                if let Some(sol) = sols.last() {
                    let gp = sp.grid[seg.end - 1];
                    sp.handoffs[slot0 + seg.index + 1]
                        .publish(Some(SvmWarm { w: None, alpha: Some(sol.beta_to_warm(gp.t)) }));
                }
            }
        } else {
            self.metrics.on_deadline_abort();
            if seg.index + 1 < sp.nseg {
                sp.handoffs[slot0 + seg.index + 1].publish(None);
            }
        }
        self.metrics.on_batch_stats(batch.batched_rhs, batch.panel_builds);
        for sol in &sols {
            self.metrics.on_solve_stats(sol.cg_iters, sol.gather_rebuilds, sol.refine_passes);
        }
        Ok(sols)
    }

    /// Assemble a finished `CvPath`: fold paths → CV-error curve →
    /// winning grid point refit on the full data (its preparation comes
    /// from the same shared cache, so a warm service refits without a
    /// build).
    ///
    /// Under a deadline, every fold path is trimmed to the common solved
    /// prefix and the curve is scored over that prefix; the winner refit
    /// is the one solve allowed past the deadline (a `Truncated` CV
    /// result without its refit would be useless).
    fn assemble_cv(&mut self, sp: &SharedCvPath) -> Result<JobResult, JobError> {
        let (mut fold_paths, completed) = sp.take_fold_paths()?;
        let total = sp.grid.len();
        if completed == 0 {
            return Err(JobError::DeadlineExceeded);
        }
        if completed < total {
            for path in &mut fold_paths {
                path.truncate(completed);
            }
        }
        let cv_errors = cv::cv_error_curve(&sp.x, &sp.y, sp.folds, &fold_paths);
        let best_index = cv::best_index(&cv_errors);
        let gp = sp.grid[best_index];
        let prep = self.checked_prep(sp.dataset_id, sp.backend, &sp.x, &sp.y, &[gp])?;
        let prob = EnProblem::shared(sp.x.clone(), sp.y.clone(), gp.t, gp.lambda2);
        let best = match sp.backend {
            BackendChoice::Rust => {
                self.rust.solve_prepared(prep.as_ref(), &mut self.scratch, &prob, None, None)
            }
            BackendChoice::Xla => match self.xla.as_ref() {
                Some(xla) => {
                    xla.solve_prepared(prep.as_ref(), &mut self.scratch, &prob, None, None)
                }
                None => {
                    return Err(JobError::Internal(
                        "internal: xla backend missing after ensure".into(),
                    ))
                }
            },
        }
        .map_err(|e| self.sweep_error(e))?;
        self.metrics.on_solve_stats(best.cg_iters, best.gather_rebuilds, best.refine_passes);
        let inner = JobResult::CvPath(CvPathResult { fold_paths, cv_errors, best_index, best });
        if completed < total {
            Ok(JobResult::Truncated { completed, total, partial: Box::new(inner) })
        } else {
            Ok(inner)
        }
    }

    /// Run one response chunk of a `MultiResponse` job; the last chunk
    /// to land assembles the response-ordered result and replies.
    fn handle_multi_segment(&mut self, seg: MultiSegment) {
        let sp = seg.shared.clone();
        {
            let wait = sp.submitted.elapsed();
            let mut fp = lock(&sp.first_pickup);
            *fp = Some(fp.map_or(wait, |v| v.min(wait)));
        }
        let result = if deadline_expired(&sp.submitted, sp.options.deadline) {
            // Record a zero-point part: assembly's common prefix becomes
            // empty and the job reports `DeadlineExceeded`.
            self.metrics.on_deadline_abort();
            Ok((
                (seg.start..seg.end).map(|_| Vec::new()).collect(),
                vec![None; seg.end - seg.start],
                vec![None; seg.end - seg.start],
                0,
            ))
        } else {
            let deadline = sp.options.deadline;
            let submitted = sp.submitted.clone();
            self.run_attempts(
                sp.options.retry,
                move || deadline_expired(&submitted, deadline),
                |ctx| {
                    ctx.fault_pickup();
                    ctx.solve_multi_segment(&seg)
                },
            )
        };
        // A retry backoff that burned the deadline still holds whatever
        // prefix earlier attempts checkpointed — record that instead of
        // an error so assembly truncates rather than failing the job.
        let result = match result {
            Err(JobError::DeadlineExceeded) => {
                Ok(self.multi_part_from_checkpoint(&sp, &seg))
            }
            other => other,
        };
        sp.finish_segment(seg.index, result, &self.metrics);
    }

    /// Screening verdicts for the whole job, computed once under the
    /// shared mutex: one fused `XᵀY` panel product gives every
    /// response's λ_max in a single pass over the design. A response is
    /// retired outright only when skipping it provably cannot move bits
    /// — primal mode and an exactly-zero response, where the Newton
    /// solve converges at iteration zero with w = 0 and the back-map
    /// returns exact-zero β at every grid point.
    fn ensure_screen(&self, sp: &SharedMultiResponse, primal: bool) -> Arc<ScreenInfo> {
        let mut guard = lock(&sp.screen);
        if let Some(info) = &*guard {
            return info.clone();
        }
        let n = sp.x.rows();
        let r = sp.responses.len();
        let mut ypanel = MultiVec::zeros(n, r);
        for (j, y) in sp.responses.iter().enumerate() {
            ypanel.col_mut(j).copy_from_slice(y);
        }
        let mut grads = MultiVec::zeros(sp.x.cols(), r);
        sp.x.matvec_t_multi_into(&ypanel, &mut grads);
        let lambda_max: Vec<f64> = (0..r)
            .map(|j| grads.col(j).iter().fold(0.0f64, |m, &g| m.max(g.abs())) / n as f64)
            .collect();
        let screened: Vec<bool> = sp
            .responses
            .iter()
            .map(|y| primal && y.iter().all(|&v| v.to_bits() == 0))
            .collect();
        self.metrics.on_responses(r);
        self.metrics.on_responses_screened(screened.iter().filter(|&&s| s).count());
        let info = Arc::new(ScreenInfo { lambda_max, screened });
        *guard = Some(info.clone());
        info
    }

    /// The chunk solve: fetch the job's one shared preparation (every
    /// chunk asks the single-flight cache for the same key, so it is
    /// built exactly once per job at any worker count), compute or reuse
    /// the screening verdicts, then run one fused multi-response sweep
    /// over this chunk's unscreened responses. The preparation is built
    /// on `responses[0]` but serves every response: the reduced sample
    /// columns are response-independent, and the ±y/t shifts are applied
    /// per solve by the shift-aware kernels.
    fn solve_multi_segment(&mut self, seg: &MultiSegment) -> Result<MultiPart, JobError> {
        let sp = seg.shared.as_ref();
        let prep = self.checked_prep(
            sp.dataset_id,
            sp.backend,
            &sp.x,
            &sp.responses[0],
            &sp.grid,
        )?;
        let screen = self.ensure_screen(sp, prep.mode() == SvmMode::Primal);
        let live: Vec<usize> =
            (seg.start..seg.end).filter(|&r| !screen.screened[r]).collect();
        let cslot = (sp.options.retry.max_attempts > 1).then(|| &sp.checkpoints[seg.index]);
        let (resumed_pts, resumed_broken) = cslot.map_or((0, 0), |s| {
            lock(s).as_ref().and_then(|cp| cp.partial.as_ref()).map_or((0, 0), |p| {
                (p.points_done, p.broken.iter().filter(|b| b.is_some()).count())
            })
        });
        if resumed_pts > 0 {
            self.metrics.on_resumed_from_checkpoint();
        }
        let deadline = sp.options.deadline;
        let submitted = sp.submitted.clone();
        let faults = self.faults.clone();
        let metrics = self.metrics.clone();
        let use_ctl = deadline.is_some() || faults.is_some();
        let expired = move || deadline_expired(&submitted, deadline);
        let noop = || {};
        let poison = move || faults.as_ref().is_some_and(|f| f.on_solve());
        let on_intra_abort = move || metrics.on_intra_solve_abort();
        let ctl = SweepCtl {
            expired: &expired,
            before_solve: &noop,
            poison: &poison,
            on_intra_abort: &on_intra_abort,
        };
        let ctl_opt = use_ctl.then_some(&ctl);
        let out = sweep_multi_prepared(
            &self.rust,
            prep.as_ref(),
            &mut self.scratch,
            &sp.x,
            &sp.responses,
            &live,
            &sp.grid,
            self.config.multi_response_early_stop,
            ctl_opt,
            cslot,
        )
        .map_err(|e| self.sweep_error(e))?;
        self.metrics.on_batch_stats(out.stats.batched_rhs, out.stats.panel_builds);
        if cslot.is_some() {
            self.metrics.on_checkpoints_published(out.points_done.saturating_sub(resumed_pts));
        }
        // Guardrail evictions fail the member, not the batch: meter only
        // the ones this attempt newly retired (a resumed attempt re-sees
        // evictions already counted before the interruption).
        let broken_now = out.broken.iter().filter(|b| b.is_some()).count();
        let newly_evicted = broken_now.saturating_sub(resumed_broken);
        if newly_evicted > 0 {
            self.metrics.on_members_evicted(newly_evicted);
        }
        // `points_done` only means "deadline cut here" when the sweep says
        // so — an all-screened chunk or an every-response early stop also
        // ends the point-major loop short of the grid.
        let points_done = if out.deadline_hit { out.points_done } else { sp.grid.len() };
        if out.deadline_hit {
            self.metrics.on_deadline_abort();
        }
        let mut live_paths = out.paths.into_iter();
        let mut live_stops = out.early_stopped_at.into_iter();
        let mut live_broken = out.broken.into_iter();
        let mut paths = Vec::with_capacity(seg.end - seg.start);
        let mut stops = Vec::with_capacity(seg.end - seg.start);
        let mut broken = Vec::with_capacity(seg.end - seg.start);
        for r in seg.start..seg.end {
            if screen.screened[r] {
                paths.push(self.screened_path(sp, r));
                stops.push(None);
                broken.push(None);
            } else {
                let path = live_paths.next().expect("one path per live response");
                for sol in &path {
                    self.metrics.on_solve_stats(
                        sol.cg_iters,
                        sol.gather_rebuilds,
                        sol.refine_passes,
                    );
                }
                paths.push(path);
                stops.push(live_stops.next().expect("one stop flag per live response"));
                broken.push(live_broken.next().expect("one verdict per live response"));
            }
        }
        self.metrics
            .on_responses_early_stopped(stops.iter().filter(|s| s.is_some()).count());
        Ok((paths, stops, broken, points_done))
    }

    /// Reconstruct a chunk part from whatever earlier attempts
    /// checkpointed, for a chunk whose retry loop ran out of deadline.
    /// Live responses take their checkpointed prefixes; screened
    /// responses regenerate their synthetic paths (assembly truncates
    /// every path to the common completed prefix, so full-length
    /// screened paths are safe). With no checkpoint — or one taken
    /// before the screen verdicts existed — the part is empty and
    /// assembly reports the deadline.
    fn multi_part_from_checkpoint(
        &self,
        sp: &SharedMultiResponse,
        seg: &MultiSegment,
    ) -> MultiPart {
        let w = seg.end - seg.start;
        let empty =
            || ((0..w).map(|_| Vec::new()).collect(), vec![None; w], vec![None; w], 0);
        let Some(cp) = lock(&sp.checkpoints[seg.index]).take() else {
            return empty();
        };
        let Some(partial) = cp.partial else {
            return empty();
        };
        let Some(screen) = lock(&sp.screen).clone() else {
            return empty();
        };
        let mut live_paths = partial.paths.into_iter();
        let mut live_stops = partial.stopped.into_iter();
        let mut live_broken = partial.broken.into_iter();
        let mut paths = Vec::with_capacity(w);
        let mut stops = Vec::with_capacity(w);
        let mut broken = Vec::with_capacity(w);
        for r in seg.start..seg.end {
            if screen.screened[r] {
                paths.push(self.screened_path(sp, r));
                stops.push(None);
                broken.push(None);
            } else {
                paths.push(live_paths.next().unwrap_or_default());
                stops.push(live_stops.next().flatten());
                broken.push(live_broken.next().flatten());
            }
        }
        (paths, stops, broken, partial.points_done)
    }

    /// Path of a screened (exactly-zero, primal-mode) response: β = 0 at
    /// every grid point, with the same fields a real solve of the zero
    /// response produces. The real solve converges at Newton iteration
    /// zero — w = 0 leaves every slack at exactly 1.0, the ±y/t shift
    /// terms vanish with y = 0 and the paired gradient contributions
    /// cancel exactly — before any CG, panel gather or refinement, and
    /// the back-map of the resulting α (`α_j = α_{p+j} = 2C`) yields
    /// exact +0.0 β bits with no degeneracy. Only `seconds` differs,
    /// which nothing bit-compares.
    fn screened_path(&self, sp: &SharedMultiResponse, r: usize) -> Vec<EnSolution> {
        let p = sp.x.cols();
        sp.grid
            .iter()
            .map(|gp| {
                let beta = vec![0.0; p];
                let prob = EnProblem::shared(
                    sp.x.clone(),
                    sp.responses[r].clone(),
                    gp.t,
                    gp.lambda2,
                );
                EnSolution {
                    objective: prob.objective(&beta),
                    beta,
                    solver: EnSolverKind::SvenCpu,
                    iterations: 0,
                    cg_iters: 0,
                    gather_rebuilds: 0,
                    refine_passes: 0,
                    seconds: 0.0,
                    degenerate: None,
                    aborted: false,
                    broken: None,
                }
            })
            .collect()
    }
}

/// The coordinator service.
pub struct Service {
    pool: Pool<WorkItem>,
    metrics: Arc<Metrics>,
    preps: Arc<PrepCache<PrepKey>>,
    next_id: std::sync::atomic::AtomicU64,
    workers: usize,
    path_segment_min: usize,
    /// Admission-control budget (`None` ⇒ unbounded, the default).
    admission: Option<Arc<Admission>>,
}

impl Service {
    /// Start the service, validating the configuration first — the
    /// fallible constructor ([`ServiceConfig::validate`]).
    pub fn try_start(config: ServiceConfig) -> Result<Self, ServiceConfigError> {
        config.validate()?;
        let metrics = Arc::new(Metrics::new());
        // validate() just proved this resolves; record the dispatched
        // kernel + cache geometry so `Metrics::report` names them.
        if let Ok(ctx) = crate::linalg::KernelCtx::for_choice(config.sven.kernel) {
            metrics.set_kernel_info(ctx.describe());
        }
        let preps = Arc::new(PrepCache::new(config.prep_cache_capacity, metrics.clone()));
        let metrics_for_workers = metrics.clone();
        let metrics_for_respawn = metrics.clone();
        let preps_for_workers = preps.clone();
        let workers = config.pool.workers;
        let path_segment_min = config.path_segment_min;
        let admission = config.max_queue_depth.map(|d| Arc::new(Admission::new(d)));
        let faults = config
            .fault_plan
            .as_ref()
            .filter(|plan| !plan.is_empty())
            .map(|plan| Arc::new(FaultState::new(plan.clone())));
        let cfg = config.clone();
        // Workers probe the pool's live backlog to decide whether a
        // hand-off wait is worth parking for; the queue only exists once
        // the pool does, so hand a late-bound cell into the factory and
        // fill it immediately after spawn (before any job can be
        // submitted through the not-yet-constructed `Service`).
        let backlog: Arc<OnceLock<Arc<Queue<WorkItem>>>> = Arc::new(OnceLock::new());
        let backlog_for_workers = backlog.clone();
        let pool = Pool::spawn_supervised(
            &config.pool,
            move |_wid| {
                WorkerCtx::new(
                    cfg.clone(),
                    preps_for_workers.clone(),
                    metrics_for_workers.clone(),
                    faults.clone(),
                    backlog_for_workers.clone(),
                )
            },
            |ctx: &mut WorkerCtx, item: WorkItem| match item {
                WorkItem::Job(job) => ctx.handle(job),
                WorkItem::Segment(seg) => ctx.handle_segment(seg),
                WorkItem::CvSegment(seg) => ctx.handle_cv_segment(seg),
                WorkItem::MultiSegment(seg) => ctx.handle_multi_segment(seg),
            },
            move |_wid| metrics_for_respawn.on_worker_respawn(),
        );
        let _ = backlog.set(pool.queue_handle());
        Ok(Service {
            pool,
            metrics,
            preps,
            next_id: std::sync::atomic::AtomicU64::new(0),
            workers,
            path_segment_min,
            admission,
        })
    }

    /// Start the service with its worker pool and shared prep cache.
    /// Panics on an invalid configuration; use [`Service::try_start`]
    /// to handle [`ServiceConfigError`] gracefully.
    pub fn start(config: ServiceConfig) -> Self {
        match Service::try_start(config) {
            Ok(service) => service,
            Err(e) => panic!("{e}"),
        }
    }

    /// How many segments a path grid of `len` points splits into.
    fn segments_for(&self, len: usize) -> usize {
        if self.workers <= 1 || self.path_segment_min == usize::MAX {
            return 1;
        }
        self.workers.min(len / self.path_segment_min).max(1)
    }

    /// Solve-unit cost of a job for admission control: roughly "how many
    /// grid-point solves does accepting this enqueue".
    fn job_cost(kind: &JobKind) -> usize {
        match kind {
            JobKind::Point { .. } => 1,
            JobKind::Path { grid } => grid.len().max(1),
            JobKind::CvPath { folds, grid } => (folds * grid.len()).max(1),
            JobKind::MultiResponse { responses, grid } => {
                (responses.len() * grid.len()).max(1)
            }
        }
    }

    /// [`Service::submit_with`] with default options (no deadline, no
    /// retries).
    pub fn submit(
        &self,
        dataset_id: u64,
        x: Arc<Design>,
        y: Arc<Vec<f64>>,
        kind: JobKind,
        backend: BackendChoice,
    ) -> Result<Receiver<SolveOutcome>, JobError> {
        self.submit_with(dataset_id, x, y, kind, backend, SubmitOptions::default())
    }

    /// Submit a job; the outcome arrives on the returned receiver.
    /// `Err(JobError::Closed)` when the service no longer accepts work
    /// and `Err(JobError::Overloaded { .. })` when admission control
    /// sheds the job (`max_queue_depth`), so callers can tell "queued"
    /// from "rejected" from "shed". A shed job touches no worker and
    /// builds no state.
    ///
    /// `options.deadline` bounds the job's wall clock from submission:
    /// sweeps check it at grid-point boundaries and return the solved
    /// prefix as [`JobResult::Truncated`] (bit-identical to the same
    /// prefix of an unbounded run). `options.retry` re-runs transient
    /// failures (worker panics, failed preparation builds) with capped
    /// exponential backoff.
    ///
    /// Long `Path` grids are split into `min(workers, len /
    /// path_segment_min)` chained segments dispatched across the pool
    /// (speculative warm starts keep the result bit-for-bit identical to
    /// the single-worker sweep); everything else ships as one work item.
    pub fn submit_with(
        &self,
        dataset_id: u64,
        x: Arc<Design>,
        y: Arc<Vec<f64>>,
        kind: JobKind,
        backend: BackendChoice,
        options: SubmitOptions,
    ) -> Result<Receiver<SolveOutcome>, JobError> {
        // Admission first: a shed job must cost nothing — no id, no
        // channel, no validation, no queue slot.
        let ticket = match &self.admission {
            Some(adm) => {
                let cost = Self::job_cost(&kind);
                match adm.try_admit(cost) {
                    Ok(ticket) => Some(ticket),
                    Err(depth) => {
                        self.metrics.on_shed();
                        return Err(JobError::Overloaded {
                            depth,
                            max_depth: adm.max_depth(),
                            cost,
                        });
                    }
                }
            }
            None => None,
        };
        let (tx, rx) = channel();
        let id = self
            .next_id
            .fetch_add(1, std::sync::atomic::Ordering::Relaxed);
        // Reclaim ownership of the grid so segmentation moves it into the
        // shared state instead of deep-copying a possibly huge Vec.
        let kind = match kind {
            JobKind::Path { grid } => {
                let nseg = self.segments_for(grid.len());
                if nseg > 1 {
                    return self
                        .submit_segmented(
                            id, dataset_id, x, y, grid, backend, tx, nseg, options, ticket,
                        )
                        .map(|()| rx);
                }
                JobKind::Path { grid }
            }
            JobKind::CvPath { folds, grid } => {
                return self
                    .submit_cv(
                        id, dataset_id, x, y, folds, grid, backend, tx, options, ticket,
                    )
                    .map(|()| rx);
            }
            JobKind::MultiResponse { responses, grid } => {
                return self
                    .submit_multi(
                        id, dataset_id, x, responses, grid, backend, tx, options, ticket,
                    )
                    .map(|()| rx);
            }
            point => point,
        };
        let job = SolveJob {
            id,
            dataset_id,
            x,
            y,
            kind,
            backend,
            reply: tx,
            submitted: Timer::start(),
            options,
            ticket,
        };
        match self.pool.submit(WorkItem::Job(job)) {
            Ok(()) => {
                self.metrics.on_submit();
                Ok(rx)
            }
            Err(_job) => {
                self.metrics.on_reject();
                Err(JobError::Closed)
            }
        }
    }

    /// Enqueue a path job as `nseg` contiguous segments. The first
    /// rejected segment (service closing concurrently) is recorded as a
    /// failed part so the assembly still completes — with an error — once
    /// the already-queued segments drain.
    #[allow(clippy::too_many_arguments)]
    fn submit_segmented(
        &self,
        id: u64,
        dataset_id: u64,
        x: Arc<Design>,
        y: Arc<Vec<f64>>,
        grid: Vec<GridPoint>,
        backend: BackendChoice,
        reply: Sender<SolveOutcome>,
        nseg: usize,
        options: SubmitOptions,
        ticket: Option<CostTicket>,
    ) -> Result<(), JobError> {
        // Fail fast on bad parameters: the unsegmented path validates the
        // whole grid before solving anything, so the segmented path must
        // not let an invalid late point waste full sweeps of the earlier
        // segments. Same accepted-then-failed semantics as a worker-side
        // rejection.
        if let Err(e) = validate_job(&x, &y, &grid) {
            self.metrics.on_submit();
            self.metrics.on_fail(0.0);
            let _ = reply.send(SolveOutcome {
                id,
                result: Err(JobError::Invalid(e)),
                total_seconds: 0.0,
                queue_wait_seconds: 0.0,
            });
            return Ok(());
        }
        let sizes = segment_sizes(grid.len(), nseg);
        let shared = Arc::new(SegmentedPath {
            id,
            dataset_id,
            x,
            y,
            backend,
            grid,
            reply: Mutex::new(reply),
            submitted: Timer::start(),
            options,
            ticket,
            parts: Mutex::new((0..nseg).map(|_| None).collect()),
            remaining: AtomicUsize::new(nseg),
            first_pickup: Mutex::new(None),
            handoffs: (0..nseg).map(|_| Handoff::new()).collect(),
            checkpoints: (0..nseg).map(|_| CheckpointSlot::default()).collect(),
        });
        // Contiguous ranges, sized as evenly as integer division allows.
        let mut start = 0usize;
        for (index, &size) in sizes.iter().enumerate() {
            let end = start + size;
            let seg = PathSegment { shared: shared.clone(), index, start, end };
            start = end;
            if self.pool.submit(WorkItem::Segment(seg)).is_err() {
                if index == 0 {
                    // Nothing queued: a plain rejection.
                    self.metrics.on_reject();
                    return Err(JobError::Closed);
                }
                // Closed mid-submit: fail this and every later segment so
                // the already-queued ones still assemble (to an error).
                for later in index..nseg {
                    shared.finish_segment(later, Err(JobError::Closed), &self.metrics);
                }
                break;
            }
        }
        self.metrics.on_submit();
        Ok(())
    }

    /// Enqueue a CV-path job as `folds × nseg` fold-segment work items.
    /// Bad parameters fail fast as an accepted-then-failed outcome
    /// (before any fold burns a sweep); a service closing mid-submit
    /// fails the unqueued parts so the queued ones still assemble.
    #[allow(clippy::too_many_arguments)]
    fn submit_cv(
        &self,
        id: u64,
        dataset_id: u64,
        x: Arc<Design>,
        y: Arc<Vec<f64>>,
        folds: usize,
        grid: Vec<GridPoint>,
        backend: BackendChoice,
        reply: Sender<SolveOutcome>,
        options: SubmitOptions,
        ticket: Option<CostTicket>,
    ) -> Result<(), JobError> {
        let invalid = if folds < 2 {
            Some(format!("invalid job: cv needs at least 2 folds, got {folds}"))
        } else if folds > x.rows() {
            Some(format!(
                "invalid job: {folds} folds exceed the {} data rows",
                x.rows()
            ))
        } else if grid.is_empty() {
            Some("invalid job: cv grid is empty".to_string())
        } else {
            validate_job(&x, &y, &grid).err()
        };
        if let Some(e) = invalid {
            self.metrics.on_submit();
            self.metrics.on_fail(0.0);
            let _ = reply.send(SolveOutcome {
                id,
                result: Err(JobError::Invalid(e)),
                total_seconds: 0.0,
                queue_wait_seconds: 0.0,
            });
            return Ok(());
        }
        // Per-fold segmentation mirrors a standalone `Path` job of this
        // grid exactly (same `segments_for` split), which is what makes
        // fold paths bit-for-bit standalone paths.
        let nseg = self.segments_for(grid.len());
        let sizes = segment_sizes(grid.len(), nseg);
        let shared = Arc::new(SharedCvPath {
            id,
            dataset_id,
            x,
            y,
            backend,
            folds,
            grid,
            fold_data: (0..folds).map(|_| Mutex::new(None)).collect(),
            reply: Mutex::new(reply),
            submitted: Timer::start(),
            options,
            ticket,
            parts: Mutex::new((0..folds * nseg).map(|_| None).collect()),
            remaining: AtomicUsize::new(folds * nseg),
            first_pickup: Mutex::new(None),
            nseg,
            handoffs: (0..folds * nseg).map(|_| Handoff::new()).collect(),
            checkpoints: (0..folds * nseg).map(|_| CheckpointSlot::default()).collect(),
        });
        'folds: for f in 0..folds {
            let mut start = 0usize;
            for (index, &size) in sizes.iter().enumerate() {
                let end = start + size;
                let seg = CvSegment { shared: shared.clone(), fold: f, index, start, end };
                start = end;
                if self.pool.submit(WorkItem::CvSegment(seg)).is_err() {
                    if f == 0 && index == 0 {
                        // Nothing queued: a plain rejection.
                        self.metrics.on_reject();
                        return Err(JobError::Closed);
                    }
                    // Closed mid-submit: fail this and every later part
                    // so the already-queued ones still assemble (to an
                    // error — the assembly scan short-circuits on the
                    // first failed part, so no refit is attempted).
                    for slot in (f * nseg + index)..(folds * nseg) {
                        if shared.record(slot, Err(JobError::Closed)) {
                            let err = match shared.take_fold_paths() {
                                Err(e) => e,
                                Ok(_) => JobError::Internal(
                                    "internal: cv assembly raced".to_string(),
                                ),
                            };
                            shared.send_outcome(Err(err), &self.metrics);
                        }
                    }
                    break 'folds;
                }
            }
        }
        self.metrics.on_submit();
        Ok(())
    }

    /// Enqueue a multi-response job as `segments_for(R)` contiguous
    /// response chunks (the widest chunks the pool can still spread —
    /// wide chunks maximize fused-panel batch width). Bad parameters
    /// fail fast as an accepted-then-failed outcome before any chunk
    /// burns a sweep; a service closing mid-submit fails the unqueued
    /// chunks so the queued ones still assemble (to an error).
    #[allow(clippy::too_many_arguments)]
    fn submit_multi(
        &self,
        id: u64,
        dataset_id: u64,
        x: Arc<Design>,
        responses: Vec<Arc<Vec<f64>>>,
        grid: Vec<GridPoint>,
        backend: BackendChoice,
        reply: Sender<SolveOutcome>,
        options: SubmitOptions,
        ticket: Option<CostTicket>,
    ) -> Result<(), JobError> {
        let invalid = if backend == BackendChoice::Xla {
            // The XLA artifacts are compiled for single-response solves;
            // the fused multi-response batch path is CPU-only for now.
            Some("invalid job: multi-response jobs require the rust backend".to_string())
        } else if responses.is_empty() {
            Some("invalid job: multi-response job has no responses".to_string())
        } else if grid.is_empty() {
            Some("invalid job: multi-response grid is empty".to_string())
        } else {
            let mut err = None;
            for (r, y) in responses.iter().enumerate() {
                if y.len() != x.rows() {
                    err = Some(format!(
                        "invalid job: X has {} rows but response {} has {} entries",
                        x.rows(),
                        r,
                        y.len()
                    ));
                    break;
                }
                if let Some(v) = y.iter().find(|v| !v.is_finite()) {
                    err = Some(format!(
                        "invalid job: response {r} contains a non-finite value ({v})"
                    ));
                    break;
                }
            }
            err.or_else(|| validate_job(&x, &responses[0], &grid).err())
        };
        if let Some(e) = invalid {
            self.metrics.on_submit();
            self.metrics.on_fail(0.0);
            let _ = reply.send(SolveOutcome {
                id,
                result: Err(JobError::Invalid(e)),
                total_seconds: 0.0,
                queue_wait_seconds: 0.0,
            });
            return Ok(());
        }
        let nresp = responses.len();
        let nseg = self.segments_for(nresp);
        let shared = Arc::new(SharedMultiResponse {
            id,
            dataset_id,
            x,
            responses,
            backend,
            grid,
            screen: Mutex::new(None),
            reply: Mutex::new(reply),
            submitted: Timer::start(),
            options,
            ticket,
            parts: Mutex::new((0..nseg).map(|_| None).collect()),
            remaining: AtomicUsize::new(nseg),
            first_pickup: Mutex::new(None),
            checkpoints: (0..nseg).map(|_| CheckpointSlot::default()).collect(),
        });
        let sizes = segment_sizes(nresp, nseg);
        let mut start = 0usize;
        for (index, &size) in sizes.iter().enumerate() {
            let end = start + size;
            let seg = MultiSegment { shared: shared.clone(), index, start, end };
            start = end;
            if self.pool.submit(WorkItem::MultiSegment(seg)).is_err() {
                if index == 0 {
                    // Nothing queued: a plain rejection.
                    self.metrics.on_reject();
                    return Err(JobError::Closed);
                }
                // Closed mid-submit: fail this and every later chunk so
                // the already-queued ones still assemble (to an error).
                for later in index..nseg {
                    shared.finish_segment(later, Err(JobError::Closed), &self.metrics);
                }
                break;
            }
        }
        self.metrics.on_submit();
        Ok(())
    }

    /// Convenience: submit a k-fold cross-validated path sweep.
    pub fn submit_cv_path(
        &self,
        dataset_id: u64,
        x: Arc<Design>,
        y: Arc<Vec<f64>>,
        folds: usize,
        grid: Vec<GridPoint>,
        backend: BackendChoice,
    ) -> Result<Receiver<SolveOutcome>, JobError> {
        self.submit(dataset_id, x, y, JobKind::CvPath { folds, grid }, backend)
    }

    /// [`Service::submit_cv_path`] with explicit [`SubmitOptions`].
    #[allow(clippy::too_many_arguments)]
    pub fn submit_cv_path_with(
        &self,
        dataset_id: u64,
        x: Arc<Design>,
        y: Arc<Vec<f64>>,
        folds: usize,
        grid: Vec<GridPoint>,
        backend: BackendChoice,
        options: SubmitOptions,
    ) -> Result<Receiver<SolveOutcome>, JobError> {
        self.submit_with(dataset_id, x, y, JobKind::CvPath { folds, grid }, backend, options)
    }

    /// Convenience: submit a single (t, λ₂) solve.
    pub fn submit_point(
        &self,
        dataset_id: u64,
        x: Arc<Design>,
        y: Arc<Vec<f64>>,
        t: f64,
        lambda2: f64,
        backend: BackendChoice,
    ) -> Result<Receiver<SolveOutcome>, JobError> {
        self.submit(dataset_id, x, y, JobKind::Point { t, lambda2 }, backend)
    }

    /// Convenience: submit a warm-start chained path sweep.
    pub fn submit_path(
        &self,
        dataset_id: u64,
        x: Arc<Design>,
        y: Arc<Vec<f64>>,
        grid: Vec<GridPoint>,
        backend: BackendChoice,
    ) -> Result<Receiver<SolveOutcome>, JobError> {
        self.submit(dataset_id, x, y, JobKind::Path { grid }, backend)
    }

    /// [`Service::submit_path`] with explicit [`SubmitOptions`].
    pub fn submit_path_with(
        &self,
        dataset_id: u64,
        x: Arc<Design>,
        y: Arc<Vec<f64>>,
        grid: Vec<GridPoint>,
        backend: BackendChoice,
        options: SubmitOptions,
    ) -> Result<Receiver<SolveOutcome>, JobError> {
        self.submit_with(dataset_id, x, y, JobKind::Path { grid }, backend, options)
    }

    /// Convenience: submit a whole-screen multi-response sweep — R
    /// response vectors over one design and one grid, one preparation
    /// build, fused batched solves, λ_max screening.
    pub fn submit_multi_response(
        &self,
        dataset_id: u64,
        x: Arc<Design>,
        responses: Vec<Arc<Vec<f64>>>,
        grid: Vec<GridPoint>,
        backend: BackendChoice,
    ) -> Result<Receiver<SolveOutcome>, JobError> {
        let y = responses.first().cloned().unwrap_or_default();
        self.submit(dataset_id, x, y, JobKind::MultiResponse { responses, grid }, backend)
    }

    /// [`Service::submit_multi_response`] with explicit [`SubmitOptions`].
    pub fn submit_multi_response_with(
        &self,
        dataset_id: u64,
        x: Arc<Design>,
        responses: Vec<Arc<Vec<f64>>>,
        grid: Vec<GridPoint>,
        backend: BackendChoice,
        options: SubmitOptions,
    ) -> Result<Receiver<SolveOutcome>, JobError> {
        let y = responses.first().cloned().unwrap_or_default();
        self.submit_with(
            dataset_id,
            x,
            y,
            JobKind::MultiResponse { responses, grid },
            backend,
            options,
        )
    }

    pub fn metrics(&self) -> &Arc<Metrics> {
        &self.metrics
    }

    /// Ready entries in the shared preparation cache.
    pub fn prep_cache_len(&self) -> usize {
        self.preps.len()
    }

    pub fn backlog(&self) -> usize {
        self.pool.backlog()
    }

    /// Solve-units currently admitted and not yet finished (0 when
    /// admission control is off).
    pub fn admitted_depth(&self) -> usize {
        self.admission.as_ref().map_or(0, |adm| adm.depth())
    }

    /// Stop accepting new jobs; queued work keeps draining. Subsequent
    /// [`Service::submit`] calls return `Err(JobError::Closed)`.
    pub fn close(&self) {
        self.pool.close();
    }

    /// Drain and stop.
    pub fn shutdown(self) {
        self.pool.shutdown();
    }
}

#[cfg(test)]
#[allow(clippy::unwrap_used)]
mod tests {
    use super::*;
    use crate::data::{synth_regression, SynthSpec};
    use crate::solvers::glmnet::{self, GlmnetConfig};

    #[test]
    fn service_solves_jobs_in_parallel() {
        let d = synth_regression(&SynthSpec {
            n: 30,
            p: 20,
            support: 5,
            seed: 301,
            ..Default::default()
        });
        let lambda = glmnet::cd::lambda_max(&d.x, &d.y, 0.5) * 0.3;
        let g = glmnet::solve_penalized(&d.x, &d.y, lambda, &GlmnetConfig::default(), None);
        let t = crate::linalg::vecops::norm1(&g.beta);
        assert!(t > 0.0);
        let lambda2 = 30.0 * lambda * 0.5;

        let service = Service::start(ServiceConfig {
            pool: PoolConfig { workers: 2, queue_capacity: 8 },
            ..Default::default()
        });
        let x = Arc::new(Design::from(d.x.clone()));
        let y = Arc::new(d.y.clone());
        let rxs: Vec<_> = (0..6)
            .map(|i| {
                service
                    .submit_point(
                        1,
                        x.clone(),
                        y.clone(),
                        t * (0.5 + 0.1 * i as f64),
                        lambda2,
                        BackendChoice::Rust,
                    )
                    .expect("service accepting jobs")
            })
            .collect();
        let outcomes: Vec<SolveOutcome> =
            rxs.into_iter().map(|rx| rx.recv().unwrap()).collect();
        assert_eq!(outcomes.len(), 6);
        for o in &outcomes {
            let sol = o.result.clone().expect("solve ok").expect_point();
            assert!(sol.beta.len() == 20);
        }
        assert_eq!(service.metrics().completed(), 6);
        // one data set ⇒ exactly one preparation build, shared by both
        // workers; the other five jobs hit the cache.
        assert_eq!(service.metrics().prep_builds(), 1);
        assert_eq!(service.metrics().prep_hits(), 5);
        assert_eq!(service.prep_cache_len(), 1);
        service.shutdown();
    }

    #[test]
    fn bad_jobs_report_failure_not_panic() {
        let d = synth_regression(&SynthSpec {
            n: 10,
            p: 5,
            support: 2,
            seed: 302,
            ..Default::default()
        });
        let x = Arc::new(Design::from(d.x.clone()));
        let y = Arc::new(d.y.clone());

        // An XLA job with a missing artifact dir exercises the backend
        // error path.
        let mut cfg = ServiceConfig {
            pool: PoolConfig { workers: 1, queue_capacity: 2 },
            ..Default::default()
        };
        cfg.artifact_dir = Some(std::path::PathBuf::from("/nonexistent"));
        let service = Service::start(cfg);
        let rx = service
            .submit_point(7, x.clone(), y.clone(), 0.5, 0.1, BackendChoice::Xla)
            .unwrap();
        let out = rx.recv().unwrap();
        assert!(out.result.is_err());
        assert_eq!(service.metrics().failed(), 1);

        // Invalid parameters (t ≤ 0, λ₂ < 0) come back as failed
        // outcomes, not worker panics.
        let rx = service
            .submit_point(7, x.clone(), y.clone(), -1.0, 0.1, BackendChoice::Rust)
            .unwrap();
        assert!(rx.recv().unwrap().result.is_err());
        let rx = service
            .submit_point(7, x.clone(), y.clone(), 0.5, -0.1, BackendChoice::Rust)
            .unwrap();
        assert!(rx.recv().unwrap().result.is_err());
        // Dimension mismatch (X is 10×5, y has 3 entries) fails the job
        // instead of tripping a kernel assert on the worker thread.
        let rx = service
            .submit_point(8, x.clone(), Arc::new(vec![0.0; 3]), 0.5, 0.1, BackendChoice::Rust)
            .unwrap();
        assert!(rx.recv().unwrap().result.is_err());
        // Reusing a dataset_id for a differently-shaped design is caught
        // against the cached preparation instead of indexing out of
        // bounds in the kernels.
        let rx = service
            .submit_point(9, x, y.clone(), 0.5, 0.1, BackendChoice::Rust)
            .unwrap();
        rx.recv().unwrap().result.expect("good job ok");
        let other = synth_regression(&SynthSpec {
            n: 10,
            p: 4,
            support: 2,
            seed: 303,
            ..Default::default()
        });
        let rx = service
            .submit_point(
                9,
                Arc::new(Design::from(other.x.clone())),
                Arc::new(other.y.clone()),
                0.5,
                0.1,
                BackendChoice::Rust,
            )
            .unwrap();
        let err = rx.recv().unwrap().result.unwrap_err().to_string();
        assert!(err.contains("dataset ids must identify"), "got: {err}");
        assert_eq!(service.metrics().failed(), 5);
        service.shutdown();
    }

    #[test]
    fn submit_after_close_is_rejected() {
        let d = synth_regression(&SynthSpec {
            n: 12,
            p: 6,
            support: 3,
            seed: 303,
            ..Default::default()
        });
        let service = Service::start(ServiceConfig {
            pool: PoolConfig { workers: 1, queue_capacity: 2 },
            ..Default::default()
        });
        let x = Arc::new(Design::from(d.x.clone()));
        let y = Arc::new(d.y.clone());
        service.close();
        let res = service.submit_point(1, x, y, 0.5, 0.1, BackendChoice::Rust);
        assert_eq!(res.err(), Some(JobError::Closed));
        assert_eq!(service.metrics().rejected(), 1);
        assert_eq!(service.metrics().submitted(), 0);
        service.shutdown();
    }

    #[test]
    fn zero_valued_config_knobs_are_rejected_at_construction() {
        let ok = ServiceConfig::default();
        assert!(ok.validate().is_ok());
        let cases: Vec<(&str, ServiceConfig)> = vec![
            (
                "path_segment_min",
                ServiceConfig { path_segment_min: 0, ..Default::default() },
            ),
            (
                "prep_cache_capacity",
                ServiceConfig { prep_cache_capacity: 0, ..Default::default() },
            ),
            (
                "pool.workers",
                ServiceConfig {
                    pool: PoolConfig { workers: 0, queue_capacity: 4 },
                    ..Default::default()
                },
            ),
            (
                "pool.queue_capacity",
                ServiceConfig {
                    pool: PoolConfig { workers: 1, queue_capacity: 0 },
                    ..Default::default()
                },
            ),
            (
                "max_queue_depth",
                ServiceConfig { max_queue_depth: Some(0), ..Default::default() },
            ),
        ];
        for (knob, cfg) in cases {
            let err = cfg.validate().expect_err(knob);
            assert!(err.to_string().contains(knob), "{knob}: {err}");
            assert!(Service::try_start(cfg).is_err(), "{knob} must fail try_start");
        }
        // usize::MAX stays the documented segmentation-off switch.
        let off = ServiceConfig { path_segment_min: usize::MAX, ..Default::default() };
        assert!(off.validate().is_ok());
        // The early-stop threshold must be positive and finite when set.
        for bad in [0.0, -0.5, f64::NAN, f64::INFINITY] {
            let cfg = ServiceConfig {
                multi_response_early_stop: Some(bad),
                ..Default::default()
            };
            let err = cfg.validate().expect_err("early stop threshold");
            assert!(err.to_string().contains("multi_response_early_stop"), "got: {err}");
        }
        let es = ServiceConfig { multi_response_early_stop: Some(1e-3), ..Default::default() };
        assert!(es.validate().is_ok());
    }

    #[test]
    fn cv_jobs_validate_folds_and_grid() {
        let d = synth_regression(&SynthSpec {
            n: 10,
            p: 6,
            support: 3,
            seed: 304,
            ..Default::default()
        });
        let service = Service::start(ServiceConfig {
            pool: PoolConfig { workers: 1, queue_capacity: 8 },
            ..Default::default()
        });
        let x = Arc::new(Design::from(d.x.clone()));
        let y = Arc::new(d.y.clone());
        let grid = vec![GridPoint { t: 0.4, lambda2: 0.5 }];
        // folds < 2
        let rx = service
            .submit_cv_path(1, x.clone(), y.clone(), 1, grid.clone(), BackendChoice::Rust)
            .unwrap();
        let err = rx.recv().unwrap().result.unwrap_err().to_string();
        assert!(err.contains("at least 2 folds"), "got: {err}");
        // folds > n
        let rx = service
            .submit_cv_path(1, x.clone(), y.clone(), 11, grid.clone(), BackendChoice::Rust)
            .unwrap();
        let err = rx.recv().unwrap().result.unwrap_err().to_string();
        assert!(err.contains("exceed"), "got: {err}");
        // empty grid
        let rx = service
            .submit_cv_path(1, x.clone(), y.clone(), 3, Vec::new(), BackendChoice::Rust)
            .unwrap();
        let err = rx.recv().unwrap().result.unwrap_err().to_string();
        assert!(err.contains("grid is empty"), "got: {err}");
        // invalid grid point
        let rx = service
            .submit_cv_path(
                1,
                x,
                y,
                3,
                vec![GridPoint { t: -1.0, lambda2: 0.5 }],
                BackendChoice::Rust,
            )
            .unwrap();
        let err = rx.recv().unwrap().result.unwrap_err().to_string();
        assert!(err.contains("t must be positive"), "got: {err}");
        assert_eq!(service.metrics().failed(), 4);
        assert_eq!(service.metrics().prep_builds(), 0);
        assert_eq!(service.metrics().cv_folds(), 0);
        service.shutdown();
    }

    #[test]
    fn multi_response_jobs_validate_inputs() {
        let d = synth_regression(&SynthSpec {
            n: 10,
            p: 5,
            support: 2,
            seed: 305,
            ..Default::default()
        });
        let service = Service::start(ServiceConfig {
            pool: PoolConfig { workers: 1, queue_capacity: 8 },
            ..Default::default()
        });
        let x = Arc::new(Design::from(d.x.clone()));
        let y = Arc::new(d.y.clone());
        let grid = vec![GridPoint { t: 0.4, lambda2: 0.5 }];
        // no responses
        let rx = service
            .submit_multi_response(1, x.clone(), Vec::new(), grid.clone(), BackendChoice::Rust)
            .unwrap();
        let err = rx.recv().unwrap().result.unwrap_err().to_string();
        assert!(err.contains("no responses"), "got: {err}");
        // empty grid
        let rx = service
            .submit_multi_response(1, x.clone(), vec![y.clone()], Vec::new(), BackendChoice::Rust)
            .unwrap();
        let err = rx.recv().unwrap().result.unwrap_err().to_string();
        assert!(err.contains("grid is empty"), "got: {err}");
        // length mismatch in a later response
        let rx = service
            .submit_multi_response(
                1,
                x.clone(),
                vec![y.clone(), Arc::new(vec![0.0; 3])],
                grid.clone(),
                BackendChoice::Rust,
            )
            .unwrap();
        let err = rx.recv().unwrap().result.unwrap_err().to_string();
        assert!(err.contains("response 1 has 3 entries"), "got: {err}");
        // a NaN hiding in one response
        let rx = service
            .submit_multi_response(
                1,
                x.clone(),
                vec![y.clone(), Arc::new(vec![f64::NAN; 10])],
                grid.clone(),
                BackendChoice::Rust,
            )
            .unwrap();
        let err = rx.recv().unwrap().result.unwrap_err().to_string();
        assert!(err.contains("non-finite"), "got: {err}");
        // bad grid point
        let rx = service
            .submit_multi_response(
                1,
                x.clone(),
                vec![y.clone()],
                vec![GridPoint { t: -1.0, lambda2: 0.5 }],
                BackendChoice::Rust,
            )
            .unwrap();
        let err = rx.recv().unwrap().result.unwrap_err().to_string();
        assert!(err.contains("t must be positive"), "got: {err}");
        // the fused batch path is CPU-only: XLA multi jobs fail cleanly
        let rx = service
            .submit_multi_response(1, x, vec![y], grid, BackendChoice::Xla)
            .unwrap();
        let err = rx.recv().unwrap().result.unwrap_err().to_string();
        assert!(err.contains("require the rust backend"), "got: {err}");
        assert_eq!(service.metrics().failed(), 6);
        assert_eq!(service.metrics().prep_builds(), 0);
        assert_eq!(service.metrics().responses_total(), 0);
        service.shutdown();
    }

    #[test]
    fn multi_response_job_screens_zero_responses_and_builds_one_prep() {
        // 2p > n ⇒ primal regime, where the zero-response screen fires.
        let d = synth_regression(&SynthSpec {
            n: 14,
            p: 20,
            support: 4,
            seed: 306,
            ..Default::default()
        });
        let service = Service::start(ServiceConfig {
            pool: PoolConfig { workers: 2, queue_capacity: 8 },
            ..Default::default()
        });
        let x = Arc::new(Design::from(d.x.clone()));
        let y = Arc::new(d.y.clone());
        let zero = Arc::new(vec![0.0; 14]);
        let grid =
            vec![GridPoint { t: 0.4, lambda2: 0.5 }, GridPoint { t: 0.8, lambda2: 0.5 }];
        let rx = service
            .submit_multi_response(
                1,
                x.clone(),
                vec![y.clone(), zero, y.clone()],
                grid.clone(),
                BackendChoice::Rust,
            )
            .unwrap();
        let res = rx.recv().unwrap().result.expect("multi ok").expect_multi_response();
        assert_eq!(res.paths.len(), 3);
        assert_eq!(res.screened, vec![false, true, false]);
        assert_eq!(res.lambda_max[1], 0.0);
        assert!(res.lambda_max[0] > 0.0);
        assert_eq!(res.early_stopped_at, vec![None, None, None]);
        for path in &res.paths {
            assert_eq!(path.len(), 2);
        }
        for sol in &res.paths[1] {
            assert!(sol.beta.iter().all(|&b| b == 0.0));
            assert_eq!(sol.iterations, 0);
            assert!(sol.degenerate.is_none());
        }
        // responses 0 and 2 carry the same data ⇒ identical bits.
        for (a, b) in res.paths[0].iter().zip(res.paths[2].iter()) {
            let ab: Vec<u64> = a.beta.iter().map(|v| v.to_bits()).collect();
            let bb: Vec<u64> = b.beta.iter().map(|v| v.to_bits()).collect();
            assert_eq!(ab, bb);
            assert_eq!(a.iterations, b.iterations);
        }
        let m = service.metrics();
        assert_eq!(m.prep_builds(), 1);
        assert_eq!(m.responses_total(), 3);
        assert_eq!(m.responses_screened_out(), 1);
        assert_eq!(m.responses_early_stopped(), 0);
        assert!(m.report().contains("responses_total=3"));
        assert!(m.report().contains("responses_screened_out=1"));
        service.shutdown();
    }

    #[test]
    fn startup_records_dispatched_kernel_in_metrics() {
        let service = Service::start(ServiceConfig {
            pool: PoolConfig { workers: 1, queue_capacity: 2 },
            ..Default::default()
        });
        let info = service
            .metrics()
            .kernel_info()
            .expect("kernel info recorded at startup")
            .to_string();
        assert!(info.starts_with("kernel="), "got: {info}");
        assert!(info.contains("cache["), "got: {info}");
        assert!(service.metrics().report().contains(&info));
        service.shutdown();
    }

    #[test]
    fn prep_cache_eviction_under_capacity_pressure() {
        let service = Service::start(ServiceConfig {
            pool: PoolConfig { workers: 1, queue_capacity: 8 },
            prep_cache_capacity: 1,
            ..Default::default()
        });
        for (id, seed) in [(1u64, 311u64), (2, 312), (3, 313)] {
            let d = synth_regression(&SynthSpec {
                n: 24,
                p: 10,
                support: 4,
                seed,
                ..Default::default()
            });
            let rx = service
                .submit_point(
                    id,
                    Arc::new(Design::from(d.x.clone())),
                    Arc::new(d.y.clone()),
                    0.4,
                    0.5,
                    BackendChoice::Rust,
                )
                .unwrap();
            rx.recv().unwrap().result.expect("solve ok");
        }
        assert_eq!(service.metrics().prep_builds(), 3);
        assert_eq!(service.metrics().prep_evictions(), 2);
        assert_eq!(service.prep_cache_len(), 1);
        service.shutdown();
    }
}
