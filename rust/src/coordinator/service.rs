//! The solver service: a leader that accepts Elastic Net solve jobs and
//! dispatches them across the worker pool, with a shared per-dataset
//! preparation cache, warm metrics and graceful drain — the "deployable"
//! face of the SVEN system (exercised end-to-end by
//! `examples/end_to_end.rs`).
//!
//! Zero-copy by construction: a [`SolveJob`] carries `Arc<Design>` /
//! `Arc<Vec<f64>>`, problems are [`EnProblem::shared`] views, and
//! preparations are immutable `Arc<dyn SvmPrep>`s shared by every worker
//! through the single-flight [`PrepCache`] — K jobs on one data set do
//! zero design/response deep copies and exactly one preparation build,
//! regardless of worker count.

use super::metrics::Metrics;
use super::path::{sweep_prepared, GridPoint};
use super::pool::{Pool, PoolConfig};
use super::prep_cache::PrepCache;
use crate::linalg::Design;
use crate::solvers::elastic_net::{EnProblem, EnSolution};
use crate::solvers::sven::{RustBackend, Sven, SvenConfig, SvmPrep, SvmScratch};
use crate::util::Timer;
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::Arc;

/// Which solver a job should use.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum BackendChoice {
    /// In-process Newton ("SVEN (CPU)").
    Rust,
    /// AOT artifacts over PJRT ("SVEN (XLA)").
    Xla,
}

/// What a job asks for: one (t, λ₂) point, or a whole warm-start chained
/// path sweep — the paper's Figure-1/2 access pattern as a servable
/// request.
#[derive(Clone, Debug)]
pub enum JobKind {
    /// One constrained-form solve.
    Point { t: f64, lambda2: f64 },
    /// A warm-start chained sweep over the grid, solved in order on one
    /// worker against the shared preparation. Matches an offline
    /// [`PathRunner::run`](super::path::PathRunner::run) bit-for-bit
    /// when the runner keeps its default `warm_start: true` (path jobs
    /// always chain warm starts — that's the amortization they exist
    /// for; a cold-start sweep is just a sequence of `Point` jobs).
    Path { grid: Vec<GridPoint> },
}

/// A solve job. Data sets (dense or sparse [`Design`]s) are shared via
/// `Arc` and identified by `dataset_id` so the service can cache
/// preparations across jobs and workers. The id is a contract: one id ↔
/// one data set. Workers reject a reused id whose design shape differs
/// from the cached preparation; a same-shape different-data reuse is
/// undetectable and yields answers for the originally-prepared data.
pub struct SolveJob {
    pub id: u64,
    pub dataset_id: u64,
    pub x: Arc<Design>,
    pub y: Arc<Vec<f64>>,
    pub kind: JobKind,
    pub backend: BackendChoice,
    /// Where to send the outcome.
    pub reply: Sender<SolveOutcome>,
    /// Submission timestamp (set by `Service::submit`).
    pub submitted: Timer,
}

/// Successful payload of a job, mirroring [`JobKind`].
#[derive(Clone, Debug)]
pub enum JobResult {
    Point(EnSolution),
    /// Per-point solutions, in grid order.
    Path(Vec<EnSolution>),
}

impl JobResult {
    /// Unwrap a point result (panics on a path result — caller bug).
    pub fn expect_point(self) -> EnSolution {
        match self {
            JobResult::Point(sol) => sol,
            JobResult::Path(_) => panic!("expected a point result, got a path"),
        }
    }

    /// Unwrap a path result (panics on a point result — caller bug).
    pub fn expect_path(self) -> Vec<EnSolution> {
        match self {
            JobResult::Path(sols) => sols,
            JobResult::Point(_) => panic!("expected a path result, got a point"),
        }
    }
}

/// The outcome of a job.
pub struct SolveOutcome {
    pub id: u64,
    pub result: Result<JobResult, String>,
    /// Seconds from submit to completion.
    pub total_seconds: f64,
    /// Seconds the job waited in the queue before a worker picked it up.
    pub queue_wait_seconds: f64,
}

/// Submission rejected: the service has been closed or shut down.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct ServiceClosed;

impl std::fmt::Display for ServiceClosed {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str("service is closed; job rejected")
    }
}

impl std::error::Error for ServiceClosed {}

/// Service configuration.
#[derive(Clone, Debug)]
pub struct ServiceConfig {
    pub pool: PoolConfig,
    pub sven: SvenConfig,
    /// Artifact directory for XLA workers (None ⇒ default dir).
    pub artifact_dir: Option<std::path::PathBuf>,
    /// Max ready preparations in the shared cache (LRU beyond this).
    pub prep_cache_capacity: usize,
}

impl Default for ServiceConfig {
    fn default() -> Self {
        ServiceConfig {
            pool: PoolConfig::default(),
            sven: SvenConfig::default(),
            artifact_dir: None,
            prep_cache_capacity: 16,
        }
    }
}

/// Cache key: one preparation per (data set, backend).
type PrepKey = (u64, BackendChoice);

/// Per-worker solver context: one rust backend, one lazy XLA backend, a
/// per-thread scratch, and a handle on the service-wide shared
/// preparation cache.
struct WorkerCtx {
    rust: Sven<RustBackend>,
    xla: Option<Sven<crate::runtime::XlaBackend>>,
    xla_error: Option<String>,
    preps: Arc<PrepCache<PrepKey>>,
    scratch: SvmScratch,
    config: ServiceConfig,
    metrics: Arc<Metrics>,
}

impl WorkerCtx {
    fn new(
        config: ServiceConfig,
        preps: Arc<PrepCache<PrepKey>>,
        metrics: Arc<Metrics>,
    ) -> Self {
        WorkerCtx {
            rust: Sven::with_config(RustBackend::default(), config.sven.clone()),
            xla: None,
            xla_error: None,
            preps,
            scratch: SvmScratch::new(),
            config,
            metrics,
        }
    }

    fn ensure_xla(&mut self) -> Result<(), String> {
        if self.xla.is_some() {
            return Ok(());
        }
        if let Some(err) = &self.xla_error {
            return Err(err.clone());
        }
        let dir = self
            .config
            .artifact_dir
            .clone()
            .unwrap_or_else(crate::runtime::default_artifact_dir);
        match crate::runtime::XlaEngine::load(&dir) {
            Ok(engine) => {
                let backend = crate::runtime::XlaBackend::new(Arc::new(engine));
                self.xla = Some(Sven::with_config(backend, self.config.sven.clone()));
                Ok(())
            }
            Err(e) => {
                let msg = format!("xla backend unavailable: {e}");
                self.xla_error = Some(msg.clone());
                Err(msg)
            }
        }
    }

    fn handle(&mut self, job: SolveJob) {
        // Real queue wait: submit → worker pickup (the backpressure
        // signal behind `Metrics::queue_wait_summary`).
        let queue_wait = job.submitted.elapsed();
        let outcome = self.solve(&job);
        let total = job.submitted.elapsed();
        match &outcome {
            Ok(_) => self.metrics.on_complete(total, queue_wait),
            Err(_) => self.metrics.on_fail(queue_wait),
        }
        let _ = job.reply.send(SolveOutcome {
            id: job.id,
            result: outcome,
            total_seconds: total,
            queue_wait_seconds: queue_wait,
        });
    }

    /// Fetch (or single-flight build) the shared preparation for a job.
    fn prep_for(&mut self, job: &SolveJob) -> Result<Arc<dyn SvmPrep>, String> {
        if job.backend == BackendChoice::Xla {
            self.ensure_xla()?;
        }
        let key = (job.dataset_id, job.backend);
        let rust = &self.rust;
        let xla = &self.xla;
        self.preps.get_or_build(key, || match job.backend {
            BackendChoice::Rust => {
                rust.prepare_shared(&job.x, &job.y).map_err(|e| e.to_string())
            }
            BackendChoice::Xla => xla
                .as_ref()
                .unwrap()
                .prepare_shared(&job.x, &job.y)
                .map_err(|e| e.to_string()),
        })
    }

    fn solve(&mut self, job: &SolveJob) -> Result<JobResult, String> {
        // Validate up front so bad parameters become a failed outcome,
        // not a worker-thread panic inside `EnProblem`'s (or the linalg
        // kernels') asserts.
        if job.x.rows() != job.y.len() {
            return Err(format!(
                "invalid job: X has {} rows but y has {} entries",
                job.x.rows(),
                job.y.len()
            ));
        }
        let check = |t: f64, lambda2: f64| -> Result<(), String> {
            if t.is_nan() || t <= 0.0 {
                return Err(format!("invalid job: t must be positive, got {t}"));
            }
            if lambda2.is_nan() || lambda2 < 0.0 {
                return Err(format!(
                    "invalid job: lambda2 must be non-negative, got {lambda2}"
                ));
            }
            Ok(())
        };
        match &job.kind {
            JobKind::Point { t, lambda2 } => check(*t, *lambda2),
            JobKind::Path { grid } => grid
                .iter()
                .try_for_each(|gp| check(gp.t, gp.lambda2)),
        }?;
        let prep = self.prep_for(job)?;
        // `dataset_id` is the caller's promise that the data is the same;
        // a reused id with a differently-shaped design would otherwise
        // drive the cached preparation into kernel index asserts (or,
        // worse, silently solve against the wrong matrix). Catch the
        // detectable half of that misuse here.
        let dims = prep.dims();
        if dims != (job.x.rows(), job.x.cols()) {
            return Err(format!(
                "invalid job: dataset_id {} was prepared as {}×{} but this job's \
                 design is {}×{} — dataset ids must identify one data set",
                job.dataset_id,
                dims.0,
                dims.1,
                job.x.rows(),
                job.x.cols()
            ));
        }
        match &job.kind {
            JobKind::Point { t, lambda2 } => {
                let prob = EnProblem::shared(job.x.clone(), job.y.clone(), *t, *lambda2);
                let sol = match job.backend {
                    BackendChoice::Rust => self.rust.solve_prepared(
                        prep.as_ref(),
                        &mut self.scratch,
                        &prob,
                        None,
                    ),
                    BackendChoice::Xla => self.xla.as_ref().unwrap().solve_prepared(
                        prep.as_ref(),
                        &mut self.scratch,
                        &prob,
                        None,
                    ),
                }
                .map_err(|e| e.to_string())?;
                Ok(JobResult::Point(sol))
            }
            JobKind::Path { grid } => {
                let sols = match job.backend {
                    BackendChoice::Rust => sweep_prepared(
                        &self.rust,
                        prep.as_ref(),
                        &mut self.scratch,
                        &job.x,
                        &job.y,
                        grid,
                        true,
                    ),
                    BackendChoice::Xla => sweep_prepared(
                        self.xla.as_ref().unwrap(),
                        prep.as_ref(),
                        &mut self.scratch,
                        &job.x,
                        &job.y,
                        grid,
                        true,
                    ),
                }
                .map_err(|e| e.to_string())?;
                Ok(JobResult::Path(sols))
            }
        }
    }
}

/// The coordinator service.
pub struct Service {
    pool: Pool<SolveJob>,
    metrics: Arc<Metrics>,
    preps: Arc<PrepCache<PrepKey>>,
    next_id: std::sync::atomic::AtomicU64,
}

impl Service {
    /// Start the service with its worker pool and shared prep cache.
    pub fn start(config: ServiceConfig) -> Self {
        let metrics = Arc::new(Metrics::new());
        let preps = Arc::new(PrepCache::new(config.prep_cache_capacity, metrics.clone()));
        let metrics_for_workers = metrics.clone();
        let preps_for_workers = preps.clone();
        let cfg = config.clone();
        let pool = Pool::spawn(
            &config.pool,
            move |_wid| {
                WorkerCtx::new(
                    cfg.clone(),
                    preps_for_workers.clone(),
                    metrics_for_workers.clone(),
                )
            },
            |ctx: &mut WorkerCtx, job: SolveJob| ctx.handle(job),
        );
        Service {
            pool,
            metrics,
            preps,
            next_id: std::sync::atomic::AtomicU64::new(0),
        }
    }

    /// Submit a job; the outcome arrives on the returned receiver.
    /// `Err(ServiceClosed)` when the service no longer accepts work, so
    /// callers can tell "queued" from "rejected".
    pub fn submit(
        &self,
        dataset_id: u64,
        x: Arc<Design>,
        y: Arc<Vec<f64>>,
        kind: JobKind,
        backend: BackendChoice,
    ) -> Result<Receiver<SolveOutcome>, ServiceClosed> {
        let (tx, rx) = channel();
        let id = self
            .next_id
            .fetch_add(1, std::sync::atomic::Ordering::Relaxed);
        let job = SolveJob {
            id,
            dataset_id,
            x,
            y,
            kind,
            backend,
            reply: tx,
            submitted: Timer::start(),
        };
        match self.pool.submit(job) {
            Ok(()) => {
                self.metrics.on_submit();
                Ok(rx)
            }
            Err(_job) => {
                self.metrics.on_reject();
                Err(ServiceClosed)
            }
        }
    }

    /// Convenience: submit a single (t, λ₂) solve.
    pub fn submit_point(
        &self,
        dataset_id: u64,
        x: Arc<Design>,
        y: Arc<Vec<f64>>,
        t: f64,
        lambda2: f64,
        backend: BackendChoice,
    ) -> Result<Receiver<SolveOutcome>, ServiceClosed> {
        self.submit(dataset_id, x, y, JobKind::Point { t, lambda2 }, backend)
    }

    /// Convenience: submit a warm-start chained path sweep.
    pub fn submit_path(
        &self,
        dataset_id: u64,
        x: Arc<Design>,
        y: Arc<Vec<f64>>,
        grid: Vec<GridPoint>,
        backend: BackendChoice,
    ) -> Result<Receiver<SolveOutcome>, ServiceClosed> {
        self.submit(dataset_id, x, y, JobKind::Path { grid }, backend)
    }

    pub fn metrics(&self) -> &Arc<Metrics> {
        &self.metrics
    }

    /// Ready entries in the shared preparation cache.
    pub fn prep_cache_len(&self) -> usize {
        self.preps.len()
    }

    pub fn backlog(&self) -> usize {
        self.pool.backlog()
    }

    /// Stop accepting new jobs; queued work keeps draining. Subsequent
    /// [`Service::submit`] calls return `Err(ServiceClosed)`.
    pub fn close(&self) {
        self.pool.close();
    }

    /// Drain and stop.
    pub fn shutdown(self) {
        self.pool.shutdown();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::{synth_regression, SynthSpec};
    use crate::solvers::glmnet::{self, GlmnetConfig};

    #[test]
    fn service_solves_jobs_in_parallel() {
        let d = synth_regression(&SynthSpec {
            n: 30,
            p: 20,
            support: 5,
            seed: 301,
            ..Default::default()
        });
        let lambda = glmnet::cd::lambda_max(&d.x, &d.y, 0.5) * 0.3;
        let g = glmnet::solve_penalized(&d.x, &d.y, lambda, &GlmnetConfig::default(), None);
        let t = crate::linalg::vecops::norm1(&g.beta);
        assert!(t > 0.0);
        let lambda2 = 30.0 * lambda * 0.5;

        let service = Service::start(ServiceConfig {
            pool: PoolConfig { workers: 2, queue_capacity: 8 },
            ..Default::default()
        });
        let x = Arc::new(Design::from(d.x.clone()));
        let y = Arc::new(d.y.clone());
        let rxs: Vec<_> = (0..6)
            .map(|i| {
                service
                    .submit_point(
                        1,
                        x.clone(),
                        y.clone(),
                        t * (0.5 + 0.1 * i as f64),
                        lambda2,
                        BackendChoice::Rust,
                    )
                    .expect("service accepting jobs")
            })
            .collect();
        let outcomes: Vec<SolveOutcome> =
            rxs.into_iter().map(|rx| rx.recv().unwrap()).collect();
        assert_eq!(outcomes.len(), 6);
        for o in &outcomes {
            let sol = o.result.clone().expect("solve ok").expect_point();
            assert!(sol.beta.len() == 20);
        }
        assert_eq!(service.metrics().completed(), 6);
        // one data set ⇒ exactly one preparation build, shared by both
        // workers; the other five jobs hit the cache.
        assert_eq!(service.metrics().prep_builds(), 1);
        assert_eq!(service.metrics().prep_hits(), 5);
        assert_eq!(service.prep_cache_len(), 1);
        service.shutdown();
    }

    #[test]
    fn bad_jobs_report_failure_not_panic() {
        let d = synth_regression(&SynthSpec {
            n: 10,
            p: 5,
            support: 2,
            seed: 302,
            ..Default::default()
        });
        let x = Arc::new(Design::from(d.x.clone()));
        let y = Arc::new(d.y.clone());

        // An XLA job with a missing artifact dir exercises the backend
        // error path.
        let mut cfg = ServiceConfig {
            pool: PoolConfig { workers: 1, queue_capacity: 2 },
            ..Default::default()
        };
        cfg.artifact_dir = Some(std::path::PathBuf::from("/nonexistent"));
        let service = Service::start(cfg);
        let rx = service
            .submit_point(7, x.clone(), y.clone(), 0.5, 0.1, BackendChoice::Xla)
            .unwrap();
        let out = rx.recv().unwrap();
        assert!(out.result.is_err());
        assert_eq!(service.metrics().failed(), 1);

        // Invalid parameters (t ≤ 0, λ₂ < 0) come back as failed
        // outcomes, not worker panics.
        let rx = service
            .submit_point(7, x.clone(), y.clone(), -1.0, 0.1, BackendChoice::Rust)
            .unwrap();
        assert!(rx.recv().unwrap().result.is_err());
        let rx = service
            .submit_point(7, x.clone(), y.clone(), 0.5, -0.1, BackendChoice::Rust)
            .unwrap();
        assert!(rx.recv().unwrap().result.is_err());
        // Dimension mismatch (X is 10×5, y has 3 entries) fails the job
        // instead of tripping a kernel assert on the worker thread.
        let rx = service
            .submit_point(8, x.clone(), Arc::new(vec![0.0; 3]), 0.5, 0.1, BackendChoice::Rust)
            .unwrap();
        assert!(rx.recv().unwrap().result.is_err());
        // Reusing a dataset_id for a differently-shaped design is caught
        // against the cached preparation instead of indexing out of
        // bounds in the kernels.
        let rx = service
            .submit_point(9, x, y.clone(), 0.5, 0.1, BackendChoice::Rust)
            .unwrap();
        rx.recv().unwrap().result.expect("good job ok");
        let other = synth_regression(&SynthSpec {
            n: 10,
            p: 4,
            support: 2,
            seed: 303,
            ..Default::default()
        });
        let rx = service
            .submit_point(
                9,
                Arc::new(Design::from(other.x.clone())),
                Arc::new(other.y.clone()),
                0.5,
                0.1,
                BackendChoice::Rust,
            )
            .unwrap();
        let err = rx.recv().unwrap().result.unwrap_err();
        assert!(err.contains("dataset ids must identify"), "got: {err}");
        assert_eq!(service.metrics().failed(), 5);
        service.shutdown();
    }

    #[test]
    fn submit_after_close_is_rejected() {
        let d = synth_regression(&SynthSpec {
            n: 12,
            p: 6,
            support: 3,
            seed: 303,
            ..Default::default()
        });
        let service = Service::start(ServiceConfig {
            pool: PoolConfig { workers: 1, queue_capacity: 2 },
            ..Default::default()
        });
        let x = Arc::new(Design::from(d.x.clone()));
        let y = Arc::new(d.y.clone());
        service.close();
        let res = service.submit_point(1, x, y, 0.5, 0.1, BackendChoice::Rust);
        assert_eq!(res.err(), Some(ServiceClosed));
        assert_eq!(service.metrics().rejected(), 1);
        assert_eq!(service.metrics().submitted(), 0);
        service.shutdown();
    }

    #[test]
    fn prep_cache_eviction_under_capacity_pressure() {
        let service = Service::start(ServiceConfig {
            pool: PoolConfig { workers: 1, queue_capacity: 8 },
            prep_cache_capacity: 1,
            ..Default::default()
        });
        for (id, seed) in [(1u64, 311u64), (2, 312), (3, 313)] {
            let d = synth_regression(&SynthSpec {
                n: 24,
                p: 10,
                support: 4,
                seed,
                ..Default::default()
            });
            let rx = service
                .submit_point(
                    id,
                    Arc::new(Design::from(d.x.clone())),
                    Arc::new(d.y.clone()),
                    0.4,
                    0.5,
                    BackendChoice::Rust,
                )
                .unwrap();
            rx.recv().unwrap().result.expect("solve ok");
        }
        assert_eq!(service.metrics().prep_builds(), 3);
        assert_eq!(service.metrics().prep_evictions(), 2);
        assert_eq!(service.prep_cache_len(), 1);
        service.shutdown();
    }
}
