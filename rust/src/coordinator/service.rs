//! The solver service: a leader that accepts Elastic Net solve jobs and
//! dispatches them across the worker pool, with per-dataset preparation
//! caching, warm metrics and graceful drain — the "deployable" face of
//! the SVEN system (exercised end-to-end by `examples/end_to_end.rs`).

use super::metrics::Metrics;
use super::pool::{Pool, PoolConfig};
use crate::linalg::Design;
use crate::solvers::elastic_net::{EnProblem, EnSolution};
use crate::solvers::sven::{RustBackend, Sven, SvenConfig};
use crate::util::Timer;
use std::collections::HashMap;
use std::sync::mpsc::{channel, Sender};
use std::sync::Arc;

/// Which solver a job should use.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum BackendChoice {
    /// In-process Newton ("SVEN (CPU)").
    Rust,
    /// AOT artifacts over PJRT ("SVEN (XLA)").
    Xla,
}

/// A solve job. Data sets (dense or sparse [`Design`]s) are shared via
/// `Arc` and identified by `dataset_id` so workers can cache
/// preparations across jobs.
pub struct SolveJob {
    pub id: u64,
    pub dataset_id: u64,
    pub x: Arc<Design>,
    pub y: Arc<Vec<f64>>,
    pub t: f64,
    pub lambda2: f64,
    pub backend: BackendChoice,
    /// Where to send the outcome.
    pub reply: Sender<SolveOutcome>,
    /// Submission timestamp (set by `Service::submit`).
    pub submitted: Timer,
}

/// The outcome of a job.
pub struct SolveOutcome {
    pub id: u64,
    pub result: Result<EnSolution, String>,
    /// Seconds from submit to completion.
    pub total_seconds: f64,
}

/// Service configuration.
#[derive(Clone, Debug)]
pub struct ServiceConfig {
    pub pool: PoolConfig,
    pub sven: SvenConfig,
    /// Artifact directory for XLA workers (None ⇒ default dir).
    pub artifact_dir: Option<std::path::PathBuf>,
}

impl Default for ServiceConfig {
    fn default() -> Self {
        ServiceConfig {
            pool: PoolConfig::default(),
            sven: SvenConfig::default(),
            artifact_dir: None,
        }
    }
}

/// Per-worker solver context: one rust backend, one lazy XLA backend, and
/// a preparation cache keyed by (dataset, backend, shape).
struct WorkerCtx {
    rust: Sven<RustBackend>,
    xla: Option<Sven<crate::runtime::XlaBackend>>,
    xla_error: Option<String>,
    preps: HashMap<(u64, BackendChoice), Box<dyn crate::solvers::sven::PreparedSvm>>,
    config: ServiceConfig,
    metrics: Arc<Metrics>,
}

impl WorkerCtx {
    fn new(config: ServiceConfig, metrics: Arc<Metrics>) -> Self {
        WorkerCtx {
            rust: Sven::with_config(RustBackend::default(), config.sven.clone()),
            xla: None,
            xla_error: None,
            preps: HashMap::new(),
            config,
            metrics,
        }
    }

    fn ensure_xla(&mut self) -> Result<(), String> {
        if self.xla.is_some() {
            return Ok(());
        }
        if let Some(err) = &self.xla_error {
            return Err(err.clone());
        }
        let dir = self
            .config
            .artifact_dir
            .clone()
            .unwrap_or_else(crate::runtime::default_artifact_dir);
        match crate::runtime::XlaEngine::load(&dir) {
            Ok(engine) => {
                let backend = crate::runtime::XlaBackend::new(Arc::new(engine));
                self.xla = Some(Sven::with_config(backend, self.config.sven.clone()));
                Ok(())
            }
            Err(e) => {
                let msg = format!("xla backend unavailable: {e}");
                self.xla_error = Some(msg.clone());
                Err(msg)
            }
        }
    }

    fn handle(&mut self, job: SolveJob) {
        let outcome = self.solve(&job);
        let total = job.submitted.elapsed();
        match &outcome {
            Ok(_) => self.metrics.on_complete(total, 0.0),
            Err(_) => self.metrics.on_fail(),
        }
        let _ = job.reply.send(SolveOutcome {
            id: job.id,
            result: outcome,
            total_seconds: total,
        });
    }

    fn solve(&mut self, job: &SolveJob) -> Result<EnSolution, String> {
        let prob = EnProblem::new(
            (*job.x).clone(),
            (*job.y).clone(),
            job.t,
            job.lambda2,
        );
        let key = (job.dataset_id, job.backend);
        // Build (or fetch) the preparation for this dataset+backend.
        if !self.preps.contains_key(&key) {
            let prep = match job.backend {
                BackendChoice::Rust => self
                    .rust
                    .prepare(job.x.as_ref(), &job.y)
                    .map_err(|e| e.to_string())?,
                BackendChoice::Xla => {
                    self.ensure_xla()?;
                    self.xla
                        .as_ref()
                        .unwrap()
                        .prepare(job.x.as_ref(), &job.y)
                        .map_err(|e| e.to_string())?
                }
            };
            self.preps.insert(key, prep);
        }
        let prep = self.preps.get_mut(&key).unwrap();
        let sven_result = match job.backend {
            BackendChoice::Rust => {
                self.rust.solve_prepared(prep.as_mut(), &prob, None)
            }
            BackendChoice::Xla => {
                self.xla.as_ref().unwrap().solve_prepared(prep.as_mut(), &prob, None)
            }
        };
        sven_result.map_err(|e| e.to_string())
    }
}

/// The coordinator service.
pub struct Service {
    pool: Pool<SolveJob>,
    metrics: Arc<Metrics>,
    next_id: std::sync::atomic::AtomicU64,
}

impl Service {
    /// Start the service with its worker pool.
    pub fn start(config: ServiceConfig) -> Self {
        let metrics = Arc::new(Metrics::new());
        let metrics_for_workers = metrics.clone();
        let cfg = config.clone();
        let pool = Pool::spawn(
            &config.pool,
            move |_wid| WorkerCtx::new(cfg.clone(), metrics_for_workers.clone()),
            |ctx: &mut WorkerCtx, job: SolveJob| ctx.handle(job),
        );
        Service {
            pool,
            metrics,
            next_id: std::sync::atomic::AtomicU64::new(0),
        }
    }

    /// Submit a solve; the outcome arrives on the returned receiver.
    #[allow(clippy::too_many_arguments)]
    pub fn submit(
        &self,
        dataset_id: u64,
        x: Arc<Design>,
        y: Arc<Vec<f64>>,
        t: f64,
        lambda2: f64,
        backend: BackendChoice,
    ) -> std::sync::mpsc::Receiver<SolveOutcome> {
        let (tx, rx) = channel();
        let id = self
            .next_id
            .fetch_add(1, std::sync::atomic::Ordering::Relaxed);
        self.metrics.on_submit();
        let job = SolveJob {
            id,
            dataset_id,
            x,
            y,
            t,
            lambda2,
            backend,
            reply: tx,
            submitted: Timer::start(),
        };
        if self.pool.submit(job).is_err() {
            // pool already shut down; the receiver will simply disconnect
        }
        rx
    }

    pub fn metrics(&self) -> &Arc<Metrics> {
        &self.metrics
    }

    pub fn backlog(&self) -> usize {
        self.pool.backlog()
    }

    /// Drain and stop.
    pub fn shutdown(self) {
        self.pool.shutdown();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::{synth_regression, SynthSpec};
    use crate::solvers::glmnet::{self, GlmnetConfig};

    #[test]
    fn service_solves_jobs_in_parallel() {
        let d = synth_regression(&SynthSpec {
            n: 30,
            p: 20,
            support: 5,
            seed: 301,
            ..Default::default()
        });
        let lambda = glmnet::cd::lambda_max(&d.x, &d.y, 0.5) * 0.3;
        let g = glmnet::solve_penalized(&d.x, &d.y, lambda, &GlmnetConfig::default(), None);
        let t = crate::linalg::vecops::norm1(&g.beta);
        assert!(t > 0.0);
        let lambda2 = 30.0 * lambda * 0.5;

        let service = Service::start(ServiceConfig {
            pool: PoolConfig { workers: 2, queue_capacity: 8 },
            ..Default::default()
        });
        let x = Arc::new(Design::from(d.x.clone()));
        let y = Arc::new(d.y.clone());
        let rxs: Vec<_> = (0..6)
            .map(|i| {
                service.submit(
                    1,
                    x.clone(),
                    y.clone(),
                    t * (0.5 + 0.1 * i as f64),
                    lambda2,
                    BackendChoice::Rust,
                )
            })
            .collect();
        let outcomes: Vec<SolveOutcome> =
            rxs.into_iter().map(|rx| rx.recv().unwrap()).collect();
        assert_eq!(outcomes.len(), 6);
        for o in &outcomes {
            let sol = o.result.as_ref().expect("solve ok");
            assert!(sol.beta.len() == 20);
        }
        assert_eq!(service.metrics().completed(), 6);
        service.shutdown();
    }

    #[test]
    fn bad_jobs_report_failure_not_panic() {
        let service = Service::start(ServiceConfig {
            pool: PoolConfig { workers: 1, queue_capacity: 2 },
            ..Default::default()
        });
        // λ₂ < 0 panics inside EnProblem::new — the worker must catch this
        // as an error... EnProblem asserts, so instead feed an XLA job with
        // a missing artifact dir to exercise the error path.
        let d = synth_regression(&SynthSpec {
            n: 10,
            p: 5,
            support: 2,
            seed: 302,
            ..Default::default()
        });
        let mut cfg = ServiceConfig {
            pool: PoolConfig { workers: 1, queue_capacity: 2 },
            ..Default::default()
        };
        cfg.artifact_dir = Some(std::path::PathBuf::from("/nonexistent"));
        let service2 = Service::start(cfg);
        let rx = service2.submit(
            7,
            Arc::new(Design::from(d.x.clone())),
            Arc::new(d.y.clone()),
            0.5,
            0.1,
            BackendChoice::Xla,
        );
        let out = rx.recv().unwrap();
        assert!(out.result.is_err());
        assert_eq!(service2.metrics().failed(), 1);
        service2.shutdown();
        service.shutdown();
    }
}
