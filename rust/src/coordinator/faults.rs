//! Deterministic fault injection for the coordinator — the harness that
//! pins every recovery path in the fault-isolation layer.
//!
//! A [`FaultPlan`] names service-wide event ordinals (0-based) at which
//! to misbehave: panic or fail the N-th preparation build, panic at the
//! N-th work-item pickup, panic or delay the N-th grid-point solve.
//! Ordinals are assigned by atomic counters in [`FaultState`], so a plan
//! replays identically on a one-worker pool and stays a *deterministic
//! schedule of injected events* (each listed ordinal fires exactly once)
//! at any worker count. The plan rides
//! [`ServiceConfig::fault_plan`](super::ServiceConfig::fault_plan) and
//! exists for tests and benches only — production configs leave it
//! `None`, which compiles the hooks down to a `None` check.
//!
//! Recovery contract under injection: a panicking solve fails *that job*
//! with [`JobError::WorkerPanic`](super::JobError::WorkerPanic) (or
//! succeeds on retry), a failing build wakes every single-flight waiter
//! and evicts the slot, a delay pushes a deadline-carrying job into
//! bit-identical-prefix truncation — and results that still succeed are
//! bit-for-bit what a fault-free run produces.

use crate::rng::Rng;
use std::sync::atomic::{AtomicU64, Ordering};
use std::time::Duration;

/// A seeded, test/bench-only schedule of injected faults.
#[derive(Clone, Debug, Default)]
pub struct FaultPlan {
    /// Preparation-build ordinals that panic mid-build.
    pub prep_build_panics: Vec<u64>,
    /// Preparation-build ordinals that return a build error.
    pub prep_build_errors: Vec<u64>,
    /// Work-item pickup ordinals that panic before solving anything.
    pub segment_panics: Vec<u64>,
    /// Grid-point solve ordinals that panic mid-sweep.
    pub solve_panics: Vec<u64>,
    /// Grid-point solve ordinals that stall for the given duration
    /// before solving (the deadline-pressure lever).
    pub solve_delays: Vec<(u64, Duration)>,
    /// Grid-point solve ordinals whose regularisation parameter is
    /// poisoned with NaN before solving — the non-finite value enters
    /// the solver's own arithmetic, so the numerical-health guardrails
    /// (not the injection site) must stop it from reaching a served β.
    pub solve_nans: Vec<u64>,
}

impl FaultPlan {
    /// Derive a pseudo-random plan from `seed`: roughly `density` faults
    /// of each kind scattered over the first `horizon` events of each
    /// counter. Deterministic in `seed` — the soak test and bench replay
    /// the same schedule every run.
    pub fn seeded(seed: u64, horizon: u64, density: usize) -> Self {
        let mut rng = Rng::seed_from(seed ^ 0x51_7e_a5_ed);
        let horizon = horizon.max(1);
        let mut draw = |n: usize| -> Vec<u64> {
            let mut v: Vec<u64> = (0..n).map(|_| rng.next_u64() % horizon).collect();
            v.sort_unstable();
            v.dedup();
            v
        };
        FaultPlan {
            prep_build_panics: draw(density / 2),
            prep_build_errors: draw(density / 2),
            segment_panics: draw(density),
            solve_panics: draw(density),
            solve_delays: draw(density)
                .into_iter()
                .map(|k| (k, Duration::from_millis(1 + rng.next_u64() % 5)))
                .collect(),
            // NaN poisoning is opt-in (`with_seeded_nans`): a breakdown
            // is a *deterministic* failure — retrying cannot heal bad
            // arithmetic — so seeded soak schedules, whose contract is
            // "every failure is an exhausted transient", stay NaN-free.
            solve_nans: Vec::new(),
        }
    }

    /// Add roughly `density` seeded NaN-poisoned solve ordinals over the
    /// same horizon — the numerical-breakdown soak schedule. Poisoned
    /// jobs fail (or evict the poisoned member) with
    /// `NumericalBreakdown`; they never serve a non-finite β.
    pub fn with_seeded_nans(mut self, seed: u64, horizon: u64, density: usize) -> Self {
        let mut rng = Rng::seed_from(seed ^ 0x0bad_f00d);
        let horizon = horizon.max(1);
        let mut v: Vec<u64> = (0..density).map(|_| rng.next_u64() % horizon).collect();
        v.sort_unstable();
        v.dedup();
        self.solve_nans = v;
        self
    }

    /// True when the plan injects nothing.
    pub fn is_empty(&self) -> bool {
        self.prep_build_panics.is_empty()
            && self.prep_build_errors.is_empty()
            && self.segment_panics.is_empty()
            && self.solve_panics.is_empty()
            && self.solve_delays.is_empty()
            && self.solve_nans.is_empty()
    }
}

/// Runtime state of a plan: service-wide event counters. Shared by every
/// worker of one service, so ordinals are global across the pool.
pub struct FaultState {
    plan: FaultPlan,
    prep_builds: AtomicU64,
    pickups: AtomicU64,
    solves: AtomicU64,
}

impl FaultState {
    pub fn new(plan: FaultPlan) -> Self {
        FaultState {
            plan,
            prep_builds: AtomicU64::new(0),
            pickups: AtomicU64::new(0),
            solves: AtomicU64::new(0),
        }
    }

    /// Called at the start of every preparation build. Panics or returns
    /// an injected build error when this build's ordinal is listed.
    pub fn on_prep_build(&self) -> Result<(), String> {
        let k = self.prep_builds.fetch_add(1, Ordering::Relaxed);
        if self.plan.prep_build_panics.contains(&k) {
            panic!("injected fault: prep build #{k} panics");
        }
        if self.plan.prep_build_errors.contains(&k) {
            return Err(format!("injected fault: prep build #{k} fails"));
        }
        Ok(())
    }

    /// Called at every work-item pickup. Panics when listed.
    pub fn on_pickup(&self) {
        let k = self.pickups.fetch_add(1, Ordering::Relaxed);
        if self.plan.segment_panics.contains(&k) {
            panic!("injected fault: work item #{k} panics");
        }
    }

    /// Called before every grid-point solve. Sleeps and/or panics when
    /// listed (the delay fires first, so a delayed ordinal can also push
    /// a later ordinal past a deadline). Returns `true` when this
    /// solve's ordinal is NaN-poisoned: the caller must corrupt the
    /// solve's regularisation parameter so the guardrail ladder — not
    /// the injection site — has to catch the non-finite values.
    pub fn on_solve(&self) -> bool {
        let k = self.solves.fetch_add(1, Ordering::Relaxed);
        if let Some((_, d)) = self.plan.solve_delays.iter().find(|(i, _)| *i == k) {
            std::thread::sleep(*d);
        }
        if self.plan.solve_panics.contains(&k) {
            panic!("injected fault: solve #{k} panics");
        }
        self.plan.solve_nans.contains(&k)
    }
}

#[cfg(test)]
#[allow(clippy::unwrap_used)]
mod tests {
    use super::*;

    #[test]
    fn seeded_plans_are_deterministic() {
        let a = FaultPlan::seeded(42, 100, 6);
        let b = FaultPlan::seeded(42, 100, 6);
        assert_eq!(a.solve_panics, b.solve_panics);
        assert_eq!(a.prep_build_errors, b.prep_build_errors);
        assert_eq!(a.segment_panics, b.segment_panics);
        let c = FaultPlan::seeded(43, 100, 6);
        assert_ne!(
            (a.solve_panics, a.segment_panics),
            (c.solve_panics, c.segment_panics),
            "different seeds must differ"
        );
        assert!(a.solve_delays.iter().all(|(k, _)| *k < 100));
    }

    #[test]
    fn ordinals_fire_exactly_once() {
        let state = FaultState::new(FaultPlan {
            prep_build_errors: vec![1],
            ..Default::default()
        });
        assert!(state.on_prep_build().is_ok()); // ordinal 0
        assert!(state.on_prep_build().is_err()); // ordinal 1: injected
        assert!(state.on_prep_build().is_ok()); // ordinal 2
    }

    #[test]
    fn listed_solve_panics() {
        let state = FaultState::new(FaultPlan {
            solve_panics: vec![0],
            ..Default::default()
        });
        let r = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| state.on_solve()));
        assert!(r.is_err());
        assert!(!state.on_solve()); // ordinal 1 passes, unpoisoned
    }

    #[test]
    fn listed_solve_nans_poison_exactly_once() {
        let state = FaultState::new(FaultPlan {
            solve_nans: vec![1],
            ..Default::default()
        });
        assert!(!state.on_solve()); // ordinal 0
        assert!(state.on_solve()); // ordinal 1: poisoned
        assert!(!state.on_solve()); // ordinal 2
        let plan = FaultPlan { solve_nans: vec![0], ..Default::default() };
        assert!(!plan.is_empty(), "a NaN-only plan still injects");
    }

    #[test]
    fn empty_plan_is_inert() {
        let plan = FaultPlan::default();
        assert!(plan.is_empty());
        let state = FaultState::new(plan);
        for _ in 0..10 {
            assert!(state.on_prep_build().is_ok());
            state.on_pickup();
            assert!(!state.on_solve());
        }
    }
}
