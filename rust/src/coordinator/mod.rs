//! L3 coordinator — the deployable system around the SVEN reduction.
//!
//! The paper's systems pitch is that the Elastic Net becomes "free" once
//! you have an optimized parallel SVM; this module is the machinery that
//! makes that a service rather than a script:
//!
//! - [`path`] — the paper's evaluation protocol as a scheduler: derive
//!   the glmnet λ-path, subsample 40 settings with distinct supports, and
//!   sweep them with prepared-problem reuse + warm starts (the chaining
//!   core, [`path::sweep_prepared`], is shared with the service's
//!   `JobKind::Path` worker).
//! - [`cv`] — k-fold cross-validation as a first-class workload: fold
//!   splits and sub-problems, per-λ CV-error curves, and the
//!   `JobKind::CvPath` result type (fold paths + winning refit).
//! - [`queue`] — bounded MPMC work queue (condvar-based, backpressure).
//! - [`pool`] — supervised worker pool; workers own thread-local solver
//!   state (backends + scratch) but share the immutable preparations,
//!   and a panic that escapes the handler respawns the worker's context
//!   instead of shrinking the pool.
//! - [`prep_cache`] — service-level `Arc<dyn SvmPrep>` cache keyed by
//!   (dataset, backend): single-flight builds, LRU bound, counted in
//!   metrics; failed or panicked builds wake every waiter and evict the
//!   slot so a retry rebuilds cleanly.
//! - [`admission`] — structured [`JobError`]s, per-submission
//!   [`SubmitOptions`] (deadline + [`RetryPolicy`]), and the cost-based
//!   admission budget behind `ServiceConfig::max_queue_depth`.
//! - [`faults`] — deterministic fault injection ([`FaultPlan`]) for
//!   tests and benches: seeded panics, failed builds, and delays at
//!   exact ordinals, off in production configs.
//! - [`service`] — the request loop: submit point or path jobs, collect
//!   responses, drain gracefully; per-request latency + queue-wait
//!   metrics, per-attempt panic isolation, deadline truncation.
//! - [`metrics`] — counters and latency summaries, including the
//!   robustness counters (panics, respawns, sheds, retries, truncations).

// The coordinator is the part of the crate that must degrade rather than
// die: no naked unwraps. Intentional assertions use `expect` with an
// invariant message; poison-tolerant locking lives in `sync`.
#![deny(clippy::unwrap_used)]

pub mod admission;
pub mod cv;
pub mod faults;
pub mod metrics;
pub mod path;
pub mod pool;
pub mod prep_cache;
pub mod queue;
pub mod service;
mod sync;

pub use admission::{JobError, RetryPolicy, SubmitOptions};
pub use cv::CvPathResult;
pub use faults::FaultPlan;
pub use metrics::Metrics;
pub use path::{GridPoint, MultiSweepOut, PathRunResult, PathRunner, PathRunnerConfig};
pub use pool::{Pool, PoolConfig};
pub use prep_cache::PrepCache;
pub use queue::Queue;
pub use service::{
    BackendChoice, JobKind, JobResult, MultiResponseResult, Service, ServiceConfig,
    ServiceConfigError, SolveJob, SolveOutcome,
};
