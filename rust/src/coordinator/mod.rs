//! L3 coordinator — the deployable system around the SVEN reduction.
//!
//! The paper's systems pitch is that the Elastic Net becomes "free" once
//! you have an optimized parallel SVM; this module is the machinery that
//! makes that a service rather than a script:
//!
//! - [`path`] — the paper's evaluation protocol as a scheduler: derive
//!   the glmnet λ-path, subsample 40 settings with distinct supports, and
//!   sweep them with prepared-problem reuse + warm starts (the chaining
//!   core, [`path::sweep_prepared`], is shared with the service's
//!   `JobKind::Path` worker).
//! - [`cv`] — k-fold cross-validation as a first-class workload: fold
//!   splits and sub-problems, per-λ CV-error curves, and the
//!   `JobKind::CvPath` result type (fold paths + winning refit).
//! - [`queue`] — bounded MPMC work queue (condvar-based, backpressure).
//! - [`pool`] — worker pool; workers own thread-local solver state
//!   (backends + scratch) but share the immutable preparations.
//! - [`prep_cache`] — service-level `Arc<dyn SvmPrep>` cache keyed by
//!   (dataset, backend): single-flight builds, LRU bound, counted in
//!   metrics.
//! - [`service`] — the request loop: submit point or path jobs, collect
//!   responses, drain gracefully; per-request latency + queue-wait
//!   metrics.
//! - [`metrics`] — counters and latency summaries.

pub mod cv;
pub mod metrics;
pub mod path;
pub mod pool;
pub mod prep_cache;
pub mod queue;
pub mod service;

pub use cv::CvPathResult;
pub use metrics::Metrics;
pub use path::{GridPoint, MultiSweepOut, PathRunResult, PathRunner, PathRunnerConfig};
pub use pool::{Pool, PoolConfig};
pub use prep_cache::PrepCache;
pub use queue::Queue;
pub use service::{
    BackendChoice, JobKind, JobResult, MultiResponseResult, Service, ServiceClosed,
    ServiceConfig, ServiceConfigError, SolveJob, SolveOutcome,
};
