//! L3 coordinator — the deployable system around the SVEN reduction.
//!
//! The paper's systems pitch is that the Elastic Net becomes "free" once
//! you have an optimized parallel SVM; this module is the machinery that
//! makes that a service rather than a script:
//!
//! - [`path`] — the paper's evaluation protocol as a scheduler: derive
//!   the glmnet λ-path, subsample 40 settings with distinct supports, and
//!   sweep them with prepared-problem reuse + warm starts.
//! - [`queue`] — bounded MPMC work queue (condvar-based, backpressure).
//! - [`pool`] — worker pool; each worker owns a thread-local solver
//!   context (the PJRT handles are not `Send`).
//! - [`service`] — the request loop: submit solve jobs, collect
//!   responses, drain gracefully; per-request latency metrics.
//! - [`metrics`] — counters and latency summaries.

pub mod metrics;
pub mod path;
pub mod pool;
pub mod queue;
pub mod service;

pub use metrics::Metrics;
pub use path::{PathRunResult, PathRunner, PathRunnerConfig};
pub use pool::{Pool, PoolConfig};
pub use queue::Queue;
pub use service::{BackendChoice, Service, ServiceConfig, SolveJob, SolveOutcome};
