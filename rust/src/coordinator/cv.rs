//! k-fold cross-validation over one shared data set — the dominant real
//! workload for picking λ, served as a first-class job.
//!
//! A `JobKind::CvPath { folds, grid }` job splits the rows of one
//! `Arc<Design>` into k contiguous validation slices, builds each fold's
//! training sub-problem **once** (gathered rows, shared via `Arc` across
//! every worker thereafter — workers never copy), runs each fold's grid
//! as a warm-start chained path through the same `sweep_prepared` core
//! as `JobKind::Path` (so each fold's path is bit-for-bit a standalone
//! path job on that fold's data), and assembles the per-λ CV-error curve
//! plus the winning grid point refit on the full data. Fold preparations
//! flow through the service's single-flight prep cache under derived
//! dataset ids, so fold×segment fan-out still builds each preparation
//! exactly once.

use crate::linalg::Design;
use crate::solvers::elastic_net::EnSolution;
use std::ops::Range;
use std::sync::Arc;

/// Validation slice of fold `f`: the k slices are contiguous, cover all
/// `n` rows, and differ in size by at most one (the first `n % folds`
/// folds get the extra row).
pub fn fold_validation_rows(n: usize, folds: usize, f: usize) -> Range<usize> {
    debug_assert!(f < folds);
    let base = n / folds;
    let extra = n % folds;
    let start = f * base + f.min(extra);
    let size = base + usize::from(f < extra);
    start..start + size
}

/// Training rows of fold `f` — everything outside the validation slice,
/// in ascending order (the deterministic gather order every consumer,
/// including the bit-for-bit service tests, relies on).
pub fn fold_training_rows(n: usize, folds: usize, f: usize) -> Vec<usize> {
    let val = fold_validation_rows(n, folds, f);
    (0..n).filter(|i| !val.contains(i)).collect()
}

/// Build fold `f`'s training sub-problem `(X_train, y_train)`. One
/// gather per fold; the result is shared via `Arc` from then on. The
/// gathered rows are bit-identical copies, so a solve against the
/// result is bit-for-bit a solve against that data submitted as its own
/// data set.
pub fn fold_problem(
    x: &Design,
    y: &[f64],
    folds: usize,
    f: usize,
) -> (Arc<Design>, Arc<Vec<f64>>) {
    let rows = fold_training_rows(x.rows(), folds, f);
    let xf = x.gather_rows(&rows);
    let yf: Vec<f64> = rows.iter().map(|&i| y[i]).collect();
    (Arc::new(xf), Arc::new(yf))
}

/// Mean squared validation error of `beta` on fold `f`'s held-out rows.
pub fn fold_validation_mse(
    x: &Design,
    y: &[f64],
    folds: usize,
    f: usize,
    beta: &[f64],
) -> f64 {
    let val = fold_validation_rows(x.rows(), folds, f);
    let m = val.len();
    let mut sum = 0.0;
    for i in val {
        let e = x.row_dot(i, beta) - y[i];
        sum += e * e;
    }
    sum / m as f64
}

/// Assemble the CV curve: `cv_errors[g]` is the mean over folds of the
/// validation MSE of fold `f`'s β at grid point `g` (fold-ascending
/// accumulation — deterministic).
pub fn cv_error_curve(
    x: &Design,
    y: &[f64],
    folds: usize,
    fold_paths: &[Vec<EnSolution>],
) -> Vec<f64> {
    let grid_len = fold_paths.first().map_or(0, |p| p.len());
    let mut errs = vec![0.0; grid_len];
    for (f, path) in fold_paths.iter().enumerate() {
        for (g, sol) in path.iter().enumerate() {
            errs[g] += fold_validation_mse(x, y, folds, f, &sol.beta);
        }
    }
    for e in errs.iter_mut() {
        *e /= folds as f64;
    }
    errs
}

/// argmin of the CV curve (ties → the first, i.e. the sparser end when
/// the grid runs sparse→dense); empty curves return 0.
pub fn best_index(errs: &[f64]) -> usize {
    let mut best = 0;
    for (i, &e) in errs.iter().enumerate() {
        if e < errs[best] {
            best = i;
        }
    }
    best
}

/// Derived dataset id of fold `f` of data set `dataset_id` — the prep
/// cache key of the fold sub-problem (splitmix64 mix; colliding with a
/// caller-chosen id is as unlikely as any 64-bit hash collision, and a
/// differently-shaped collision is rejected by the prep dims check).
pub(crate) fn fold_dataset_id(dataset_id: u64, f: u64) -> u64 {
    let mut z = dataset_id ^ 0x9E37_79B9_7F4A_7C15u64.wrapping_mul(f.wrapping_add(1));
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

/// Result of a `JobKind::CvPath` job.
#[derive(Clone, Debug)]
pub struct CvPathResult {
    /// Per-fold solution paths (fold-major, grid order), each
    /// bit-for-bit identical to a standalone `JobKind::Path` on that
    /// fold's training data.
    pub fold_paths: Vec<Vec<EnSolution>>,
    /// Mean validation MSE per grid point, averaged across folds.
    pub cv_errors: Vec<f64>,
    /// argmin of `cv_errors` (ties → first).
    pub best_index: usize,
    /// The winning grid point refit on the **full** data set.
    pub best: EnSolution,
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::Mat;
    use crate::rng::Rng;

    #[test]
    fn folds_partition_all_rows() {
        for (n, k) in [(10usize, 3usize), (12, 4), (7, 7), (23, 5)] {
            let mut seen = vec![false; n];
            for f in 0..k {
                let val = fold_validation_rows(n, k, f);
                assert!(!val.is_empty(), "n={n} k={k} f={f}");
                for i in val.clone() {
                    assert!(!seen[i], "row {i} in two folds (n={n} k={k})");
                    seen[i] = true;
                }
                let train = fold_training_rows(n, k, f);
                assert_eq!(train.len(), n - val.len());
                assert!(train.iter().all(|i| !val.contains(i)));
                assert!(train.windows(2).all(|w| w[0] < w[1]), "sorted");
            }
            assert!(seen.iter().all(|&s| s), "n={n} k={k}: rows uncovered");
        }
    }

    #[test]
    fn fold_problem_gathers_training_rows() {
        let mut rng = Rng::seed_from(71);
        let x = Mat::from_fn(9, 4, |_, _| rng.normal());
        let y: Vec<f64> = (0..9).map(|_| rng.normal()).collect();
        let d = Design::from(x.clone());
        let (xf, yf) = fold_problem(&d, &y, 3, 1);
        let train = fold_training_rows(9, 3, 1);
        assert_eq!(xf.rows(), train.len());
        assert_eq!(yf.len(), train.len());
        let xfd = xf.to_dense();
        for (s, &r) in train.iter().enumerate() {
            assert_eq!(yf[s], y[r]);
            for j in 0..4 {
                assert_eq!(xfd.get(s, j).to_bits(), x.get(r, j).to_bits());
            }
        }
    }

    #[test]
    fn mse_and_curve_and_argmin() {
        // 4 rows, 2 folds; identity-ish design so the MSE is hand
        // checkable.
        let x = Design::from(Mat::from_fn(4, 1, |_, _| 1.0));
        let y = vec![1.0, 3.0, 5.0, 7.0];
        // β = [3]: predictions all 3. Fold 0 validates rows 0..2 → mse
        // ((3-1)² + (3-3)²)/2 = 2; fold 1 rows 2..4 → ((3-5)²+(3-7)²)/2
        // = 10.
        assert!((fold_validation_mse(&x, &y, 2, 0, &[3.0]) - 2.0).abs() < 1e-12);
        assert!((fold_validation_mse(&x, &y, 2, 1, &[3.0]) - 10.0).abs() < 1e-12);
        assert_eq!(best_index(&[3.0, 1.0, 1.0, 2.0]), 1);
        assert_eq!(best_index(&[]), 0);
    }

    #[test]
    fn fold_ids_are_distinct() {
        let base = 42u64;
        let ids: Vec<u64> = (0..8).map(|f| fold_dataset_id(base, f)).collect();
        for a in 0..8 {
            assert_ne!(ids[a], base);
            for b in (a + 1)..8 {
                assert_ne!(ids[a], ids[b]);
            }
        }
    }
}
