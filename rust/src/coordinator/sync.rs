//! Poison-tolerant synchronization helpers for cross-thread coordinator
//! state.
//!
//! Worker panics are caught and converted into failed jobs
//! ([`JobError::WorkerPanic`](super::JobError::WorkerPanic)), but a
//! panic while a mutex is held still poisons it — and the coordinator's
//! mutexes guard state whose invariants hold at every yield point
//! (queue contents, hand-off slots, recorded segment parts, metric
//! reservoirs). For such state, poisoning carries no information worth
//! aborting over: a second thread `unwrap()`ing the `PoisonError` would
//! turn one isolated fault into a process-wide panic cascade, which is
//! exactly what the fault-isolation layer exists to prevent. These
//! helpers recover the guard instead.

use std::sync::{Condvar, Mutex, MutexGuard, PoisonError};

/// Lock `m`, recovering the guard if a previous holder panicked.
pub(crate) fn lock<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    m.lock().unwrap_or_else(PoisonError::into_inner)
}

/// Park on `cv` until notified, recovering the guard on poison.
pub(crate) fn wait<'a, T>(cv: &Condvar, guard: MutexGuard<'a, T>) -> MutexGuard<'a, T> {
    cv.wait(guard).unwrap_or_else(PoisonError::into_inner)
}

/// Park on `cv` while `condition` holds, for at most `dur`, recovering
/// the guard on poison — the bounded form behind the segment hand-off
/// wait (a successor parks briefly for its in-flight predecessor
/// instead of speculating, and a poisoned or never-publishing
/// predecessor can only cost the timeout, never a hang).
pub(crate) fn wait_timeout_while<'a, T>(
    cv: &Condvar,
    guard: MutexGuard<'a, T>,
    dur: std::time::Duration,
    condition: impl FnMut(&mut T) -> bool,
) -> MutexGuard<'a, T> {
    match cv.wait_timeout_while(guard, dur, condition) {
        Ok((g, _timeout)) => g,
        Err(poisoned) => poisoned.into_inner().0,
    }
}

#[cfg(test)]
#[allow(clippy::unwrap_used)]
mod tests {
    use super::*;
    use std::sync::{Arc, Mutex};

    #[test]
    fn lock_recovers_from_poison() {
        let m = Arc::new(Mutex::new(7usize));
        let m2 = m.clone();
        let _ = std::thread::spawn(move || {
            let _g = m2.lock().unwrap();
            panic!("poison the mutex");
        })
        .join();
        assert!(m.is_poisoned());
        assert_eq!(*lock(&m), 7);
    }

    #[test]
    fn wait_wakes_through_poisoned_mutex() {
        let state = Arc::new((Mutex::new(false), Condvar::new()));
        let s2 = state.clone();
        // Poison first, then flip the flag from another thread.
        let p = state.clone();
        let _ = std::thread::spawn(move || {
            let _g = p.0.lock().unwrap();
            panic!("poison");
        })
        .join();
        let setter = std::thread::spawn(move || {
            std::thread::sleep(std::time::Duration::from_millis(10));
            *lock(&s2.0) = true;
            s2.1.notify_all();
        });
        let mut g = lock(&state.0);
        while !*g {
            g = wait(&state.1, g);
        }
        setter.join().unwrap();
    }
}
