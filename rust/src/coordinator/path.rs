//! The paper's evaluation protocol as a reusable runner: derive the
//! glmnet path, subsample settings with distinct support sizes, and sweep
//! them with SVEN using prepared-problem reuse and warm starts — the
//! access pattern behind Figures 1–3.

use crate::data::Dataset;
use crate::linalg::vecops;
use crate::solvers::elastic_net::EnProblem;
use crate::solvers::glmnet::{self, PathPoint, PathSettings};
use crate::solvers::sven::{Sven, SvmBackend, SvmWarm};
use crate::util::Timer;

/// Configuration of a path run.
#[derive(Clone, Debug)]
pub struct PathRunnerConfig {
    /// Number of evaluation settings (the paper uses 40).
    pub grid: usize,
    /// Dense-path settings used to derive the grid.
    pub path: PathSettings,
    /// Warm-start successive solves from the previous point.
    pub warm_start: bool,
    /// Floor for λ₂ so C stays finite when the grid contains κ = 1 points.
    pub lambda2_floor: f64,
}

impl Default for PathRunnerConfig {
    fn default() -> Self {
        let mut path = PathSettings::default();
        // The reference path defines the evaluation grid (t = |β*|₁), so
        // its CD tolerance bounds every downstream comparison: at the
        // default 1e-9 the dense end of the path carries ~1e-3 coordinate
        // error, which would be misread as SVEN deviation.
        path.cd.tol = 1e-13;
        PathRunnerConfig { grid: 40, path, warm_start: true, lambda2_floor: 1e-6 }
    }
}

/// One solved grid point, with reference and SVEN solutions side by side.
#[derive(Clone, Debug)]
pub struct PathRunResult {
    pub t: f64,
    pub lambda2: f64,
    pub lambda: f64,
    /// Reference (glmnet) coefficients.
    pub beta_ref: Vec<f64>,
    /// SVEN coefficients.
    pub beta: Vec<f64>,
    /// max_j |β − β_ref| for this point.
    pub max_dev: f64,
    pub nnz: usize,
    /// SVEN solve seconds (excludes preparation, which is amortized).
    pub seconds: f64,
    pub iterations: usize,
}

/// Path runner over any SVEN backend.
pub struct PathRunner {
    pub config: PathRunnerConfig,
}

impl PathRunner {
    pub fn new(config: PathRunnerConfig) -> Self {
        PathRunner { config }
    }

    /// Derive the evaluation grid (paper protocol): glmnet dense path →
    /// subsample `grid` points with distinct supports.
    pub fn derive_grid(&self, data: &Dataset) -> Vec<PathPoint> {
        let pts = glmnet::compute_path(&data.x, &data.y, &self.config.path);
        glmnet::path::subsample_distinct(&pts, self.config.grid)
    }

    /// Sweep the grid with SVEN; returns per-point results including the
    /// reference deviation (the paper's "identical results" check).
    pub fn run<B: SvmBackend>(
        &self,
        data: &Dataset,
        sven: &Sven<B>,
        grid: &[PathPoint],
    ) -> anyhow::Result<Vec<PathRunResult>> {
        let mut prep = sven.prepare(&data.x, &data.y)?;
        let mut results = Vec::with_capacity(grid.len());
        let mut warm: Option<SvmWarm> = None;
        for pt in grid {
            let lambda2 = pt.lambda2.max(self.config.lambda2_floor);
            let prob =
                EnProblem::new(data.x.clone(), data.y.clone(), pt.t, lambda2);
            let timer = Timer::start();
            let sol = sven.solve_prepared(prep.as_mut(), &prob, warm.as_ref())?;
            let seconds = timer.elapsed();
            let max_dev = pt
                .beta
                .iter()
                .zip(&sol.beta)
                .map(|(a, b)| (a - b).abs())
                .fold(0.0f64, f64::max);
            if self.config.warm_start {
                warm = Some(SvmWarm { w: None, alpha: Some(sol.beta_to_warm(pt.t)) });
            }
            results.push(PathRunResult {
                t: pt.t,
                lambda2,
                lambda: pt.lambda,
                beta_ref: pt.beta.clone(),
                nnz: vecops::nnz(&sol.beta, 1e-8),
                max_dev,
                seconds,
                iterations: sol.iterations,
                beta: sol.beta,
            });
        }
        Ok(results)
    }

    /// Convenience: derive the grid and run in one call.
    pub fn derive_and_run<B: SvmBackend>(
        &self,
        data: &Dataset,
        sven: &Sven<B>,
    ) -> anyhow::Result<Vec<PathRunResult>> {
        let grid = self.derive_grid(data);
        self.run(data, sven, &grid)
    }
}

/// Worst deviation across a whole run — the Figure-1 "paths match" stat.
pub fn max_deviation(results: &[PathRunResult]) -> f64 {
    results.iter().map(|r| r.max_dev).fold(0.0, f64::max)
}

impl crate::solvers::elastic_net::EnSolution {
    /// Rebuild a feasible dual warm start from β (α⁺ = max(β,0)·Σ/t …):
    /// approximate but effective — only used to seed the next path point.
    pub fn beta_to_warm(&self, t: f64) -> Vec<f64> {
        let p = self.beta.len();
        let mut alpha = vec![0.0; 2 * p];
        for j in 0..p {
            if self.beta[j] > 0.0 {
                alpha[j] = self.beta[j] / t;
            } else {
                alpha[p + j] = -self.beta[j] / t;
            }
        }
        alpha
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::{synth_regression, SynthSpec};
    use crate::solvers::sven::RustBackend;

    fn dataset(n: usize, p: usize, seed: u64) -> Dataset {
        synth_regression(&SynthSpec { n, p, support: 6, seed, ..Default::default() })
    }

    #[test]
    fn grid_has_distinct_supports() {
        let d = dataset(50, 30, 201);
        let runner = PathRunner::new(PathRunnerConfig {
            grid: 12,
            path: PathSettings { num_lambda: 60, ..Default::default() },
            ..Default::default()
        });
        let grid = runner.derive_grid(&d);
        assert!(!grid.is_empty() && grid.len() <= 12);
        let supports: Vec<usize> = grid.iter().map(|g| g.nnz).collect();
        let mut dedup = supports.clone();
        dedup.dedup();
        assert_eq!(supports, dedup);
    }

    #[test]
    fn sven_matches_reference_along_path() {
        let d = dataset(40, 25, 202);
        let runner = PathRunner::new(PathRunnerConfig {
            grid: 8,
            path: PathSettings { num_lambda: 40, ..Default::default() },
            ..Default::default()
        });
        let sven = Sven::new(RustBackend::default());
        let results = runner.derive_and_run(&d, &sven).unwrap();
        assert!(!results.is_empty());
        let dev = max_deviation(&results);
        assert!(dev < 5e-4, "path deviation {dev}");
    }

    #[test]
    fn dual_regime_path() {
        let d = dataset(120, 10, 203);
        let runner = PathRunner::new(PathRunnerConfig {
            grid: 6,
            path: PathSettings { num_lambda: 30, ..Default::default() },
            ..Default::default()
        });
        let sven = Sven::new(RustBackend::default());
        let results = runner.derive_and_run(&d, &sven).unwrap();
        let dev = max_deviation(&results);
        assert!(dev < 5e-4, "path deviation {dev}");
    }

    #[test]
    fn timings_recorded() {
        let d = dataset(30, 20, 204);
        let runner = PathRunner::new(PathRunnerConfig {
            grid: 4,
            path: PathSettings { num_lambda: 25, ..Default::default() },
            ..Default::default()
        });
        let sven = Sven::new(RustBackend::default());
        let results = runner.derive_and_run(&d, &sven).unwrap();
        assert!(results.iter().all(|r| r.seconds > 0.0));
    }
}
