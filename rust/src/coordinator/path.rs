//! The paper's evaluation protocol as a reusable runner: derive the
//! glmnet path, subsample settings with distinct support sizes, and sweep
//! them with SVEN using prepared-problem reuse and warm starts — the
//! access pattern behind Figures 1–3.
//!
//! The warm-start chaining itself lives in [`sweep_prepared`], shared
//! between the offline [`PathRunner`] and the service's
//! [`JobKind::Path`](crate::coordinator::service::JobKind) worker, so a
//! path submitted as a service job reproduces an offline run bit-for-bit.

use crate::data::Dataset;
use crate::linalg::{vecops, Design};
use crate::solvers::elastic_net::{EnProblem, EnSolution};
use crate::solvers::glmnet::{self, PathPoint, PathSettings};
use crate::solvers::svm::SolveCtl;
use crate::solvers::sven::{
    Sven, SvmBackend, SvmBatchStats, SvmMode, SvmPrep, SvmScratch, SvmWarm,
};
use std::sync::{Arc, Mutex};

/// One (t, λ₂) setting of a sweep — the wire form of a grid point (the
/// reference β and penalized-form parameters stay behind in
/// [`PathPoint`]).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct GridPoint {
    /// L1 budget t > 0.
    pub t: f64,
    /// L2 regularization λ₂ (already floored; see
    /// [`PathRunnerConfig::lambda2_floor`]).
    pub lambda2: f64,
}

/// Cooperative sweep control: lets the service's fault-isolation layer
/// reach inside a sweep at grid-point granularity without the sweep
/// knowing about deadlines or fault plans.
///
/// `expired` is polled at grid-point (primal: chunk) boundaries; once it
/// returns `true` the sweep stops and returns the solved prefix —
/// bit-identical to the same prefix of an uncontrolled sweep, because
/// batch composition never moves a bit (see [`sweep_prepared`]).
/// `before_solve` runs once per grid-point solve about to start; the
/// fault-injection harness uses it to panic or stall at its scheduled
/// solve ordinals (a panic unwinds out of the sweep and is caught at the
/// job-attempt layer).
pub struct SweepCtl<'a> {
    /// True once the job's wall-clock budget is exhausted.
    pub expired: &'a dyn Fn() -> bool,
    /// Hook before each grid-point solve (fault injection; may panic).
    pub before_solve: &'a dyn Fn(),
    /// Consulted once per grid-point solve, in solve order, right after
    /// `before_solve`: `true` poisons that solve's `t` with NaN — the
    /// fault harness's numerical-breakdown injection. The poisoned NaN
    /// propagates into the reduced design, trips the solver's
    /// non-finite guardrails, and must never reach a served β.
    pub poison: &'a dyn Fn() -> bool,
    /// Called when the deadline aborted *inside* a solve (at Newton-
    /// iteration granularity) and its half-converged iterate was
    /// discarded; the sweep still returns only completed grid points.
    pub on_intra_abort: &'a dyn Fn(),
}

impl SweepCtl<'_> {
    fn expired(&self) -> bool {
        (self.expired)()
    }

    fn before_solves(&self, n: usize) {
        for _ in 0..n {
            (self.before_solve)();
        }
    }

    /// Grid-point `t`, NaN-poisoned when the fault schedule says so.
    fn poisoned_t(&self, t: f64) -> f64 {
        if (self.poison)() {
            f64::NAN
        } else {
            t
        }
    }
}

/// Durable progress of one sweep, published into shared job state after
/// every completed grid point so a retry (worker panic, stall recovery,
/// deadline shedding) resumes where the dead attempt stopped instead of
/// re-solving the prefix.
///
/// Resume is bit-for-bit: `completed` holds exactly the solutions an
/// uninterrupted sweep produces for those points (a checkpoint is only
/// written after a point fully converges — never a half-converged β),
/// and `warm` is the warm-chain state the next point would have been
/// seeded with (the primal ignores it; the dual resumes its exact
/// chain).
#[derive(Clone, Debug, Default)]
pub struct SweepCheckpoint {
    /// Solutions for the completed prefix of the grid, in grid order.
    pub completed: Vec<EnSolution>,
    /// Warm-start chain state after the last completed point.
    pub warm: Option<SvmWarm>,
    /// Multi-response sweep state ([`sweep_multi_prepared`]); `None`
    /// for plain sweeps.
    pub partial: Option<MultiSweepCheckpoint>,
}

/// [`SweepCheckpoint::partial`]: the point-major multi-response sweep's
/// full resume state — per-response solved prefixes plus the warm /
/// early-stop / eviction bookkeeping that shapes the remaining points.
#[derive(Clone, Debug)]
pub struct MultiSweepCheckpoint {
    /// Per-response solved prefixes, indexed like the sweep's `live`.
    pub paths: Vec<Vec<EnSolution>>,
    /// Per-response dual warm chains.
    pub warms: Vec<Option<SvmWarm>>,
    /// Per-response previous deviance (early-stop plateau detection).
    pub prev_dev: Vec<Option<f64>>,
    /// Per-response early-stop point, as in [`MultiSweepOut`].
    pub stopped: Vec<Option<usize>>,
    /// Per-response guardrail eviction: `Some(detail)` once a response's
    /// member hit a numerical breakdown and was retired.
    pub broken: Vec<Option<String>>,
    /// Grid points fully completed (the resume position).
    pub points_done: usize,
}

impl MultiSweepCheckpoint {
    fn new(r: usize) -> Self {
        MultiSweepCheckpoint {
            paths: (0..r).map(|_| Vec::new()).collect(),
            warms: vec![None; r],
            prev_dev: vec![None; r],
            stopped: vec![None; r],
            broken: vec![None; r],
            points_done: 0,
        }
    }
}

/// Shared slot a sweep publishes its [`SweepCheckpoint`] into (and
/// resumes from) — owned by the job's shared state so every retry
/// attempt of the same job sees the same slot.
pub type CheckpointSlot = Mutex<Option<SweepCheckpoint>>;

/// Sentinel error for a numerical breakdown that survived the solver's
/// degradation ladder, in the exact format
/// [`JobError::from_solver`](crate::coordinator::admission::JobError)
/// parses back into `JobError::NumericalBreakdown`.
fn breakdown_error(stage: String, detail: &str) -> anyhow::Error {
    anyhow::anyhow!("numerical breakdown at {stage}: {detail}")
}

/// Primal chunk width under an active [`SweepCtl`]: small enough that a
/// deadline lands within one chunk of where it would land point-by-point,
/// large enough to keep the lockstep-Newton panels wide.
const CTL_CHUNK: usize = 8;

/// Warm-start chained sweep over a prepared data set: solve each grid
/// point in order, seeding every solve after the first from the previous
/// β. This is *the* amortized access pattern of the paper (Figures 1–3):
/// one preparation, many cheap (t, λ₂) solves.
///
/// `warm0` seeds the *first* point: `None` for a whole-path sweep (the
/// offline runner and unsegmented service jobs), or the handed-off warm
/// start of the previous segment when the coordinator splits one long
/// grid into chained segments.
///
/// **Batched fast path:** primal-mode preparations run the whole grid
/// through the backend's batched solve ([`SvmPrep::solve_batch`] — one
/// lockstep Newton fusing gradients, margin refreshes, and shared-panel
/// blocked CG across the points). This cannot move a bit: the chain's
/// warm starts carry only dual variables, which the primal solver
/// ignores, so the sequential chain is a sequence of cold solves and
/// the batched engine is pinned bit-identical to those. Dual-mode
/// sweeps keep the sequential chain (their warm starts do real work).
///
/// Both the offline [`PathRunner::run`] and the coordinator's
/// `JobKind::Path` workers call exactly this function, so the two
/// produce bit-identical coefficient sequences. Returns the per-point
/// solutions plus the batch fusion stats (zero for sequential sweeps).
///
/// `ctl: Some(..)` activates cooperative deadline/fault control: the
/// primal fast path switches from one whole-grid batch to [`CTL_CHUNK`]-
/// wide batches so expiry is observed at chunk boundaries — still
/// bit-identical, since every primal batch member equals its solo cold
/// solve regardless of how the grid is chunked. The deadline is also
/// threaded *into* each solve ([`SolveCtl`]), so expiry mid-point aborts
/// at Newton-iteration granularity and the half-converged iterate is
/// discarded. A truncated return (`out.len() < grid.len()`) means the
/// deadline fired; the prefix is exactly what an uncontrolled sweep
/// produces for those points.
///
/// `checkpoint: Some(slot)` resumes from (and publishes into) the
/// slot's [`SweepCheckpoint`] after every completed point; a solve that
/// trips the numerical guardrails fails the sweep with the
/// `numerical breakdown at …` sentinel error.
#[allow(clippy::too_many_arguments)]
pub fn sweep_prepared<B: SvmBackend>(
    sven: &Sven<B>,
    prep: &dyn SvmPrep,
    scratch: &mut SvmScratch,
    x: &Arc<Design>,
    y: &Arc<Vec<f64>>,
    grid: &[GridPoint],
    warm0: Option<SvmWarm>,
    warm_start: bool,
    ctl: Option<&SweepCtl<'_>>,
    checkpoint: Option<&CheckpointSlot>,
) -> anyhow::Result<(Vec<EnSolution>, SvmBatchStats)> {
    // Resume: adopt the published prefix and its warm chain, then sweep
    // only the remaining suffix. The prefix was checkpointed after each
    // full convergence, so the concatenation is bit-identical to an
    // uninterrupted sweep (primal: all cold solves; dual: the exact
    // warm chain continues from `cp.warm`).
    let (mut out, mut warm) =
        match checkpoint.and_then(|slot| slot.lock().expect("checkpoint lock").clone()) {
            Some(cp) => {
                let warm = cp.warm.clone();
                (cp.completed, warm)
            }
            None => (Vec::with_capacity(grid.len()), warm0),
        };
    let skip = out.len().min(grid.len());
    let grid = &grid[skip..];
    let solve_ctl = ctl.map(|c| SolveCtl::new(c.expired));
    let publish = |sol: &EnSolution, warm: &Option<SvmWarm>| {
        if let Some(slot) = checkpoint {
            let mut s = slot.lock().expect("checkpoint lock");
            let cp = s.get_or_insert_with(SweepCheckpoint::default);
            cp.completed.push(sol.clone());
            cp.warm = warm.clone();
        }
    };
    let primal_cold =
        prep.mode() == SvmMode::Primal && warm.as_ref().map_or(true, |w| w.w.is_none());
    if primal_cold && grid.len() > 1 {
        let Some(ctl) = ctl else {
            let pts: Vec<(f64, f64)> = grid.iter().map(|gp| (gp.t, gp.lambda2)).collect();
            let (sols, stats) = sven.solve_prepared_batch(prep, scratch, x, y, &pts, None)?;
            for sol in sols {
                if let Some(msg) = &sol.broken {
                    return Err(breakdown_error(format!("grid[{}]", out.len()), msg));
                }
                publish(&sol, &warm);
                out.push(sol);
            }
            return Ok((out, stats));
        };
        let mut stats = SvmBatchStats::default();
        for chunk in grid.chunks(CTL_CHUNK) {
            if ctl.expired() {
                break;
            }
            ctl.before_solves(chunk.len());
            let pts: Vec<(f64, f64)> =
                chunk.iter().map(|gp| (ctl.poisoned_t(gp.t), gp.lambda2)).collect();
            let (sols, st) =
                sven.solve_prepared_batch(prep, scratch, x, y, &pts, solve_ctl.as_ref())?;
            stats.merge(&st);
            for sol in sols {
                if sol.aborted {
                    // Deadline fired inside the chunk's lockstep Newton:
                    // keep only the completed prefix; later members of
                    // the chunk (even converged ones) are re-solved cold
                    // on resume, bit-identically.
                    (ctl.on_intra_abort)();
                    return Ok((out, stats));
                }
                if let Some(msg) = &sol.broken {
                    return Err(breakdown_error(format!("grid[{}]", out.len()), msg));
                }
                publish(&sol, &warm);
                out.push(sol);
            }
        }
        return Ok((out, stats));
    }
    for gp in grid {
        let mut t = gp.t;
        if let Some(ctl) = ctl {
            if ctl.expired() {
                break;
            }
            ctl.before_solves(1);
            t = ctl.poisoned_t(t);
        }
        let prob = EnProblem::shared(x.clone(), y.clone(), t, gp.lambda2);
        let sol = sven.solve_prepared(prep, scratch, &prob, warm.as_ref(), solve_ctl.as_ref())?;
        if sol.aborted {
            if let Some(ctl) = ctl {
                (ctl.on_intra_abort)();
            }
            break;
        }
        if let Some(msg) = &sol.broken {
            return Err(breakdown_error(format!("grid[{}]", out.len()), msg));
        }
        if warm_start {
            warm = Some(SvmWarm { w: None, alpha: Some(sol.beta_to_warm(gp.t)) });
        }
        publish(&sol, &warm);
        out.push(sol);
    }
    Ok((out, SvmBatchStats::default()))
}

/// Output of [`sweep_multi_prepared`] for one chunk of responses.
pub struct MultiSweepOut {
    /// Per-response solved paths, indexed like the `live` argument.
    /// Early-stopped responses carry a truncated prefix of the grid.
    pub paths: Vec<Vec<EnSolution>>,
    /// Grid index at which each response's deviance plateaued (its path
    /// still includes that point); `None` ⇒ the full grid was solved.
    pub early_stopped_at: Vec<Option<usize>>,
    /// Grid points the sweep actually iterated (== `grid.len()` unless a
    /// deadline truncated the sweep); responses retired by early stopping
    /// hold shorter paths than this.
    pub points_done: usize,
    /// True when an active [`SweepCtl`] deadline stopped the sweep before
    /// the grid was exhausted.
    pub deadline_hit: bool,
    /// Per-response numerical-breakdown eviction: `Some(detail)` means
    /// the response's member tripped the guardrail ladder and was
    /// retired — its path holds the clean prefix solved before the
    /// breakdown, and its siblings are unaffected (bit-identical to a
    /// sweep without the sick member).
    pub broken: Vec<Option<String>>,
    /// Fusion stats summed over every batched solve of the sweep.
    pub stats: SvmBatchStats,
}

/// Multi-response sweep over one shared preparation: solve the full
/// `grid` for every response in `live` (indices into `responses`).
///
/// Primal-mode preparations fuse **all** `(response × grid point)`
/// members into one batched Newton ([`Sven::solve_prepared_batch_multi`])
/// — the response dimension rides the same panel width as path points,
/// which is the widest workout the blocked-CG substrate gets. Dual-mode
/// preparations run per-response warm-chained sequential sweeps through
/// [`Sven::solve_prepared_response`], reusing the preparation's cached
/// `G₀` across responses. Either way response `r`'s path is bit-for-bit
/// what a standalone [`sweep_prepared`] over a fresh `(x, yᵣ)`
/// preparation produces (same grid, warm chaining on).
///
/// `early_stop: Some(thresh)` switches to a point-by-point sweep that
/// retires a response once the relative deviance improvement between
/// consecutive grid points drops to `thresh` or below — the solved
/// prefix is still bit-identical to the standalone path's prefix
/// (batch composition never moves a bit); the default `None` keeps
/// full paths.
///
/// `ctl: Some(..)` also forces the point-major sweep so the deadline is
/// observed at grid-point boundaries (and, via [`SolveCtl`], inside each
/// solve at Newton-iteration granularity); a truncated sweep reports how
/// far it got via [`MultiSweepOut::points_done`] / `deadline_hit`, and
/// the solved prefixes are bit-identical to the uncontrolled sweep's.
///
/// `checkpoint: Some(slot)` resumes from / publishes into the slot's
/// [`MultiSweepCheckpoint`] after each completed point. A member that
/// trips the numerical guardrails is *evicted* — recorded in
/// [`MultiSweepOut::broken`], its siblings keep solving (their fused
/// passes are per-column independent, so eviction never moves a bit of
/// a healthy member's path).
#[allow(clippy::too_many_arguments)]
pub fn sweep_multi_prepared<B: SvmBackend>(
    sven: &Sven<B>,
    prep: &dyn SvmPrep,
    scratch: &mut SvmScratch,
    x: &Arc<Design>,
    responses: &[Arc<Vec<f64>>],
    live: &[usize],
    grid: &[GridPoint],
    early_stop: Option<f64>,
    ctl: Option<&SweepCtl<'_>>,
    checkpoint: Option<&CheckpointSlot>,
) -> anyhow::Result<MultiSweepOut> {
    let r = live.len();
    let primal = prep.mode() == SvmMode::Primal;
    let solve_ctl = ctl.map(|c| SolveCtl::new(c.expired));
    let mut stats = SvmBatchStats::default();
    if early_stop.is_none() && ctl.is_none() && checkpoint.is_none() {
        let mut paths: Vec<Vec<EnSolution>> =
            (0..r).map(|_| Vec::with_capacity(grid.len())).collect();
        let mut broken: Vec<Option<String>> = vec![None; r];
        if primal && r * grid.len() > 1 {
            let members: Vec<(usize, f64, f64)> = live
                .iter()
                .flat_map(|&resp| grid.iter().map(move |gp| (resp, gp.t, gp.lambda2)))
                .collect();
            let (sols, st) =
                sven.solve_prepared_batch_multi(prep, scratch, x, responses, &members, None)?;
            stats.merge(&st);
            let mut it = sols.into_iter();
            for (i, path) in paths.iter_mut().enumerate() {
                for _ in 0..grid.len() {
                    let sol = it.next().expect("one solution per member");
                    match (&broken[i], &sol.broken) {
                        (None, Some(msg)) => broken[i] = Some(msg.clone()),
                        (None, None) => path.push(sol),
                        // Past the member's breakdown point: keep only
                        // the clean prefix.
                        (Some(_), _) => {}
                    }
                }
            }
        } else {
            for (i, &resp) in live.iter().enumerate() {
                let mut warm: Option<SvmWarm> = None;
                for gp in grid {
                    let prob =
                        EnProblem::shared(x.clone(), responses[resp].clone(), gp.t, gp.lambda2);
                    let sol =
                        sven.solve_prepared_response(prep, scratch, &prob, warm.as_ref(), None)?;
                    if let Some(msg) = &sol.broken {
                        broken[i] = Some(msg.clone());
                        break;
                    }
                    warm = Some(SvmWarm { w: None, alpha: Some(sol.beta_to_warm(gp.t)) });
                    paths[i].push(sol);
                }
            }
        }
        return Ok(MultiSweepOut {
            paths,
            early_stopped_at: vec![None; r],
            points_done: grid.len(),
            deadline_hit: false,
            broken,
            stats,
        });
    }
    // Point-major sweep: one grid point at a time across the still-live
    // responses (batched in the primal), retiring plateaued columns the
    // way blocked CG retires converged ones, and observing the deadline
    // between points. Resume state (if any) restores the per-response
    // prefixes, warm chains and retirement bookkeeping exactly as the
    // dead attempt left them after its last *completed* point.
    let resumed = checkpoint
        .and_then(|slot| slot.lock().expect("checkpoint lock").clone())
        .and_then(|cp| cp.partial);
    let (mut paths, mut warms, mut prev_dev, mut stopped, mut broken, start_k) = match resumed {
        Some(p) => (p.paths, p.warms, p.prev_dev, p.stopped, p.broken, p.points_done),
        None => (
            (0..r).map(|_| Vec::with_capacity(grid.len())).collect(),
            vec![None; r],
            vec![None; r],
            vec![None; r],
            vec![None; r],
            0,
        ),
    };
    let mut active: Vec<usize> =
        (0..r).filter(|&i| stopped[i].is_none() && broken[i].is_none()).collect();
    let mut points_done = start_k.min(grid.len());
    let mut deadline_hit = false;
    'points: for (k, gp) in grid.iter().enumerate().skip(points_done) {
        if active.is_empty() {
            break;
        }
        if let Some(ctl) = ctl {
            if ctl.expired() {
                deadline_hit = true;
                break;
            }
            ctl.before_solves(active.len());
        }
        // Per-member t, NaN-poisoned per the fault schedule (in solve
        // order, one draw per member).
        let ts: Vec<f64> = active
            .iter()
            .map(|_| ctl.map_or(gp.t, |c| c.poisoned_t(gp.t)))
            .collect();
        let mut evicted: Vec<(usize, String)> = Vec::new();
        if primal && active.len() > 1 {
            let members: Vec<(usize, f64, f64)> = active
                .iter()
                .zip(&ts)
                .map(|(&i, &t)| (live[i], t, gp.lambda2))
                .collect();
            let (sols, st) =
                sven.solve_prepared_batch_multi(prep, scratch, x, responses, &members, solve_ctl.as_ref())?;
            stats.merge(&st);
            if sols.iter().any(|s| s.aborted) {
                // Deadline fired inside the fused Newton: discard the
                // whole point (converged members included — they're
                // re-solved bit-identically on resume) so every path
                // stays a prefix of exactly `points_done` points.
                if let Some(ctl) = ctl {
                    (ctl.on_intra_abort)();
                }
                deadline_hit = true;
                break 'points;
            }
            for (&i, sol) in active.iter().zip(sols) {
                if let Some(msg) = &sol.broken {
                    evicted.push((i, msg.clone()));
                } else {
                    paths[i].push(sol);
                }
            }
        } else {
            let mut pushed: Vec<usize> = Vec::with_capacity(active.len());
            for (&i, &t) in active.iter().zip(&ts) {
                let prob = EnProblem::shared(
                    x.clone(),
                    responses[live[i]].clone(),
                    t,
                    gp.lambda2,
                );
                let sol = sven.solve_prepared_response(
                    prep,
                    scratch,
                    &prob,
                    warms[i].as_ref(),
                    solve_ctl.as_ref(),
                )?;
                if sol.aborted {
                    // Roll back the members already solved at this point
                    // so the point is all-or-nothing (see above).
                    for &j in &pushed {
                        paths[j].pop();
                    }
                    if let Some(ctl) = ctl {
                        (ctl.on_intra_abort)();
                    }
                    deadline_hit = true;
                    break 'points;
                }
                if let Some(msg) = &sol.broken {
                    evicted.push((i, msg.clone()));
                    continue;
                }
                warms[i] = Some(SvmWarm { w: None, alpha: Some(sol.beta_to_warm(gp.t)) });
                paths[i].push(sol);
                pushed.push(i);
            }
        }
        for (i, msg) in &evicted {
            broken[*i] = Some(msg.clone());
        }
        active.retain(|i| broken[*i].is_none());
        points_done = k + 1;
        if let Some(thresh) = early_stop {
            let mut keep = Vec::with_capacity(active.len());
            for &i in &active {
                let sol = paths[i].last().expect("point just solved");
                let mut resid = x.matvec(&sol.beta);
                vecops::axpy(-1.0, responses[live[i]].as_slice(), &mut resid);
                let dev = vecops::norm2_sq(&resid);
                let plateaued = match prev_dev[i] {
                    Some(pd) => pd - dev <= thresh * pd.max(f64::MIN_POSITIVE),
                    None => false,
                };
                prev_dev[i] = Some(dev);
                if plateaued {
                    stopped[i] = Some(k);
                } else {
                    keep.push(i);
                }
            }
            active = keep;
        }
        if let Some(slot) = checkpoint {
            let mut s = slot.lock().expect("checkpoint lock");
            let cp = s.get_or_insert_with(SweepCheckpoint::default);
            let part = cp.partial.get_or_insert_with(|| MultiSweepCheckpoint::new(r));
            for i in 0..r {
                while part.paths[i].len() < paths[i].len() {
                    part.paths[i].push(paths[i][part.paths[i].len()].clone());
                }
            }
            part.warms.clone_from(&warms);
            part.prev_dev.clone_from(&prev_dev);
            part.stopped.clone_from(&stopped);
            part.broken.clone_from(&broken);
            part.points_done = points_done;
        }
    }
    Ok(MultiSweepOut {
        paths,
        early_stopped_at: stopped,
        points_done,
        deadline_hit,
        broken,
        stats,
    })
}

/// Configuration of a path run.
#[derive(Clone, Debug)]
pub struct PathRunnerConfig {
    /// Number of evaluation settings (the paper uses 40).
    pub grid: usize,
    /// Dense-path settings used to derive the grid.
    pub path: PathSettings,
    /// Warm-start successive solves from the previous point.
    pub warm_start: bool,
    /// Floor for λ₂ so C stays finite when the grid contains κ = 1 points.
    pub lambda2_floor: f64,
}

impl Default for PathRunnerConfig {
    fn default() -> Self {
        let mut path = PathSettings::default();
        // The reference path defines the evaluation grid (t = |β*|₁), so
        // its CD tolerance bounds every downstream comparison: at the
        // default 1e-9 the dense end of the path carries ~1e-3 coordinate
        // error, which would be misread as SVEN deviation.
        path.cd.tol = 1e-13;
        PathRunnerConfig { grid: 40, path, warm_start: true, lambda2_floor: 1e-6 }
    }
}

/// One solved grid point, with reference and SVEN solutions side by side.
#[derive(Clone, Debug)]
pub struct PathRunResult {
    pub t: f64,
    pub lambda2: f64,
    pub lambda: f64,
    /// Reference (glmnet) coefficients.
    pub beta_ref: Vec<f64>,
    /// SVEN coefficients.
    pub beta: Vec<f64>,
    /// max_j |β − β_ref| for this point.
    pub max_dev: f64,
    pub nnz: usize,
    /// SVEN solve seconds (excludes preparation, which is amortized).
    pub seconds: f64,
    pub iterations: usize,
}

/// Path runner over any SVEN backend.
pub struct PathRunner {
    pub config: PathRunnerConfig,
}

impl PathRunner {
    pub fn new(config: PathRunnerConfig) -> Self {
        PathRunner { config }
    }

    /// Derive the evaluation grid (paper protocol): glmnet dense path →
    /// subsample `grid` points with distinct supports.
    pub fn derive_grid(&self, data: &Dataset) -> Vec<PathPoint> {
        let pts = glmnet::compute_path(&data.x, &data.y, &self.config.path);
        glmnet::path::subsample_distinct(&pts, self.config.grid)
    }

    /// Project full path points down to the (t, λ₂) wire form with this
    /// runner's λ₂ floor applied — the grid a `JobKind::Path` service job
    /// carries. Feeding these to the service reproduces [`Self::run`]'s
    /// coefficient sequence bit-for-bit when `warm_start` is at its
    /// default `true` (service path jobs always chain warm starts).
    pub fn grid_points(&self, grid: &[PathPoint]) -> Vec<GridPoint> {
        grid.iter()
            .map(|pt| GridPoint {
                t: pt.t,
                lambda2: pt.lambda2.max(self.config.lambda2_floor),
            })
            .collect()
    }

    /// Sweep the grid with SVEN; returns per-point results including the
    /// reference deviation (the paper's "identical results" check).
    pub fn run<B: SvmBackend>(
        &self,
        data: &Dataset,
        sven: &Sven<B>,
        grid: &[PathPoint],
    ) -> anyhow::Result<Vec<PathRunResult>> {
        let x = Arc::new(Design::from(data.x.clone()));
        let y = Arc::new(data.y.clone());
        let prep = sven.prepare_shared(&x, &y)?;
        let mut scratch = SvmScratch::new();
        let points = self.grid_points(grid);
        let (sols, _batch) = sweep_prepared(
            sven,
            prep.as_ref(),
            &mut scratch,
            &x,
            &y,
            &points,
            None,
            self.config.warm_start,
            None,
            None,
        )?;
        Ok(grid
            .iter()
            .zip(points)
            .zip(sols)
            .map(|((pt, gp), sol)| {
                let max_dev = pt
                    .beta
                    .iter()
                    .zip(&sol.beta)
                    .map(|(a, b)| (a - b).abs())
                    .fold(0.0f64, f64::max);
                PathRunResult {
                    t: gp.t,
                    lambda2: gp.lambda2,
                    lambda: pt.lambda,
                    beta_ref: pt.beta.clone(),
                    nnz: vecops::nnz(&sol.beta, 1e-8),
                    max_dev,
                    seconds: sol.seconds,
                    iterations: sol.iterations,
                    beta: sol.beta,
                }
            })
            .collect())
    }

    /// Convenience: derive the grid and run in one call.
    pub fn derive_and_run<B: SvmBackend>(
        &self,
        data: &Dataset,
        sven: &Sven<B>,
    ) -> anyhow::Result<Vec<PathRunResult>> {
        let grid = self.derive_grid(data);
        self.run(data, sven, &grid)
    }
}

/// Worst deviation across a whole run — the Figure-1 "paths match" stat.
pub fn max_deviation(results: &[PathRunResult]) -> f64 {
    results.iter().map(|r| r.max_dev).fold(0.0, f64::max)
}

impl crate::solvers::elastic_net::EnSolution {
    /// Rebuild a feasible dual warm start from β (α⁺ = max(β,0)·Σ/t …):
    /// approximate but effective — only used to seed the next path point.
    pub fn beta_to_warm(&self, t: f64) -> Vec<f64> {
        let p = self.beta.len();
        let mut alpha = vec![0.0; 2 * p];
        for j in 0..p {
            if self.beta[j] > 0.0 {
                alpha[j] = self.beta[j] / t;
            } else {
                alpha[p + j] = -self.beta[j] / t;
            }
        }
        alpha
    }
}

#[cfg(test)]
#[allow(clippy::unwrap_used)]
mod tests {
    use super::*;
    use crate::data::{synth_regression, SynthSpec};
    use crate::solvers::sven::RustBackend;

    fn dataset(n: usize, p: usize, seed: u64) -> Dataset {
        synth_regression(&SynthSpec { n, p, support: 6, seed, ..Default::default() })
    }

    #[test]
    fn grid_has_distinct_supports() {
        let d = dataset(50, 30, 201);
        let runner = PathRunner::new(PathRunnerConfig {
            grid: 12,
            path: PathSettings { num_lambda: 60, ..Default::default() },
            ..Default::default()
        });
        let grid = runner.derive_grid(&d);
        assert!(!grid.is_empty() && grid.len() <= 12);
        let supports: Vec<usize> = grid.iter().map(|g| g.nnz).collect();
        let mut dedup = supports.clone();
        dedup.dedup();
        assert_eq!(supports, dedup);
    }

    #[test]
    fn sven_matches_reference_along_path() {
        let d = dataset(40, 25, 202);
        let runner = PathRunner::new(PathRunnerConfig {
            grid: 8,
            path: PathSettings { num_lambda: 40, ..Default::default() },
            ..Default::default()
        });
        let sven = Sven::new(RustBackend::default());
        let results = runner.derive_and_run(&d, &sven).unwrap();
        assert!(!results.is_empty());
        let dev = max_deviation(&results);
        assert!(dev < 5e-4, "path deviation {dev}");
    }

    #[test]
    fn dual_regime_path() {
        let d = dataset(120, 10, 203);
        let runner = PathRunner::new(PathRunnerConfig {
            grid: 6,
            path: PathSettings { num_lambda: 30, ..Default::default() },
            ..Default::default()
        });
        let sven = Sven::new(RustBackend::default());
        let results = runner.derive_and_run(&d, &sven).unwrap();
        let dev = max_deviation(&results);
        assert!(dev < 5e-4, "path deviation {dev}");
    }

    #[test]
    fn timings_recorded() {
        let d = dataset(30, 20, 204);
        let runner = PathRunner::new(PathRunnerConfig {
            grid: 4,
            path: PathSettings { num_lambda: 25, ..Default::default() },
            ..Default::default()
        });
        let sven = Sven::new(RustBackend::default());
        let results = runner.derive_and_run(&d, &sven).unwrap();
        assert!(results.iter().all(|r| r.seconds > 0.0));
    }

    #[test]
    fn multi_sweep_matches_per_response_sweeps_bitwise() {
        // One shared prep + sweep_multi_prepared ≡ per-response
        // sweep_prepared over fresh preps, bit for bit, in both regimes.
        use crate::rng::Rng;
        let grid = [
            GridPoint { t: 0.3, lambda2: 0.5 },
            GridPoint { t: 0.6, lambda2: 0.5 },
            GridPoint { t: 0.9, lambda2: 0.4 },
        ];
        for (n, p) in [(14usize, 20usize), (60, 8)] {
            // (14, 20): 2p > n ⇒ primal; (60, 8): dual.
            let mut rng = Rng::seed_from(206);
            let x: Arc<Design> =
                Arc::new(crate::linalg::Mat::from_fn(n, p, |_, _| rng.normal()).into());
            let responses: Vec<Arc<Vec<f64>>> = (0..3)
                .map(|_| Arc::new((0..n).map(|_| rng.normal()).collect::<Vec<f64>>()))
                .collect();
            let sven = Sven::new(RustBackend::default());
            let prep = sven.prepare_shared(&x, &responses[0]).unwrap();
            let mut scratch = SvmScratch::new();
            let live = [0usize, 1, 2];
            let multi = sweep_multi_prepared(
                &sven,
                prep.as_ref(),
                &mut scratch,
                &x,
                &responses,
                &live,
                &grid,
                None,
                None,
                None,
            )
            .unwrap();
            assert!(multi.early_stopped_at.iter().all(Option::is_none));
            assert!(multi.broken.iter().all(Option::is_none));
            assert_eq!(multi.points_done, grid.len());
            assert!(!multi.deadline_hit);
            for (i, y) in responses.iter().enumerate() {
                let solo_prep = sven.prepare_shared(&x, y).unwrap();
                let (solo, _) = sweep_prepared(
                    &sven,
                    solo_prep.as_ref(),
                    &mut scratch,
                    &x,
                    y,
                    &grid,
                    None,
                    true,
                    None,
                    None,
                )
                .unwrap();
                assert_eq!(multi.paths[i].len(), solo.len());
                for (k, (ms, ss)) in multi.paths[i].iter().zip(&solo).enumerate() {
                    assert_eq!(ms.iterations, ss.iterations, "n={n} resp {i} pt {k}");
                    for j in 0..p {
                        assert_eq!(
                            ms.beta[j].to_bits(),
                            ss.beta[j].to_bits(),
                            "n={n} resp {i} pt {k} j={j}"
                        );
                    }
                }
            }
        }
    }

    #[test]
    fn multi_sweep_early_stop_truncates_to_bitwise_prefix() {
        // A plateau threshold of 1.0 retires every response right after
        // its second point (pd − dev ≤ pd always); the solved prefix
        // must be bit-identical to the full sweep's prefix.
        use crate::rng::Rng;
        let grid = [
            GridPoint { t: 0.2, lambda2: 0.5 },
            GridPoint { t: 0.5, lambda2: 0.5 },
            GridPoint { t: 0.8, lambda2: 0.5 },
        ];
        let mut rng = Rng::seed_from(207);
        let x: Arc<Design> =
            Arc::new(crate::linalg::Mat::from_fn(12, 18, |_, _| rng.normal()).into());
        let responses: Vec<Arc<Vec<f64>>> = (0..2)
            .map(|_| Arc::new((0..12).map(|_| rng.normal()).collect::<Vec<f64>>()))
            .collect();
        let sven = Sven::new(RustBackend::default());
        let prep = sven.prepare_shared(&x, &responses[0]).unwrap();
        let mut scratch = SvmScratch::new();
        let live = [0usize, 1];
        let full = sweep_multi_prepared(
            &sven,
            prep.as_ref(),
            &mut scratch,
            &x,
            &responses,
            &live,
            &grid,
            None,
            None,
            None,
        )
        .unwrap();
        let stopped = sweep_multi_prepared(
            &sven,
            prep.as_ref(),
            &mut scratch,
            &x,
            &responses,
            &live,
            &grid,
            Some(1.0),
            None,
            None,
        )
        .unwrap();
        for i in 0..2 {
            assert_eq!(stopped.early_stopped_at[i], Some(1), "resp {i}");
            assert_eq!(stopped.paths[i].len(), 2, "resp {i}");
            for (k, (ts, fs)) in stopped.paths[i].iter().zip(&full.paths[i]).enumerate() {
                for j in 0..18 {
                    assert_eq!(
                        ts.beta[j].to_bits(),
                        fs.beta[j].to_bits(),
                        "resp {i} pt {k} j={j}"
                    );
                }
            }
        }
    }

    #[test]
    fn controlled_sweep_truncates_to_bitwise_prefix() {
        // A SweepCtl whose deadline fires after `budget` solves must stop
        // the sweep with a prefix bit-identical to the uncontrolled run —
        // in the primal this also pins chunk-composition: 8-wide chunks
        // reproduce the single whole-grid batch exactly.
        use crate::rng::Rng;
        use std::cell::Cell;
        for (n, p, budget, expect_len) in
            [(14usize, 20usize, 5usize, CTL_CHUNK), (60, 8, 3, 3)]
        {
            let mut rng = Rng::seed_from(208);
            let x: Arc<Design> =
                Arc::new(crate::linalg::Mat::from_fn(n, p, |_, _| rng.normal()).into());
            let y: Arc<Vec<f64>> =
                Arc::new((0..n).map(|_| rng.normal()).collect::<Vec<f64>>());
            let grid: Vec<GridPoint> = (0..12)
                .map(|k| GridPoint { t: 0.1 + 0.07 * k as f64, lambda2: 0.5 })
                .collect();
            let sven = Sven::new(RustBackend::default());
            let prep = sven.prepare_shared(&x, &y).unwrap();
            let mut scratch = SvmScratch::new();
            let (full, _) = sweep_prepared(
                &sven, prep.as_ref(), &mut scratch, &x, &y, &grid, None, true, None, None,
            )
            .unwrap();
            let solved = Cell::new(0usize);
            let expired = || solved.get() >= budget;
            let before_solve = || solved.set(solved.get() + 1);
            let no_poison = || false;
            let no_abort = || {};
            let ctl = SweepCtl {
                expired: &expired,
                before_solve: &before_solve,
                poison: &no_poison,
                on_intra_abort: &no_abort,
            };
            let (trunc, _) = sweep_prepared(
                &sven,
                prep.as_ref(),
                &mut scratch,
                &x,
                &y,
                &grid,
                None,
                true,
                Some(&ctl),
                None,
            )
            .unwrap();
            assert_eq!(trunc.len(), expect_len, "n={n}");
            for (k, (ts, fs)) in trunc.iter().zip(&full).enumerate() {
                for j in 0..p {
                    assert_eq!(
                        ts.beta[j].to_bits(),
                        fs.beta[j].to_bits(),
                        "n={n} pt {k} j={j}"
                    );
                }
            }
        }
    }

    #[test]
    fn sweep_resumed_from_checkpoint_is_bit_identical() {
        // Kill the sweep after `budget` solves (deadline), then resume
        // from the published checkpoint: the concatenation must be
        // bit-for-bit the uninterrupted sweep, in both regimes.
        use crate::rng::Rng;
        use std::cell::Cell;
        for (n, p) in [(14usize, 20usize), (60, 8)] {
            let mut rng = Rng::seed_from(209);
            let x: Arc<Design> =
                Arc::new(crate::linalg::Mat::from_fn(n, p, |_, _| rng.normal()).into());
            let y: Arc<Vec<f64>> =
                Arc::new((0..n).map(|_| rng.normal()).collect::<Vec<f64>>());
            let grid: Vec<GridPoint> = (0..10)
                .map(|k| GridPoint { t: 0.1 + 0.08 * k as f64, lambda2: 0.5 })
                .collect();
            let sven = Sven::new(RustBackend::default());
            let prep = sven.prepare_shared(&x, &y).unwrap();
            let mut scratch = SvmScratch::new();
            let (full, _) = sweep_prepared(
                &sven, prep.as_ref(), &mut scratch, &x, &y, &grid, None, true, None, None,
            )
            .unwrap();
            for budget in [1usize, 4, 7] {
                let slot: CheckpointSlot = Mutex::new(None);
                let solved = Cell::new(0usize);
                let expired = || solved.get() >= budget;
                let before_solve = || solved.set(solved.get() + 1);
                let no_poison = || false;
                let no_abort = || {};
                let ctl = SweepCtl {
                    expired: &expired,
                    before_solve: &before_solve,
                    poison: &no_poison,
                    on_intra_abort: &no_abort,
                };
                let (trunc, _) = sweep_prepared(
                    &sven,
                    prep.as_ref(),
                    &mut scratch,
                    &x,
                    &y,
                    &grid,
                    None,
                    true,
                    Some(&ctl),
                    Some(&slot),
                )
                .unwrap();
                assert!(trunc.len() < grid.len(), "n={n} budget {budget} not truncated");
                let published =
                    slot.lock().unwrap().as_ref().map_or(0, |cp| cp.completed.len());
                assert_eq!(published, trunc.len(), "n={n} budget {budget}");
                // Second attempt, fresh ctl that never expires, same slot.
                let never = || false;
                let ctl2 = SweepCtl {
                    expired: &never,
                    before_solve: &no_abort,
                    poison: &no_poison,
                    on_intra_abort: &no_abort,
                };
                let (resumed, _) = sweep_prepared(
                    &sven,
                    prep.as_ref(),
                    &mut scratch,
                    &x,
                    &y,
                    &grid,
                    None,
                    true,
                    Some(&ctl2),
                    Some(&slot),
                )
                .unwrap();
                assert_eq!(resumed.len(), full.len(), "n={n} budget {budget}");
                for (k, (rs, fs)) in resumed.iter().zip(&full).enumerate() {
                    for j in 0..p {
                        assert_eq!(
                            rs.beta[j].to_bits(),
                            fs.beta[j].to_bits(),
                            "n={n} budget {budget} pt {k} j={j}"
                        );
                    }
                }
            }
        }
    }

    #[test]
    fn poisoned_sweep_fails_with_breakdown_sentinel() {
        // A poison schedule that NaNs the third solve must surface the
        // `numerical breakdown at …` sentinel, never a served β.
        use crate::rng::Rng;
        use std::cell::Cell;
        for (n, p) in [(14usize, 20usize), (60, 8)] {
            let mut rng = Rng::seed_from(210);
            let x: Arc<Design> =
                Arc::new(crate::linalg::Mat::from_fn(n, p, |_, _| rng.normal()).into());
            let y: Arc<Vec<f64>> =
                Arc::new((0..n).map(|_| rng.normal()).collect::<Vec<f64>>());
            let grid: Vec<GridPoint> = (0..6)
                .map(|k| GridPoint { t: 0.2 + 0.1 * k as f64, lambda2: 0.5 })
                .collect();
            let sven = Sven::new(RustBackend::default());
            let prep = sven.prepare_shared(&x, &y).unwrap();
            let mut scratch = SvmScratch::new();
            let never = || false;
            let noop = || {};
            let drawn = Cell::new(0usize);
            let poison = || {
                drawn.set(drawn.get() + 1);
                drawn.get() == 3
            };
            let ctl = SweepCtl {
                expired: &never,
                before_solve: &noop,
                poison: &poison,
                on_intra_abort: &noop,
            };
            let err = sweep_prepared(
                &sven,
                prep.as_ref(),
                &mut scratch,
                &x,
                &y,
                &grid,
                None,
                true,
                Some(&ctl),
                None,
            )
            .unwrap_err();
            let msg = err.to_string();
            assert!(
                msg.starts_with("numerical breakdown at grid[2]:"),
                "n={n} unexpected error: {msg}"
            );
        }
    }

    #[test]
    fn multi_sweep_evicts_poisoned_member_and_siblings_stay_bit_identical() {
        // Poison one member's solve at point 0: that response is evicted
        // with the breakdown detail recorded, and its siblings' full
        // paths match the clean sweep bit-for-bit.
        use crate::rng::Rng;
        use std::cell::Cell;
        let grid = [
            GridPoint { t: 0.3, lambda2: 0.5 },
            GridPoint { t: 0.6, lambda2: 0.5 },
            GridPoint { t: 0.9, lambda2: 0.4 },
        ];
        for (n, p) in [(14usize, 20usize), (60, 8)] {
            let mut rng = Rng::seed_from(211);
            let x: Arc<Design> =
                Arc::new(crate::linalg::Mat::from_fn(n, p, |_, _| rng.normal()).into());
            let responses: Vec<Arc<Vec<f64>>> = (0..3)
                .map(|_| Arc::new((0..n).map(|_| rng.normal()).collect::<Vec<f64>>()))
                .collect();
            let sven = Sven::new(RustBackend::default());
            let prep = sven.prepare_shared(&x, &responses[0]).unwrap();
            let mut scratch = SvmScratch::new();
            let live = [0usize, 1, 2];
            let clean = sweep_multi_prepared(
                &sven,
                prep.as_ref(),
                &mut scratch,
                &x,
                &responses,
                &live,
                &grid,
                None,
                None,
                None,
            )
            .unwrap();
            let never = || false;
            let noop = || {};
            // Point 0 draws members in order 0,1,2 — poison the second.
            let drawn = Cell::new(0usize);
            let poison = || {
                drawn.set(drawn.get() + 1);
                drawn.get() == 2
            };
            let ctl = SweepCtl {
                expired: &never,
                before_solve: &noop,
                poison: &poison,
                on_intra_abort: &noop,
            };
            let sick = sweep_multi_prepared(
                &sven,
                prep.as_ref(),
                &mut scratch,
                &x,
                &responses,
                &live,
                &grid,
                None,
                Some(&ctl),
                None,
            )
            .unwrap();
            assert!(sick.broken[1].is_some(), "n={n} member not evicted");
            assert!(sick.paths[1].is_empty(), "n={n} evicted member kept points");
            assert!(!sick.deadline_hit);
            assert_eq!(sick.points_done, grid.len());
            for &i in &[0usize, 2] {
                assert!(sick.broken[i].is_none(), "n={n} sibling {i} evicted");
                assert_eq!(sick.paths[i].len(), grid.len());
                for (k, (ss, cs)) in sick.paths[i].iter().zip(&clean.paths[i]).enumerate() {
                    for j in 0..p {
                        assert_eq!(
                            ss.beta[j].to_bits(),
                            cs.beta[j].to_bits(),
                            "n={n} sibling {i} pt {k} j={j}"
                        );
                    }
                }
            }
        }
    }

    #[test]
    fn multi_sweep_resumed_from_checkpoint_is_bit_identical() {
        use crate::rng::Rng;
        use std::cell::Cell;
        let grid: Vec<GridPoint> = (0..5)
            .map(|k| GridPoint { t: 0.2 + 0.15 * k as f64, lambda2: 0.5 })
            .collect();
        for (n, p) in [(14usize, 20usize), (60, 8)] {
            let mut rng = Rng::seed_from(212);
            let x: Arc<Design> =
                Arc::new(crate::linalg::Mat::from_fn(n, p, |_, _| rng.normal()).into());
            let responses: Vec<Arc<Vec<f64>>> = (0..2)
                .map(|_| Arc::new((0..n).map(|_| rng.normal()).collect::<Vec<f64>>()))
                .collect();
            let sven = Sven::new(RustBackend::default());
            let prep = sven.prepare_shared(&x, &responses[0]).unwrap();
            let mut scratch = SvmScratch::new();
            let live = [0usize, 1];
            let full = sweep_multi_prepared(
                &sven,
                prep.as_ref(),
                &mut scratch,
                &x,
                &responses,
                &live,
                &grid,
                None,
                None,
                None,
            )
            .unwrap();
            let slot: CheckpointSlot = Mutex::new(None);
            let solved = Cell::new(0usize);
            // Expire after the 4th member-solve: two full points done.
            let expired = || solved.get() >= 4;
            let before_solve = || solved.set(solved.get() + 1);
            let no_poison = || false;
            let noop = || {};
            let ctl = SweepCtl {
                expired: &expired,
                before_solve: &before_solve,
                poison: &no_poison,
                on_intra_abort: &noop,
            };
            let trunc = sweep_multi_prepared(
                &sven,
                prep.as_ref(),
                &mut scratch,
                &x,
                &responses,
                &live,
                &grid,
                None,
                Some(&ctl),
                Some(&slot),
            )
            .unwrap();
            assert!(trunc.deadline_hit, "n={n}");
            assert!(trunc.points_done < grid.len(), "n={n}");
            let never = || false;
            let ctl2 = SweepCtl {
                expired: &never,
                before_solve: &noop,
                poison: &no_poison,
                on_intra_abort: &noop,
            };
            let resumed = sweep_multi_prepared(
                &sven,
                prep.as_ref(),
                &mut scratch,
                &x,
                &responses,
                &live,
                &grid,
                None,
                Some(&ctl2),
                Some(&slot),
            )
            .unwrap();
            assert_eq!(resumed.points_done, grid.len(), "n={n}");
            for i in 0..2 {
                assert_eq!(resumed.paths[i].len(), grid.len(), "n={n} resp {i}");
                for (k, (rs, fs)) in resumed.paths[i].iter().zip(&full.paths[i]).enumerate()
                {
                    for j in 0..p {
                        assert_eq!(
                            rs.beta[j].to_bits(),
                            fs.beta[j].to_bits(),
                            "n={n} resp {i} pt {k} j={j}"
                        );
                    }
                }
            }
        }
    }

    #[test]
    fn grid_points_apply_floor() {
        let runner = PathRunner::new(PathRunnerConfig::default());
        let pt = PathPoint {
            lambda: 0.1,
            kappa: 1.0,
            t: 0.5,
            lambda2: 0.0,
            beta: vec![],
            nnz: 1,
            epochs: 1,
        };
        let gps = runner.grid_points(&[pt]);
        assert_eq!(gps[0].lambda2, runner.config.lambda2_floor);
        assert_eq!(gps[0].t, 0.5);
    }
}
