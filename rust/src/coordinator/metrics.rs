//! Service metrics: counters and latency records, cheap enough for the
//! request hot path (atomics + a mutex-guarded reservoir only on
//! completion).

use crate::util::Summary;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

/// Aggregated coordinator metrics.
#[derive(Default)]
pub struct Metrics {
    submitted: AtomicU64,
    completed: AtomicU64,
    failed: AtomicU64,
    latencies: Mutex<Vec<f64>>,
    queue_waits: Mutex<Vec<f64>>,
}

impl Metrics {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn on_submit(&self) {
        self.submitted.fetch_add(1, Ordering::Relaxed);
    }

    pub fn on_complete(&self, latency_s: f64, queue_wait_s: f64) {
        self.completed.fetch_add(1, Ordering::Relaxed);
        self.latencies.lock().unwrap().push(latency_s);
        self.queue_waits.lock().unwrap().push(queue_wait_s);
    }

    pub fn on_fail(&self) {
        self.failed.fetch_add(1, Ordering::Relaxed);
    }

    pub fn submitted(&self) -> u64 {
        self.submitted.load(Ordering::Relaxed)
    }

    pub fn completed(&self) -> u64 {
        self.completed.load(Ordering::Relaxed)
    }

    pub fn failed(&self) -> u64 {
        self.failed.load(Ordering::Relaxed)
    }

    /// End-to-end latency summary (None until something completed).
    pub fn latency_summary(&self) -> Option<Summary> {
        let l = self.latencies.lock().unwrap();
        if l.is_empty() {
            None
        } else {
            Some(Summary::from(l.clone()))
        }
    }

    /// Queue-wait summary — the backpressure signal.
    pub fn queue_wait_summary(&self) -> Option<Summary> {
        let l = self.queue_waits.lock().unwrap();
        if l.is_empty() {
            None
        } else {
            Some(Summary::from(l.clone()))
        }
    }

    /// One-line report for logs.
    pub fn report(&self) -> String {
        let lat = self
            .latency_summary()
            .map(|s| {
                format!(
                    "latency p50={} p95={} max={}",
                    crate::util::fmt_duration(s.median()),
                    crate::util::fmt_duration(s.p95()),
                    crate::util::fmt_duration(s.max())
                )
            })
            .unwrap_or_else(|| "latency n/a".into());
        format!(
            "submitted={} completed={} failed={} {lat}",
            self.submitted(),
            self.completed(),
            self.failed()
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_and_summary() {
        let m = Metrics::new();
        m.on_submit();
        m.on_submit();
        m.on_complete(0.010, 0.001);
        m.on_complete(0.020, 0.002);
        m.on_fail();
        assert_eq!(m.submitted(), 2);
        assert_eq!(m.completed(), 2);
        assert_eq!(m.failed(), 1);
        let s = m.latency_summary().unwrap();
        assert!((s.median() - 0.015).abs() < 1e-12);
        assert!(m.report().contains("completed=2"));
    }

    #[test]
    fn empty_summaries_are_none() {
        let m = Metrics::new();
        assert!(m.latency_summary().is_none());
        assert!(m.queue_wait_summary().is_none());
    }
}
