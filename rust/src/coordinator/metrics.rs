//! Service metrics: counters and latency records, cheap enough for the
//! request hot path (atomics + a mutex-guarded reservoir only on
//! completion).

use super::sync::lock;
use crate::util::Summary;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Mutex, OnceLock};

/// Aggregated coordinator metrics.
#[derive(Default)]
pub struct Metrics {
    /// Dispatched kernel + cache geometry, set once at service startup.
    kernel_info: OnceLock<String>,
    submitted: AtomicU64,
    completed: AtomicU64,
    failed: AtomicU64,
    rejected: AtomicU64,
    prep_hits: AtomicU64,
    prep_builds: AtomicU64,
    prep_evictions: AtomicU64,
    path_segments: AtomicU64,
    sv_gather_rebuilds: AtomicU64,
    cg_iters_total: AtomicU64,
    refine_iters_total: AtomicU64,
    f32_panel_bytes: AtomicU64,
    cv_folds: AtomicU64,
    batched_cg_rhs_total: AtomicU64,
    batch_panel_rebuilds: AtomicU64,
    responses_total: AtomicU64,
    responses_screened_out: AtomicU64,
    responses_early_stopped: AtomicU64,
    segment_handoffs: AtomicU64,
    segment_handoff_waits: AtomicU64,
    worker_panics: AtomicU64,
    worker_respawns: AtomicU64,
    jobs_truncated: AtomicU64,
    jobs_shed: AtomicU64,
    jobs_retried: AtomicU64,
    deadline_aborts: AtomicU64,
    intra_solve_aborts: AtomicU64,
    prep_build_failures: AtomicU64,
    checkpoints_published: AtomicU64,
    resumed_from_checkpoint: AtomicU64,
    numerical_breakdowns: AtomicU64,
    members_evicted: AtomicU64,
    latencies: Mutex<Vec<f64>>,
    queue_waits: Mutex<Vec<f64>>,
}

impl Metrics {
    pub fn new() -> Self {
        Self::default()
    }

    /// Record the dispatched-kernel/geometry line (once; later calls are
    /// ignored — dispatch is fixed for the process lifetime).
    pub fn set_kernel_info(&self, info: String) {
        let _ = self.kernel_info.set(info);
    }

    /// Dispatched kernel + cache geometry, if recorded.
    pub fn kernel_info(&self) -> Option<&str> {
        self.kernel_info.get().map(String::as_str)
    }

    pub fn on_submit(&self) {
        self.submitted.fetch_add(1, Ordering::Relaxed);
    }

    pub fn on_complete(&self, latency_s: f64, queue_wait_s: f64) {
        self.completed.fetch_add(1, Ordering::Relaxed);
        lock(&self.latencies).push(latency_s);
        lock(&self.queue_waits).push(queue_wait_s);
    }

    /// Failed jobs record their queue wait too — backpressure must stay
    /// visible precisely when the system is misbehaving.
    pub fn on_fail(&self, queue_wait_s: f64) {
        self.failed.fetch_add(1, Ordering::Relaxed);
        lock(&self.queue_waits).push(queue_wait_s);
    }

    /// A submission bounced off a closed service.
    pub fn on_reject(&self) {
        self.rejected.fetch_add(1, Ordering::Relaxed);
    }

    /// A job found its preparation in the shared cache (including
    /// single-flight waiters that joined an in-progress build).
    pub fn on_prep_hit(&self) {
        self.prep_hits.fetch_add(1, Ordering::Relaxed);
    }

    /// A job actually built a preparation (a cache miss).
    pub fn on_prep_build(&self) {
        self.prep_builds.fetch_add(1, Ordering::Relaxed);
    }

    /// A ready preparation was evicted to respect the capacity bound.
    pub fn on_prep_eviction(&self) {
        self.prep_evictions.fetch_add(1, Ordering::Relaxed);
    }

    /// A worker picked up one segment of a split `Path` grid.
    pub fn on_path_segment(&self) {
        self.path_segments.fetch_add(1, Ordering::Relaxed);
    }

    /// Per-solve counters reported by the SVM backends: inner-CG
    /// iterations, active-set panel rebuilds, and mixed-precision
    /// refinement passes (accumulated across the solves of each job;
    /// `refine_passes` stays 0 for pure-f64 solves).
    pub fn on_solve_stats(&self, cg_iters: usize, gather_rebuilds: usize, refine_passes: usize) {
        if cg_iters > 0 {
            self.cg_iters_total.fetch_add(cg_iters as u64, Ordering::Relaxed);
        }
        if gather_rebuilds > 0 {
            self.sv_gather_rebuilds.fetch_add(gather_rebuilds as u64, Ordering::Relaxed);
        }
        if refine_passes > 0 {
            self.refine_iters_total.fetch_add(refine_passes as u64, Ordering::Relaxed);
        }
    }

    /// Bytes of f32 shadow panels held by a freshly built preparation
    /// (0 for pure-f64 preps; accumulated across prep builds so the
    /// mixed tier's memory cost is visible next to its solve counters).
    pub fn on_f32_panel_bytes(&self, bytes: usize) {
        if bytes > 0 {
            self.f32_panel_bytes.fetch_add(bytes as u64, Ordering::Relaxed);
        }
    }

    /// A CV-fold sub-problem was built (once per fold per `CvPath` job).
    pub fn on_cv_fold(&self) {
        self.cv_folds.fetch_add(1, Ordering::Relaxed);
    }

    /// Batch fusion counters from a sweep: Newton right-hand sides that
    /// went through blocked CG, and physical shared-panel gathers.
    pub fn on_batch_stats(&self, batched_rhs: usize, panel_builds: usize) {
        if batched_rhs > 0 {
            self.batched_cg_rhs_total.fetch_add(batched_rhs as u64, Ordering::Relaxed);
        }
        if panel_builds > 0 {
            self.batch_panel_rebuilds.fetch_add(panel_builds as u64, Ordering::Relaxed);
        }
    }

    /// Responses carried by a multi-response job (counted once per job,
    /// when its shared screening pass runs).
    pub fn on_responses(&self, n: usize) {
        if n > 0 {
            self.responses_total.fetch_add(n as u64, Ordering::Relaxed);
        }
    }

    /// Responses the λ_max screen retired without a single SVM solve.
    pub fn on_responses_screened(&self, n: usize) {
        if n > 0 {
            self.responses_screened_out.fetch_add(n as u64, Ordering::Relaxed);
        }
    }

    /// Responses whose deviance plateaued before the end of the grid.
    pub fn on_responses_early_stopped(&self, n: usize) {
        if n > 0 {
            self.responses_early_stopped.fetch_add(n as u64, Ordering::Relaxed);
        }
    }

    /// A path segment chained from its predecessor's handed-off warm
    /// start instead of re-solving the boundary endpoint speculatively.
    pub fn on_segment_handoff(&self) {
        self.segment_handoffs.fetch_add(1, Ordering::Relaxed);
    }

    /// A path segment obtained its predecessor's warm start by briefly
    /// waiting on the hand-off condvar (the predecessor was in flight
    /// and the pool had other queued work to absorb the pause).
    pub fn on_segment_handoff_wait(&self) {
        self.segment_handoff_waits.fetch_add(1, Ordering::Relaxed);
    }

    /// A worker caught a panic while executing a job attempt (the job
    /// fails with `WorkerPanic` or retries; the worker survives).
    pub fn on_worker_panic(&self) {
        self.worker_panics.fetch_add(1, Ordering::Relaxed);
    }

    /// A panic escaped job-level isolation and the pool rebuilt the
    /// worker's context in place (the supervised-worker backstop).
    pub fn on_worker_respawn(&self) {
        self.worker_respawns.fetch_add(1, Ordering::Relaxed);
    }

    /// A deadline-carrying job completed with a truncated (but
    /// bit-identical) prefix of its grid.
    pub fn on_truncated(&self) {
        self.jobs_truncated.fetch_add(1, Ordering::Relaxed);
    }

    /// Admission control shed a submission before it touched the queue.
    pub fn on_shed(&self) {
        self.jobs_shed.fetch_add(1, Ordering::Relaxed);
    }

    /// A transient failure triggered a retry attempt.
    pub fn on_job_retried(&self) {
        self.jobs_retried.fetch_add(1, Ordering::Relaxed);
    }

    /// A work item stopped early (skipped or truncated mid-sweep)
    /// because its job's deadline passed.
    pub fn on_deadline_abort(&self) {
        self.deadline_aborts.fetch_add(1, Ordering::Relaxed);
    }

    /// A deadline fired *inside* a batched Newton solve and the sweep
    /// discarded the half-converged members (the served prefix still
    /// ends at the last fully completed grid point).
    pub fn on_intra_solve_abort(&self) {
        self.intra_solve_aborts.fetch_add(1, Ordering::Relaxed);
    }

    /// A preparation build failed or panicked (the failed cache slot is
    /// evicted and every single-flight waiter observes the error).
    pub fn on_prep_build_failure(&self) {
        self.prep_build_failures.fetch_add(1, Ordering::Relaxed);
    }

    /// A sweep published per-grid-point checkpoints into its job's
    /// shared state (`n` = points checkpointed by this work item).
    pub fn on_checkpoints_published(&self, n: usize) {
        if n > 0 {
            self.checkpoints_published.fetch_add(n as u64, Ordering::Relaxed);
        }
    }

    /// A retried work item resumed from a published checkpoint instead
    /// of re-solving its already-correct prefix.
    pub fn on_resumed_from_checkpoint(&self) {
        self.resumed_from_checkpoint.fetch_add(1, Ordering::Relaxed);
    }

    /// A non-finite value was caught inside a solve (margins, residuals
    /// or objective) before it could reach a served β.
    pub fn on_numerical_breakdown(&self) {
        self.numerical_breakdowns.fetch_add(1, Ordering::Relaxed);
    }

    /// Sick members evicted from a fused batch so their siblings could
    /// finish (counted per evicted member).
    pub fn on_members_evicted(&self, n: usize) {
        if n > 0 {
            self.members_evicted.fetch_add(n as u64, Ordering::Relaxed);
        }
    }

    pub fn submitted(&self) -> u64 {
        self.submitted.load(Ordering::Relaxed)
    }

    pub fn completed(&self) -> u64 {
        self.completed.load(Ordering::Relaxed)
    }

    pub fn failed(&self) -> u64 {
        self.failed.load(Ordering::Relaxed)
    }

    pub fn rejected(&self) -> u64 {
        self.rejected.load(Ordering::Relaxed)
    }

    pub fn prep_hits(&self) -> u64 {
        self.prep_hits.load(Ordering::Relaxed)
    }

    pub fn prep_builds(&self) -> u64 {
        self.prep_builds.load(Ordering::Relaxed)
    }

    pub fn prep_evictions(&self) -> u64 {
        self.prep_evictions.load(Ordering::Relaxed)
    }

    pub fn path_segments(&self) -> u64 {
        self.path_segments.load(Ordering::Relaxed)
    }

    pub fn sv_gather_rebuilds(&self) -> u64 {
        self.sv_gather_rebuilds.load(Ordering::Relaxed)
    }

    pub fn cg_iters_total(&self) -> u64 {
        self.cg_iters_total.load(Ordering::Relaxed)
    }

    pub fn refine_iters_total(&self) -> u64 {
        self.refine_iters_total.load(Ordering::Relaxed)
    }

    pub fn f32_panel_bytes(&self) -> u64 {
        self.f32_panel_bytes.load(Ordering::Relaxed)
    }

    pub fn cv_folds(&self) -> u64 {
        self.cv_folds.load(Ordering::Relaxed)
    }

    pub fn batched_cg_rhs_total(&self) -> u64 {
        self.batched_cg_rhs_total.load(Ordering::Relaxed)
    }

    pub fn batch_panel_rebuilds(&self) -> u64 {
        self.batch_panel_rebuilds.load(Ordering::Relaxed)
    }

    pub fn responses_total(&self) -> u64 {
        self.responses_total.load(Ordering::Relaxed)
    }

    pub fn responses_screened_out(&self) -> u64 {
        self.responses_screened_out.load(Ordering::Relaxed)
    }

    pub fn responses_early_stopped(&self) -> u64 {
        self.responses_early_stopped.load(Ordering::Relaxed)
    }

    pub fn segment_handoffs(&self) -> u64 {
        self.segment_handoffs.load(Ordering::Relaxed)
    }

    pub fn segment_handoff_waits(&self) -> u64 {
        self.segment_handoff_waits.load(Ordering::Relaxed)
    }

    pub fn worker_panics(&self) -> u64 {
        self.worker_panics.load(Ordering::Relaxed)
    }

    pub fn worker_respawns(&self) -> u64 {
        self.worker_respawns.load(Ordering::Relaxed)
    }

    pub fn jobs_truncated(&self) -> u64 {
        self.jobs_truncated.load(Ordering::Relaxed)
    }

    pub fn jobs_shed(&self) -> u64 {
        self.jobs_shed.load(Ordering::Relaxed)
    }

    pub fn jobs_retried(&self) -> u64 {
        self.jobs_retried.load(Ordering::Relaxed)
    }

    pub fn deadline_aborts(&self) -> u64 {
        self.deadline_aborts.load(Ordering::Relaxed)
    }

    pub fn prep_build_failures(&self) -> u64 {
        self.prep_build_failures.load(Ordering::Relaxed)
    }

    pub fn intra_solve_aborts(&self) -> u64 {
        self.intra_solve_aborts.load(Ordering::Relaxed)
    }

    pub fn checkpoints_published(&self) -> u64 {
        self.checkpoints_published.load(Ordering::Relaxed)
    }

    pub fn resumed_from_checkpoint(&self) -> u64 {
        self.resumed_from_checkpoint.load(Ordering::Relaxed)
    }

    pub fn numerical_breakdowns(&self) -> u64 {
        self.numerical_breakdowns.load(Ordering::Relaxed)
    }

    pub fn members_evicted(&self) -> u64 {
        self.members_evicted.load(Ordering::Relaxed)
    }

    /// End-to-end latency summary (None until something completed).
    pub fn latency_summary(&self) -> Option<Summary> {
        let l = lock(&self.latencies);
        if l.is_empty() {
            None
        } else {
            Some(Summary::from(l.clone()))
        }
    }

    /// Queue-wait summary — the backpressure signal.
    pub fn queue_wait_summary(&self) -> Option<Summary> {
        let l = lock(&self.queue_waits);
        if l.is_empty() {
            None
        } else {
            Some(Summary::from(l.clone()))
        }
    }

    /// One-line report for logs.
    pub fn report(&self) -> String {
        let lat = self
            .latency_summary()
            .map(|s| {
                format!(
                    "latency p50={} p95={} max={}",
                    crate::util::fmt_duration(s.median()),
                    crate::util::fmt_duration(s.p95()),
                    crate::util::fmt_duration(s.max())
                )
            })
            .unwrap_or_else(|| "latency n/a".into());
        let qw = self
            .queue_wait_summary()
            .map(|s| {
                format!(
                    " queue_wait p50={} max={}",
                    crate::util::fmt_duration(s.median()),
                    crate::util::fmt_duration(s.max())
                )
            })
            .unwrap_or_default();
        let kernel = self
            .kernel_info()
            .map(|k| format!(" {k}"))
            .unwrap_or_default();
        format!(
            "submitted={} completed={} failed={} rejected={} \
             prep_hits={} prep_builds={} prep_evictions={} \
             path_segments={} sv_gather_rebuilds={} cg_iters_total={} \
             refine_iters_total={} f32_panel_bytes={} \
             cv_folds={} batched_cg_rhs_total={} batch_panel_rebuilds={} \
             responses_total={} responses_screened_out={} \
             responses_early_stopped={} segment_handoffs={} \
             segment_handoff_waits={} \
             worker_panics={} worker_respawns={} jobs_truncated={} \
             jobs_shed={} jobs_retried={} deadline_aborts={} \
             intra_solve_aborts={} prep_build_failures={} \
             checkpoints_published={} resumed_from_checkpoint={} \
             numerical_breakdowns={} members_evicted={} {lat}{qw}{kernel}",
            self.submitted(),
            self.completed(),
            self.failed(),
            self.rejected(),
            self.prep_hits(),
            self.prep_builds(),
            self.prep_evictions(),
            self.path_segments(),
            self.sv_gather_rebuilds(),
            self.cg_iters_total(),
            self.refine_iters_total(),
            self.f32_panel_bytes(),
            self.cv_folds(),
            self.batched_cg_rhs_total(),
            self.batch_panel_rebuilds(),
            self.responses_total(),
            self.responses_screened_out(),
            self.responses_early_stopped(),
            self.segment_handoffs(),
            self.segment_handoff_waits(),
            self.worker_panics(),
            self.worker_respawns(),
            self.jobs_truncated(),
            self.jobs_shed(),
            self.jobs_retried(),
            self.deadline_aborts(),
            self.intra_solve_aborts(),
            self.prep_build_failures(),
            self.checkpoints_published(),
            self.resumed_from_checkpoint(),
            self.numerical_breakdowns(),
            self.members_evicted()
        )
    }
}

#[cfg(test)]
#[allow(clippy::unwrap_used)]
mod tests {
    use super::*;

    #[test]
    fn robustness_counters() {
        let m = Metrics::new();
        m.on_worker_panic();
        m.on_worker_panic();
        m.on_worker_respawn();
        m.on_truncated();
        m.on_shed();
        m.on_shed();
        m.on_shed();
        m.on_job_retried();
        m.on_deadline_abort();
        m.on_prep_build_failure();
        assert_eq!(m.worker_panics(), 2);
        assert_eq!(m.worker_respawns(), 1);
        assert_eq!(m.jobs_truncated(), 1);
        assert_eq!(m.jobs_shed(), 3);
        assert_eq!(m.jobs_retried(), 1);
        assert_eq!(m.deadline_aborts(), 1);
        assert_eq!(m.prep_build_failures(), 1);
        let report = m.report();
        assert!(report.contains("worker_panics=2"), "{report}");
        assert!(report.contains("worker_respawns=1"), "{report}");
        assert!(report.contains("jobs_truncated=1"), "{report}");
        assert!(report.contains("jobs_shed=3"), "{report}");
        assert!(report.contains("jobs_retried=1"), "{report}");
        assert!(report.contains("deadline_aborts=1"), "{report}");
        assert!(report.contains("prep_build_failures=1"), "{report}");
    }

    #[test]
    fn checkpoint_and_guardrail_counters() {
        let m = Metrics::new();
        m.on_checkpoints_published(5);
        m.on_checkpoints_published(0); // no-op
        m.on_resumed_from_checkpoint();
        m.on_numerical_breakdown();
        m.on_numerical_breakdown();
        m.on_members_evicted(2);
        m.on_members_evicted(0); // no-op
        m.on_intra_solve_abort();
        m.on_segment_handoff_wait();
        assert_eq!(m.checkpoints_published(), 5);
        assert_eq!(m.resumed_from_checkpoint(), 1);
        assert_eq!(m.numerical_breakdowns(), 2);
        assert_eq!(m.members_evicted(), 2);
        assert_eq!(m.intra_solve_aborts(), 1);
        assert_eq!(m.segment_handoff_waits(), 1);
        let report = m.report();
        assert!(report.contains("checkpoints_published=5"), "{report}");
        assert!(report.contains("resumed_from_checkpoint=1"), "{report}");
        assert!(report.contains("numerical_breakdowns=2"), "{report}");
        assert!(report.contains("members_evicted=2"), "{report}");
        assert!(report.contains("intra_solve_aborts=1"), "{report}");
        assert!(report.contains("segment_handoff_waits=1"), "{report}");
    }

    #[test]
    fn counters_and_summary() {
        let m = Metrics::new();
        m.on_submit();
        m.on_submit();
        m.on_complete(0.010, 0.001);
        m.on_complete(0.020, 0.002);
        m.on_fail(0.003);
        assert_eq!(m.submitted(), 2);
        assert_eq!(m.completed(), 2);
        assert_eq!(m.failed(), 1);
        let s = m.latency_summary().unwrap();
        assert!((s.median() - 0.015).abs() < 1e-12);
        // queue waits include the failed job's wait
        let qw = m.queue_wait_summary().unwrap();
        assert_eq!(qw.n(), 3);
        assert!((qw.median() - 0.002).abs() < 1e-12);
        assert!(m.report().contains("completed=2"));
    }

    #[test]
    fn prep_cache_counters() {
        let m = Metrics::new();
        m.on_prep_build();
        m.on_prep_hit();
        m.on_prep_hit();
        m.on_prep_eviction();
        m.on_reject();
        assert_eq!(m.prep_builds(), 1);
        assert_eq!(m.prep_hits(), 2);
        assert_eq!(m.prep_evictions(), 1);
        assert_eq!(m.rejected(), 1);
        let report = m.report();
        assert!(report.contains("prep_hits=2"));
        assert!(report.contains("prep_builds=1"));
        assert!(report.contains("prep_evictions=1"));
    }

    #[test]
    fn path_engine_counters() {
        let m = Metrics::new();
        m.on_path_segment();
        m.on_path_segment();
        m.on_solve_stats(17, 2, 0);
        m.on_solve_stats(0, 0, 0); // no-ops must not underflow or count
        m.on_solve_stats(3, 1, 0);
        assert_eq!(m.path_segments(), 2);
        assert_eq!(m.cg_iters_total(), 20);
        assert_eq!(m.sv_gather_rebuilds(), 3);
        let report = m.report();
        assert!(report.contains("path_segments=2"));
        assert!(report.contains("cg_iters_total=20"));
        assert!(report.contains("sv_gather_rebuilds=3"));
    }

    #[test]
    fn mixed_precision_counters() {
        let m = Metrics::new();
        m.on_solve_stats(10, 0, 2);
        m.on_solve_stats(5, 1, 0); // f64 solve: refinement untouched
        m.on_solve_stats(8, 0, 3);
        m.on_f32_panel_bytes(4096);
        m.on_f32_panel_bytes(0); // f64 prep: no-op
        m.on_f32_panel_bytes(1024);
        assert_eq!(m.refine_iters_total(), 5);
        assert_eq!(m.f32_panel_bytes(), 5120);
        let report = m.report();
        assert!(report.contains("refine_iters_total=5"));
        assert!(report.contains("f32_panel_bytes=5120"));
    }

    #[test]
    fn cv_and_batch_counters() {
        let m = Metrics::new();
        m.on_cv_fold();
        m.on_cv_fold();
        m.on_cv_fold();
        m.on_batch_stats(8, 2);
        m.on_batch_stats(0, 0); // no-op
        m.on_batch_stats(4, 1);
        assert_eq!(m.cv_folds(), 3);
        assert_eq!(m.batched_cg_rhs_total(), 12);
        assert_eq!(m.batch_panel_rebuilds(), 3);
        let report = m.report();
        assert!(report.contains("cv_folds=3"));
        assert!(report.contains("batched_cg_rhs_total=12"));
        assert!(report.contains("batch_panel_rebuilds=3"));
    }

    #[test]
    fn multi_response_and_handoff_counters() {
        let m = Metrics::new();
        m.on_responses(8);
        m.on_responses_screened(2);
        m.on_responses_early_stopped(3);
        m.on_responses(0); // no-ops must not count
        m.on_responses_screened(0);
        m.on_responses_early_stopped(0);
        m.on_segment_handoff();
        m.on_segment_handoff();
        assert_eq!(m.responses_total(), 8);
        assert_eq!(m.responses_screened_out(), 2);
        assert_eq!(m.responses_early_stopped(), 3);
        assert_eq!(m.segment_handoffs(), 2);
        let report = m.report();
        assert!(report.contains("responses_total=8"));
        assert!(report.contains("responses_screened_out=2"));
        assert!(report.contains("responses_early_stopped=3"));
        assert!(report.contains("segment_handoffs=2"));
    }

    #[test]
    fn kernel_info_set_once_and_reported() {
        let m = Metrics::new();
        assert!(m.kernel_info().is_none());
        assert!(!m.report().contains("kernel="));
        m.set_kernel_info("kernel=fma(6x8) cache[l1d=48K l2=2048K l3=8192K (sysfs)]".into());
        m.set_kernel_info("kernel=scalar(4x8)".into()); // ignored: dispatch is fixed
        assert_eq!(
            m.kernel_info(),
            Some("kernel=fma(6x8) cache[l1d=48K l2=2048K l3=8192K (sysfs)]")
        );
        assert!(m.report().contains("kernel=fma(6x8)"));
    }

    #[test]
    fn empty_summaries_are_none() {
        let m = Metrics::new();
        assert!(m.latency_summary().is_none());
        assert!(m.queue_wait_summary().is_none());
    }
}
