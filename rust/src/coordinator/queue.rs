//! Bounded multi-producer/multi-consumer queue with blocking push
//! (backpressure) and pop, built on Mutex + Condvar — no external crates
//! in the offline set provide this.

use super::sync::{lock, wait};
use std::collections::VecDeque;
use std::sync::{Condvar, Mutex};

/// Bounded blocking queue. `close()` wakes all consumers; `pop` returns
/// `None` once closed and drained.
pub struct Queue<T> {
    inner: Mutex<Inner<T>>,
    not_empty: Condvar,
    not_full: Condvar,
    capacity: usize,
}

struct Inner<T> {
    items: VecDeque<T>,
    closed: bool,
}

impl<T> Queue<T> {
    pub fn bounded(capacity: usize) -> Self {
        assert!(capacity > 0);
        Queue {
            inner: Mutex::new(Inner { items: VecDeque::new(), closed: false }),
            not_empty: Condvar::new(),
            not_full: Condvar::new(),
            capacity,
        }
    }

    /// Blocking push; returns `Err(item)` if the queue is closed.
    pub fn push(&self, item: T) -> Result<(), T> {
        let mut g = lock(&self.inner);
        loop {
            if g.closed {
                return Err(item);
            }
            if g.items.len() < self.capacity {
                g.items.push_back(item);
                self.not_empty.notify_one();
                return Ok(());
            }
            g = wait(&self.not_full, g);
        }
    }

    /// Blocking pop; `None` when closed and empty.
    pub fn pop(&self) -> Option<T> {
        let mut g = lock(&self.inner);
        loop {
            if let Some(item) = g.items.pop_front() {
                self.not_full.notify_one();
                return Some(item);
            }
            if g.closed {
                return None;
            }
            g = wait(&self.not_empty, g);
        }
    }

    /// Close: producers fail, consumers drain then get `None`.
    pub fn close(&self) {
        let mut g = lock(&self.inner);
        g.closed = true;
        self.not_empty.notify_all();
        self.not_full.notify_all();
    }

    pub fn len(&self) -> usize {
        lock(&self.inner).items.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

#[cfg(test)]
#[allow(clippy::unwrap_used)]
mod tests {
    use super::*;
    use std::sync::Arc;

    #[test]
    fn fifo_order() {
        let q = Queue::bounded(4);
        q.push(1).unwrap();
        q.push(2).unwrap();
        assert_eq!(q.pop(), Some(1));
        assert_eq!(q.pop(), Some(2));
    }

    #[test]
    fn close_drains_then_none() {
        let q = Queue::bounded(4);
        q.push(7).unwrap();
        q.close();
        assert!(q.push(8).is_err());
        assert_eq!(q.pop(), Some(7));
        assert_eq!(q.pop(), None);
    }

    #[test]
    fn backpressure_blocks_until_pop() {
        let q = Arc::new(Queue::bounded(1));
        q.push(1).unwrap();
        let q2 = q.clone();
        let h = std::thread::spawn(move || {
            q2.push(2).unwrap(); // blocks until main pops
            "pushed"
        });
        std::thread::sleep(std::time::Duration::from_millis(20));
        assert_eq!(q.pop(), Some(1));
        assert_eq!(h.join().unwrap(), "pushed");
        assert_eq!(q.pop(), Some(2));
    }

    #[test]
    fn mpmc_all_items_delivered() {
        let q = Arc::new(Queue::bounded(8));
        let producers: Vec<_> = (0..4)
            .map(|t| {
                let q = q.clone();
                std::thread::spawn(move || {
                    for i in 0..50 {
                        q.push(t * 100 + i).unwrap();
                    }
                })
            })
            .collect();
        let consumers: Vec<_> = (0..3)
            .map(|_| {
                let q = q.clone();
                std::thread::spawn(move || {
                    let mut got = Vec::new();
                    while let Some(v) = q.pop() {
                        got.push(v);
                    }
                    got
                })
            })
            .collect();
        for p in producers {
            p.join().unwrap();
        }
        q.close();
        let mut all: Vec<i32> = consumers
            .into_iter()
            .flat_map(|c| c.join().unwrap())
            .collect();
        all.sort_unstable();
        assert_eq!(all.len(), 200);
        all.dedup();
        assert_eq!(all.len(), 200, "no duplicates");
    }
}
