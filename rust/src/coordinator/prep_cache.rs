//! Service-level preparation cache with single-flight deduplication and
//! LRU eviction.
//!
//! The paper's speed story is amortization: prepare a data set once
//! (gram blocks / staged device buffers), then sweep many (t, λ₂)
//! settings cheaply. Before this cache each of W pool workers rebuilt its
//! own preparation for the same data set — W× the O(n·p·min(n,p)) prep
//! cost per data set. Now preparations are immutable
//! (`Arc<dyn SvmPrep>`, see [`crate::solvers::sven::SvmPrep`]) and live
//! in one cache keyed by `(dataset_id, backend)`:
//!
//! - **Single-flight**: N workers racing on a cold key produce exactly
//!   one build; the N−1 losers block on a condvar and receive the
//!   winner's `Arc` (or its error).
//! - **Bounded**: at most `capacity` ready entries, evicting the least
//!   recently used (in-flight builds are never evicted).
//! - **Observable**: hits, builds and evictions land in
//!   [`Metrics`](super::metrics::Metrics).

use super::metrics::Metrics;
use super::sync::{lock, wait};
use crate::solvers::sven::SvmPrep;
use std::collections::HashMap;
use std::hash::Hash;
use std::sync::{Arc, Condvar, Mutex};

/// Result type of a build — errors are strings so they can be cloned to
/// every single-flight waiter.
type BuildResult = Result<Arc<dyn SvmPrep>, String>;

/// A build in progress: waiters park on the condvar until the builder
/// publishes the result.
struct Flight {
    done: Mutex<Option<BuildResult>>,
    cv: Condvar,
}

impl Flight {
    fn new() -> Self {
        Flight { done: Mutex::new(None), cv: Condvar::new() }
    }

    fn publish(&self, result: BuildResult) {
        *lock(&self.done) = Some(result);
        self.cv.notify_all();
    }

    fn wait(&self) -> BuildResult {
        let mut g = lock(&self.done);
        loop {
            if let Some(r) = g.as_ref() {
                return r.clone();
            }
            g = wait(&self.cv, g);
        }
    }
}

enum Entry {
    Ready { prep: Arc<dyn SvmPrep>, last_used: u64 },
    Building(Arc<Flight>),
}

/// RAII unwind guard around a build closure (see
/// [`PrepCache::abort_build`]). Disarmed on the normal path.
struct BuildGuard<'a, K: Eq + Hash + Clone> {
    cache: &'a PrepCache<K>,
    key: &'a K,
    flight: &'a Arc<Flight>,
    armed: bool,
}

impl<K: Eq + Hash + Clone> Drop for BuildGuard<'_, K> {
    fn drop(&mut self) {
        if self.armed {
            self.cache.abort_build(self.key, self.flight);
        }
    }
}

struct Inner<K> {
    entries: HashMap<K, Entry>,
    /// Monotone use counter backing the LRU order.
    tick: u64,
}

/// Shared preparation cache. `K` is the cache key — the service uses
/// `(dataset_id, BackendChoice)`.
pub struct PrepCache<K: Eq + Hash + Clone> {
    capacity: usize,
    metrics: Arc<Metrics>,
    inner: Mutex<Inner<K>>,
}

impl<K: Eq + Hash + Clone> PrepCache<K> {
    /// A cache holding at most `capacity` ready preparations (≥ 1).
    pub fn new(capacity: usize, metrics: Arc<Metrics>) -> Self {
        PrepCache {
            capacity: capacity.max(1),
            metrics,
            inner: Mutex::new(Inner { entries: HashMap::new(), tick: 0 }),
        }
    }

    /// Ready entries currently cached.
    pub fn len(&self) -> usize {
        let inner = lock(&self.inner);
        inner
            .entries
            .values()
            .filter(|e| matches!(e, Entry::Ready { .. }))
            .count()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Fetch the preparation for `key`, building it with `build` exactly
    /// once across all concurrent callers. A failed build is not cached:
    /// the error propagates to the builder and every waiter, and the next
    /// request retries.
    pub fn get_or_build(
        &self,
        key: K,
        build: impl FnOnce() -> BuildResult,
    ) -> BuildResult {
        let flight = {
            let mut inner = lock(&self.inner);
            inner.tick += 1;
            let now = inner.tick;
            match inner.entries.get_mut(&key) {
                Some(Entry::Ready { prep, last_used }) => {
                    *last_used = now;
                    self.metrics.on_prep_hit();
                    return Ok(prep.clone());
                }
                Some(Entry::Building(flight)) => flight.clone(),
                None => {
                    let flight = Arc::new(Flight::new());
                    inner.entries.insert(key.clone(), Entry::Building(flight.clone()));
                    drop(inner);
                    // We are the builder: run outside the lock so other
                    // keys stay serviceable during the O(n·p·min(n,p))
                    // build. The guard keeps a panicking build from
                    // wedging the key: on unwind it removes the Building
                    // entry and publishes an error so waiters unblock.
                    self.metrics.on_prep_build();
                    let mut guard =
                        BuildGuard { cache: self, key: &key, flight: &flight, armed: true };
                    let result = build();
                    guard.armed = false;
                    drop(guard);
                    let mut inner = lock(&self.inner);
                    match &result {
                        Ok(prep) => {
                            inner.tick += 1;
                            let now = inner.tick;
                            inner.entries.insert(
                                key,
                                Entry::Ready { prep: prep.clone(), last_used: now },
                            );
                            self.evict_over_capacity(&mut inner);
                        }
                        Err(_) => {
                            inner.entries.remove(&key);
                            self.metrics.on_prep_build_failure();
                        }
                    }
                    drop(inner);
                    flight.publish(result.clone());
                    return result;
                }
            }
        };
        // Single-flight waiter: someone else is building this key.
        let result = flight.wait();
        if result.is_ok() {
            self.metrics.on_prep_hit();
        }
        result
    }

    /// Unwind cleanup for a panicking build closure: drop the Building
    /// entry and publish an error so single-flight waiters unblock
    /// instead of parking forever (the panic itself keeps propagating).
    fn abort_build(&self, key: &K, flight: &Arc<Flight>) {
        let mut inner = lock(&self.inner);
        let ours =
            matches!(inner.entries.get(key), Some(Entry::Building(f)) if Arc::ptr_eq(f, flight));
        if ours {
            inner.entries.remove(key);
        }
        drop(inner);
        self.metrics.on_prep_build_failure();
        flight.publish(Err("preparation build panicked".to_string()));
    }

    /// Evict least-recently-used ready entries until within capacity.
    /// In-flight builds don't count and are never evicted.
    fn evict_over_capacity(&self, inner: &mut Inner<K>) {
        loop {
            let ready = inner
                .entries
                .iter()
                .filter_map(|(k, e)| match e {
                    Entry::Ready { last_used, .. } => Some((k.clone(), *last_used)),
                    Entry::Building(_) => None,
                })
                .collect::<Vec<_>>();
            if ready.len() <= self.capacity {
                return;
            }
            let (victim, _) = ready
                .into_iter()
                .min_by_key(|(_, last_used)| *last_used)
                .expect("non-empty over-capacity set");
            inner.entries.remove(&victim);
            self.metrics.on_prep_eviction();
        }
    }
}

#[cfg(test)]
#[allow(clippy::unwrap_used)]
mod tests {
    use super::*;
    use crate::linalg::{Design, Mat};
    use crate::solvers::sven::{RustBackend, SvmBackend, SvmMode};
    use std::sync::atomic::{AtomicUsize, Ordering};

    fn dummy_prep() -> Arc<dyn SvmPrep> {
        let x = Arc::new(Design::from(Mat::from_fn(6, 2, |r, c| (r + c) as f64)));
        let y = Arc::new(vec![1.0; 6]);
        RustBackend::default().prepare(&x, &y, SvmMode::Dual).unwrap()
    }

    #[test]
    fn builds_once_then_hits() {
        let metrics = Arc::new(Metrics::new());
        let cache = PrepCache::new(4, metrics.clone());
        let builds = AtomicUsize::new(0);
        for _ in 0..5 {
            cache
                .get_or_build(1u64, || {
                    builds.fetch_add(1, Ordering::Relaxed);
                    Ok(dummy_prep())
                })
                .unwrap();
        }
        assert_eq!(builds.load(Ordering::Relaxed), 1);
        assert_eq!(metrics.prep_builds(), 1);
        assert_eq!(metrics.prep_hits(), 4);
        assert_eq!(cache.len(), 1);
    }

    #[test]
    fn concurrent_cold_key_single_flight() {
        let metrics = Arc::new(Metrics::new());
        let cache = Arc::new(PrepCache::new(4, metrics.clone()));
        let builds = Arc::new(AtomicUsize::new(0));
        let handles: Vec<_> = (0..8)
            .map(|_| {
                let cache = cache.clone();
                let builds = builds.clone();
                std::thread::spawn(move || {
                    cache
                        .get_or_build(7u64, || {
                            builds.fetch_add(1, Ordering::Relaxed);
                            // widen the race window so waiters really park
                            std::thread::sleep(std::time::Duration::from_millis(20));
                            Ok(dummy_prep())
                        })
                        .unwrap()
                })
            })
            .collect();
        let preps: Vec<_> = handles.into_iter().map(|h| h.join().unwrap()).collect();
        assert_eq!(builds.load(Ordering::Relaxed), 1, "single-flight violated");
        assert_eq!(metrics.prep_builds(), 1);
        assert_eq!(metrics.prep_hits(), 7);
        for p in &preps[1..] {
            assert!(Arc::ptr_eq(p, &preps[0]), "all callers share one prep");
        }
    }

    #[test]
    fn lru_eviction_respects_capacity() {
        let metrics = Arc::new(Metrics::new());
        let cache = PrepCache::new(2, metrics.clone());
        for key in [1u64, 2, 3] {
            cache.get_or_build(key, || Ok(dummy_prep())).unwrap();
        }
        assert_eq!(cache.len(), 2);
        assert_eq!(metrics.prep_evictions(), 1);
        // key 1 was the LRU victim: re-requesting it rebuilds
        cache.get_or_build(1u64, || Ok(dummy_prep())).unwrap();
        assert_eq!(metrics.prep_builds(), 4);
        // key 3 was touched more recently than 2 after the re-insert? No:
        // order of use is now [2, 3, 1] → requesting 2 rebuilds (evicted).
        cache.get_or_build(3u64, || Ok(dummy_prep())).unwrap();
        assert_eq!(metrics.prep_builds(), 4, "3 must still be cached");
    }

    #[test]
    fn panicking_build_unwedges_waiters() {
        let metrics = Arc::new(Metrics::new());
        let cache = Arc::new(PrepCache::new(2, metrics.clone()));
        let c2 = cache.clone();
        let builder = std::thread::spawn(move || {
            let _ = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                c2.get_or_build(5u64, || {
                    std::thread::sleep(std::time::Duration::from_millis(30));
                    panic!("boom in build")
                })
            }));
        });
        std::thread::sleep(std::time::Duration::from_millis(5));
        let c3 = cache.clone();
        let waiter = std::thread::spawn(move || c3.get_or_build(5u64, || Ok(dummy_prep())));
        builder.join().unwrap();
        // The waiter either joined the doomed flight (and gets the panic
        // error) or arrived after cleanup (and builds fine) — it must
        // never deadlock.
        if let Err(e) = waiter.join().unwrap() {
            assert!(e.contains("panicked"), "unexpected error: {e}");
        }
        // The key is not wedged: a fresh request succeeds.
        cache.get_or_build(5u64, || Ok(dummy_prep())).unwrap();
        assert_eq!(cache.len(), 1);
    }

    #[test]
    fn failed_builds_propagate_and_are_not_cached() {
        let metrics = Arc::new(Metrics::new());
        let cache = PrepCache::new(2, metrics.clone());
        let err = cache.get_or_build(9u64, || Err("boom".to_string()));
        assert_eq!(err.unwrap_err(), "boom");
        assert_eq!(cache.len(), 0);
        assert_eq!(metrics.prep_build_failures(), 1);
        // next request retries the build
        cache.get_or_build(9u64, || Ok(dummy_prep())).unwrap();
        assert_eq!(metrics.prep_builds(), 2);
        assert_eq!(cache.len(), 1);
    }

    #[test]
    fn failing_build_wakes_every_waiter_with_the_error() {
        // Regression for the single-flight failure path: when the builder
        // fails, every parked waiter must receive the error (not hang, not
        // silently rebuild inside the same flight), the slot must be
        // evicted, and the failure must be counted exactly once.
        let metrics = Arc::new(Metrics::new());
        let cache = Arc::new(PrepCache::new(2, metrics.clone()));
        let c2 = cache.clone();
        let builder = std::thread::spawn(move || {
            c2.get_or_build(11u64, || {
                // widen the window so the waiters really park on the flight
                std::thread::sleep(std::time::Duration::from_millis(30));
                Err("injected build failure".to_string())
            })
        });
        std::thread::sleep(std::time::Duration::from_millis(5));
        let waiters: Vec<_> = (0..4)
            .map(|_| {
                let c = cache.clone();
                std::thread::spawn(move || c.get_or_build(11u64, || Ok(dummy_prep())))
            })
            .collect();
        assert_eq!(builder.join().unwrap().unwrap_err(), "injected build failure");
        for w in waiters {
            match w.join().unwrap() {
                // parked on the doomed flight: sees the builder's error
                Err(e) => assert!(e.contains("injected build failure"), "{e}"),
                // arrived after eviction: rebuilt cleanly
                Ok(_) => {}
            }
        }
        assert_eq!(metrics.prep_build_failures(), 1);
        // the slot is not wedged and a retry rebuilds
        cache.get_or_build(11u64, || Ok(dummy_prep())).unwrap();
        assert_eq!(cache.len(), 1);
    }
}
