//! Worker pool over the bounded queue. Workers own thread-local state
//! built by a factory (PJRT handles are not `Send`, so each worker builds
//! its own solver context on its own thread).
//!
//! Workers are *supervised*: a panic that escapes the job handler (the
//! service catches panics per job attempt, so this is the backstop for
//! panics in context construction or in the handler's bookkeeping)
//! unwinds only the worker's loop body — the thread rebuilds its context
//! and keeps draining the queue, and the respawn is reported through the
//! `on_respawn` callback instead of silently shrinking the pool.

use super::queue::Queue;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::Arc;
use std::thread::JoinHandle;

/// Pool configuration.
#[derive(Clone, Debug)]
pub struct PoolConfig {
    pub workers: usize,
    pub queue_capacity: usize,
}

impl Default for PoolConfig {
    fn default() -> Self {
        PoolConfig {
            workers: std::thread::available_parallelism().map(|v| v.get()).unwrap_or(4).min(8),
            queue_capacity: 64,
        }
    }
}

/// A generic worker pool processing jobs of type `J`.
pub struct Pool<J: Send + 'static> {
    queue: Arc<Queue<J>>,
    handles: Vec<JoinHandle<()>>,
}

impl<J: Send + 'static> Pool<J> {
    /// Spawn `config.workers` threads. For each worker, `ctx_factory(id)`
    /// builds thread-local context (runs on the worker thread), and
    /// `handler(ctx, job)` processes jobs until the queue closes.
    pub fn spawn<C, F, H>(config: &PoolConfig, ctx_factory: F, handler: H) -> Self
    where
        F: Fn(usize) -> C + Send + Sync + 'static,
        H: Fn(&mut C, J) + Send + Sync + 'static,
        C: 'static,
    {
        Self::spawn_supervised(config, ctx_factory, handler, |_wid| {})
    }

    /// [`Pool::spawn`] with an explicit respawn observer: whenever a
    /// panic escapes the handler, the worker thread rebuilds its context
    /// and resumes popping (the in-flight job is lost to the unwind —
    /// callers wanting per-job isolation catch panics inside `handler`),
    /// and `on_respawn(worker_id)` fires once per recovery.
    pub fn spawn_supervised<C, F, H, R>(
        config: &PoolConfig,
        ctx_factory: F,
        handler: H,
        on_respawn: R,
    ) -> Self
    where
        F: Fn(usize) -> C + Send + Sync + 'static,
        H: Fn(&mut C, J) + Send + Sync + 'static,
        R: Fn(usize) + Send + Sync + 'static,
        C: 'static,
    {
        let queue = Arc::new(Queue::bounded(config.queue_capacity));
        let ctx_factory = Arc::new(ctx_factory);
        let handler = Arc::new(handler);
        let on_respawn = Arc::new(on_respawn);
        let handles = (0..config.workers.max(1))
            .map(|wid| {
                let queue = queue.clone();
                let ctx_factory = ctx_factory.clone();
                let handler = handler.clone();
                let on_respawn = on_respawn.clone();
                std::thread::Builder::new()
                    .name(format!("sven-worker-{wid}"))
                    .spawn(move || loop {
                        let run = catch_unwind(AssertUnwindSafe(|| {
                            let mut ctx = ctx_factory(wid);
                            while let Some(job) = queue.pop() {
                                handler(&mut ctx, job);
                            }
                        }));
                        match run {
                            Ok(()) => break, // queue closed and drained
                            Err(_) => {
                                on_respawn(wid);
                                // Pause briefly so a persistently-failing
                                // context factory cannot hot-spin the CPU.
                                std::thread::sleep(std::time::Duration::from_millis(
                                    10,
                                ));
                            }
                        }
                    })
                    .expect("spawn worker")
            })
            .collect();
        Pool { queue, handles }
    }

    /// Submit a job (blocks under backpressure). Err if pool is shut down.
    pub fn submit(&self, job: J) -> Result<(), J> {
        self.queue.push(job)
    }

    /// Jobs waiting in the queue.
    pub fn backlog(&self) -> usize {
        self.queue.len()
    }

    /// A shared handle on the pool's queue, for observers that need the
    /// live backlog from inside worker context (the segment hand-off
    /// wait gate: a worker only parks for a predecessor when other
    /// queued work could use the CPU a speculative re-solve would burn).
    pub(crate) fn queue_handle(&self) -> Arc<Queue<J>> {
        self.queue.clone()
    }

    /// Stop accepting new jobs (submissions return `Err`); workers keep
    /// draining what is already queued.
    pub fn close(&self) {
        self.queue.close();
    }

    /// Close the queue and join all workers (drains remaining jobs).
    pub fn shutdown(self) {
        self.queue.close();
        for h in self.handles {
            let _ = h.join();
        }
    }
}

#[cfg(test)]
#[allow(clippy::unwrap_used)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};
    use std::sync::Arc;

    #[test]
    fn panicking_handler_respawns_worker_and_later_jobs_run() {
        let done = Arc::new(AtomicUsize::new(0));
        let done2 = done.clone();
        let respawns = Arc::new(AtomicUsize::new(0));
        let respawns2 = respawns.clone();
        let pool = Pool::spawn_supervised(
            &PoolConfig { workers: 1, queue_capacity: 16 },
            |_wid| (),
            move |_, job: usize| {
                if job == 3 {
                    panic!("injected handler panic");
                }
                done2.fetch_add(1, Ordering::Relaxed);
            },
            move |_wid| {
                respawns2.fetch_add(1, Ordering::Relaxed);
            },
        );
        for i in 0..10 {
            pool.submit(i).unwrap();
        }
        pool.shutdown();
        // Job 3 is lost to the unwind; every other job still ran on the
        // single (respawned) worker.
        assert_eq!(done.load(Ordering::Relaxed), 9);
        assert_eq!(respawns.load(Ordering::Relaxed), 1);
    }

    #[test]
    fn processes_all_jobs() {
        let done = Arc::new(AtomicUsize::new(0));
        let done2 = done.clone();
        let pool = Pool::spawn(
            &PoolConfig { workers: 3, queue_capacity: 4 },
            |_wid| (),
            move |_, job: usize| {
                // trivial work
                std::hint::black_box(job * 2);
                done2.fetch_add(1, Ordering::Relaxed);
            },
        );
        for i in 0..100 {
            pool.submit(i).unwrap();
        }
        pool.shutdown();
        assert_eq!(done.load(Ordering::Relaxed), 100);
    }

    #[test]
    fn thread_local_context_built_per_worker() {
        let built = Arc::new(AtomicUsize::new(0));
        let built2 = built.clone();
        let pool = Pool::spawn(
            &PoolConfig { workers: 4, queue_capacity: 4 },
            move |_wid| {
                built2.fetch_add(1, Ordering::Relaxed);
                Vec::<usize>::new()
            },
            |ctx, job: usize| ctx.push(job),
        );
        for i in 0..8 {
            pool.submit(i).unwrap();
        }
        pool.shutdown();
        assert_eq!(built.load(Ordering::Relaxed), 4);
    }

    #[test]
    fn shutdown_drains_backlog() {
        let done = Arc::new(AtomicUsize::new(0));
        let done2 = done.clone();
        let pool = Pool::spawn(
            &PoolConfig { workers: 1, queue_capacity: 64 },
            |_| (),
            move |_, _job: usize| {
                std::thread::sleep(std::time::Duration::from_millis(1));
                done2.fetch_add(1, Ordering::Relaxed);
            },
        );
        for i in 0..20 {
            pool.submit(i).unwrap();
        }
        pool.shutdown(); // must process everything already queued
        assert_eq!(done.load(Ordering::Relaxed), 20);
    }
}
