//! Worker pool over the bounded queue. Workers own thread-local state
//! built by a factory (PJRT handles are not `Send`, so each worker builds
//! its own solver context on its own thread).

use super::queue::Queue;
use std::sync::Arc;
use std::thread::JoinHandle;

/// Pool configuration.
#[derive(Clone, Debug)]
pub struct PoolConfig {
    pub workers: usize,
    pub queue_capacity: usize,
}

impl Default for PoolConfig {
    fn default() -> Self {
        PoolConfig {
            workers: std::thread::available_parallelism().map(|v| v.get()).unwrap_or(4).min(8),
            queue_capacity: 64,
        }
    }
}

/// A generic worker pool processing jobs of type `J`.
pub struct Pool<J: Send + 'static> {
    queue: Arc<Queue<J>>,
    handles: Vec<JoinHandle<()>>,
}

impl<J: Send + 'static> Pool<J> {
    /// Spawn `config.workers` threads. For each worker, `ctx_factory(id)`
    /// builds thread-local context (runs on the worker thread), and
    /// `handler(ctx, job)` processes jobs until the queue closes.
    pub fn spawn<C, F, H>(config: &PoolConfig, ctx_factory: F, handler: H) -> Self
    where
        F: Fn(usize) -> C + Send + Sync + 'static,
        H: Fn(&mut C, J) + Send + Sync + 'static,
        C: 'static,
    {
        let queue = Arc::new(Queue::bounded(config.queue_capacity));
        let ctx_factory = Arc::new(ctx_factory);
        let handler = Arc::new(handler);
        let handles = (0..config.workers.max(1))
            .map(|wid| {
                let queue = queue.clone();
                let ctx_factory = ctx_factory.clone();
                let handler = handler.clone();
                std::thread::Builder::new()
                    .name(format!("sven-worker-{wid}"))
                    .spawn(move || {
                        let mut ctx = ctx_factory(wid);
                        while let Some(job) = queue.pop() {
                            handler(&mut ctx, job);
                        }
                    })
                    .expect("spawn worker")
            })
            .collect();
        Pool { queue, handles }
    }

    /// Submit a job (blocks under backpressure). Err if pool is shut down.
    pub fn submit(&self, job: J) -> Result<(), J> {
        self.queue.push(job)
    }

    /// Jobs waiting in the queue.
    pub fn backlog(&self) -> usize {
        self.queue.len()
    }

    /// Stop accepting new jobs (submissions return `Err`); workers keep
    /// draining what is already queued.
    pub fn close(&self) {
        self.queue.close();
    }

    /// Close the queue and join all workers (drains remaining jobs).
    pub fn shutdown(self) {
        self.queue.close();
        for h in self.handles {
            let _ = h.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};
    use std::sync::Arc;

    #[test]
    fn processes_all_jobs() {
        let done = Arc::new(AtomicUsize::new(0));
        let done2 = done.clone();
        let pool = Pool::spawn(
            &PoolConfig { workers: 3, queue_capacity: 4 },
            |_wid| (),
            move |_, job: usize| {
                // trivial work
                std::hint::black_box(job * 2);
                done2.fetch_add(1, Ordering::Relaxed);
            },
        );
        for i in 0..100 {
            pool.submit(i).unwrap();
        }
        pool.shutdown();
        assert_eq!(done.load(Ordering::Relaxed), 100);
    }

    #[test]
    fn thread_local_context_built_per_worker() {
        let built = Arc::new(AtomicUsize::new(0));
        let built2 = built.clone();
        let pool = Pool::spawn(
            &PoolConfig { workers: 4, queue_capacity: 4 },
            move |_wid| {
                built2.fetch_add(1, Ordering::Relaxed);
                Vec::<usize>::new()
            },
            |ctx, job: usize| ctx.push(job),
        );
        for i in 0..8 {
            pool.submit(i).unwrap();
        }
        pool.shutdown();
        assert_eq!(built.load(Ordering::Relaxed), 4);
    }

    #[test]
    fn shutdown_drains_backlog() {
        let done = Arc::new(AtomicUsize::new(0));
        let done2 = done.clone();
        let pool = Pool::spawn(
            &PoolConfig { workers: 1, queue_capacity: 64 },
            |_| (),
            move |_, _job: usize| {
                std::thread::sleep(std::time::Duration::from_millis(1));
                done2.fetch_add(1, Ordering::Relaxed);
            },
        );
        for i in 0..20 {
            pool.submit(i).unwrap();
        }
        pool.shutdown(); // must process everything already queued
        assert_eq!(done.load(Ordering::Relaxed), 20);
    }
}
