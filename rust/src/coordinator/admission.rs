//! The service front door: structured job errors, per-submission
//! options (deadline + retry policy), and cost-based admission control.
//!
//! Admission is budgeted in *grid-point solves*, not job counts: a
//! 200-point CV path over 10 folds is 2000 solves, and a queue-depth
//! bound that counted it as "one job" would admit unbounded work. Each
//! job's cost ([`job_cost`](super::Service)) is charged against
//! [`ServiceConfig::max_queue_depth`](super::ServiceConfig::max_queue_depth)
//! at submission and released when the job's shared state drops —
//! over-budget submissions shed immediately with
//! [`JobError::Overloaded`] instead of queueing work the service cannot
//! finish in time.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Arc;
use std::time::Duration;

/// Why a job failed — the structured error carried by
/// [`SolveOutcome::result`](super::SolveOutcome) and returned
/// synchronously by `submit*` for shed/closed submissions.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum JobError {
    /// The job's parameters are malformed (dimension mismatch, bad grid
    /// point, backend restriction). Never retried.
    Invalid(String),
    /// The service is closed or shut down; nothing was queued.
    Closed,
    /// Admission control shed the submission: charging `cost` on top of
    /// the `depth` solve-units already in flight would exceed
    /// `max_depth`. Nothing was queued and no worker was touched.
    Overloaded { depth: usize, max_depth: usize, cost: usize },
    /// A worker panicked while executing the job. The worker survives
    /// (the panic is caught per attempt) and the fault is transient:
    /// a [`RetryPolicy`] with spare attempts re-runs the work.
    WorkerPanic(String),
    /// The shared preparation build failed. The failed cache slot is
    /// evicted, so a retry rebuilds cleanly — transient.
    PrepFailed(String),
    /// The solver itself reported an error (including an unavailable
    /// XLA backend). Deterministic, so not retried.
    Solver(String),
    /// A non-finite value (NaN/±∞ margin, residual or objective) was
    /// caught inside a solve by the numerical-health guardrails, after
    /// the degradation ladder (f64 re-solve, masked fallback) was
    /// exhausted. `stage` names the guard that tripped
    /// (`"primal-newton"`, `"dual-newton"`, `"cg"`). Deterministic in
    /// the inputs, so never retried — a retry would break identically.
    NumericalBreakdown { stage: String, detail: String },
    /// The job's deadline passed before any grid point was solved (a
    /// deadline that lands mid-sweep yields a
    /// [`JobResult::Truncated`](super::JobResult::Truncated) success
    /// instead).
    DeadlineExceeded,
    /// A coordinator invariant broke — a bug, not a caller error.
    Internal(String),
}

impl JobError {
    /// Transient failures are worth retrying: the fault was in the
    /// execution (a caught panic, a failed-and-evicted prep build), not
    /// in the job itself.
    pub fn is_transient(&self) -> bool {
        matches!(self, JobError::WorkerPanic(_) | JobError::PrepFailed(_))
    }

    /// Classify a solver-reported error string: messages carrying the
    /// guardrail tag (emitted by the sweep/backend layer as
    /// `"numerical breakdown at <stage>: <detail>"`) become the
    /// structured [`JobError::NumericalBreakdown`]; everything else
    /// stays an opaque [`JobError::Solver`].
    pub(crate) fn from_solver(msg: String) -> JobError {
        const TAG: &str = "numerical breakdown at ";
        if let Some(rest) = msg.strip_prefix(TAG) {
            if let Some((stage, detail)) = rest.split_once(": ") {
                return JobError::NumericalBreakdown {
                    stage: stage.to_string(),
                    detail: detail.to_string(),
                };
            }
        }
        JobError::Solver(msg)
    }
}

impl std::fmt::Display for JobError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            JobError::Invalid(msg) => f.write_str(msg),
            JobError::Closed => f.write_str("service is closed; job rejected"),
            JobError::Overloaded { depth, max_depth, cost } => write!(
                f,
                "service overloaded: {depth} solve-units in flight + {cost} requested \
                 exceeds max_queue_depth {max_depth}; job shed"
            ),
            JobError::WorkerPanic(msg) => write!(f, "worker panicked: {msg}"),
            JobError::PrepFailed(msg) => write!(f, "preparation failed: {msg}"),
            JobError::Solver(msg) => f.write_str(msg),
            JobError::NumericalBreakdown { stage, detail } => {
                write!(f, "numerical breakdown at {stage}: {detail}")
            }
            JobError::DeadlineExceeded => {
                f.write_str("deadline exceeded before any grid point was solved")
            }
            JobError::Internal(msg) => f.write_str(msg),
        }
    }
}

impl std::error::Error for JobError {}

impl From<JobError> for String {
    fn from(e: JobError) -> Self {
        e.to_string()
    }
}

/// Capped exponential backoff for transient failures: attempt `k`
/// (1-based) sleeps `min(base_backoff · 2^(k−1), max_backoff)` before
/// re-running. `max_attempts: 1` (the default) means no retries.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct RetryPolicy {
    /// Total attempts including the first (values of 0 are treated
    /// as 1).
    pub max_attempts: u32,
    /// Backoff before the first retry.
    pub base_backoff: Duration,
    /// Backoff cap.
    pub max_backoff: Duration,
}

impl Default for RetryPolicy {
    fn default() -> Self {
        RetryPolicy {
            max_attempts: 1,
            base_backoff: Duration::from_millis(5),
            max_backoff: Duration::from_millis(200),
        }
    }
}

impl RetryPolicy {
    /// Retry `attempts` times after the first failure.
    pub fn retries(attempts: u32) -> Self {
        RetryPolicy { max_attempts: attempts.saturating_add(1), ..Default::default() }
    }

    /// Backoff to sleep after failed attempt `attempt` (1-based).
    pub fn backoff_for(&self, attempt: u32) -> Duration {
        let shift = attempt.saturating_sub(1).min(16);
        let scaled = self.base_backoff.saturating_mul(1u32 << shift);
        scaled.min(self.max_backoff)
    }
}

/// Per-submission options accepted by every `submit*_with` method.
#[derive(Clone, Copy, Debug, Default)]
pub struct SubmitOptions {
    /// Wall-clock budget from submission. Segments check it at
    /// grid-point boundaries: a deadline that lands mid-sweep returns
    /// the bit-identical solved prefix as
    /// [`JobResult::Truncated`](super::JobResult::Truncated); one that
    /// lands before any point is solved fails with
    /// [`JobError::DeadlineExceeded`].
    pub deadline: Option<Duration>,
    /// Retry policy for transient failures (worker panics, failed prep
    /// builds).
    pub retry: RetryPolicy,
}

impl SubmitOptions {
    /// Options with a deadline and no retries.
    pub fn with_deadline(deadline: Duration) -> Self {
        SubmitOptions { deadline: Some(deadline), ..Default::default() }
    }
}

/// In-flight solve-unit accounting behind `max_queue_depth`.
pub(crate) struct Admission {
    inflight: AtomicUsize,
    max: usize,
}

impl Admission {
    pub(crate) fn new(max: usize) -> Self {
        Admission { inflight: AtomicUsize::new(0), max }
    }

    /// Solve-units currently charged.
    pub(crate) fn depth(&self) -> usize {
        self.inflight.load(Ordering::Relaxed)
    }

    /// The configured budget.
    pub(crate) fn max_depth(&self) -> usize {
        self.max
    }

    /// Try to charge `cost` units; on success the returned ticket
    /// releases them when dropped. `Err(depth)` when the budget would
    /// be exceeded (a cost larger than the whole budget can never be
    /// admitted — size `max_queue_depth` to the largest job you intend
    /// to serve).
    pub(crate) fn try_admit(
        self: &Arc<Self>,
        cost: usize,
    ) -> Result<CostTicket, usize> {
        let mut cur = self.inflight.load(Ordering::Relaxed);
        loop {
            if cur.saturating_add(cost) > self.max {
                return Err(cur);
            }
            match self.inflight.compare_exchange_weak(
                cur,
                cur + cost,
                Ordering::AcqRel,
                Ordering::Relaxed,
            ) {
                Ok(_) => {
                    return Ok(CostTicket { admission: self.clone(), cost });
                }
                Err(actual) => cur = actual,
            }
        }
    }
}

/// RAII charge against the admission budget; releasing is tied to the
/// drop of the job's shared state, so the budget frees exactly when the
/// job's last work item is done with it — even when a worker panicked.
pub(crate) struct CostTicket {
    admission: Arc<Admission>,
    cost: usize,
}

impl Drop for CostTicket {
    fn drop(&mut self) {
        self.admission.inflight.fetch_sub(self.cost, Ordering::AcqRel);
    }
}

#[cfg(test)]
#[allow(clippy::unwrap_used)]
mod tests {
    use super::*;

    #[test]
    fn admission_charges_and_releases() {
        let a = Arc::new(Admission::new(10));
        let t1 = a.try_admit(6).unwrap();
        assert_eq!(a.depth(), 6);
        let err = a.try_admit(5).unwrap_err();
        assert_eq!(err, 6);
        let t2 = a.try_admit(4).unwrap();
        assert_eq!(a.depth(), 10);
        drop(t1);
        assert_eq!(a.depth(), 4);
        drop(t2);
        assert_eq!(a.depth(), 0);
    }

    #[test]
    fn oversized_cost_is_never_admissible() {
        let a = Arc::new(Admission::new(4));
        assert_eq!(a.try_admit(5).unwrap_err(), 0);
        assert_eq!(a.depth(), 0, "a failed admit must charge nothing");
    }

    #[test]
    fn backoff_is_capped_exponential() {
        let r = RetryPolicy {
            max_attempts: 5,
            base_backoff: Duration::from_millis(5),
            max_backoff: Duration::from_millis(18),
        };
        assert_eq!(r.backoff_for(1), Duration::from_millis(5));
        assert_eq!(r.backoff_for(2), Duration::from_millis(10));
        assert_eq!(r.backoff_for(3), Duration::from_millis(18));
        assert_eq!(r.backoff_for(30), Duration::from_millis(18));
    }

    #[test]
    fn breakdown_classification_and_display() {
        let e = JobError::from_solver(
            "numerical breakdown at primal-newton: non-finite objective at member 3".into(),
        );
        assert_eq!(
            e,
            JobError::NumericalBreakdown {
                stage: "primal-newton".into(),
                detail: "non-finite objective at member 3".into(),
            }
        );
        assert!(!e.is_transient(), "breakdowns are deterministic; never retried");
        let s = e.to_string();
        assert!(s.contains("primal-newton") && s.contains("non-finite"), "{s}");
        // untagged messages stay opaque solver errors
        assert_eq!(
            JobError::from_solver("cholesky failed".into()),
            JobError::Solver("cholesky failed".into())
        );
    }

    #[test]
    fn transient_classification() {
        assert!(JobError::WorkerPanic("x".into()).is_transient());
        assert!(JobError::PrepFailed("x".into()).is_transient());
        assert!(!JobError::Invalid("x".into()).is_transient());
        assert!(!JobError::Closed.is_transient());
        assert!(!JobError::DeadlineExceeded.is_transient());
        assert!(
            !JobError::Overloaded { depth: 1, max_depth: 2, cost: 3 }.is_transient()
        );
    }

    #[test]
    fn display_is_informative() {
        let e = JobError::Overloaded { depth: 7, max_depth: 8, cost: 4 };
        let s = e.to_string();
        assert!(s.contains('7') && s.contains('8') && s.contains('4'), "{s}");
        assert!(JobError::Closed.to_string().contains("closed"));
        assert!(JobError::DeadlineExceeded.to_string().contains("deadline"));
    }
}
