fn main() -> anyhow::Result<()> {
    sven::cli::run()
}
