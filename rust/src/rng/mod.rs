//! Deterministic pseudo-random numbers (no `rand` crate offline).
//!
//! `Rng` is a PCG64-style generator (splitmix64-seeded xoshiro256++ core)
//! with the handful of distributions the data generators and property
//! tests need: uniform, normal (Box–Muller with caching), Bernoulli, and
//! Fisher–Yates shuffling. Every experiment in this repo is seeded, so
//! benches and tests are exactly reproducible.

/// Splitmix64 — used for seeding and stream derivation.
#[inline]
fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

/// xoshiro256++ PRNG with distribution helpers.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
    cached_normal: Option<f64>,
}

impl Rng {
    /// Seed deterministically from a single u64.
    pub fn seed_from(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s, cached_normal: None }
    }

    /// Derive an independent stream (e.g. one per worker thread).
    pub fn substream(&self, idx: u64) -> Rng {
        let mut sm = self.s[0] ^ self.s[3] ^ idx.wrapping_mul(0xA24BAED4963EE407);
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s, cached_normal: None }
    }

    /// Next raw 64 random bits.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = (self.s[0].wrapping_add(self.s[3]))
            .rotate_left(23)
            .wrapping_add(self.s[0]);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform in [0, 1).
    #[inline]
    pub fn uniform(&mut self) -> f64 {
        // 53 high bits → double in [0,1).
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in [lo, hi).
    #[inline]
    pub fn uniform_in(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.uniform()
    }

    /// Uniform integer in [0, n).
    #[inline]
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        // Multiplicative range reduction (Lemire) — negligible bias for
        // the data-generation sizes used here.
        ((self.next_u64() as u128 * n as u128) >> 64) as usize
    }

    /// Standard normal via Box–Muller, caching the second deviate.
    pub fn normal(&mut self) -> f64 {
        if let Some(v) = self.cached_normal.take() {
            return v;
        }
        loop {
            let u1 = self.uniform();
            if u1 <= f64::MIN_POSITIVE {
                continue;
            }
            let u2 = self.uniform();
            let r = (-2.0 * u1.ln()).sqrt();
            let theta = std::f64::consts::TAU * u2;
            self.cached_normal = Some(r * theta.sin());
            return r * theta.cos();
        }
    }

    /// Normal with mean/std.
    #[inline]
    pub fn normal_ms(&mut self, mean: f64, std: f64) -> f64 {
        mean + std * self.normal()
    }

    /// Bernoulli(p).
    #[inline]
    pub fn bernoulli(&mut self, p: f64) -> bool {
        self.uniform() < p
    }

    /// In-place Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }

    /// Sample `k` distinct indices from [0, n) (partial Fisher–Yates).
    pub fn sample_indices(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n);
        let mut idx: Vec<usize> = (0..n).collect();
        for i in 0..k {
            let j = i + self.below(n - i);
            idx.swap(i, j);
        }
        idx.truncate(k);
        idx
    }

    /// Vector of standard normals.
    pub fn normal_vec(&mut self, n: usize) -> Vec<f64> {
        (0..n).map(|_| self.normal()).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_per_seed() {
        let mut a = Rng::seed_from(42);
        let mut b = Rng::seed_from(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
        let mut c = Rng::seed_from(43);
        assert_ne!(a.next_u64(), c.next_u64());
    }

    #[test]
    fn uniform_in_unit_interval() {
        let mut rng = Rng::seed_from(1);
        for _ in 0..10_000 {
            let u = rng.uniform();
            assert!((0.0..1.0).contains(&u));
        }
    }

    #[test]
    fn normal_moments() {
        let mut rng = Rng::seed_from(2);
        let n = 200_000;
        let xs: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.01, "mean {mean}");
        assert!((var - 1.0).abs() < 0.02, "var {var}");
    }

    #[test]
    fn below_in_range_and_covers() {
        let mut rng = Rng::seed_from(3);
        let mut seen = [false; 7];
        for _ in 0..1000 {
            seen[rng.below(7)] = true;
        }
        assert!(seen.iter().all(|&b| b));
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut rng = Rng::seed_from(4);
        let mut xs: Vec<usize> = (0..50).collect();
        rng.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
        assert_ne!(xs, (0..50).collect::<Vec<_>>()); // overwhelmingly likely
    }

    #[test]
    fn sample_indices_distinct() {
        let mut rng = Rng::seed_from(5);
        let idx = rng.sample_indices(100, 20);
        assert_eq!(idx.len(), 20);
        let mut sorted = idx.clone();
        sorted.sort_unstable();
        sorted.dedup();
        assert_eq!(sorted.len(), 20);
    }

    #[test]
    fn substream_differs_from_parent() {
        let parent = Rng::seed_from(6);
        let mut s0 = parent.substream(0);
        let mut s1 = parent.substream(1);
        assert_ne!(s0.next_u64(), s1.next_u64());
    }
}
