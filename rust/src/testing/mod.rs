//! Minimal in-tree property-based testing framework.
//!
//! The offline crate set has no `proptest`/`quickcheck`, so this module
//! supplies the subset the test suite needs: seeded generators built on
//! [`crate::rng::Rng`], a `forall` runner that reports the failing seed,
//! and greedy size-shrinking for the structured problem generators.
//!
//! Usage pattern (see `rust/tests/proptests.rs`):
//!
//! ```ignore
//! forall("sven matches glmnet", 50, gen_problem, |p| check(p));
//! ```

pub mod prop;

pub use prop::{forall, forall_cfg, Gen, PropConfig};
