//! The `forall` property runner and generator combinators, plus shared
//! operator test doubles (the ridge-Hessian [`RidgeOp`]/[`RidgeFamily`]
//! pair used by both the blocked-CG proptests and the `cv_micro`
//! bench — one definition, so the bench asserts exactly the operator
//! the proptests pin).

use crate::linalg::{LinOp, Mat, MultiLinOp, MultiVec};
use crate::rng::Rng;
use std::cell::RefCell;

/// Solo ridge-Hessian test double `v ↦ shift·v + Xᵀ(X·v)` built on the
/// *single-RHS* kernels — the independent reference operator for
/// blocked-CG bit-identity checks.
pub struct RidgeOp<'a> {
    pub x: &'a Mat,
    pub shift: f64,
    buf: RefCell<Vec<f64>>,
}

impl<'a> RidgeOp<'a> {
    pub fn new(x: &'a Mat, shift: f64) -> Self {
        RidgeOp { x, shift, buf: RefCell::new(Vec::new()) }
    }
}

impl LinOp for RidgeOp<'_> {
    fn dim(&self) -> usize {
        self.x.cols()
    }

    fn apply(&self, v: &[f64], out: &mut [f64]) {
        let mut b = self.buf.borrow_mut();
        b.resize(self.x.rows(), 0.0);
        self.x.matvec_into(v, &mut b);
        self.x.matvec_t_into(&b, out);
        for i in 0..out.len() {
            out[i] = self.shift * v[i] + out[i];
        }
    }
}

/// The matching [`MultiLinOp`] family: one shared X, per-problem ridge
/// shifts, fused panel products. Column `s` is bit-identical to
/// `RidgeOp::new(x, shifts[cols[s]])` by the multi-RHS kernel contract.
pub struct RidgeFamily<'a> {
    pub x: &'a Mat,
    pub shifts: Vec<f64>,
    buf: RefCell<MultiVec>,
}

impl<'a> RidgeFamily<'a> {
    pub fn new(x: &'a Mat, shifts: Vec<f64>) -> Self {
        RidgeFamily { x, shifts, buf: RefCell::new(MultiVec::zeros(0, 0)) }
    }
}

impl MultiLinOp for RidgeFamily<'_> {
    fn dim(&self) -> usize {
        self.x.cols()
    }

    fn nprobs(&self) -> usize {
        self.shifts.len()
    }

    fn apply_multi(&self, cols: &[usize], vs: &MultiVec, out: &mut MultiVec) {
        let mut b = self.buf.borrow_mut();
        b.resize(self.x.rows(), vs.ncols());
        self.x.matvec_multi_into(vs, &mut b);
        self.x.matvec_t_multi_into(&b, out);
        for (s, &j) in cols.iter().enumerate() {
            let sh = self.shifts[j];
            let v = vs.col(s);
            let o = out.col_mut(s);
            for i in 0..o.len() {
                o[i] = sh * v[i] + o[i];
            }
        }
    }
}

/// A generator draws a case from seeded randomness at a given `size`
/// (sizes ramp up across cases, like proptest's sizing).
pub trait Gen {
    type Output;
    fn generate(&self, rng: &mut Rng, size: usize) -> Self::Output;
}

impl<T, F: Fn(&mut Rng, usize) -> T> Gen for F {
    type Output = T;
    fn generate(&self, rng: &mut Rng, size: usize) -> T {
        self(rng, size)
    }
}

/// Configuration for a property run.
#[derive(Clone, Debug)]
pub struct PropConfig {
    pub cases: usize,
    pub seed: u64,
    pub min_size: usize,
    pub max_size: usize,
}

impl Default for PropConfig {
    fn default() -> Self {
        PropConfig { cases: 64, seed: 0xC0FFEE, min_size: 1, max_size: 24 }
    }
}

/// Run `prop` on `cases` generated inputs; panics with the failing seed and
/// case index on the first failure (after a shrink attempt over sizes).
///
/// `prop` returns `Result<(), String>` so failures carry a description.
pub fn forall_cfg<G: Gen>(
    name: &str,
    cfg: &PropConfig,
    gen: G,
    prop: impl Fn(&G::Output) -> Result<(), String>,
) {
    let mut failures: Option<(usize, usize, String)> = None;
    'outer: for case in 0..cfg.cases {
        // size ramps from min to max over the run
        let size = cfg.min_size
            + (cfg.max_size - cfg.min_size) * case / cfg.cases.max(1);
        let mut rng = Rng::seed_from(cfg.seed ^ (case as u64).wrapping_mul(0x9E3779B97F4A7C15));
        let input = gen.generate(&mut rng, size.max(cfg.min_size));
        if let Err(msg) = prop(&input) {
            // Shrink: retry the same case seed at smaller sizes to find a
            // minimal reproduction (generators are size-monotone).
            for s in (cfg.min_size..size).rev() {
                let mut srng =
                    Rng::seed_from(cfg.seed ^ (case as u64).wrapping_mul(0x9E3779B97F4A7C15));
                let sinput = gen.generate(&mut srng, s);
                if let Err(smsg) = prop(&sinput) {
                    failures = Some((case, s, smsg));
                    break 'outer;
                }
            }
            failures = Some((case, size, msg));
            break 'outer;
        }
    }
    if let Some((case, size, msg)) = failures {
        panic!(
            "property '{name}' failed: case={case} size={size} seed={:#x}\n  {msg}",
            cfg.seed
        );
    }
}

/// [`forall_cfg`] with the default configuration but a custom case count.
pub fn forall<G: Gen>(
    name: &str,
    cases: usize,
    gen: G,
    prop: impl Fn(&G::Output) -> Result<(), String>,
) {
    forall_cfg(name, &PropConfig { cases, ..Default::default() }, gen, prop)
}

/// Assert two floats are close; returns Err for `forall` props.
pub fn close(a: f64, b: f64, tol: f64, what: &str) -> Result<(), String> {
    let scale = 1.0f64.max(a.abs()).max(b.abs());
    if (a - b).abs() <= tol * scale {
        Ok(())
    } else {
        Err(format!("{what}: {a} vs {b} (tol {tol}, |Δ|={})", (a - b).abs()))
    }
}

/// Assert two slices are elementwise close.
pub fn close_vec(a: &[f64], b: &[f64], tol: f64, what: &str) -> Result<(), String> {
    if a.len() != b.len() {
        return Err(format!("{what}: length {} vs {}", a.len(), b.len()));
    }
    for i in 0..a.len() {
        close(a[i], b[i], tol, &format!("{what}[{i}]"))?;
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_passes() {
        forall("sum commutes", 32, |rng: &mut Rng, size: usize| {
            (0..size).map(|_| rng.uniform()).collect::<Vec<f64>>()
        }, |xs| {
            let fwd: f64 = xs.iter().sum();
            let rev: f64 = xs.iter().rev().sum();
            close(fwd, rev, 1e-9, "sum")
        });
    }

    #[test]
    #[should_panic(expected = "always fails")]
    fn failing_property_reports() {
        forall("boom", 4, |_rng: &mut Rng, size: usize| size, |_s| {
            Err("always fails".to_string())
        });
    }

    #[test]
    fn shrink_finds_smaller_case() {
        // Property fails for any size >= 3; the runner should report size 3
        // (or min) rather than the first-failing larger size.
        let result = std::panic::catch_unwind(|| {
            forall_cfg(
                "shrinks",
                &PropConfig { cases: 16, seed: 7, min_size: 1, max_size: 16 },
                |_rng: &mut Rng, size: usize| size,
                |&s| if s >= 3 { Err(format!("fails at {s}")) } else { Ok(()) },
            )
        });
        let msg = *result.unwrap_err().downcast::<String>().unwrap();
        assert!(msg.contains("size=3"), "got: {msg}");
    }

    #[test]
    fn close_vec_checks_lengths() {
        assert!(close_vec(&[1.0], &[1.0, 2.0], 1e-9, "v").is_err());
        assert!(close_vec(&[1.0, 2.0], &[1.0, 2.0 + 1e-12], 1e-9, "v").is_ok());
    }

    /// Ragged gemm shapes (never multiples of the mr/nr/kc tile sizes):
    /// the blocked parallel core must match the naive reference under
    /// **every** enabled microkernel.
    #[test]
    fn prop_blocked_matmul_matches_naive() {
        use crate::linalg::{enabled_choices, gemm, KernelCtx};
        forall(
            "blocked gemm == naive on ragged shapes",
            24,
            |rng: &mut Rng, size: usize| {
                let m = 1 + rng.below(5 + 4 * size);
                let k = 1 + rng.below(7 + 5 * size);
                let n = 1 + rng.below(5 + 4 * size);
                let a: Vec<f64> = (0..m * k).map(|_| rng.normal()).collect();
                let b: Vec<f64> = (0..k * n).map(|_| rng.normal()).collect();
                (m, k, n, a, b)
            },
            |(m, k, n, a, b)| {
                let (m, k, n) = (*m, *k, *n);
                let mut naive = vec![0.0; m * n];
                gemm::naive_matmul_into(a, b, &mut naive, m, k, n);
                for choice in enabled_choices() {
                    let ctx = KernelCtx::for_choice(choice).expect("enabled kernel");
                    for nt in [1, 3] {
                        let mut blocked = vec![0.0; m * n];
                        ctx.blocked_matmul_into(a, b, &mut blocked, m, k, n, nt);
                        close_vec(
                            &naive,
                            &blocked,
                            1e-10,
                            &format!("gemm[{choice}] {m}x{k}x{n} nt={nt}"),
                        )?;
                    }
                }
                Ok(())
            },
        );
    }

    /// Microkernel-level bit-identity pin: on random packed panels of
    /// every depth (including kc=1 and non-multiples of 4), each enabled
    /// kernel's `tile` must reproduce its scalar `tile_model` bit for
    /// bit, starting from a non-zero accumulator.
    #[test]
    fn prop_microkernel_tile_matches_model_bitwise() {
        use crate::linalg::{enabled_choices, KernelCtx};
        forall(
            "microkernel tile == scalar model bits",
            48,
            |rng: &mut Rng, size: usize| {
                let kc = 1 + rng.below(4 + 8 * size);
                // Sized for the widest tile (mr,nr ≤ 8); each kernel
                // slices its own mr/nr prefix.
                let ap: Vec<f64> = (0..kc * 8).map(|_| rng.normal()).collect();
                let bp: Vec<f64> = (0..kc * 8).map(|_| rng.normal()).collect();
                let acc0: Vec<f64> = (0..64).map(|_| rng.normal()).collect();
                (kc, ap, bp, acc0)
            },
            |(kc, ap, bp, acc0)| {
                let kc = *kc;
                for choice in enabled_choices() {
                    let kern = KernelCtx::for_choice(choice).expect("enabled kernel").micro();
                    let (mr, nr) = (kern.mr(), kern.nr());
                    let mut got = acc0[..mr * nr].to_vec();
                    kern.tile(&ap[..kc * mr], &bp[..kc * nr], kc, &mut got);
                    let mut want = acc0[..mr * nr].to_vec();
                    kern.tile_model(&ap[..kc * mr], &bp[..kc * nr], kc, &mut want);
                    for (i, (g, w)) in got.iter().zip(&want).enumerate() {
                        if g.to_bits() != w.to_bits() {
                            return Err(format!(
                                "{choice} kc={kc} tile[{i}]: {g} vs model {w}"
                            ));
                        }
                    }
                }
                Ok(())
            },
        );
    }

    /// The whole blocked core driven by the real kernel must be
    /// bit-identical to the same core driven by the kernel's scalar
    /// model, on ragged shapes (every m/n tail fringe) — extending the
    /// tile-level pin through packing, edge masking, and banding.
    #[test]
    fn prop_blocked_core_matches_model_kernel_bitwise() {
        use crate::linalg::{enabled_choices, gemm, KernelCtx};
        forall(
            "blocked core == model-kernel core bits",
            16,
            |rng: &mut Rng, size: usize| {
                let m = 1 + rng.below(8 + 6 * size);
                let k = 1 + rng.below(10 + 8 * size);
                let n = 1 + rng.below(8 + 6 * size);
                let a: Vec<f64> = (0..m * k).map(|_| rng.normal()).collect();
                let b: Vec<f64> = (0..k * n).map(|_| rng.normal()).collect();
                (m, k, n, a, b)
            },
            |(m, k, n, a, b)| {
                let (m, k, n) = (*m, *k, *n);
                for choice in enabled_choices() {
                    let ctx = KernelCtx::for_choice(choice).expect("enabled kernel");
                    let model = gemm::model_ctx(choice).expect("model for enabled kernel");
                    let mut real = vec![0.0; m * n];
                    ctx.blocked_matmul_into(a, b, &mut real, m, k, n, 2);
                    let mut modeled = vec![0.0; m * n];
                    model.blocked_matmul_into(a, b, &mut modeled, m, k, n, 2);
                    for (i, (r, w)) in real.iter().zip(&modeled).enumerate() {
                        if r.to_bits() != w.to_bits() {
                            return Err(format!(
                                "gemm[{choice}] {m}x{k}x{n} flat {i}: {r} vs model {w}"
                            ));
                        }
                    }
                    let mut greal = vec![0.0; m * m];
                    ctx.blocked_gram_into(a, &mut greal, m, k, 2);
                    let mut gmodel = vec![0.0; m * m];
                    model.blocked_gram_into(a, &mut gmodel, m, k, 2);
                    for (i, (r, w)) in greal.iter().zip(&gmodel).enumerate() {
                        if r.to_bits() != w.to_bits() {
                            return Err(format!(
                                "gram[{choice}] {m}x{k} flat {i}: {r} vs model {w}"
                            ));
                        }
                    }
                }
                Ok(())
            },
        );
    }

    /// Per-kernel thread-count determinism: for a fixed kernel choice,
    /// the blocked products are bit-identical at 1, 2, and 8 workers.
    #[test]
    fn prop_blocked_kernels_bit_stable_across_threads() {
        use crate::linalg::{enabled_choices, KernelCtx};
        forall(
            "blocked products bit-stable across 1/2/8 threads per kernel",
            12,
            |rng: &mut Rng, size: usize| {
                let m = 3 + rng.below(10 + 8 * size);
                let k = 3 + rng.below(12 + 8 * size);
                let n = 3 + rng.below(10 + 8 * size);
                let a: Vec<f64> = (0..m * k).map(|_| rng.normal()).collect();
                let b: Vec<f64> = (0..k * n).map(|_| rng.normal()).collect();
                (m, k, n, a, b)
            },
            |(m, k, n, a, b)| {
                let (m, k, n) = (*m, *k, *n);
                for choice in enabled_choices() {
                    let ctx = KernelCtx::for_choice(choice).expect("enabled kernel");
                    let mut c1 = vec![0.0; m * n];
                    ctx.blocked_matmul_into(a, b, &mut c1, m, k, n, 1);
                    let mut g1 = vec![0.0; m * m];
                    ctx.blocked_gram_into(a, &mut g1, m, k, 1);
                    for nt in [2usize, 8] {
                        let mut cn = vec![0.0; m * n];
                        ctx.blocked_matmul_into(a, b, &mut cn, m, k, n, nt);
                        for (i, (x, y)) in c1.iter().zip(&cn).enumerate() {
                            if x.to_bits() != y.to_bits() {
                                return Err(format!(
                                    "gemm[{choice}] nt={nt} flat {i}: {x} vs {y}"
                                ));
                            }
                        }
                        let mut gn = vec![0.0; m * m];
                        ctx.blocked_gram_into(a, &mut gn, m, k, nt);
                        for (i, (x, y)) in g1.iter().zip(&gn).enumerate() {
                            if x.to_bits() != y.to_bits() {
                                return Err(format!(
                                    "gram[{choice}] nt={nt} flat {i}: {x} vs {y}"
                                ));
                            }
                        }
                    }
                }
                Ok(())
            },
        );
    }

    /// Duplicate-merging in `Csr::from_triplets` must match a dense
    /// accumulation reference on random (unsorted, duplicate-heavy)
    /// triplet soups — the pin on the grouped-merge rewrite.
    #[test]
    fn prop_from_triplets_matches_dense_accumulation() {
        use crate::linalg::{Csr, Mat};
        forall(
            "from_triplets == dense accumulation",
            48,
            |rng: &mut Rng, size: usize| {
                let rows = 1 + rng.below(3 + size);
                let cols = 1 + rng.below(3 + size);
                // enough draws over a small grid to force duplicates
                let ndraws = rng.below(4 * (rows * cols).min(40) + 2);
                let trip: Vec<(usize, usize, f64)> = (0..ndraws)
                    .map(|_| (rng.below(rows), rng.below(cols), rng.normal()))
                    .collect();
                (rows, cols, trip)
            },
            |(rows, cols, trip)| {
                let (rows, cols) = (*rows, *cols);
                let mut reference = Mat::zeros(rows, cols);
                for &(r, c, v) in trip {
                    let cur = reference.get(r, c);
                    reference.set(r, c, cur + v);
                }
                let csr = Csr::from_triplets(rows, cols, trip.clone());
                let dense = csr.to_dense();
                close_vec(dense.data(), reference.data(), 1e-12, "accumulated matrix")?;
                // stored entries are unique per coordinate: nnz is bounded
                // by the number of distinct draws
                let mut coords: Vec<(usize, usize)> =
                    trip.iter().map(|&(r, c, _)| (r, c)).collect();
                coords.sort_unstable();
                coords.dedup();
                if csr.nnz() != coords.len() {
                    return Err(format!(
                        "nnz {} != distinct coords {}",
                        csr.nnz(),
                        coords.len()
                    ));
                }
                Ok(())
            },
        );
    }

    /// Every sparse kernel must be bit-identical run serial and threaded
    /// (1/2/4 workers) — the sparse twin of the blocked-GEMM determinism
    /// pin. Shapes are drawn large enough to cross the sparse fan-out
    /// threshold so the threaded paths really engage.
    #[test]
    fn prop_sparse_kernels_bit_stable() {
        use crate::linalg::{Csc, Csr, Mat};
        use crate::util::parallel::{with_parallelism, Parallelism};
        forall_cfg(
            "sparse kernels bit-stable across thread counts",
            &PropConfig { cases: 6, seed: 0xBEEF, min_size: 1, max_size: 6 },
            |rng: &mut Rng, size: usize| {
                // 600..1400 rows so the TCHUNK reduction splits; nnz well
                // past the 2^14 fan-out threshold.
                let rows = 600 + rng.below(200 + size * 120);
                let cols = 90 + rng.below(40 + size * 20);
                let per_row = 18 + rng.below(12);
                let mut trip = Vec::with_capacity(rows * per_row);
                for r in 0..rows {
                    for _ in 0..per_row {
                        trip.push((r, rng.below(cols), rng.normal()));
                    }
                }
                let a = Csr::from_triplets(rows, cols, trip);
                let x: Vec<f64> = (0..cols).map(|_| rng.normal()).collect();
                let u: Vec<f64> = (0..rows).map(|_| rng.normal()).collect();
                (a, x, u)
            },
            |(a, x, u)| {
                let run = |par: Parallelism| {
                    with_parallelism(par, || {
                        let csc = Csc::from_csr(a);
                        let mut g = Mat::zeros(a.cols(), a.cols());
                        a.gram_into(&csc, &mut g);
                        (a.matvec(x), a.matvec_t(u), a.col_norms_sq(), csc, g)
                    })
                };
                let serial = run(Parallelism::None);
                for nt in [1usize, 2, 4] {
                    let threaded = run(Parallelism::Fixed(nt));
                    for (name, s, t) in [
                        ("matvec", &serial.0, &threaded.0),
                        ("matvec_t", &serial.1, &threaded.1),
                        ("col_norms_sq", &serial.2, &threaded.2),
                    ] {
                        for (i, (a, b)) in s.iter().zip(t.iter()).enumerate() {
                            if a.to_bits() != b.to_bits() {
                                return Err(format!(
                                    "{name} nt={nt} i={i}: {a} vs {b}"
                                ));
                            }
                        }
                    }
                    if serial.3 != threaded.3 {
                        return Err(format!("csc construction differs at nt={nt}"));
                    }
                    for (i, (a, b)) in
                        serial.4.data().iter().zip(threaded.4.data()).enumerate()
                    {
                        if a.to_bits() != b.to_bits() {
                            return Err(format!("gram nt={nt} flat-index {i}: {a} vs {b}"));
                        }
                    }
                }
                Ok(())
            },
        );
    }

    /// Sparse kernels agree with their dense references on ragged shapes.
    #[test]
    fn prop_sparse_kernels_match_dense() {
        use crate::linalg::{Csc, Csr, Mat};
        forall(
            "sparse kernels == dense reference",
            24,
            |rng: &mut Rng, size: usize| {
                let rows = 2 + rng.below(6 + 4 * size);
                let cols = 2 + rng.below(6 + 4 * size);
                let density = rng.uniform_in(0.1, 0.6);
                let mut local = Rng::seed_from(rng.next_u64());
                let dense = Mat::from_fn(rows, cols, |_, _| {
                    if local.bernoulli(density) {
                        local.normal()
                    } else {
                        0.0
                    }
                });
                let x: Vec<f64> = (0..cols).map(|_| local.normal()).collect();
                let u: Vec<f64> = (0..rows).map(|_| local.normal()).collect();
                (dense, x, u)
            },
            |(dense, x, u)| {
                let a = Csr::from_dense(dense, 0.0);
                let csc = Csc::from_csr(&a);
                close_vec(&a.matvec(x), &dense.matvec(x), 1e-11, "matvec")?;
                close_vec(&a.matvec_t(u), &dense.matvec_t(u), 1e-11, "matvec_t")?;
                let mut g = Mat::zeros(a.cols(), a.cols());
                a.gram_into(&csc, &mut g);
                close_vec(g.data(), dense.gram_t().data(), 1e-10, "gram_t")?;
                let mut gg = Mat::zeros(a.rows(), a.rows());
                a.gram_rows_into(&csc, &mut gg);
                close_vec(gg.data(), dense.gram().data(), 1e-10, "gram")?;
                for c in 0..a.cols() {
                    let expect: f64 =
                        (0..a.rows()).map(|r| dense.get(r, c) * u[r]).sum();
                    close(csc.col_dot(c, u), expect, 1e-11, &format!("col_dot {c}"))?;
                }
                Ok(())
            },
        );
    }

    /// Multi-RHS kernel contract, dense side: every column of
    /// `matvec_multi_into` / `matvec_t_multi_into` is bit-identical to
    /// the single-RHS call on that column, at every thread count. Shapes
    /// are drawn to cross both the GEMV banding threshold (rows·cols ≥
    /// 2^16) and the TCHUNK reduction split (rows > 512) in most cases.
    #[test]
    fn prop_dense_multi_rhs_columns_bit_identical() {
        use crate::linalg::{Mat, MultiVec};
        use crate::util::parallel::{with_parallelism, Parallelism};
        forall_cfg(
            "dense multi-RHS columns == single-RHS bits",
            &PropConfig { cases: 8, seed: 0xD0D0, min_size: 1, max_size: 8 },
            |rng: &mut Rng, size: usize| {
                let rows = 200 + rng.below(200 + size * 150);
                let cols = 40 + rng.below(30 + size * 20);
                let r = 1 + rng.below(4);
                let a = Mat::from_fn(rows, cols, |_, _| rng.normal());
                let xs = MultiVec::from_fn(cols, r, |_, _| rng.normal());
                let us = MultiVec::from_fn(rows, r, |_, _| rng.normal());
                (a, xs, us)
            },
            |(a, xs, us)| {
                let r = xs.ncols();
                for par in [Parallelism::None, Parallelism::Fixed(3)] {
                    let (multi, multi_t) = with_parallelism(par, || {
                        let mut ys = MultiVec::zeros(a.rows(), r);
                        a.matvec_multi_into(xs, &mut ys);
                        let mut yts = MultiVec::zeros(a.cols(), r);
                        a.matvec_t_multi_into(us, &mut yts);
                        (ys, yts)
                    });
                    for j in 0..r {
                        let (single, single_t) = with_parallelism(par, || {
                            (a.matvec(xs.col(j)), a.matvec_t(us.col(j)))
                        });
                        for (i, (s, m)) in single.iter().zip(multi.col(j)).enumerate() {
                            if s.to_bits() != m.to_bits() {
                                return Err(format!(
                                    "matvec {par:?} col {j} i={i}: {s} vs {m}"
                                ));
                            }
                        }
                        for (i, (s, m)) in
                            single_t.iter().zip(multi_t.col(j)).enumerate()
                        {
                            if s.to_bits() != m.to_bits() {
                                return Err(format!(
                                    "matvec_t {par:?} col {j} i={i}: {s} vs {m}"
                                ));
                            }
                        }
                    }
                }
                Ok(())
            },
        );
    }

    /// Multi-RHS kernel contract, sparse side — including exact-zero
    /// panel entries so the per-column zero-skip matches the single-RHS
    /// skip, and thread-count bit-stability of the panel results.
    #[test]
    fn prop_sparse_multi_rhs_columns_bit_identical() {
        use crate::linalg::{Csr, MultiVec};
        use crate::util::parallel::{with_parallelism, Parallelism};
        forall_cfg(
            "sparse multi-RHS columns == single-RHS bits",
            &PropConfig { cases: 6, seed: 0xFACE, min_size: 1, max_size: 6 },
            |rng: &mut Rng, size: usize| {
                let rows = 600 + rng.below(200 + size * 120);
                let cols = 80 + rng.below(40 + size * 20);
                let per_row = 16 + rng.below(14);
                let mut trip = Vec::with_capacity(rows * per_row);
                for row in 0..rows {
                    for _ in 0..per_row {
                        trip.push((row, rng.below(cols), rng.normal()));
                    }
                }
                let a = Csr::from_triplets(rows, cols, trip);
                let r = 1 + rng.below(3);
                let xs = MultiVec::from_fn(cols, r, |_, _| rng.normal());
                let us = MultiVec::from_fn(rows, r, |i, _| {
                    if i % 5 == 0 {
                        0.0
                    } else {
                        rng.normal()
                    }
                });
                (a, xs, us)
            },
            |(a, xs, us)| {
                let r = xs.ncols();
                let run = |par: Parallelism| {
                    with_parallelism(par, || {
                        let mut ys = MultiVec::zeros(a.rows(), r);
                        a.matvec_multi_into(xs, &mut ys);
                        let mut yts = MultiVec::zeros(a.cols(), r);
                        a.matvec_t_multi_into(us, &mut yts);
                        (ys, yts)
                    })
                };
                let serial = run(Parallelism::None);
                // columns == single-RHS bits (serial)
                for j in 0..r {
                    let (single, single_t) = with_parallelism(Parallelism::None, || {
                        (a.matvec(xs.col(j)), a.matvec_t(us.col(j)))
                    });
                    for (i, (s, m)) in single.iter().zip(serial.0.col(j)).enumerate() {
                        if s.to_bits() != m.to_bits() {
                            return Err(format!("matvec col {j} i={i}: {s} vs {m}"));
                        }
                    }
                    for (i, (s, m)) in single_t.iter().zip(serial.1.col(j)).enumerate() {
                        if s.to_bits() != m.to_bits() {
                            return Err(format!("matvec_t col {j} i={i}: {s} vs {m}"));
                        }
                    }
                }
                // panel bits stable across thread counts
                for nt in [2usize, 4] {
                    let threaded = run(Parallelism::Fixed(nt));
                    for (i, (s, t)) in
                        serial.0.data().iter().zip(threaded.0.data()).enumerate()
                    {
                        if s.to_bits() != t.to_bits() {
                            return Err(format!("matvec panel nt={nt} flat {i}"));
                        }
                    }
                    for (i, (s, t)) in
                        serial.1.data().iter().zip(threaded.1.data()).enumerate()
                    {
                        if s.to_bits() != t.to_bits() {
                            return Err(format!("matvec_t panel nt={nt} flat {i}"));
                        }
                    }
                }
                Ok(())
            },
        );
    }

    /// Same property for the symmetric gram kernel, plus exact symmetry —
    /// under every enabled microkernel.
    #[test]
    fn prop_blocked_gram_matches_naive() {
        use crate::linalg::{enabled_choices, gemm, KernelCtx};
        forall(
            "blocked gram == naive on ragged shapes",
            20,
            |rng: &mut Rng, size: usize| {
                let m = 1 + rng.below(6 + 5 * size);
                let k = 1 + rng.below(8 + 6 * size);
                let a: Vec<f64> = (0..m * k).map(|_| rng.normal()).collect();
                (m, k, a)
            },
            |(m, k, a)| {
                let (m, k) = (*m, *k);
                let mut naive = vec![0.0; m * m];
                gemm::naive_gram_into(a, &mut naive, m, k);
                for choice in enabled_choices() {
                    let ctx = KernelCtx::for_choice(choice).expect("enabled kernel");
                    for nt in [1, 4] {
                        let mut blocked = vec![0.0; m * m];
                        ctx.blocked_gram_into(a, &mut blocked, m, k, nt);
                        close_vec(
                            &naive,
                            &blocked,
                            1e-10,
                            &format!("gram[{choice}] {m}x{k} nt={nt}"),
                        )?;
                        for i in 0..m {
                            for j in 0..i {
                                if blocked[i * m + j].to_bits() != blocked[j * m + i].to_bits()
                                {
                                    return Err(format!(
                                        "asymmetry[{choice}] at ({i},{j}) nt={nt}"
                                    ));
                                }
                            }
                        }
                    }
                }
                Ok(())
            },
        );
    }
}
