//! Sample-matrix abstraction for the SVM solvers.
//!
//! The primal Newton-CG only touches the data through `X̂v` and `X̂ᵀu`.
//! [`DenseSamples`] materializes the m × d matrix; [`ReducedSamples`]
//! represents the SVEN construction `X̂ = [Xᵀ − 1yᵀ/t ; Xᵀ + 1yᵀ/t]`
//! *implicitly* as one X (or Xᵀ) product plus a rank-one correction —
//! halving memory traffic and skipping the O(np) construction entirely
//! (the practical trick behind the paper's "construction requires only
//! O(np)" remark, taken one step further). The underlying design is a
//! [`Design`], so a sparse X drives the whole Newton-CG at O(nnz) per
//! product with no densification anywhere in the solve.

use crate::linalg::{vecops, Design, Mat};

/// Abstract m-samples × d-features matrix X̂.
pub trait SampleSet: Sync {
    /// Number of samples (SVM classification points).
    fn m(&self) -> usize;
    /// Feature dimension (weight-vector length).
    fn d(&self) -> usize;
    /// `out ← X̂ · v`, out length m.
    fn matvec(&self, v: &[f64], out: &mut [f64]);
    /// `out ← X̂ᵀ · u`, out length d.
    fn matvec_t(&self, u: &[f64], out: &mut [f64]);
}

/// Materialized samples (rows = samples).
pub struct DenseSamples {
    pub x: Mat,
}

impl SampleSet for DenseSamples {
    fn m(&self) -> usize {
        self.x.rows()
    }

    fn d(&self) -> usize {
        self.x.cols()
    }

    fn matvec(&self, v: &[f64], out: &mut [f64]) {
        self.x.matvec_into(v, out);
    }

    fn matvec_t(&self, u: &[f64], out: &mut [f64]) {
        self.x.matvec_t_into(u, out);
    }
}

/// The SVEN-constructed sample set, held implicitly.
///
/// With `X ∈ R^{n×p}` (the regression design), `y ∈ R^n`, budget `t`:
/// sample i ∈ [0, p) is column i of `X − y·1ᵀ/t` (class +1) and sample
/// p + i is column i of `X + y·1ᵀ/t` (class −1); both live in R^n (d = n,
/// m = 2p).
pub struct ReducedSamples<'a> {
    pub x: &'a Design,
    pub y: &'a [f64],
    pub t: f64,
}

impl ReducedSamples<'_> {
    #[inline]
    fn p(&self) -> usize {
        self.x.cols()
    }
}

impl SampleSet for ReducedSamples<'_> {
    fn m(&self) -> usize {
        2 * self.p()
    }

    fn d(&self) -> usize {
        self.x.rows()
    }

    /// `X̂·v = [Xᵀv − (yᵀv/t)·1 ; Xᵀv + (yᵀv/t)·1]`.
    fn matvec(&self, v: &[f64], out: &mut [f64]) {
        let p = self.p();
        debug_assert_eq!(v.len(), self.d());
        debug_assert_eq!(out.len(), 2 * p);
        let (top, bot) = out.split_at_mut(p);
        self.x.matvec_t_into(v, top);
        let shift = vecops::dot(self.y, v) / self.t;
        for i in 0..p {
            bot[i] = top[i] + shift;
            top[i] -= shift;
        }
    }

    /// `X̂ᵀ·u = X(u₁ + u₂) + (1ᵀu₂ − 1ᵀu₁)/t · y`.
    fn matvec_t(&self, u: &[f64], out: &mut [f64]) {
        let p = self.p();
        debug_assert_eq!(u.len(), 2 * p);
        debug_assert_eq!(out.len(), self.d());
        let (u1, u2) = u.split_at(p);
        let mut sum = vec![0.0; p];
        vecops::add(u1, u2, &mut sum);
        self.x.matvec_into(&sum, out);
        let coeff = (u2.iter().sum::<f64>() - u1.iter().sum::<f64>()) / self.t;
        vecops::axpy(coeff, self.y, out);
    }
}

/// Materialize the SVEN sample matrix (m × d) — used by tests to validate
/// [`ReducedSamples`] and by callers that prefer dense (small problems).
pub fn materialize_reduction(x: &Mat, y: &[f64], t: f64) -> Mat {
    let (n, p) = (x.rows(), x.cols());
    let mut out = Mat::zeros(2 * p, n);
    for i in 0..p {
        for r in 0..n {
            let xc = x.get(r, i);
            out.set(i, r, xc - y[r] / t);
            out.set(p + i, r, xc + y[r] / t);
        }
    }
    out
}

/// Labels of the SVEN construction: +1 for the first p samples, −1 after.
pub fn reduction_labels(p: usize) -> Vec<f64> {
    let mut y = vec![1.0; 2 * p];
    for v in y[p..].iter_mut() {
        *v = -1.0;
    }
    y
}

/// The gram matrix `K = ẐᵀẐ` of the SVEN construction
/// (`Ẑ = (ŷ₁x̂₁ … ŷₘx̂ₘ)`), built from `XᵀX` blocks in O(p²) after one
/// O(np²) product instead of the naive O(n(2p)²):
///
/// ```text
/// K = [  G₁₁  −G₁₂ ]      G₁₁ = G − s(v1ᵀ+1vᵀ) + s²c·11ᵀ
///     [ −G₁₂ᵀ  G₂₂ ]      G₂₂ = G + s(v1ᵀ+1vᵀ) + s²c·11ᵀ
///                         G₁₂ = G + s·v1ᵀ − s·1vᵀ − s²c·11ᵀ
/// ```
/// with `G = XᵀX`, `v = Xᵀy`, `c = yᵀy`, `s = 1/t`.
pub fn reduction_gram(x: &Mat, y: &[f64], t: f64) -> Mat {
    let g = x.gram_t(); // XᵀX, p×p (blocked parallel kernel)
    let v = x.matvec_t(y); // Xᵀy
    let c = vecops::norm2_sq(y);
    let s = 1.0 / t;
    let mut k = Mat::zeros(2 * x.cols(), 2 * x.cols());
    assemble_reduction_gram(&g, &v, s, s * s * c, &mut k);
    k
}

/// Row-parallel assembly of `K(t)` from the t-independent blocks
/// (`G = XᵀX`, `v = Xᵀy`, `s = 1/t`, `s2c = s²·yᵀy`). Each output row is
/// an independent elementwise formula, so the fan-out over the scoped
/// pool is embarrassingly parallel and bit-stable across thread counts.
/// Shared with the dual backend's cached-path `gram_at`.
pub(crate) fn assemble_reduction_gram(g: &Mat, v: &[f64], s: f64, s2c: f64, k: &mut Mat) {
    let p = g.rows();
    let m = 2 * p;
    debug_assert_eq!((k.rows(), k.cols()), (m, m));
    let nt = if m * m < 1 << 14 { 1 } else { crate::util::parallel::effective_threads() };
    let rows: Vec<&mut [f64]> = k.data_mut().chunks_mut(m).collect();
    crate::util::parallel::parallel_items(nt, rows, |r, row| {
        if r < p {
            // Row i of [G₁₁, −G₁₂]:
            //   K[i, j]     = G[i,j] − s(vᵢ+vⱼ) + s²c
            //   K[i, p+j]   = −(G[i,j] + s·vᵢ − s·vⱼ − s²c)
            let i = r;
            let gi = g.row(i);
            for j in 0..p {
                let gij = gi[j];
                row[j] = gij - s * (v[i] + v[j]) + s2c;
                row[p + j] = -(gij + s * v[i] - s * v[j] - s2c);
            }
        } else {
            // Row p+a of [−G₁₂ᵀ, G₂₂] (G symmetric ⇒ G₁₂ᵀ[a,b] = G₁₂[b,a]):
            //   K[p+a, b]   = −(G[a,b] + s·v_b − s·v_a − s²c)
            //   K[p+a, p+b] = G[a,b] + s(v_a+v_b) + s²c
            let a = r - p;
            let ga = g.row(a);
            for b in 0..p {
                let gab = ga[b];
                row[b] = -(gab + s * v[b] - s * v[a] - s2c);
                row[p + b] = gab + s * (v[a] + v[b]) + s2c;
            }
        }
    });
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;

    fn setup(n: usize, p: usize, seed: u64) -> (Mat, Vec<f64>, f64) {
        let mut rng = Rng::seed_from(seed);
        let x = Mat::from_fn(n, p, |_, _| rng.normal());
        let y: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
        (x, y, 0.7)
    }

    #[test]
    fn reduced_matvec_matches_materialized() {
        let (x, y, t) = setup(9, 6, 121);
        let d: Design = x.clone().into();
        let red = ReducedSamples { x: &d, y: &y, t };
        let dense = materialize_reduction(&x, &y, t);
        let mut rng = Rng::seed_from(122);
        let v: Vec<f64> = (0..9).map(|_| rng.normal()).collect();
        let mut out_red = vec![0.0; 12];
        red.matvec(&v, &mut out_red);
        let out_dense = dense.matvec(&v);
        for i in 0..12 {
            assert!((out_red[i] - out_dense[i]).abs() < 1e-10, "i={i}");
        }
    }

    #[test]
    fn reduced_matvec_t_matches_materialized() {
        let (x, y, t) = setup(7, 5, 123);
        let d: Design = x.clone().into();
        let red = ReducedSamples { x: &d, y: &y, t };
        let dense = materialize_reduction(&x, &y, t);
        let mut rng = Rng::seed_from(124);
        let u: Vec<f64> = (0..10).map(|_| rng.normal()).collect();
        let mut out_red = vec![0.0; 7];
        red.matvec_t(&u, &mut out_red);
        let out_dense = dense.matvec_t(&u);
        for i in 0..7 {
            assert!((out_red[i] - out_dense[i]).abs() < 1e-10, "i={i}");
        }
    }

    #[test]
    fn gram_matches_materialized() {
        let (x, y, t) = setup(8, 4, 125);
        let k = reduction_gram(&x, &y, t);
        // naive: Ẑ columns are ŷ_i x̂_i; K = ẐᵀẐ
        let xhat = materialize_reduction(&x, &y, t); // rows = samples
        let labels = reduction_labels(4);
        let m = 8usize;
        for i in 0..m {
            for j in 0..m {
                let kij: f64 = labels[i]
                    * labels[j]
                    * vecops::dot(xhat.row(i), xhat.row(j));
                assert!(
                    (k.get(i, j) - kij).abs() < 1e-9,
                    "({i},{j}): {} vs {kij}",
                    k.get(i, j)
                );
            }
        }
    }

    #[test]
    fn labels_shape() {
        let l = reduction_labels(3);
        assert_eq!(l, vec![1.0, 1.0, 1.0, -1.0, -1.0, -1.0]);
    }

    #[test]
    fn reduced_ops_over_sparse_design_match_materialized() {
        // The SVEN sample operator over a sparse Design must agree with
        // the densified construction — the primal solver's O(nnz) path.
        let mut rng = Rng::seed_from(126);
        let x = Mat::from_fn(11, 7, |_, _| {
            if rng.bernoulli(0.35) {
                rng.normal()
            } else {
                0.0
            }
        });
        let y: Vec<f64> = (0..11).map(|_| rng.normal()).collect();
        let t = 0.9;
        let d: Design = crate::linalg::Csr::from_dense(&x, 0.0).into();
        assert!(d.is_sparse());
        let red = ReducedSamples { x: &d, y: &y, t };
        let dense = materialize_reduction(&x, &y, t);
        let v: Vec<f64> = (0..11).map(|_| rng.normal()).collect();
        let mut out = vec![0.0; 14];
        red.matvec(&v, &mut out);
        let expect = dense.matvec(&v);
        for i in 0..14 {
            assert!((out[i] - expect[i]).abs() < 1e-10, "matvec {i}");
        }
        let u: Vec<f64> = (0..14).map(|_| rng.normal()).collect();
        let mut out_t = vec![0.0; 11];
        red.matvec_t(&u, &mut out_t);
        let expect_t = dense.matvec_t(&u);
        for i in 0..11 {
            assert!((out_t[i] - expect_t[i]).abs() < 1e-10, "matvec_t {i}");
        }
    }
}
