//! Sample-matrix abstraction for the SVM solvers.
//!
//! The primal Newton-CG only touches the data through `X̂v` and `X̂ᵀu`.
//! [`DenseSamples`] materializes the m × d matrix; [`ReducedSamples`]
//! represents the SVEN construction `X̂ = [Xᵀ − 1yᵀ/t ; Xᵀ + 1yᵀ/t]`
//! *implicitly* as one X (or Xᵀ) product plus a rank-one correction —
//! halving memory traffic and skipping the O(np) construction entirely
//! (the practical trick behind the paper's "construction requires only
//! O(np)" remark, taken one step further). The underlying design is a
//! [`Design`], so a sparse X drives the whole Newton-CG at O(nnz) per
//! product with no densification anywhere in the solve.

use crate::linalg::lowp::{vecops_f32, MatF32};
use crate::linalg::{vecops, Csr, Design, DesignShadowF32, Mat, MultiVec};

/// Abstract m-samples × d-features matrix X̂.
pub trait SampleSet: Sync {
    /// Number of samples (SVM classification points).
    fn m(&self) -> usize;
    /// Feature dimension (weight-vector length).
    fn d(&self) -> usize;
    /// `out ← X̂ · v`, out length m.
    fn matvec(&self, v: &[f64], out: &mut [f64]);
    /// `out ← X̂ᵀ · u`, out length d.
    fn matvec_t(&self, u: &[f64], out: &mut [f64]);

    /// Fused multi-RHS `out ← X̂ · V` (V is `d × r`, out `m × r`).
    /// Column `j` of `out` is bit-identical to `matvec(V.col(j), ..)` —
    /// the panel form exists purely to amortize the data traffic (the
    /// batched margin refresh of the primal Newton).
    fn matvec_multi(&self, vs: &MultiVec, out: &mut MultiVec);

    /// Fused multi-RHS `out ← X̂ᵀ · U` (U is `m × r`, out `d × r`); same
    /// per-column bit-identity contract as [`SampleSet::matvec_multi`].
    fn matvec_t_multi(&self, us: &MultiVec, out: &mut MultiVec);

    /// Gather the sample rows `rows` into a reused compact panel. The
    /// panel's products ([`SampleSet::gathered_matvec`] /
    /// [`SampleSet::gathered_matvec_t`]) equal the corresponding
    /// masked-full-matrix products to floating-point reassociation — the
    /// active-set (shrinking) Newton runs its Hessian-vector products on
    /// the m_sv-row panel instead of masking all m rows.
    fn gather_rows_into(&self, rows: &[usize], out: &mut GatheredRows);

    /// `out ← G · v` over a panel gathered from this sample set
    /// (`out.len() ==` the gathered row count).
    fn gathered_matvec(&self, g: &GatheredRows, v: &[f64], out: &mut [f64]);

    /// `out ← Gᵀ · u` over a gathered panel (`out.len() == d`).
    fn gathered_matvec_t(&self, g: &GatheredRows, u: &[f64], out: &mut [f64]);

    // -- mixed-precision hooks ---------------------------------------------
    //
    // The `_f32` twins run the bandwidth-bound core product in f32 (the
    // input is demoted through the reusable `fbuf` scratch; rank-one
    // shifts and the widened output stay f64) when the sample set
    // carries an f32 shadow, and fall back to the exact f64 product
    // otherwise. Accuracy is single precision — callers wrap them in
    // f64 iterative refinement ([`crate::linalg::cg_solve_refined`]).
    // Like the f64 CG products they are fixed-order (kernel-choice
    // independent) and bit-stable across thread counts.

    /// Whether the `_f32` hooks are served by a genuine f32 tier (the
    /// mixed-precision Newton only engages when true).
    fn mixed_available(&self) -> bool {
        false
    }

    /// f32-tier [`SampleSet::matvec`] (see the hook contract above).
    fn matvec_f32(&self, v: &[f64], out: &mut [f64], fbuf: &mut Vec<f32>) {
        let _ = fbuf;
        self.matvec(v, out);
    }

    /// f32-tier [`SampleSet::matvec_t`].
    fn matvec_t_f32(&self, u: &[f64], out: &mut [f64], fbuf: &mut Vec<f32>) {
        let _ = fbuf;
        self.matvec_t(u, out);
    }

    /// f32-tier [`SampleSet::gathered_matvec`] (uses the panel's shadow,
    /// see [`GatheredRows::build_f32_shadow`]).
    fn gathered_matvec_f32(
        &self,
        g: &GatheredRows,
        v: &[f64],
        out: &mut [f64],
        fbuf: &mut Vec<f32>,
    ) {
        let _ = fbuf;
        self.gathered_matvec(g, v, out);
    }

    /// f32-tier [`SampleSet::gathered_matvec_t`].
    fn gathered_matvec_t_f32(
        &self,
        g: &GatheredRows,
        u: &[f64],
        out: &mut [f64],
        fbuf: &mut Vec<f32>,
    ) {
        let _ = fbuf;
        self.gathered_matvec_t(g, u, out);
    }
}

/// A reusable compact panel of gathered sample rows (see
/// [`SampleSet::gather_rows_into`]). The storage variant tracks the
/// sample set it was gathered from: dense sample matrices gather into a
/// dense row panel, the implicit SVEN reduction gathers the underlying
/// design columns (dense or sparse) plus the per-row sign of its
/// rank-one `±y/t` correction, which stays implicit in the products.
#[derive(Default)]
pub struct GatheredRows {
    store: GatherStore,
    /// Per-gathered-row sign of the implicit rank-one correction
    /// (ReducedSamples); empty when the sample set has none.
    sign: Vec<f64>,
    /// One-time f32 copy of the panel for the mixed-precision tier;
    /// invalidated whenever the panel is re-gathered and rebuilt on
    /// demand by [`GatheredRows::build_f32_shadow`].
    shadow: Option<GatherShadowF32>,
}

#[derive(Default)]
enum GatherStore {
    #[default]
    Empty,
    Dense(Mat),
    Sparse(Csr),
}

/// f32 shadow of a [`GatherStore`]: a packed [`MatF32`] for dense
/// panels, demoted values sharing the CSR structure for sparse ones.
enum GatherShadowF32 {
    Dense(MatF32),
    Sparse(Vec<f32>),
}

impl GatheredRows {
    pub fn new() -> Self {
        Self::default()
    }

    /// Number of gathered rows.
    pub fn m(&self) -> usize {
        match &self.store {
            GatherStore::Empty => 0,
            GatherStore::Dense(m) => m.rows(),
            GatherStore::Sparse(c) => c.rows(),
        }
    }

    /// Per-row signs of the implicit rank-one correction (empty when the
    /// originating sample set has none).
    pub(crate) fn signs(&self) -> &[f64] {
        &self.sign
    }

    /// Fused multi-RHS product of the *bare* panel store (no rank-one
    /// shift): column `j` of `out` is bit-identical to the single-RHS
    /// `matvec_into` on the same store — the substrate of the batched
    /// Newton's shared-panel Hessian products.
    pub(crate) fn store_matvec_multi_into(&self, vs: &MultiVec, out: &mut MultiVec) {
        match &self.store {
            GatherStore::Dense(panel) => panel.matvec_multi_into(vs, out),
            GatherStore::Sparse(panel) => panel.matvec_multi_into(vs, out),
            GatherStore::Empty => panic!("empty gather panel"),
        }
    }

    /// Transpose twin of [`GatheredRows::store_matvec_multi_into`].
    pub(crate) fn store_matvec_t_multi_into(&self, us: &MultiVec, out: &mut MultiVec) {
        match &self.store {
            GatherStore::Dense(panel) => panel.matvec_t_multi_into(us, out),
            GatherStore::Sparse(panel) => panel.matvec_t_multi_into(us, out),
            GatherStore::Empty => panic!("empty gather panel"),
        }
    }

    /// Borrow (and, if needed, switch to) the dense storage. Dropping
    /// the shadow here keeps every gather path honest: a re-gathered
    /// panel can never serve a stale f32 copy.
    fn dense_store(&mut self) -> &mut Mat {
        self.shadow = None;
        if !matches!(self.store, GatherStore::Dense(_)) {
            self.store = GatherStore::Dense(Mat::zeros(0, 0));
        }
        match &mut self.store {
            GatherStore::Dense(m) => m,
            _ => unreachable!(),
        }
    }

    /// Borrow (and, if needed, switch to) the sparse storage (same
    /// shadow invalidation as [`GatheredRows::dense_store`]).
    fn sparse_store(&mut self) -> &mut Csr {
        self.shadow = None;
        if !matches!(self.store, GatherStore::Sparse(_)) {
            self.store = GatherStore::Sparse(Csr::empty());
        }
        match &mut self.store {
            GatherStore::Sparse(c) => c,
            _ => unreachable!(),
        }
    }

    /// Demote the gathered panel into its f32 shadow (no-op when the
    /// shadow is already current for this gather). The mixed-precision
    /// Newton calls this once per gather, so the demotion cost is
    /// amortized over every CG product on the panel.
    pub fn build_f32_shadow(&mut self) {
        if self.shadow.is_some() {
            return;
        }
        self.shadow = match &self.store {
            GatherStore::Empty => None,
            GatherStore::Dense(panel) => Some(GatherShadowF32::Dense(MatF32::from_mat(panel))),
            GatherStore::Sparse(panel) => Some(GatherShadowF32::Sparse(panel.values_f32())),
        };
    }

    /// Bytes held by the current f32 shadow (0 when absent).
    pub fn shadow_bytes(&self) -> usize {
        match &self.shadow {
            None => 0,
            Some(GatherShadowF32::Dense(panel)) => panel.bytes(),
            Some(GatherShadowF32::Sparse(vals)) => std::mem::size_of_val(vals.as_slice()),
        }
    }

    /// Whether [`GatheredRows::build_f32_shadow`] has run since the last
    /// gather.
    pub fn has_shadow(&self) -> bool {
        self.shadow.is_some()
    }

    /// `out ← panel · x32` through the f32 shadow (f64 output). Panics
    /// if no shadow is built — callers gate on [`GatheredRows::has_shadow`].
    fn shadow_matvec_into(&self, x32: &[f32], out: &mut [f64]) {
        match (&self.shadow, &self.store) {
            (Some(GatherShadowF32::Dense(panel)), _) => panel.matvec_into(x32, out),
            (Some(GatherShadowF32::Sparse(vals)), GatherStore::Sparse(csr)) => {
                csr.matvec_f32_into(vals, x32, out)
            }
            _ => panic!("gather panel has no f32 shadow"),
        }
    }

    /// Transpose twin of [`GatheredRows::shadow_matvec_into`].
    fn shadow_matvec_t_into(&self, x32: &[f32], out: &mut [f64]) {
        match (&self.shadow, &self.store) {
            (Some(GatherShadowF32::Dense(panel)), _) => panel.matvec_t_into(x32, out),
            (Some(GatherShadowF32::Sparse(vals)), GatherStore::Sparse(csr)) => {
                csr.matvec_t_f32_into(vals, x32, out)
            }
            _ => panic!("gather panel has no f32 shadow"),
        }
    }
}

/// Materialized samples (rows = samples).
pub struct DenseSamples {
    pub x: Mat,
}

impl SampleSet for DenseSamples {
    fn m(&self) -> usize {
        self.x.rows()
    }

    fn d(&self) -> usize {
        self.x.cols()
    }

    fn matvec(&self, v: &[f64], out: &mut [f64]) {
        self.x.matvec_into(v, out);
    }

    fn matvec_t(&self, u: &[f64], out: &mut [f64]) {
        self.x.matvec_t_into(u, out);
    }

    fn matvec_multi(&self, vs: &MultiVec, out: &mut MultiVec) {
        self.x.matvec_multi_into(vs, out);
    }

    fn matvec_t_multi(&self, us: &MultiVec, out: &mut MultiVec) {
        self.x.matvec_t_multi_into(us, out);
    }

    fn gather_rows_into(&self, rows: &[usize], out: &mut GatheredRows) {
        out.sign.clear();
        self.x.gather_rows_into(rows, out.dense_store());
    }

    fn gathered_matvec(&self, g: &GatheredRows, v: &[f64], out: &mut [f64]) {
        match &g.store {
            GatherStore::Dense(panel) => panel.matvec_into(v, out),
            _ => panic!("panel was not gathered from DenseSamples"),
        }
    }

    fn gathered_matvec_t(&self, g: &GatheredRows, u: &[f64], out: &mut [f64]) {
        match &g.store {
            GatherStore::Dense(panel) => panel.matvec_t_into(u, out),
            _ => panic!("panel was not gathered from DenseSamples"),
        }
    }
}

/// The SVEN-constructed sample set, held implicitly.
///
/// With `X ∈ R^{n×p}` (the regression design), `y ∈ R^n`, budget `t`:
/// sample i ∈ [0, p) is column i of `X − y·1ᵀ/t` (class +1) and sample
/// p + i is column i of `X + y·1ᵀ/t` (class −1); both live in R^n (d = n,
/// m = 2p).
pub struct ReducedSamples<'a> {
    pub x: &'a Design,
    pub y: &'a [f64],
    pub t: f64,
    /// Optional one-time f32 shadow of the design: when present, the
    /// `_f32` product hooks run their core products through it and
    /// [`SampleSet::mixed_available`] reports true.
    x32: Option<&'a DesignShadowF32>,
}

impl<'a> ReducedSamples<'a> {
    /// Plain f64 sample operator (every product in full precision).
    pub fn new(x: &'a Design, y: &'a [f64], t: f64) -> Self {
        ReducedSamples { x, y, t, x32: None }
    }

    /// Sample operator with an f32 design shadow attached — the
    /// mixed-precision tier's entry point. The shadow must mirror `x`
    /// (same storage kind and shape; see [`DesignShadowF32::of`]).
    pub fn with_shadow(x: &'a Design, y: &'a [f64], t: f64, x32: &'a DesignShadowF32) -> Self {
        ReducedSamples { x, y, t, x32: Some(x32) }
    }
}

impl ReducedSamples<'_> {
    #[inline]
    fn p(&self) -> usize {
        self.x.cols()
    }
}

impl SampleSet for ReducedSamples<'_> {
    fn m(&self) -> usize {
        2 * self.p()
    }

    fn d(&self) -> usize {
        self.x.rows()
    }

    /// `X̂·v = [Xᵀv − (yᵀv/t)·1 ; Xᵀv + (yᵀv/t)·1]`.
    fn matvec(&self, v: &[f64], out: &mut [f64]) {
        let p = self.p();
        debug_assert_eq!(v.len(), self.d());
        debug_assert_eq!(out.len(), 2 * p);
        let (top, bot) = out.split_at_mut(p);
        self.x.matvec_t_into(v, top);
        let shift = vecops::dot(self.y, v) / self.t;
        for i in 0..p {
            bot[i] = top[i] + shift;
            top[i] -= shift;
        }
    }

    /// `X̂ᵀ·u = X(u₁ + u₂) + (1ᵀu₂ − 1ᵀu₁)/t · y`.
    fn matvec_t(&self, u: &[f64], out: &mut [f64]) {
        let p = self.p();
        debug_assert_eq!(u.len(), 2 * p);
        debug_assert_eq!(out.len(), self.d());
        let (u1, u2) = u.split_at(p);
        let mut sum = vec![0.0; p];
        vecops::add(u1, u2, &mut sum);
        self.x.matvec_into(&sum, out);
        let coeff = (u2.iter().sum::<f64>() - u1.iter().sum::<f64>()) / self.t;
        vecops::axpy(coeff, self.y, out);
    }

    /// Panel form of [`SampleSet::matvec`]: one fused `XᵀV` pass feeds
    /// every column; the per-column shift and top/bottom assembly repeat
    /// the single-RHS operations exactly, so each output column is
    /// bit-identical to the single-RHS call. Delegates to the
    /// per-column-budget kernel [`reduced_matvec_batch`] with this
    /// problem's `t` broadcast — one kernel body serves the single- and
    /// cross-problem cases.
    fn matvec_multi(&self, vs: &MultiVec, out: &mut MultiVec) {
        let ts = vec![self.t; vs.ncols()];
        reduced_matvec_batch(self.x, self.y, &ts, vs, out);
    }

    /// Panel form of [`SampleSet::matvec_t`]; one fused `X·S` pass over
    /// the per-column sums, same bit-identity contract (delegates to
    /// [`reduced_matvec_t_batch`]).
    fn matvec_t_multi(&self, us: &MultiVec, out: &mut MultiVec) {
        let ts = vec![self.t; us.ncols()];
        reduced_matvec_t_batch(self.x, self.y, &ts, us, out);
    }

    /// Gather the selected X̂ rows: row `s < p` is design column `s`
    /// (sign −1 on the `y/t` shift), row `p + s` is design column `s`
    /// (sign +1). The panel holds the bare design columns — dense rows or
    /// a CSC-sliced CSR — and the rank-one correction stays implicit in
    /// the gathered products, so a sparse design gathers in O(Σ nnz(col))
    /// with no densification.
    fn gather_rows_into(&self, rows: &[usize], out: &mut GatheredRows) {
        let p = self.p();
        out.sign.clear();
        out.sign.extend(rows.iter().map(|&s| if s < p { -1.0 } else { 1.0 }));
        let cols: Vec<usize> = rows.iter().map(|&s| if s < p { s } else { s - p }).collect();
        match self.x {
            Design::Dense(m) => m.gather_cols_as_rows_into(&cols, out.dense_store()),
            Design::Sparse { csc, .. } => csc.gather_cols_into(&cols, out.sparse_store()),
        }
    }

    /// `G·v`: panel product plus the shared `yᵀv/t` shift, signed per
    /// row.
    fn gathered_matvec(&self, g: &GatheredRows, v: &[f64], out: &mut [f64]) {
        match &g.store {
            GatherStore::Dense(panel) => panel.matvec_into(v, out),
            GatherStore::Sparse(panel) => panel.matvec_into(v, out),
            GatherStore::Empty => panic!("empty gather panel"),
        }
        let shift = vecops::dot(self.y, v) / self.t;
        for (o, s) in out.iter_mut().zip(&g.sign) {
            *o += s * shift;
        }
    }

    /// `Gᵀ·u`: panel transpose product plus the signed-sum rank-one `y`
    /// correction.
    fn gathered_matvec_t(&self, g: &GatheredRows, u: &[f64], out: &mut [f64]) {
        match &g.store {
            GatherStore::Dense(panel) => panel.matvec_t_into(u, out),
            GatherStore::Sparse(panel) => panel.matvec_t_into(u, out),
            GatherStore::Empty => panic!("empty gather panel"),
        }
        let mut coeff = 0.0;
        for (ui, si) in u.iter().zip(&g.sign) {
            coeff += ui * si;
        }
        vecops::axpy(coeff / self.t, self.y, out);
    }

    fn mixed_available(&self) -> bool {
        self.x32.is_some()
    }

    /// [`SampleSet::matvec`] with the `Xᵀv` core demoted to f32; the
    /// `yᵀv/t` shift and the top/bottom assembly repeat the f64 path
    /// exactly.
    fn matvec_f32(&self, v: &[f64], out: &mut [f64], fbuf: &mut Vec<f32>) {
        let Some(shadow) = self.x32 else {
            return self.matvec(v, out);
        };
        let p = self.p();
        debug_assert_eq!(v.len(), self.d());
        debug_assert_eq!(out.len(), 2 * p);
        let (top, bot) = out.split_at_mut(p);
        vecops_f32::demote(v, fbuf);
        shadow.matvec_t_into(self.x, fbuf, top);
        let shift = vecops::dot(self.y, v) / self.t;
        for i in 0..p {
            bot[i] = top[i] + shift;
            top[i] -= shift;
        }
    }

    /// [`SampleSet::matvec_t`] with the `X·(u₁+u₂)` core demoted to f32;
    /// the column sum and the rank-one `y` correction stay f64.
    fn matvec_t_f32(&self, u: &[f64], out: &mut [f64], fbuf: &mut Vec<f32>) {
        let Some(shadow) = self.x32 else {
            return self.matvec_t(u, out);
        };
        let p = self.p();
        debug_assert_eq!(u.len(), 2 * p);
        debug_assert_eq!(out.len(), self.d());
        let (u1, u2) = u.split_at(p);
        let mut sum = vec![0.0; p];
        vecops::add(u1, u2, &mut sum);
        vecops_f32::demote(&sum, fbuf);
        shadow.matvec_into(self.x, fbuf, out);
        let coeff = (u2.iter().sum::<f64>() - u1.iter().sum::<f64>()) / self.t;
        vecops::axpy(coeff, self.y, out);
    }

    /// [`SampleSet::gathered_matvec`] through the panel's f32 shadow
    /// (falls back to f64 when none is built).
    fn gathered_matvec_f32(
        &self,
        g: &GatheredRows,
        v: &[f64],
        out: &mut [f64],
        fbuf: &mut Vec<f32>,
    ) {
        if self.x32.is_none() || !g.has_shadow() {
            return self.gathered_matvec(g, v, out);
        }
        vecops_f32::demote(v, fbuf);
        g.shadow_matvec_into(fbuf, out);
        let shift = vecops::dot(self.y, v) / self.t;
        for (o, s) in out.iter_mut().zip(&g.sign) {
            *o += s * shift;
        }
    }

    /// [`SampleSet::gathered_matvec_t`] through the panel's f32 shadow.
    fn gathered_matvec_t_f32(
        &self,
        g: &GatheredRows,
        u: &[f64],
        out: &mut [f64],
        fbuf: &mut Vec<f32>,
    ) {
        if self.x32.is_none() || !g.has_shadow() {
            return self.gathered_matvec_t(g, u, out);
        }
        vecops_f32::demote(u, fbuf);
        g.shadow_matvec_t_into(fbuf, out);
        let mut coeff = 0.0;
        for (ui, si) in u.iter().zip(&g.sign) {
            coeff += ui * si;
        }
        vecops::axpy(coeff / self.t, self.y, out);
    }
}

/// Column-batched [`ReducedSamples::matvec`] across *problems*: column
/// `j` is `X̂_{ts[j]} · vs.col(j)` — the same design/response viewed at
/// per-column budgets `ts[j]`, so S neighboring path points share one
/// fused `XᵀV` pass. Column `j` is **bit-identical** to
/// `ReducedSamples { x, y, t: ts[j] }.matvec(vs.col(j))` at any thread
/// count (the shared product keeps the multi-RHS per-column contract;
/// the shift/assembly repeats the single-RHS operations exactly).
pub fn reduced_matvec_batch(
    x: &Design,
    y: &[f64],
    ts: &[f64],
    vs: &MultiVec,
    out: &mut MultiVec,
) {
    let ys = vec![y; ts.len()];
    reduced_matvec_batch_multi(x, &ys, ts, vs, out);
}

/// Column-batched [`ReducedSamples::matvec_t`] across problems; same
/// per-column budget/bit-identity contract as [`reduced_matvec_batch`].
pub fn reduced_matvec_t_batch(
    x: &Design,
    y: &[f64],
    ts: &[f64],
    us: &MultiVec,
    out: &mut MultiVec,
) {
    let ys = vec![y; ts.len()];
    reduced_matvec_t_batch_multi(x, &ys, ts, us, out);
}

/// [`reduced_matvec_batch`] generalized to per-column *responses*:
/// column `j` views the shared design through `(ys[j], ts[j])`, so a
/// batch mixing path points and responses still shares the one fused
/// `XᵀV` pass (the only part that touches `X`). Column `j` stays
/// **bit-identical** to `ReducedSamples { x, y: ys[j], t: ts[j]
/// }.matvec(vs.col(j))` — the `±yᵀv/t` shift is per-column arithmetic
/// either way.
pub fn reduced_matvec_batch_multi(
    x: &Design,
    ys: &[&[f64]],
    ts: &[f64],
    vs: &MultiVec,
    out: &mut MultiVec,
) {
    let p = x.cols();
    let r = vs.ncols();
    debug_assert_eq!(ts.len(), r);
    debug_assert_eq!(ys.len(), r);
    debug_assert_eq!(vs.rows(), x.rows());
    debug_assert_eq!((out.rows(), out.ncols()), (2 * p, r));
    let mut tmp = MultiVec::zeros(p, r);
    x.matvec_t_multi_into(vs, &mut tmp);
    for j in 0..r {
        let shift = vecops::dot(ys[j], vs.col(j)) / ts[j];
        let tcol = tmp.col(j);
        let (top, bot) = out.col_mut(j).split_at_mut(p);
        for i in 0..p {
            bot[i] = tcol[i] + shift;
            top[i] = tcol[i] - shift;
        }
    }
}

/// Per-column-response twin of [`reduced_matvec_t_batch`]; same
/// bit-identity contract as [`reduced_matvec_batch_multi`].
pub fn reduced_matvec_t_batch_multi(
    x: &Design,
    ys: &[&[f64]],
    ts: &[f64],
    us: &MultiVec,
    out: &mut MultiVec,
) {
    let p = x.cols();
    let r = us.ncols();
    debug_assert_eq!(ts.len(), r);
    debug_assert_eq!(ys.len(), r);
    debug_assert_eq!(us.rows(), 2 * p);
    debug_assert_eq!((out.rows(), out.ncols()), (x.rows(), r));
    let mut sums = MultiVec::zeros(p, r);
    for j in 0..r {
        let (u1, u2) = us.col(j).split_at(p);
        vecops::add(u1, u2, sums.col_mut(j));
    }
    x.matvec_multi_into(&sums, out);
    for j in 0..r {
        let (u1, u2) = us.col(j).split_at(p);
        let coeff = (u2.iter().sum::<f64>() - u1.iter().sum::<f64>()) / ts[j];
        vecops::axpy(coeff, ys[j], out.col_mut(j));
    }
}

/// Materialize the SVEN sample matrix (m × d) — used by tests to validate
/// [`ReducedSamples`] and by callers that prefer dense (small problems).
pub fn materialize_reduction(x: &Mat, y: &[f64], t: f64) -> Mat {
    let (n, p) = (x.rows(), x.cols());
    let mut out = Mat::zeros(2 * p, n);
    for i in 0..p {
        for r in 0..n {
            let xc = x.get(r, i);
            out.set(i, r, xc - y[r] / t);
            out.set(p + i, r, xc + y[r] / t);
        }
    }
    out
}

/// Labels of the SVEN construction: +1 for the first p samples, −1 after.
pub fn reduction_labels(p: usize) -> Vec<f64> {
    let mut y = vec![1.0; 2 * p];
    for v in y[p..].iter_mut() {
        *v = -1.0;
    }
    y
}

/// The gram matrix `K = ẐᵀẐ` of the SVEN construction
/// (`Ẑ = (ŷ₁x̂₁ … ŷₘx̂ₘ)`), built from `XᵀX` blocks in O(p²) after one
/// O(np²) product instead of the naive O(n(2p)²):
///
/// ```text
/// K = [  G₁₁  −G₁₂ ]      G₁₁ = G − s(v1ᵀ+1vᵀ) + s²c·11ᵀ
///     [ −G₁₂ᵀ  G₂₂ ]      G₂₂ = G + s(v1ᵀ+1vᵀ) + s²c·11ᵀ
///                         G₁₂ = G + s·v1ᵀ − s·1vᵀ − s²c·11ᵀ
/// ```
/// with `G = XᵀX`, `v = Xᵀy`, `c = yᵀy`, `s = 1/t`.
pub fn reduction_gram(x: &Mat, y: &[f64], t: f64) -> Mat {
    let g = x.gram_t(); // XᵀX, p×p (blocked parallel kernel)
    let v = x.matvec_t(y); // Xᵀy
    let c = vecops::norm2_sq(y);
    let s = 1.0 / t;
    let mut k = Mat::zeros(2 * x.cols(), 2 * x.cols());
    assemble_reduction_gram(&g, &v, s, s * s * c, &mut k);
    k
}

/// Row-parallel assembly of `K(t)` from the t-independent blocks
/// (`G = XᵀX`, `v = Xᵀy`, `s = 1/t`, `s2c = s²·yᵀy`). Each output row is
/// an independent elementwise formula, so the fan-out over the scoped
/// pool is embarrassingly parallel and bit-stable across thread counts.
/// Shared with the dual backend's cached-path `gram_at`.
pub(crate) fn assemble_reduction_gram(g: &Mat, v: &[f64], s: f64, s2c: f64, k: &mut Mat) {
    let p = g.rows();
    let m = 2 * p;
    debug_assert_eq!((k.rows(), k.cols()), (m, m));
    let nt = if m * m < 1 << 14 { 1 } else { crate::util::parallel::effective_threads() };
    let rows: Vec<&mut [f64]> = k.data_mut().chunks_mut(m).collect();
    crate::util::parallel::parallel_items(nt, rows, |r, row| {
        if r < p {
            // Row i of [G₁₁, −G₁₂]:
            //   K[i, j]     = G[i,j] − s(vᵢ+vⱼ) + s²c
            //   K[i, p+j]   = −(G[i,j] + s·vᵢ − s·vⱼ − s²c)
            let i = r;
            let gi = g.row(i);
            for j in 0..p {
                let gij = gi[j];
                row[j] = gij - s * (v[i] + v[j]) + s2c;
                row[p + j] = -(gij + s * v[i] - s * v[j] - s2c);
            }
        } else {
            // Row p+a of [−G₁₂ᵀ, G₂₂] (G symmetric ⇒ G₁₂ᵀ[a,b] = G₁₂[b,a]):
            //   K[p+a, b]   = −(G[a,b] + s·v_b − s·v_a − s²c)
            //   K[p+a, p+b] = G[a,b] + s(v_a+v_b) + s²c
            let a = r - p;
            let ga = g.row(a);
            for b in 0..p {
                let gab = ga[b];
                row[b] = -(gab + s * v[b] - s * v[a] - s2c);
                row[p + b] = gab + s * (v[a] + v[b]) + s2c;
            }
        }
    });
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;

    fn setup(n: usize, p: usize, seed: u64) -> (Mat, Vec<f64>, f64) {
        let mut rng = Rng::seed_from(seed);
        let x = Mat::from_fn(n, p, |_, _| rng.normal());
        let y: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
        (x, y, 0.7)
    }

    #[test]
    fn reduced_matvec_matches_materialized() {
        let (x, y, t) = setup(9, 6, 121);
        let d: Design = x.clone().into();
        let red = ReducedSamples::new(&d, &y, t);
        let dense = materialize_reduction(&x, &y, t);
        let mut rng = Rng::seed_from(122);
        let v: Vec<f64> = (0..9).map(|_| rng.normal()).collect();
        let mut out_red = vec![0.0; 12];
        red.matvec(&v, &mut out_red);
        let out_dense = dense.matvec(&v);
        for i in 0..12 {
            assert!((out_red[i] - out_dense[i]).abs() < 1e-10, "i={i}");
        }
    }

    #[test]
    fn reduced_matvec_t_matches_materialized() {
        let (x, y, t) = setup(7, 5, 123);
        let d: Design = x.clone().into();
        let red = ReducedSamples::new(&d, &y, t);
        let dense = materialize_reduction(&x, &y, t);
        let mut rng = Rng::seed_from(124);
        let u: Vec<f64> = (0..10).map(|_| rng.normal()).collect();
        let mut out_red = vec![0.0; 7];
        red.matvec_t(&u, &mut out_red);
        let out_dense = dense.matvec_t(&u);
        for i in 0..7 {
            assert!((out_red[i] - out_dense[i]).abs() < 1e-10, "i={i}");
        }
    }

    #[test]
    fn gram_matches_materialized() {
        let (x, y, t) = setup(8, 4, 125);
        let k = reduction_gram(&x, &y, t);
        // naive: Ẑ columns are ŷ_i x̂_i; K = ẐᵀẐ
        let xhat = materialize_reduction(&x, &y, t); // rows = samples
        let labels = reduction_labels(4);
        let m = 8usize;
        for i in 0..m {
            for j in 0..m {
                let kij: f64 = labels[i]
                    * labels[j]
                    * vecops::dot(xhat.row(i), xhat.row(j));
                assert!(
                    (k.get(i, j) - kij).abs() < 1e-9,
                    "({i},{j}): {} vs {kij}",
                    k.get(i, j)
                );
            }
        }
    }

    #[test]
    fn labels_shape() {
        let l = reduction_labels(3);
        assert_eq!(l, vec![1.0, 1.0, 1.0, -1.0, -1.0, -1.0]);
    }

    #[test]
    fn reduced_multi_rhs_columns_bit_match_single_rhs() {
        let (x, y, t) = setup(10, 7, 127);
        let d: Design = x.clone().into();
        let red = ReducedSamples::new(&d, &y, t);
        let mut rng = Rng::seed_from(128);
        let vs = MultiVec::from_fn(10, 3, |_, _| rng.normal());
        let us = MultiVec::from_fn(14, 3, |_, _| rng.normal());
        let mut outs = MultiVec::zeros(14, 3);
        red.matvec_multi(&vs, &mut outs);
        let mut outs_t = MultiVec::zeros(10, 3);
        red.matvec_t_multi(&us, &mut outs_t);
        for j in 0..3 {
            let mut single = vec![0.0; 14];
            red.matvec(vs.col(j), &mut single);
            for (a, b) in single.iter().zip(outs.col(j)) {
                assert_eq!(a.to_bits(), b.to_bits(), "matvec col {j}");
            }
            let mut single_t = vec![0.0; 10];
            red.matvec_t(us.col(j), &mut single_t);
            for (a, b) in single_t.iter().zip(outs_t.col(j)) {
                assert_eq!(a.to_bits(), b.to_bits(), "matvec_t col {j}");
            }
        }
    }

    /// Gathered-panel products must agree with the materialized rows for
    /// both dense and sparse designs (the shrinking Newton's invariant).
    #[test]
    fn gathered_products_match_materialized_rows() {
        let mut rng = Rng::seed_from(129);
        let x = Mat::from_fn(9, 6, |_, _| {
            if rng.bernoulli(0.5) {
                rng.normal()
            } else {
                0.0
            }
        });
        let y: Vec<f64> = (0..9).map(|_| rng.normal()).collect();
        let t = 0.8;
        let dense_design: Design = x.clone().into();
        let sparse_design: Design = crate::linalg::Csr::from_dense(&x, 0.0).into();
        let full = materialize_reduction(&x, &y, t);
        let rows = [1usize, 4, 7, 10, 11];
        let v: Vec<f64> = (0..9).map(|_| rng.normal()).collect();
        let u: Vec<f64> = (0..rows.len()).map(|_| rng.normal()).collect();
        for design in [&dense_design, &sparse_design] {
            let red = ReducedSamples::new(design, &y, t);
            let mut panel = GatheredRows::new();
            red.gather_rows_into(&rows, &mut panel);
            assert_eq!(panel.m(), rows.len());
            let mut got = vec![0.0; rows.len()];
            red.gathered_matvec(&panel, &v, &mut got);
            for (s, &r) in rows.iter().enumerate() {
                let expect = vecops::dot(full.row(r), &v);
                assert!(
                    (got[s] - expect).abs() < 1e-10,
                    "matvec s={s} sparse={}",
                    design.is_sparse()
                );
            }
            let mut got_t = vec![0.0; 9];
            red.gathered_matvec_t(&panel, &u, &mut got_t);
            let mut expect_t = vec![0.0; 9];
            for (s, &r) in rows.iter().enumerate() {
                vecops::axpy(u[s], full.row(r), &mut expect_t);
            }
            for i in 0..9 {
                assert!(
                    (got_t[i] - expect_t[i]).abs() < 1e-10,
                    "matvec_t i={i} sparse={}",
                    design.is_sparse()
                );
            }
        }
    }

    /// The per-column-budget batch kernels must reproduce the
    /// corresponding single-problem operators bit-for-bit — the
    /// cross-problem fusion contract of the batched Newton.
    #[test]
    fn batch_kernels_bit_match_per_problem_ops() {
        let (x, y, _) = setup(9, 6, 141);
        for design in [
            Design::from(x.clone()),
            Design::from(crate::linalg::Csr::from_dense(&x, 0.0)),
        ] {
            let ts = [0.4, 0.9, 2.5];
            let mut rng = Rng::seed_from(142);
            let vs = MultiVec::from_fn(9, 3, |_, _| rng.normal());
            let us = MultiVec::from_fn(12, 3, |_, _| rng.normal());
            let mut out = MultiVec::zeros(12, 3);
            reduced_matvec_batch(&design, &y, &ts, &vs, &mut out);
            let mut out_t = MultiVec::zeros(9, 3);
            reduced_matvec_t_batch(&design, &y, &ts, &us, &mut out_t);
            for j in 0..3 {
                let red = ReducedSamples::new(&design, &y, ts[j]);
                let mut single = vec![0.0; 12];
                red.matvec(vs.col(j), &mut single);
                for (a, b) in single.iter().zip(out.col(j)) {
                    assert_eq!(a.to_bits(), b.to_bits(), "matvec col {j}");
                }
                let mut single_t = vec![0.0; 9];
                red.matvec_t(us.col(j), &mut single_t);
                for (a, b) in single_t.iter().zip(out_t.col(j)) {
                    assert_eq!(a.to_bits(), b.to_bits(), "matvec_t col {j}");
                }
            }
        }
    }

    /// The per-column-*response* batch kernels must reproduce the
    /// corresponding single-problem operators bit-for-bit — the
    /// cross-response fusion contract of the multi-response Newton.
    #[test]
    fn multi_response_batch_kernels_bit_match_per_problem_ops() {
        let (x, _, _) = setup(9, 6, 151);
        let mut rng = Rng::seed_from(152);
        let responses: Vec<Vec<f64>> =
            (0..3).map(|_| (0..9).map(|_| rng.normal()).collect()).collect();
        for design in [
            Design::from(x.clone()),
            Design::from(crate::linalg::Csr::from_dense(&x, 0.0)),
        ] {
            let ts = [0.5, 1.3, 0.8];
            let ys: Vec<&[f64]> = responses.iter().map(Vec::as_slice).collect();
            let vs = MultiVec::from_fn(9, 3, |_, _| rng.normal());
            let us = MultiVec::from_fn(12, 3, |_, _| rng.normal());
            let mut out = MultiVec::zeros(12, 3);
            reduced_matvec_batch_multi(&design, &ys, &ts, &vs, &mut out);
            let mut out_t = MultiVec::zeros(9, 3);
            reduced_matvec_t_batch_multi(&design, &ys, &ts, &us, &mut out_t);
            for j in 0..3 {
                let red = ReducedSamples::new(&design, &responses[j], ts[j]);
                let mut single = vec![0.0; 12];
                red.matvec(vs.col(j), &mut single);
                for (a, b) in single.iter().zip(out.col(j)) {
                    assert_eq!(a.to_bits(), b.to_bits(), "matvec col {j}");
                }
                let mut single_t = vec![0.0; 9];
                red.matvec_t(us.col(j), &mut single_t);
                for (a, b) in single_t.iter().zip(out_t.col(j)) {
                    assert_eq!(a.to_bits(), b.to_bits(), "matvec_t col {j}");
                }
            }
        }
    }

    #[test]
    fn dense_samples_gather_and_multi() {
        let mut rng = Rng::seed_from(130);
        let x = Mat::from_fn(8, 5, |_, _| rng.normal());
        let s = DenseSamples { x: x.clone() };
        let vs = MultiVec::from_fn(5, 2, |_, _| rng.normal());
        let mut out = MultiVec::zeros(8, 2);
        s.matvec_multi(&vs, &mut out);
        for j in 0..2 {
            let mut single = vec![0.0; 8];
            s.matvec(vs.col(j), &mut single);
            for (a, b) in single.iter().zip(out.col(j)) {
                assert_eq!(a.to_bits(), b.to_bits());
            }
        }
        let rows = [6usize, 1];
        let mut panel = GatheredRows::new();
        s.gather_rows_into(&rows, &mut panel);
        let v: Vec<f64> = (0..5).map(|_| rng.normal()).collect();
        let mut got = vec![0.0; 2];
        s.gathered_matvec(&panel, &v, &mut got);
        for (s_i, &r) in rows.iter().enumerate() {
            let expect = vecops::dot(x.row(r), &v);
            assert!((got[s_i] - expect).abs() < 1e-12);
        }
    }

    #[test]
    fn reduced_ops_over_sparse_design_match_materialized() {
        // The SVEN sample operator over a sparse Design must agree with
        // the densified construction — the primal solver's O(nnz) path.
        let mut rng = Rng::seed_from(126);
        let x = Mat::from_fn(11, 7, |_, _| {
            if rng.bernoulli(0.35) {
                rng.normal()
            } else {
                0.0
            }
        });
        let y: Vec<f64> = (0..11).map(|_| rng.normal()).collect();
        let t = 0.9;
        let d: Design = crate::linalg::Csr::from_dense(&x, 0.0).into();
        assert!(d.is_sparse());
        let red = ReducedSamples::new(&d, &y, t);
        let dense = materialize_reduction(&x, &y, t);
        let v: Vec<f64> = (0..11).map(|_| rng.normal()).collect();
        let mut out = vec![0.0; 14];
        red.matvec(&v, &mut out);
        let expect = dense.matvec(&v);
        for i in 0..14 {
            assert!((out[i] - expect[i]).abs() < 1e-10, "matvec {i}");
        }
        let u: Vec<f64> = (0..14).map(|_| rng.normal()).collect();
        let mut out_t = vec![0.0; 11];
        red.matvec_t(&u, &mut out_t);
        let expect_t = dense.matvec_t(&u);
        for i in 0..11 {
            assert!((out_t[i] - expect_t[i]).abs() < 1e-10, "matvec_t {i}");
        }
    }

    fn rel_dev(a: &[f64], b: &[f64]) -> f64 {
        let num = a.iter().zip(b).map(|(x, y)| (x - y).powi(2)).sum::<f64>().sqrt();
        num / vecops::norm2(b).max(1e-30)
    }

    /// The `_f32` hooks must track the f64 products to single precision
    /// for both dense and sparse designs — full and gathered forms.
    #[test]
    fn f32_hooks_track_f64_products_to_single_precision() {
        let mut rng = Rng::seed_from(143);
        let x = Mat::from_fn(30, 12, |_, _| {
            if rng.bernoulli(0.6) {
                rng.normal()
            } else {
                0.0
            }
        });
        let y: Vec<f64> = (0..30).map(|_| rng.normal()).collect();
        let t = 0.8;
        let v: Vec<f64> = (0..30).map(|_| rng.normal()).collect();
        let u: Vec<f64> = (0..24).map(|_| rng.normal()).collect();
        let rows = [0usize, 3, 9, 14, 20, 23];
        let ug: Vec<f64> = (0..rows.len()).map(|_| rng.normal()).collect();
        for design in [
            Design::from(x.clone()),
            Design::from(crate::linalg::Csr::from_dense(&x, 0.0)),
        ] {
            let shadow = DesignShadowF32::of(&design);
            let red = ReducedSamples::with_shadow(&design, &y, t, &shadow);
            assert!(red.mixed_available());
            let mut fbuf = Vec::new();
            let mut f64_out = vec![0.0; 24];
            let mut f32_out = vec![0.0; 24];
            red.matvec(&v, &mut f64_out);
            red.matvec_f32(&v, &mut f32_out, &mut fbuf);
            assert!(rel_dev(&f32_out, &f64_out) < 1e-4, "matvec sparse={}", design.is_sparse());
            let mut f64_t = vec![0.0; 30];
            let mut f32_t = vec![0.0; 30];
            red.matvec_t(&u, &mut f64_t);
            red.matvec_t_f32(&u, &mut f32_t, &mut fbuf);
            assert!(rel_dev(&f32_t, &f64_t) < 1e-4, "matvec_t sparse={}", design.is_sparse());
            let mut panel = GatheredRows::new();
            red.gather_rows_into(&rows, &mut panel);
            assert!(!panel.has_shadow());
            panel.build_f32_shadow();
            assert!(panel.has_shadow() && panel.shadow_bytes() > 0);
            let mut g64 = vec![0.0; rows.len()];
            let mut g32 = vec![0.0; rows.len()];
            red.gathered_matvec(&panel, &v, &mut g64);
            red.gathered_matvec_f32(&panel, &v, &mut g32, &mut fbuf);
            assert!(rel_dev(&g32, &g64) < 1e-4, "gathered sparse={}", design.is_sparse());
            let mut gt64 = vec![0.0; 30];
            let mut gt32 = vec![0.0; 30];
            red.gathered_matvec_t(&panel, &ug, &mut gt64);
            red.gathered_matvec_t_f32(&panel, &ug, &mut gt32, &mut fbuf);
            assert!(rel_dev(&gt32, &gt64) < 1e-4, "gathered_t sparse={}", design.is_sparse());
            // Re-gathering invalidates the shadow so it can never go
            // stale.
            red.gather_rows_into(&rows[..3], &mut panel);
            assert!(!panel.has_shadow());
        }
    }

    /// Without a shadow the hooks are the f64 products, bit for bit —
    /// what keeps every existing caller's behavior untouched under the
    /// mixed-precision CI leg.
    #[test]
    fn f32_hooks_without_shadow_are_exact_f64() {
        let (x, y, t) = setup(12, 8, 145);
        let d: Design = x.clone().into();
        let red = ReducedSamples::new(&d, &y, t);
        assert!(!red.mixed_available());
        let mut rng = Rng::seed_from(146);
        let v: Vec<f64> = (0..12).map(|_| rng.normal()).collect();
        let mut fbuf = Vec::new();
        let mut a = vec![0.0; 16];
        let mut b = vec![0.0; 16];
        red.matvec(&v, &mut a);
        red.matvec_f32(&v, &mut b, &mut fbuf);
        for i in 0..16 {
            assert_eq!(a[i].to_bits(), b[i].to_bits(), "i={i}");
        }
    }

    /// The f32 hook outputs must be bit-stable across thread counts
    /// (the crate determinism contract extends to the mixed tier).
    #[test]
    fn f32_hooks_bit_stable_across_threads() {
        let mut rng = Rng::seed_from(147);
        let n = 900; // large enough to cross the parallel gates
        let p = 17;
        let x = Mat::from_fn(n, p, |_, _| rng.normal());
        let y: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
        let d: Design = x.into();
        let shadow = DesignShadowF32::of(&d);
        let red = ReducedSamples::with_shadow(&d, &y, 0.9, &shadow);
        let v: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
        let u: Vec<f64> = (0..2 * p).map(|_| rng.normal()).collect();
        let mut fbuf = Vec::new();
        let run = |nt: usize, fbuf: &mut Vec<f32>| {
            crate::util::parallel::with_parallelism(
                crate::util::parallel::Parallelism::Fixed(nt),
                || {
                    let mut mv = vec![0.0; 2 * p];
                    red.matvec_f32(&v, &mut mv, fbuf);
                    let mut mt = vec![0.0; n];
                    red.matvec_t_f32(&u, &mut mt, fbuf);
                    (mv, mt)
                },
            )
        };
        let (mv1, mt1) = run(1, &mut fbuf);
        for nt in [2, 5, 8] {
            let (mvn, mtn) = run(nt, &mut fbuf);
            assert!(
                mv1.iter().zip(&mvn).all(|(a, b)| a.to_bits() == b.to_bits()),
                "matvec_f32 nt={nt}"
            );
            assert!(
                mt1.iter().zip(&mtn).all(|(a, b)| a.to_bits() == b.to_bits()),
                "matvec_t_f32 nt={nt}"
            );
        }
    }
}
