//! Squared-hinge-loss linear SVM **without bias** — the reduction target
//! of the paper (its §2 eq. 2/3), solved the way Chapelle (2007) does:
//!
//! - **Primal** ([`primal_newton`]): Newton steps on
//!   `½‖w‖² + C·Σ max(0, 1 − ŷᵢ wᵀx̂ᵢ)²`, with the Newton system solved by
//!   conjugate gradients using Hessian-vector products
//!   `v ↦ v + 2C·X̂ᵀ(sv ⊙ (X̂v))` — two matvecs, no Hessian materialized.
//!   Used when the weight dimension d is the small side (2p > n in the
//!   reduction).
//! - **Dual** ([`dual_newton`]): active-set Newton on the non-negative QP
//!   `min αᵀKα + 1/(2C)·‖α‖² − 2·1ᵀα, α ≥ 0` over the gram matrix
//!   `K = ẐᵀẐ` — the kernelized route, used when samples are the small
//!   side (n ≥ 2p), where K can be cached across path points.
//!
//! Both return the dual variables `α` (the quantity SVEN's back-map
//! needs); at the optimum `α_i = 2C·max(0, 1 − ŷᵢ wᵀx̂ᵢ)`, and any
//! positive rescaling of α leaves the back-map unchanged.

pub mod dual;
pub mod primal;
pub mod samples;

/// Intra-solve control: the coordinator's deadline threaded down to
/// Newton-iteration granularity. `expired` is polled at primal Newton
/// *round* and dual *pivot* boundaries; when it fires the solver
/// abandons its half-converged members and returns them flagged
/// `aborted`, so a sweep can cut at the last fully *completed* grid
/// point instead of blowing the deadline by an entire solve. Passing
/// `None` is the uncontrolled fast path.
pub struct SolveCtl<'a> {
    expired: &'a dyn Fn() -> bool,
}

impl<'a> SolveCtl<'a> {
    pub fn new(expired: &'a dyn Fn() -> bool) -> Self {
        SolveCtl { expired }
    }

    /// Poll the deadline — cheap, once per round/pivot, never inside
    /// the fused kernels.
    pub fn expired(&self) -> bool {
        (self.expired)()
    }
}

pub use dual::{dual_newton, DualOptions, DualResult};
pub use primal::{
    primal_newton, primal_newton_batch, primal_newton_batch_ys, PrimalBatchPoint,
    PrimalBatchStats, PrimalOptions, PrimalResult,
};
pub use samples::{
    reduced_matvec_batch, reduced_matvec_batch_multi, reduced_matvec_t_batch,
    reduced_matvec_t_batch_multi, DenseSamples, GatheredRows, ReducedSamples, SampleSet,
};
