//! Dual active-set Newton for the squared-hinge SVM (paper eq. 3):
//!
//! ```text
//! min_{α ≥ 0}  αᵀKα + 1/(2C)·‖α‖² − 2·1ᵀα,      K = ẐᵀẐ
//! ```
//!
//! On a fixed free set F the problem is an unconstrained SPD solve
//! `(K_FF + I/(2C))·α_F = 1`; the active-set loop (Lawson–Hanson NNLS
//! structure, block pivoting for speed) moves variables between the bound
//! and free sets until the KKT conditions hold:
//! `α_i > 0 ⇒ g_i = 0`, `α_i = 0 ⇒ g_i ≥ 0` with
//! `g = 2Kα + α/C − 2`.
//!
//! The data enters only through K, so when `n ≥ 2p` the caller computes K
//! once in O(n·p²) (see [`super::samples::reduction_gram`]) and every
//! subsequent solve is dimension-independent — the effect that makes the
//! paper's Figure-3 SVEN timings flat in t.

use crate::linalg::{Cholesky, Mat};

/// Options for [`dual_newton`].
#[derive(Clone, Debug)]
pub struct DualOptions {
    /// KKT tolerance on the gradient.
    pub tol: f64,
    /// Cap on active-set changes.
    pub max_pivots: usize,
}

impl Default for DualOptions {
    fn default() -> Self {
        DualOptions { tol: 1e-10, max_pivots: 10_000 }
    }
}

#[derive(Clone, Debug)]
pub struct DualResult {
    pub alpha: Vec<f64>,
    pub pivots: usize,
    pub converged: bool,
    /// Dual objective at `alpha`.
    pub objective: f64,
    /// The intra-solve deadline fired at a pivot boundary and the solve
    /// stopped on a half-converged iterate — never serve this `alpha`.
    pub aborted: bool,
    /// A non-finite value (NaN C, poisoned gram, non-finite gradient or
    /// objective) tripped the numerical-health guard; the message names
    /// what broke. Never serve this `alpha`.
    pub broken: Option<String>,
}

/// Gradient `g = 2Kα + α/C − 2` (only for entries in `idx` if given).
fn gradient(k: &Mat, alpha: &[f64], c: f64, out: &mut [f64]) {
    k.matvec_into(alpha, out);
    for i in 0..out.len() {
        out[i] = 2.0 * out[i] + alpha[i] / c - 2.0;
    }
}

fn objective(k: &Mat, alpha: &[f64], c: f64) -> f64 {
    let ka = k.matvec(alpha);
    let mut obj = 0.0;
    for i in 0..alpha.len() {
        obj += alpha[i] * ka[i] + alpha[i] * alpha[i] / (2.0 * c) - 2.0 * alpha[i];
    }
    obj
}

/// Solve the non-negative dual QP given the gram matrix `K` (m × m).
/// `warm` seeds the free set (entries > 0). `ctl` (when given) is
/// polled at pivot boundaries: an expired deadline aborts the solve and
/// flags the result instead of finishing the active-set walk.
pub fn dual_newton(
    k: &Mat,
    c: f64,
    opts: &DualOptions,
    warm: Option<&[f64]>,
    ctl: Option<&super::SolveCtl>,
) -> DualResult {
    let m = k.rows();
    assert_eq!(k.cols(), m);
    let mut alpha = vec![0.0; m];
    if !c.is_finite() {
        return DualResult {
            alpha,
            pivots: 0,
            converged: false,
            objective: f64::NAN,
            aborted: false,
            broken: Some(format!("non-finite regularisation parameter C = {c}")),
        };
    }
    let mut free: Vec<bool> = vec![false; m];
    if let Some(w) = warm {
        assert_eq!(w.len(), m);
        for i in 0..m {
            if w[i] > 0.0 {
                free[i] = true;
            }
        }
    }
    // If cold, start from the steepest-descent seed: all gradients are −2
    // at α = 0, so every variable is a candidate; pick the best single one
    // to avoid factorizing the full K immediately.
    if free.iter().all(|f| !f) {
        let mut best = 0usize;
        let mut best_k = f64::INFINITY;
        for i in 0..m {
            let kii = k.get(i, i) + 1.0 / (2.0 * c);
            // unconstrained single-variable optimum value: −1/kii
            if kii < best_k {
                best_k = kii;
                best = i;
            }
        }
        free[best] = true;
    }

    let mut g = vec![0.0; m];
    let mut pivots = 0usize;
    let mut converged = false;
    let mut aborted = false;
    let mut broken: Option<String> = None;

    while pivots < opts.max_pivots {
        if ctl.is_some_and(|c| c.expired()) {
            // Deadline at pivot granularity: abandon the half-converged
            // iterate — the caller serves the last completed grid point.
            aborted = true;
            break;
        }
        // ---- solve equality-constrained subproblem on F -----------------
        let idx: Vec<usize> = (0..m).filter(|&i| free[i]).collect();
        if idx.is_empty() {
            break;
        }
        let nf = idx.len();
        // Gather K_FF + I/(2C) row-parallel: each output row reads one
        // row of K through the free-index map (disjoint writes, so the
        // fan-out is deterministic for any worker count).
        let mut kff = Mat::zeros(nf, nf);
        {
            let nt = if nf * nf < 1 << 14 {
                1
            } else {
                crate::util::parallel::effective_threads()
            };
            let idx_ref = &idx;
            let rows: Vec<&mut [f64]> = kff.data_mut().chunks_mut(nf).collect();
            crate::util::parallel::parallel_items(nt, rows, |a, row| {
                let krow = k.row(idx_ref[a]);
                for (b, rv) in row.iter_mut().enumerate() {
                    *rv = krow[idx_ref[b]];
                }
                row[a] += 1.0 / (2.0 * c);
            });
        }
        let rhs = vec![1.0; nf];
        let sol = match Cholesky::factor_ridged(&kff, 1e-12, 8) {
            Ok(ch) => ch.solve(&rhs),
            Err(_) => {
                // Singular free set (warm seeding can activate both twins
                // α⁺_j/α⁻_j whose kernel columns are anti-correlated):
                // escape with one projected gradient step and rebuild the
                // free set — never exit on a non-KKT iterate.
                gradient(k, &alpha, c, &mut g);
                let lip: f64 = (0..m).map(|i| k.get(i, i)).fold(0.0, f64::max)
                    * 2.0
                    * m as f64
                    + 1.0 / c;
                for i in 0..m {
                    alpha[i] = (alpha[i] - g[i] / lip).max(0.0);
                    free[i] = alpha[i] > 0.0;
                }
                pivots += 1;
                continue;
            }
        };

        // ---- feasibility: clip along the segment α_F → sol --------------
        if sol.iter().all(|v| *v >= 0.0) {
            for (a, &i) in idx.iter().enumerate() {
                alpha[i] = sol[a];
            }
        } else {
            // Largest feasible step along α_F → sol, then drop only the
            // *blocking* variables (those pushed negative). Dropping every
            // α ≤ 0 would, for a zero warm iterate (θ = 0), empty the
            // whole free set and strand the solver at α = 0.
            let mut theta = 1.0f64;
            for (a, &i) in idx.iter().enumerate() {
                if sol[a] < 0.0 {
                    let step = alpha[i] / (alpha[i] - sol[a]);
                    theta = theta.min(step);
                }
            }
            for (a, &i) in idx.iter().enumerate() {
                alpha[i] += theta * (sol[a] - alpha[i]);
                if sol[a] < 0.0 && alpha[i] <= 1e-14 {
                    alpha[i] = 0.0;
                    free[i] = false;
                } else if alpha[i] < 0.0 {
                    alpha[i] = 0.0;
                }
            }
            pivots += 1;
            continue;
        }

        // ---- KKT check -----------------------------------------------
        gradient(k, &alpha, c, &mut g);
        if g.iter().any(|v| !v.is_finite()) {
            // A poisoned gram row or α went non-finite. `f64::max` folds
            // would silently drop the NaN (max returns the non-NaN
            // operand), so check entries explicitly — then flag and stop
            // before another pivot launders the NaN into a "converged"
            // iterate.
            broken = Some("non-finite KKT gradient".into());
            break;
        }
        let gscale = 1.0f64.max(g.iter().fold(0.0f64, |a, v| a.max(v.abs())));
        let mut worst = -opts.tol * gscale;
        let mut worst_i = None;
        for i in 0..m {
            if !free[i] && g[i] < worst {
                worst = g[i];
                worst_i = Some(i);
            }
        }
        // Free-variable residual: the Cholesky solve makes it zero in
        // exact arithmetic, but a ridged fallback on a near-singular
        // free set (e.g. both twins α⁺_j and α⁻_j free — their kernel
        // columns are strongly anti-correlated) leaves it large. Checking
        // only bound variables would then declare FALSE convergence.
        let free_resid = (0..m)
            .filter(|&i| free[i])
            .map(|i| g[i].abs())
            .fold(0.0f64, f64::max);
        match worst_i {
            Some(i) => {
                free[i] = true;
                pivots += 1;
            }
            None if free_resid <= 1e-7 * gscale => {
                if std::env::var("SVEN_DUAL_DEBUG").is_ok() {
                    eprintln!(
                        "[dual] exit pivots={pivots} nfree={} free_resid={free_resid:.3e} gscale={gscale:.3e} asum={:.3e}",
                        free.iter().filter(|f| **f).count(),
                        alpha.iter().sum::<f64>()
                    );
                }
                converged = true;
                break;
            }
            None => {
                // Stuck on a degenerate free set: take one projected
                // gradient step (guaranteed descent) and rebuild the free
                // set from the moved iterate.
                let lip: f64 = (0..m).map(|i| k.get(i, i)).fold(0.0, f64::max) * 2.0
                    * m as f64
                    + 1.0 / c;
                for i in 0..m {
                    alpha[i] = (alpha[i] - g[i] / lip).max(0.0);
                    free[i] = alpha[i] > 0.0;
                }
                pivots += 1;
            }
        }
    }

    let obj = objective(k, &alpha, c);
    if broken.is_none() && !aborted && (!obj.is_finite() || alpha.iter().any(|a| !a.is_finite()))
    {
        broken = Some("non-finite dual objective or iterate".into());
        converged = false;
    }
    DualResult { alpha, pivots, converged, objective: obj, aborted, broken }
}

#[cfg(test)]
mod tests {
    use super::*;
    use super::super::primal::{primal_newton, PrimalOptions};
    use super::super::samples::{DenseSamples, SampleSet};
    use crate::rng::Rng;

    /// Random binary classification set; returns (samples, labels, K).
    fn random_problem(m: usize, d: usize, seed: u64) -> (DenseSamples, Vec<f64>, Mat) {
        let mut rng = Rng::seed_from(seed);
        let x = Mat::from_fn(m, d, |_, _| rng.normal());
        let y: Vec<f64> = (0..m).map(|i| if i % 2 == 0 { 1.0 } else { -1.0 }).collect();
        // K_ij = y_i y_j x_i·x_j
        let mut k = Mat::zeros(m, m);
        for i in 0..m {
            for j in 0..m {
                let dot: f64 = (0..d).map(|q| x.get(i, q) * x.get(j, q)).sum();
                k.set(i, j, y[i] * y[j] * dot);
            }
        }
        (DenseSamples { x }, y, k)
    }

    #[test]
    fn kkt_holds_at_solution() {
        let (_, _, k) = random_problem(14, 5, 141);
        let c = 1.3;
        let r = dual_newton(&k, c, &DualOptions::default(), None, None);
        assert!(r.converged);
        let mut g = vec![0.0; 14];
        gradient(&k, &r.alpha, c, &mut g);
        for i in 0..14 {
            if r.alpha[i] > 1e-10 {
                assert!(g[i].abs() < 1e-7, "free i={i} g={}", g[i]);
            } else {
                assert!(g[i] > -1e-7, "bound i={i} g={}", g[i]);
            }
        }
    }

    #[test]
    fn matches_primal_solution() {
        let (s, y, k) = random_problem(12, 4, 142);
        let c = 2.0;
        let dual = dual_newton(&k, c, &DualOptions::default(), None, None);
        let primal = primal_newton(&s, &y, c, &PrimalOptions::default(), None);
        // w = Σ ŷᵢ αᵢ x̂ᵢ must match the primal w
        let ya: Vec<f64> = (0..12).map(|i| y[i] * dual.alpha[i]).collect();
        let mut w = vec![0.0; 4];
        s.matvec_t(&ya, &mut w);
        for j in 0..4 {
            assert!(
                (w[j] - primal.w[j]).abs() < 1e-6,
                "j={j}: dual {} vs primal {}",
                w[j],
                primal.w[j]
            );
        }
        // and α themselves must match (solution unique for C < ∞)
        for i in 0..12 {
            assert!(
                (dual.alpha[i] - primal.alpha[i]).abs() < 1e-6,
                "α[{i}]: {} vs {}",
                dual.alpha[i],
                primal.alpha[i]
            );
        }
    }

    #[test]
    fn warm_start_reduces_pivots() {
        let (_, _, k) = random_problem(20, 6, 143);
        let c = 1.0;
        let cold = dual_newton(&k, c, &DualOptions::default(), None, None);
        let warm = dual_newton(&k, c, &DualOptions::default(), Some(&cold.alpha), None);
        assert!(warm.pivots <= cold.pivots);
        for i in 0..20 {
            assert!((warm.alpha[i] - cold.alpha[i]).abs() < 1e-8);
        }
    }

    #[test]
    fn objective_decreases_vs_zero() {
        let (_, _, k) = random_problem(10, 3, 144);
        let r = dual_newton(&k, 1.0, &DualOptions::default(), None, None);
        assert!(r.objective < 0.0, "dual optimum must beat α = 0 (obj 0)");
    }

    #[test]
    fn alpha_nonnegative() {
        let (_, _, k) = random_problem(25, 7, 145);
        let r = dual_newton(&k, 5.0, &DualOptions::default(), None, None);
        assert!(r.alpha.iter().all(|a| *a >= 0.0));
        assert!(!r.aborted && r.broken.is_none());
    }

    #[test]
    fn nan_c_trips_the_guardrail() {
        let (_, _, k) = random_problem(10, 3, 146);
        let r = dual_newton(&k, f64::NAN, &DualOptions::default(), None, None);
        assert!(r.broken.is_some(), "NaN C must be flagged, not served");
        assert!(!r.converged);
    }

    #[test]
    fn poisoned_gram_trips_the_guardrail() {
        let (_, _, mut k) = random_problem(12, 4, 147);
        k.set(3, 3, f64::NAN);
        let r = dual_newton(&k, 1.0, &DualOptions::default(), None, None);
        assert!(r.broken.is_some(), "poisoned K must be flagged, not served");
        assert!(!r.converged);
    }

    #[test]
    fn expired_ctl_aborts_at_pivot_boundary() {
        use super::super::SolveCtl;
        let (_, _, k) = random_problem(20, 6, 148);
        let always = || true;
        let ctl = SolveCtl::new(&always);
        let r = dual_newton(&k, 1.0, &DualOptions::default(), None, Some(&ctl));
        assert!(r.aborted, "an already-expired ctl must abort before the first pivot");
        assert!(!r.converged);
        assert_eq!(r.pivots, 0);
        // a never-expiring ctl is bit-identical to no ctl at all
        let never = || false;
        let ctl = SolveCtl::new(&never);
        let with = dual_newton(&k, 1.0, &DualOptions::default(), None, Some(&ctl));
        let without = dual_newton(&k, 1.0, &DualOptions::default(), None, None);
        assert_eq!(with.pivots, without.pivots);
        for i in 0..with.alpha.len() {
            assert_eq!(with.alpha[i].to_bits(), without.alpha[i].to_bits(), "i={i}");
        }
    }
}
