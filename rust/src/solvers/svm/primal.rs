//! Primal Newton-CG for the squared-hinge SVM (Chapelle 2007, §4–5),
//! with active-set shrinking.
//!
//! The objective `f(w) = ½‖w‖² + C·Σᵢ max(0, 1 − ŷᵢ wᵀx̂ᵢ)²` is piecewise
//! quadratic and differentiable; on a fixed support-vector set it *is*
//! quadratic, so Newton converges in a finite number of set changes. The
//! Newton system is solved matrix-free by CG (the computation the paper
//! offloads to GPU BLAS; here it is the computation the XLA artifact
//! performs).
//!
//! Three structural optimizations over the textbook loop:
//!
//! - **Active-set gather (shrinking).** The masked Hessian-vector
//!   product streams the full m × d sample matrix (two full GEMVs per
//!   CG iteration) even when few rows are support vectors. Instead, the
//!   SV rows are gathered into a reused compact panel ([`GatheredRows`])
//!   and every CG product runs on the m_sv × d submatrix — one gather
//!   costs about one gathered product and is amortized over the whole
//!   CG solve. The panel is re-gathered only when the set changes (on
//!   the stable tail of the solve it never is).
//! - **Batched margin refresh.** Each Newton iteration computes
//!   `X̂·[w, δ]` as one fused 2-column multi-RHS product
//!   ([`SampleSet::matvec_multi`]): the data is streamed once for both
//!   the exact margin refresh and the line-search direction product,
//!   instead of once per vector.
//! - **O(m) line search.** With `X̂δ` cached, each backtracking trial
//!   evaluates margins as `o + step·(X̂δ)` in O(m + d) — the seed
//!   re-ran a full O(m·d) `matvec` per trial.

use super::samples::{
    reduced_matvec_batch_multi, reduced_matvec_t_batch_multi, reduction_labels, GatheredRows,
    ReducedSamples, SampleSet,
};
use crate::linalg::{
    cg_solve_multi_with, cg_solve_refined, cg_solve_with, vecops, CgOptions, CgScratch, Design,
    DesignShadowF32, LinOp, MultiLinOp, MultiVec,
};
use std::cell::RefCell;

/// Options for [`primal_newton`].
#[derive(Clone, Debug)]
pub struct PrimalOptions {
    /// Gradient-norm tolerance, relative to √d.
    pub tol: f64,
    pub max_newton: usize,
    pub cg: CgOptions,
    /// Active-set shrinking: gather the SV rows into a compact panel
    /// (re-gathered only on set change) and run the CG Hessian products
    /// on it. Disable to force the masked full-matrix products (the
    /// pre-shrinking behavior, kept for comparison).
    pub shrink: bool,
    /// Gather only while `m_sv ≤ shrink_max_frac · m`; above it the
    /// masked product already touches mostly-useful rows and the gather
    /// copy is waste.
    pub shrink_max_frac: f64,
}

impl Default for PrimalOptions {
    fn default() -> Self {
        PrimalOptions {
            tol: 1e-10,
            max_newton: 100,
            cg: CgOptions { tol: 1e-12, max_iter: 0 },
            shrink: true,
            shrink_max_frac: 0.75,
        }
    }
}

/// Result of a primal solve.
#[derive(Clone, Debug)]
pub struct PrimalResult {
    pub w: Vec<f64>,
    /// Dual variables recovered as `α_i = 2C·max(0, 1 − ŷᵢ wᵀx̂ᵢ)`.
    pub alpha: Vec<f64>,
    pub newton_iters: usize,
    pub cg_iters_total: usize,
    /// How many times the SV rows were gathered into the compact panel
    /// (0 ⇒ the solve ran entirely on masked full-matrix products).
    pub gather_rebuilds: usize,
    /// Outer iterative-refinement passes across all Newton systems
    /// (0 ⇒ the solve ran in pure f64).
    pub refine_passes_total: usize,
    pub converged: bool,
    /// Final objective value.
    pub objective: f64,
    /// The intra-solve deadline ([`super::SolveCtl`]) fired at a Newton
    /// round boundary and this member was abandoned half-converged —
    /// never serve this iterate.
    pub aborted: bool,
    /// The numerical-health guard tripped (non-finite margins, gradient
    /// or objective) after the degradation ladder — f64 re-solve, then
    /// a masked full-matrix re-solve — was exhausted. The message names
    /// the stage. Never serve this iterate.
    pub broken: Option<String>,
}

/// Hessian operator `v ↦ v + 2C·X̂ᵀ(sv_mask ⊙ (X̂·v))` over the *full*
/// sample matrix — used while the SV set is still changing. The two
/// products route through the banded parallel GEMV layer in
/// [`crate::linalg`] (deterministic fixed-chunk reduction for the
/// transpose side), so the CG inner loop scales with the `Parallelism`
/// knob without giving up bit-stable iterates.
struct MaskedHess<'a, S: SampleSet> {
    samples: &'a S,
    sv_mask: &'a [f64], // 1.0 for support vectors, else 0.0
    two_c: f64,
    buf: &'a RefCell<Vec<f64>>,
    /// Route the two sample products through the f32 hooks (the "fast"
    /// operator of [`cg_solve_refined`]); the mask and the `v + 2C·…`
    /// assembly stay f64 either way.
    mixed: bool,
    fbuf: &'a RefCell<Vec<f32>>,
}

impl<S: SampleSet> LinOp for MaskedHess<'_, S> {
    fn dim(&self) -> usize {
        self.samples.d()
    }

    fn apply(&self, v: &[f64], out: &mut [f64]) {
        let mut xm = self.buf.borrow_mut();
        xm.resize(self.samples.m(), 0.0);
        if self.mixed {
            let mut fb = self.fbuf.borrow_mut();
            self.samples.matvec_f32(v, &mut xm, &mut fb);
        } else {
            self.samples.matvec(v, &mut xm);
        }
        for (o, m) in xm.iter_mut().zip(self.sv_mask.iter()) {
            *o *= m;
        }
        if self.mixed {
            let mut fb = self.fbuf.borrow_mut();
            self.samples.matvec_t_f32(&xm, out, &mut fb);
        } else {
            self.samples.matvec_t(&xm, out);
        }
        for i in 0..out.len() {
            out[i] = v[i] + self.two_c * out[i];
        }
    }
}

/// Hessian operator over the gathered SV panel: `v ↦ v + 2C·Gᵀ(G·v)`
/// with G the m_sv × d submatrix of support-vector rows — no mask, no
/// dead rows. Products cost O(m_sv·d) (dense) / O(nnz(SV cols)) (sparse)
/// instead of O(m·d).
struct GatheredHess<'a, S: SampleSet> {
    samples: &'a S,
    panel: &'a GatheredRows,
    two_c: f64,
    buf: &'a RefCell<Vec<f64>>,
    /// Same fast/exact split as [`MaskedHess::mixed`], over the panel's
    /// f32 shadow ([`GatheredRows::build_f32_shadow`]).
    mixed: bool,
    fbuf: &'a RefCell<Vec<f32>>,
}

impl<S: SampleSet> LinOp for GatheredHess<'_, S> {
    fn dim(&self) -> usize {
        self.samples.d()
    }

    fn apply(&self, v: &[f64], out: &mut [f64]) {
        let mut gm = self.buf.borrow_mut();
        gm.resize(self.panel.m(), 0.0);
        if self.mixed {
            let mut fb = self.fbuf.borrow_mut();
            self.samples.gathered_matvec_f32(self.panel, v, &mut gm, &mut fb);
            self.samples.gathered_matvec_t_f32(self.panel, &gm, out, &mut fb);
        } else {
            self.samples.gathered_matvec(self.panel, v, &mut gm);
            self.samples.gathered_matvec_t(self.panel, &gm, out);
        }
        for i in 0..out.len() {
            out[i] = v[i] + self.two_c * out[i];
        }
    }
}

/// Solve one Newton system `H·δ = rhs` through whichever operator form
/// the caller picked (gathered panel when `panel` is present, masked
/// full-matrix otherwise), in pure f64 or — when `mixed` — with the f32
/// operator inside f64 iterative refinement ([`cg_solve_refined`]),
/// which meets the same `cg.tol` contract. Returns
/// `(cg_iters, refine_passes, non_finite)`.
///
/// Degradation ladder: refinement already retries an f32 stall in f64;
/// on top of that, a gathered solve that reports non-finite values
/// re-solves once from zero on the masked full-matrix f64 operator
/// (when the caller supplied the mask) before the member is failed —
/// `non_finite = true` in the return means the ladder is exhausted.
#[allow(clippy::too_many_arguments)]
fn solve_direction<S: SampleSet>(
    samples: &S,
    sv_mask: Option<&[f64]>,
    panel: Option<&GatheredRows>,
    two_c: f64,
    mixed: bool,
    rhs: &[f64],
    delta: &mut [f64],
    cg: &CgOptions,
    scratch: &mut CgScratch,
    buf: &RefCell<Vec<f64>>,
    fbuf: &RefCell<Vec<f32>>,
) -> (usize, usize, bool) {
    if let Some(panel) = panel {
        let exact = GatheredHess { samples, panel, two_c, buf, mixed: false, fbuf };
        let (mut iters, passes, mut non_finite) = if mixed {
            let fast = GatheredHess { samples, panel, two_c, buf, mixed: true, fbuf };
            let out = cg_solve_refined(&exact, &fast, rhs, delta, cg, scratch);
            (out.cg_iters, out.refine_passes, out.non_finite)
        } else {
            let out = cg_solve_with(&exact, rhs, delta, cg, scratch);
            (out.iters, 0, out.non_finite)
        };
        if non_finite {
            if let Some(mask) = sv_mask {
                delta.fill(0.0);
                let exact =
                    MaskedHess { samples, sv_mask: mask, two_c, buf, mixed: false, fbuf };
                let out = cg_solve_with(&exact, rhs, delta, cg, scratch);
                iters += out.iters;
                non_finite = out.non_finite;
            }
        }
        (iters, passes, non_finite)
    } else {
        let mask = sv_mask.expect("masked form needs the SV mask");
        let exact = MaskedHess { samples, sv_mask: mask, two_c, buf, mixed: false, fbuf };
        if mixed {
            let fast = MaskedHess { samples, sv_mask: mask, two_c, buf, mixed: true, fbuf };
            let out = cg_solve_refined(&exact, &fast, rhs, delta, cg, scratch);
            (out.cg_iters, out.refine_passes, out.non_finite)
        } else {
            let out = cg_solve_with(&exact, rhs, delta, cg, scratch);
            (out.iters, 0, out.non_finite)
        }
    }
}

/// Objective, gradient pieces, and support mask at `w`.
/// Returns (objective, margins o = X̂w).
fn evaluate<S: SampleSet>(
    samples: &S,
    yhat: &[f64],
    c: f64,
    w: &[f64],
    o: &mut [f64],
    slack: &mut [f64],
    mask: &mut [f64],
) -> f64 {
    samples.matvec(w, o);
    let mut loss = 0.0;
    for i in 0..o.len() {
        let s = 1.0 - yhat[i] * o[i];
        if s > 0.0 {
            slack[i] = s;
            mask[i] = 1.0;
            loss += s * s;
        } else {
            slack[i] = 0.0;
            mask[i] = 0.0;
        }
    }
    0.5 * vecops::norm2_sq(w) + c * loss
}

/// Minimize the primal squared-hinge objective; warm-startable via `w0`.
pub fn primal_newton<S: SampleSet>(
    samples: &S,
    yhat: &[f64],
    c: f64,
    opts: &PrimalOptions,
    w0: Option<&[f64]>,
) -> PrimalResult {
    let (m, d) = (samples.m(), samples.d());
    assert_eq!(yhat.len(), m);
    let mut w = w0.map(|w| w.to_vec()).unwrap_or_else(|| vec![0.0; d]);
    assert_eq!(w.len(), d);

    let mut o = vec![0.0; m];
    let mut slack = vec![0.0; m];
    let mut mask = vec![0.0; m];
    let mut ys = vec![0.0; m];
    let mut grad = vec![0.0; d];
    let mut delta = vec![0.0; d];
    let mut cg_scratch = CgScratch::new();
    let hess_buf = RefCell::new(vec![0.0; m]);
    let fbuf = RefCell::new(Vec::new());
    // Mixed precision engages only when the sample set carries an f32
    // shadow; every Newton system then runs f32 CG inside f64
    // refinement, to the same `opts.cg.tol`.
    let mixed = samples.mixed_available();
    // [w, δ] input panel and its [X̂w, X̂δ] image — the batched margin
    // refresh (one fused pass per Newton iteration).
    let mut wd = MultiVec::zeros(d, 2);
    let mut od = MultiVec::zeros(m, 2);
    let mut cg_total = 0usize;
    let mut gather_rebuilds = 0usize;
    let mut refine_total = 0usize;
    let mut converged = false;
    let mut broken: Option<String> = None;

    let mut obj = evaluate(samples, yhat, c, &w, &mut o, &mut slack, &mut mask);
    // Guardrail: a poisoned input (NaN C, t, y, or warm start) shows up
    // here as non-finite margins or objective — fail fast, before any
    // Newton work runs on garbage.
    if !obj.is_finite() || o.iter().any(|v| !v.is_finite()) {
        broken = Some("non-finite initial margins or objective".into());
    }
    let sv_of = |mask: &[f64]| -> Vec<usize> {
        (0..mask.len()).filter(|&i| mask[i] == 1.0).collect()
    };
    let mut sv = sv_of(&mask);
    let mut gathered_set: Vec<usize> = Vec::new();
    let mut panel = GatheredRows::new();

    let mut newton = 0;
    while broken.is_none() && newton < opts.max_newton {
        // grad = w − 2C·X̂ᵀ(ŷ ⊙ slack) restricted to support vectors
        for i in 0..m {
            ys[i] = yhat[i] * slack[i] * mask[i];
        }
        samples.matvec_t(&ys, &mut grad);
        for i in 0..d {
            grad[i] = w[i] - 2.0 * c * grad[i];
        }
        let gnorm = vecops::norm2(&grad) / (d as f64).sqrt();
        if !gnorm.is_finite() {
            // NaN compares false against any tolerance, so it must be
            // caught explicitly or the solve grinds to max_newton.
            broken = Some("non-finite gradient".into());
            break;
        }
        if gnorm <= opts.tol * (1.0 + obj.abs()) {
            converged = true;
            break;
        }

        // Newton direction: H δ = −grad (matrix-free CG) over the
        // gathered SV panel when the set is small enough to pay. One
        // gather costs about one gathered product and is amortized over
        // every CG iteration of the step (and over later steps on the
        // same set — the panel is rebuilt only when the set changes, and
        // on the stable tail of the solve it never is).
        let use_gather = opts.shrink
            && !sv.is_empty()
            && (sv.len() as f64) <= opts.shrink_max_frac * m as f64;
        if use_gather && gathered_set != sv {
            samples.gather_rows_into(&sv, &mut panel);
            gathered_set.clone_from(&sv);
            gather_rebuilds += 1;
            if mixed {
                panel.build_f32_shadow();
            }
        }
        let rhs: Vec<f64> = grad.iter().map(|g| -g).collect();
        delta.fill(0.0);
        let (iters, passes, non_finite) = if use_gather {
            solve_direction(
                samples,
                Some(&mask),
                Some(&panel),
                2.0 * c,
                mixed,
                &rhs,
                &mut delta,
                &opts.cg,
                &mut cg_scratch,
                &hess_buf,
                &fbuf,
            )
        } else {
            solve_direction(
                samples,
                Some(&mask),
                None,
                2.0 * c,
                mixed,
                &rhs,
                &mut delta,
                &opts.cg,
                &mut cg_scratch,
                &hess_buf,
                &fbuf,
            )
        };
        cg_total += iters;
        refine_total += passes;
        if non_finite {
            broken = Some("non-finite Newton system after masked re-solve".into());
            break;
        }

        // Batched margin refresh: [X̂w, X̂δ] in one fused panel product —
        // exact margins for the line search (no incremental drift) plus
        // the cached direction product, for one streaming pass.
        wd.col_mut(0).copy_from_slice(&w);
        wd.col_mut(1).copy_from_slice(&delta);
        samples.matvec_multi(&wd, &mut od);
        let ow = od.col(0);
        let xd = od.col(1);

        // Line search on cached margins: the full Newton step is exact on
        // a stable SV set; back off geometrically if the set change
        // increased the objective. Each trial is O(m) + O(1) (the ‖w‖²
        // term expands quadratically in step).
        let wnorm_sq = vecops::norm2_sq(&w);
        let wdot = vecops::dot(&w, &delta);
        let dnorm_sq = vecops::norm2_sq(&delta);
        let mut step = 1.0;
        let mut accepted = false;
        for _ in 0..40 {
            let mut loss = 0.0;
            for i in 0..m {
                let s = 1.0 - yhat[i] * (ow[i] + step * xd[i]);
                if s > 0.0 {
                    loss += s * s;
                }
            }
            let quad = wnorm_sq + 2.0 * step * wdot + step * step * dnorm_sq;
            let obj_try = 0.5 * quad + c * loss;
            if obj_try <= obj + 1e-12 * obj.abs() {
                accepted = true;
                break;
            }
            step *= 0.5;
        }
        newton += 1;
        if !accepted {
            if delta.iter().any(|v| !v.is_finite()) {
                // Every trial objective was NaN, not merely non-improving.
                broken = Some("non-finite Newton direction".into());
            } else {
                // No decrease along the Newton direction — numerically at
                // the optimum. State (o/slack/mask) still describes w;
                // stop.
                converged = true;
            }
            break;
        }

        // Accept: w ← w + step·δ; margins from the cached panel (exact —
        // ow is this iteration's fused refresh of X̂w).
        for i in 0..d {
            w[i] += step * delta[i];
        }
        let mut loss = 0.0;
        for i in 0..m {
            o[i] = ow[i] + step * xd[i];
            let s = 1.0 - yhat[i] * o[i];
            if s > 0.0 {
                slack[i] = s;
                mask[i] = 1.0;
                loss += s * s;
            } else {
                slack[i] = 0.0;
                mask[i] = 0.0;
            }
        }
        obj = 0.5 * vecops::norm2_sq(&w) + c * loss;
        if !obj.is_finite() {
            broken = Some("non-finite objective after step".into());
            break;
        }
        sv = sv_of(&mask);
    }

    // α_i = 2C·slack_i at the final iterate.
    let _ = evaluate(samples, yhat, c, &w, &mut o, &mut slack, &mut mask);
    let alpha: Vec<f64> = slack.iter().map(|s| 2.0 * c * s).collect();
    PrimalResult {
        w,
        alpha,
        newton_iters: newton,
        cg_iters_total: cg_total,
        gather_rebuilds,
        refine_passes_total: refine_total,
        converged: converged && broken.is_none(),
        objective: obj,
        aborted: false,
        broken,
    }
}

/// One problem of a batched primal solve over a shared `(X, y)`: the
/// SVEN reduction at budget `t` and regularization `c`, optionally
/// warm-started in the primal.
#[derive(Clone, Debug)]
pub struct PrimalBatchPoint {
    pub t: f64,
    pub c: f64,
    /// Primal warm start (length n = design rows); `None` ⇒ cold.
    pub w0: Option<Vec<f64>>,
}

/// Aggregate fusion statistics of a batched solve. Per-problem counters
/// (`newton_iters`, `cg_iters_total`, `gather_rebuilds`) live in each
/// [`PrimalResult`] with exactly their solo meanings.
#[derive(Clone, Copy, Debug, Default)]
pub struct PrimalBatchStats {
    /// Physical SV-panel gathers performed. Batch members whose active
    /// sets agree share one gather, so this can be far below the sum of
    /// per-problem `gather_rebuilds` (which count solo-equivalent
    /// rebuilds).
    pub panel_builds: usize,
    /// Right-hand sides driven through blocked CG (groups of width ≥ 2);
    /// each counts one whole Newton system, not one CG iteration.
    pub batched_rhs: usize,
    /// Panel compactions inside the blocked-CG solves.
    pub cg_compactions: usize,
    /// Histogram of Newton-direction group widths, log₂-bucketed:
    /// bucket k counts groups of width in `[2ᵏ, 2ᵏ⁺¹)` (bucket 7 is
    /// open-ended). Width-1 groups (solo paths) land in bucket 0, so the
    /// histogram totals every Newton direction the batch solved.
    pub width_hist: [u32; 8],
    /// Widest Newton-direction group seen (1 when nothing ever fused).
    pub max_fused_width: usize,
}

impl PrimalBatchStats {
    /// Accumulate another batch's stats (segmented sweeps sum these).
    pub fn merge(&mut self, other: &PrimalBatchStats) {
        self.panel_builds += other.panel_builds;
        self.batched_rhs += other.batched_rhs;
        self.cg_compactions += other.cg_compactions;
        for (a, b) in self.width_hist.iter_mut().zip(&other.width_hist) {
            *a += b;
        }
        self.max_fused_width = self.max_fused_width.max(other.max_fused_width);
    }

    /// Record one Newton-direction group of `width` members.
    fn on_group(&mut self, width: usize) {
        debug_assert!(width >= 1);
        let bucket = (usize::BITS - 1 - width.leading_zeros()).min(7) as usize;
        self.width_hist[bucket] += 1;
        self.max_fused_width = self.max_fused_width.max(width);
    }
}

/// Hessian family of a shared-SV-panel batch: member `j` is
/// `v ↦ v + 2C_j·Ĝ_jᵀ(Ĝ_j·v)` where every `Ĝ_j` shares one gathered
/// panel of bare design columns (the panel is t- *and* y-independent;
/// the implicit `±y_j/t_j` shift is applied per column, so members
/// viewing the shared design through different responses still fuse).
/// One fused panel product per blocked-CG iteration serves every
/// member — the panel-width-in-the-Hessian lever of the batched Newton.
/// Per-column bits match the solo [`GatheredHess`] exactly (the fused
/// store products keep the single-RHS reduction order; the shift
/// arithmetic repeats [`ReducedSamples::gathered_matvec`] /
/// [`ReducedSamples::gathered_matvec_t`] verbatim).
struct BatchGatheredHess<'a> {
    panel: &'a GatheredRows,
    /// Per-member response (indexed by problem id within the group).
    ys: &'a [&'a [f64]],
    d: usize,
    /// Per-member budget t.
    ts: &'a [f64],
    /// Per-member 2C.
    two_cs: &'a [f64],
    gm: RefCell<MultiVec>,
}

impl MultiLinOp for BatchGatheredHess<'_> {
    fn dim(&self) -> usize {
        self.d
    }

    fn nprobs(&self) -> usize {
        self.ts.len()
    }

    fn apply_multi(&self, cols: &[usize], vs: &MultiVec, out: &mut MultiVec) {
        let mut gm = self.gm.borrow_mut();
        gm.resize(self.panel.m(), vs.ncols());
        self.panel.store_matvec_multi_into(vs, &mut gm);
        let signs = self.panel.signs();
        for (s, &j) in cols.iter().enumerate() {
            let shift = vecops::dot(self.ys[j], vs.col(s)) / self.ts[j];
            for (gi, si) in gm.col_mut(s).iter_mut().zip(signs) {
                *gi += si * shift;
            }
        }
        self.panel.store_matvec_t_multi_into(&gm, out);
        for (s, &j) in cols.iter().enumerate() {
            let mut coeff = 0.0;
            for (ui, si) in gm.col(s).iter().zip(signs) {
                coeff += ui * si;
            }
            vecops::axpy(coeff / self.ts[j], self.ys[j], out.col_mut(s));
            let v = vs.col(s);
            let o = out.col_mut(s);
            let tc = self.two_cs[j];
            for i in 0..o.len() {
                o[i] = v[i] + tc * o[i];
            }
        }
    }
}

/// Batched primal Newton over the shared SVEN reduction: solve the S
/// problems `(t_s, C_s)` of `points` against one `(X, y)` in lockstep.
///
/// Per round, the batch fuses everything that streams the shared data:
/// the gradients (`X̂ᵀ·` across all live members), the margin refresh
/// (`X̂·[w, δ]` across all live members), and — where members' SV sets
/// agree — the Newton systems themselves, gathered once and solved
/// together through [`cg_solve_multi_with`] so every CG iteration runs
/// one panel-wide Hessian product. Members whose sets diverge fall back
/// to the solo per-problem path.
///
/// **Contract:** result `s` (weights, duals, iteration counts) is
/// bit-identical to `primal_newton(ReducedSamples::new(x, y, t_s),
/// reduction_labels(p), c_s, opts, w0_s)` at any thread count and any
/// batch composition — batching is purely a memory-traffic optimization
/// (pinned by the `batch_matches_solo_*` tests and the service-level
/// path gates).
///
/// With `shadow` present the batch runs mixed precision: every member's
/// Newton systems go through f32 CG under f64 refinement, one member at
/// a time (the blocked-CG group fusion is f64-only for now — fusing it
/// with refinement is a tracked follow-on), and the bit-identity
/// contract holds against the solo `ReducedSamples::with_shadow` run by
/// the same construction. The fused gradient and margin-refresh passes
/// stay f64 in both modes.
pub fn primal_newton_batch(
    x: &Design,
    y: &[f64],
    points: &[PrimalBatchPoint],
    opts: &PrimalOptions,
    shadow: Option<&DesignShadowF32>,
    ctl: Option<&super::SolveCtl>,
) -> (Vec<PrimalResult>, PrimalBatchStats) {
    let ys = vec![y; points.len()];
    primal_newton_batch_ys(x, &ys, points, opts, shadow, ctl)
}

/// [`primal_newton_batch`] generalized to per-member responses: member
/// `s` solves the SVEN reduction of `(x, ys[s])` at `(t_s, C_s)`. This
/// is the multi-response screen engine's compute core — R responses at
/// one grid point (or any mixed response/path batch) share every fused
/// pass above, and members whose SV sets agree share one gathered panel
/// regardless of which response they view the design through (the panel
/// holds bare design columns; the `±y/t` shift stays per-member). The
/// solo bit-identity contract is unchanged: result `s` is bit-identical
/// to `primal_newton` on `ReducedSamples::new(x, ys[s], t_s)`.
pub fn primal_newton_batch_ys(
    x: &Design,
    ys: &[&[f64]],
    points: &[PrimalBatchPoint],
    opts: &PrimalOptions,
    shadow: Option<&DesignShadowF32>,
    ctl: Option<&super::SolveCtl>,
) -> (Vec<PrimalResult>, PrimalBatchStats) {
    let nprobs = points.len();
    let p = x.cols();
    let (m, d) = (2 * p, x.rows());
    assert_eq!(ys.len(), nprobs);
    for y in ys {
        assert_eq!(y.len(), d);
    }
    let yhat = reduction_labels(p);
    let mut stats = PrimalBatchStats::default();
    if nprobs == 0 {
        return (Vec::new(), stats);
    }

    struct Prob {
        t: f64,
        c: f64,
        w: Vec<f64>,
        o: Vec<f64>,
        slack: Vec<f64>,
        mask: Vec<f64>,
        grad: Vec<f64>,
        delta: Vec<f64>,
        obj: f64,
        sv: Vec<usize>,
        /// Solo-equivalent gather tracking (keeps `gather_rebuilds`
        /// meaning exactly what it means in [`primal_newton`]).
        tracked_set: Vec<usize>,
        /// What this problem's own physical panel currently holds.
        panel_set: Vec<usize>,
        newton: usize,
        cg_total: usize,
        gather_rebuilds: usize,
        refine_total: usize,
        converged: bool,
        done: bool,
        aborted: bool,
        broken: Option<String>,
    }

    let mixed = shadow.is_some();
    fn samples_at<'s>(
        x: &'s Design,
        shadow: Option<&'s DesignShadowF32>,
        t: f64,
        y: &'s [f64],
    ) -> ReducedSamples<'s> {
        match shadow {
            Some(sh) => ReducedSamples::with_shadow(x, y, t, sh),
            None => ReducedSamples::new(x, y, t),
        }
    }

    let mut st: Vec<Prob> = points
        .iter()
        .map(|pt| {
            let w = pt.w0.clone().unwrap_or_else(|| vec![0.0; d]);
            assert_eq!(w.len(), d);
            Prob {
                t: pt.t,
                c: pt.c,
                w,
                o: vec![0.0; m],
                slack: vec![0.0; m],
                mask: vec![0.0; m],
                grad: vec![0.0; d],
                delta: vec![0.0; d],
                obj: 0.0,
                sv: Vec::new(),
                tracked_set: Vec::new(),
                panel_set: Vec::new(),
                newton: 0,
                cg_total: 0,
                gather_rebuilds: 0,
                refine_total: 0,
                converged: false,
                done: false,
                aborted: false,
                broken: None,
            }
        })
        .collect();
    let mut panels: Vec<GatheredRows> = (0..nprobs).map(|_| GatheredRows::new()).collect();
    let mut cg_scratch = CgScratch::new();
    let hess_buf = RefCell::new(vec![0.0; m]);
    let fbuf = RefCell::new(Vec::new());
    let mut in_panel = MultiVec::zeros(0, 0);
    let mut out_panel = MultiVec::zeros(0, 0);
    let mut wd_panel = MultiVec::zeros(0, 0);
    let mut od_panel = MultiVec::zeros(0, 0);

    // Initial margins / objective / SV sets: one fused pass.
    {
        let ts: Vec<f64> = st.iter().map(|s| s.t).collect();
        in_panel.resize(d, nprobs);
        out_panel.resize(m, nprobs);
        for (j, s) in st.iter().enumerate() {
            in_panel.col_mut(j).copy_from_slice(&s.w);
        }
        reduced_matvec_batch_multi(x, ys, &ts, &in_panel, &mut out_panel);
        for (j, s) in st.iter_mut().enumerate() {
            s.o.copy_from_slice(out_panel.col(j));
            let mut loss = 0.0;
            for i in 0..m {
                let sl = 1.0 - yhat[i] * s.o[i];
                if sl > 0.0 {
                    s.slack[i] = sl;
                    s.mask[i] = 1.0;
                    loss += sl * sl;
                } else {
                    s.slack[i] = 0.0;
                    s.mask[i] = 0.0;
                }
            }
            s.obj = 0.5 * vecops::norm2_sq(&s.w) + s.c * loss;
            s.sv = (0..m).filter(|&i| s.mask[i] == 1.0).collect();
            // Guardrail: a poisoned member (NaN C, t, or response) is
            // evicted from the fused panel here — before any round — and
            // its siblings solve on untouched (per-column bit-identical
            // fused passes keep them clean).
            if !s.obj.is_finite() || s.o.iter().any(|v| !v.is_finite()) {
                s.broken = Some("non-finite initial margins or objective".into());
                s.done = true;
            }
        }
    }

    loop {
        // Intra-solve deadline, polled once per Newton round: abandon
        // every still-live member at this round boundary — a
        // half-converged iterate is flagged `aborted` and never served.
        if ctl.is_some_and(|c| c.expired()) {
            for s in st.iter_mut() {
                if !s.done {
                    s.aborted = true;
                    s.done = true;
                }
            }
            break;
        }
        // Live set for this round, after the solo loop-head cap check.
        let mut live: Vec<usize> = Vec::new();
        for (j, s) in st.iter_mut().enumerate() {
            if s.done {
                continue;
            }
            if s.newton >= opts.max_newton {
                s.done = true;
            } else {
                live.push(j);
            }
        }
        if live.is_empty() {
            break;
        }

        // (1) Gradients — one fused X̂ᵀ pass across the batch:
        //     grad_j = w_j − 2C_j·X̂ᵀ(ŷ ⊙ slack_j).
        let lts: Vec<f64> = live.iter().map(|&j| st[j].t).collect();
        let lys: Vec<&[f64]> = live.iter().map(|&j| ys[j]).collect();
        in_panel.resize(m, live.len());
        out_panel.resize(d, live.len());
        for (l, &j) in live.iter().enumerate() {
            let s = &st[j];
            let u = in_panel.col_mut(l);
            for i in 0..m {
                u[i] = yhat[i] * s.slack[i] * s.mask[i];
            }
        }
        reduced_matvec_t_batch_multi(x, &lys, &lts, &in_panel, &mut out_panel);
        let mut still: Vec<usize> = Vec::with_capacity(live.len());
        for (l, &j) in live.iter().enumerate() {
            let s = &mut st[j];
            let g = out_panel.col(l);
            for i in 0..d {
                s.grad[i] = s.w[i] - 2.0 * s.c * g[i];
            }
            let gnorm = vecops::norm2(&s.grad) / (d as f64).sqrt();
            if !gnorm.is_finite() {
                // NaN compares false against any tolerance; evict the
                // member rather than dragging a poisoned column through
                // the fused passes to max_newton.
                s.broken = Some("non-finite gradient".into());
                s.done = true;
            } else if gnorm <= opts.tol * (1.0 + s.obj.abs()) {
                s.converged = true;
                s.done = true;
            } else {
                still.push(j);
            }
        }
        let live = still;
        if live.is_empty() {
            continue;
        }

        // (2) Newton directions. Members whose SV sets agree share one
        // gathered panel and solve together through blocked CG; the rest
        // run the solo per-problem path (masked or gathered).
        let use_gather: Vec<bool> = live
            .iter()
            .map(|&j| {
                let s = &st[j];
                opts.shrink
                    && !s.sv.is_empty()
                    && (s.sv.len() as f64) <= opts.shrink_max_frac * m as f64
            })
            .collect();
        let mut grouped = vec![false; live.len()];
        for a in 0..live.len() {
            if grouped[a] {
                continue;
            }
            grouped[a] = true;
            let lead = live[a];
            if !use_gather[a] {
                // Masked solo fallback (the pre-shrinking operator).
                stats.on_group(1);
                let samples = samples_at(x, shadow, st[lead].t, ys[lead]);
                let two_c = 2.0 * st[lead].c;
                let rhs: Vec<f64> = st[lead].grad.iter().map(|g| -g).collect();
                let mut delta = std::mem::take(&mut st[lead].delta);
                delta.fill(0.0);
                let (iters, passes, non_finite) = solve_direction(
                    &samples,
                    Some(&st[lead].mask),
                    None,
                    two_c,
                    mixed,
                    &rhs,
                    &mut delta,
                    &opts.cg,
                    &mut cg_scratch,
                    &hess_buf,
                    &fbuf,
                );
                st[lead].delta = delta;
                st[lead].cg_total += iters;
                st[lead].refine_total += passes;
                if non_finite {
                    st[lead].broken =
                        Some("non-finite Newton system after masked re-solve".into());
                    st[lead].done = true;
                }
                continue;
            }
            let mut members = vec![lead];
            // Mixed precision runs per-member refinement loops, so
            // members never group (the blocked-CG fusion stays f64-only
            // until refinement learns the panel form — see ROADMAP).
            if !mixed {
                for b in (a + 1)..live.len() {
                    if !grouped[b] && use_gather[b] && st[live[b]].sv == st[lead].sv {
                        grouped[b] = true;
                        members.push(live[b]);
                    }
                }
            }
            // Solo-equivalent rebuild accounting for every member.
            for &j in &members {
                let s = &mut st[j];
                if s.tracked_set != s.sv {
                    s.tracked_set = s.sv.clone();
                    s.gather_rebuilds += 1;
                }
            }
            // One physical gather serves the whole group (the panel's
            // bare columns are t-independent). Host the panel on any
            // member that already holds this exact set — when a previous
            // round's host converges, the survivors inherit its panel
            // instead of re-gathering identical contents.
            let host = members
                .iter()
                .copied()
                .find(|&j| st[j].panel_set == st[j].sv)
                .unwrap_or(lead);
            if st[host].panel_set != st[host].sv {
                let sv = st[host].sv.clone();
                let samples = samples_at(x, shadow, st[host].t, ys[host]);
                samples.gather_rows_into(&sv, &mut panels[host]);
                st[host].panel_set = sv;
                stats.panel_builds += 1;
            }
            if mixed {
                // No-op when the shadow is already current; demotes once
                // per physical gather otherwise.
                panels[host].build_f32_shadow();
            }
            if members.len() == 1 {
                // Gathered solo path on the (now current) panel.
                stats.on_group(1);
                let samples = samples_at(x, shadow, st[lead].t, ys[lead]);
                let two_c = 2.0 * st[lead].c;
                let rhs: Vec<f64> = st[lead].grad.iter().map(|g| -g).collect();
                let mut delta = std::mem::take(&mut st[lead].delta);
                delta.fill(0.0);
                let (iters, passes, non_finite) = solve_direction(
                    &samples,
                    Some(&st[lead].mask),
                    Some(&panels[host]),
                    two_c,
                    mixed,
                    &rhs,
                    &mut delta,
                    &opts.cg,
                    &mut cg_scratch,
                    &hess_buf,
                    &fbuf,
                );
                st[lead].delta = delta;
                st[lead].cg_total += iters;
                st[lead].refine_total += passes;
                if non_finite {
                    st[lead].broken =
                        Some("non-finite Newton system after masked re-solve".into());
                    st[lead].done = true;
                }
            } else {
                // Blocked CG: one fused panel product per iteration for
                // the whole group.
                let width = members.len();
                stats.on_group(width);
                let gts: Vec<f64> = members.iter().map(|&j| st[j].t).collect();
                let gys: Vec<&[f64]> = members.iter().map(|&j| ys[j]).collect();
                let gtwo_cs: Vec<f64> = members.iter().map(|&j| 2.0 * st[j].c).collect();
                let mut rhs = MultiVec::zeros(d, width);
                let mut dx = MultiVec::zeros(d, width);
                for (l, &j) in members.iter().enumerate() {
                    for (ri, gi) in rhs.col_mut(l).iter_mut().zip(&st[j].grad) {
                        *ri = -gi;
                    }
                }
                let cg_opts = vec![opts.cg.clone(); width];
                let cg_out = {
                    let hess = BatchGatheredHess {
                        panel: &panels[host],
                        ys: &gys,
                        d,
                        ts: &gts,
                        two_cs: &gtwo_cs,
                        gm: RefCell::new(MultiVec::zeros(0, 0)),
                    };
                    cg_solve_multi_with(&hess, &rhs, &mut dx, &cg_opts, &mut cg_scratch)
                };
                stats.batched_rhs += width;
                stats.cg_compactions += cg_out.compactions;
                for (l, &j) in members.iter().enumerate() {
                    st[j].cg_total += cg_out.outcomes[l].iters;
                    if cg_out.outcomes[l].non_finite {
                        // Ladder rung: re-solve this member alone on the
                        // masked full-matrix f64 operator (exactly what
                        // its solo gathered solve would retry) before
                        // failing it. Siblings' columns are untouched.
                        let samples = samples_at(x, shadow, st[j].t, ys[j]);
                        let rhs: Vec<f64> = st[j].grad.iter().map(|g| -g).collect();
                        let mut delta = std::mem::take(&mut st[j].delta);
                        delta.fill(0.0);
                        let (iters, _, non_finite) = solve_direction(
                            &samples,
                            Some(&st[j].mask),
                            None,
                            2.0 * st[j].c,
                            false,
                            &rhs,
                            &mut delta,
                            &opts.cg,
                            &mut cg_scratch,
                            &hess_buf,
                            &fbuf,
                        );
                        st[j].delta = delta;
                        st[j].cg_total += iters;
                        if non_finite {
                            st[j].broken = Some(
                                "non-finite Newton system after masked re-solve".into(),
                            );
                            st[j].done = true;
                        }
                    } else {
                        st[j].delta.copy_from_slice(dx.col(l));
                    }
                }
            }
        }

        // (3) Fused margin refresh across the whole batch: one
        //     X̂·[w₁, δ₁, w₂, δ₂, …] pass.
        let refresh_ts: Vec<f64> = live.iter().flat_map(|&j| [st[j].t, st[j].t]).collect();
        let refresh_ys: Vec<&[f64]> = live.iter().flat_map(|&j| [ys[j], ys[j]]).collect();
        wd_panel.resize(d, 2 * live.len());
        od_panel.resize(m, 2 * live.len());
        for (l, &j) in live.iter().enumerate() {
            wd_panel.col_mut(2 * l).copy_from_slice(&st[j].w);
            wd_panel.col_mut(2 * l + 1).copy_from_slice(&st[j].delta);
        }
        reduced_matvec_batch_multi(x, &refresh_ys, &refresh_ts, &wd_panel, &mut od_panel);

        // (4) Line search + accept, per problem (scalar work).
        for (l, &j) in live.iter().enumerate() {
            let s = &mut st[j];
            if s.done {
                // Evicted mid-round by the Newton-system guardrail.
                continue;
            }
            let ow = od_panel.col(2 * l);
            let xd = od_panel.col(2 * l + 1);
            let wnorm_sq = vecops::norm2_sq(&s.w);
            let wdot = vecops::dot(&s.w, &s.delta);
            let dnorm_sq = vecops::norm2_sq(&s.delta);
            let mut step = 1.0;
            let mut accepted = false;
            for _ in 0..40 {
                let mut loss = 0.0;
                for i in 0..m {
                    let sl = 1.0 - yhat[i] * (ow[i] + step * xd[i]);
                    if sl > 0.0 {
                        loss += sl * sl;
                    }
                }
                let quad = wnorm_sq + 2.0 * step * wdot + step * step * dnorm_sq;
                let obj_try = 0.5 * quad + s.c * loss;
                if obj_try <= s.obj + 1e-12 * s.obj.abs() {
                    accepted = true;
                    break;
                }
                step *= 0.5;
            }
            s.newton += 1;
            if !accepted {
                if s.delta.iter().any(|v| !v.is_finite()) {
                    s.broken = Some("non-finite Newton direction".into());
                } else {
                    s.converged = true;
                }
                s.done = true;
                continue;
            }
            for i in 0..d {
                s.w[i] += step * s.delta[i];
            }
            let mut loss = 0.0;
            for i in 0..m {
                s.o[i] = ow[i] + step * xd[i];
                let sl = 1.0 - yhat[i] * s.o[i];
                if sl > 0.0 {
                    s.slack[i] = sl;
                    s.mask[i] = 1.0;
                    loss += sl * sl;
                } else {
                    s.slack[i] = 0.0;
                    s.mask[i] = 0.0;
                }
            }
            s.obj = 0.5 * vecops::norm2_sq(&s.w) + s.c * loss;
            if !s.obj.is_finite() {
                s.broken = Some("non-finite objective after step".into());
                s.done = true;
                continue;
            }
            s.sv = (0..m).filter(|&i| s.mask[i] == 1.0).collect();
        }
    }

    // Final margins (exact, fused) and the dual recovery α = 2C·slack.
    {
        let ts: Vec<f64> = st.iter().map(|s| s.t).collect();
        in_panel.resize(d, nprobs);
        out_panel.resize(m, nprobs);
        for (j, s) in st.iter().enumerate() {
            in_panel.col_mut(j).copy_from_slice(&s.w);
        }
        reduced_matvec_batch_multi(x, ys, &ts, &in_panel, &mut out_panel);
        for (j, s) in st.iter_mut().enumerate() {
            let o = out_panel.col(j);
            for i in 0..m {
                s.o[i] = o[i];
                let sl = 1.0 - yhat[i] * o[i];
                s.slack[i] = if sl > 0.0 { sl } else { 0.0 };
            }
        }
    }
    let results = st
        .into_iter()
        .map(|s| {
            let alpha: Vec<f64> = s.slack.iter().map(|sl| 2.0 * s.c * sl).collect();
            PrimalResult {
                w: s.w,
                alpha,
                newton_iters: s.newton,
                cg_iters_total: s.cg_total,
                gather_rebuilds: s.gather_rebuilds,
                refine_passes_total: s.refine_total,
                converged: s.converged && s.broken.is_none(),
                objective: s.obj,
                aborted: s.aborted,
                broken: s.broken,
            }
        })
        .collect();
    (results, stats)
}

#[cfg(test)]
mod tests {
    use super::*;
    use super::super::samples::DenseSamples;
    use crate::linalg::Mat;
    use crate::rng::Rng;

    /// Linearly separable toy set: two Gaussian blobs.
    fn blobs(m_half: usize, d: usize, gap: f64, seed: u64) -> (DenseSamples, Vec<f64>) {
        let mut rng = Rng::seed_from(seed);
        let mut x = Mat::zeros(2 * m_half, d);
        let mut y = vec![0.0; 2 * m_half];
        for i in 0..2 * m_half {
            let cls = if i < m_half { 1.0 } else { -1.0 };
            y[i] = cls;
            for j in 0..d {
                let center = if j == 0 { cls * gap } else { 0.0 };
                x.set(i, j, center + 0.3 * rng.normal());
            }
        }
        (DenseSamples { x }, y)
    }

    fn objective(s: &DenseSamples, y: &[f64], c: f64, w: &[f64]) -> f64 {
        let mut o = vec![0.0; s.m()];
        s.matvec(w, &mut o);
        let loss: f64 = (0..s.m())
            .map(|i| {
                let sl = (1.0 - y[i] * o[i]).max(0.0);
                sl * sl
            })
            .sum();
        0.5 * vecops::norm2_sq(w) + c * loss
    }

    #[test]
    fn separates_blobs() {
        let (s, y) = blobs(20, 4, 2.0, 131);
        let r = primal_newton(&s, &y, 1.0, &PrimalOptions::default(), None);
        assert!(r.converged);
        let mut o = vec![0.0; s.m()];
        s.matvec(&r.w, &mut o);
        let correct = (0..s.m()).filter(|&i| y[i] * o[i] > 0.0).count();
        assert!(correct as f64 >= 0.95 * s.m() as f64, "correct {correct}");
    }

    #[test]
    fn gradient_zero_at_solution() {
        let (s, y) = blobs(15, 3, 1.0, 132);
        let c = 2.5;
        let r = primal_newton(&s, &y, c, &PrimalOptions::default(), None);
        // finite-difference check of stationarity
        let f0 = objective(&s, &y, c, &r.w);
        for j in 0..3 {
            for d in [-1e-5, 1e-5] {
                let mut w = r.w.clone();
                w[j] += d;
                assert!(objective(&s, &y, c, &w) >= f0 - 1e-9, "j={j}");
            }
        }
    }

    #[test]
    fn alpha_consistent_with_slack() {
        let (s, y) = blobs(10, 3, 0.5, 133);
        let c = 1.7;
        let r = primal_newton(&s, &y, c, &PrimalOptions::default(), None);
        let mut o = vec![0.0; s.m()];
        s.matvec(&r.w, &mut o);
        for i in 0..s.m() {
            let expect = 2.0 * c * (1.0 - y[i] * o[i]).max(0.0);
            assert!((r.alpha[i] - expect).abs() < 1e-8, "i={i}");
        }
    }

    #[test]
    fn dual_primal_w_relation() {
        // w must equal Σ ŷᵢ αᵢ x̂ᵢ / ... in our scaling: stationarity gives
        // w = 2C Σ ŷᵢ slackᵢ x̂ᵢ = Σ ŷᵢ αᵢ x̂ᵢ.
        let (s, y) = blobs(12, 4, 0.8, 134);
        let r = primal_newton(&s, &y, 3.0, &PrimalOptions::default(), None);
        let ya: Vec<f64> = (0..s.m()).map(|i| y[i] * r.alpha[i]).collect();
        let mut w_rec = vec![0.0; 4];
        s.matvec_t(&ya, &mut w_rec);
        for j in 0..4 {
            assert!((w_rec[j] - r.w[j]).abs() < 1e-6, "j={j}: {} vs {}", w_rec[j], r.w[j]);
        }
    }

    #[test]
    fn warm_start_converges_immediately() {
        let (s, y) = blobs(15, 4, 1.0, 135);
        let r1 = primal_newton(&s, &y, 1.0, &PrimalOptions::default(), None);
        let r2 = primal_newton(&s, &y, 1.0, &PrimalOptions::default(), Some(&r1.w));
        assert!(r2.newton_iters <= 1, "warm start took {}", r2.newton_iters);
    }

    #[test]
    fn larger_c_fits_harder() {
        let (s, y) = blobs(15, 3, 0.3, 136);
        let lo = primal_newton(&s, &y, 0.1, &PrimalOptions::default(), None);
        let hi = primal_newton(&s, &y, 50.0, &PrimalOptions::default(), None);
        // total squared slack must not increase with C
        let slack_sum = |r: &PrimalResult| -> f64 {
            let mut o = vec![0.0; s.m()];
            s.matvec(&r.w, &mut o);
            (0..s.m()).map(|i| (1.0 - y[i] * o[i]).max(0.0).powi(2)).sum()
        };
        assert!(slack_sum(&hi) <= slack_sum(&lo) + 1e-9);
    }

    /// Shrinking on/off must land on the same optimum (the gathered and
    /// masked Hessians describe the same quadratic), and the widely
    /// separated blobs (few SVs) must actually trigger a gather.
    #[test]
    fn gathered_and_masked_solves_agree() {
        let (s, y) = blobs(30, 5, 2.0, 137);
        let c = 4.0;
        let on = primal_newton(&s, &y, c, &PrimalOptions::default(), None);
        let off = primal_newton(
            &s,
            &y,
            c,
            &PrimalOptions { shrink: false, ..Default::default() },
            None,
        );
        assert_eq!(off.gather_rebuilds, 0);
        // Widely separated blobs end with few SVs, so the shrinking path
        // must actually engage.
        assert!(on.gather_rebuilds >= 1, "gather never engaged");
        assert!(on.converged && off.converged);
        for j in 0..5 {
            assert!(
                (on.w[j] - off.w[j]).abs() < 1e-6,
                "j={j}: {} vs {}",
                on.w[j],
                off.w[j]
            );
        }
        let obj_on = objective(&s, &y, c, &on.w);
        let obj_off = objective(&s, &y, c, &off.w);
        assert!((obj_on - obj_off).abs() <= 1e-9 * (1.0 + obj_off.abs()));
    }

    /// The batched Newton's headline contract: every member of a batch
    /// is bit-identical to its solo `primal_newton` run — weights,
    /// duals, and iteration counters — whatever the batch composition.
    #[test]
    fn batch_matches_solo_bit_for_bit() {
        use crate::linalg::Design;
        let mut rng = Rng::seed_from(139);
        let x = Mat::from_fn(14, 30, |_, _| rng.normal());
        let y: Vec<f64> = (0..14).map(|_| rng.normal()).collect();
        let d: Design = x.into();
        let labels = reduction_labels(30);
        // shrink_max_frac 1.0 ⇒ the gathered path engages from round one
        // (every sample starts inside the margin at w = 0), so the
        // duplicated pair below is guaranteed to group.
        let opts = PrimalOptions { shrink_max_frac: 1.0, ..Default::default() };
        let points: Vec<PrimalBatchPoint> = [(0.4, 3.0), (0.7, 5.0), (1.1, 8.0), (0.7, 5.0)]
            .iter()
            .map(|&(t, c)| PrimalBatchPoint { t, c, w0: None })
            .collect();
        let (batch, stats) = primal_newton_batch(&d, &y, &points, &opts, None, None);
        assert_eq!(batch.len(), 4);
        // Two identical members walk identical trajectories, so their SV
        // sets agree every round: the shared-panel blocked CG must have
        // engaged.
        assert!(stats.batched_rhs >= 2, "identical members must batch");
        for (s, pt) in batch.iter().zip(&points) {
            let red = ReducedSamples::new(&d, &y, pt.t);
            let solo = primal_newton(&red, &labels, pt.c, &opts, None);
            assert_eq!(solo.newton_iters, s.newton_iters);
            assert_eq!(solo.cg_iters_total, s.cg_iters_total);
            assert_eq!(solo.gather_rebuilds, s.gather_rebuilds);
            assert_eq!(solo.converged, s.converged);
            for i in 0..14 {
                assert_eq!(solo.w[i].to_bits(), s.w[i].to_bits(), "w i={i}");
            }
            for i in 0..60 {
                assert_eq!(solo.alpha[i].to_bits(), s.alpha[i].to_bits(), "α i={i}");
            }
        }
    }

    /// Multi-response batches — members viewing the shared design
    /// through *different* responses — must keep the solo bit-identity
    /// contract, and members whose SV sets agree must still fuse across
    /// responses (at w = 0 every sample is inside the margin whatever
    /// the response, so round one groups the whole batch).
    #[test]
    fn multi_response_batch_matches_solo_bit_for_bit() {
        use crate::linalg::Design;
        let mut rng = Rng::seed_from(149);
        let x = Mat::from_fn(14, 30, |_, _| rng.normal());
        let responses: Vec<Vec<f64>> =
            (0..3).map(|_| (0..14).map(|_| rng.normal()).collect()).collect();
        let d: Design = x.into();
        let labels = reduction_labels(30);
        let opts = PrimalOptions { shrink_max_frac: 1.0, ..Default::default() };
        // Mixed response/path batch: response 0 at two budgets, responses
        // 1 and 2 at one each — the MultiResponse job's member shape.
        let members: Vec<(usize, f64, f64)> =
            vec![(0, 0.4, 3.0), (0, 0.7, 5.0), (1, 0.5, 4.0), (2, 0.9, 6.0)];
        let ys: Vec<&[f64]> = members.iter().map(|&(r, _, _)| responses[r].as_slice()).collect();
        let points: Vec<PrimalBatchPoint> = members
            .iter()
            .map(|&(_, t, c)| PrimalBatchPoint { t, c, w0: None })
            .collect();
        let (batch, stats) = primal_newton_batch_ys(&d, &ys, &points, &opts, None, None);
        assert_eq!(batch.len(), 4);
        // All four members start on the full SV set, so the first round
        // fuses them into one width-4 blocked-CG group.
        assert!(stats.batched_rhs >= 4, "cross-response members must batch");
        assert!(stats.max_fused_width >= 4, "width 4 group expected");
        assert!(stats.width_hist[2] >= 1, "width-4 bucket must be hit");
        for (s, &(r, t, c)) in batch.iter().zip(&members) {
            let red = ReducedSamples::new(&d, &responses[r], t);
            let solo = primal_newton(&red, &labels, c, &opts, None);
            assert_eq!(solo.newton_iters, s.newton_iters);
            assert_eq!(solo.cg_iters_total, s.cg_iters_total);
            assert_eq!(solo.gather_rebuilds, s.gather_rebuilds);
            assert_eq!(solo.converged, s.converged);
            for i in 0..14 {
                assert_eq!(solo.w[i].to_bits(), s.w[i].to_bits(), "resp {r} w i={i}");
            }
            for i in 0..60 {
                assert_eq!(solo.alpha[i].to_bits(), s.alpha[i].to_bits(), "resp {r} α i={i}");
            }
        }
    }

    /// A width-1 batch is exactly a solo solve, warm starts included.
    #[test]
    fn batch_width_one_and_warm_start_match_solo() {
        use crate::linalg::Design;
        let mut rng = Rng::seed_from(140);
        let x = Mat::from_fn(10, 24, |_, _| rng.normal());
        let y: Vec<f64> = (0..10).map(|_| rng.normal()).collect();
        let d: Design = x.into();
        let labels = reduction_labels(24);
        let opts = PrimalOptions::default();
        let red = ReducedSamples::new(&d, &y, 0.6);
        let first = primal_newton(&red, &labels, 4.0, &opts, None);
        let solo = primal_newton(&red, &labels, 4.0, &opts, Some(&first.w));
        let (batch, _) = primal_newton_batch(
            &d,
            &y,
            &[PrimalBatchPoint { t: 0.6, c: 4.0, w0: Some(first.w.clone()) }],
            &opts,
            None,
            None,
        );
        assert_eq!(solo.newton_iters, batch[0].newton_iters);
        for i in 0..10 {
            assert_eq!(solo.w[i].to_bits(), batch[0].w[i].to_bits(), "i={i}");
        }
    }

    /// The masked (shrink-off) fallback inside the batch must also match
    /// its solo twin.
    #[test]
    fn batch_masked_fallback_matches_solo() {
        use crate::linalg::Design;
        let mut rng = Rng::seed_from(141);
        let x = Mat::from_fn(12, 20, |_, _| rng.normal());
        let y: Vec<f64> = (0..12).map(|_| rng.normal()).collect();
        let d: Design = x.into();
        let labels = reduction_labels(20);
        let opts = PrimalOptions { shrink: false, ..Default::default() };
        let points: Vec<PrimalBatchPoint> = [(0.5, 2.0), (0.9, 6.0)]
            .iter()
            .map(|&(t, c)| PrimalBatchPoint { t, c, w0: None })
            .collect();
        let (batch, stats) = primal_newton_batch(&d, &y, &points, &opts, None, None);
        assert_eq!(stats.panel_builds, 0, "shrink off ⇒ no gathers");
        assert_eq!(stats.batched_rhs, 0, "masked members never group");
        for (s, pt) in batch.iter().zip(&points) {
            let red = ReducedSamples::new(&d, &y, pt.t);
            let solo = primal_newton(&red, &labels, pt.c, &opts, None);
            assert_eq!(solo.newton_iters, s.newton_iters);
            for i in 0..12 {
                assert_eq!(solo.w[i].to_bits(), s.w[i].to_bits(), "i={i}");
            }
        }
    }

    /// The shrinking solve over the SVEN reduction (the production
    /// configuration) must match the masked solve there too.
    #[test]
    fn gathered_reduction_solve_matches_masked() {
        use super::super::samples::{reduction_labels, ReducedSamples};
        use crate::linalg::Design;
        let mut rng = Rng::seed_from(138);
        let x = Mat::from_fn(12, 40, |_, _| rng.normal());
        let y: Vec<f64> = (0..12).map(|_| rng.normal()).collect();
        let d: Design = x.into();
        let red = ReducedSamples::new(&d, &y, 0.7);
        let labels = reduction_labels(40);
        let on = primal_newton(&red, &labels, 8.0, &PrimalOptions::default(), None);
        let off = primal_newton(
            &red,
            &labels,
            8.0,
            &PrimalOptions { shrink: false, ..Default::default() },
            None,
        );
        for j in 0..12 {
            assert!(
                (on.w[j] - off.w[j]).abs() < 1e-5,
                "j={j}: {} vs {}",
                on.w[j],
                off.w[j]
            );
        }
    }

    /// Mixed precision must land on the f64 optimum (the refinement loop
    /// guarantees every Newton direction meets the f64 CG tolerance) for
    /// dense and sparse designs, shrinking on and off.
    #[test]
    fn mixed_precision_solve_matches_f64() {
        use crate::linalg::{Design, DesignShadowF32};
        let mut rng = Rng::seed_from(142);
        let x = Mat::from_fn(13, 28, |_, _| {
            if rng.bernoulli(0.7) {
                rng.normal()
            } else {
                0.0
            }
        });
        let y: Vec<f64> = (0..13).map(|_| rng.normal()).collect();
        let labels = reduction_labels(28);
        for design in [
            Design::from(x.clone()),
            Design::from(crate::linalg::Csr::from_dense(&x, 0.0)),
        ] {
            let shadow = DesignShadowF32::of(&design);
            for shrink in [true, false] {
                let opts = PrimalOptions { shrink, ..Default::default() };
                let exact = primal_newton(
                    &ReducedSamples::new(&design, &y, 0.7),
                    &labels,
                    6.0,
                    &opts,
                    None,
                );
                let mixed = primal_newton(
                    &ReducedSamples::with_shadow(&design, &y, 0.7, &shadow),
                    &labels,
                    6.0,
                    &opts,
                    None,
                );
                assert!(
                    mixed.refine_passes_total > 0,
                    "mixed solve never refined (sparse={} shrink={shrink})",
                    design.is_sparse()
                );
                assert!(exact.converged && mixed.converged);
                for i in 0..13 {
                    assert!(
                        (exact.w[i] - mixed.w[i]).abs() < 1e-6,
                        "sparse={} shrink={shrink} i={i}: {} vs {}",
                        design.is_sparse(),
                        exact.w[i],
                        mixed.w[i]
                    );
                }
            }
        }
    }

    /// Mixed batch vs mixed solo: the bit-identity contract holds in the
    /// mixed tier too (members run per-member refinement, never group).
    #[test]
    fn mixed_batch_matches_mixed_solo_bit_for_bit() {
        use crate::linalg::{Design, DesignShadowF32};
        let mut rng = Rng::seed_from(143);
        let x = Mat::from_fn(12, 26, |_, _| rng.normal());
        let y: Vec<f64> = (0..12).map(|_| rng.normal()).collect();
        let d: Design = x.into();
        let shadow = DesignShadowF32::of(&d);
        let labels = reduction_labels(26);
        let opts = PrimalOptions { shrink_max_frac: 1.0, ..Default::default() };
        let points: Vec<PrimalBatchPoint> = [(0.4, 3.0), (0.7, 5.0), (0.7, 5.0)]
            .iter()
            .map(|&(t, c)| PrimalBatchPoint { t, c, w0: None })
            .collect();
        let (batch, stats) = primal_newton_batch(&d, &y, &points, &opts, Some(&shadow), None);
        assert_eq!(stats.batched_rhs, 0, "mixed members must not group");
        for (s, pt) in batch.iter().zip(&points) {
            let red = ReducedSamples::with_shadow(&d, &y, pt.t, &shadow);
            let solo = primal_newton(&red, &labels, pt.c, &opts, None);
            assert_eq!(solo.newton_iters, s.newton_iters);
            assert_eq!(solo.cg_iters_total, s.cg_iters_total);
            assert_eq!(solo.refine_passes_total, s.refine_passes_total);
            for i in 0..12 {
                assert_eq!(solo.w[i].to_bits(), s.w[i].to_bits(), "w i={i}");
            }
            for (a, b) in solo.alpha.iter().zip(&s.alpha) {
                assert_eq!(a.to_bits(), b.to_bits());
            }
        }
    }

    /// Guardrail ladder, eviction leg: a member with a poisoned
    /// regularisation parameter is failed alone — flagged `broken`,
    /// never `converged` — while its batch siblings stay bit-identical
    /// to their solo runs (the fused passes are per-column independent).
    #[test]
    fn nan_member_is_evicted_and_siblings_stay_bit_identical() {
        use crate::linalg::Design;
        let mut rng = Rng::seed_from(151);
        let x = Mat::from_fn(14, 30, |_, _| rng.normal());
        let y: Vec<f64> = (0..14).map(|_| rng.normal()).collect();
        let d: Design = x.into();
        let labels = reduction_labels(30);
        let opts = PrimalOptions { shrink_max_frac: 1.0, ..Default::default() };
        let points: Vec<PrimalBatchPoint> = [(0.4, 3.0), (0.7, f64::NAN), (1.1, 8.0)]
            .iter()
            .map(|&(t, c)| PrimalBatchPoint { t, c, w0: None })
            .collect();
        let (batch, _) = primal_newton_batch(&d, &y, &points, &opts, None, None);
        let sick = &batch[1];
        assert!(sick.broken.is_some(), "NaN C must trip the guardrail");
        assert!(!sick.converged);
        assert_eq!(sick.newton_iters, 0, "evicted before any round");
        for &j in &[0usize, 2] {
            let red = ReducedSamples::new(&d, &y, points[j].t);
            let solo = primal_newton(&red, &labels, points[j].c, &opts, None);
            assert!(solo.converged && batch[j].converged);
            assert!(batch[j].broken.is_none());
            for i in 0..14 {
                assert_eq!(solo.w[i].to_bits(), batch[j].w[i].to_bits(), "j={j} i={i}");
            }
        }
        // A poisoned budget t corrupts the margins instead of the
        // objective sum — the margin guard must catch that form too.
        let (b2, _) = primal_newton_batch(
            &d,
            &y,
            &[PrimalBatchPoint { t: f64::NAN, c: 5.0, w0: None }],
            &opts,
            None,
            None,
        );
        assert!(b2[0].broken.is_some(), "NaN t must trip the margin guard");
        assert!(!b2[0].converged);
    }

    /// Solo solves walk the same guardrail: a poisoned C is flagged
    /// `broken`, never reported converged.
    #[test]
    fn solo_nan_c_is_flagged_broken() {
        let (s, y) = blobs(10, 3, 0.5, 152);
        let r = primal_newton(&s, &y, f64::NAN, &PrimalOptions::default(), None);
        assert!(r.broken.is_some());
        assert!(!r.converged);
        assert_eq!(r.newton_iters, 0);
    }

    /// An already-expired deadline aborts every member at the first
    /// round boundary: no Newton work, `aborted` set, never `converged`
    /// — the coordinator must treat such iterates as non-results.
    #[test]
    fn expired_ctl_aborts_at_round_boundary() {
        use super::super::SolveCtl;
        use crate::linalg::Design;
        let mut rng = Rng::seed_from(153);
        let x = Mat::from_fn(12, 24, |_, _| rng.normal());
        let y: Vec<f64> = (0..12).map(|_| rng.normal()).collect();
        let d: Design = x.into();
        let expired = || true;
        let ctl = SolveCtl::new(&expired);
        let points: Vec<PrimalBatchPoint> = [(0.5, 2.0), (0.9, 6.0)]
            .iter()
            .map(|&(t, c)| PrimalBatchPoint { t, c, w0: None })
            .collect();
        let (batch, _) =
            primal_newton_batch(&d, &y, &points, &PrimalOptions::default(), None, Some(&ctl));
        for s in &batch {
            assert!(s.aborted);
            assert!(!s.converged);
            assert_eq!(s.newton_iters, 0, "no Newton round may run past the deadline");
        }
    }

    /// A deadline that never fires must leave the batch bit-identical
    /// to the uncontrolled run — polling is observation, not steering.
    #[test]
    fn unexpired_ctl_is_bit_identical_to_uncontrolled() {
        use super::super::SolveCtl;
        use crate::linalg::Design;
        let mut rng = Rng::seed_from(154);
        let x = Mat::from_fn(12, 24, |_, _| rng.normal());
        let y: Vec<f64> = (0..12).map(|_| rng.normal()).collect();
        let d: Design = x.into();
        let live = || false;
        let ctl = SolveCtl::new(&live);
        let opts = PrimalOptions { shrink_max_frac: 1.0, ..Default::default() };
        let points: Vec<PrimalBatchPoint> = [(0.4, 3.0), (0.7, 5.0)]
            .iter()
            .map(|&(t, c)| PrimalBatchPoint { t, c, w0: None })
            .collect();
        let (a, _) = primal_newton_batch(&d, &y, &points, &opts, None, Some(&ctl));
        let (b, _) = primal_newton_batch(&d, &y, &points, &opts, None, None);
        for (ra, rb) in a.iter().zip(&b) {
            assert!(!ra.aborted && ra.broken.is_none());
            assert_eq!(ra.newton_iters, rb.newton_iters);
            for (wa, wb) in ra.w.iter().zip(&rb.w) {
                assert_eq!(wa.to_bits(), wb.to_bits());
            }
        }
    }
}
