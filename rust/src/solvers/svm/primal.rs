//! Primal Newton-CG for the squared-hinge SVM (Chapelle 2007, §4–5),
//! with active-set shrinking.
//!
//! The objective `f(w) = ½‖w‖² + C·Σᵢ max(0, 1 − ŷᵢ wᵀx̂ᵢ)²` is piecewise
//! quadratic and differentiable; on a fixed support-vector set it *is*
//! quadratic, so Newton converges in a finite number of set changes. The
//! Newton system is solved matrix-free by CG (the computation the paper
//! offloads to GPU BLAS; here it is the computation the XLA artifact
//! performs).
//!
//! Three structural optimizations over the textbook loop:
//!
//! - **Active-set gather (shrinking).** The masked Hessian-vector
//!   product streams the full m × d sample matrix (two full GEMVs per
//!   CG iteration) even when few rows are support vectors. Instead, the
//!   SV rows are gathered into a reused compact panel ([`GatheredRows`])
//!   and every CG product runs on the m_sv × d submatrix — one gather
//!   costs about one gathered product and is amortized over the whole
//!   CG solve. The panel is re-gathered only when the set changes (on
//!   the stable tail of the solve it never is).
//! - **Batched margin refresh.** Each Newton iteration computes
//!   `X̂·[w, δ]` as one fused 2-column multi-RHS product
//!   ([`SampleSet::matvec_multi`]): the data is streamed once for both
//!   the exact margin refresh and the line-search direction product,
//!   instead of once per vector.
//! - **O(m) line search.** With `X̂δ` cached, each backtracking trial
//!   evaluates margins as `o + step·(X̂δ)` in O(m + d) — the seed
//!   re-ran a full O(m·d) `matvec` per trial.

use super::samples::{GatheredRows, SampleSet};
use crate::linalg::{cg_solve_with, vecops, CgOptions, CgScratch, LinOp, MultiVec};
use std::cell::RefCell;

/// Options for [`primal_newton`].
#[derive(Clone, Debug)]
pub struct PrimalOptions {
    /// Gradient-norm tolerance, relative to √d.
    pub tol: f64,
    pub max_newton: usize,
    pub cg: CgOptions,
    /// Active-set shrinking: gather the SV rows into a compact panel
    /// (re-gathered only on set change) and run the CG Hessian products
    /// on it. Disable to force the masked full-matrix products (the
    /// pre-shrinking behavior, kept for comparison).
    pub shrink: bool,
    /// Gather only while `m_sv ≤ shrink_max_frac · m`; above it the
    /// masked product already touches mostly-useful rows and the gather
    /// copy is waste.
    pub shrink_max_frac: f64,
}

impl Default for PrimalOptions {
    fn default() -> Self {
        PrimalOptions {
            tol: 1e-10,
            max_newton: 100,
            cg: CgOptions { tol: 1e-12, max_iter: 0 },
            shrink: true,
            shrink_max_frac: 0.75,
        }
    }
}

/// Result of a primal solve.
#[derive(Clone, Debug)]
pub struct PrimalResult {
    pub w: Vec<f64>,
    /// Dual variables recovered as `α_i = 2C·max(0, 1 − ŷᵢ wᵀx̂ᵢ)`.
    pub alpha: Vec<f64>,
    pub newton_iters: usize,
    pub cg_iters_total: usize,
    /// How many times the SV rows were gathered into the compact panel
    /// (0 ⇒ the solve ran entirely on masked full-matrix products).
    pub gather_rebuilds: usize,
    pub converged: bool,
    /// Final objective value.
    pub objective: f64,
}

/// Hessian operator `v ↦ v + 2C·X̂ᵀ(sv_mask ⊙ (X̂·v))` over the *full*
/// sample matrix — used while the SV set is still changing. The two
/// products route through the banded parallel GEMV layer in
/// [`crate::linalg`] (deterministic fixed-chunk reduction for the
/// transpose side), so the CG inner loop scales with the `Parallelism`
/// knob without giving up bit-stable iterates.
struct MaskedHess<'a, S: SampleSet> {
    samples: &'a S,
    sv_mask: &'a [f64], // 1.0 for support vectors, else 0.0
    two_c: f64,
    buf: &'a RefCell<Vec<f64>>,
}

impl<S: SampleSet> LinOp for MaskedHess<'_, S> {
    fn dim(&self) -> usize {
        self.samples.d()
    }

    fn apply(&self, v: &[f64], out: &mut [f64]) {
        let mut xm = self.buf.borrow_mut();
        xm.resize(self.samples.m(), 0.0);
        self.samples.matvec(v, &mut xm);
        for (o, m) in xm.iter_mut().zip(self.sv_mask.iter()) {
            *o *= m;
        }
        self.samples.matvec_t(&xm, out);
        for i in 0..out.len() {
            out[i] = v[i] + self.two_c * out[i];
        }
    }
}

/// Hessian operator over the gathered SV panel: `v ↦ v + 2C·Gᵀ(G·v)`
/// with G the m_sv × d submatrix of support-vector rows — no mask, no
/// dead rows. Products cost O(m_sv·d) (dense) / O(nnz(SV cols)) (sparse)
/// instead of O(m·d).
struct GatheredHess<'a, S: SampleSet> {
    samples: &'a S,
    panel: &'a GatheredRows,
    two_c: f64,
    buf: &'a RefCell<Vec<f64>>,
}

impl<S: SampleSet> LinOp for GatheredHess<'_, S> {
    fn dim(&self) -> usize {
        self.samples.d()
    }

    fn apply(&self, v: &[f64], out: &mut [f64]) {
        let mut gm = self.buf.borrow_mut();
        gm.resize(self.panel.m(), 0.0);
        self.samples.gathered_matvec(self.panel, v, &mut gm);
        self.samples.gathered_matvec_t(self.panel, &gm, out);
        for i in 0..out.len() {
            out[i] = v[i] + self.two_c * out[i];
        }
    }
}

/// Objective, gradient pieces, and support mask at `w`.
/// Returns (objective, margins o = X̂w).
fn evaluate<S: SampleSet>(
    samples: &S,
    yhat: &[f64],
    c: f64,
    w: &[f64],
    o: &mut [f64],
    slack: &mut [f64],
    mask: &mut [f64],
) -> f64 {
    samples.matvec(w, o);
    let mut loss = 0.0;
    for i in 0..o.len() {
        let s = 1.0 - yhat[i] * o[i];
        if s > 0.0 {
            slack[i] = s;
            mask[i] = 1.0;
            loss += s * s;
        } else {
            slack[i] = 0.0;
            mask[i] = 0.0;
        }
    }
    0.5 * vecops::norm2_sq(w) + c * loss
}

/// Minimize the primal squared-hinge objective; warm-startable via `w0`.
pub fn primal_newton<S: SampleSet>(
    samples: &S,
    yhat: &[f64],
    c: f64,
    opts: &PrimalOptions,
    w0: Option<&[f64]>,
) -> PrimalResult {
    let (m, d) = (samples.m(), samples.d());
    assert_eq!(yhat.len(), m);
    let mut w = w0.map(|w| w.to_vec()).unwrap_or_else(|| vec![0.0; d]);
    assert_eq!(w.len(), d);

    let mut o = vec![0.0; m];
    let mut slack = vec![0.0; m];
    let mut mask = vec![0.0; m];
    let mut ys = vec![0.0; m];
    let mut grad = vec![0.0; d];
    let mut delta = vec![0.0; d];
    let mut cg_scratch = CgScratch::new();
    let hess_buf = RefCell::new(vec![0.0; m]);
    // [w, δ] input panel and its [X̂w, X̂δ] image — the batched margin
    // refresh (one fused pass per Newton iteration).
    let mut wd = MultiVec::zeros(d, 2);
    let mut od = MultiVec::zeros(m, 2);
    let mut cg_total = 0usize;
    let mut gather_rebuilds = 0usize;
    let mut converged = false;

    let mut obj = evaluate(samples, yhat, c, &w, &mut o, &mut slack, &mut mask);
    let sv_of = |mask: &[f64]| -> Vec<usize> {
        (0..mask.len()).filter(|&i| mask[i] == 1.0).collect()
    };
    let mut sv = sv_of(&mask);
    let mut gathered_set: Vec<usize> = Vec::new();
    let mut panel = GatheredRows::new();

    let mut newton = 0;
    while newton < opts.max_newton {
        // grad = w − 2C·X̂ᵀ(ŷ ⊙ slack) restricted to support vectors
        for i in 0..m {
            ys[i] = yhat[i] * slack[i] * mask[i];
        }
        samples.matvec_t(&ys, &mut grad);
        for i in 0..d {
            grad[i] = w[i] - 2.0 * c * grad[i];
        }
        let gnorm = vecops::norm2(&grad) / (d as f64).sqrt();
        if gnorm <= opts.tol * (1.0 + obj.abs()) {
            converged = true;
            break;
        }

        // Newton direction: H δ = −grad (matrix-free CG) over the
        // gathered SV panel when the set is small enough to pay. One
        // gather costs about one gathered product and is amortized over
        // every CG iteration of the step (and over later steps on the
        // same set — the panel is rebuilt only when the set changes, and
        // on the stable tail of the solve it never is).
        let use_gather = opts.shrink
            && !sv.is_empty()
            && (sv.len() as f64) <= opts.shrink_max_frac * m as f64;
        if use_gather && gathered_set != sv {
            samples.gather_rows_into(&sv, &mut panel);
            gathered_set.clone_from(&sv);
            gather_rebuilds += 1;
        }
        let rhs: Vec<f64> = grad.iter().map(|g| -g).collect();
        delta.fill(0.0);
        let cg_out = if use_gather {
            let hess = GatheredHess { samples, panel: &panel, two_c: 2.0 * c, buf: &hess_buf };
            cg_solve_with(&hess, &rhs, &mut delta, &opts.cg, &mut cg_scratch)
        } else {
            let hess = MaskedHess { samples, sv_mask: &mask, two_c: 2.0 * c, buf: &hess_buf };
            cg_solve_with(&hess, &rhs, &mut delta, &opts.cg, &mut cg_scratch)
        };
        cg_total += cg_out.iters;

        // Batched margin refresh: [X̂w, X̂δ] in one fused panel product —
        // exact margins for the line search (no incremental drift) plus
        // the cached direction product, for one streaming pass.
        wd.col_mut(0).copy_from_slice(&w);
        wd.col_mut(1).copy_from_slice(&delta);
        samples.matvec_multi(&wd, &mut od);
        let ow = od.col(0);
        let xd = od.col(1);

        // Line search on cached margins: the full Newton step is exact on
        // a stable SV set; back off geometrically if the set change
        // increased the objective. Each trial is O(m) + O(1) (the ‖w‖²
        // term expands quadratically in step).
        let wnorm_sq = vecops::norm2_sq(&w);
        let wdot = vecops::dot(&w, &delta);
        let dnorm_sq = vecops::norm2_sq(&delta);
        let mut step = 1.0;
        let mut accepted = false;
        for _ in 0..40 {
            let mut loss = 0.0;
            for i in 0..m {
                let s = 1.0 - yhat[i] * (ow[i] + step * xd[i]);
                if s > 0.0 {
                    loss += s * s;
                }
            }
            let quad = wnorm_sq + 2.0 * step * wdot + step * step * dnorm_sq;
            let obj_try = 0.5 * quad + c * loss;
            if obj_try <= obj + 1e-12 * obj.abs() {
                accepted = true;
                break;
            }
            step *= 0.5;
        }
        newton += 1;
        if !accepted {
            // No decrease along the Newton direction — numerically at the
            // optimum. State (o/slack/mask) still describes w; stop.
            converged = true;
            break;
        }

        // Accept: w ← w + step·δ; margins from the cached panel (exact —
        // ow is this iteration's fused refresh of X̂w).
        for i in 0..d {
            w[i] += step * delta[i];
        }
        let mut loss = 0.0;
        for i in 0..m {
            o[i] = ow[i] + step * xd[i];
            let s = 1.0 - yhat[i] * o[i];
            if s > 0.0 {
                slack[i] = s;
                mask[i] = 1.0;
                loss += s * s;
            } else {
                slack[i] = 0.0;
                mask[i] = 0.0;
            }
        }
        obj = 0.5 * vecops::norm2_sq(&w) + c * loss;
        sv = sv_of(&mask);
    }

    // α_i = 2C·slack_i at the final iterate.
    let _ = evaluate(samples, yhat, c, &w, &mut o, &mut slack, &mut mask);
    let alpha: Vec<f64> = slack.iter().map(|s| 2.0 * c * s).collect();
    PrimalResult {
        w,
        alpha,
        newton_iters: newton,
        cg_iters_total: cg_total,
        gather_rebuilds,
        converged,
        objective: obj,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use super::super::samples::DenseSamples;
    use crate::linalg::Mat;
    use crate::rng::Rng;

    /// Linearly separable toy set: two Gaussian blobs.
    fn blobs(m_half: usize, d: usize, gap: f64, seed: u64) -> (DenseSamples, Vec<f64>) {
        let mut rng = Rng::seed_from(seed);
        let mut x = Mat::zeros(2 * m_half, d);
        let mut y = vec![0.0; 2 * m_half];
        for i in 0..2 * m_half {
            let cls = if i < m_half { 1.0 } else { -1.0 };
            y[i] = cls;
            for j in 0..d {
                let center = if j == 0 { cls * gap } else { 0.0 };
                x.set(i, j, center + 0.3 * rng.normal());
            }
        }
        (DenseSamples { x }, y)
    }

    fn objective(s: &DenseSamples, y: &[f64], c: f64, w: &[f64]) -> f64 {
        let mut o = vec![0.0; s.m()];
        s.matvec(w, &mut o);
        let loss: f64 = (0..s.m())
            .map(|i| {
                let sl = (1.0 - y[i] * o[i]).max(0.0);
                sl * sl
            })
            .sum();
        0.5 * vecops::norm2_sq(w) + c * loss
    }

    #[test]
    fn separates_blobs() {
        let (s, y) = blobs(20, 4, 2.0, 131);
        let r = primal_newton(&s, &y, 1.0, &PrimalOptions::default(), None);
        assert!(r.converged);
        let mut o = vec![0.0; s.m()];
        s.matvec(&r.w, &mut o);
        let correct = (0..s.m()).filter(|&i| y[i] * o[i] > 0.0).count();
        assert!(correct as f64 >= 0.95 * s.m() as f64, "correct {correct}");
    }

    #[test]
    fn gradient_zero_at_solution() {
        let (s, y) = blobs(15, 3, 1.0, 132);
        let c = 2.5;
        let r = primal_newton(&s, &y, c, &PrimalOptions::default(), None);
        // finite-difference check of stationarity
        let f0 = objective(&s, &y, c, &r.w);
        for j in 0..3 {
            for d in [-1e-5, 1e-5] {
                let mut w = r.w.clone();
                w[j] += d;
                assert!(objective(&s, &y, c, &w) >= f0 - 1e-9, "j={j}");
            }
        }
    }

    #[test]
    fn alpha_consistent_with_slack() {
        let (s, y) = blobs(10, 3, 0.5, 133);
        let c = 1.7;
        let r = primal_newton(&s, &y, c, &PrimalOptions::default(), None);
        let mut o = vec![0.0; s.m()];
        s.matvec(&r.w, &mut o);
        for i in 0..s.m() {
            let expect = 2.0 * c * (1.0 - y[i] * o[i]).max(0.0);
            assert!((r.alpha[i] - expect).abs() < 1e-8, "i={i}");
        }
    }

    #[test]
    fn dual_primal_w_relation() {
        // w must equal Σ ŷᵢ αᵢ x̂ᵢ / ... in our scaling: stationarity gives
        // w = 2C Σ ŷᵢ slackᵢ x̂ᵢ = Σ ŷᵢ αᵢ x̂ᵢ.
        let (s, y) = blobs(12, 4, 0.8, 134);
        let r = primal_newton(&s, &y, 3.0, &PrimalOptions::default(), None);
        let ya: Vec<f64> = (0..s.m()).map(|i| y[i] * r.alpha[i]).collect();
        let mut w_rec = vec![0.0; 4];
        s.matvec_t(&ya, &mut w_rec);
        for j in 0..4 {
            assert!((w_rec[j] - r.w[j]).abs() < 1e-6, "j={j}: {} vs {}", w_rec[j], r.w[j]);
        }
    }

    #[test]
    fn warm_start_converges_immediately() {
        let (s, y) = blobs(15, 4, 1.0, 135);
        let r1 = primal_newton(&s, &y, 1.0, &PrimalOptions::default(), None);
        let r2 = primal_newton(&s, &y, 1.0, &PrimalOptions::default(), Some(&r1.w));
        assert!(r2.newton_iters <= 1, "warm start took {}", r2.newton_iters);
    }

    #[test]
    fn larger_c_fits_harder() {
        let (s, y) = blobs(15, 3, 0.3, 136);
        let lo = primal_newton(&s, &y, 0.1, &PrimalOptions::default(), None);
        let hi = primal_newton(&s, &y, 50.0, &PrimalOptions::default(), None);
        // total squared slack must not increase with C
        let slack_sum = |r: &PrimalResult| -> f64 {
            let mut o = vec![0.0; s.m()];
            s.matvec(&r.w, &mut o);
            (0..s.m()).map(|i| (1.0 - y[i] * o[i]).max(0.0).powi(2)).sum()
        };
        assert!(slack_sum(&hi) <= slack_sum(&lo) + 1e-9);
    }

    /// Shrinking on/off must land on the same optimum (the gathered and
    /// masked Hessians describe the same quadratic), and the widely
    /// separated blobs (few SVs) must actually trigger a gather.
    #[test]
    fn gathered_and_masked_solves_agree() {
        let (s, y) = blobs(30, 5, 2.0, 137);
        let c = 4.0;
        let on = primal_newton(&s, &y, c, &PrimalOptions::default(), None);
        let off = primal_newton(
            &s,
            &y,
            c,
            &PrimalOptions { shrink: false, ..Default::default() },
            None,
        );
        assert_eq!(off.gather_rebuilds, 0);
        // Widely separated blobs end with few SVs, so the shrinking path
        // must actually engage.
        assert!(on.gather_rebuilds >= 1, "gather never engaged");
        assert!(on.converged && off.converged);
        for j in 0..5 {
            assert!(
                (on.w[j] - off.w[j]).abs() < 1e-6,
                "j={j}: {} vs {}",
                on.w[j],
                off.w[j]
            );
        }
        let obj_on = objective(&s, &y, c, &on.w);
        let obj_off = objective(&s, &y, c, &off.w);
        assert!((obj_on - obj_off).abs() <= 1e-9 * (1.0 + obj_off.abs()));
    }

    /// The shrinking solve over the SVEN reduction (the production
    /// configuration) must match the masked solve there too.
    #[test]
    fn gathered_reduction_solve_matches_masked() {
        use super::super::samples::{reduction_labels, ReducedSamples};
        use crate::linalg::Design;
        let mut rng = Rng::seed_from(138);
        let x = Mat::from_fn(12, 40, |_, _| rng.normal());
        let y: Vec<f64> = (0..12).map(|_| rng.normal()).collect();
        let d: Design = x.into();
        let red = ReducedSamples { x: &d, y: &y, t: 0.7 };
        let labels = reduction_labels(40);
        let on = primal_newton(&red, &labels, 8.0, &PrimalOptions::default(), None);
        let off = primal_newton(
            &red,
            &labels,
            8.0,
            &PrimalOptions { shrink: false, ..Default::default() },
            None,
        );
        for j in 0..12 {
            assert!(
                (on.w[j] - off.w[j]).abs() < 1e-5,
                "j={j}: {} vs {}",
                on.w[j],
                off.w[j]
            );
        }
    }
}
