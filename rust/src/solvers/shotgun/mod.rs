//! Shotgun: parallel coordinate descent for L1-regularized regression
//! (Bradley, Kyrola, Bickson & Guestrin, ICML 2011) — the paper's parallel
//! CPU baseline.
//!
//! P worker threads repeatedly pick random coordinates and apply the
//! soft-threshold update *concurrently*; the shared residual vector is
//! updated with atomic compare-and-swap f64 arithmetic. Bradley et al.
//! prove convergence as long as P is below a spectral threshold of XᵀX;
//! like the original implementation, we expose P and default it to the
//! machine's parallelism.
//!
//! The update body is written once over [`DesignCols`] — dense designs
//! iterate a contiguous transposed copy, sparse designs iterate the CSC
//! mirror — so Shotgun's per-update cost is O(nnz(x_j)) on sparse data
//! (exactly the regime Bradley et al. built it for) without densifying.

use crate::linalg::{vecops, Design, DesignCols, Mat};
use crate::rng::Rng;
use std::sync::atomic::{AtomicU64, Ordering};

/// Configuration for a Shotgun solve (penalized Lasso / Elastic Net form,
/// same convention as [`crate::solvers::glmnet`]).
#[derive(Clone, Debug)]
pub struct ShotgunConfig {
    pub kappa: f64,
    pub tol: f64,
    pub max_epochs: usize,
    /// Parallel updates per round (0 ⇒ available parallelism).
    pub threads: usize,
    pub seed: u64,
}

impl Default for ShotgunConfig {
    fn default() -> Self {
        ShotgunConfig { kappa: 1.0, tol: 1e-9, max_epochs: 10_000, threads: 0, seed: 0x5407 }
    }
}

#[derive(Clone, Debug)]
pub struct ShotgunResult {
    pub beta: Vec<f64>,
    pub epochs: usize,
    pub converged: bool,
}

/// Atomic f64 add via CAS.
#[inline]
fn atomic_add(cell: &AtomicU64, delta: f64) {
    let mut cur = cell.load(Ordering::Relaxed);
    loop {
        let new = f64::from_bits(cur) + delta;
        match cell.compare_exchange_weak(cur, new.to_bits(), Ordering::Relaxed, Ordering::Relaxed)
        {
            Ok(_) => return,
            Err(actual) => cur = actual,
        }
    }
}

/// Solve `min 1/(2n)‖Xβ−y‖² + λ(κ|β|₁ + (1−κ)/2‖β‖²)` by parallel CD.
pub fn solve_shotgun(
    x: &Mat,
    y: &[f64],
    lambda: f64,
    cfg: &ShotgunConfig,
    beta0: Option<&[f64]>,
) -> ShotgunResult {
    let cols = DesignCols::Dense(x.transpose());
    shotgun_core(&cols, x.rows(), x.cols(), y, lambda, cfg, beta0)
}

/// [`solve_shotgun`] over a [`Design`]: sparse designs run every update
/// through the CSC mirror with no densification.
pub fn solve_shotgun_design(
    design: &Design,
    y: &[f64],
    lambda: f64,
    cfg: &ShotgunConfig,
    beta0: Option<&[f64]>,
) -> ShotgunResult {
    let cols = design.cols_view();
    shotgun_core(&cols, design.rows(), design.cols(), y, lambda, cfg, beta0)
}

fn shotgun_core(
    cols: &DesignCols,
    n: usize,
    p: usize,
    y: &[f64],
    lambda: f64,
    cfg: &ShotgunConfig,
    beta0: Option<&[f64]>,
) -> ShotgunResult {
    assert_eq!(y.len(), n);
    let nf = n as f64;
    let l1 = lambda * cfg.kappa;
    let l2 = lambda * (1.0 - cfg.kappa);
    let denom = 1.0 + l2;
    let threads = if cfg.threads == 0 {
        std::thread::available_parallelism().map(|v| v.get()).unwrap_or(4)
    } else {
        cfg.threads
    };
    let thresh = cfg.tol * vecops::norm2_sq(y).max(1e-300);

    let beta: Vec<AtomicU64> = (0..p)
        .map(|j| AtomicU64::new(beta0.map(|b| b[j]).unwrap_or(0.0).to_bits()))
        .collect();
    // residual r = y − Xβ, shared and atomically updated
    let r: Vec<AtomicU64> = {
        let mut r0 = y.to_vec();
        if let Some(b0) = beta0 {
            for j in 0..p {
                if b0[j] != 0.0 {
                    cols.col_axpy(j, -b0[j], &mut r0);
                }
            }
        }
        r0.into_iter().map(|v| AtomicU64::new(v.to_bits())).collect()
    };

    // One soft-threshold update of coordinate j against the shared
    // residual (racy reads/writes are fine per the Shotgun analysis);
    // returns d²·n of the applied change, 0.0 if the coordinate held.
    let update = |j: usize| -> f64 {
        let bj = f64::from_bits(beta[j].load(Ordering::Relaxed));
        let mut dotp = 0.0;
        cols.for_each_nz(j, |i, xij| {
            dotp += xij * f64::from_bits(r[i].load(Ordering::Relaxed));
        });
        let zj = dotp / nf + bj;
        let bj_new = vecops::soft_threshold(zj, l1) / denom;
        let d = bj_new - bj;
        if d != 0.0 {
            // racy but convergent: publish β then r
            beta[j].store(bj_new.to_bits(), Ordering::Relaxed);
            cols.for_each_nz(j, |i, xij| {
                atomic_add(&r[i], -d * xij);
            });
            d * d * nf
        } else {
            0.0
        }
    };

    let rng = Rng::seed_from(cfg.seed);
    let mut epochs = 0usize;
    let mut converged = false;

    while epochs < cfg.max_epochs {
        // One epoch = p coordinate updates spread over `threads` workers,
        // each drawing coordinates uniformly at random (with replacement),
        // exactly Shotgun's scheme.
        let updates_per_thread = p.div_ceil(threads);
        let max_delta: f64 = std::thread::scope(|s| {
            let handles: Vec<_> = (0..threads)
                .map(|tid| {
                    let mut trng = rng.substream((epochs * threads + tid) as u64);
                    let update = &update;
                    s.spawn(move || {
                        let mut local_max: f64 = 0.0;
                        for _ in 0..updates_per_thread {
                            local_max = local_max.max(update(trng.below(p)));
                        }
                        local_max
                    })
                })
                .collect();
            handles.into_iter().map(|h| h.join().unwrap()).fold(0.0, f64::max)
        });
        epochs += 1;
        if max_delta < thresh {
            // Random sampling with replacement can miss coordinates in an
            // epoch; confirm convergence with one deterministic full sweep
            // before declaring victory.
            let mut confirm_max = 0.0f64;
            for j in 0..p {
                confirm_max = confirm_max.max(update(j));
            }
            epochs += 1;
            if confirm_max < thresh {
                converged = true;
                break;
            }
        }
    }

    let beta_out: Vec<f64> =
        beta.iter().map(|b| f64::from_bits(b.load(Ordering::Relaxed))).collect();
    ShotgunResult { beta: beta_out, epochs, converged }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::{synth_regression, SynthSpec};
    use crate::linalg::Csr;
    use crate::solvers::glmnet::{self, GlmnetConfig};

    fn data(n: usize, p: usize, seed: u64) -> (Mat, Vec<f64>) {
        let d = synth_regression(&SynthSpec { n, p, support: 6, seed, ..Default::default() });
        (d.x, d.y)
    }

    #[test]
    fn matches_glmnet_lasso() {
        let (x, y) = data(60, 30, 101);
        let lambda = glmnet::cd::lambda_max(&x, &y, 1.0) * 0.3;
        let g = glmnet::solve_penalized(
            &x,
            &y,
            lambda,
            &GlmnetConfig { kappa: 1.0, ..Default::default() },
            None,
        );
        let s = solve_shotgun(
            &x,
            &y,
            lambda,
            &ShotgunConfig { kappa: 1.0, tol: 1e-12, ..Default::default() },
            None,
        );
        assert!(s.converged);
        for j in 0..30 {
            assert!(
                (g.beta[j] - s.beta[j]).abs() < 1e-4,
                "j={j}: {} vs {}",
                g.beta[j],
                s.beta[j]
            );
        }
    }

    #[test]
    fn elastic_net_mixing_supported() {
        let (x, y) = data(50, 20, 102);
        let lambda = glmnet::cd::lambda_max(&x, &y, 0.5) * 0.2;
        let g = glmnet::solve_penalized(
            &x,
            &y,
            lambda,
            &GlmnetConfig { kappa: 0.5, ..Default::default() },
            None,
        );
        let s = solve_shotgun(
            &x,
            &y,
            lambda,
            &ShotgunConfig { kappa: 0.5, tol: 1e-12, ..Default::default() },
            None,
        );
        for j in 0..20 {
            assert!((g.beta[j] - s.beta[j]).abs() < 1e-4, "j={j}");
        }
    }

    #[test]
    fn single_thread_degenerates_to_cd() {
        let (x, y) = data(40, 15, 103);
        let lambda = glmnet::cd::lambda_max(&x, &y, 1.0) * 0.4;
        let s = solve_shotgun(
            &x,
            &y,
            lambda,
            &ShotgunConfig { kappa: 1.0, threads: 1, tol: 1e-12, ..Default::default() },
            None,
        );
        assert!(s.converged);
        let g = glmnet::solve_penalized(
            &x,
            &y,
            lambda,
            &GlmnetConfig { kappa: 1.0, ..Default::default() },
            None,
        );
        for j in 0..15 {
            assert!((g.beta[j] - s.beta[j]).abs() < 1e-4, "j={j}");
        }
    }

    #[test]
    fn warm_start_accepted() {
        let (x, y) = data(40, 15, 104);
        let lambda = glmnet::cd::lambda_max(&x, &y, 1.0) * 0.3;
        let cold = solve_shotgun(&x, &y, lambda, &ShotgunConfig::default(), None);
        let warm = solve_shotgun(&x, &y, lambda, &ShotgunConfig::default(), Some(&cold.beta));
        assert!(warm.epochs <= cold.epochs);
    }

    #[test]
    fn sparse_design_matches_dense_shotgun() {
        // Same seed + thread count ⇒ same coordinate draws; dense and
        // sparse column access converge to the same Lasso solution.
        let mut rng = crate::rng::Rng::seed_from(105);
        let x = Mat::from_fn(50, 24, |_, _| {
            if rng.bernoulli(0.2) {
                rng.normal()
            } else {
                0.0
            }
        });
        let y: Vec<f64> = (0..50).map(|_| rng.normal()).collect();
        let design = Design::from(Csr::from_dense(&x, 0.0));
        let lambda = glmnet::cd::lambda_max(&x, &y, 1.0) * 0.3;
        let cfg = ShotgunConfig { kappa: 1.0, threads: 2, tol: 1e-12, ..Default::default() };
        let dense = solve_shotgun(&x, &y, lambda, &cfg, None);
        let sparse = solve_shotgun_design(&design, &y, lambda, &cfg, None);
        assert!(dense.converged && sparse.converged);
        for j in 0..24 {
            assert!(
                (dense.beta[j] - sparse.beta[j]).abs() < 1e-5,
                "j={j}: {} vs {}",
                dense.beta[j],
                sparse.beta[j]
            );
        }
    }
}
