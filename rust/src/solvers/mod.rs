//! Solvers: the paper's SVEN reduction plus every baseline it is
//! evaluated against, each built from scratch.
//!
//! | module | paper comparator | algorithm |
//! |---|---|---|
//! | [`glmnet`] | glmnet (Friedman et al. 2010) | coordinate descent, covariance updates, active sets, warm-started path |
//! | [`shotgun`] | Shotgun (Bradley et al. 2011) | parallel stochastic coordinate descent (Lasso) |
//! | [`l1ls`] | L1_LS (Kim et al. 2007) | log-barrier interior point + PCG (Lasso) |
//! | [`svm`] | Chapelle 2007 primal/dual SVM | squared-hinge SVM Newton-CG, no bias — the reduction target |
//! | [`sven`] | the paper's contribution | Elastic Net → SVM reduction, backend-pluggable |

pub mod elastic_net;
pub mod glmnet;
pub mod l1ls;
pub mod shotgun;
pub mod svm;
pub mod sven;
