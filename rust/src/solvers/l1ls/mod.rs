//! L1_LS: log-barrier interior-point method for L1-regularized least
//! squares (Kim, Koh, Lustig, Boyd & Gorinevsky, 2007) — the paper's
//! third baseline (Lasso only, like the original MATLAB package).
//!
//! Solves `min ‖Xβ − y‖² + λ̄·|β|₁` through the bound reformulation
//! `min ‖Xβ − y‖² + λ̄·Σu  s.t. −u ≤ β ≤ u`, with a log barrier on the
//! bounds and truncated-Newton steps computed by preconditioned conjugate
//! gradients (the paper's PCG with the diagonal preconditioner).
//!
//! To interoperate with the glmnet-convention benches, [`solve_l1ls`]
//! takes the penalized-form λ and converts internally (λ̄ = 2nλκ).

use crate::linalg::{
    cg_solve_multi_with, cg_solve_with, vecops, CgOptions, CgScratch, LinOp, Mat, MultiLinOp,
    MultiVec,
};

/// Configuration (penalized-Lasso convention; κ fixed to 1).
#[derive(Clone, Debug)]
pub struct L1LsConfig {
    /// Relative duality-gap target.
    pub tol: f64,
    pub max_newton: usize,
    /// Barrier update factor μ.
    pub mu: f64,
    pub cg: CgOptions,
}

impl Default for L1LsConfig {
    fn default() -> Self {
        L1LsConfig {
            tol: 1e-8,
            max_newton: 400,
            mu: 2.0,
            cg: CgOptions { tol: 1e-3, max_iter: 5000 },
        }
    }
}

#[derive(Clone, Debug)]
pub struct L1LsResult {
    pub beta: Vec<f64>,
    pub newton_iters: usize,
    pub duality_gap: f64,
    pub converged: bool,
}

/// Schur-complement reduced Hessian `2t̄·XᵀX + D` as a CG operator,
/// applied via two X matvecs (never materializing XᵀX) — the structure
/// the Kim et al. PCG exploits for large sparse problems.
struct ReducedHessian<'a> {
    x: &'a Mat,
    two_tbar: f64,
    d: Vec<f64>,
    /// diag(2t̄·XᵀX) + d — Jacobi preconditioner
    precond_diag: Vec<f64>,
    scratch_n: std::cell::RefCell<Vec<f64>>,
}

impl LinOp for ReducedHessian<'_> {
    fn dim(&self) -> usize {
        self.x.cols()
    }

    fn apply(&self, v: &[f64], out: &mut [f64]) {
        let mut xn = self.scratch_n.borrow_mut();
        self.x.matvec_into(v, &mut xn);
        self.x.matvec_t_into(&xn, out);
        for i in 0..out.len() {
            out[i] = self.two_tbar * out[i] + self.d[i] * v[i];
        }
    }

    fn precond(&self, r: &[f64], out: &mut [f64]) -> bool {
        for i in 0..r.len() {
            out[i] = r[i] / self.precond_diag[i];
        }
        true
    }
}

/// Solve the penalized Lasso `1/(2n)‖Xβ−y‖² + λ|β|₁` by the Kim et al.
/// primal interior-point method.
pub fn solve_l1ls(x: &Mat, y: &[f64], lambda: f64, cfg: &L1LsConfig) -> L1LsResult {
    let (n, p) = (x.rows(), x.cols());
    // Kim et al. objective scale: ‖Xβ−y‖² + λ̄|β|₁ == 2n × glmnet form.
    let lam = 2.0 * n as f64 * lambda;

    let mut beta = vec![0.0; p];
    let mut u = vec![1.0; p];
    let mut tbar = 1.0f64.max(1.0 / lam);

    let col_sq: Vec<f64> = {
        let xt = x.transpose();
        (0..p).map(|j| vecops::norm2_sq(xt.row(j))).collect()
    };

    let mut newton_iters = 0usize;
    let mut gap = f64::INFINITY;
    let mut converged = false;
    // One CG workspace for the whole interior-point loop: the truncated
    // Newton below runs hundreds of CG solves on the same dimension.
    let mut cg_scratch = CgScratch::new();

    let mut r = vec![0.0; n]; // residual Xβ − y
    while newton_iters < cfg.max_newton {
        // residual and primal objective
        x.matvec_into(&beta, &mut r);
        vecops::axpy(-1.0, y, &mut r);
        let primal = vecops::norm2_sq(&r) + lam * vecops::norm1(&beta);

        // Dual feasible point ν = 2r·s with s chosen so ‖Xᵀν‖∞ ≤ λ̄
        // (Kim et al. eq. 5): G(ν) = −¼‖ν‖² − νᵀy.
        let xtr = x.matvec_t(&r);
        let inf = vecops::norm_inf(&xtr).max(1e-300);
        let s = (lam / (2.0 * inf)).min(1.0);
        let nu: Vec<f64> = r.iter().map(|v| 2.0 * s * v).collect();
        let g_dual = -0.25 * vecops::norm2_sq(&nu) - vecops::dot(&nu, y);
        gap = primal - g_dual;
        let rel_gap = gap / g_dual.abs().max(1e-300);
        if rel_gap <= cfg.tol || gap <= cfg.tol {
            converged = true;
            break;
        }

        // Barrier parameter update (Kim et al. §III-B):
        // t̄ ← max{ μ·min(2p/η, t̄), t̄ }.
        tbar = (cfg.mu * (2.0 * p as f64 / gap).min(tbar)).max(tbar);

        // Newton system on (β, u) with u eliminated by Schur complement.
        let f1: Vec<f64> = (0..p).map(|i| u[i] + beta[i]).collect();
        let f2: Vec<f64> = (0..p).map(|i| u[i] - beta[i]).collect();
        let grad_beta: Vec<f64> = {
            // t̄·(2Xᵀr) − (1/f1 − 1/f2)
            (0..p).map(|i| tbar * 2.0 * xtr[i] - (1.0 / f1[i] - 1.0 / f2[i])).collect()
        };
        let grad_u: Vec<f64> =
            (0..p).map(|i| tbar * lam - (1.0 / f1[i] + 1.0 / f2[i])).collect();

        let d1: Vec<f64> =
            (0..p).map(|i| 1.0 / (f1[i] * f1[i]) + 1.0 / (f2[i] * f2[i])).collect();
        let d2: Vec<f64> =
            (0..p).map(|i| 1.0 / (f1[i] * f1[i]) - 1.0 / (f2[i] * f2[i])).collect();
        // Reduced diagonal: D1 − D2²/D1
        let dred: Vec<f64> = (0..p).map(|i| d1[i] - d2[i] * d2[i] / d1[i]).collect();
        let rhs: Vec<f64> =
            (0..p).map(|i| -(grad_beta[i] - d2[i] / d1[i] * grad_u[i])).collect();

        let two_tbar = 2.0 * tbar;
        let op = ReducedHessian {
            x,
            two_tbar,
            precond_diag: (0..p)
                .map(|i| (two_tbar * col_sq[i] + dred[i]).max(1e-300))
                .collect(),
            d: dred,
            scratch_n: std::cell::RefCell::new(vec![0.0; n]),
        };
        let mut dbeta = vec![0.0; p];
        // Truncated Newton: CG accuracy tightens as the gap closes
        // (Kim et al.'s adaptive rule).
        let cg_opts = CgOptions {
            tol: (0.1 * rel_gap).clamp(cfg.cg.tol.min(1e-10), 1e-2),
            max_iter: cfg.cg.max_iter,
        };
        cg_solve_with(&op, &rhs, &mut dbeta, &cg_opts, &mut cg_scratch);
        let du: Vec<f64> =
            (0..p).map(|i| -(grad_u[i] + d2[i] * dbeta[i]) / d1[i]).collect();

        // Backtracking line search keeping u ± β strictly positive and
        // decreasing the barrier objective.
        let phi = |beta_t: &[f64], u_t: &[f64]| -> f64 {
            let mut rt = x.matvec(beta_t);
            vecops::axpy(-1.0, y, &mut rt);
            let mut val = tbar * (vecops::norm2_sq(&rt) + lam * u_t.iter().sum::<f64>());
            for i in 0..p {
                let a = u_t[i] + beta_t[i];
                let b = u_t[i] - beta_t[i];
                if a <= 0.0 || b <= 0.0 {
                    return f64::INFINITY;
                }
                val -= a.ln() + b.ln();
            }
            val
        };
        let phi0 = phi(&beta, &u);
        let gdot = vecops::dot(&grad_beta, &dbeta) + vecops::dot(&grad_u, &du);
        let mut step = 1.0;
        for _ in 0..50 {
            let bt: Vec<f64> = (0..p).map(|i| beta[i] + step * dbeta[i]).collect();
            let ut: Vec<f64> = (0..p).map(|i| u[i] + step * du[i]).collect();
            if phi(&bt, &ut) <= phi0 + 0.01 * step * gdot {
                beta = bt;
                u = ut;
                break;
            }
            step *= 0.5;
        }
        newton_iters += 1;
    }

    L1LsResult { beta, newton_iters, duality_gap: gap, converged }
}

/// The [`ReducedHessian`] family across λ's: member `j` is
/// `2t̄_j·XᵀX + D_j` over one shared X, so every blocked-CG iteration
/// streams X once for all live interior-point systems. Per-column bits
/// match the solo operator exactly (the fused X kernels keep the
/// single-RHS reduction order; the diagonal terms are per-column scalar
/// work).
struct BatchReducedHessian<'a> {
    x: &'a Mat,
    two_tbars: Vec<f64>,
    /// Per-problem reduced diagonals, borrowed from the problem states
    /// (read-only during the solve — no per-round copies).
    d: Vec<&'a [f64]>,
    precond_diag: Vec<Vec<f64>>,
    xn: std::cell::RefCell<MultiVec>,
}

impl MultiLinOp for BatchReducedHessian<'_> {
    fn dim(&self) -> usize {
        self.x.cols()
    }

    fn nprobs(&self) -> usize {
        self.two_tbars.len()
    }

    fn apply_multi(&self, cols: &[usize], vs: &MultiVec, out: &mut MultiVec) {
        let mut xn = self.xn.borrow_mut();
        xn.resize(self.x.rows(), vs.ncols());
        self.x.matvec_multi_into(vs, &mut xn);
        self.x.matvec_t_multi_into(&xn, out);
        for (s, &j) in cols.iter().enumerate() {
            let tt = self.two_tbars[j];
            let dj = self.d[j];
            let v = vs.col(s);
            let o = out.col_mut(s);
            for i in 0..o.len() {
                o[i] = tt * o[i] + dj[i] * v[i];
            }
        }
    }

    fn precond(&self, j: usize, r: &[f64], out: &mut [f64]) -> bool {
        let pd = &self.precond_diag[j];
        for i in 0..r.len() {
            out[i] = r[i] / pd[i];
        }
        true
    }
}

/// Batched multi-λ interior point: run the [`solve_l1ls`] loop for every
/// λ in lockstep and solve the per-iteration truncated-Newton systems
/// together through blocked CG — one fused X / Xᵀ panel pass per CG
/// iteration across all live λ's (the regularization-path workload as a
/// single data-streaming sweep). Result `j` is **bit-identical** to
/// `solve_l1ls(x, y, lambdas[j], cfg)`: every per-problem operation
/// replicates the solo loop's order, and the blocked CG is pinned
/// bit-identical per column.
pub fn solve_l1ls_batch(
    x: &Mat,
    y: &[f64],
    lambdas: &[f64],
    cfg: &L1LsConfig,
) -> Vec<L1LsResult> {
    let (n, p) = (x.rows(), x.cols());

    struct Prob {
        lam: f64,
        beta: Vec<f64>,
        u: Vec<f64>,
        tbar: f64,
        newton_iters: usize,
        gap: f64,
        rel_gap: f64,
        grad_beta: Vec<f64>,
        grad_u: Vec<f64>,
        d1: Vec<f64>,
        d2: Vec<f64>,
        dred: Vec<f64>,
        rhs: Vec<f64>,
        converged: bool,
        done: bool,
    }

    let col_sq: Vec<f64> = {
        let xt = x.transpose();
        (0..p).map(|j| vecops::norm2_sq(xt.row(j))).collect()
    };
    let mut st: Vec<Prob> = lambdas
        .iter()
        .map(|&lambda| {
            let lam = 2.0 * n as f64 * lambda;
            Prob {
                lam,
                beta: vec![0.0; p],
                u: vec![1.0; p],
                tbar: 1.0f64.max(1.0 / lam),
                newton_iters: 0,
                gap: f64::INFINITY,
                rel_gap: f64::INFINITY,
                grad_beta: Vec::new(),
                grad_u: Vec::new(),
                d1: Vec::new(),
                d2: Vec::new(),
                dred: Vec::new(),
                rhs: Vec::new(),
                converged: false,
                done: false,
            }
        })
        .collect();

    let mut cg_scratch = CgScratch::new();
    let mut r_buf = vec![0.0; n];
    loop {
        // Live set after the solo loop-head cap check.
        let mut live: Vec<usize> = Vec::new();
        for (j, s) in st.iter_mut().enumerate() {
            if s.done {
                continue;
            }
            if s.newton_iters >= cfg.max_newton {
                s.done = true;
            } else {
                live.push(j);
            }
        }
        if live.is_empty() {
            break;
        }

        // Pre-CG phase, per problem (residual, duality gap, barrier
        // update, Newton-system pieces) — verbatim the solo ordering.
        for &j in &live {
            let s = &mut st[j];
            x.matvec_into(&s.beta, &mut r_buf);
            vecops::axpy(-1.0, y, &mut r_buf);
            let primal = vecops::norm2_sq(&r_buf) + s.lam * vecops::norm1(&s.beta);
            let xtr = x.matvec_t(&r_buf);
            let inf = vecops::norm_inf(&xtr).max(1e-300);
            let sc = (s.lam / (2.0 * inf)).min(1.0);
            let nu: Vec<f64> = r_buf.iter().map(|v| 2.0 * sc * v).collect();
            let g_dual = -0.25 * vecops::norm2_sq(&nu) - vecops::dot(&nu, y);
            s.gap = primal - g_dual;
            s.rel_gap = s.gap / g_dual.abs().max(1e-300);
            if s.rel_gap <= cfg.tol || s.gap <= cfg.tol {
                s.converged = true;
                s.done = true;
                continue;
            }
            s.tbar = (cfg.mu * (2.0 * p as f64 / s.gap).min(s.tbar)).max(s.tbar);
            let f1: Vec<f64> = (0..p).map(|i| s.u[i] + s.beta[i]).collect();
            let f2: Vec<f64> = (0..p).map(|i| s.u[i] - s.beta[i]).collect();
            s.grad_beta = (0..p)
                .map(|i| s.tbar * 2.0 * xtr[i] - (1.0 / f1[i] - 1.0 / f2[i]))
                .collect();
            s.grad_u = (0..p).map(|i| s.tbar * s.lam - (1.0 / f1[i] + 1.0 / f2[i])).collect();
            s.d1 = (0..p)
                .map(|i| 1.0 / (f1[i] * f1[i]) + 1.0 / (f2[i] * f2[i]))
                .collect();
            s.d2 = (0..p)
                .map(|i| 1.0 / (f1[i] * f1[i]) - 1.0 / (f2[i] * f2[i]))
                .collect();
            s.dred = (0..p).map(|i| s.d1[i] - s.d2[i] * s.d2[i] / s.d1[i]).collect();
            s.rhs = (0..p)
                .map(|i| -(s.grad_beta[i] - s.d2[i] / s.d1[i] * s.grad_u[i]))
                .collect();
        }
        let solving: Vec<usize> = live.iter().copied().filter(|&j| !st[j].done).collect();
        if solving.is_empty() {
            continue;
        }

        // The blocked solve: every live λ's Newton system through one
        // panel-wide CG, each with its own adaptive tolerance.
        let width = solving.len();
        let two_tbars: Vec<f64> = solving.iter().map(|&j| 2.0 * st[j].tbar).collect();
        let ds: Vec<&[f64]> = solving.iter().map(|&j| st[j].dred.as_slice()).collect();
        let pds: Vec<Vec<f64>> = solving
            .iter()
            .enumerate()
            .map(|(l, &j)| {
                (0..p)
                    .map(|i| (two_tbars[l] * col_sq[i] + st[j].dred[i]).max(1e-300))
                    .collect()
            })
            .collect();
        let mut rhs_panel = MultiVec::zeros(p, width);
        let mut dbeta_panel = MultiVec::zeros(p, width);
        for (l, &j) in solving.iter().enumerate() {
            rhs_panel.col_mut(l).copy_from_slice(&st[j].rhs);
        }
        let cg_opts: Vec<CgOptions> = solving
            .iter()
            .map(|&j| CgOptions {
                tol: (0.1 * st[j].rel_gap).clamp(cfg.cg.tol.min(1e-10), 1e-2),
                max_iter: cfg.cg.max_iter,
            })
            .collect();
        let op = BatchReducedHessian {
            x,
            two_tbars,
            d: ds,
            precond_diag: pds,
            xn: std::cell::RefCell::new(MultiVec::zeros(0, 0)),
        };
        cg_solve_multi_with(&op, &rhs_panel, &mut dbeta_panel, &cg_opts, &mut cg_scratch);

        // Post-CG phase, per problem: du, line search, accept.
        for (l, &j) in solving.iter().enumerate() {
            let s = &mut st[j];
            let dbeta = dbeta_panel.col(l);
            let du: Vec<f64> =
                (0..p).map(|i| -(s.grad_u[i] + s.d2[i] * dbeta[i]) / s.d1[i]).collect();
            let tbar = s.tbar;
            let lam = s.lam;
            let phi = |beta_t: &[f64], u_t: &[f64]| -> f64 {
                let mut rt = x.matvec(beta_t);
                vecops::axpy(-1.0, y, &mut rt);
                let mut val = tbar * (vecops::norm2_sq(&rt) + lam * u_t.iter().sum::<f64>());
                for i in 0..p {
                    let a = u_t[i] + beta_t[i];
                    let b = u_t[i] - beta_t[i];
                    if a <= 0.0 || b <= 0.0 {
                        return f64::INFINITY;
                    }
                    val -= a.ln() + b.ln();
                }
                val
            };
            let phi0 = phi(&s.beta, &s.u);
            let gdot = vecops::dot(&s.grad_beta, dbeta) + vecops::dot(&s.grad_u, &du);
            let mut step = 1.0;
            for _ in 0..50 {
                let bt: Vec<f64> = (0..p).map(|i| s.beta[i] + step * dbeta[i]).collect();
                let ut: Vec<f64> = (0..p).map(|i| s.u[i] + step * du[i]).collect();
                if phi(&bt, &ut) <= phi0 + 0.01 * step * gdot {
                    s.beta = bt;
                    s.u = ut;
                    break;
                }
                step *= 0.5;
            }
            s.newton_iters += 1;
        }
    }
    st.into_iter()
        .map(|s| L1LsResult {
            beta: s.beta,
            newton_iters: s.newton_iters,
            duality_gap: s.gap,
            converged: s.converged,
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::{synth_regression, SynthSpec};
    use crate::solvers::glmnet::{self, GlmnetConfig};

    fn data(n: usize, p: usize, seed: u64) -> (Mat, Vec<f64>) {
        let d = synth_regression(&SynthSpec { n, p, support: 5, seed, ..Default::default() });
        (d.x, d.y)
    }

    #[test]
    fn matches_glmnet_lasso() {
        let (x, y) = data(50, 20, 111);
        let lambda = glmnet::cd::lambda_max(&x, &y, 1.0) * 0.3;
        let g = glmnet::solve_penalized(
            &x,
            &y,
            lambda,
            &GlmnetConfig { kappa: 1.0, tol: 1e-12, ..Default::default() },
            None,
        );
        let l = solve_l1ls(&x, &y, lambda, &L1LsConfig { tol: 1e-10, ..Default::default() });
        assert!(l.converged, "gap {}", l.duality_gap);
        for j in 0..20 {
            assert!(
                (g.beta[j] - l.beta[j]).abs() < 1e-4,
                "j={j}: {} vs {}",
                g.beta[j],
                l.beta[j]
            );
        }
    }

    #[test]
    fn high_lambda_gives_zero() {
        let (x, y) = data(30, 12, 112);
        let lambda = glmnet::cd::lambda_max(&x, &y, 1.0) * 1.2;
        let l = solve_l1ls(&x, &y, lambda, &L1LsConfig::default());
        assert!(vecops::norm_inf(&l.beta) < 1e-5, "beta {:?}", l.beta);
    }

    #[test]
    fn wide_problem_p_gg_n() {
        let (x, y) = data(25, 120, 113);
        let lambda = glmnet::cd::lambda_max(&x, &y, 1.0) * 0.5;
        let g = glmnet::solve_penalized(
            &x,
            &y,
            lambda,
            &GlmnetConfig { kappa: 1.0, tol: 1e-12, ..Default::default() },
            None,
        );
        let l = solve_l1ls(&x, &y, lambda, &L1LsConfig { tol: 1e-10, ..Default::default() });
        for j in 0..120 {
            assert!((g.beta[j] - l.beta[j]).abs() < 1e-3, "j={j}");
        }
    }

    /// The batched multi-λ loop must reproduce each solo solve
    /// bit-for-bit — the blocked-CG fusion is pure memory traffic.
    #[test]
    fn batch_matches_solo_bit_for_bit() {
        let (x, y) = data(40, 18, 115);
        let lmax = glmnet::cd::lambda_max(&x, &y, 1.0);
        let lambdas = [0.5 * lmax, 0.3 * lmax, 0.15 * lmax];
        let cfg = L1LsConfig { tol: 1e-8, ..Default::default() };
        let batch = solve_l1ls_batch(&x, &y, &lambdas, &cfg);
        assert_eq!(batch.len(), 3);
        for (j, &lambda) in lambdas.iter().enumerate() {
            let solo = solve_l1ls(&x, &y, lambda, &cfg);
            assert_eq!(solo.newton_iters, batch[j].newton_iters, "λ {j}");
            assert_eq!(solo.converged, batch[j].converged, "λ {j}");
            for i in 0..18 {
                assert_eq!(
                    solo.beta[i].to_bits(),
                    batch[j].beta[i].to_bits(),
                    "λ {j} i={i}"
                );
            }
        }
    }

    #[test]
    fn gap_is_certificate() {
        // The duality gap bounds suboptimality: objective(l1ls) −
        // objective(glmnet, tight tol) ≤ gap.
        let (x, y) = data(40, 16, 114);
        let lambda = glmnet::cd::lambda_max(&x, &y, 1.0) * 0.25;
        let l = solve_l1ls(&x, &y, lambda, &L1LsConfig { tol: 1e-6, ..Default::default() });
        let g = glmnet::solve_penalized(
            &x,
            &y,
            lambda,
            &GlmnetConfig { kappa: 1.0, tol: 1e-14, ..Default::default() },
            None,
        );
        let lam_bar = 2.0 * 40.0 * lambda;
        let obj = |b: &[f64]| {
            let mut r = x.matvec(b);
            vecops::axpy(-1.0, &y, &mut r);
            vecops::norm2_sq(&r) + lam_bar * vecops::norm1(b)
        };
        assert!(obj(&l.beta) - obj(&g.beta) <= l.duality_gap + 1e-9);
    }
}
