//! L1_LS: log-barrier interior-point method for L1-regularized least
//! squares (Kim, Koh, Lustig, Boyd & Gorinevsky, 2007) — the paper's
//! third baseline (Lasso only, like the original MATLAB package).
//!
//! Solves `min ‖Xβ − y‖² + λ̄·|β|₁` through the bound reformulation
//! `min ‖Xβ − y‖² + λ̄·Σu  s.t. −u ≤ β ≤ u`, with a log barrier on the
//! bounds and truncated-Newton steps computed by preconditioned conjugate
//! gradients (the paper's PCG with the diagonal preconditioner).
//!
//! To interoperate with the glmnet-convention benches, [`solve_l1ls`]
//! takes the penalized-form λ and converts internally (λ̄ = 2nλκ).

use crate::linalg::{cg_solve_with, vecops, CgOptions, CgScratch, LinOp, Mat};

/// Configuration (penalized-Lasso convention; κ fixed to 1).
#[derive(Clone, Debug)]
pub struct L1LsConfig {
    /// Relative duality-gap target.
    pub tol: f64,
    pub max_newton: usize,
    /// Barrier update factor μ.
    pub mu: f64,
    pub cg: CgOptions,
}

impl Default for L1LsConfig {
    fn default() -> Self {
        L1LsConfig {
            tol: 1e-8,
            max_newton: 400,
            mu: 2.0,
            cg: CgOptions { tol: 1e-3, max_iter: 5000 },
        }
    }
}

#[derive(Clone, Debug)]
pub struct L1LsResult {
    pub beta: Vec<f64>,
    pub newton_iters: usize,
    pub duality_gap: f64,
    pub converged: bool,
}

/// Schur-complement reduced Hessian `2t̄·XᵀX + D` as a CG operator,
/// applied via two X matvecs (never materializing XᵀX) — the structure
/// the Kim et al. PCG exploits for large sparse problems.
struct ReducedHessian<'a> {
    x: &'a Mat,
    two_tbar: f64,
    d: Vec<f64>,
    /// diag(2t̄·XᵀX) + d — Jacobi preconditioner
    precond_diag: Vec<f64>,
    scratch_n: std::cell::RefCell<Vec<f64>>,
}

impl LinOp for ReducedHessian<'_> {
    fn dim(&self) -> usize {
        self.x.cols()
    }

    fn apply(&self, v: &[f64], out: &mut [f64]) {
        let mut xn = self.scratch_n.borrow_mut();
        self.x.matvec_into(v, &mut xn);
        self.x.matvec_t_into(&xn, out);
        for i in 0..out.len() {
            out[i] = self.two_tbar * out[i] + self.d[i] * v[i];
        }
    }

    fn precond(&self, r: &[f64], out: &mut [f64]) -> bool {
        for i in 0..r.len() {
            out[i] = r[i] / self.precond_diag[i];
        }
        true
    }
}

/// Solve the penalized Lasso `1/(2n)‖Xβ−y‖² + λ|β|₁` by the Kim et al.
/// primal interior-point method.
pub fn solve_l1ls(x: &Mat, y: &[f64], lambda: f64, cfg: &L1LsConfig) -> L1LsResult {
    let (n, p) = (x.rows(), x.cols());
    // Kim et al. objective scale: ‖Xβ−y‖² + λ̄|β|₁ == 2n × glmnet form.
    let lam = 2.0 * n as f64 * lambda;

    let mut beta = vec![0.0; p];
    let mut u = vec![1.0; p];
    let mut tbar = 1.0f64.max(1.0 / lam);

    let col_sq: Vec<f64> = {
        let xt = x.transpose();
        (0..p).map(|j| vecops::norm2_sq(xt.row(j))).collect()
    };

    let mut newton_iters = 0usize;
    let mut gap = f64::INFINITY;
    let mut converged = false;
    // One CG workspace for the whole interior-point loop: the truncated
    // Newton below runs hundreds of CG solves on the same dimension.
    let mut cg_scratch = CgScratch::new();

    let mut r = vec![0.0; n]; // residual Xβ − y
    while newton_iters < cfg.max_newton {
        // residual and primal objective
        x.matvec_into(&beta, &mut r);
        vecops::axpy(-1.0, y, &mut r);
        let primal = vecops::norm2_sq(&r) + lam * vecops::norm1(&beta);

        // Dual feasible point ν = 2r·s with s chosen so ‖Xᵀν‖∞ ≤ λ̄
        // (Kim et al. eq. 5): G(ν) = −¼‖ν‖² − νᵀy.
        let xtr = x.matvec_t(&r);
        let inf = vecops::norm_inf(&xtr).max(1e-300);
        let s = (lam / (2.0 * inf)).min(1.0);
        let nu: Vec<f64> = r.iter().map(|v| 2.0 * s * v).collect();
        let g_dual = -0.25 * vecops::norm2_sq(&nu) - vecops::dot(&nu, y);
        gap = primal - g_dual;
        let rel_gap = gap / g_dual.abs().max(1e-300);
        if rel_gap <= cfg.tol || gap <= cfg.tol {
            converged = true;
            break;
        }

        // Barrier parameter update (Kim et al. §III-B):
        // t̄ ← max{ μ·min(2p/η, t̄), t̄ }.
        tbar = (cfg.mu * (2.0 * p as f64 / gap).min(tbar)).max(tbar);

        // Newton system on (β, u) with u eliminated by Schur complement.
        let f1: Vec<f64> = (0..p).map(|i| u[i] + beta[i]).collect();
        let f2: Vec<f64> = (0..p).map(|i| u[i] - beta[i]).collect();
        let grad_beta: Vec<f64> = {
            // t̄·(2Xᵀr) − (1/f1 − 1/f2)
            (0..p).map(|i| tbar * 2.0 * xtr[i] - (1.0 / f1[i] - 1.0 / f2[i])).collect()
        };
        let grad_u: Vec<f64> =
            (0..p).map(|i| tbar * lam - (1.0 / f1[i] + 1.0 / f2[i])).collect();

        let d1: Vec<f64> =
            (0..p).map(|i| 1.0 / (f1[i] * f1[i]) + 1.0 / (f2[i] * f2[i])).collect();
        let d2: Vec<f64> =
            (0..p).map(|i| 1.0 / (f1[i] * f1[i]) - 1.0 / (f2[i] * f2[i])).collect();
        // Reduced diagonal: D1 − D2²/D1
        let dred: Vec<f64> = (0..p).map(|i| d1[i] - d2[i] * d2[i] / d1[i]).collect();
        let rhs: Vec<f64> =
            (0..p).map(|i| -(grad_beta[i] - d2[i] / d1[i] * grad_u[i])).collect();

        let two_tbar = 2.0 * tbar;
        let op = ReducedHessian {
            x,
            two_tbar,
            precond_diag: (0..p)
                .map(|i| (two_tbar * col_sq[i] + dred[i]).max(1e-300))
                .collect(),
            d: dred,
            scratch_n: std::cell::RefCell::new(vec![0.0; n]),
        };
        let mut dbeta = vec![0.0; p];
        // Truncated Newton: CG accuracy tightens as the gap closes
        // (Kim et al.'s adaptive rule).
        let cg_opts = CgOptions {
            tol: (0.1 * rel_gap).clamp(cfg.cg.tol.min(1e-10), 1e-2),
            max_iter: cfg.cg.max_iter,
        };
        cg_solve_with(&op, &rhs, &mut dbeta, &cg_opts, &mut cg_scratch);
        let du: Vec<f64> =
            (0..p).map(|i| -(grad_u[i] + d2[i] * dbeta[i]) / d1[i]).collect();

        // Backtracking line search keeping u ± β strictly positive and
        // decreasing the barrier objective.
        let phi = |beta_t: &[f64], u_t: &[f64]| -> f64 {
            let mut rt = x.matvec(beta_t);
            vecops::axpy(-1.0, y, &mut rt);
            let mut val = tbar * (vecops::norm2_sq(&rt) + lam * u_t.iter().sum::<f64>());
            for i in 0..p {
                let a = u_t[i] + beta_t[i];
                let b = u_t[i] - beta_t[i];
                if a <= 0.0 || b <= 0.0 {
                    return f64::INFINITY;
                }
                val -= a.ln() + b.ln();
            }
            val
        };
        let phi0 = phi(&beta, &u);
        let gdot = vecops::dot(&grad_beta, &dbeta) + vecops::dot(&grad_u, &du);
        let mut step = 1.0;
        for _ in 0..50 {
            let bt: Vec<f64> = (0..p).map(|i| beta[i] + step * dbeta[i]).collect();
            let ut: Vec<f64> = (0..p).map(|i| u[i] + step * du[i]).collect();
            if phi(&bt, &ut) <= phi0 + 0.01 * step * gdot {
                beta = bt;
                u = ut;
                break;
            }
            step *= 0.5;
        }
        newton_iters += 1;
    }

    L1LsResult { beta, newton_iters, duality_gap: gap, converged }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::{synth_regression, SynthSpec};
    use crate::solvers::glmnet::{self, GlmnetConfig};

    fn data(n: usize, p: usize, seed: u64) -> (Mat, Vec<f64>) {
        let d = synth_regression(&SynthSpec { n, p, support: 5, seed, ..Default::default() });
        (d.x, d.y)
    }

    #[test]
    fn matches_glmnet_lasso() {
        let (x, y) = data(50, 20, 111);
        let lambda = glmnet::cd::lambda_max(&x, &y, 1.0) * 0.3;
        let g = glmnet::solve_penalized(
            &x,
            &y,
            lambda,
            &GlmnetConfig { kappa: 1.0, tol: 1e-12, ..Default::default() },
            None,
        );
        let l = solve_l1ls(&x, &y, lambda, &L1LsConfig { tol: 1e-10, ..Default::default() });
        assert!(l.converged, "gap {}", l.duality_gap);
        for j in 0..20 {
            assert!(
                (g.beta[j] - l.beta[j]).abs() < 1e-4,
                "j={j}: {} vs {}",
                g.beta[j],
                l.beta[j]
            );
        }
    }

    #[test]
    fn high_lambda_gives_zero() {
        let (x, y) = data(30, 12, 112);
        let lambda = glmnet::cd::lambda_max(&x, &y, 1.0) * 1.2;
        let l = solve_l1ls(&x, &y, lambda, &L1LsConfig::default());
        assert!(vecops::norm_inf(&l.beta) < 1e-5, "beta {:?}", l.beta);
    }

    #[test]
    fn wide_problem_p_gg_n() {
        let (x, y) = data(25, 120, 113);
        let lambda = glmnet::cd::lambda_max(&x, &y, 1.0) * 0.5;
        let g = glmnet::solve_penalized(
            &x,
            &y,
            lambda,
            &GlmnetConfig { kappa: 1.0, tol: 1e-12, ..Default::default() },
            None,
        );
        let l = solve_l1ls(&x, &y, lambda, &L1LsConfig { tol: 1e-10, ..Default::default() });
        for j in 0..120 {
            assert!((g.beta[j] - l.beta[j]).abs() < 1e-3, "j={j}");
        }
    }

    #[test]
    fn gap_is_certificate() {
        // The duality gap bounds suboptimality: objective(l1ls) −
        // objective(glmnet, tight tol) ≤ gap.
        let (x, y) = data(40, 16, 114);
        let lambda = glmnet::cd::lambda_max(&x, &y, 1.0) * 0.25;
        let l = solve_l1ls(&x, &y, lambda, &L1LsConfig { tol: 1e-6, ..Default::default() });
        let g = glmnet::solve_penalized(
            &x,
            &y,
            lambda,
            &GlmnetConfig { kappa: 1.0, tol: 1e-14, ..Default::default() },
            None,
        );
        let lam_bar = 2.0 * 40.0 * lambda;
        let obj = |b: &[f64]| {
            let mut r = x.matvec(b);
            vecops::axpy(-1.0, &y, &mut r);
            vecops::norm2_sq(&r) + lam_bar * vecops::norm1(b)
        };
        assert!(obj(&l.beta) - obj(&g.beta) <= l.duality_gap + 1e-9);
    }
}
