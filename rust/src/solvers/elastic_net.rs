//! Elastic Net problem/solution types, objectives and optimality checks.
//!
//! Two equivalent parameterizations appear in the paper:
//!
//! - **Constrained** (eq. 1, what SVEN solves):
//!   `min ‖Xβ − y‖² + λ₂‖β‖²  s.t. |β|₁ ≤ t`
//! - **Penalized** (what glmnet solves):
//!   `min 1/(2n)·‖Xβ − y‖² + λ·(κ·|β|₁ + (1−κ)/2·‖β‖²)`
//!
//! The paper's evaluation protocol converts between them: solve the
//! penalized path with glmnet, then feed `t = |β*|₁` and the matching `λ₂`
//! into SVEN. [`EnProblem`] carries the constrained form; conversions live
//! here.

use crate::linalg::{vecops, Design, Mat};
use std::sync::Arc;

/// A (constrained-form) Elastic Net problem instance.
///
/// Convention follows the paper: `x` is `n × p` (samples × features), `y`
/// is length `n`, assumed centered; features assumed normalized (see
/// [`crate::data::standardize`]). The design is a [`Design`], so sparse
/// problems (e.g. loaded via `read_svmlight`) flow through the solvers
/// without ever materializing an n × p dense matrix.
///
/// The data set lives behind `Arc`s: a problem descriptor is a *view*
/// onto shared data plus the two scalars `(t, λ₂)`, so cloning one — or
/// building forty of them for a path sweep, or fanning a service job out
/// to W workers — never copies the design or the response. Build with
/// [`EnProblem::new`] at the data boundary (wraps owned data once) or
/// [`EnProblem::shared`] on the hot path (pure `Arc` bumps).
#[derive(Clone, Debug)]
pub struct EnProblem {
    /// Design matrix, n × p (dense or sparse), shared.
    pub x: Arc<Design>,
    /// Centered response, length n, shared.
    pub y: Arc<Vec<f64>>,
    /// L1 budget t > 0.
    pub t: f64,
    /// L2 regularization λ₂ ≥ 0 (0 ⇒ Lasso).
    pub lambda2: f64,
}

impl EnProblem {
    /// Build a problem from a dense `Mat`, a sparse `Csr`-backed
    /// [`Design`], or any other `Into<Design>`, wrapping the data into
    /// fresh `Arc`s (one move, no copy).
    pub fn new(x: impl Into<Design>, y: Vec<f64>, t: f64, lambda2: f64) -> Self {
        Self::shared(Arc::new(x.into()), Arc::new(y), t, lambda2)
    }

    /// Zero-copy constructor over already-shared data — the per-job /
    /// per-path-point form (two reference-count bumps, nothing else).
    pub fn shared(x: Arc<Design>, y: Arc<Vec<f64>>, t: f64, lambda2: f64) -> Self {
        assert_eq!(x.rows(), y.len(), "X rows must match y length");
        // A NaN budget passes through to the solver's numerical-health
        // guardrails (which classify it as a breakdown, never serving a
        // non-finite β); zero/negative budgets are caller bugs and
        // still assert here.
        assert!(t.is_nan() || t > 0.0, "L1 budget must be positive");
        assert!(lambda2 >= 0.0, "lambda2 must be non-negative");
        EnProblem { x, y, t, lambda2 }
    }

    /// The same data set at different `(t, λ₂)` — the path-sweep step.
    pub fn with_budget(&self, t: f64, lambda2: f64) -> Self {
        Self::shared(self.x.clone(), self.y.clone(), t, lambda2)
    }

    pub fn n(&self) -> usize {
        self.x.rows()
    }

    pub fn p(&self) -> usize {
        self.x.cols()
    }

    /// Constrained-form objective `‖Xβ − y‖² + λ₂‖β‖²`.
    pub fn objective(&self, beta: &[f64]) -> f64 {
        assert_eq!(beta.len(), self.p());
        let mut r = self.x.matvec(beta);
        vecops::axpy(-1.0, &self.y, &mut r);
        vecops::norm2_sq(&r) + self.lambda2 * vecops::norm2_sq(beta)
    }

    /// Gradient of the smooth part: `2Xᵀ(Xβ − y) + 2λ₂β`.
    pub fn gradient(&self, beta: &[f64]) -> Vec<f64> {
        let mut r = self.x.matvec(beta);
        vecops::axpy(-1.0, &self.y, &mut r);
        let mut g = self.x.matvec_t(&r);
        vecops::scale(2.0, &mut g);
        vecops::axpy(2.0 * self.lambda2, beta, &mut g);
        g
    }

    /// KKT residual of the constrained problem at `beta` (assuming the L1
    /// constraint is active, as the paper does for non-degenerate `t`):
    /// there must exist ν ≥ 0 with, for each i,
    ///   `g_i + ν·sign(β_i) = 0`   if β_i ≠ 0,
    ///   `|g_i| ≤ ν`               if β_i = 0.
    /// We estimate ν from the active coordinates and return the maximum
    /// violation (0 = optimal). Also checks `|β|₁ ≤ t (1+tol)`.
    pub fn kkt_residual(&self, beta: &[f64]) -> f64 {
        let g = self.gradient(beta);
        let active: Vec<usize> =
            (0..beta.len()).filter(|&i| beta[i].abs() > 1e-9).collect();
        let budget_violation = (vecops::norm1(beta) - self.t).max(0.0) / self.t;
        if active.is_empty() {
            return budget_violation;
        }
        // ν̂ = mean over active of −g_i·sign(β_i)
        let nu: f64 = active
            .iter()
            .map(|&i| -g[i] * beta[i].signum())
            .sum::<f64>()
            / active.len() as f64;
        let nu = nu.max(0.0);
        let mut viol: f64 = budget_violation;
        let gscale = 1.0f64.max(vecops::norm_inf(&g));
        for i in 0..beta.len() {
            if beta[i].abs() > 1e-9 {
                viol = viol.max((g[i] + nu * beta[i].signum()).abs() / gscale);
            } else {
                viol = viol.max((g[i].abs() - nu).max(0.0) / gscale);
            }
        }
        viol
    }
}

/// Which algorithm produced a solution (for reporting).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum EnSolverKind {
    Glmnet,
    Shotgun,
    L1Ls,
    SvenCpu,
    SvenXla,
}

impl EnSolverKind {
    pub fn name(&self) -> &'static str {
        match self {
            EnSolverKind::Glmnet => "glmnet",
            EnSolverKind::Shotgun => "shotgun",
            EnSolverKind::L1Ls => "l1_ls",
            EnSolverKind::SvenCpu => "sven_cpu",
            EnSolverKind::SvenXla => "sven_xla",
        }
    }
}

/// Degenerate outcomes the reduction can detect (paper footnote 1 & §3).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Degenerate {
    /// SVM selected no support vectors (|α|₁ = 0) — β = 0 returned.
    NoSupportVectors,
    /// L1 budget so large the constraint is slack (ridge regime).
    SlackBudget,
}

/// Solution of an Elastic Net solve.
#[derive(Clone, Debug)]
pub struct EnSolution {
    pub beta: Vec<f64>,
    pub solver: EnSolverKind,
    /// Objective value at `beta` (constrained form).
    pub objective: f64,
    /// Iterations (solver-specific meaning: CD epochs / Newton steps / IPM iters).
    pub iterations: usize,
    /// Total inner-CG iterations of the solve (primal Newton backends;
    /// 0 where there is no inner CG) — feeds the coordinator's
    /// `cg_iters_total` metric.
    pub cg_iters: usize,
    /// Active-set panel rebuilds of the solve (primal shrinking Newton;
    /// 0 otherwise) — feeds the coordinator's `sv_gather_rebuilds`
    /// metric.
    pub gather_rebuilds: usize,
    /// Outer iterative-refinement passes of a mixed-precision solve
    /// (0 ⇒ pure f64) — feeds the coordinator's `refine_iters_total`
    /// metric.
    pub refine_passes: usize,
    /// Wall-clock seconds of the solve proper (excludes data generation).
    pub seconds: f64,
    /// Degeneracy flag, if the reduction hit one.
    pub degenerate: Option<Degenerate>,
    /// The solve was abandoned at an intra-solve deadline boundary
    /// (Newton round / dual pivot): `beta` is a half-converged iterate
    /// and must never be served. Sweeps cut back to the last fully
    /// completed grid point instead.
    pub aborted: bool,
    /// The solver's numerical-health guardrail tripped after its
    /// degradation ladder was exhausted (the message names the stage):
    /// `beta` may carry non-finite values and must never be served.
    pub broken: Option<String>,
}

impl EnSolution {
    /// Count of selected features.
    pub fn nnz(&self) -> usize {
        vecops::nnz(&self.beta, 1e-8)
    }
}

/// Convert a penalized-form solution to the constrained-form budget:
/// `t = |β*|₁` (the paper's protocol for building the evaluation grid).
pub fn budget_from_beta(beta: &[f64]) -> f64 {
    vecops::norm1(beta)
}

/// Penalized-form Elastic Net objective used by the CD baselines:
/// `1/(2n)·‖Xβ − y‖² + λ·(κ|β|₁ + (1−κ)/2·‖β‖²)`.
pub fn penalized_objective(x: &Mat, y: &[f64], beta: &[f64], lambda: f64, kappa: f64) -> f64 {
    let n = x.rows() as f64;
    let mut r = x.matvec(beta);
    vecops::axpy(-1.0, y, &mut r);
    vecops::norm2_sq(&r) / (2.0 * n)
        + lambda * (kappa * vecops::norm1(beta) + 0.5 * (1.0 - kappa) * vecops::norm2_sq(beta))
}

/// Map the penalized parameters (λ, κ) at solution β* to the constrained
/// parameters (t, λ₂) the SVEN form needs.
///
/// Matching gradients of the two Lagrangians on the active set gives
/// `λ₂ = n·λ·(1−κ)` (the 1/(2n) loss scaling times the 2· in the
/// constrained loss), and `t = |β*|₁` by the paper's protocol.
pub fn penalized_to_constrained(
    beta_star: &[f64],
    lambda: f64,
    kappa: f64,
    n: usize,
) -> (f64, f64) {
    let t = budget_from_beta(beta_star);
    let lambda2 = n as f64 * lambda * (1.0 - kappa);
    (t, lambda2)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;

    fn tiny_problem() -> EnProblem {
        let mut rng = Rng::seed_from(51);
        let x = Mat::from_fn(10, 4, |_, _| rng.normal());
        let y: Vec<f64> = (0..10).map(|_| rng.normal()).collect();
        EnProblem::new(x, y, 1.0, 0.5)
    }

    #[test]
    fn objective_at_zero_is_y_norm() {
        let p = tiny_problem();
        let obj = p.objective(&vec![0.0; 4]);
        assert!((obj - vecops::norm2_sq(&p.y)).abs() < 1e-12);
    }

    #[test]
    fn gradient_matches_finite_difference() {
        let p = tiny_problem();
        let beta = vec![0.1, -0.2, 0.3, 0.05];
        let g = p.gradient(&beta);
        let eps = 1e-6;
        for i in 0..4 {
            let mut bp = beta.clone();
            let mut bm = beta.clone();
            bp[i] += eps;
            bm[i] -= eps;
            let fd = (p.objective(&bp) - p.objective(&bm)) / (2.0 * eps);
            assert!((g[i] - fd).abs() < 1e-4, "i={i}: {} vs {}", g[i], fd);
        }
    }

    #[test]
    fn kkt_zero_solution_with_huge_gradient_violates() {
        let p = tiny_problem();
        // β = 0 with y ≠ 0 has nonzero gradient ⇒ some positive violation
        // relative to ν = 0 (no active features).
        let v = p.kkt_residual(&vec![0.0; 4]);
        assert!(v >= 0.0);
    }

    #[test]
    fn budget_violation_detected() {
        let p = tiny_problem(); // t = 1
        let beta = vec![2.0, 0.0, 0.0, 0.0]; // |β|₁ = 2 > t
        assert!(p.kkt_residual(&beta) >= 1.0 - 1e-12);
    }

    #[test]
    fn penalized_to_constrained_mapping() {
        let beta = vec![0.5, -0.25, 0.0];
        let (t, l2) = penalized_to_constrained(&beta, 0.1, 0.5, 20);
        assert!((t - 0.75).abs() < 1e-12);
        assert!((l2 - 20.0 * 0.1 * 0.5).abs() < 1e-12);
    }

    #[test]
    #[should_panic(expected = "budget")]
    fn rejects_nonpositive_budget() {
        let p = tiny_problem();
        EnProblem::shared(p.x, p.y, 0.0, 0.1);
    }

    #[test]
    fn shared_and_with_budget_are_zero_copy() {
        let p = tiny_problem();
        let q = p.with_budget(2.0, 0.25);
        assert!(Arc::ptr_eq(&p.x, &q.x), "with_budget must share the design");
        assert!(Arc::ptr_eq(&p.y, &q.y), "with_budget must share the response");
        assert_eq!(q.t, 2.0);
        assert_eq!(q.lambda2, 0.25);
        let r = q.clone();
        assert!(Arc::ptr_eq(&q.x, &r.x), "clone must share the design");
    }
}
