//! glmnet-style Elastic Net: cyclic coordinate descent with active sets,
//! naive *and* covariance update rules, and a warm-started λ path —
//! the algorithmic content of Friedman, Hastie & Tibshirani (2010), which
//! the paper uses as its strongest (single-core) baseline.
//!
//! Penalized form solved here (glmnet's own convention):
//!
//! ```text
//! min_β 1/(2n)·‖Xβ − y‖² + λ·( κ·|β|₁ + (1−κ)/2·‖β‖² )
//! ```
//!
//! For standardized columns (‖x_j‖² = n) the coordinate update is closed
//! form: `β_j ← S(z_j, λκ) / (1 + λ(1−κ))` with
//! `z_j = 1/n·⟨x_j, r⟩ + β_j` and `S` the soft-threshold.

pub mod cd;
pub mod path;

pub use cd::{
    lambda_max, lambda_max_design, solve_penalized, solve_penalized_design, CdMode,
    GlmnetConfig, GlmnetResult,
};
pub use path::{compute_path, PathPoint, PathSettings};
