//! Cyclic coordinate descent core (Friedman et al. 2010).
//!
//! The naive (residual-based) update is written once over
//! [`DesignCols`] — the column-access layer of [`Design`] — so dense
//! designs keep their contiguous transposed-copy inner loop and sparse
//! designs pay O(nnz(x_j)) per coordinate with zero densification.

use crate::linalg::{vecops, Design, DesignCols, Mat};

/// Inner update rule.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum CdMode {
    /// Residual-based updates: O(n) per coordinate dense, O(nnz(x_j))
    /// sparse. Best when p ≫ n.
    Naive,
    /// Covariance updates: cache ⟨x_j, y⟩ and ⟨x_j, x_k⟩ for active k —
    /// O(|active|) per coordinate after caching. Best when n ≫ p.
    /// Dense-only: the cached rows are dense p-vectors, so sparse designs
    /// fall back to [`CdMode::Naive`] rather than densify.
    Covariance,
    /// Pick per problem shape (glmnet's own heuristic).
    Auto,
}

/// Solver configuration.
#[derive(Clone, Debug)]
pub struct GlmnetConfig {
    /// L1 fraction κ ∈ (0, 1]; glmnet calls this `alpha`.
    pub kappa: f64,
    /// Convergence: max coordinate-wise objective decrease below this
    /// (glmnet's criterion, scaled by null deviance).
    pub tol: f64,
    pub max_epochs: usize,
    pub mode: CdMode,
}

impl Default for GlmnetConfig {
    fn default() -> Self {
        GlmnetConfig { kappa: 0.5, tol: 1e-9, max_epochs: 10_000, mode: CdMode::Auto }
    }
}

/// Outcome of a penalized solve.
#[derive(Clone, Debug)]
pub struct GlmnetResult {
    pub beta: Vec<f64>,
    /// CD epochs (full or active-set sweeps) executed.
    pub epochs: usize,
    pub converged: bool,
}

/// Solve the penalized Elastic Net at a single λ, warm-starting from
/// `beta0` if given. `x` must be standardized (‖x_j‖² = n), `y` centered.
pub fn solve_penalized(
    x: &Mat,
    y: &[f64],
    lambda: f64,
    cfg: &GlmnetConfig,
    beta0: Option<&[f64]>,
) -> GlmnetResult {
    let (n, p) = (x.rows(), x.cols());
    assert_eq!(y.len(), n);
    let mode = match cfg.mode {
        CdMode::Auto => {
            if n > 4 * p {
                CdMode::Covariance
            } else {
                CdMode::Naive
            }
        }
        m => m,
    };
    match mode {
        CdMode::Naive => {
            let cols = DesignCols::Dense(x.transpose());
            solve_naive_cols(&cols, n, p, y, lambda, cfg, beta0)
        }
        CdMode::Covariance => solve_covariance(x, y, lambda, cfg, beta0),
        CdMode::Auto => unreachable!(),
    }
}

/// [`solve_penalized`] over a [`Design`]. Dense designs route through the
/// dense entry (same mode heuristics, same numerics); sparse designs run
/// the naive update through the CSC mirror — the whole solve is O(nnz)
/// per epoch and never materializes an n × p dense matrix.
pub fn solve_penalized_design(
    design: &Design,
    y: &[f64],
    lambda: f64,
    cfg: &GlmnetConfig,
    beta0: Option<&[f64]>,
) -> GlmnetResult {
    match design {
        Design::Dense(x) => solve_penalized(x, y, lambda, cfg, beta0),
        Design::Sparse { .. } => {
            let (n, p) = (design.rows(), design.cols());
            assert_eq!(y.len(), n);
            let cols = design.cols_view();
            solve_naive_cols(&cols, n, p, y, lambda, cfg, beta0)
        }
    }
}

/// Convergence scale: glmnet measures coordinate updates against the null
/// deviance so tolerance is dimensionless.
fn null_dev(y: &[f64]) -> f64 {
    vecops::norm2_sq(y).max(1e-300)
}

/// Naive-update core over a column-access view. The residual
/// `r = y − Xβ` is maintained by per-column axpys, so every operation —
/// initialization included — costs O(nnz(x_j)) on sparse columns.
fn solve_naive_cols(
    cols: &DesignCols,
    n: usize,
    p: usize,
    y: &[f64],
    lambda: f64,
    cfg: &GlmnetConfig,
    beta0: Option<&[f64]>,
) -> GlmnetResult {
    let nf = n as f64;
    let l1 = lambda * cfg.kappa;
    let l2 = lambda * (1.0 - cfg.kappa);
    let denom = 1.0 + l2;
    let thresh = cfg.tol * null_dev(y);

    let mut beta = beta0.map(|b| b.to_vec()).unwrap_or_else(|| vec![0.0; p]);
    assert_eq!(beta.len(), p);

    let mut r = y.to_vec();
    for j in 0..p {
        if beta[j] != 0.0 {
            cols.col_axpy(j, -beta[j], &mut r);
        }
    }

    let mut active: Vec<usize> = (0..p).filter(|&j| beta[j] != 0.0).collect();
    let mut epochs = 0usize;
    let mut converged = false;

    loop {
        // ---- inner: iterate active set to convergence -------------------
        loop {
            let mut max_delta = 0.0f64;
            for &j in &active {
                let bj = beta[j];
                let zj = cols.col_dot(j, &r) / nf + bj;
                let bj_new = vecops::soft_threshold(zj, l1) / denom;
                if bj_new != bj {
                    cols.col_axpy(j, bj - bj_new, &mut r);
                    beta[j] = bj_new;
                    let d = bj_new - bj;
                    max_delta = max_delta.max(d * d * nf);
                }
            }
            epochs += 1;
            if max_delta < thresh || epochs >= cfg.max_epochs {
                break;
            }
        }
        if epochs >= cfg.max_epochs {
            break;
        }
        // ---- outer: full sweep; grow active set ------------------------
        let mut changed = false;
        let mut max_delta = 0.0f64;
        for j in 0..p {
            let bj = beta[j];
            let zj = cols.col_dot(j, &r) / nf + bj;
            let bj_new = vecops::soft_threshold(zj, l1) / denom;
            if bj_new != bj {
                cols.col_axpy(j, bj - bj_new, &mut r);
                beta[j] = bj_new;
                let d = bj_new - bj;
                max_delta = max_delta.max(d * d * nf);
                if bj == 0.0 {
                    changed = true;
                }
            }
        }
        epochs += 1;
        active = (0..p).filter(|&j| beta[j] != 0.0).collect();
        if !changed && max_delta < thresh {
            converged = true;
            break;
        }
        if epochs >= cfg.max_epochs {
            break;
        }
    }
    GlmnetResult { beta, epochs, converged }
}

fn solve_covariance(
    x: &Mat,
    y: &[f64],
    lambda: f64,
    cfg: &GlmnetConfig,
    beta0: Option<&[f64]>,
) -> GlmnetResult {
    let (n, p) = (x.rows(), x.cols());
    let nf = n as f64;
    let l1 = lambda * cfg.kappa;
    let l2 = lambda * (1.0 - cfg.kappa);
    let denom = 1.0 + l2;
    let thresh = cfg.tol * null_dev(y);

    let mut beta = beta0.map(|b| b.to_vec()).unwrap_or_else(|| vec![0.0; p]);

    let xt = x.transpose();
    // xty_j = 1/n ⟨x_j, y⟩ — computed once.
    let xty: Vec<f64> = (0..p).map(|j| vecops::dot(xt.row(j), y) / nf).collect();
    // Covariance rows 1/n ⟨x_j, x_k⟩, filled lazily for features that ever
    // become active (the glmnet trick: O(n·p) per *new* active feature).
    let mut cov: Vec<Option<Vec<f64>>> = vec![None; p];
    // g_j = 1/n ⟨x_j, Xβ⟩ maintained incrementally.
    let mut g = vec![0.0; p];
    for j in 0..p {
        if beta[j] != 0.0 {
            ensure_cov(&xt, &mut cov, j, nf);
        }
    }
    for j in 0..p {
        if beta[j] != 0.0 {
            let c = cov[j].as_ref().unwrap();
            let bj = beta[j];
            for k in 0..p {
                g[k] += c[k] * bj;
            }
        }
    }

    let mut epochs = 0usize;
    let mut converged = false;
    let mut active: Vec<usize> = (0..p).filter(|&j| beta[j] != 0.0).collect();

    loop {
        loop {
            let mut max_delta = 0.0f64;
            for &j in &active {
                let bj = beta[j];
                let zj = xty[j] - g[j] + bj;
                let bj_new = vecops::soft_threshold(zj, l1) / denom;
                if bj_new != bj {
                    ensure_cov(&xt, &mut cov, j, nf);
                    let c = cov[j].as_ref().unwrap();
                    let d = bj_new - bj;
                    for k in 0..p {
                        g[k] += c[k] * d;
                    }
                    beta[j] = bj_new;
                    max_delta = max_delta.max(d * d * nf);
                }
            }
            epochs += 1;
            if max_delta < thresh || epochs >= cfg.max_epochs {
                break;
            }
        }
        if epochs >= cfg.max_epochs {
            break;
        }
        let mut changed = false;
        let mut max_delta = 0.0f64;
        for j in 0..p {
            let bj = beta[j];
            let zj = xty[j] - g[j] + bj;
            let bj_new = vecops::soft_threshold(zj, l1) / denom;
            if bj_new != bj {
                ensure_cov(&xt, &mut cov, j, nf);
                let c = cov[j].as_ref().unwrap();
                let d = bj_new - bj;
                for k in 0..p {
                    g[k] += c[k] * d;
                }
                beta[j] = bj_new;
                max_delta = max_delta.max(d * d * nf);
                if bj == 0.0 {
                    changed = true;
                }
            }
        }
        epochs += 1;
        active = (0..p).filter(|&j| beta[j] != 0.0).collect();
        if !changed && max_delta < thresh {
            converged = true;
            break;
        }
        if epochs >= cfg.max_epochs {
            break;
        }
    }
    GlmnetResult { beta, epochs, converged }
}

fn ensure_cov(xt: &Mat, cov: &mut [Option<Vec<f64>>], j: usize, nf: f64) {
    if cov[j].is_none() {
        let xj = xt.row(j);
        let row: Vec<f64> =
            (0..xt.rows()).map(|k| vecops::dot(xj, xt.row(k)) / nf).collect();
        cov[j] = Some(row);
    }
}

/// The smallest λ at which all coefficients are zero:
/// `λ_max = max_j |⟨x_j, y⟩| / (n·κ)`.
///
/// κ is clamped below at `1e-3`: as κ → 0 the penalty loses its L1 part
/// and λ_max diverges, so the clamp keeps near-ridge path grids finite
/// (the same guard glmnet applies). κ = 0 exactly — pure ridge — has no
/// finite λ_max at all and is rejected with a panic rather than silently
/// clamped.
pub fn lambda_max(x: &Mat, y: &[f64], kappa: f64) -> f64 {
    lambda_max_from_grad(&x.matvec_t(y), x.rows(), kappa)
}

/// [`lambda_max`] over a [`Design`] — O(nnz) on sparse designs. Same
/// clamp and κ = 0 rejection.
pub fn lambda_max_design(design: &Design, y: &[f64], kappa: f64) -> f64 {
    lambda_max_from_grad(&design.matvec_t(y), design.rows(), kappa)
}

/// Shared λ_max core over the precomputed gradient `g = Xᵀy` — the κ
/// guard and clamp live here, once.
fn lambda_max_from_grad(g: &[f64], n: usize, kappa: f64) -> f64 {
    assert!(
        kappa > 0.0,
        "lambda_max requires kappa > 0: a pure-ridge penalty (kappa = 0) has no \
         finite lambda at which all coefficients vanish"
    );
    vecops::norm_inf(g) / (n as f64 * kappa.max(1e-3))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::{synth_regression, SynthSpec};
    use crate::linalg::Csr;
    use crate::solvers::elastic_net::penalized_objective;

    fn test_data(n: usize, p: usize, seed: u64) -> (Mat, Vec<f64>) {
        let d = synth_regression(&SynthSpec {
            n,
            p,
            support: p.min(6),
            seed,
            ..Default::default()
        });
        (d.x, d.y)
    }

    #[test]
    fn lambda_max_zeroes_solution() {
        let (x, y) = test_data(30, 10, 81);
        let cfg = GlmnetConfig::default();
        let lmax = lambda_max(&x, &y, cfg.kappa);
        let r = solve_penalized(&x, &y, lmax * 1.001, &cfg, None);
        assert!(r.beta.iter().all(|b| *b == 0.0), "beta {:?}", r.beta);
        // Just below λ_max at least one coefficient activates.
        let r2 = solve_penalized(&x, &y, lmax * 0.95, &cfg, None);
        assert!(r2.beta.iter().any(|b| *b != 0.0));
    }

    #[test]
    #[should_panic(expected = "kappa > 0")]
    fn lambda_max_rejects_zero_kappa() {
        let (x, y) = test_data(10, 4, 87);
        lambda_max(&x, &y, 0.0);
    }

    #[test]
    fn lambda_max_clamps_tiny_kappa() {
        // κ below the clamp behaves exactly as κ = 1e-3 (documented guard
        // against divergent near-ridge grids), and the result is finite.
        let (x, y) = test_data(20, 6, 88);
        let tiny = lambda_max(&x, &y, 1e-9);
        let at_clamp = lambda_max(&x, &y, 1e-3);
        assert!(tiny.is_finite() && tiny > 0.0);
        assert_eq!(tiny.to_bits(), at_clamp.to_bits());
        // above the clamp the value actually depends on κ
        assert!(lambda_max(&x, &y, 0.5) < at_clamp);
    }

    #[test]
    fn naive_and_covariance_agree() {
        let (x, y) = test_data(60, 25, 82);
        let cfg_n = GlmnetConfig { mode: CdMode::Naive, ..Default::default() };
        let cfg_c = GlmnetConfig { mode: CdMode::Covariance, ..Default::default() };
        let lambda = lambda_max(&x, &y, 0.5) * 0.3;
        let a = solve_penalized(&x, &y, lambda, &cfg_n, None);
        let b = solve_penalized(&x, &y, lambda, &cfg_c, None);
        for j in 0..25 {
            assert!((a.beta[j] - b.beta[j]).abs() < 1e-6, "j={j}");
        }
    }

    #[test]
    fn sparse_design_matches_dense_cd() {
        // Same algorithm (naive updates) over the dense transposed copy
        // and the CSC mirror: solutions agree within CD tolerance.
        let mut rng = crate::rng::Rng::seed_from(89);
        let x = Mat::from_fn(40, 30, |_, _| {
            if rng.bernoulli(0.15) {
                rng.normal()
            } else {
                0.0
            }
        });
        let y: Vec<f64> = (0..40).map(|_| rng.normal()).collect();
        let design = Design::from(Csr::from_dense(&x, 0.0));
        assert!(design.is_sparse());
        let cfg = GlmnetConfig { mode: CdMode::Naive, tol: 1e-12, ..Default::default() };
        let lambda = lambda_max(&x, &y, cfg.kappa) * 0.3;
        assert!(
            (lambda - lambda_max_design(&design, &y, cfg.kappa)).abs()
                < 1e-12 * (1.0 + lambda),
            "lambda_max dense vs design"
        );
        let dense = solve_penalized(&x, &y, lambda, &cfg, None);
        let sparse = solve_penalized_design(&design, &y, lambda, &cfg, None);
        assert_eq!(dense.converged, sparse.converged);
        for j in 0..30 {
            assert!(
                (dense.beta[j] - sparse.beta[j]).abs() < 1e-6,
                "j={j}: {} vs {}",
                dense.beta[j],
                sparse.beta[j]
            );
        }
    }

    #[test]
    fn solution_beats_perturbations() {
        // Local optimality: objective at the CD solution is no worse than
        // at small perturbations of each coordinate.
        let (x, y) = test_data(40, 12, 83);
        let cfg = GlmnetConfig::default();
        let lambda = lambda_max(&x, &y, cfg.kappa) * 0.2;
        let r = solve_penalized(&x, &y, lambda, &cfg, None);
        assert!(r.converged);
        let f0 = penalized_objective(&x, &y, &r.beta, lambda, cfg.kappa);
        for j in 0..12 {
            for d in [-1e-5, 1e-5] {
                let mut b = r.beta.clone();
                b[j] += d;
                let f = penalized_objective(&x, &y, &b, lambda, cfg.kappa);
                assert!(f >= f0 - 1e-12, "j={j} d={d}: {f} < {f0}");
            }
        }
    }

    #[test]
    fn warm_start_converges_faster() {
        let (x, y) = test_data(80, 40, 84);
        let cfg = GlmnetConfig::default();
        let lambda = lambda_max(&x, &y, cfg.kappa) * 0.1;
        let cold = solve_penalized(&x, &y, lambda, &cfg, None);
        let warm = solve_penalized(&x, &y, lambda, &cfg, Some(&cold.beta));
        assert!(warm.epochs <= cold.epochs);
        // Both are within the CD tolerance of the optimum; per-coordinate
        // agreement is bounded by √(tol·‖y‖²/n) ≈ 3e-5 here.
        for j in 0..40 {
            assert!((warm.beta[j] - cold.beta[j]).abs() < 1e-4);
        }
    }

    #[test]
    fn pure_lasso_kappa_one() {
        let (x, y) = test_data(50, 20, 85);
        let cfg = GlmnetConfig { kappa: 1.0, ..Default::default() };
        let lambda = lambda_max(&x, &y, 1.0) * 0.3;
        let r = solve_penalized(&x, &y, lambda, &cfg, None);
        assert!(r.converged);
        // Lasso at moderate λ must be sparse.
        let nnz = r.beta.iter().filter(|b| **b != 0.0).count();
        assert!(nnz < 20, "nnz={nnz}");
    }

    #[test]
    fn heavier_l2_shrinks_norm() {
        let (x, y) = test_data(50, 20, 86);
        let lambda = lambda_max(&x, &y, 0.9) * 0.2;
        let lo = solve_penalized(
            &x,
            &y,
            lambda,
            &GlmnetConfig { kappa: 0.9, ..Default::default() },
            None,
        );
        let hi = solve_penalized(
            &x,
            &y,
            lambda * 4.0,
            &GlmnetConfig { kappa: 0.9, ..Default::default() },
            None,
        );
        assert!(vecops::norm2_sq(&hi.beta) <= vecops::norm2_sq(&lo.beta) + 1e-12);
    }
}
