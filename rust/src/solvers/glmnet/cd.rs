//! Cyclic coordinate descent core (Friedman et al. 2010).

use crate::linalg::{vecops, Mat};

/// Inner update rule.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum CdMode {
    /// Residual-based updates: O(n) per coordinate. Best when p ≫ n.
    Naive,
    /// Covariance updates: cache ⟨x_j, y⟩ and ⟨x_j, x_k⟩ for active k —
    /// O(|active|) per coordinate after caching. Best when n ≫ p.
    Covariance,
    /// Pick per problem shape (glmnet's own heuristic).
    Auto,
}

/// Solver configuration.
#[derive(Clone, Debug)]
pub struct GlmnetConfig {
    /// L1 fraction κ ∈ (0, 1]; glmnet calls this `alpha`.
    pub kappa: f64,
    /// Convergence: max coordinate-wise objective decrease below this
    /// (glmnet's criterion, scaled by null deviance).
    pub tol: f64,
    pub max_epochs: usize,
    pub mode: CdMode,
}

impl Default for GlmnetConfig {
    fn default() -> Self {
        GlmnetConfig { kappa: 0.5, tol: 1e-9, max_epochs: 10_000, mode: CdMode::Auto }
    }
}

/// Outcome of a penalized solve.
#[derive(Clone, Debug)]
pub struct GlmnetResult {
    pub beta: Vec<f64>,
    /// CD epochs (full or active-set sweeps) executed.
    pub epochs: usize,
    pub converged: bool,
}

/// Solve the penalized Elastic Net at a single λ, warm-starting from
/// `beta0` if given. `x` must be standardized (‖x_j‖² = n), `y` centered.
pub fn solve_penalized(
    x: &Mat,
    y: &[f64],
    lambda: f64,
    cfg: &GlmnetConfig,
    beta0: Option<&[f64]>,
) -> GlmnetResult {
    let (n, p) = (x.rows(), x.cols());
    assert_eq!(y.len(), n);
    let mode = match cfg.mode {
        CdMode::Auto => {
            if n > 4 * p {
                CdMode::Covariance
            } else {
                CdMode::Naive
            }
        }
        m => m,
    };
    match mode {
        CdMode::Naive => solve_naive(x, y, lambda, cfg, beta0),
        CdMode::Covariance => solve_covariance(x, y, lambda, cfg, beta0),
        CdMode::Auto => unreachable!(),
    }
}

/// Convergence scale: glmnet measures coordinate updates against the null
/// deviance so tolerance is dimensionless.
fn null_dev(y: &[f64]) -> f64 {
    vecops::norm2_sq(y).max(1e-300)
}

fn solve_naive(
    x: &Mat,
    y: &[f64],
    lambda: f64,
    cfg: &GlmnetConfig,
    beta0: Option<&[f64]>,
) -> GlmnetResult {
    let (n, p) = (x.rows(), x.cols());
    let nf = n as f64;
    let l1 = lambda * cfg.kappa;
    let l2 = lambda * (1.0 - cfg.kappa);
    let denom = 1.0 + l2;
    let thresh = cfg.tol * null_dev(y);

    let mut beta = beta0.map(|b| b.to_vec()).unwrap_or_else(|| vec![0.0; p]);
    assert_eq!(beta.len(), p);

    // Residual r = y − Xβ. Columns are strided in the row-major Mat, so we
    // keep a column-major copy of X for the CD inner loop (one-time O(np)).
    let xt = x.transpose(); // xt.row(j) = column j, contiguous
    let mut r = y.to_vec();
    if beta.iter().any(|b| *b != 0.0) {
        let xb = x.matvec(&beta);
        vecops::sub(y, &xb, &mut r);
    }

    let mut active: Vec<usize> = (0..p).filter(|&j| beta[j] != 0.0).collect();
    let mut epochs = 0usize;
    let mut converged = false;

    loop {
        // ---- inner: iterate active set to convergence -------------------
        loop {
            let mut max_delta = 0.0f64;
            for &j in &active {
                let xj = xt.row(j);
                let bj = beta[j];
                let zj = vecops::dot(xj, &r) / nf + bj;
                let bj_new = vecops::soft_threshold(zj, l1) / denom;
                if bj_new != bj {
                    vecops::axpy(bj - bj_new, xj, &mut r);
                    beta[j] = bj_new;
                    let d = bj_new - bj;
                    max_delta = max_delta.max(d * d * nf);
                }
            }
            epochs += 1;
            if max_delta < thresh || epochs >= cfg.max_epochs {
                break;
            }
        }
        if epochs >= cfg.max_epochs {
            break;
        }
        // ---- outer: full sweep; grow active set ------------------------
        let mut changed = false;
        let mut max_delta = 0.0f64;
        for j in 0..p {
            let xj = xt.row(j);
            let bj = beta[j];
            let zj = vecops::dot(xj, &r) / nf + bj;
            let bj_new = vecops::soft_threshold(zj, l1) / denom;
            if bj_new != bj {
                vecops::axpy(bj - bj_new, xj, &mut r);
                beta[j] = bj_new;
                let d = bj_new - bj;
                max_delta = max_delta.max(d * d * nf);
                if bj == 0.0 {
                    changed = true;
                }
            }
        }
        epochs += 1;
        active = (0..p).filter(|&j| beta[j] != 0.0).collect();
        if !changed && max_delta < thresh {
            converged = true;
            break;
        }
        if epochs >= cfg.max_epochs {
            break;
        }
    }
    GlmnetResult { beta, epochs, converged }
}

fn solve_covariance(
    x: &Mat,
    y: &[f64],
    lambda: f64,
    cfg: &GlmnetConfig,
    beta0: Option<&[f64]>,
) -> GlmnetResult {
    let (n, p) = (x.rows(), x.cols());
    let nf = n as f64;
    let l1 = lambda * cfg.kappa;
    let l2 = lambda * (1.0 - cfg.kappa);
    let denom = 1.0 + l2;
    let thresh = cfg.tol * null_dev(y);

    let mut beta = beta0.map(|b| b.to_vec()).unwrap_or_else(|| vec![0.0; p]);

    let xt = x.transpose();
    // xty_j = 1/n ⟨x_j, y⟩ — computed once.
    let xty: Vec<f64> = (0..p).map(|j| vecops::dot(xt.row(j), y) / nf).collect();
    // Covariance rows 1/n ⟨x_j, x_k⟩, filled lazily for features that ever
    // become active (the glmnet trick: O(n·p) per *new* active feature).
    let mut cov: Vec<Option<Vec<f64>>> = vec![None; p];
    // g_j = 1/n ⟨x_j, Xβ⟩ maintained incrementally.
    let mut g = vec![0.0; p];
    for j in 0..p {
        if beta[j] != 0.0 {
            ensure_cov(&xt, &mut cov, j, nf);
        }
    }
    for j in 0..p {
        if beta[j] != 0.0 {
            let c = cov[j].as_ref().unwrap();
            let bj = beta[j];
            for k in 0..p {
                g[k] += c[k] * bj;
            }
        }
    }

    let mut epochs = 0usize;
    let mut converged = false;
    let mut active: Vec<usize> = (0..p).filter(|&j| beta[j] != 0.0).collect();

    loop {
        loop {
            let mut max_delta = 0.0f64;
            for &j in &active {
                let bj = beta[j];
                let zj = xty[j] - g[j] + bj;
                let bj_new = vecops::soft_threshold(zj, l1) / denom;
                if bj_new != bj {
                    ensure_cov(&xt, &mut cov, j, nf);
                    let c = cov[j].as_ref().unwrap();
                    let d = bj_new - bj;
                    for k in 0..p {
                        g[k] += c[k] * d;
                    }
                    beta[j] = bj_new;
                    max_delta = max_delta.max(d * d * nf);
                }
            }
            epochs += 1;
            if max_delta < thresh || epochs >= cfg.max_epochs {
                break;
            }
        }
        if epochs >= cfg.max_epochs {
            break;
        }
        let mut changed = false;
        let mut max_delta = 0.0f64;
        for j in 0..p {
            let bj = beta[j];
            let zj = xty[j] - g[j] + bj;
            let bj_new = vecops::soft_threshold(zj, l1) / denom;
            if bj_new != bj {
                ensure_cov(&xt, &mut cov, j, nf);
                let c = cov[j].as_ref().unwrap();
                let d = bj_new - bj;
                for k in 0..p {
                    g[k] += c[k] * d;
                }
                beta[j] = bj_new;
                max_delta = max_delta.max(d * d * nf);
                if bj == 0.0 {
                    changed = true;
                }
            }
        }
        epochs += 1;
        active = (0..p).filter(|&j| beta[j] != 0.0).collect();
        if !changed && max_delta < thresh {
            converged = true;
            break;
        }
        if epochs >= cfg.max_epochs {
            break;
        }
    }
    GlmnetResult { beta, epochs, converged }
}

fn ensure_cov(xt: &Mat, cov: &mut [Option<Vec<f64>>], j: usize, nf: f64) {
    if cov[j].is_none() {
        let xj = xt.row(j);
        let row: Vec<f64> =
            (0..xt.rows()).map(|k| vecops::dot(xj, xt.row(k)) / nf).collect();
        cov[j] = Some(row);
    }
}

/// The smallest λ at which all coefficients are zero:
/// `λ_max = max_j |⟨x_j, y⟩| / (n·κ)`.
pub fn lambda_max(x: &Mat, y: &[f64], kappa: f64) -> f64 {
    let g = x.matvec_t(y);
    vecops::norm_inf(&g) / (x.rows() as f64 * kappa.max(1e-3))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::{synth_regression, SynthSpec};
    use crate::solvers::elastic_net::penalized_objective;

    fn test_data(n: usize, p: usize, seed: u64) -> (Mat, Vec<f64>) {
        let d = synth_regression(&SynthSpec {
            n,
            p,
            support: p.min(6),
            seed,
            ..Default::default()
        });
        (d.x, d.y)
    }

    #[test]
    fn lambda_max_zeroes_solution() {
        let (x, y) = test_data(30, 10, 81);
        let cfg = GlmnetConfig::default();
        let lmax = lambda_max(&x, &y, cfg.kappa);
        let r = solve_penalized(&x, &y, lmax * 1.001, &cfg, None);
        assert!(r.beta.iter().all(|b| *b == 0.0), "beta {:?}", r.beta);
        // Just below λ_max at least one coefficient activates.
        let r2 = solve_penalized(&x, &y, lmax * 0.95, &cfg, None);
        assert!(r2.beta.iter().any(|b| *b != 0.0));
    }

    #[test]
    fn naive_and_covariance_agree() {
        let (x, y) = test_data(60, 25, 82);
        let cfg_n = GlmnetConfig { mode: CdMode::Naive, ..Default::default() };
        let cfg_c = GlmnetConfig { mode: CdMode::Covariance, ..Default::default() };
        let lambda = lambda_max(&x, &y, 0.5) * 0.3;
        let a = solve_penalized(&x, &y, lambda, &cfg_n, None);
        let b = solve_penalized(&x, &y, lambda, &cfg_c, None);
        for j in 0..25 {
            assert!((a.beta[j] - b.beta[j]).abs() < 1e-6, "j={j}");
        }
    }

    #[test]
    fn solution_beats_perturbations() {
        // Local optimality: objective at the CD solution is no worse than
        // at small perturbations of each coordinate.
        let (x, y) = test_data(40, 12, 83);
        let cfg = GlmnetConfig::default();
        let lambda = lambda_max(&x, &y, cfg.kappa) * 0.2;
        let r = solve_penalized(&x, &y, lambda, &cfg, None);
        assert!(r.converged);
        let f0 = penalized_objective(&x, &y, &r.beta, lambda, cfg.kappa);
        for j in 0..12 {
            for d in [-1e-5, 1e-5] {
                let mut b = r.beta.clone();
                b[j] += d;
                let f = penalized_objective(&x, &y, &b, lambda, cfg.kappa);
                assert!(f >= f0 - 1e-12, "j={j} d={d}: {f} < {f0}");
            }
        }
    }

    #[test]
    fn warm_start_converges_faster() {
        let (x, y) = test_data(80, 40, 84);
        let cfg = GlmnetConfig::default();
        let lambda = lambda_max(&x, &y, cfg.kappa) * 0.1;
        let cold = solve_penalized(&x, &y, lambda, &cfg, None);
        let warm = solve_penalized(&x, &y, lambda, &cfg, Some(&cold.beta));
        assert!(warm.epochs <= cold.epochs);
        // Both are within the CD tolerance of the optimum; per-coordinate
        // agreement is bounded by √(tol·‖y‖²/n) ≈ 3e-5 here.
        for j in 0..40 {
            assert!((warm.beta[j] - cold.beta[j]).abs() < 1e-4);
        }
    }

    #[test]
    fn pure_lasso_kappa_one() {
        let (x, y) = test_data(50, 20, 85);
        let cfg = GlmnetConfig { kappa: 1.0, ..Default::default() };
        let lambda = lambda_max(&x, &y, 1.0) * 0.3;
        let r = solve_penalized(&x, &y, lambda, &cfg, None);
        assert!(r.converged);
        // Lasso at moderate λ must be sparse.
        let nnz = r.beta.iter().filter(|b| **b != 0.0).count();
        assert!(nnz < 20, "nnz={nnz}");
    }

    #[test]
    fn heavier_l2_shrinks_norm() {
        let (x, y) = test_data(50, 20, 86);
        let lambda = lambda_max(&x, &y, 0.9) * 0.2;
        let lo = solve_penalized(
            &x,
            &y,
            lambda,
            &GlmnetConfig { kappa: 0.9, ..Default::default() },
            None,
        );
        let hi = solve_penalized(
            &x,
            &y,
            lambda * 4.0,
            &GlmnetConfig { kappa: 0.9, ..Default::default() },
            None,
        );
        assert!(vecops::norm2_sq(&hi.beta) <= vecops::norm2_sq(&lo.beta) + 1e-12);
    }
}
