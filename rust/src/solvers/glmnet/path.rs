//! Warm-started regularization path — glmnet's pathwise strategy and the
//! source of the paper's evaluation grid (§5 "Regularization path"): solve
//! a geometric λ sequence, record `t = |β*|₁` and `λ₂ = n·λ·(1−κ)` at each
//! point, and sub-sample settings with distinct support sizes.

use super::cd::{lambda_max, solve_penalized, GlmnetConfig, GlmnetResult};
use crate::linalg::{vecops, Mat};
use crate::solvers::elastic_net::penalized_to_constrained;

/// One solved point on the path, carrying both parameterizations.
#[derive(Clone, Debug)]
pub struct PathPoint {
    /// Penalized-form λ (glmnet scale).
    pub lambda: f64,
    /// L1 fraction κ.
    pub kappa: f64,
    /// Constrained-form L1 budget t = |β*|₁.
    pub t: f64,
    /// Constrained-form L2 coefficient λ₂ = n·λ·(1−κ).
    pub lambda2: f64,
    pub beta: Vec<f64>,
    pub nnz: usize,
    pub epochs: usize,
}

/// Path construction settings.
#[derive(Clone, Debug)]
pub struct PathSettings {
    pub kappa: f64,
    /// Number of λ values on the full path.
    pub num_lambda: usize,
    /// λ_min = ratio · λ_max.
    pub lambda_min_ratio: f64,
    pub cd: GlmnetConfig,
}

impl Default for PathSettings {
    fn default() -> Self {
        PathSettings {
            kappa: 0.5,
            num_lambda: 100,
            lambda_min_ratio: 1e-3,
            // The path defines the evaluation grid (t = |β*|₁); a loose CD
            // tolerance here would be misread downstream as SVEN error, so
            // reference paths are solved tighter than the timed runs.
            cd: GlmnetConfig { tol: 1e-13, ..GlmnetConfig::default() },
        }
    }
}

/// Solve the full warm-started path (dense λ grid, decreasing).
pub fn compute_path(x: &Mat, y: &[f64], settings: &PathSettings) -> Vec<PathPoint> {
    let n = x.rows();
    let mut cfg = settings.cd.clone();
    cfg.kappa = settings.kappa;
    let lmax = lambda_max(x, y, settings.kappa);
    let lmin = lmax * settings.lambda_min_ratio;
    let k = settings.num_lambda.max(2);
    let step = (lmin / lmax).powf(1.0 / (k - 1) as f64);

    let mut points = Vec::with_capacity(k);
    let mut warm: Option<Vec<f64>> = None;
    let mut lambda = lmax;
    for _ in 0..k {
        let GlmnetResult { beta, epochs, .. } =
            solve_penalized(x, y, lambda, &cfg, warm.as_deref());
        let (t, lambda2) = penalized_to_constrained(&beta, lambda, settings.kappa, n);
        points.push(PathPoint {
            lambda,
            kappa: settings.kappa,
            t,
            lambda2,
            nnz: vecops::nnz(&beta, 1e-10),
            epochs,
            beta: beta.clone(),
        });
        warm = Some(beta);
        lambda *= step;
    }
    points
}

/// The paper's protocol: from a dense path, pick `count` evenly spaced
/// points *with distinct support sizes* (and strictly positive budgets) to
/// form the evaluation grid.
pub fn subsample_distinct(points: &[PathPoint], count: usize) -> Vec<PathPoint> {
    // Keep the first point per distinct nnz > 0.
    let mut distinct: Vec<&PathPoint> = Vec::new();
    let mut seen = std::collections::BTreeSet::new();
    for pt in points {
        if pt.nnz == 0 || pt.t <= 0.0 {
            continue;
        }
        if seen.insert(pt.nnz) {
            distinct.push(pt);
        }
    }
    if distinct.is_empty() {
        return Vec::new();
    }
    let count = count.min(distinct.len());
    (0..count)
        .map(|i| {
            let idx = i * (distinct.len() - 1) / count.max(1).max(count - 1).max(1);
            distinct[idx.min(distinct.len() - 1)].clone()
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::{synth_regression, SynthSpec};

    fn data() -> (Mat, Vec<f64>) {
        let d = synth_regression(&SynthSpec {
            n: 60,
            p: 30,
            support: 8,
            seed: 91,
            ..Default::default()
        });
        (d.x, d.y)
    }

    #[test]
    fn path_is_monotone_in_support() {
        let (x, y) = data();
        let pts = compute_path(&x, &y, &PathSettings { num_lambda: 30, ..Default::default() });
        assert_eq!(pts.len(), 30);
        // nnz grows (weakly) as λ decreases along the path head.
        assert_eq!(pts[0].nnz, 0, "at λ_max everything is zero");
        assert!(pts.last().unwrap().nnz > 0);
        // budgets t grow as λ shrinks
        let t_first_active = pts.iter().find(|p| p.nnz > 0).unwrap().t;
        assert!(pts.last().unwrap().t > t_first_active);
    }

    #[test]
    fn lambda_grid_is_geometric() {
        let (x, y) = data();
        let pts = compute_path(&x, &y, &PathSettings { num_lambda: 10, ..Default::default() });
        let r0 = pts[1].lambda / pts[0].lambda;
        for w in pts.windows(2) {
            assert!(((w[1].lambda / w[0].lambda) - r0).abs() < 1e-12);
        }
    }

    #[test]
    fn subsample_distinct_supports() {
        let (x, y) = data();
        let pts = compute_path(&x, &y, &PathSettings { num_lambda: 60, ..Default::default() });
        let grid = subsample_distinct(&pts, 10);
        assert!(!grid.is_empty() && grid.len() <= 10);
        let nnzs: Vec<usize> = grid.iter().map(|p| p.nnz).collect();
        let mut dedup = nnzs.clone();
        dedup.dedup();
        assert_eq!(nnzs, dedup, "supports must be distinct: {nnzs:?}");
        assert!(grid.iter().all(|p| p.t > 0.0));
    }

    #[test]
    fn constrained_params_consistent() {
        let (x, y) = data();
        let pts = compute_path(&x, &y, &PathSettings { num_lambda: 20, ..Default::default() });
        for pt in pts.iter().filter(|p| p.nnz > 0) {
            assert!((pt.t - vecops::norm1(&pt.beta)).abs() < 1e-12);
            assert!((pt.lambda2 - 60.0 * pt.lambda * 0.5).abs() < 1e-12);
        }
    }
}
