//! Pluggable SVM backends for SVEN.
//!
//! [`RustBackend`] solves in-process with the Newton solvers of
//! [`crate::solvers::svm`] — the "SVEN (CPU)" line of the paper's figures.
//! The XLA backend (see [`crate::runtime`]) implements the same trait over
//! AOT-compiled artifacts — "SVEN (XLA)", the stand-in for "SVEN (GPU)".
//!
//! Backends prepare from a [`Design`], so a sparse data set flows through
//! preparation (gram blocks via the CSR/CSC join, Xᵀy via sparse GEMV)
//! and every per-point solve without densifying.
//!
//! A preparation is split into two halves:
//!
//! - [`SvmPrep`] — the immutable, `Send + Sync` half (gram blocks,
//!   staged device buffers, `Arc`s onto the data set), built once per
//!   data set and shared freely: the path runner reuses one across 40
//!   points, and the coordinator's service-level cache shares one
//!   `Arc<dyn SvmPrep>` across every worker thread.
//! - [`SvmScratch`] — the small mutable half (the assembled dual gram
//!   `K(t)` buffer), owned per calling thread and passed into each solve.

use crate::linalg::{resolved_precision, vecops, Design, DesignShadowF32, Mat, Precision};
use crate::solvers::svm::{
    dual_newton, primal_newton, primal_newton_batch, primal_newton_batch_ys,
    samples::reduction_gram, samples::reduction_labels, DualOptions, PrimalBatchPoint,
    PrimalBatchStats, PrimalOptions, ReducedSamples, SampleSet, SolveCtl,
};
use std::sync::Arc;

/// Fusion statistics of a batched SVM solve (shared panel builds,
/// blocked-CG right-hand sides, CG panel compactions) — the primal
/// Newton's [`PrimalBatchStats`], surfaced at the backend boundary so
/// the coordinator can meter them.
pub type SvmBatchStats = PrimalBatchStats;

/// Primal/dual selection. `Auto` applies the paper's rule: primal when
/// 2p > n (weight dimension n is the small side), dual otherwise.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SvmMode {
    Auto,
    Primal,
    Dual,
}

impl SvmMode {
    /// Resolve `Auto` for a given problem shape.
    pub fn resolve(self, n: usize, p: usize) -> SvmMode {
        match self {
            SvmMode::Auto => {
                if 2 * p > n {
                    SvmMode::Primal
                } else {
                    SvmMode::Dual
                }
            }
            m => m,
        }
    }
}

/// Warm-start state carried between path points.
#[derive(Clone, Debug, Default)]
pub struct SvmWarm {
    /// Primal weights (length n).
    pub w: Option<Vec<f64>>,
    /// Dual variables (length 2p).
    pub alpha: Option<Vec<f64>>,
}

/// Output of one SVM solve in reduction space.
#[derive(Clone, Debug)]
pub struct SvmSolve {
    /// Dual variables, length 2p.
    pub alpha: Vec<f64>,
    /// Primal weights if the backend produced them (length n).
    pub w: Option<Vec<f64>>,
    /// Newton iterations / pivots.
    pub iters: usize,
    /// Total CG iterations inside the solve (primal Newton; 0 for
    /// solvers without an inner CG).
    pub cg_iters: usize,
    /// Active-set panel rebuilds (primal shrinking Newton; 0 otherwise).
    pub gather_rebuilds: usize,
    /// Outer iterative-refinement passes across the solve's Newton
    /// systems (0 ⇒ the solve ran in pure f64).
    pub refine_passes: usize,
    /// The intra-solve deadline fired and this solve was abandoned at a
    /// Newton-round / pivot boundary — the iterate must not be served.
    pub aborted: bool,
    /// The solver's numerical-health guardrail tripped after its
    /// degradation ladder was exhausted; the message names the stage.
    /// The iterate must not be served.
    pub broken: Option<String>,
}

/// Per-solve mutable workspace. Everything a solve mutates lives here —
/// one scratch per calling thread — so the preparation itself can stay
/// immutable and shared. The dual path reuses the `K(t)` buffer across
/// path points (2p × 2p, the largest transient of a dual solve).
#[derive(Default)]
pub struct SvmScratch {
    /// Reusable dense matrix buffer (the assembled dual gram `K(t)`).
    k: Option<Mat>,
}

impl SvmScratch {
    pub fn new() -> Self {
        Self::default()
    }

    /// Borrow a `rows × cols` matrix buffer, reallocating only on shape
    /// change. Callers must overwrite every entry (the buffer carries the
    /// previous solve's values).
    pub(crate) fn mat(&mut self, rows: usize, cols: usize) -> &mut Mat {
        let stale = match &self.k {
            Some(m) => m.rows() != rows || m.cols() != cols,
            None => true,
        };
        if stale {
            self.k = Some(Mat::zeros(rows, cols));
        }
        self.k.as_mut().unwrap()
    }
}

/// A data set prepared for repeated (t, C) solves: the immutable half of
/// a preparation.
///
/// `Send + Sync` by contract so one `Arc<dyn SvmPrep>` can serve every
/// worker in the coordinator pool (the single-flight prep cache depends
/// on this). The offline `xla` stub satisfies the bound; a real PJRT
/// re-link must either provide thread-safe handles or wrap them in a
/// mutex before implementing this trait.
pub trait SvmPrep: Send + Sync {
    /// Solve the reduction SVM at budget `t` and regularization `C`,
    /// using `scratch` for all mutable state. A `ctl` carries the
    /// coordinator's intra-solve deadline down to Newton-round / pivot
    /// granularity: an expired solve comes back flagged `aborted`
    /// (never an error, never a half-converged iterate served as done).
    fn solve(
        &self,
        t: f64,
        c: f64,
        warm: Option<&SvmWarm>,
        scratch: &mut SvmScratch,
        ctl: Option<&SolveCtl>,
    ) -> anyhow::Result<SvmSolve>;
    /// Which formulation this preparation uses.
    fn mode(&self) -> SvmMode;
    /// Shape (n, p) of the prepared data set — lets cache consumers
    /// reject a key that was reused for a differently-shaped design
    /// before any kernel trips an index assert.
    fn dims(&self) -> (usize, usize);
    /// Solve several `(t, C)` points against this preparation,
    /// cold-started. The default runs them sequentially; backends with a
    /// batched engine (the primal Newton) override it to fuse the
    /// solves — with the hard contract that every solution is
    /// **bit-identical** to the sequential default (the batched engine
    /// only reorganizes memory traffic).
    fn solve_batch(
        &self,
        pts: &[(f64, f64)],
        scratch: &mut SvmScratch,
        ctl: Option<&SolveCtl>,
    ) -> anyhow::Result<(Vec<SvmSolve>, SvmBatchStats)> {
        let mut out = Vec::with_capacity(pts.len());
        for &(t, c) in pts {
            out.push(self.solve(t, c, None, scratch, ctl)?);
        }
        Ok((out, SvmBatchStats::default()))
    }
    /// Bytes held by the preparation's one-time f32 design shadow
    /// (0 when the prep runs in pure f64). Lets the coordinator meter
    /// mixed-precision memory alongside its solve counters.
    fn f32_shadow_bytes(&self) -> usize {
        0
    }
    /// Solve a mixed response × (t, C) batch against this preparation,
    /// cold-started: member `(r, t, c)` solves the reduction SVM for
    /// response `responses[r]` at `(t, c)`. The preparation's own `y`
    /// is ignored — only its y-independent state (design, gram blocks,
    /// f32 shadow) is reused — so every member must be **bit-identical**
    /// to a fresh preparation of `(x, responses[r])` solved cold at
    /// `(t, c)`. Backends without a multi-response engine report an
    /// error and the coordinator fails the job up front.
    fn solve_batch_multi(
        &self,
        responses: &[Arc<Vec<f64>>],
        members: &[(usize, f64, f64)],
        scratch: &mut SvmScratch,
        ctl: Option<&SolveCtl>,
    ) -> anyhow::Result<(Vec<SvmSolve>, SvmBatchStats)> {
        let _ = (responses, members, scratch, ctl);
        anyhow::bail!("backend does not support multi-response batches")
    }
    /// Solo solve for an override response `y` against this
    /// preparation's design (same y-independent caches, different
    /// right-hand side). The dual regime's multi-response sweep uses
    /// this to chain per-response warm starts exactly as a standalone
    /// preparation of `(x, y)` would — the contract is bit-identity
    /// with `prepare(x, y)` followed by `solve(t, c, warm, ..)`.
    fn solve_response(
        &self,
        y: &[f64],
        t: f64,
        c: f64,
        warm: Option<&SvmWarm>,
        scratch: &mut SvmScratch,
        ctl: Option<&SolveCtl>,
    ) -> anyhow::Result<SvmSolve> {
        let _ = (y, t, c, warm, scratch, ctl);
        anyhow::bail!("backend does not support response-override solves")
    }
}

/// An SVM solving engine SVEN can drive.
pub trait SvmBackend {
    fn name(&self) -> &str;
    /// Prepare `x` (n × p, dense or sparse) / `y` for repeated solves.
    /// The preparation holds `Arc`s onto the data (no copies) plus its
    /// own caches (gram blocks, staged device buffers); the returned
    /// `Arc<dyn SvmPrep>` is shared across threads by the coordinator.
    fn prepare(
        &self,
        x: &Arc<Design>,
        y: &Arc<Vec<f64>>,
        mode: SvmMode,
    ) -> anyhow::Result<Arc<dyn SvmPrep>>;
}

/// In-process Newton backend ("SVEN (CPU)").
#[derive(Clone, Debug)]
pub struct RustBackend {
    pub primal: PrimalOptions,
    pub dual: DualOptions,
}

impl Default for RustBackend {
    fn default() -> Self {
        RustBackend { primal: PrimalOptions::default(), dual: DualOptions::default() }
    }
}

impl SvmBackend for RustBackend {
    fn name(&self) -> &str {
        "rust-newton"
    }

    fn prepare(
        &self,
        x: &Arc<Design>,
        y: &Arc<Vec<f64>>,
        mode: SvmMode,
    ) -> anyhow::Result<Arc<dyn SvmPrep>> {
        let (n, p) = (x.rows(), x.cols());
        // Precision is resolved here, at prep time, so a preparation is
        // immutably pinned to one tier: service-level prep caches key on
        // the resolved precision and a cached prep can never flip tier
        // mid-path. The dual backend currently ignores `MixedF32` and
        // stays f64 (see ROADMAP: f32 Cholesky / dual tier follow-on).
        match mode.resolve(n, p) {
            SvmMode::Primal => {
                let shadow = match resolved_precision() {
                    Precision::MixedF32 => Some(DesignShadowF32::of(x.as_ref())),
                    _ => None,
                };
                Ok(Arc::new(PreparedPrimal {
                    opts: self.primal.clone(),
                    x: x.clone(),
                    y: y.clone(),
                    shadow,
                }))
            }
            SvmMode::Dual => Ok(Arc::new(PreparedDual {
                opts: self.dual.clone(),
                // t-independent gram pieces, computed once: dense designs
                // use the packed blocked kernel, sparse designs the
                // threaded CSR/CSC join — either way G₀ is p × p.
                g0: x.gram_t(),
                v: x.matvec_t(y),
                yy: vecops::norm2_sq(y),
                x: x.clone(),
                y: y.clone(),
            })),
            SvmMode::Auto => unreachable!(),
        }
    }
}

fn primal_to_solve(r: crate::solvers::svm::PrimalResult) -> SvmSolve {
    SvmSolve {
        alpha: r.alpha,
        w: Some(r.w),
        iters: r.newton_iters,
        cg_iters: r.cg_iters_total,
        gather_rebuilds: r.gather_rebuilds,
        refine_passes: r.refine_passes_total,
        aborted: r.aborted,
        broken: r.broken,
    }
}

struct PreparedPrimal {
    opts: PrimalOptions,
    x: Arc<Design>,
    y: Arc<Vec<f64>>,
    /// One-time f32 copy of the design, built at prep time when the
    /// resolved precision is `MixedF32`. Its presence is the sole mixed
    /// signal downstream: solves construct [`ReducedSamples::with_shadow`]
    /// when it is `Some` and pure-f64 samples otherwise.
    shadow: Option<DesignShadowF32>,
}

impl SvmPrep for PreparedPrimal {
    fn solve(
        &self,
        t: f64,
        c: f64,
        warm: Option<&SvmWarm>,
        _scratch: &mut SvmScratch,
        ctl: Option<&SolveCtl>,
    ) -> anyhow::Result<SvmSolve> {
        if ctl.is_some() {
            // A deadline-carrying solo solve routes through the width-1
            // batch — the only primal engine that polls the ctl — which
            // is pinned bit-identical to the solo path.
            let points =
                [PrimalBatchPoint { t, c, w0: warm.and_then(|w| w.w.clone()) }];
            let (mut rs, _) = primal_newton_batch(
                self.x.as_ref(),
                self.y.as_slice(),
                &points,
                &self.opts,
                self.shadow.as_ref(),
                ctl,
            );
            let r = rs.pop().expect("width-1 batch returns one result");
            return Ok(primal_to_solve(r));
        }
        let samples = match &self.shadow {
            Some(sh) => ReducedSamples::with_shadow(self.x.as_ref(), self.y.as_slice(), t, sh),
            None => ReducedSamples::new(self.x.as_ref(), self.y.as_slice(), t),
        };
        let labels = reduction_labels(self.x.cols());
        let w0 = warm.and_then(|w| w.w.as_deref());
        let r = primal_newton(&samples, &labels, c, &self.opts, w0);
        Ok(primal_to_solve(r))
    }

    fn mode(&self) -> SvmMode {
        SvmMode::Primal
    }

    fn dims(&self) -> (usize, usize) {
        (self.x.rows(), self.x.cols())
    }

    /// The batched entry point: neighboring path points (or CV-fold grid
    /// points) share every data-streaming pass of the Newton through
    /// [`primal_newton_batch`] — fused gradients/margins, shared SV
    /// gathers where active sets agree, blocked CG over the panel.
    /// Bit-identical to the sequential default (pinned in
    /// `svm::primal`'s batch tests).
    fn solve_batch(
        &self,
        pts: &[(f64, f64)],
        _scratch: &mut SvmScratch,
        ctl: Option<&SolveCtl>,
    ) -> anyhow::Result<(Vec<SvmSolve>, SvmBatchStats)> {
        let points: Vec<PrimalBatchPoint> =
            pts.iter().map(|&(t, c)| PrimalBatchPoint { t, c, w0: None }).collect();
        let (results, stats) = primal_newton_batch(
            self.x.as_ref(),
            self.y.as_slice(),
            &points,
            &self.opts,
            self.shadow.as_ref(),
            ctl,
        );
        Ok((results.into_iter().map(primal_to_solve).collect(), stats))
    }

    fn f32_shadow_bytes(&self) -> usize {
        self.shadow.as_ref().map_or(0, |s| s.bytes())
    }

    /// Multi-response entry: the response index only changes which
    /// per-column ±y/t shift the reduced operators apply, so members
    /// with different responses still share the gathered SV panel and
    /// the blocked-CG panel product (the panel stores bare design
    /// columns + label signs — it is y-independent). The prep's own
    /// `y` is never read.
    fn solve_batch_multi(
        &self,
        responses: &[Arc<Vec<f64>>],
        members: &[(usize, f64, f64)],
        _scratch: &mut SvmScratch,
        ctl: Option<&SolveCtl>,
    ) -> anyhow::Result<(Vec<SvmSolve>, SvmBatchStats)> {
        let ys: Vec<&[f64]> = members.iter().map(|&(r, _, _)| responses[r].as_slice()).collect();
        let points: Vec<PrimalBatchPoint> =
            members.iter().map(|&(_, t, c)| PrimalBatchPoint { t, c, w0: None }).collect();
        let (results, stats) = primal_newton_batch_ys(
            self.x.as_ref(),
            &ys,
            &points,
            &self.opts,
            self.shadow.as_ref(),
            ctl,
        );
        Ok((results.into_iter().map(primal_to_solve).collect(), stats))
    }

    fn solve_response(
        &self,
        y: &[f64],
        t: f64,
        c: f64,
        warm: Option<&SvmWarm>,
        _scratch: &mut SvmScratch,
        ctl: Option<&SolveCtl>,
    ) -> anyhow::Result<SvmSolve> {
        if ctl.is_some() {
            // Same width-1 batch routing as `solve`: the batched engine
            // is the one that polls the deadline.
            let ys = [y];
            let points =
                [PrimalBatchPoint { t, c, w0: warm.and_then(|w| w.w.clone()) }];
            let (mut rs, _) = primal_newton_batch_ys(
                self.x.as_ref(),
                &ys,
                &points,
                &self.opts,
                self.shadow.as_ref(),
                ctl,
            );
            let r = rs.pop().expect("width-1 batch returns one result");
            return Ok(primal_to_solve(r));
        }
        let samples = match &self.shadow {
            Some(sh) => ReducedSamples::with_shadow(self.x.as_ref(), y, t, sh),
            None => ReducedSamples::new(self.x.as_ref(), y, t),
        };
        let labels = reduction_labels(self.x.cols());
        let w0 = warm.and_then(|w| w.w.as_deref());
        let r = primal_newton(&samples, &labels, c, &self.opts, w0);
        Ok(primal_to_solve(r))
    }
}

struct PreparedDual {
    opts: DualOptions,
    g0: Mat,
    v: Vec<f64>,
    yy: f64,
    x: Arc<Design>,
    y: Arc<Vec<f64>>,
}

impl PreparedDual {
    /// Assemble K(t) from the cached, t-independent blocks in O(p²),
    /// row-parallel over the scoped pool, into a caller-owned buffer.
    fn gram_at_into(&self, t: f64, k: &mut Mat) {
        let s = 1.0 / t;
        crate::solvers::svm::samples::assemble_reduction_gram(
            &self.g0,
            &self.v,
            s,
            s * s * self.yy,
            k,
        );
    }
}

impl SvmPrep for PreparedDual {
    fn solve(
        &self,
        t: f64,
        c: f64,
        warm: Option<&SvmWarm>,
        scratch: &mut SvmScratch,
        ctl: Option<&SolveCtl>,
    ) -> anyhow::Result<SvmSolve> {
        let p = self.g0.rows();
        let k = scratch.mat(2 * p, 2 * p);
        self.gram_at_into(t, k);
        let warm_alpha = warm.and_then(|w| w.alpha.as_deref());
        let r = dual_newton(k, c, &self.opts, warm_alpha, ctl);
        // w = Ẑα is cheap and useful for warm starts: Ẑ = [X̂₁, −X̂₂]
        let p = self.x.cols();
        let samples = ReducedSamples::new(self.x.as_ref(), self.y.as_slice(), t);
        let mut signed = r.alpha.clone();
        for v in signed[p..].iter_mut() {
            *v = -*v;
        }
        let mut w = vec![0.0; self.x.rows()];
        samples.matvec_t(&signed, &mut w);
        Ok(SvmSolve {
            alpha: r.alpha,
            w: Some(w),
            iters: r.pivots,
            cg_iters: 0,
            gather_rebuilds: 0,
            refine_passes: 0,
            aborted: r.aborted,
            broken: r.broken,
        })
    }

    fn mode(&self) -> SvmMode {
        SvmMode::Dual
    }

    fn dims(&self) -> (usize, usize) {
        (self.x.rows(), self.x.cols())
    }

    /// Multi-response entry for the dual regime. No batched dual
    /// Newton exists yet (see ROADMAP), but the expensive t- and
    /// y-independent block `G₀ = XᵀX` is reused across the whole batch;
    /// only `v_r = Xᵀy_r` and `‖y_r‖²` are built, once per distinct
    /// response. Each member assembles `K(t)` and solves cold exactly
    /// like `solve(t, c, None, ..)` on a fresh `(x, y_r)` preparation,
    /// so results are bit-identical to the standalone path.
    fn solve_batch_multi(
        &self,
        responses: &[Arc<Vec<f64>>],
        members: &[(usize, f64, f64)],
        scratch: &mut SvmScratch,
        ctl: Option<&SolveCtl>,
    ) -> anyhow::Result<(Vec<SvmSolve>, SvmBatchStats)> {
        let p = self.g0.rows();
        let mut cache: Vec<Option<(Vec<f64>, f64)>> = vec![None; responses.len()];
        let mut out = Vec::with_capacity(members.len());
        for &(r, t, c) in members {
            if cache[r].is_none() {
                let y = responses[r].as_slice();
                cache[r] = Some((self.x.matvec_t(y), vecops::norm2_sq(y)));
            }
            let (v, yy) = {
                let (v, yy) = cache[r].as_ref().unwrap();
                (v.as_slice(), *yy)
            };
            let s = 1.0 / t;
            let k = scratch.mat(2 * p, 2 * p);
            crate::solvers::svm::samples::assemble_reduction_gram(&self.g0, v, s, s * s * yy, k);
            let rr = dual_newton(k, c, &self.opts, None, ctl);
            let samples = ReducedSamples::new(self.x.as_ref(), responses[r].as_slice(), t);
            let mut signed = rr.alpha.clone();
            for sv in signed[p..].iter_mut() {
                *sv = -*sv;
            }
            let mut w = vec![0.0; self.x.rows()];
            samples.matvec_t(&signed, &mut w);
            out.push(SvmSolve {
                alpha: rr.alpha,
                w: Some(w),
                iters: rr.pivots,
                cg_iters: 0,
                gather_rebuilds: 0,
                refine_passes: 0,
                aborted: rr.aborted,
                broken: rr.broken,
            });
        }
        Ok((out, SvmBatchStats::default()))
    }

    fn solve_response(
        &self,
        y: &[f64],
        t: f64,
        c: f64,
        warm: Option<&SvmWarm>,
        scratch: &mut SvmScratch,
        ctl: Option<&SolveCtl>,
    ) -> anyhow::Result<SvmSolve> {
        let p = self.g0.rows();
        let v = self.x.matvec_t(y);
        let yy = vecops::norm2_sq(y);
        let s = 1.0 / t;
        let k = scratch.mat(2 * p, 2 * p);
        crate::solvers::svm::samples::assemble_reduction_gram(&self.g0, &v, s, s * s * yy, k);
        let warm_alpha = warm.and_then(|w| w.alpha.as_deref());
        let r = dual_newton(k, c, &self.opts, warm_alpha, ctl);
        let samples = ReducedSamples::new(self.x.as_ref(), y, t);
        let mut signed = r.alpha.clone();
        for sv in signed[p..].iter_mut() {
            *sv = -*sv;
        }
        let mut w = vec![0.0; self.x.rows()];
        samples.matvec_t(&signed, &mut w);
        Ok(SvmSolve {
            alpha: r.alpha,
            w: Some(w),
            iters: r.pivots,
            cg_iters: 0,
            gather_rebuilds: 0,
            refine_passes: 0,
            aborted: r.aborted,
            broken: r.broken,
        })
    }
}

/// Validate that `reduction_gram` and the cached-block assembly agree —
/// exposed for tests and the runtime's own cross-checks.
pub fn gram_assembly_check(x: &Mat, y: &[f64], t: f64) -> f64 {
    let direct = reduction_gram(x, y, t);
    let design: Arc<Design> = Arc::new(x.clone().into());
    let prep = PreparedDual {
        opts: DualOptions::default(),
        g0: design.gram_t(),
        v: design.matvec_t(y),
        yy: vecops::norm2_sq(y),
        x: design,
        y: Arc::new(y.to_vec()),
    };
    let p = x.cols();
    let mut assembled = Mat::zeros(2 * p, 2 * p);
    prep.gram_at_into(t, &mut assembled);
    let mut max = 0.0f64;
    for i in 0..direct.rows() {
        for j in 0..direct.cols() {
            max = max.max((direct.get(i, j) - assembled.get(i, j)).abs());
        }
    }
    max
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;

    #[test]
    fn mode_resolution() {
        assert_eq!(SvmMode::Auto.resolve(10, 20), SvmMode::Primal); // 2p=40 > n=10
        assert_eq!(SvmMode::Auto.resolve(100, 20), SvmMode::Dual); // 2p=40 ≤ 100
        assert_eq!(SvmMode::Primal.resolve(100, 20), SvmMode::Primal);
        assert_eq!(SvmMode::Dual.resolve(10, 20), SvmMode::Dual);
    }

    #[test]
    fn gram_assembly_matches_direct() {
        let mut rng = Rng::seed_from(161);
        let x = Mat::from_fn(12, 5, |_, _| rng.normal());
        let y: Vec<f64> = (0..12).map(|_| rng.normal()).collect();
        for t in [0.1, 1.0, 10.0] {
            let dev = gram_assembly_check(&x, &y, t);
            assert!(dev < 1e-9, "t={t} dev={dev}");
        }
    }

    #[test]
    fn primal_dual_same_alpha_up_to_scale() {
        let mut rng = Rng::seed_from(162);
        let x: Arc<Design> = Arc::new(Mat::from_fn(30, 6, |_, _| rng.normal()).into());
        let y = Arc::new((0..30).map(|_| rng.normal()).collect::<Vec<f64>>());
        let backend = RustBackend::default();
        let prim = backend.prepare(&x, &y, SvmMode::Primal).unwrap();
        let dual = backend.prepare(&x, &y, SvmMode::Dual).unwrap();
        let (t, c) = (0.8, 5.0);
        let mut scratch = SvmScratch::new();
        let a = prim.solve(t, c, None, &mut scratch, None).unwrap().alpha;
        let b = dual.solve(t, c, None, &mut scratch, None).unwrap().alpha;
        for i in 0..12 {
            assert!((a[i] - b[i]).abs() < 1e-5, "i={i}: {} vs {}", a[i], b[i]);
        }
    }

    #[test]
    fn sparse_and_dense_preparations_agree() {
        // A sparse Design must produce the same SVM solution as its
        // densified twin, in both modes.
        let mut rng = Rng::seed_from(163);
        let m = Mat::from_fn(40, 9, |_, _| {
            if rng.bernoulli(0.25) {
                rng.normal()
            } else {
                0.0
            }
        });
        let y = Arc::new((0..40).map(|_| rng.normal()).collect::<Vec<f64>>());
        let dense: Arc<Design> = Arc::new(m.clone().into());
        let sparse: Arc<Design> =
            Arc::new(crate::linalg::Csr::from_dense(&m, 0.0).into());
        let backend = RustBackend::default();
        let mut scratch = SvmScratch::new();
        for mode in [SvmMode::Primal, SvmMode::Dual] {
            let pd = backend.prepare(&dense, &y, mode).unwrap();
            let ps = backend.prepare(&sparse, &y, mode).unwrap();
            let a = pd.solve(0.7, 4.0, None, &mut scratch, None).unwrap().alpha;
            let b = ps.solve(0.7, 4.0, None, &mut scratch, None).unwrap().alpha;
            for i in 0..18 {
                assert!(
                    (a[i] - b[i]).abs() < 1e-6,
                    "{mode:?} i={i}: {} vs {}",
                    a[i],
                    b[i]
                );
            }
        }
    }

    #[test]
    fn mixed_precision_prep_matches_f64_prep() {
        // A preparation resolved under MixedF32 must carry an f32 shadow,
        // refine at least once, and land within solver tolerance of the
        // pure-f64 preparation — for both solo and batched entry points.
        let mut rng = Rng::seed_from(165);
        let x: Arc<Design> = Arc::new(Mat::from_fn(14, 11, |_, _| rng.normal()).into());
        let y = Arc::new((0..14).map(|_| rng.normal()).collect::<Vec<f64>>());
        let backend = RustBackend::default();
        let mut scratch = SvmScratch::new();
        let f64_prep = crate::linalg::with_precision(crate::linalg::Precision::F64, || {
            backend.prepare(&x, &y, SvmMode::Primal).unwrap()
        });
        let mix_prep =
            crate::linalg::with_precision(crate::linalg::Precision::MixedF32, || {
                backend.prepare(&x, &y, SvmMode::Primal).unwrap()
            });
        assert_eq!(f64_prep.f32_shadow_bytes(), 0);
        assert!(mix_prep.f32_shadow_bytes() > 0, "mixed prep holds no shadow");
        let (t, c) = (0.7, 4.0);
        let a = f64_prep.solve(t, c, None, &mut scratch, None).unwrap();
        let b = mix_prep.solve(t, c, None, &mut scratch, None).unwrap();
        assert_eq!(a.refine_passes, 0);
        assert!(b.refine_passes > 0, "mixed solve never refined");
        let wa = a.w.as_ref().unwrap();
        let wb = b.w.as_ref().unwrap();
        for i in 0..wa.len() {
            assert!((wa[i] - wb[i]).abs() < 1e-6, "i={i}: {} vs {}", wa[i], wb[i]);
        }
        let pts = [(0.5, 3.0), (0.7, 4.0)];
        let (bs, _) = mix_prep.solve_batch(&pts, &mut scratch, None).unwrap();
        let (fs, _) = f64_prep.solve_batch(&pts, &mut scratch, None).unwrap();
        for (sb, sf) in bs.iter().zip(&fs) {
            assert!(sb.refine_passes > 0);
            let (wb, wf) = (sb.w.as_ref().unwrap(), sf.w.as_ref().unwrap());
            for i in 0..wf.len() {
                assert!((wb[i] - wf[i]).abs() < 1e-6, "batch i={i}");
            }
        }
    }

    #[test]
    fn multi_response_batches_match_fresh_preps_bitwise() {
        // solve_batch_multi never reads the prep's own y: a batch solved
        // against a prep built on r0 must reproduce, bit for bit, fresh
        // preps of (x, r) solved cold — in both regimes.
        let mut rng = Rng::seed_from(166);
        let x: Arc<Design> = Arc::new(Mat::from_fn(26, 7, |_, _| rng.normal()).into());
        let r0 = Arc::new((0..26).map(|_| rng.normal()).collect::<Vec<f64>>());
        let r1 = Arc::new((0..26).map(|_| rng.normal()).collect::<Vec<f64>>());
        let responses = vec![r0.clone(), r1.clone()];
        let members = [(0usize, 0.6, 3.0), (1usize, 0.6, 3.0), (1usize, 0.9, 5.0)];
        let backend = RustBackend::default();
        let mut scratch = SvmScratch::new();
        for mode in [SvmMode::Primal, SvmMode::Dual] {
            let prep = backend.prepare(&x, &r0, mode).unwrap();
            let (sols, _) =
                prep.solve_batch_multi(&responses, &members, &mut scratch, None).unwrap();
            for (sol, &(r, t, c)) in sols.iter().zip(members.iter()) {
                let solo_prep = backend.prepare(&x, &responses[r], mode).unwrap();
                let solo = solo_prep.solve(t, c, None, &mut scratch, None).unwrap();
                assert_eq!(sol.alpha.len(), solo.alpha.len());
                for i in 0..sol.alpha.len() {
                    assert_eq!(
                        sol.alpha[i].to_bits(),
                        solo.alpha[i].to_bits(),
                        "{mode:?} alpha i={i}"
                    );
                }
                let (w, ws) = (sol.w.as_ref().unwrap(), solo.w.as_ref().unwrap());
                for i in 0..w.len() {
                    assert_eq!(w[i].to_bits(), ws[i].to_bits(), "{mode:?} w i={i}");
                }
            }
        }
    }

    #[test]
    fn preps_are_shareable_across_threads() {
        // The coordinator contract: one Arc<dyn SvmPrep> solved from
        // several threads at once (each with its own scratch) must give
        // identical results.
        let mut rng = Rng::seed_from(164);
        let x: Arc<Design> = Arc::new(Mat::from_fn(24, 7, |_, _| rng.normal()).into());
        let y = Arc::new((0..24).map(|_| rng.normal()).collect::<Vec<f64>>());
        let backend = RustBackend::default();
        let prep = backend.prepare(&x, &y, SvmMode::Dual).unwrap();
        let mut scratch = SvmScratch::new();
        let reference = prep.solve(0.9, 3.0, None, &mut scratch, None).unwrap().alpha;
        let handles: Vec<_> = (0..3)
            .map(|_| {
                let prep = prep.clone();
                std::thread::spawn(move || {
                    let mut scratch = SvmScratch::new();
                    prep.solve(0.9, 3.0, None, &mut scratch, None).unwrap().alpha
                })
            })
            .collect();
        for h in handles {
            let alpha = h.join().unwrap();
            for i in 0..14 {
                assert_eq!(alpha[i].to_bits(), reference[i].to_bits(), "i={i}");
            }
        }
    }
}
